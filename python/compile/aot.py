"""AOT pipeline (S12): lower the L2 model (with L1 Pallas kernels) to HLO
text artifacts the Rust runtime loads via PJRT, and export float weights
in the `INHWGT01` binary format `rust/src/model/weights.rs` reads.

HLO *text* is the interchange format — jax ≥ 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts written to --out-dir:
    attn_<mech>_t<seq>.hlo.txt   one attention head per (mechanism, T)
    model_<mech>.hlo.txt         full 1-layer transformer forward
    model_<mech>.weights.bin     float weights for the Rust integer engine
    manifest.json                catalog consumed by runtime/registry.rs
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.dotprod import dotprod_attention_pallas
from .kernels.inhibitor import inhibitor_attention_pallas
from .model import ModelCfg, forward, init_params

jax.config.update("jax_platform_name", "cpu")

# The sequence lengths of the paper's scaling experiments (Tables 3/4
# float-path analogue) — one artifact per (mechanism, T).
ATTN_SEQ_LENS = (32, 64, 128, 256)
ATTN_DIM = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_attention(mechanism: str, seq_len: int, dim: int = ATTN_DIM) -> str:
    spec = jax.ShapeDtypeStruct((seq_len, dim), jnp.float32)

    if mechanism == "dotprod":
        def fn(q, k, v):
            return (dotprod_attention_pallas(q, k, v),)
    elif mechanism == "inhibitor":
        def fn(q, k, v):
            return (inhibitor_attention_pallas(q, k, v),)
    elif mechanism == "inhibitor-signed":
        def fn(q, k, v):
            return (inhibitor_attention_pallas(q, k, v, signed=True),)
    else:
        raise ValueError(mechanism)

    return to_hlo_text(jax.jit(fn).lower(spec, spec, spec))


def lower_model(cfg: ModelCfg, params) -> str:
    if cfg.vocab > 0:
        spec = jax.ShapeDtypeStruct((cfg.seq_len,), jnp.int32)
    else:
        spec = jax.ShapeDtypeStruct((cfg.seq_len, cfg.in_features), jnp.float32)

    def fn(x):
        return (forward(params, x, cfg, use_pallas=True),)

    return to_hlo_text(jax.jit(fn).lower(spec))


def export_weights(params: dict, path: str):
    """Write the INHWGT01 binary format (see rust/src/model/weights.rs)."""
    with open(path, "wb") as f:
        f.write(b"INHWGT01")
        f.write(struct.pack("<I", len(params)))
        for name in sorted(params):
            t = np.asarray(params[name], np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", t.ndim))
            for d in t.shape:
                f.write(struct.pack("<I", d))
            f.write(t.tobytes())


def model_config_json(cfg: ModelCfg) -> dict:
    return {
        "mechanism": cfg.mechanism,
        "n_layers": cfg.n_layers,
        "seq_len": cfg.seq_len,
        "dim": cfg.dim,
        "ffn_dim": cfg.ffn_dim,
        "vocab": cfg.vocab,
        "in_features": cfg.in_features,
        "head": cfg.head,
        "n_classes": cfg.n_classes,
        "act_bits": 16,
        "weight_bits": 8,
        "alpha": cfg.alpha,
        "gamma": cfg.gamma,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only lower the T=32 heads (fast dev loop)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"attention": [], "models": []}

    seq_lens = ATTN_SEQ_LENS[:1] if args.quick else ATTN_SEQ_LENS
    for mech in ("dotprod", "inhibitor", "inhibitor-signed"):
        for t in seq_lens:
            name = f"attn_{mech}_t{t}"
            text = lower_attention(mech, t)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["attention"].append(
                {"name": name, "mechanism": mech, "seq_len": t,
                 "dim": ATTN_DIM, "file": f"{name}.hlo.txt"}
            )
            print(f"lowered {name}: {len(text)} chars")

    # Full model artifacts: one per mechanism, the quickstart scenario
    # (continuous-input regressor shaped like the adding task).
    for mech in ("dotprod", "inhibitor"):
        cfg = ModelCfg(mechanism=mech, seq_len=16, dim=32, ffn_dim=64,
                       in_features=2, head="regress")
        params = init_params(jax.random.PRNGKey(0), cfg)
        name = f"model_{mech}"
        text = lower_model(cfg, params)
        with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        export_weights(params, os.path.join(args.out_dir, f"{name}.weights.bin"))
        manifest["models"].append(
            {"name": name, "config": model_config_json(cfg),
             "file": f"{name}.hlo.txt", "weights": f"{name}.weights.bin"}
        )
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['attention'])} heads, "
          f"{len(manifest['models'])} models")


if __name__ == "__main__":
    main()
