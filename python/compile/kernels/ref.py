"""Pure-jnp reference oracles for the attention kernels (L1 ground truth).

These transcribe the paper's equations directly (no fusion tricks) and are
the single source of truth the Pallas kernels, the JAX model and — via the
exported test vectors — the Rust engines are validated against.
"""

import jax.numpy as jnp


def dotprod_attention(q, k, v):
    """Conventional scaled dot-product attention (paper eq. 3 + H = S·V)."""
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    s = s / s.sum(axis=-1, keepdims=True)
    return s @ v


def inhibitor_scores(q, k, gamma=None, alpha=0.5):
    """Manhattan inhibition score, paper eq. 5 with the shifted Z'.

    Z_ij = (1/gamma) * sum_k |Q_ik - K_jk|;  Z' = relu(Z - alpha).
    """
    d = q.shape[-1]
    if gamma is None:
        gamma = jnp.sqrt(jnp.asarray(d, q.dtype))
    z = jnp.abs(q[:, None, :] - k[None, :, :]).sum(-1) / gamma
    return jnp.maximum(z - alpha, 0.0)


def inhibitor_attention(q, k, v, gamma=None, alpha=0.5):
    """Unsigned inhibition, paper eq. 6: H_ik = sum_j relu(V_jk - Z_ij)."""
    z = inhibitor_scores(q, k, gamma, alpha)
    return jnp.maximum(v[None, :, :] - z[:, :, None], 0.0).sum(axis=1)


def inhibitor_attention_signed(q, k, v, gamma=None, alpha=0.5):
    """Signed inhibition, paper eq. 7."""
    z = inhibitor_scores(q, k, gamma, alpha)
    vp = jnp.maximum(v, 0.0)[None, :, :]
    vn = jnp.minimum(v, 0.0)[None, :, :]
    zz = z[:, :, None]
    return (jnp.maximum(vp - zz, 0.0) + jnp.minimum(vn + zz, 0.0)).sum(axis=1)


def inhibitor_attention_fused(q, k, v, gamma=None, alpha=0.5):
    """Appendix eq. 9: memory-lean rewrite via x+ = (x + |x|)/2.

    2*H_ik = sum_j V_jk - sum_j Z_ij + sum_j |V_jk - Z_ij|.
    Still materializes Z (n, n) but never the (n, n, d) broadcast.
    """
    z = inhibitor_scores(q, k, gamma, alpha)
    sum_v = v.sum(axis=0)[None, :]
    sum_z = z.sum(axis=1)[:, None]
    sum_abs = jnp.abs(v[None, :, :] - z[:, :, None]).sum(axis=1)
    return 0.5 * (sum_v - sum_z + sum_abs)


def inhibitor_attention_signed_fused(q, k, v, gamma=None, alpha=0.5):
    """Appendix eq. 10 (signed fused form)."""
    z = inhibitor_scores(q, k, gamma, alpha)
    vp = jnp.maximum(v, 0.0)
    vn = jnp.minimum(v, 0.0)
    sum_v = v.sum(axis=0)[None, :]
    t1 = jnp.abs(vp[None, :, :] - z[:, :, None]).sum(axis=1)
    t2 = jnp.abs(vn[None, :, :] + z[:, :, None]).sum(axis=1)
    return 0.5 * (sum_v + t1 - t2)
