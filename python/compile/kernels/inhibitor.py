"""L1 Pallas kernel: fused Inhibitor attention (paper eqs. 5-10).

TPU adaptation of the paper's torch.cdist trick (DESIGN.md
SS Hardware-Adaptation): Q/K/V are tiled into VMEM blocks via BlockSpec; a
2-D grid walks (query block, key block). Inside a block the |Q-K| and
|V-Z| reductions run on the VPU - deliberately *no* MXU matmul, mirroring
the mechanism's multiplication-free design. The (n, n, d) broadcast the
appendix warns about exists only block-locally ((Bq, Bk, d) in VMEM,
never in HBM).

Per-block math (appendix eq. 9, kept x2 to stay exact - the caller halves):
    Z_blk   = relu(cdist1(Q_blk, K_blk)/gamma - alpha)          (Bq, Bk)
    acc    += sum_j V_blk - sum_j Z_blk + sum_j |V_blk - Z_blk|  (Bq, d)

VMEM footprint per grid step (f32): Bq*d + 2*Bk*d + Bq*Bk + Bq*Bk*d + Bq*d
bytes*4; with Bq=Bk=128, d=64 that is ~4.4 MiB - comfortably inside the
~16 MiB VMEM of a TPU core. interpret=True everywhere (CPU PJRT cannot run
Mosaic custom-calls); the BlockSpec schedule is still exercised.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inhibitor_block_kernel(q_ref, k_ref, v_ref, o_ref, *, gamma, alpha, signed):
    j = pl.program_id(1)
    q = q_ref[...]  # (Bq, d)
    k = k_ref[...]  # (Bk, d)
    v = v_ref[...]  # (Bk, d)

    # Manhattan scores for this tile: (Bq, Bk). The (Bq, Bk, d) broadcast
    # lives only in VMEM/registers for this block.
    z = jnp.abs(q[:, None, :] - k[None, :, :]).sum(-1) / gamma
    z = jnp.maximum(z - alpha, 0.0)

    if signed:
        # eq. 10: 2H += sum_j V + sum_j |V+ - Z| - sum_j |V- + Z|
        vp = jnp.maximum(v, 0.0)
        vn = jnp.minimum(v, 0.0)
        part = (
            v.sum(axis=0)[None, :]
            + jnp.abs(vp[None, :, :] - z[:, :, None]).sum(axis=1)
            - jnp.abs(vn[None, :, :] + z[:, :, None]).sum(axis=1)
        )
    else:
        # eq. 9: 2H += sum_j V - sum_j Z + sum_j |V - Z|
        part = (
            v.sum(axis=0)[None, :]
            - z.sum(axis=1)[:, None]
            + jnp.abs(v[None, :, :] - z[:, :, None]).sum(axis=1)
        )

    # Accumulate across key blocks: same output block for every j.
    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def inhibitor_attention_pallas(
    q, k, v, gamma=None, alpha=0.5, *, signed=False, block_q=None, block_k=None
):
    """Fused inhibitor attention via Pallas. Returns H (n, d).

    q, k, v: (n, d) arrays (a single head). Block sizes default to
    min(n, 128) - the VMEM-friendly tile discussed in the module docstring.
    """
    n, d = q.shape
    if gamma is None:
        gamma = float(d) ** 0.5
    bq = block_q or min(n, 128)
    bk = block_k or min(n, 128)
    assert n % bq == 0 and n % bk == 0, "sequence length must tile evenly"

    kernel = functools.partial(
        _inhibitor_block_kernel, gamma=gamma, alpha=alpha, signed=signed
    )
    h2 = pl.pallas_call(
        kernel,
        grid=(n // bq, n // bk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),  # Q: per query tile
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),  # K: per key tile
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),  # V: rides with K
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),  # revisited over j
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(q, k, v)
    return 0.5 * h2
