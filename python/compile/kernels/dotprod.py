"""L1 Pallas kernel: scaled dot-product attention baseline.

The comparator kernel for the paper's Table 3/4 float-path analogue. Uses
the MXU-shaped matmul (what the Inhibitor removes) with a row-block grid:
each grid step holds one query tile and the full K/V in VMEM (the bench
shapes are small; a production flash-style two-level grid is unnecessary
here and would not change the comparison).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dotprod_block_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[...]  # (Bq, d)
    k = k_ref[...]  # (n, d)
    v = v_ref[...]  # (n, d)
    d = q.shape[-1]
    s = q @ k.T / jnp.sqrt(jnp.asarray(d, q.dtype))  # MXU matmul
    s = s - s.max(axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / e.sum(axis=-1, keepdims=True)
    o_ref[...] = p @ v


def dotprod_attention_pallas(q, k, v, *, block_q=None):
    """Dot-product attention via Pallas. q, k, v: (n, d); returns (n, d)."""
    n, d = q.shape
    bq = block_q or min(n, 128)
    assert n % bq == 0, "sequence length must tile evenly"
    return pl.pallas_call(
        _dotprod_block_kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(q, k, v)
