"""Table 1 training experiments (E1): both attention mechanisms on the
four benchmark tasks, multiple seeds, significance-style reporting.

Build-time only. Hand-rolled Adam (optax is not in the image) and a pure
JAX CTC loss for the handwriting task. Run via `make table1` or:

    cd python && python -m compile.train --all --out ../results/table1.json
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .model import ModelCfg, forward_batch, init_params

jax.config.update("jax_platform_name", "cpu")


# ----------------------------------------------------------------------
# Optimizer (Adam)
# ----------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------

def mse_loss(params, xs, ys, cfg):
    pred = forward_batch(params, xs, cfg)
    return jnp.mean((pred - ys) ** 2)


def xent_loss(params, xs, ys, cfg):
    logits = forward_batch(params, xs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=-1))


def ctc_loss_single(log_probs, labels):
    """CTC forward algorithm (log space) for one example.

    log_probs: (T, C) log-softmax outputs, class 0 = blank.
    labels: (L,) targets in [1, C).
    """
    t_len, _ = log_probs.shape
    lab_len = labels.shape[0]
    # Extended label sequence: blank, l1, blank, l2, ... blank  (2L+1).
    ext = jnp.zeros(2 * lab_len + 1, jnp.int32)
    ext = ext.at[1::2].set(labels)
    s = 2 * lab_len + 1
    neg_inf = -1e30
    alpha = jnp.full((s,), neg_inf)
    alpha = alpha.at[0].set(log_probs[0, 0])
    alpha = alpha.at[1].set(log_probs[0, ext[1]])

    def step(alpha, lp):
        prev1 = jnp.concatenate([jnp.array([neg_inf]), alpha[:-1]])
        prev2 = jnp.concatenate([jnp.array([neg_inf, neg_inf]), alpha[:-2]])
        # Skip transition allowed when current label != label two back and
        # current is not a blank.
        can_skip = (ext != jnp.concatenate([jnp.array([-1, -1]), ext[:-2]])) & (ext != 0)
        best = jnp.logaddexp(alpha, prev1)
        best = jnp.where(can_skip, jnp.logaddexp(best, prev2), best)
        alpha = best + lp[ext]
        return alpha, None

    alpha, _ = jax.lax.scan(step, alpha, log_probs[1:])
    return -jnp.logaddexp(alpha[-1], alpha[-2])


def ctc_loss(params, xs, labels, cfg):
    logits = forward_batch(params, xs, cfg)  # (B, T, C)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.mean(jax.vmap(ctc_loss_single)(logp, labels))


def ctc_greedy_decode(logits):
    """Best-path decoding: argmax, collapse repeats, drop blanks."""
    path = np.asarray(logits).argmax(-1)
    out = []
    prev = -1
    for p in path:
        if p != prev and p != 0:
            out.append(int(p))
        prev = p
    return out


# ----------------------------------------------------------------------
# Task runners
# ----------------------------------------------------------------------

def _train(cfg, loss_fn, make_batch, steps, seed, lr=2e-3, log=None):
    rng = jax.random.PRNGKey(seed)
    params = init_params(rng, cfg)
    state = adam_init(params)
    value_and_grad = jax.jit(jax.value_and_grad(lambda p, x, y: loss_fn(p, x, y, cfg)))
    curve = []
    for step in range(steps):
        xs, ys = make_batch(step)
        loss, grads = value_and_grad(params, xs, ys)
        params, state = adam_step(params, grads, state, lr=lr)
        if log is not None and (step % max(1, steps // 20) == 0 or step == steps - 1):
            curve.append((step, float(loss)))
            log(f"    step {step:4d}  loss {float(loss):.5f}")
    return params, curve


def run_adding(mechanism, seed, steps=400, log=None):
    cfg = ModelCfg(
        mechanism=mechanism, seq_len=100, dim=24, ffn_dim=48,
        in_features=2, head="regress",
    )
    np_rng = np.random.default_rng(seed)

    def make_batch(_step):
        return datasets.adding(np_rng, 32, cfg.seq_len)

    params, curve = _train(cfg, mse_loss, make_batch, steps, seed, log=log)
    xt, yt = datasets.adding(np.random.default_rng(seed + 10_000), 512, cfg.seq_len)
    mse = float(jnp.mean((forward_batch(params, xt, cfg) - yt) ** 2))
    return {"metric": "mse", "value": mse, "curve": curve}


def run_digits(mechanism, seed, steps=400, log=None):
    cfg = ModelCfg(
        mechanism=mechanism, seq_len=8, dim=32, ffn_dim=64,
        in_features=8, head="classify", n_classes=10,
    )
    np_rng = np.random.default_rng(seed)

    def make_batch(_step):
        return datasets.digits(np_rng, 64)

    params, curve = _train(cfg, xent_loss, make_batch, steps, seed, log=log)
    xt, yt = datasets.digits(np.random.default_rng(seed + 10_000), 1024)
    pred = np.asarray(forward_batch(params, xt, cfg)).argmax(-1)
    acc = float((pred == yt).mean())
    return {"metric": "acc", "value": acc, "curve": curve}


def run_sentiment(mechanism, seed, steps=400, log=None):
    cfg = ModelCfg(
        mechanism=mechanism, seq_len=32, dim=32, ffn_dim=64,
        vocab=datasets.sentiment_vocab(), head="classify", n_classes=2,
    )
    np_rng = np.random.default_rng(seed)

    def make_batch(_step):
        return datasets.sentiment(np_rng, 64, cfg.seq_len)

    params, curve = _train(cfg, xent_loss, make_batch, steps, seed, log=log)
    xt, yt = datasets.sentiment(np.random.default_rng(seed + 10_000), 1024, cfg.seq_len)
    pred = np.asarray(forward_batch(params, xt, cfg)).argmax(-1)
    acc = float((pred == yt).mean())
    return {"metric": "acc", "value": acc, "curve": curve}


def run_handwriting(mechanism, seed, steps=400, log=None):
    t = datasets.HW_WORD_LEN * datasets.HW_FRAMES_PER_CHAR
    cfg = ModelCfg(
        mechanism=mechanism, seq_len=t, dim=32, ffn_dim=64,
        in_features=datasets.HW_FEATURES, head="per_position",
        n_classes=datasets.HW_ALPHABET + 1,  # + CTC blank
    )
    np_rng = np.random.default_rng(seed)

    def make_batch(_step):
        return datasets.handwriting(np_rng, 32)

    params, curve = _train(cfg, ctc_loss, make_batch, steps, seed, log=log)
    xt, yt = datasets.handwriting(np.random.default_rng(seed + 10_000), 256)
    logits = np.asarray(forward_batch(params, xt, cfg))
    dist = 0.0
    for b in range(xt.shape[0]):
        dist += datasets.edit_distance(ctc_greedy_decode(logits[b]), list(yt[b]))
    # Report mean edit distance ×10 to land in the paper's 17-19 scale
    # units (the paper's absolute value depends on the IAM label lengths).
    return {"metric": "edit", "value": dist / xt.shape[0], "curve": curve}


TASKS = {
    "adding": run_adding,
    "digits": run_digits,
    "sentiment": run_sentiment,
    "handwriting": run_handwriting,
}

MECHANISMS = ["dotprod", "inhibitor"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--task", choices=sorted(TASKS), default=None)
    ap.add_argument("--mechanism", choices=MECHANISMS + ["inhibitor-signed"])
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default=None)
    ap.add_argument("--ablation", action="store_true",
                    help="also run the signed-inhibitor variant")
    args = ap.parse_args()

    tasks = sorted(TASKS) if args.all or not args.task else [args.task]
    mechs = list(MECHANISMS)
    if args.ablation:
        mechs.append("inhibitor-signed")
    if args.mechanism:
        mechs = [args.mechanism]

    results = {}
    for task in tasks:
        for mech in mechs:
            vals = []
            for seed in range(args.seeds):
                t0 = time.time()
                r = TASKS[task](mech, seed, steps=args.steps, log=print)
                vals.append(r["value"])
                print(f"{task:12s} {mech:18s} seed={seed} "
                      f"{r['metric']}={r['value']:.4f} ({time.time()-t0:.1f}s)")
            arr = np.asarray(vals)
            results[f"{task}/{mech}"] = {
                "metric": r["metric"],
                "mean": float(arr.mean()),
                "std": float(arr.std(ddof=1)) if len(arr) > 1 else 0.0,
                "values": vals,
            }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")
    for k, v in results.items():
        print(f"{k:32s} {v['metric']:5s} {v['mean']:.4f} ± {v['std']:.4f}")


if __name__ == "__main__":
    main()
