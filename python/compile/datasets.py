"""Synthetic task generators for the Table 1 benchmark suite (S11).

The image has no network access, so MNIST / IMDB / IAM are replaced by
synthetic analogues that exercise identical code paths (DESIGN.md §3).
The Table 1 claim being reproduced is *parity between the two attention
mechanisms trained on the same data*, which these analogues preserve —
both mechanisms always see identical datasets and seeds.

* `adding`     — the Hochreiter & Schmidhuber (1997) adding problem,
                 generated exactly as in the paper (length-100 sequences).
* `digits`     — "MNIST-like": 8×8 class-conditional stroke-template
                 images with pixel noise, 10 classes.
* `sentiment`  — "IMDB-like": token sequences from class-correlated
                 lexicons (positive/negative vocabulary mix), 2 classes.
* `handwriting`— "IAMW-like": glyph sequences rendered to noisy feature
                 frames, labelled with character strings for CTC training
                 and edit-distance evaluation.
"""

import numpy as np


def adding(rng: np.random.Generator, n_samples: int, seq_len: int = 100):
    """Inputs (B, T, 2): uniform numbers + two-hot markers; target = dot."""
    numbers = rng.uniform(0.0, 1.0, size=(n_samples, seq_len)).astype(np.float32)
    marks = np.zeros((n_samples, seq_len), np.float32)
    for b in range(n_samples):
        i, j = rng.choice(seq_len, size=2, replace=False)
        marks[b, i] = 1.0
        marks[b, j] = 1.0
    x = np.stack([numbers, marks], axis=-1)
    y = (numbers * marks).sum(-1, keepdims=True)
    return x, y


_DIGIT_TEMPLATES = None


def _digit_templates(rng: np.random.Generator):
    """Fixed per-class stroke patterns on an 8×8 grid (seeded once)."""
    global _DIGIT_TEMPLATES
    if _DIGIT_TEMPLATES is None:
        t_rng = np.random.default_rng(12345)  # class templates are fixed
        templates = []
        for _ in range(10):
            img = np.zeros((8, 8), np.float32)
            # A few random strokes per class.
            for _ in range(4):
                r0, c0 = t_rng.integers(0, 8, 2)
                dr, dc = t_rng.integers(-1, 2, 2)
                r, c = r0, c0
                for _ in range(5):
                    img[r % 8, c % 8] = 1.0
                    r, c = r + dr, c + dc
            templates.append(img)
        _DIGIT_TEMPLATES = np.stack(templates)
    del rng
    return _DIGIT_TEMPLATES


def digits(rng: np.random.Generator, n_samples: int):
    """8×8 noisy template images → sequence of 8 row-vectors. 10 classes."""
    templates = _digit_templates(rng)
    labels = rng.integers(0, 10, n_samples)
    imgs = templates[labels] + rng.normal(0.0, 0.35, size=(n_samples, 8, 8))
    return imgs.astype(np.float32), labels.astype(np.int32)


# Class-correlated lexicons: tokens 2..101 positive-ish, 102..201 negative-ish;
# 0 = pad, 1 = neutral filler.
_SENT_VOCAB = 202


def sentiment(rng: np.random.Generator, n_samples: int, seq_len: int = 32):
    """Token sequences with class-dependent lexicon mixing. 2 classes."""
    labels = rng.integers(0, 2, n_samples)
    xs = np.empty((n_samples, seq_len), np.int32)
    for b in range(n_samples):
        pos_p = 0.62 if labels[b] == 1 else 0.38
        kinds = rng.random(seq_len)
        toks = np.where(
            kinds < 0.3,
            1,  # neutral filler
            np.where(
                rng.random(seq_len) < pos_p,
                rng.integers(2, 102, seq_len),
                rng.integers(102, 202, seq_len),
            ),
        )
        xs[b] = toks
    return xs, labels.astype(np.int32)


def sentiment_vocab():
    return _SENT_VOCAB


# Handwriting task: alphabet of 8 characters + CTC blank (index 0).
HW_ALPHABET = 8
HW_FRAMES_PER_CHAR = 3
HW_WORD_LEN = 4
HW_FEATURES = 12

_GLYPHS = None


def _glyphs():
    global _GLYPHS
    if _GLYPHS is None:
        g_rng = np.random.default_rng(777)
        # Each character renders to FRAMES_PER_CHAR fixed feature frames.
        _GLYPHS = g_rng.normal(
            0.0, 1.0, size=(HW_ALPHABET, HW_FRAMES_PER_CHAR, HW_FEATURES)
        ).astype(np.float32)
    return _GLYPHS


def handwriting(rng: np.random.Generator, n_samples: int):
    """Noisy glyph-frame sequences + character labels (for CTC).

    Returns x (B, T, F) with T = WORD_LEN·FRAMES_PER_CHAR, and labels
    (B, WORD_LEN) with values in [1, ALPHABET] (0 is the CTC blank).
    """
    glyphs = _glyphs()
    t = HW_WORD_LEN * HW_FRAMES_PER_CHAR
    labels = rng.integers(1, HW_ALPHABET + 1, size=(n_samples, HW_WORD_LEN))
    xs = np.empty((n_samples, t, HW_FEATURES), np.float32)
    for b in range(n_samples):
        frames = [glyphs[c - 1] for c in labels[b]]
        xs[b] = np.concatenate(frames, axis=0)
    xs += rng.normal(0.0, 0.3, size=xs.shape).astype(np.float32)
    return xs, labels.astype(np.int32)


def edit_distance(a, b):
    """Levenshtein distance between two sequences."""
    la, lb = len(a), len(b)
    dp = np.arange(lb + 1)
    for i in range(1, la + 1):
        prev = dp.copy()
        dp[0] = i
        for j in range(1, lb + 1):
            cost = 0 if a[i - 1] == b[j - 1] else 1
            dp[j] = min(prev[j] + 1, dp[j - 1] + 1, prev[j - 1] + cost)
    return int(dp[lb])
