"""L2: the JAX transformer (build-time).

Pure-pytree implementation (flax/optax are not in the image): `init_params`
builds the weight pytree, `forward` runs the model with either attention
mechanism. The Pallas kernels from `kernels/` are used on the AOT/inference
path (`use_pallas=True`); training uses the mathematically identical fused
jnp references (interpret-mode Pallas would slow training pointlessly).

The module mirrors `rust/src/model/` exactly: same block structure
(pre-LN), same head kinds, same weight names in the export — the Rust
engine loads `export_weights` output directly.
"""

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.dotprod import dotprod_attention_pallas
from .kernels.inhibitor import inhibitor_attention_pallas


@dataclass(frozen=True)
class ModelCfg:
    mechanism: str = "inhibitor"  # dotprod | inhibitor | inhibitor-signed
    n_layers: int = 1
    seq_len: int = 16
    dim: int = 32
    ffn_dim: int = 64
    vocab: int = 0          # 0 => continuous inputs
    in_features: int = 2
    head: str = "regress"   # regress | classify | per_position
    n_classes: int = 1
    alpha: float = 0.5
    gamma: float = -1.0     # <=0 => sqrt(dim)

    def with_(self, **kw):
        return replace(self, **kw)


def _glorot(rng, shape):
    fan_in = shape[-1]
    return jax.random.normal(rng, shape, jnp.float32) / jnp.sqrt(fan_in)


def init_params(rng, cfg: ModelCfg):
    """Build the parameter pytree (names match the Rust weight loader)."""
    keys = iter(jax.random.split(rng, 64))
    p = {}
    if cfg.vocab > 0:
        p["embedding.table"] = 0.5 * jax.random.normal(
            next(keys), (cfg.vocab, cfg.dim), jnp.float32
        )
    else:
        p["in_proj.w"] = _glorot(next(keys), (cfg.dim, cfg.in_features))
        p["in_proj.b"] = jnp.zeros((cfg.dim,))
    for i in range(cfg.n_layers):
        pre = f"block{i}"
        for name in ("wq", "wk", "wv", "wo"):
            p[f"{pre}.{name}.w"] = _glorot(next(keys), (cfg.dim, cfg.dim))
            p[f"{pre}.{name}.b"] = jnp.zeros((cfg.dim,))
        p[f"{pre}.ffn.fc1.w"] = _glorot(next(keys), (cfg.ffn_dim, cfg.dim))
        p[f"{pre}.ffn.fc1.b"] = jnp.zeros((cfg.ffn_dim,))
        p[f"{pre}.ffn.fc2.w"] = _glorot(next(keys), (cfg.dim, cfg.ffn_dim))
        p[f"{pre}.ffn.fc2.b"] = jnp.zeros((cfg.dim,))
        for ln in ("ln1", "ln2"):
            p[f"{pre}.{ln}.gamma"] = jnp.ones((cfg.dim,))
            p[f"{pre}.{ln}.beta"] = jnp.zeros((cfg.dim,))
    n_out = cfg.n_classes if cfg.head in ("classify", "per_position") else 1
    p["head.w"] = _glorot(next(keys), (n_out, cfg.dim))
    p["head.b"] = jnp.zeros((n_out,))
    return p


def _layernorm(x, gamma, beta):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * gamma + beta


def _attention(cfg: ModelCfg, q, k, v, use_pallas: bool):
    gamma = None if cfg.gamma <= 0 else cfg.gamma
    if cfg.mechanism == "dotprod":
        fn = dotprod_attention_pallas if use_pallas else ref.dotprod_attention
        return fn(q, k, v)
    signed = cfg.mechanism == "inhibitor-signed"
    if use_pallas:
        bq = _tile(cfg.seq_len)
        return inhibitor_attention_pallas(
            q, k, v, gamma=gamma, alpha=cfg.alpha, signed=signed,
            block_q=bq, block_k=bq,
        )
    fn = ref.inhibitor_attention_signed_fused if signed else ref.inhibitor_attention_fused
    return fn(q, k, v, gamma=gamma, alpha=cfg.alpha)


def _tile(n):
    """Largest power-of-two tile ≤ min(n, 128) that divides n."""
    t = 1
    while t * 2 <= min(n, 128) and n % (t * 2) == 0:
        t *= 2
    return t


def _block(params, pre, cfg: ModelCfg, x, use_pallas: bool):
    xn = _layernorm(x, params[f"{pre}.ln1.gamma"], params[f"{pre}.ln1.beta"])
    q = xn @ params[f"{pre}.wq.w"].T + params[f"{pre}.wq.b"]
    k = xn @ params[f"{pre}.wk.w"].T + params[f"{pre}.wk.b"]
    v = xn @ params[f"{pre}.wv.w"].T + params[f"{pre}.wv.b"]
    h = _attention(cfg, q, k, v, use_pallas)
    h = h @ params[f"{pre}.wo.w"].T + params[f"{pre}.wo.b"]
    x = x + h
    xn = _layernorm(x, params[f"{pre}.ln2.gamma"], params[f"{pre}.ln2.beta"])
    f = jnp.maximum(xn @ params[f"{pre}.ffn.fc1.w"].T + params[f"{pre}.ffn.fc1.b"], 0.0)
    f = f @ params[f"{pre}.ffn.fc2.w"].T + params[f"{pre}.ffn.fc2.b"]
    return x + f


def forward(params, x, cfg: ModelCfg, use_pallas: bool = False):
    """Single-example forward.

    x: (seq, in_features) floats, or (seq,) int32 token ids when vocab > 0.
    Returns logits: (n_classes,) / (1,) / (seq, n_classes) per head kind.
    """
    if cfg.vocab > 0:
        h = params["embedding.table"][x]
    else:
        h = x @ params["in_proj.w"].T + params["in_proj.b"]
    for i in range(cfg.n_layers):
        h = _block(params, f"block{i}", cfg, h, use_pallas)
    if cfg.head == "per_position":
        return h @ params["head.w"].T + params["head.b"]
    pooled = h.mean(axis=0)
    return pooled @ params["head.w"].T + params["head.b"]


def forward_batch(params, xs, cfg: ModelCfg, use_pallas: bool = False):
    """vmapped batch forward: xs (B, seq, feat) or (B, seq)."""
    return jax.vmap(lambda x: forward(params, x, cfg, use_pallas))(xs)
