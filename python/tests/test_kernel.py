"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes, dtypes, block sizes and the (alpha, gamma)
hyper-parameters; every property asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dotprod import dotprod_attention_pallas
from compile.kernels.inhibitor import inhibitor_attention_pallas

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16])
SEQS = st.sampled_from([2, 4, 8, 16, 32])
SEEDS = st.integers(0, 2**31 - 1)


def rand_qkv(seed, n, d, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(0, scale, (n, d)), dtype) for _ in range(3)]


def tile(n):
    t = 1
    while t * 2 <= min(n, 128) and n % (t * 2) == 0:
        t *= 2
    return t


# ----------------------------------------------------------------------
# Reference self-consistency (paper identities, eqs. 8-11)
# ----------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(SEEDS, SEQS, DIMS)
def test_fused_rewrite_equals_naive_unsigned(seed, n, d):
    q, k, v = rand_qkv(seed, n, d)
    a = ref.inhibitor_attention(q, k, v)
    b = ref.inhibitor_attention_fused(q, k, v)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(SEEDS, SEQS, DIMS)
def test_fused_rewrite_equals_naive_signed(seed, n, d):
    q, k, v = rand_qkv(seed, n, d)
    a = ref.inhibitor_attention_signed(q, k, v)
    b = ref.inhibitor_attention_signed_fused(q, k, v)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_signed_equals_unsigned_for_nonnegative_v():
    q, k, v = rand_qkv(7, 8, 4)
    v = jnp.abs(v)
    a = ref.inhibitor_attention(q, k, v)
    b = ref.inhibitor_attention_signed(q, k, v)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Pallas kernels vs oracles
# ----------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(SEEDS, SEQS, DIMS, st.booleans())
def test_inhibitor_pallas_matches_ref(seed, n, d, signed):
    q, k, v = rand_qkv(seed, n, d)
    fn = ref.inhibitor_attention_signed if signed else ref.inhibitor_attention
    want = fn(q, k, v)
    got = inhibitor_attention_pallas(
        q, k, v, signed=signed, block_q=tile(n), block_k=tile(n)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(SEEDS, st.sampled_from([4, 8, 16]), st.sampled_from([2, 4, 8]))
def test_inhibitor_pallas_block_size_invariance(seed, n, d):
    """The result must not depend on the BlockSpec tiling."""
    q, k, v = rand_qkv(seed, n, d)
    full = inhibitor_attention_pallas(q, k, v, block_q=n, block_k=n)
    for b in (1, 2, n // 2):
        if n % b == 0:
            tiled = inhibitor_attention_pallas(q, k, v, block_q=b, block_k=b)
            np.testing.assert_allclose(tiled, full, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(SEEDS, SEQS, DIMS)
def test_dotprod_pallas_matches_ref(seed, n, d):
    q, k, v = rand_qkv(seed, n, d)
    want = ref.dotprod_attention(q, k, v)
    got = dotprod_attention_pallas(q, k, v, block_q=tile(n))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(SEEDS, st.floats(0.0, 2.0), st.floats(0.5, 4.0))
def test_inhibitor_pallas_alpha_gamma(seed, alpha, gamma):
    q, k, v = rand_qkv(seed, 8, 4)
    want = ref.inhibitor_attention(q, k, v, gamma=gamma, alpha=alpha)
    got = inhibitor_attention_pallas(q, k, v, gamma=gamma, alpha=alpha,
                                     block_q=4, block_k=4)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bfloat16_supported():
    q, k, v = rand_qkv(3, 8, 4, dtype=jnp.bfloat16)
    got = inhibitor_attention_pallas(q, k, v, block_q=4, block_k=4)
    want = ref.inhibitor_attention(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=0.05, atol=0.1,
    )


# ----------------------------------------------------------------------
# Behavioural properties from the paper
# ----------------------------------------------------------------------

def test_zero_distance_passes_values_through():
    # Q == K and alpha >= 0 => Z' = 0 => H = column sums of relu'd V.
    q = jnp.ones((4, 2))
    v = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (4, 2)), jnp.float32)
    h = inhibitor_attention_pallas(q, q, v, block_q=4, block_k=4)
    np.testing.assert_allclose(h, jnp.tile(v.sum(0), (4, 1)), rtol=1e-5, atol=1e-5)


def test_distant_keys_fully_inhibited():
    q = jnp.zeros((2, 2))
    k = 100.0 * jnp.ones((2, 2))
    v = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    h = inhibitor_attention_pallas(q, k, v, block_q=2, block_k=2)
    np.testing.assert_allclose(h, jnp.zeros((2, 2)), atol=1e-6)


def test_inhibitor_is_permutation_equivariant_in_keys():
    q, k, v = rand_qkv(11, 8, 4)
    perm = np.random.default_rng(2).permutation(8)
    a = inhibitor_attention_pallas(q, k, v, block_q=4, block_k=4)
    b = inhibitor_attention_pallas(q, k[perm], v[perm], block_q=4, block_k=4)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [3, 6])
def test_uneven_tiling_rejected(n):
    q = jnp.zeros((n, 2))
    with pytest.raises(AssertionError):
        inhibitor_attention_pallas(q, q, q, block_q=4, block_k=4)
