"""Dataset generator tests: shapes, label correctness, determinism."""

import numpy as np

from compile import datasets


def test_adding_target_is_marked_dot_product():
    rng = np.random.default_rng(0)
    x, y = datasets.adding(rng, 16, seq_len=50)
    assert x.shape == (16, 50, 2) and y.shape == (16, 1)
    for b in range(16):
        marks = x[b, :, 1]
        assert marks.sum() == 2.0  # exactly two-hot
        want = (x[b, :, 0] * marks).sum()
        assert abs(y[b, 0] - want) < 1e-6


def test_digits_are_classaligned_templates():
    rng = np.random.default_rng(1)
    x, y = datasets.digits(rng, 64)
    assert x.shape == (64, 8, 8) and y.shape == (64,)
    assert set(np.unique(y)).issubset(set(range(10)))
    # Same label => closer to its own template than noise floor implies.
    t = datasets._digit_templates(rng)
    for b in range(8):
        dists = [np.abs(x[b] - t[c]).mean() for c in range(10)]
        assert int(np.argmin(dists)) == y[b]


def test_sentiment_lexicon_correlates_with_label():
    rng = np.random.default_rng(2)
    x, y = datasets.sentiment(rng, 512, seq_len=32)
    pos_frac = ((x >= 2) & (x < 102)).mean(axis=1)
    assert pos_frac[y == 1].mean() > pos_frac[y == 0].mean() + 0.05


def test_handwriting_frames_match_glyphs():
    rng = np.random.default_rng(3)
    x, y = datasets.handwriting(rng, 8)
    t = datasets.HW_WORD_LEN * datasets.HW_FRAMES_PER_CHAR
    assert x.shape == (8, t, datasets.HW_FEATURES)
    assert y.shape == (8, datasets.HW_WORD_LEN)
    assert y.min() >= 1 and y.max() <= datasets.HW_ALPHABET
    # De-noised frames are closest to the labelled glyph.
    g = datasets._glyphs()
    frames0 = x[0, : datasets.HW_FRAMES_PER_CHAR]
    dists = [np.abs(frames0 - g[c]).mean() for c in range(datasets.HW_ALPHABET)]
    assert int(np.argmin(dists)) == y[0, 0] - 1


def test_generators_are_seed_deterministic():
    a1 = datasets.adding(np.random.default_rng(7), 4)[0]
    a2 = datasets.adding(np.random.default_rng(7), 4)[0]
    np.testing.assert_array_equal(a1, a2)
