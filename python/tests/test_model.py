"""L2 tests: model shapes, mechanism parity of code paths, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelCfg, forward, forward_batch, init_params


def make(cfg, seed=0):
    return init_params(jax.random.PRNGKey(seed), cfg)


@pytest.mark.parametrize("mech", ["dotprod", "inhibitor", "inhibitor-signed"])
@pytest.mark.parametrize(
    "head,n_classes,want_shape",
    [("regress", 1, (1,)), ("classify", 10, (10,)), ("per_position", 5, (8, 5))],
)
def test_forward_shapes(mech, head, n_classes, want_shape):
    cfg = ModelCfg(mechanism=mech, seq_len=8, dim=16, ffn_dim=32,
                   in_features=4, head=head, n_classes=n_classes)
    params = make(cfg)
    x = jnp.ones((8, 4))
    out = forward(params, x, cfg)
    assert out.shape == want_shape


def test_token_model():
    cfg = ModelCfg(mechanism="inhibitor", seq_len=12, dim=16, ffn_dim=32,
                   vocab=50, head="classify", n_classes=2)
    params = make(cfg)
    x = jnp.arange(12, dtype=jnp.int32) % 50
    out = forward(params, x, cfg)
    assert out.shape == (2,)


@pytest.mark.parametrize("mech", ["dotprod", "inhibitor", "inhibitor-signed"])
def test_pallas_path_matches_jnp_path(mech):
    """The AOT (pallas) forward must equal the training (jnp) forward."""
    cfg = ModelCfg(mechanism=mech, seq_len=16, dim=8, ffn_dim=16,
                   in_features=4, head="classify", n_classes=3)
    params = make(cfg, seed=3)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 4)), jnp.float32)
    a = forward(params, x, cfg, use_pallas=False)
    b = forward(params, x, cfg, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_batch_forward_matches_loop():
    cfg = ModelCfg(mechanism="inhibitor", seq_len=8, dim=16, ffn_dim=32,
                   in_features=4, head="regress")
    params = make(cfg)
    xs = jnp.asarray(np.random.default_rng(1).normal(size=(5, 8, 4)), jnp.float32)
    batched = forward_batch(params, xs, cfg)
    looped = jnp.stack([forward(params, xs[i], cfg) for i in range(5)])
    np.testing.assert_allclose(batched, looped, rtol=1e-5, atol=1e-5)


def test_deterministic_init():
    cfg = ModelCfg()
    p1, p2 = make(cfg, 7), make(cfg, 7)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_mechanisms_differ():
    """Same weights, different attention => different outputs."""
    base = ModelCfg(mechanism="dotprod", seq_len=8, dim=16, ffn_dim=32,
                    in_features=4, head="regress")
    params = make(base, 1)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(8, 4)), jnp.float32)
    a = forward(params, x, base)
    b = forward(params, x, base.with_(mechanism="inhibitor"))
    assert not np.allclose(a, b)


def test_gradients_flow_through_inhibitor():
    cfg = ModelCfg(mechanism="inhibitor", seq_len=8, dim=16, ffn_dim=32,
                   in_features=4, head="regress")
    params = make(cfg, 5)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(8, 4)), jnp.float32)

    def loss(p):
        return forward(p, x, cfg)[0] ** 2

    grads = jax.grad(loss)(params)
    total = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(total) and total > 0.0
