"""Training-path tests: CTC loss vs brute force, Adam, loss decreases."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets
from compile.model import ModelCfg
from compile.train import (
    adam_init,
    adam_step,
    ctc_greedy_decode,
    ctc_loss_single,
    mse_loss,
    run_adding,
)


def brute_force_ctc(log_probs, labels):
    """Enumerate every alignment path; sum probabilities of those that
    collapse to `labels` (exponential — only for tiny cases)."""
    lp = np.asarray(log_probs)
    t, c = lp.shape
    target = list(labels)
    total = -np.inf
    for path in itertools.product(range(c), repeat=t):
        collapsed = []
        prev = -1
        for s in path:
            if s != prev and s != 0:
                collapsed.append(s)
            prev = s
        if collapsed == target:
            total = np.logaddexp(total, sum(lp[i, s] for i, s in enumerate(path)))
    return -total


def test_ctc_loss_matches_brute_force():
    rng = np.random.default_rng(0)
    for _ in range(5):
        t, c, l = 4, 3, 2
        logits = rng.normal(size=(t, c))
        logp = jax.nn.log_softmax(jnp.asarray(logits, jnp.float32))
        labels = jnp.asarray(rng.integers(1, c, size=l), jnp.int32)
        got = float(ctc_loss_single(logp, labels))
        want = brute_force_ctc(np.asarray(logp), list(np.asarray(labels)))
        assert abs(got - want) < 1e-3, (got, want)


def test_ctc_greedy_decode_collapses():
    # Path 0,1,1,0,2,2 -> [1, 2]
    logits = np.full((6, 3), -5.0)
    for i, s in enumerate([0, 1, 1, 0, 2, 2]):
        logits[i, s] = 5.0
    assert ctc_greedy_decode(jnp.asarray(logits)) == [1, 2]


def test_adam_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adam_init(params)
    grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    for _ in range(300):
        params, state = adam_step(params, grad_fn(params), state, lr=5e-2)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_training_reduces_loss_quickly():
    """A 60-step run on the adding task must beat the constant predictor."""
    r = run_adding("inhibitor", seed=0, steps=60)
    # Constant-mean predictor MSE on the adding task ~ Var(y) ~ 0.17.
    assert r["value"] < 0.17, r


def test_edit_distance():
    assert datasets.edit_distance([1, 2, 3], [1, 2, 3]) == 0
    assert datasets.edit_distance([1, 2, 3], [1, 3]) == 1
    assert datasets.edit_distance([], [1, 2]) == 2
    assert datasets.edit_distance([1, 2], [2, 1]) == 2


def test_mse_loss_on_perfect_prediction_is_zero():
    cfg = ModelCfg(mechanism="inhibitor", seq_len=4, dim=8, ffn_dim=16,
                   in_features=2, head="regress")
    params = {"zero": jnp.zeros(())}  # not used; direct check of the math
    del params
    xs = jnp.zeros((2, 4, 2))
    ys = jnp.zeros((2, 1))
    import jax.random as jr
    from compile.model import init_params
    p = init_params(jr.PRNGKey(0), cfg)
    val = float(mse_loss(p, xs, ys, cfg))
    assert np.isfinite(val)
