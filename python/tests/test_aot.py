"""AOT pipeline tests: HLO text generation and the weight export format."""

import struct

import jax
import numpy as np
import pytest

from compile.aot import export_weights, lower_attention, lower_model, model_config_json
from compile.model import ModelCfg, init_params


@pytest.mark.parametrize("mech", ["dotprod", "inhibitor", "inhibitor-signed"])
def test_lower_attention_produces_hlo_text(mech):
    text = lower_attention(mech, seq_len=8, dim=4)
    assert "HloModule" in text
    assert "f32[8,4]" in text  # entry params carry the expected shapes


def test_lower_model_produces_hlo_text():
    cfg = ModelCfg(mechanism="inhibitor", seq_len=8, dim=8, ffn_dim=16,
                   in_features=2, head="regress")
    params = init_params(jax.random.PRNGKey(0), cfg)
    text = lower_model(cfg, params)
    assert "HloModule" in text
    assert "f32[8,2]" in text


def test_export_weights_binary_format(tmp_path):
    cfg = ModelCfg(seq_len=4, dim=8, ffn_dim=16, in_features=2)
    params = init_params(jax.random.PRNGKey(1), cfg)
    path = tmp_path / "w.bin"
    export_weights(params, str(path))
    blob = path.read_bytes()
    assert blob[:8] == b"INHWGT01"
    (count,) = struct.unpack("<I", blob[8:12])
    assert count == len(params)
    # Parse the full file back and compare tensors.
    off = 12
    seen = {}
    for _ in range(count):
        (nlen,) = struct.unpack("<H", blob[off:off + 2]); off += 2
        name = blob[off:off + nlen].decode(); off += nlen
        (rank,) = struct.unpack("<B", blob[off:off + 1]); off += 1
        dims = struct.unpack(f"<{rank}I", blob[off:off + 4 * rank]); off += 4 * rank
        n = int(np.prod(dims)) if rank else 1
        data = np.frombuffer(blob[off:off + 4 * n], np.float32).reshape(dims)
        off += 4 * n
        seen[name] = data
    assert off == len(blob)
    for k, v in params.items():
        np.testing.assert_array_equal(seen[k], np.asarray(v, np.float32))


def test_config_json_round_trips_mechanism():
    cfg = ModelCfg(mechanism="inhibitor-signed", head="classify", n_classes=7)
    j = model_config_json(cfg)
    assert j["mechanism"] == "inhibitor-signed"
    assert j["n_classes"] == 7
    assert j["act_bits"] == 16
