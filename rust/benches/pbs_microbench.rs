//! PBS micro-benchmarks: per-operation cost of every TFHE primitive, and
//! the cost-model calibration data (measured vs modeled PBS time across
//! parameter sets). This is the §Perf instrument for L3's FHE hot path.
//!
//!   cargo bench --bench pbs_microbench

use inhibitor::bench_harness::{bench, BenchConfig};
use inhibitor::optimizer::cost::pbs_cost;
use inhibitor::tfhe::{bootstrap::Lut, ClientKey, Encoder, FheContext, TfheParams};
use inhibitor::util::prng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(0x9B5);

    println!("=== PBS primitives (test_small: n=320, N=512, p=3) ===");
    let p = TfheParams::test_small();
    let ck = ClientKey::generate(p, &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let a = ctx.encrypt(2, &ck, &mut rng);
    let b = ctx.encrypt(-1, &ck, &mut rng);
    let cfg = BenchConfig { warmup_iters: 3, samples: 20, inner_iters: 1 };
    let fast = BenchConfig { warmup_iters: 100, samples: 20, inner_iters: 200 };
    let rows = vec![
        bench("lwe add (0 PBS)", fast, || ctx.add(&a, &b)),
        bench("lwe scalar_mul (0 PBS)", fast, || ctx.scalar_mul(&a, 3)),
        bench("relu (1 PBS)", cfg, || ctx.relu(&a)),
        bench("abs (1 PBS)", cfg, || ctx.abs(&a)),
        bench("ct_mul (2 PBS, eq. 1)", cfg, || ctx.ct_mul(&a, &b)),
    ];
    for r in &rows {
        println!("  {}", r.summary());
    }
    let linear = rows[0].mean_s;
    let one_pbs = rows[2].mean_s;
    println!(
        "  PBS / linear-op cost ratio: {:.0}×  (the paper's whole premise)",
        one_pbs / linear
    );

    println!("\n=== Cost model calibration: measured vs modeled across parameter sets ===");
    println!(
        "{:>6} {:>6} {:>4} {:>12} {:>14} {:>10}",
        "n", "N", "p", "measured", "model flops", "flops/s"
    );
    let mut fps_samples = Vec::new();
    for (n, nn, bits) in [(320usize, 512usize, 3u32), (320, 1024, 4), (512, 2048, 4)] {
        let mut params = TfheParams::test_small();
        params.lwe_dim = n;
        params.poly_size = nn;
        params.message_bits = bits;
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let ct = enc.encrypt_raw(1, &ck, &mut rng);
        let lut = Lut::from_fn(&params, |m| m);
        let m = bench(
            &format!("pbs n={n} N={nn}"),
            BenchConfig { warmup_iters: 2, samples: 10, inner_iters: 1 },
            || sk.pbs(&ct, &lut),
        );
        let model = pbs_cost(&params).0;
        let fps = model / m.mean_s;
        fps_samples.push(fps);
        println!(
            "{:>6} {:>6} {:>4} {:>12} {:>14.3e} {:>10.2e}",
            n,
            nn,
            bits,
            inhibitor::bench_harness::Measurement::fmt_time(m.mean_s),
            model,
            fps
        );
    }
    let spread = fps_samples.iter().cloned().fold(f64::MIN, f64::max)
        / fps_samples.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "model quality: implied flops/s spread across sets = {:.2}× (1.0 = perfect scaling model)",
        spread
    );
}
