//! PBS micro-benchmarks: per-operation cost of every TFHE primitive, the
//! cost-model calibration data (measured vs modeled PBS time across
//! parameter sets), and the batched parallel PBS engine sweep
//! (batch-size × thread-count). This is the §Perf instrument for L3's
//! FHE hot path; it writes a machine-readable throughput record to
//! `BENCH_pbs.json` so the perf trajectory is tracked across PRs.
//!
//!   cargo bench --bench pbs_microbench

use inhibitor::bench_harness::{bench, BenchConfig};
use inhibitor::optimizer::cost::pbs_cost;
use inhibitor::tfhe::lwe::LweCiphertext;
use inhibitor::tfhe::{
    bootstrap::Lut, ClientKey, Encoder, FheContext, PreparedLut, TfheParams,
};
use inhibitor::util::json::Json;
use inhibitor::util::prng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(0x9B5);

    println!("=== PBS primitives (test_small: n=320, N=512, p=3) ===");
    let p = TfheParams::test_small();
    let ck = ClientKey::generate(p, &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let a = ctx.encrypt(2, &ck, &mut rng);
    let b = ctx.encrypt(-1, &ck, &mut rng);
    let cfg = BenchConfig { warmup_iters: 3, samples: 20, inner_iters: 1 };
    let fast = BenchConfig { warmup_iters: 100, samples: 20, inner_iters: 200 };
    let rows = vec![
        bench("lwe add (0 PBS)", fast, || ctx.add(&a, &b)),
        bench("lwe scalar_mul (0 PBS)", fast, || ctx.scalar_mul(&a, 3)),
        bench("relu (1 PBS)", cfg, || ctx.relu(&a)),
        bench("abs (1 PBS)", cfg, || ctx.abs(&a)),
        bench("ct_mul (2 PBS, eq. 1)", cfg, || ctx.ct_mul(&a, &b)),
    ];
    for r in &rows {
        println!("  {}", r.summary());
    }
    let linear = rows[0].mean_s;
    let one_pbs = rows[2].mean_s;
    println!(
        "  PBS / linear-op cost ratio: {:.0}×  (the paper's whole premise)",
        one_pbs / linear
    );

    // === Prepared-LUT accumulator caching: single-thread latency =========
    println!("\n=== Prepared LUT vs per-call accumulator rebuild (1 thread) ===");
    let sk = &ctx.sk;
    let enc = Encoder::new(p);
    let ct1 = enc.encrypt_raw(1, &ck, &mut rng);
    let lut = Lut::from_fn(&p, |m| m);
    let prepared = sk.prepare_lut(&lut);
    let m_rebuild = bench("pbs (rebuild accumulator per call)", cfg, || sk.pbs(&ct1, &lut));
    let m_prepared = bench("pbs (prepared accumulator)", cfg, || {
        sk.pbs_prepared(&ct1, &prepared)
    });
    let mut scratch = sk.scratch();
    let m_scratch = bench("pbs (prepared + reused scratch)", cfg, || {
        sk.pbs_prepared_with_scratch(&ct1, &prepared, &mut scratch)
    });
    for m in [&m_rebuild, &m_prepared, &m_scratch] {
        println!("  {}", m.summary());
    }
    let single_speedup = m_rebuild.mean_s / m_scratch.mean_s;
    println!("  single-thread speedup vs rebuild baseline: {single_speedup:.3}×");

    // === Batch × thread sweep ============================================
    println!("\n=== pbs_batch throughput: batch-size × thread-count sweep ===");
    let space = p.message_space();
    let cts: Vec<LweCiphertext> =
        (0..128u64).map(|i| enc.encrypt_raw(i % space, &ck, &mut rng)).collect();
    let thread_counts = [1usize, 2, 4, 8];
    println!(
        "{:>6} {:>8} {:>12} {:>12} {:>10}",
        "batch", "threads", "total", "PBS/sec", "speedup"
    );
    let mut sweep_records = Vec::new();
    for &batch in &[16usize, 64, 128] {
        let jobs: Vec<(&LweCiphertext, &PreparedLut)> =
            cts[..batch].iter().map(|c| (c, &prepared)).collect();
        let mut base_pbs_per_sec = 0.0f64;
        for &threads in &thread_counts {
            let samples = if batch >= 128 { 8 } else { 12 };
            let m = bench(
                &format!("pbs_batch b={batch} t={threads}"),
                BenchConfig { warmup_iters: 1, samples, inner_iters: 1 },
                || sk.pbs_batch(&jobs, threads),
            );
            let pbs_per_sec = batch as f64 / m.mean_s;
            if threads == 1 {
                base_pbs_per_sec = pbs_per_sec;
            }
            let speedup = pbs_per_sec / base_pbs_per_sec;
            println!(
                "{:>6} {:>8} {:>12} {:>12.1} {:>9.2}x",
                batch,
                threads,
                inhibitor::bench_harness::Measurement::fmt_time(m.mean_s),
                pbs_per_sec,
                speedup
            );
            sweep_records.push(Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("threads", Json::num(threads as f64)),
                ("mean_s", Json::num(m.mean_s)),
                ("ci95_s", Json::num(m.ci95_s)),
                ("pbs_per_sec", Json::num(pbs_per_sec)),
                ("speedup_vs_1_thread", Json::num(speedup)),
            ]));
        }
    }

    // === Machine-readable perf record ====================================
    let record = Json::obj(vec![
        ("bench", Json::str("pbs_microbench")),
        (
            "params",
            Json::obj(vec![
                ("lwe_dim", Json::num(p.lwe_dim as f64)),
                ("poly_size", Json::num(p.poly_size as f64)),
                ("message_bits", Json::num(p.message_bits as f64)),
            ]),
        ),
        (
            "single_thread",
            Json::obj(vec![
                ("rebuild_s", Json::num(m_rebuild.mean_s)),
                ("prepared_s", Json::num(m_prepared.mean_s)),
                ("prepared_scratch_s", Json::num(m_scratch.mean_s)),
                ("speedup_vs_rebuild", Json::num(single_speedup)),
            ]),
        ),
        ("sweep", Json::arr(sweep_records)),
    ]);
    // Write next to the workspace root (cargo runs benches with CWD at
    // the package root), where the perf-trajectory record is checked in.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pbs.json");
    match std::fs::write(path, format!("{record}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!("\n=== Cost model calibration: measured vs modeled across parameter sets ===");
    println!(
        "{:>6} {:>6} {:>4} {:>12} {:>14} {:>10}",
        "n", "N", "p", "measured", "model flops", "flops/s"
    );
    let mut fps_samples = Vec::new();
    for (n, nn, bits) in [(320usize, 512usize, 3u32), (320, 1024, 4), (512, 2048, 4)] {
        let mut params = TfheParams::test_small();
        params.lwe_dim = n;
        params.poly_size = nn;
        params.message_bits = bits;
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let ct = enc.encrypt_raw(1, &ck, &mut rng);
        let lut = Lut::from_fn(&params, |m| m);
        let m = bench(
            &format!("pbs n={n} N={nn}"),
            BenchConfig { warmup_iters: 2, samples: 10, inner_iters: 1 },
            || sk.pbs(&ct, &lut),
        );
        let model = pbs_cost(&params).0;
        let fps = model / m.mean_s;
        fps_samples.push(fps);
        println!(
            "{:>6} {:>6} {:>4} {:>12} {:>14.3e} {:>10.2e}",
            n,
            nn,
            bits,
            inhibitor::bench_harness::Measurement::fmt_time(m.mean_s),
            model,
            fps
        );
    }
    let spread = fps_samples.iter().cloned().fold(f64::MIN, f64::max)
        / fps_samples.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "model quality: implied flops/s spread across sets = {:.2}× (1.0 = perfect scaling model)",
        spread
    );
}
