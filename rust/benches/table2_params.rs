//! E2 / paper Table 2: TFHE compiler parameters for the two attention
//! circuits at T ∈ {2, 4, 8, 16} (d = 2, 3-bit inputs), selected by our
//! Bergerat-style optimizer; per-PBS cost converted to ms via a measured
//! calibration bootstrap.
//!
//!   cargo bench --bench table2_params

use inhibitor::tfhe::{bootstrap::Lut, ClientKey, Encoder, TfheParams};
use inhibitor::util::prng::Xoshiro256;

fn main() {
    // Calibrate flops/sec from real PBS executions on this host.
    let mut rng = Xoshiro256::new(3);
    let p = TfheParams::test_small();
    let ck = ClientKey::generate(p, &mut rng);
    let sk = ck.server_key(&mut rng);
    let enc = Encoder::new(p);
    let ct = enc.encrypt_raw(1, &ck, &mut rng);
    let lut = Lut::from_fn(&p, |m| m);
    let m = inhibitor::bench_harness::bench(
        "calibration PBS",
        inhibitor::bench_harness::BenchConfig { warmup_iters: 2, samples: 10, inner_iters: 1 },
        || sk.pbs(&ct, &lut),
    );
    println!("calibration: {}", m.summary());
    let fps = inhibitor::optimizer::cost::calibrate_flops_per_sec(m.mean_s, &p);
    println!("host throughput ≈ {:.2e} flop-equiv/s", fps);
    inhibitor::bench_tables::print_table2(fps);
}
