//! Plan-executor perf instrument: (a) declarative `CircuitPlan` execution
//! vs the PR 1 hand-staged forwards (the plan path must not regress), and
//! (b) cross-request fused level execution vs per-request execution of
//! the same co-scheduled batch (the fusion path must be no slower — at
//! small `T` it fills the worker pool that solo requests leave idle).
//! Writes a machine-readable record to `BENCH_plan.json`.
//!
//!   cargo bench --bench plan_bench

use inhibitor::attention::Mechanism;
use inhibitor::bench_harness::{bench, BenchConfig};
use inhibitor::coordinator::storage::DEFAULT_STORAGE_BUDGET;
use inhibitor::coordinator::{Bundle, CtStore, FusedLevelExecutor, FusedRequest, KeyManager};
use inhibitor::fhe_circuits::{
    CtMatrix, DecodeFhe, DotProductFhe, InhibitorFhe, InhibitorSignedFhe, ModelFhe, MultiHeadFhe,
};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    set_wavefront_dispatch, CircuitPlan, ClientKey, FheContext, PlanRewriter, TfheParams,
};
use inhibitor::util::json::Json;
use inhibitor::util::prng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(0x71A9);
    let (t, d) = (2usize, 2usize);
    let threads = inhibitor::tfhe::default_fhe_threads();
    let cfg = BenchConfig { warmup_iters: 1, samples: 10, inner_iters: 1 };
    let mut records = Vec::new();

    println!("=== Plan executor vs hand-staged circuits (T={t}, d={d}, {threads} threads) ===");
    for mech in ["inhibitor", "dotprod"] {
        let bits = if mech == "dotprod" { 6 } else { 5 };
        let ck = ClientKey::generate(TfheParams::test_for_bits(bits), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        ctx.set_threads(threads);
        let q = ITensor::random(&[t, d], -2, 2, &mut rng);
        let k = ITensor::random(&[t, d], -2, 2, &mut rng);
        let v = ITensor::random(&[t, d], 0, 3, &mut rng);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let (m_staged, m_plan) = if mech == "dotprod" {
            let head = DotProductFhe::new(d, 2);
            (
                bench(&format!("{mech} staged"), cfg, || head.forward_staged(&ctx, &cq, &ckk, &cv)),
                bench(&format!("{mech} plan"), cfg, || head.forward(&ctx, &cq, &ckk, &cv)),
            )
        } else {
            let head = InhibitorFhe::new(d, 1);
            (
                bench(&format!("{mech} staged"), cfg, || head.forward_staged(&ctx, &cq, &ckk, &cv)),
                bench(&format!("{mech} plan"), cfg, || head.forward(&ctx, &cq, &ckk, &cv)),
            )
        };
        println!("  {}", m_staged.summary());
        println!("  {}", m_plan.summary());
        println!("  plan/staged latency ratio: {:.3}", m_plan.mean_s / m_staged.mean_s);
        records.push(Json::obj(vec![
            ("mechanism", Json::str(mech)),
            ("staged_s", Json::num(m_staged.mean_s)),
            ("plan_s", Json::num(m_plan.mean_s)),
            ("plan_over_staged", Json::num(m_plan.mean_s / m_staged.mean_s)),
        ]));
    }

    // === Fused vs per-request execution of a co-scheduled batch =========
    println!("\n=== Cross-request fusion: R co-scheduled T={t} inhibitor requests ===");
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    ctx.set_threads(threads);
    let head = InhibitorFhe::new(d, 1);
    let plan = head.plan(t, d);
    let mut fusion_records = Vec::new();
    for &n_req in &[2usize, 4, 8] {
        let bundles: Vec<Vec<CtInt>> = (0..n_req)
            .map(|_| {
                let q = ITensor::random(&[t, d], -2, 2, &mut rng);
                let k = ITensor::random(&[t, d], -2, 2, &mut rng);
                let v = ITensor::random(&[t, d], 0, 3, &mut rng);
                let mut inputs = Vec::with_capacity(3 * t * d);
                for tensor in [&q, &k, &v] {
                    inputs.extend(
                        tensor.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)),
                    );
                }
                inputs
            })
            .collect();
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (&plan, b.as_slice())).collect();
        let m_solo = bench(&format!("solo x{n_req}"), cfg, || {
            bundles.iter().map(|b| plan.execute(&ctx, b)).collect::<Vec<_>>()
        });
        let m_fused =
            bench(&format!("fused x{n_req}"), cfg, || FusedLevelExecutor::new(&ctx).run(&requests));
        let solo_rps = n_req as f64 / m_solo.mean_s;
        let fused_rps = n_req as f64 / m_fused.mean_s;
        println!(
            "  R={n_req}: solo {:.2} req/s, fused {:.2} req/s ({:.2}x)",
            solo_rps,
            fused_rps,
            fused_rps / solo_rps
        );
        fusion_records.push(Json::obj(vec![
            ("requests", Json::num(n_req as f64)),
            ("solo_req_per_sec", Json::num(solo_rps)),
            ("fused_req_per_sec", Json::num(fused_rps)),
            ("fused_speedup", Json::num(fused_rps / solo_rps)),
        ]));
    }

    // === Wavefront vs legacy barrier dispatch (PR 8) ===================
    // The same co-scheduled batch under both dispatchers. Waves ≡ levels
    // in this IR, so the executed work is identical — the delta is
    // scheduling only (ready-set dispatch + work stealing vs a strict
    // level barrier), recorded as requests/sec. A cross-key pair (two
    // sessions, distinct server keys) then runs through one fused
    // execution: every tick sweeps both keys' jobs in one pool pass.
    println!("\n=== Wavefront dispatch: barrier vs wavefront req/s, cross-key fusion ===");
    let n_req = 4usize;
    let wf_bundles: Vec<Vec<CtInt>> = (0..n_req)
        .map(|_| {
            let q = ITensor::random(&[t, d], -2, 2, &mut rng);
            let k = ITensor::random(&[t, d], -2, 2, &mut rng);
            let v = ITensor::random(&[t, d], 0, 3, &mut rng);
            let mut inputs = Vec::with_capacity(3 * t * d);
            for tensor in [&q, &k, &v] {
                inputs.extend(
                    tensor.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)),
                );
            }
            inputs
        })
        .collect();
    let wf_requests: Vec<(&CircuitPlan, &[CtInt])> =
        wf_bundles.iter().map(|b| (&plan, b.as_slice())).collect();
    set_wavefront_dispatch(Some(false));
    let m_barrier = bench(&format!("barrier x{n_req}"), cfg, || {
        FusedLevelExecutor::new(&ctx).run(&wf_requests)
    });
    set_wavefront_dispatch(Some(true));
    let m_wave = bench(&format!("wavefront x{n_req}"), cfg, || {
        FusedLevelExecutor::new(&ctx).run(&wf_requests)
    });
    set_wavefront_dispatch(None);
    let barrier_rps = n_req as f64 / m_barrier.mean_s;
    let wavefront_rps = n_req as f64 / m_wave.mean_s;
    let ck_b = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx_b = FheContext::new(ck_b.server_key(&mut rng));
    ctx_b.set_threads(threads);
    let bundle_b: Vec<CtInt> = {
        let q = ITensor::random(&[t, d], -2, 2, &mut rng);
        let k = ITensor::random(&[t, d], -2, 2, &mut rng);
        let v = ITensor::random(&[t, d], 0, 3, &mut rng);
        let mut inputs = Vec::with_capacity(3 * t * d);
        for tensor in [&q, &k, &v] {
            inputs.extend(tensor.data.iter().map(|&val| ctx_b.encrypt(val, &ck_b, &mut rng)));
        }
        inputs
    };
    let cross: Vec<FusedRequest> = vec![
        FusedRequest::new(&plan, &wf_bundles[0]),
        FusedRequest::new(&plan, &bundle_b).with_ctx(&ctx_b),
    ];
    let m_cross =
        bench("cross-key x2", cfg, || FusedLevelExecutor::new(&ctx).run_checked(&cross));
    let (_, cross_stats) = FusedLevelExecutor::new(&ctx).run_checked(&cross);
    println!(
        "  R={n_req}: barrier {barrier_rps:.2} req/s, wavefront {wavefront_rps:.2} req/s \
         ({:.2}x); cross-key fused_keys={} stolen_jobs={} worker_utilization={:.3}",
        wavefront_rps / barrier_rps,
        cross_stats.fused_keys,
        cross_stats.stolen_jobs,
        cross_stats.worker_utilization(),
    );
    let wavefront_records = vec![Json::obj(vec![
        ("requests", Json::num(n_req as f64)),
        ("barrier_req_per_sec", Json::num(barrier_rps)),
        ("wavefront_req_per_sec", Json::num(wavefront_rps)),
        ("wavefront_speedup", Json::num(wavefront_rps / barrier_rps)),
        ("cross_key_requests", Json::num(cross.len() as f64)),
        ("cross_key_s", Json::num(m_cross.mean_s)),
        ("fused_keys", Json::num(cross_stats.fused_keys as f64)),
        ("stolen_jobs", Json::num(cross_stats.stolen_jobs as f64)),
        ("worker_utilization", Json::num(cross_stats.worker_utilization())),
    ])];

    // === Rewritten vs unrewritten plans (CSE + multi-value packing) ====
    // The signed inhibitor is the circuit where both passes bite: the
    // verbatim eq.-7 plan carries T-fold duplicate V⁺/V⁻ splits (CSE)
    // whose survivors share inputs pairwise (packing). Counts come from
    // the plans themselves; latencies from executing both on one keyset.
    println!("\n=== Plan rewrites: signed inhibitor T={t}, d={d} (ϑ=1 packing budget) ===");
    let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    ctx.set_threads(threads);
    let head = InhibitorSignedFhe::new(d, 1);
    let raw = head.plan(t, d);
    let (rewritten, stats) = PlanRewriter::for_ctx(&ctx).rewrite(head.plan(t, d));
    let mut inputs: Vec<CtInt> = Vec::with_capacity(3 * t * d);
    for (lo, hi, n) in [(-2i64, 1i64, 2 * t * d), (-3, 3, t * d)] {
        let vals = ITensor::random(&[n, 1], lo, hi, &mut rng);
        inputs.extend(vals.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)));
    }
    let m_raw = bench("signed unrewritten", cfg, || raw.execute(&ctx, &inputs));
    let m_rw = bench("signed rewritten", cfg, || rewritten.execute(&ctx, &inputs));
    println!("  {}", m_raw.summary());
    println!("  {}", m_rw.summary());
    println!(
        "  pbs {} -> {}, blind rotations {} -> {} (cse_merged={}, packed={} in {} groups)",
        raw.pbs_count(),
        rewritten.pbs_count(),
        raw.blind_rotation_count(),
        rewritten.blind_rotation_count(),
        stats.cse_merged,
        stats.packed_luts,
        stats.multi_groups,
    );
    let rewrite_records = vec![Json::obj(vec![
        ("mechanism", Json::str("inhibitor-signed")),
        ("pbs_unrewritten", Json::num(raw.pbs_count() as f64)),
        ("pbs_rewritten", Json::num(rewritten.pbs_count() as f64)),
        ("blind_rotations_unrewritten", Json::num(raw.blind_rotation_count() as f64)),
        ("blind_rotations_rewritten", Json::num(rewritten.blind_rotation_count() as f64)),
        ("cse_merged", Json::num(stats.cse_merged as f64)),
        ("multi_groups", Json::num(stats.multi_groups as f64)),
        ("unrewritten_s", Json::num(m_raw.mean_s)),
        ("rewritten_s", Json::num(m_rw.mean_s)),
        ("speedup", Json::num(m_raw.mean_s / m_rw.mean_s)),
    ])];

    // === Multi-head: one fused H-head plan vs H single-head plans ======
    // The cross-head payoff (same keyset, ϑ=1 budget): H shared-KV
    // signed heads in ONE plan — CSE dedupes the per-head V⁺/V⁻ splits
    // across head boundaries and packing executes the survivors once
    // for the whole block — against H separately-rewritten single-head
    // plans over the same values. `rewritten` above IS the
    // separately-rewritten single-head plan.
    println!("\n=== Multi-head: fused H-head signed plan vs H single plans (shared KV) ===");
    let heads = 4usize;
    let mh = MultiHeadFhe::new(Mechanism::InhibitorSigned, d, heads, true);
    let (fused, _) = PlanRewriter::for_ctx(&ctx).rewrite(mh.plan(t, d));
    let sep_pbs = heads as u64 * rewritten.pbs_count();
    let sep_rot = heads as u64 * rewritten.blind_rotation_count();
    // Shared-KV input pool: H Q segments, then one K and one V segment.
    let mut mh_inputs: Vec<CtInt> = Vec::with_capacity((heads + 2) * t * d);
    for seg in 0..heads + 2 {
        let (lo, hi) = if seg <= heads { (-2i64, 1i64) } else { (-3, 3) };
        let vals = ITensor::random(&[t * d, 1], lo, hi, &mut rng);
        mh_inputs.extend(vals.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)));
    }
    // Per-head bundles of the same ciphertexts: q_h ‖ k ‖ v.
    let head_bundles: Vec<Vec<CtInt>> = (0..heads)
        .map(|hh| {
            let mut bundle: Vec<CtInt> = Vec::with_capacity(3 * t * d);
            bundle.extend(mh_inputs[hh * t * d..(hh + 1) * t * d].iter().cloned());
            bundle.extend(mh_inputs[heads * t * d..].iter().cloned());
            bundle
        })
        .collect();
    let m_fused = bench("multihead fused", cfg, || fused.execute(&ctx, &mh_inputs));
    let m_sep = bench("multihead separate", cfg, || {
        head_bundles.iter().map(|bundle| rewritten.execute(&ctx, bundle)).collect::<Vec<_>>()
    });
    println!("  {}", m_fused.summary());
    println!("  {}", m_sep.summary());
    println!(
        "  H={heads}: pbs {sep_pbs} -> {}, blind rotations {sep_rot} -> {} ({:.3}x latency)",
        fused.pbs_count(),
        fused.blind_rotation_count(),
        m_sep.mean_s / m_fused.mean_s,
    );
    let multihead_records = vec![Json::obj(vec![
        ("mechanism", Json::str("inhibitor-signed")),
        ("heads", Json::num(heads as f64)),
        ("shared_kv", Json::num(1.0)),
        ("pbs_fused", Json::num(fused.pbs_count() as f64)),
        ("pbs_separate", Json::num(sep_pbs as f64)),
        ("blind_rotations_fused", Json::num(fused.blind_rotation_count() as f64)),
        ("blind_rotations_separate", Json::num(sep_rot as f64)),
        ("fused_s", Json::num(m_fused.mean_s)),
        ("separate_s", Json::num(m_sep.mean_s)),
        ("speedup", Json::num(m_sep.mean_s / m_fused.mean_s)),
    ])];

    // === Block subsystem: fused L-layer model plan vs per-layer plans ==
    // The cross-layer payoff: L = 2 full signed transformer blocks
    // (attention + W_O + residuals + requants + ReLU FFN) in ONE plan —
    // stacked boundary trios pack and the level loop never drains
    // between layers — against executing the same two blocks as two
    // separately-rewritten single-block plans chained through their
    // intermediate ciphertexts.
    println!("\n=== Block: fused L=2 signed block stack vs per-layer block plans ===");
    let (b_heads, b_layers) = (2usize, 2usize);
    let d_model = b_heads * d;
    // ϑ = 2 keyset: the cross-layer requant+ReLU+split trios only share
    // a rotation at budget ≥ 4 — at the rewrite section's ϑ = 1 budget
    // the fused and per-layer rotation counts provably tie (pinned by
    // tests/block_it.rs), and this section exists to record the win.
    let ck = ClientKey::generate(TfheParams::test_multi_lut_theta(4, 2), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    ctx.set_threads(threads);
    let model = ModelFhe::demo(
        Mechanism::InhibitorSigned,
        d_model,
        b_heads,
        b_layers,
        false,
        d_model,
        0xB1,
    );
    let stage_a = ModelFhe::new(vec![model.blocks[0].clone()]);
    let stage_b = ModelFhe::new(vec![model.blocks[1].clone()]);
    let (fused_block, _) = PlanRewriter::for_ctx(&ctx).rewrite(model.plan(t));
    let (plan_a, _) = PlanRewriter::for_ctx(&ctx).rewrite(stage_a.plan(t));
    let (plan_b, _) = PlanRewriter::for_ctx(&ctx).rewrite(stage_b.plan(t));
    let stage_pbs = plan_a.pbs_count() + plan_b.pbs_count();
    let stage_rot = plan_a.blind_rotation_count() + plan_b.blind_rotation_count();
    // Timing instrument only: deep-layer intermediates may wrap at the
    // 4-bit width — bit-exactness at proper widths is
    // `tests/block_it.rs`' job.
    let x = ITensor::random(&[t, d_model], -1, 1, &mut rng);
    let block_inputs: Vec<CtInt> =
        x.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)).collect();
    let m_block_fused =
        bench("block fused L=2", cfg, || fused_block.execute(&ctx, &block_inputs));
    let m_block_stages = bench("block per-layer x2", cfg, || {
        let mid = plan_a.execute(&ctx, &block_inputs);
        plan_b.execute(&ctx, &mid)
    });
    println!("  {}", m_block_fused.summary());
    println!("  {}", m_block_stages.summary());
    println!(
        "  L={b_layers} H={b_heads}: pbs {stage_pbs} -> {}, blind rotations {stage_rot} -> {} \
         ({:.3}x latency)",
        fused_block.pbs_count(),
        fused_block.blind_rotation_count(),
        m_block_stages.mean_s / m_block_fused.mean_s,
    );
    let block_records = vec![Json::obj(vec![
        ("mechanism", Json::str("inhibitor-signed")),
        ("heads", Json::num(b_heads as f64)),
        ("layers", Json::num(b_layers as f64)),
        ("d_model", Json::num(d_model as f64)),
        ("pbs_fused", Json::num(fused_block.pbs_count() as f64)),
        ("pbs_stages", Json::num(stage_pbs as f64)),
        ("blind_rotations_fused", Json::num(fused_block.blind_rotation_count() as f64)),
        ("blind_rotations_stages", Json::num(stage_rot as f64)),
        ("fused_s", Json::num(m_block_fused.mean_s)),
        ("stages_s", Json::num(m_block_stages.mean_s)),
        ("speedup", Json::num(m_block_stages.mean_s / m_block_fused.mean_s)),
    ])];

    // === Incremental decode: per-token step vs full-prefix recompute ===
    // The PR 7 payoff: at prefix length t the step plan does O(t·d)
    // work where the non-incremental alternative re-runs the whole
    // causal prefill — O(t²·d) cumulative over a stream. Three numbers
    // per token position: the stream-opening prefill (T = 1), the
    // steady-state step, and the full recompute it replaces. Same
    // timing-instrument caveat as the block section: widths are for
    // latency only, bit-exactness lives in tests/decode_it.rs.
    println!("\n=== Decode: per-token step plan vs full-prefix recompute (signed, L=1) ===");
    let dec_model =
        ModelFhe::demo(Mechanism::InhibitorSigned, d_model, b_heads, 1, false, d_model, 0xDE);
    let decode = DecodeFhe::new(dec_model);
    let cached_len = 2usize;
    let dec_x = ITensor::random(&[cached_len + 1, d_model], -1, 1, &mut rng);
    let dec_grid = CtMatrix::encrypt(&dec_x, &ctx, &ck, &mut rng);
    // Steady-state operands: the encrypted cache bundle at prefix
    // `cached_len` plus the next token's row.
    let grid_t0 = CtMatrix {
        rows: cached_len,
        cols: d_model,
        data: dec_grid.data[..cached_len * d_model].to_vec(),
    };
    let (_, dec_cache) = decode.prefill(&ctx, &grid_t0);
    let new_row = &dec_grid.data[cached_len * d_model..];
    let step_plan = decode.step_plan_for(&ctx, cached_len);
    let full_plan = decode.prefill_plan_for(&ctx, cached_len + 1);
    let prefill_plan = decode.prefill_plan_for(&ctx, 1);
    let step_refs: Vec<&CtInt> = new_row.iter().chain(dec_cache.iter()).collect();
    let full_refs: Vec<&CtInt> = dec_grid.data.iter().collect();
    let first_refs: Vec<&CtInt> = dec_grid.data[..d_model].iter().collect();
    let m_dec_prefill =
        bench("decode prefill T=1", cfg, || prefill_plan.execute_ref(&ctx, &first_refs));
    let m_dec_step = bench(&format!("decode step @t={cached_len}"), cfg, || {
        step_plan.execute_ref(&ctx, &step_refs)
    });
    let m_dec_full = bench(&format!("full recompute T={}", cached_len + 1), cfg, || {
        full_plan.execute_ref(&ctx, &full_refs)
    });
    println!("  {}", m_dec_prefill.summary());
    println!("  {}", m_dec_step.summary());
    println!("  {}", m_dec_full.summary());
    println!(
        "  t={cached_len}: pbs {} (step) vs {} (recompute), {:.3}x latency",
        step_plan.pbs_count(),
        full_plan.pbs_count(),
        m_dec_full.mean_s / m_dec_step.mean_s,
    );
    let decode_records = vec![Json::obj(vec![
        ("mechanism", Json::str("inhibitor-signed")),
        ("heads", Json::num(b_heads as f64)),
        ("layers", Json::num(1.0)),
        ("d_model", Json::num(d_model as f64)),
        ("cached_len", Json::num(cached_len as f64)),
        ("pbs_step", Json::num(step_plan.pbs_count() as f64)),
        ("pbs_full_recompute", Json::num(full_plan.pbs_count() as f64)),
        ("blind_rotations_step", Json::num(step_plan.blind_rotation_count() as f64)),
        (
            "blind_rotations_full_recompute",
            Json::num(full_plan.blind_rotation_count() as f64),
        ),
        ("prefill_s", Json::num(m_dec_prefill.mean_s)),
        ("step_s", Json::num(m_dec_step.mean_s)),
        ("full_recompute_s", Json::num(m_dec_full.mean_s)),
        ("step_speedup_vs_recompute", Json::num(m_dec_full.mean_s / m_dec_step.mean_s)),
    ])];

    // === Storage tier: hot takes vs sink spill/rehydrate, key parking ==
    // The PR 9 seam: one CtStore take/insert cycle served from the hot
    // tier vs the same cycle at budget 0 (encode → sink put on insert,
    // sink get → decode on take), plus KeyManager session parking — the
    // server key encoded into the sink — and the cold attach that
    // rebuilds it (key decode + FFT-plan rebuild).
    println!("\n=== Storage tier: hot vs spilled take/insert, park + cold attach ===");
    let bundle_cts: Vec<CtInt> =
        (0..d_model).map(|i| ctx.encrypt((i as i64 % 3) - 1, &ck, &mut rng)).collect();
    let hot_store = CtStore::with_memory("bench", DEFAULT_STORAGE_BUDGET);
    hot_store.insert(1, 1, Bundle { cts: bundle_cts.clone(), meta: 0 });
    let m_hot = bench("storage hot take+insert", cfg, || {
        let b = hot_store.try_take(1, 1).expect("tier").expect("live");
        hot_store.insert(1, 1, b);
    });
    let cold_store = CtStore::with_memory("bench", 0);
    cold_store.insert(1, 1, Bundle { cts: bundle_cts, meta: 0 });
    let m_cold = bench("storage spill+rehydrate", cfg, || {
        let b = cold_store.try_take(1, 1).expect("tier").expect("live");
        cold_store.insert(1, 1, b);
    });
    let km = KeyManager::new();
    let mut park_rng = Xoshiro256::new(0x57A6);
    let park_ck = ClientKey::generate(TfheParams::test_small(), &mut park_rng);
    let park_id = km.create_session(FheContext::new(park_ck.server_key(&mut park_rng)));
    let m_attach = bench("key park + cold attach", cfg, || {
        km.park_session(park_id).expect("parkable");
        let _ = km.session(park_id).expect("cold attach");
    });
    let cold_attaches =
        km.storage().metrics().cold_key_attaches.load(std::sync::atomic::Ordering::Relaxed);
    println!("  {}", m_hot.summary());
    println!("  {}", m_cold.summary());
    println!("  {}", m_attach.summary());
    println!(
        "  spilled/hot latency ratio: {:.2}, cold attaches: {cold_attaches}",
        m_cold.mean_s / m_hot.mean_s,
    );
    let storage_records = vec![Json::obj(vec![
        ("bundle_cts", Json::num(d_model as f64)),
        ("hot_take_insert_s", Json::num(m_hot.mean_s)),
        ("spill_rehydrate_s", Json::num(m_cold.mean_s)),
        ("spill_over_hot", Json::num(m_cold.mean_s / m_hot.mean_s)),
        ("park_cold_attach_s", Json::num(m_attach.mean_s)),
        ("cold_key_attaches", Json::num(cold_attaches as f64)),
    ])];

    // === Radix wide arithmetic: legalized wide accumulator vs native ===
    // The PR 10 seam: the same signed inhibitor once at native width and
    // once with a declared 9-bit accumulator — legalized into three
    // 3-bit limbs with packed carry propagation — on one 6-bit ϑ = 1
    // keyset. Counts come from the plans and the legalizer's RadixInfo;
    // the closed-form oracle (`optimizer::profile_radix`) is pinned
    // against these counters by tests/radix_it.rs, so the record here is
    // the carry overhead actually paid plus wall-clock.
    println!("\n=== Radix: legalized 9-bit accumulator vs native signed inhibitor ===");
    let ck = ClientKey::generate(TfheParams::test_multi_lut(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    ctx.set_threads(threads);
    let declared_bits = 9u32;
    let narrow_head = InhibitorSignedFhe::new(d, 1);
    let wide_head = InhibitorSignedFhe::new(d, 1).with_accumulator_bits(declared_bits);
    let (narrow_plan, _) = PlanRewriter::for_ctx(&ctx).rewrite(narrow_head.plan(t, d));
    let (wide_plan, wide_stats) = PlanRewriter::for_ctx(&ctx).rewrite(wide_head.plan(t, d));
    let radix_info = wide_plan.radix().expect("declared width must legalize").clone();
    let mut radix_inputs: Vec<CtInt> = Vec::with_capacity(3 * t * d);
    for (lo, hi, n) in [(-2i64, 1i64, 2 * t * d), (-3, 3, t * d)] {
        let vals = ITensor::random(&[n, 1], lo, hi, &mut rng);
        radix_inputs.extend(vals.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)));
    }
    let m_narrow = bench("signed native width", cfg, || narrow_plan.execute(&ctx, &radix_inputs));
    let m_wide = bench(
        &format!("signed wide {declared_bits}-bit x{} limbs", radix_info.spec.limbs),
        cfg,
        || wide_plan.execute(&ctx, &radix_inputs),
    );
    println!("  {}", m_narrow.summary());
    println!("  {}", m_wide.summary());
    println!(
        "  {declared_bits}-bit / native: pbs {} -> {}, blind rotations {} -> {} \
         (widened={} x{} limbs, carry_luts={}, carry_rotations={}, {:.3}x latency)",
        narrow_plan.pbs_count(),
        wide_plan.pbs_count(),
        narrow_plan.blind_rotation_count(),
        wide_plan.blind_rotation_count(),
        radix_info.widened,
        radix_info.spec.limbs,
        radix_info.carry_luts,
        radix_info.carry_rotations,
        m_wide.mean_s / m_narrow.mean_s,
    );
    let radix_records = vec![Json::obj(vec![
        ("mechanism", Json::str("inhibitor-signed")),
        ("declared_bits", Json::num(declared_bits as f64)),
        ("native_bits", Json::num(radix_info.spec.native_bits as f64)),
        ("limb_bits", Json::num(radix_info.spec.limb_bits as f64)),
        ("limbs", Json::num(radix_info.spec.limbs as f64)),
        ("widened_outputs", Json::num(wide_stats.radix_widened as f64)),
        ("pbs_native", Json::num(narrow_plan.pbs_count() as f64)),
        ("pbs_wide", Json::num(wide_plan.pbs_count() as f64)),
        ("blind_rotations_native", Json::num(narrow_plan.blind_rotation_count() as f64)),
        ("blind_rotations_wide", Json::num(wide_plan.blind_rotation_count() as f64)),
        ("carry_luts", Json::num(radix_info.carry_luts as f64)),
        ("carry_rotations", Json::num(radix_info.carry_rotations as f64)),
        ("native_s", Json::num(m_narrow.mean_s)),
        ("wide_s", Json::num(m_wide.mean_s)),
        ("wide_over_native", Json::num(m_wide.mean_s / m_narrow.mean_s)),
    ])];

    let record = Json::obj(vec![
        ("bench", Json::str("plan_bench")),
        ("seq_len", Json::num(t as f64)),
        ("dim", Json::num(d as f64)),
        ("threads", Json::num(threads as f64)),
        ("plan_vs_staged", Json::arr(records)),
        ("fusion", Json::arr(fusion_records)),
        ("wavefront", Json::arr(wavefront_records)),
        ("rewrite", Json::arr(rewrite_records)),
        ("multihead", Json::arr(multihead_records)),
        ("block", Json::arr(block_records)),
        ("decode", Json::arr(decode_records)),
        ("storage", Json::arr(storage_records)),
        ("radix", Json::arr(radix_records)),
    ]);
    // Write next to the workspace root (cargo runs benches with CWD at
    // the package root), where the perf-trajectory record is checked in.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_plan.json");
    match std::fs::write(path, format!("{record}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
