//! Plan-executor perf instrument: (a) declarative `CircuitPlan` execution
//! vs the PR 1 hand-staged forwards (the plan path must not regress), and
//! (b) cross-request fused level execution vs per-request execution of
//! the same co-scheduled batch (the fusion path must be no slower — at
//! small `T` it fills the worker pool that solo requests leave idle).
//! Writes a machine-readable record to `BENCH_plan.json`.
//!
//!   cargo bench --bench plan_bench

use inhibitor::bench_harness::{bench, BenchConfig};
use inhibitor::coordinator::FusedLevelExecutor;
use inhibitor::fhe_circuits::{CtMatrix, DotProductFhe, InhibitorFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{CircuitPlan, ClientKey, FheContext, TfheParams};
use inhibitor::util::json::Json;
use inhibitor::util::prng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(0x71A9);
    let (t, d) = (2usize, 2usize);
    let threads = inhibitor::tfhe::default_fhe_threads();
    let cfg = BenchConfig { warmup_iters: 1, samples: 10, inner_iters: 1 };
    let mut records = Vec::new();

    println!("=== Plan executor vs hand-staged circuits (T={t}, d={d}, {threads} threads) ===");
    for mech in ["inhibitor", "dotprod"] {
        let bits = if mech == "dotprod" { 6 } else { 5 };
        let ck = ClientKey::generate(TfheParams::test_for_bits(bits), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        ctx.set_threads(threads);
        let q = ITensor::random(&[t, d], -2, 2, &mut rng);
        let k = ITensor::random(&[t, d], -2, 2, &mut rng);
        let v = ITensor::random(&[t, d], 0, 3, &mut rng);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let (m_staged, m_plan) = if mech == "dotprod" {
            let head = DotProductFhe::new(d, 2);
            (
                bench(&format!("{mech} staged"), cfg, || head.forward_staged(&ctx, &cq, &ckk, &cv)),
                bench(&format!("{mech} plan"), cfg, || head.forward(&ctx, &cq, &ckk, &cv)),
            )
        } else {
            let head = InhibitorFhe::new(d, 1);
            (
                bench(&format!("{mech} staged"), cfg, || head.forward_staged(&ctx, &cq, &ckk, &cv)),
                bench(&format!("{mech} plan"), cfg, || head.forward(&ctx, &cq, &ckk, &cv)),
            )
        };
        println!("  {}", m_staged.summary());
        println!("  {}", m_plan.summary());
        println!("  plan/staged latency ratio: {:.3}", m_plan.mean_s / m_staged.mean_s);
        records.push(Json::obj(vec![
            ("mechanism", Json::str(mech)),
            ("staged_s", Json::num(m_staged.mean_s)),
            ("plan_s", Json::num(m_plan.mean_s)),
            ("plan_over_staged", Json::num(m_plan.mean_s / m_staged.mean_s)),
        ]));
    }

    // === Fused vs per-request execution of a co-scheduled batch =========
    println!("\n=== Cross-request fusion: R co-scheduled T={t} inhibitor requests ===");
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    ctx.set_threads(threads);
    let head = InhibitorFhe::new(d, 1);
    let plan = head.plan(t, d);
    let mut fusion_records = Vec::new();
    for &n_req in &[2usize, 4, 8] {
        let bundles: Vec<Vec<CtInt>> = (0..n_req)
            .map(|_| {
                let q = ITensor::random(&[t, d], -2, 2, &mut rng);
                let k = ITensor::random(&[t, d], -2, 2, &mut rng);
                let v = ITensor::random(&[t, d], 0, 3, &mut rng);
                let mut inputs = Vec::with_capacity(3 * t * d);
                for tensor in [&q, &k, &v] {
                    inputs.extend(
                        tensor.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)),
                    );
                }
                inputs
            })
            .collect();
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (&plan, b.as_slice())).collect();
        let m_solo = bench(&format!("solo x{n_req}"), cfg, || {
            bundles.iter().map(|b| plan.execute(&ctx, b)).collect::<Vec<_>>()
        });
        let m_fused =
            bench(&format!("fused x{n_req}"), cfg, || FusedLevelExecutor::new(&ctx).run(&requests));
        let solo_rps = n_req as f64 / m_solo.mean_s;
        let fused_rps = n_req as f64 / m_fused.mean_s;
        println!(
            "  R={n_req}: solo {:.2} req/s, fused {:.2} req/s ({:.2}x)",
            solo_rps,
            fused_rps,
            fused_rps / solo_rps
        );
        fusion_records.push(Json::obj(vec![
            ("requests", Json::num(n_req as f64)),
            ("solo_req_per_sec", Json::num(solo_rps)),
            ("fused_req_per_sec", Json::num(fused_rps)),
            ("fused_speedup", Json::num(fused_rps / solo_rps)),
        ]));
    }

    let record = Json::obj(vec![
        ("bench", Json::str("plan_bench")),
        ("seq_len", Json::num(t as f64)),
        ("dim", Json::num(d as f64)),
        ("threads", Json::num(threads as f64)),
        ("plan_vs_staged", Json::arr(records)),
        ("fusion", Json::arr(fusion_records)),
    ]);
    // Write next to the workspace root (cargo runs benches with CWD at
    // the package root), where the perf-trajectory record is checked in.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_plan.json");
    match std::fs::write(path, format!("{record}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
