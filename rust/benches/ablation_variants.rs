//! E5 ablations: design choices called out in the paper —
//!   * fused (appendix eq. 9/10) vs naive (eq. 6/7) inhibition,
//!   * shifted-score α sweep (Z' = (Z − α)⁺): sparsity of surviving terms,
//!   * signed vs unsigned inhibitor cost,
//!   * Manhattan-score vs dot-product score cost in isolation.
//!
//!   cargo bench --bench ablation_variants

use inhibitor::attention::inhibitor::{
    inhibit_fused_x2, inhibit_naive, inhibit_signed_fused_x2, inhibit_signed_naive,
    inhibitor_scores,
};
use inhibitor::bench_harness::{bench_auto, print_table};
use inhibitor::quant::FixedMult;
use inhibitor::tensor::ITensor;
use inhibitor::util::prng::Xoshiro256;
use std::time::Duration;

fn main() {
    let mut rng = Xoshiro256::new(0xAB1A);
    let (t, d) = (128usize, 64usize);
    let q = ITensor::random(&[t, d], -127, 127, &mut rng);
    let k = ITensor::random(&[t, d], -127, 127, &mut rng);
    let v = ITensor::random(&[t, d], -127, 127, &mut rng);
    let inv_gamma = FixedMult::from_f64(1.0 / (d as f64).sqrt());
    let z = inhibitor_scores(&q, &k, inv_gamma, 4);
    let target = Duration::from_millis(200);

    // --- fused vs naive ---
    let rows = vec![
        bench_auto("inhibit naive (eq. 6)", target, || inhibit_naive(&z, &v)),
        bench_auto("inhibit fused (eq. 9)", target, || inhibit_fused_x2(&z, &v)),
        bench_auto("signed naive (eq. 7)", target, || inhibit_signed_naive(&z, &v)),
        bench_auto("signed fused (eq. 10)", target, || inhibit_signed_fused_x2(&z, &v)),
        bench_auto("scores manhattan (eq. 5)", target, || {
            inhibitor_scores(&q, &k, inv_gamma, 4)
        }),
        bench_auto("scores dot-product (QKᵀ)", target, || q.matmul(&k.transpose2())),
    ];
    print_table(
        &format!("Ablation: implementations at T={t}, d={d} (int16 codes)"),
        &rows,
        |name| {
            // ratio columns: fused vs its naive counterpart
            match name {
                "inhibit fused (eq. 9)" => Some(0),
                "signed fused (eq. 10)" => Some(2),
                "scores manhattan (eq. 5)" => Some(5),
                _ => None,
            }
        },
    );

    // --- α sweep: how much of V survives inhibition ---
    println!("\n=== Shifted-score α sweep (surviving mass at T=64, d=32) ===");
    println!("{:>8} {:>14} {:>16}", "α (codes)", "mean Z'", "nonzero H terms");
    // Inputs scaled so the score magnitude is commensurate with V (z'
    // mean ~30 at α=0): the α sweep then spans no-shift → full pass.
    let (t2, d2) = (64usize, 32usize);
    let q2 = ITensor::random(&[t2, d2], -8, 8, &mut rng);
    let k2 = ITensor::random(&[t2, d2], -8, 8, &mut rng);
    let v2 = ITensor::random(&[t2, d2], 0, 64, &mut rng);
    for alpha_q in [0i64, 8, 16, 24, 32, 48] {
        let z2 = inhibitor_scores(&q2, &k2, FixedMult::from_f64(1.0 / (d2 as f64).sqrt()), alpha_q);
        let mean_z = z2.data.iter().sum::<i64>() as f64 / z2.data.len() as f64;
        // Count (j, k) terms that survive the ReLU in eq. 6.
        let mut nonzero = 0usize;
        let mut total = 0usize;
        for i in 0..t2 {
            for kk in 0..d2 {
                for j in 0..t2 {
                    total += 1;
                    if v2.at2(j, kk) - z2.at2(i, j) > 0 {
                        nonzero += 1;
                    }
                }
            }
        }
        println!(
            "{:>8} {:>14.1} {:>15.1}%",
            alpha_q,
            mean_z,
            100.0 * nonzero as f64 / total as f64
        );
    }
    println!("(larger α ⇒ smaller Z' ⇒ more value mass passes — the paper's motivation for the shift)");
}
