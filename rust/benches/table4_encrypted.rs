//! E4 / paper Table 4: encrypted attention execution time, both
//! mechanisms, T ∈ {2, 4, 8, 16}, d = 2, under the real TFHE
//! implementation.
//!
//! Method mirrors the paper (whose Table 4 caption reads "Estimated
//! encrypted execution time"): small T cells are executed outright and
//! timed; the largest cells are *measured-PBS × counted-PBS* estimates
//! (every PBS in the circuit is identical work, so the product is exact
//! up to linear-op noise, which we also measure). Set
//! INHIBITOR_BENCH_FULL=1 to force full execution of every cell.
//!
//!   cargo bench --bench table4_encrypted

use inhibitor::fhe_circuits::{CtMatrix, DotProductFhe, InhibitorFhe};
use inhibitor::optimizer::profile;
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::{bootstrap, ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::Xoshiro256;
use std::time::Instant;

struct Cell {
    mech: &'static str,
    t: usize,
    seconds: f64,
    pbs: u64,
    executed: bool,
}

fn main() {
    let full = std::env::var("INHIBITOR_BENCH_FULL").is_ok();
    let dim = 2usize;
    let mut rng = Xoshiro256::new(0xF4E);

    // One keyset per mechanism at the precision its circuit needs
    // (paper: dot-product needs ~2 bits more — that is *why* it is slower
    // per PBS; we reproduce that by using the profiled message width).
    let mut cells: Vec<Cell> = Vec::new();
    for (mech_name, is_dot) in [("inhibitor", false), ("dotprod", true)] {
        // Execution parameters: profile-determined message bits, bench
        // poly size; lwe_dim per the bench curve.
        let prof = profile(
            if is_dot {
                inhibitor::attention::Mechanism::DotProduct
            } else {
                inhibitor::attention::Mechanism::Inhibitor
            },
            4,
            dim,
            3,
        );
        let bits = prof.required_message_bits().min(6);
        let params = TfheParams::bench_for_bits(bits);
        println!(
            "[{mech_name}] keygen: n={} N={} p={}b (profile wanted {}b)",
            params.lwe_dim,
            params.poly_size,
            bits,
            prof.required_message_bits()
        );
        let ck = ClientKey::generate(params, &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));

        // Measure per-PBS cost once per keyset.
        let ct = ctx.encrypt(1, &ck, &mut rng);
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            let _ = ctx.relu(&ct);
        }
        let per_pbs = t0.elapsed().as_secs_f64() / reps as f64;
        println!("[{mech_name}] measured {:.1} ms/PBS", per_pbs * 1e3);

        for t in [2usize, 4, 8, 16] {
            // Expected PBS straight from the circuit plan — the same DAG
            // `forward` executes, so the accounting cannot drift.
            let pbs_expected = if is_dot {
                DotProductFhe::new(dim, 2).plan(t, dim).pbs_count()
            } else {
                InhibitorFhe::new(dim, 1).plan(t, dim).pbs_count()
            };
            // Default budget keeps `cargo bench` under ~5 min; the full
            // sweep (results/table4.txt was produced with these budgets:
            // inhibitor ≤8, dotprod ≤4) runs with INHIBITOR_BENCH_FULL=1.
            let execute = full || t <= if is_dot { 2 } else { 4 };
            if execute {
                let q = ITensor::random(&[t, dim], -2, 2, &mut rng);
                let k = ITensor::random(&[t, dim], -2, 2, &mut rng);
                let v = ITensor::random(&[t, dim], 0, 3, &mut rng);
                let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
                let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
                let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
                bootstrap::reset_pbs_count();
                let t0 = Instant::now();
                if is_dot {
                    let _ = DotProductFhe::new(dim, 2).forward(&ctx, &cq, &ckk, &cv);
                } else {
                    let _ = InhibitorFhe::new(dim, 1).forward(&ctx, &cq, &ckk, &cv);
                }
                let secs = t0.elapsed().as_secs_f64();
                let pbs = bootstrap::pbs_count();
                assert_eq!(pbs, pbs_expected, "PBS accounting must match the circuit");
                cells.push(Cell { mech: mech_name, t, seconds: secs, pbs, executed: true });
                println!("[{mech_name}] T={t}: executed {pbs} PBS in {secs:.2}s");
            } else {
                let secs = per_pbs * pbs_expected as f64;
                cells.push(Cell {
                    mech: mech_name,
                    t,
                    seconds: secs,
                    pbs: pbs_expected,
                    executed: false,
                });
                println!(
                    "[{mech_name}] T={t}: estimated {pbs_expected} PBS × {:.1} ms = {secs:.1}s",
                    per_pbs * 1e3
                );
            }
        }
    }

    println!("\n=== Table 4 — encrypted attention, CPU (d=2) ===");
    println!(
        "{:>4} {:>14} {:>14} {:>8}   {:>14} {:>8}",
        "T", "dotprod", "inhibitor", "speedup", "paper dp/inh", "paper x"
    );
    for &(t, p_dot, p_inh) in &inhibitor::bench_tables::PAPER_TABLE4_S {
        let dot = cells.iter().find(|c| c.t == t && c.mech == "dotprod");
        let inh = cells.iter().find(|c| c.t == t && c.mech == "inhibitor");
        if let (Some(dot), Some(inh)) = (dot, inh) {
            println!(
                "{:>4} {:>12.2}s{} {:>12.2}s{} {:>7.2}x   {:>6.2}/{:<6.3}s {:>7.2}x",
                t,
                dot.seconds,
                if dot.executed { " " } else { "*" },
                inh.seconds,
                if inh.executed { " " } else { "*" },
                dot.seconds / inh.seconds,
                p_dot,
                p_inh,
                p_dot / p_inh,
            );
        }
    }
    println!("(* = measured-PBS × counted-PBS estimate, as in the paper's caption)");
    for c in &cells {
        println!(
            "RAW {} T={} seconds={:.4} pbs={} executed={}",
            c.mech, c.t, c.seconds, c.pbs, c.executed
        );
    }
}
