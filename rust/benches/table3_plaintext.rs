//! E3 / paper Table 3: plaintext integer attention timing on CPU, both
//! mechanisms, T ∈ {32, 64, 128, 256}, fixed-size single head (d = 64),
//! int16 codes — the paper's own experimental setup ("integer 16-bit
//! arithmetics implemented in the Rust programming language").
//!
//!   cargo bench --bench table3_plaintext

use std::time::Duration;

fn main() {
    let cells =
        inhibitor::bench_tables::run_table3(&[32, 64, 128, 256], 64, Duration::from_millis(300));
    inhibitor::bench_tables::print_table3(&cells);
    for c in &cells {
        println!(
            "RAW {mech} T={t} mean_s={m:.6e} ci95_s={ci:.2e} n={n}",
            mech = c.mechanism.name(),
            t = c.seq_len,
            m = c.measurement.mean_s,
            ci = c.measurement.ci95_s,
            n = c.measurement.samples
        );
    }
}
