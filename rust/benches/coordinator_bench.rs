//! E6: coordinator throughput/latency — batching on vs off, queue depth
//! sweep. L3 must not be the bottleneck (the paper's costs live in the
//! engines); this bench verifies the coordinator overhead is µs-scale.
//!
//!   cargo bench --bench coordinator_bench

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{
    BatchPolicy, Coordinator, EnginePath, FusedLevelExecutor, FusedRequest, Payload, RoutePolicy,
};
use inhibitor::fhe_circuits::InhibitorFhe;
use inhibitor::model::{ModelConfig, QTransformer};
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::{Rng64, Xoshiro256};
use std::time::{Duration, Instant};

fn run_load(c: &Coordinator, n: usize, concurrency: usize) -> (f64, f64) {
    let t0 = Instant::now();
    let mut lat_sum = 0.0;
    let mut done = 0usize;
    let mut inflight = Vec::new();
    for i in 0..n {
        let rx = c
            .submit(
                EnginePath::QuantInt("inhibitor".into()),
                Payload::Features(vec![(i % 7) as f32 * 0.1; 8 * 4], (8, 4)),
            )
            .expect("submit");
        inflight.push(rx);
        if inflight.len() >= concurrency {
            for rx in inflight.drain(..) {
                let r = rx.recv_timeout(Duration::from_secs(30)).expect("resp");
                lat_sum += r.latency_s;
                done += 1;
            }
        }
    }
    for rx in inflight {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("resp");
        lat_sum += r.latency_s;
        done += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    (done as f64 / wall, lat_sum / done as f64)
}

fn coordinator(max_batch: usize, max_wait_us: u64) -> Coordinator {
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
    cfg.in_features = 4;
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(cfg, 3),
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            queue_cap: 65536,
        },
    );
    c
}

fn main() {
    println!("=== Coordinator throughput/latency (quant engine, T=8 d=16 model) ===");
    println!(
        "{:>10} {:>12} {:>12} {:>14} {:>12}",
        "max_batch", "max_wait", "concurrency", "req/s", "mean lat"
    );
    for &(mb, wait_us) in &[(1usize, 0u64), (8, 200), (32, 500)] {
        for &conc in &[1usize, 16, 128] {
            let c = coordinator(mb, wait_us);
            // Warm.
            run_load(&c, 64, conc);
            let (rps, lat) = run_load(&c, 2000, conc);
            println!(
                "{:>10} {:>10}µs {:>12} {:>14.0} {:>10.1}µs",
                mb,
                wait_us,
                conc,
                rps,
                lat * 1e6
            );
        }
    }

    // Pure dispatch overhead: an engine that does nothing.
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 1, 1);
    cfg.in_features = 1;
    cfg.ffn_dim = 1;
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(cfg, 1),
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100), queue_cap: 65536 },
    );
    run_load(&c, 256, 64);
    let (rps, lat) = run_load(&c, 20_000, 256);
    println!(
        "\ndispatch floor (1×1 model): {:.0} req/s, {:.1} µs mean latency — \
         coordinator overhead per request",
        rps,
        lat * 1e6
    );

    fault_tolerance_overhead();
}

/// PR 6: price of the fault-tolerant executor when nothing goes wrong.
/// Serving routes every encrypted batch through `run_checked` — per-job
/// panic isolation (`catch_unwind` in the PBS pool) plus deadline/
/// cancellation checks at each level boundary. Compare it against the
/// unchecked solo path (`CircuitPlan::execute`) on the same plan and
/// inputs; the target recorded in BENCH_plan.json is < 1% overhead
/// (the checks are O(levels), the work is O(PBS)).
fn fault_tolerance_overhead() {
    let (t, d) = (2usize, 2usize);
    let mut rng = Xoshiro256::new(0xFA0BE);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let plan = InhibitorFhe::new(d, 1).plan_for(&ctx, t, d);
    let inputs: Vec<CtInt> = (0..3 * t * d)
        .map(|i| {
            let v = if i < 2 * t * d {
                rng.next_range_i64(-2, 2)
            } else {
                rng.next_range_i64(0, 3)
            };
            ctx.encrypt(v, &ck, &mut rng)
        })
        .collect();
    let exec = FusedLevelExecutor::new(&ctx);
    // Warm both paths (LUT caches, allocator).
    let _ = plan.execute(&ctx, &inputs);
    let _ = exec.run_checked(&[FusedRequest::new(&plan, &inputs)]);

    const REPS: usize = 5;
    let t0 = Instant::now();
    for _ in 0..REPS {
        let _ = plan.execute(&ctx, &inputs);
    }
    let unchecked = t0.elapsed().as_secs_f64() / REPS as f64;
    let t0 = Instant::now();
    for _ in 0..REPS {
        let (results, _) = exec.run_checked(&[FusedRequest::new(&plan, &inputs)]);
        assert!(results.iter().all(|r| r.is_ok()));
    }
    let checked = t0.elapsed().as_secs_f64() / REPS as f64;
    let overhead_pct = (checked / unchecked - 1.0) * 100.0;
    println!(
        "\n=== Fault-tolerance overhead (no faults armed, inhibitor t={t} d={d}) ===\n\
         unchecked plan.execute : {:.3} ms/run\n\
         checked   run_checked  : {:.3} ms/run\n\
         overhead               : {overhead_pct:+.2}% (target < 1%)",
        unchecked * 1e3,
        checked * 1e3,
    );
}
