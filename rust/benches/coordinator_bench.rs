//! E6: coordinator throughput/latency — batching on vs off, queue depth
//! sweep. L3 must not be the bottleneck (the paper's costs live in the
//! engines); this bench verifies the coordinator overhead is µs-scale.
//!
//!   cargo bench --bench coordinator_bench

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::model::{ModelConfig, QTransformer};
use std::time::{Duration, Instant};

fn run_load(c: &Coordinator, n: usize, concurrency: usize) -> (f64, f64) {
    let t0 = Instant::now();
    let mut lat_sum = 0.0;
    let mut done = 0usize;
    let mut inflight = Vec::new();
    for i in 0..n {
        let rx = c
            .submit(
                EnginePath::QuantInt("inhibitor".into()),
                Payload::Features(vec![(i % 7) as f32 * 0.1; 8 * 4], (8, 4)),
            )
            .expect("submit");
        inflight.push(rx);
        if inflight.len() >= concurrency {
            for rx in inflight.drain(..) {
                let r = rx.recv_timeout(Duration::from_secs(30)).expect("resp");
                lat_sum += r.latency_s;
                done += 1;
            }
        }
    }
    for rx in inflight {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("resp");
        lat_sum += r.latency_s;
        done += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    (done as f64 / wall, lat_sum / done as f64)
}

fn coordinator(max_batch: usize, max_wait_us: u64) -> Coordinator {
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
    cfg.in_features = 4;
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(cfg, 3),
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            queue_cap: 65536,
        },
    );
    c
}

fn main() {
    println!("=== Coordinator throughput/latency (quant engine, T=8 d=16 model) ===");
    println!(
        "{:>10} {:>12} {:>12} {:>14} {:>12}",
        "max_batch", "max_wait", "concurrency", "req/s", "mean lat"
    );
    for &(mb, wait_us) in &[(1usize, 0u64), (8, 200), (32, 500)] {
        for &conc in &[1usize, 16, 128] {
            let c = coordinator(mb, wait_us);
            // Warm.
            run_load(&c, 64, conc);
            let (rps, lat) = run_load(&c, 2000, conc);
            println!(
                "{:>10} {:>10}µs {:>12} {:>14.0} {:>10.1}µs",
                mb,
                wait_us,
                conc,
                rps,
                lat * 1e6
            );
        }
    }

    // Pure dispatch overhead: an engine that does nothing.
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 1, 1);
    cfg.in_features = 1;
    cfg.ffn_dim = 1;
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(cfg, 1),
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100), queue_cap: 65536 },
    );
    run_load(&c, 256, 64);
    let (rps, lat) = run_load(&c, 20_000, 256);
    println!(
        "\ndispatch floor (1×1 model): {:.0} req/s, {:.1} µs mean latency — \
         coordinator overhead per request",
        rps,
        lat * 1e6
    );
}
