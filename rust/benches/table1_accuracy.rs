//! E1 / paper Table 1: task-parity results. The training itself is the
//! build-path experiment (`make table1` → results/table1.json, JAX); this
//! bench (a) prints that table next to the paper's numbers when present
//! and (b) re-checks mechanism parity *in the quantized Rust engine* on
//! the adding task: quantized inference with either mechanism must track
//! its own float-engine reference closely (the quantization gap is the
//! deployment-relevant metric for an FHE stack).
//!
//!   cargo bench --bench table1_accuracy

use inhibitor::attention::Mechanism;
use inhibitor::model::{ModelConfig, ModelInput, QTransformer, TaskHead};
use inhibitor::tensor::ITensor;
use inhibitor::util::json::Json;
use inhibitor::util::prng::{Rng64, Xoshiro256};

fn main() {
    // (a) training results from the build path.
    match std::fs::read_to_string("results/table1.json") {
        Ok(text) => match Json::parse(&text) {
            Ok(j) => print_training_table(&j),
            Err(e) => println!("results/table1.json unparseable: {e}"),
        },
        Err(_) => {
            println!("results/table1.json not found — run `make table1` for the training half")
        }
    }

    // (b) quantized-engine parity check (both mechanisms, same inputs).
    println!("\n=== Quantized-engine mechanism parity (adding-task shape) ===");
    let mut rng = Xoshiro256::new(0xE1);
    for mech in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
        let mut cfg = ModelConfig::small(mech, 32, 24);
        cfg.in_features = 2;
        cfg.head = TaskHead::Regress;
        let model = QTransformer::random(cfg, 42);
        // Output spread across random inputs — a degenerate (constant)
        // head would flag a broken mechanism integration.
        let mut outs = Vec::new();
        for _ in 0..64 {
            let x = ITensor::random(&[32, 2], -100, 100, &mut rng);
            outs.push(model.forward(&ModelInput::Features(x)).data[0] as f64);
        }
        let mean = outs.iter().sum::<f64>() / outs.len() as f64;
        let var = outs.iter().map(|o| (o - mean) * (o - mean)).sum::<f64>() / outs.len() as f64;
        println!(
            "{:<18} output mean {:>10.2} std {:>10.2}  (responsive: {})",
            mech.name(),
            mean,
            var.sqrt(),
            var > 0.0
        );
        assert!(var > 0.0, "{} head is unresponsive to inputs", mech.name());
    }
    let _ = rng.next_u64();
}

fn print_training_table(j: &Json) {
    println!("=== Table 1 — benchmark-task parity (trained in JAX, build path) ===");
    println!("paper:  adding mse 0.11%/0.12%, MNIST acc 98.2/97.9, IMDB acc 87.2/87.3, IAMW edit 17.9/18.1");
    println!("{:<14} {:<18} {:>8} {:>10} {:>10}", "task", "mechanism", "metric", "mean", "std");
    if let Json::Obj(map) = j {
        for (key, v) in map {
            let metric = v.get("metric").and_then(|m| m.as_str()).unwrap_or("?");
            let mean = v.get("mean").and_then(|m| m.as_f64()).unwrap_or(f64::NAN);
            let std = v.get("std").and_then(|m| m.as_f64()).unwrap_or(f64::NAN);
            let mut parts = key.splitn(2, '/');
            let task = parts.next().unwrap_or("?");
            let mech = parts.next().unwrap_or("?");
            println!("{task:<14} {mech:<18} {metric:>8} {mean:>10.4} {std:>10.4}");
        }
    }
}
