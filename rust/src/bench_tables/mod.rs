//! Paper-table printers (S13): shared by `rust/benches/*` and the
//! `inhibitor tables` CLI subcommand. Each function regenerates one table
//! of the paper's evaluation in the same row layout, annotated with the
//! paper's reference values so the *shape* comparison is immediate.
//!
//! PBS counts in these tables (Table 2's `#PBS` column via
//! `optimizer::profile`, Table 4's expected counts) are derived from
//! `tfhe::plan::CircuitPlan` — the executed DAG — not hand formulas.

use crate::attention::{AttentionHead, AttnConfig, Mechanism};
use crate::bench_harness::{bench_auto, Measurement};
use crate::tensor::ITensor;
use crate::util::prng::Xoshiro256;
use std::time::Duration;

/// Paper reference values (for side-by-side printing).
pub const PAPER_TABLE3_US: [(usize, f64, f64); 4] = [
    // (T, dotprod µs, inhibitor µs)
    (32, 98.6, 63.1),
    (64, 330.0, 178.0),
    (128, 1200.0, 577.0),
    (256, 4480.0, 2500.0),
];

pub const PAPER_TABLE4_S: [(usize, f64, f64); 4] = [
    // (T, dotprod s, inhibitor s)
    (2, 2.68, 0.749),
    (4, 22.4, 8.56),
    (8, 107.0, 23.8),
    (16, 828.0, 127.0),
];

/// One measured cell of Table 3.
pub struct Table3Cell {
    pub mechanism: Mechanism,
    pub seq_len: usize,
    pub measurement: Measurement,
}

/// Run the plaintext int16 timing experiment (Table 3): fixed-size single
/// head (d = `dim`), int16 codes, both mechanisms.
pub fn run_table3(seq_lens: &[usize], dim: usize, target: Duration) -> Vec<Table3Cell> {
    let mut cells = Vec::new();
    let mut rng = Xoshiro256::new(0x7AB1E3);
    for &t in seq_lens {
        for mech in [Mechanism::DotProduct, Mechanism::Inhibitor] {
            let cfg = AttnConfig::new(mech, t, dim);
            let head = AttentionHead::build(cfg, 0.01);
            // int16 codes, as in the paper's Rust experiment.
            let q = ITensor::random(&[t, dim], -127, 127, &mut rng);
            let k = ITensor::random(&[t, dim], -127, 127, &mut rng);
            let v = ITensor::random(&[t, dim], -127, 127, &mut rng);
            let m = bench_auto(
                &format!("{} T={}", mech.name(), t),
                target,
                || head.forward(&q, &k, &v),
            );
            cells.push(Table3Cell { mechanism: mech, seq_len: t, measurement: m });
        }
    }
    cells
}

/// Print Table 3 next to the paper's numbers.
pub fn print_table3(cells: &[Table3Cell]) {
    println!("\n=== Table 3 — plaintext int16 attention, CPU (single head, d fixed) ===");
    println!(
        "{:>4} {:>14} {:>14} {:>8}   {:>12} {:>8}",
        "T", "dotprod", "inhibitor", "speedup", "paper dp/inh", "paper x"
    );
    for &(t, p_dot, p_inh) in &PAPER_TABLE3_US {
        let dot = cells.iter().find(|c| c.seq_len == t && c.mechanism == Mechanism::DotProduct);
        let inh = cells.iter().find(|c| c.seq_len == t && c.mechanism == Mechanism::Inhibitor);
        if let (Some(dot), Some(inh)) = (dot, inh) {
            println!(
                "{:>4} {:>14} {:>14} {:>7.2}x   {:>5.0}/{:<5.0}µs {:>7.2}x",
                t,
                Measurement::fmt_time(dot.measurement.mean_s),
                Measurement::fmt_time(inh.measurement.mean_s),
                dot.measurement.mean_s / inh.measurement.mean_s,
                p_dot,
                p_inh,
                p_dot / p_inh,
            );
        }
    }
}

/// Print Table 2 (parameter optimizer output) next to the paper's rows.
pub fn print_table2(flops_per_sec: f64) {
    let rows = crate::optimizer::table2(&[2, 4, 8, 16], flops_per_sec);
    println!("\n=== Table 2 — TFHE parameters selected by the optimizer (d=2, 3-bit inputs) ===");
    println!(
        "{:>4} {:<12} {:>7} {:>8} {:>6} {:>9} {:>4} {:>5} {:>7} {:>11}",
        "T", "mechanism", "lweDim", "baseLog", "level", "polySize", "int", "uint", "#PBS", "est PBS ms"
    );
    for r in rows {
        println!(
            "{:>4} {:<12} {:>7} {:>8} {:>6} {:>9} {:>4} {:>5} {:>7} {:>11.2}",
            r.seq_len,
            r.mechanism,
            r.lwe_dim,
            r.base_log,
            r.level,
            r.poly_size,
            r.int_bits,
            r.uint_bits,
            r.pbs_count,
            r.est_pbs_ms
        );
    }
    println!("paper: inhibitor rows used int 5-6 / uint 4-6; dotprod int 6-8 / uint 7-8,");
    println!("       polySize 2048-4096, lweDim 792-883, baseLog 15-23, level 1-2.");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_produces_all_cells_and_inhibitor_wins() {
        // Tiny target duration — statistical quality is the bench's job;
        // here we assert structure + the headline direction at T=64.
        let cells = run_table3(&[64], 64, Duration::from_millis(30));
        assert_eq!(cells.len(), 2);
        let dot = &cells[0].measurement.mean_s;
        let inh = &cells[1].measurement.mean_s;
        assert!(
            inh < dot,
            "inhibitor ({inh:.2e}s) should beat dotprod ({dot:.2e}s) at T=64"
        );
    }
}
