//! TFHE parameter sets (S4): macro-parameters (LWE dimension, GLWE
//! polynomial size/dimension, noise) and micro-parameters (decomposition
//! base/levels) in the taxonomy of Bergerat et al. 2023. The optimizer
//! (`crate::optimizer`) *derives* sets like these from noise + cost
//! models; the constants here are hand-checked working sets used by tests
//! and benches.

/// Gadget decomposition parameters (base 2^base_log, `level` digits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecompParams {
    pub base_log: usize,
    pub level: usize,
}

impl DecompParams {
    pub const fn new(base_log: usize, level: usize) -> Self {
        DecompParams { base_log, level }
    }
}

/// Complete parameter set for the levelled LWE + PBS pipeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TfheParams {
    /// LWE dimension n (the "small" key the client encrypts under).
    pub lwe_dim: usize,
    /// GLWE polynomial size N (power of two).
    pub poly_size: usize,
    /// GLWE dimension k.
    pub glwe_dim: usize,
    /// LWE fresh-noise std (torus fraction).
    pub lwe_noise_std: f64,
    /// GLWE fresh-noise std (torus fraction).
    pub glwe_noise_std: f64,
    /// PBS (bootstrap key) decomposition.
    pub pbs_decomp: DecompParams,
    /// Key-switch decomposition.
    pub ks_decomp: DecompParams,
    /// Message precision in bits (excluding the padding bit).
    pub message_bits: u32,
}

impl TfheParams {
    /// Size of the message space (number of slots).
    pub fn message_space(&self) -> u64 {
        1u64 << self.message_bits
    }

    /// Encoding step Δ = 2^64 / 2^(message_bits + 1) — one padding bit.
    pub fn delta(&self) -> u64 {
        1u64 << (63 - self.message_bits)
    }

    /// Dimension of the LWE ciphertext extracted from a GLWE (k·N).
    pub fn extracted_lwe_dim(&self) -> usize {
        self.glwe_dim * self.poly_size
    }

    /// Sanity checks used by tests and the optimizer.
    pub fn validate(&self) -> Result<(), String> {
        if !self.poly_size.is_power_of_two() {
            return Err(format!("poly_size {} must be a power of two", self.poly_size));
        }
        if self.poly_size < (1usize << (self.message_bits + 1)) {
            return Err(format!(
                "poly_size {} too small for {} message bits (+padding): blind rotation \
                 cannot resolve all slots",
                self.poly_size, self.message_bits
            ));
        }
        if self.pbs_decomp.base_log * self.pbs_decomp.level > 64 {
            return Err("pbs decomposition exceeds 64 bits".into());
        }
        if self.ks_decomp.base_log * self.ks_decomp.level > 64 {
            return Err("ks decomposition exceeds 64 bits".into());
        }
        Ok(())
    }

    /// Working set for fast unit tests: ~2^80-security-class toy noise but
    /// structurally identical to production sets. 3-bit messages.
    pub fn test_small() -> Self {
        TfheParams {
            lwe_dim: 320,
            poly_size: 512,
            glwe_dim: 1,
            lwe_noise_std: 2f64.powi(-22),
            glwe_noise_std: 2f64.powi(-42),
            pbs_decomp: DecompParams::new(15, 2),
            ks_decomp: DecompParams::new(4, 3),
            message_bits: 3,
        }
    }

    /// Fast test/demo set scaled to a message width: N sized so the
    /// mod-switch noise clears the half-slot, KS decomposition sized so
    /// its rounding error does too (base_log·level must comfortably
    /// exceed message_bits + padding + margin).
    pub fn test_for_bits(message_bits: u32) -> Self {
        let mut p = Self::test_small();
        p.message_bits = message_bits;
        p.poly_size = match message_bits {
            0..=3 => 512,
            4..=5 => 1024,
            _ => 2048,
        };
        p.ks_decomp = if message_bits >= 5 {
            DecompParams::new(4, 6)
        } else {
            DecompParams::new(4, 3)
        };
        p
    }

    /// Bench set for `message_bits` ∈ 2..=8, mirroring the shape of the
    /// paper's Table 2 (lweDim ~800, polySize 2048/4096, baseLog 15–23,
    /// level 1–2). Noise follows the security curve in
    /// `optimizer::noise::min_noise_for_security` at λ=128.
    pub fn bench_for_bits(message_bits: u32) -> Self {
        // Larger message spaces need bigger accumulators (N) and lower
        // GLWE noise; these mirror Concrete's published parameter curves.
        // Mod-switch noise σ ≈ √(n/24)/(2N) must clear Δ/2 = 2^-(p+2):
        // p ≤ 4 → N=2048, p ∈ {5,6} → N=4096, p ≥ 7 → N=8192.
        let (poly_size, pbs_decomp) = match message_bits {
            0..=5 => (2048, DecompParams::new(23, 1)),
            6 => (4096, DecompParams::new(22, 1)),
            _ => (8192, DecompParams::new(15, 2)),
        };
        // Higher precision needs a quieter small key (KS noise ∝ σ_lwe²),
        // and a finer KS decomposition.
        let lwe_dim = 750 + 30 * message_bits as usize;
        let ks_decomp = match message_bits {
            0..=4 => DecompParams::new(4, 6),
            5..=6 => DecompParams::new(3, 8),
            _ => DecompParams::new(2, 14),
        };
        TfheParams {
            lwe_dim,
            poly_size,
            glwe_dim: 1,
            lwe_noise_std: crate::optimizer::noise::min_noise_for_security(lwe_dim, 128),
            glwe_noise_std: crate::optimizer::noise::min_noise_for_security(poly_size, 128),
            pbs_decomp,
            ks_decomp,
            message_bits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_small_validates() {
        TfheParams::test_small().validate().unwrap();
    }

    #[test]
    fn delta_and_space() {
        let p = TfheParams::test_small();
        assert_eq!(p.message_space(), 8);
        assert_eq!(p.delta(), 1u64 << 60);
        assert_eq!(p.extracted_lwe_dim(), 512);
    }

    #[test]
    fn rejects_undersized_poly() {
        let mut p = TfheParams::test_small();
        p.message_bits = 9; // needs poly_size ≥ 1024
        assert!(p.validate().is_err());
    }

    #[test]
    fn bench_sets_validate_for_all_widths() {
        for bits in 2..=8 {
            let p = TfheParams::bench_for_bits(bits);
            p.validate().unwrap_or_else(|e| panic!("bits={bits}: {e}"));
            assert!(p.lwe_dim >= 750);
        }
    }
}
