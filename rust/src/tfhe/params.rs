//! TFHE parameter sets (S4): macro-parameters (LWE dimension, GLWE
//! polynomial size/dimension, noise) and micro-parameters (decomposition
//! base/levels) in the taxonomy of Bergerat et al. 2023. The optimizer
//! (`crate::optimizer`) *derives* sets like these from noise + cost
//! models; the constants here are hand-checked working sets used by tests
//! and benches.

/// Gadget decomposition parameters (base 2^base_log, `level` digits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecompParams {
    pub base_log: usize,
    pub level: usize,
}

impl DecompParams {
    pub const fn new(base_log: usize, level: usize) -> Self {
        DecompParams { base_log, level }
    }
}

/// Complete parameter set for the levelled LWE + PBS pipeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TfheParams {
    /// LWE dimension n (the "small" key the client encrypts under).
    pub lwe_dim: usize,
    /// GLWE polynomial size N (power of two).
    pub poly_size: usize,
    /// GLWE dimension k.
    pub glwe_dim: usize,
    /// LWE fresh-noise std (torus fraction).
    pub lwe_noise_std: f64,
    /// GLWE fresh-noise std (torus fraction).
    pub glwe_noise_std: f64,
    /// PBS (bootstrap key) decomposition.
    pub pbs_decomp: DecompParams,
    /// Key-switch decomposition.
    pub ks_decomp: DecompParams,
    /// Message precision in bits (excluding the padding bit).
    pub message_bits: u32,
    /// Multi-value bootstrap budget ϑ: up to `2^ϑ` LUTs of the same
    /// input may share one blind rotation (`ServerKey::pbs_multi`). The
    /// packed accumulator needs `2^ϑ` sub-slots per message slot and the
    /// coarse mod-switch costs ϑ bits of noise margin, so a set only
    /// advertises ϑ > 0 when its polynomial size carries that headroom
    /// (enforced by [`TfheParams::validate`]). 0 disables packing.
    pub many_lut_log: u32,
}

impl TfheParams {
    /// Size of the message space (number of slots).
    pub fn message_space(&self) -> u64 {
        1u64 << self.message_bits
    }

    /// Encoding step Δ = 2^64 / 2^(message_bits + 1) — one padding bit.
    pub fn delta(&self) -> u64 {
        1u64 << (63 - self.message_bits)
    }

    /// Dimension of the LWE ciphertext extracted from a GLWE (k·N).
    pub fn extracted_lwe_dim(&self) -> usize {
        self.glwe_dim * self.poly_size
    }

    /// Sanity checks used by tests and the optimizer.
    pub fn validate(&self) -> Result<(), String> {
        if !self.poly_size.is_power_of_two() {
            return Err(format!("poly_size {} must be a power of two", self.poly_size));
        }
        if self.poly_size < (1usize << (self.message_bits + 1)) {
            return Err(format!(
                "poly_size {} too small for {} message bits (+padding): blind rotation \
                 cannot resolve all slots",
                self.poly_size, self.message_bits
            ));
        }
        if self.pbs_decomp.base_log * self.pbs_decomp.level > 64 {
            return Err("pbs decomposition exceeds 64 bits".into());
        }
        if self.ks_decomp.base_log * self.ks_decomp.level > 64 {
            return Err("ks decomposition exceeds 64 bits".into());
        }
        // Multi-value bootstrap: each message slot must hold 2^ϑ sub-slots
        // *and* keep the half-slot pre-rotation aligned to the sub-slot
        // stride, i.e. slot = N/2^p ≥ 2^(ϑ+1).
        if self.many_lut_log > 0
            && self.poly_size < (1usize << (self.message_bits + 1 + self.many_lut_log))
        {
            return Err(format!(
                "poly_size {} too small for a 2^{} multi-value bootstrap budget at {} \
                 message bits: packing needs N ≥ 2^(p + 1 + ϑ)",
                self.poly_size, self.many_lut_log, self.message_bits
            ));
        }
        Ok(())
    }

    /// Largest number of LUTs [`ServerKey::pbs_multi`] may fuse into one
    /// blind rotation under this set (1 = packing disabled).
    ///
    /// [`ServerKey::pbs_multi`]: super::bootstrap::ServerKey::pbs_multi
    pub fn max_multi_lut(&self) -> usize {
        1usize << self.many_lut_log
    }

    /// Working set for fast unit tests: ~2^80-security-class toy noise but
    /// structurally identical to production sets. 3-bit messages.
    pub fn test_small() -> Self {
        TfheParams {
            lwe_dim: 320,
            poly_size: 512,
            glwe_dim: 1,
            lwe_noise_std: 2f64.powi(-22),
            glwe_noise_std: 2f64.powi(-42),
            pbs_decomp: DecompParams::new(15, 2),
            ks_decomp: DecompParams::new(4, 3),
            message_bits: 3,
            many_lut_log: 0,
        }
    }

    /// Fast test/demo set scaled to a message width: N sized so the
    /// mod-switch noise clears the half-slot, KS decomposition sized so
    /// its rounding error does too (base_log·level must comfortably
    /// exceed message_bits + padding + margin).
    pub fn test_for_bits(message_bits: u32) -> Self {
        let mut p = Self::test_small();
        p.message_bits = message_bits;
        p.poly_size = match message_bits {
            0..=3 => 512,
            4..=5 => 1024,
            _ => 2048,
        };
        p.ks_decomp = if message_bits >= 5 {
            DecompParams::new(4, 6)
        } else {
            DecompParams::new(4, 3)
        };
        p
    }

    /// Test set with a multi-value bootstrap budget of ϑ = 1 (two LUTs
    /// per blind rotation): [`Self::test_for_bits`] with the polynomial
    /// size doubled, which buys exactly the one bit of mod-switch margin
    /// the coarser rounding of `pbs_multi` consumes — the packed path
    /// decodes with the same σ-margin the standard path has at the base
    /// size. The KS decomposition is deepened to match the doubled
    /// extracted dimension (same choice `test_for_bits` makes at N=2048).
    ///
    /// Margin note: the base sets give bits ≤ 4 roughly twice the
    /// half-slot headroom of bits 5 (N does not grow between 4 and 5
    /// message bits), and the doubling preserves that ratio — so the
    /// packed path at 5 bits runs at the *same, tighter* margin the
    /// existing `test_for_bits(5)` tests run at, while the decode-exact
    /// test grids (`rewrite_it`, `pbs_multi` unit tests) pin the
    /// comfortable ≤ 4-bit sets.
    pub fn test_multi_lut(message_bits: u32) -> Self {
        Self::test_multi_lut_theta(message_bits, 1)
    }

    /// Generalization of [`Self::test_multi_lut`] to an arbitrary
    /// multi-value budget ϑ: the polynomial size scales by `2^ϑ`, buying
    /// exactly the ϑ bits of mod-switch margin the coarser rounding of a
    /// `2^ϑ`-way packed accumulator consumes — the σ-margin argument of
    /// the ϑ = 1 set applies bit-for-bit per doubling. ϑ = 2 is the set
    /// the block-circuit tests use to execute requant + ReLU + split
    /// groups of three distinct tables in one blind rotation.
    pub fn test_multi_lut_theta(message_bits: u32, theta: u32) -> Self {
        assert!(theta >= 1, "a multi-value test set needs ϑ ≥ 1");
        let mut p = Self::test_for_bits(message_bits);
        p.poly_size <<= theta;
        p.ks_decomp = DecompParams::new(4, 6);
        p.many_lut_log = theta;
        p
    }

    /// Bench set for `message_bits` ∈ 2..=8, mirroring the shape of the
    /// paper's Table 2 (lweDim ~800, polySize 2048/4096, baseLog 15–23,
    /// level 1–2). Noise follows the security curve in
    /// `optimizer::noise::min_noise_for_security` at λ=128.
    pub fn bench_for_bits(message_bits: u32) -> Self {
        // Larger message spaces need bigger accumulators (N) and lower
        // GLWE noise; these mirror Concrete's published parameter curves.
        // Mod-switch noise σ ≈ √(n/24)/(2N) must clear Δ/2 = 2^-(p+2):
        // p ≤ 4 → N=2048, p ∈ {5,6} → N=4096, p ≥ 7 → N=8192.
        let (poly_size, pbs_decomp) = match message_bits {
            0..=5 => (2048, DecompParams::new(23, 1)),
            6 => (4096, DecompParams::new(22, 1)),
            _ => (8192, DecompParams::new(15, 2)),
        };
        // Higher precision needs a quieter small key (KS noise ∝ σ_lwe²),
        // and a finer KS decomposition. Bits 4 takes the deeper split
        // already at bench scale: its packed budget (below) narrows the
        // half-slot by one bit, and the (4,6) rows would eat the margin.
        let lwe_dim = 750 + 30 * message_bits as usize;
        let ks_decomp = match message_bits {
            0..=3 => DecompParams::new(4, 6),
            4..=6 => DecompParams::new(3, 8),
            _ => DecompParams::new(2, 14),
        };
        // Packed budget: a 2^ϑ-way multi-value bootstrap mod-switches to
        // a ϑ-bit-coarser grid, so ϑ > 0 is only advertised where the
        // λ=128 curve still clears the narrower half-slot at the bench
        // failure class (2^-17) — through 4 message bits at these macro
        // parameters, pinned by `optimizer::noise::bench_packed_sets_are_
        // feasible` and the headroom test below. Wider spaces stay
        // unpacked until the curve provisions the extra margin.
        let many_lut_log = if message_bits <= 4 { 1 } else { 0 };
        TfheParams {
            lwe_dim,
            poly_size,
            glwe_dim: 1,
            lwe_noise_std: crate::optimizer::noise::min_noise_for_security(lwe_dim, 128),
            glwe_noise_std: crate::optimizer::noise::min_noise_for_security(poly_size, 128),
            pbs_decomp,
            ks_decomp,
            message_bits,
            many_lut_log,
        }
    }

    /// Candidate set the parameter search probes: both the grid walk and
    /// the feasibility binary search in `optimizer::search` build their
    /// candidates through this one constructor so the candidate shape
    /// (k = 1, noise on the λ=`security` curve, packing off — the search
    /// costs by LUT evaluations, a conservative bound when the chosen
    /// set carries no packing headroom) cannot silently diverge between
    /// the two call sites.
    pub fn search_candidate(
        lwe_dim: usize,
        poly_size: usize,
        glwe_noise_std: f64,
        pbs_decomp: DecompParams,
        ks_decomp: DecompParams,
        message_bits: u32,
        security: u32,
    ) -> Self {
        TfheParams {
            lwe_dim,
            poly_size,
            glwe_dim: 1,
            lwe_noise_std: crate::optimizer::noise::min_noise_for_security(lwe_dim, security),
            glwe_noise_std,
            pbs_decomp,
            ks_decomp,
            message_bits,
            many_lut_log: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_small_validates() {
        TfheParams::test_small().validate().unwrap();
    }

    #[test]
    fn delta_and_space() {
        let p = TfheParams::test_small();
        assert_eq!(p.message_space(), 8);
        assert_eq!(p.delta(), 1u64 << 60);
        assert_eq!(p.extracted_lwe_dim(), 512);
    }

    #[test]
    fn rejects_undersized_poly() {
        let mut p = TfheParams::test_small();
        p.message_bits = 9; // needs poly_size ≥ 1024
        assert!(p.validate().is_err());
    }

    #[test]
    fn multi_lut_sets_validate_and_advertise_budget() {
        for bits in 3..=5 {
            let p = TfheParams::test_multi_lut(bits);
            p.validate().unwrap_or_else(|e| panic!("bits={bits}: {e}"));
            assert_eq!(p.max_multi_lut(), 2);
            assert_eq!(p.poly_size, 2 * TfheParams::test_for_bits(bits).poly_size);
        }
        assert_eq!(TfheParams::test_small().max_multi_lut(), 1, "default: packing off");
    }

    #[test]
    fn theta2_sets_validate_and_advertise_groups_of_four() {
        for bits in 3..=5 {
            let p = TfheParams::test_multi_lut_theta(bits, 2);
            p.validate().unwrap_or_else(|e| panic!("bits={bits}: {e}"));
            assert_eq!(p.max_multi_lut(), 4);
            assert_eq!(p.poly_size, 4 * TfheParams::test_for_bits(bits).poly_size);
        }
        // ϑ = 1 must stay exactly the historical test_multi_lut set.
        assert_eq!(TfheParams::test_multi_lut_theta(4, 1), TfheParams::test_multi_lut(4));
    }

    #[test]
    fn rejects_multi_lut_budget_without_headroom() {
        // N=512 resolves 8 message bits (+padding) exactly, with no spare
        // sub-slot for a packed accumulator.
        let mut p = TfheParams::test_small();
        p.message_bits = 8;
        p.validate().unwrap();
        p.many_lut_log = 1;
        assert!(p.validate().is_err());
        p.poly_size = 1024;
        p.validate().unwrap();
    }

    #[test]
    fn bench_sets_validate_for_all_widths() {
        for bits in 2..=8 {
            let p = TfheParams::bench_for_bits(bits);
            p.validate().unwrap_or_else(|e| panic!("bits={bits}: {e}"));
            assert!(p.lwe_dim >= 750);
        }
    }

    #[test]
    fn bench_packed_budget_keeps_coarse_rounding_headroom() {
        // The coarse-rounding headroom invariant at bench scale: every
        // width that advertises a packed budget must keep at least one
        // spare power of two between the packed sub-slot floor
        // 2^(p + 1 + ϑ) and N — the same clearance ratio the unpacked
        // curve keeps between 2^(p + 1) and N — so pbs_multi's coarser
        // mod-switch grid never lands inside the half-slot the standard
        // path was provisioned to resolve. The noise side of the same
        // invariant (the λ=128 curve clearing the narrower half-slot at
        // the 2^-17 bench failure class) is pinned in
        // `optimizer::noise::tests::bench_packed_sets_are_feasible`.
        let mut packed_widths = 0;
        for bits in 2..=8u32 {
            let p = TfheParams::bench_for_bits(bits);
            if p.many_lut_log == 0 {
                continue;
            }
            packed_widths += 1;
            assert!(
                p.poly_size >= (1usize << (p.message_bits + 2 + p.many_lut_log)),
                "bits={bits}: N={} leaves no coarse-rounding headroom at ϑ={}",
                p.poly_size,
                p.many_lut_log
            );
            assert!(p.max_multi_lut() >= 2, "bits={bits}");
        }
        // Table-4 / plan_bench widths exercise packed rotations: the
        // budget is provisioned on the low-precision bench rows, not
        // merely allowed by validate().
        assert!(packed_widths >= 3, "only {packed_widths} bench widths carry a packed budget");
        assert_eq!(TfheParams::bench_for_bits(4).max_multi_lut(), 2);
        assert_eq!(TfheParams::bench_for_bits(5).max_multi_lut(), 1, "unprovisioned width");
    }
}
