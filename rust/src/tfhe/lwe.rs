//! LWE ciphertexts and secret keys (S4).
//!
//! `LweCiphertext = (a_1..a_n, b)` with `b = Σ a_i·s_i + m + e` over the
//! discretized torus. Homomorphic: addition, subtraction, multiplication
//! by plaintext literals ("constant-to-variable" in the paper's terms),
//! and plaintext offset addition. Variable×variable multiplication does
//! NOT exist at this layer — that is the paper's entire point; it must be
//! built from two PBS (see `ops::ct_mul`).

use super::torus::{gaussian_torus, Torus};
use crate::util::prng::{Rng64, Xoshiro256};

/// Binary LWE secret key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LweSecretKey {
    /// Bits stored as 0/1 u64 for branch-free dot products.
    pub bits: Vec<u64>,
}

impl LweSecretKey {
    pub fn generate(dim: usize, rng: &mut Xoshiro256) -> Self {
        LweSecretKey { bits: (0..dim).map(|_| rng.next_u64() & 1).collect() }
    }

    pub fn dim(&self) -> usize {
        self.bits.len()
    }
}

/// An LWE ciphertext: mask + body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LweCiphertext {
    pub mask: Vec<Torus>,
    pub body: Torus,
}

impl LweCiphertext {
    pub fn dim(&self) -> usize {
        self.mask.len()
    }

    /// Encrypt a torus message under `key` with fresh noise `noise_std`.
    pub fn encrypt(msg: Torus, key: &LweSecretKey, noise_std: f64, rng: &mut Xoshiro256) -> Self {
        let mask: Vec<Torus> = (0..key.dim()).map(|_| rng.next_u64()).collect();
        let mut body = msg.wrapping_add(gaussian_torus(noise_std, rng));
        for (a, s) in mask.iter().zip(key.bits.iter()) {
            body = body.wrapping_add(a.wrapping_mul(*s));
        }
        LweCiphertext { mask, body }
    }

    /// Noiseless "trivial" encryption (known-plaintext constant): mask 0.
    /// Decryptable under any key; used for circuit constants.
    pub fn trivial(msg: Torus, dim: usize) -> Self {
        LweCiphertext { mask: vec![0; dim], body: msg }
    }

    /// Decrypt to the noisy torus phase (caller rounds/decodes).
    pub fn decrypt(&self, key: &LweSecretKey) -> Torus {
        assert_eq!(self.dim(), key.dim(), "ciphertext/key dimension mismatch");
        let mut phase = self.body;
        for (a, s) in self.mask.iter().zip(key.bits.iter()) {
            phase = phase.wrapping_sub(a.wrapping_mul(*s));
        }
        phase
    }

    /// Homomorphic addition.
    pub fn add(&self, o: &Self) -> Self {
        assert_eq!(self.dim(), o.dim());
        LweCiphertext {
            mask: self.mask.iter().zip(o.mask.iter()).map(|(a, b)| a.wrapping_add(*b)).collect(),
            body: self.body.wrapping_add(o.body),
        }
    }

    /// Homomorphic subtraction.
    pub fn sub(&self, o: &Self) -> Self {
        assert_eq!(self.dim(), o.dim());
        LweCiphertext {
            mask: self.mask.iter().zip(o.mask.iter()).map(|(a, b)| a.wrapping_sub(*b)).collect(),
            body: self.body.wrapping_sub(o.body),
        }
    }

    /// In-place addition (hot path: avoids reallocating the mask).
    pub fn add_assign(&mut self, o: &Self) {
        assert_eq!(self.dim(), o.dim());
        for (a, b) in self.mask.iter_mut().zip(o.mask.iter()) {
            *a = a.wrapping_add(*b);
        }
        self.body = self.body.wrapping_add(o.body);
    }

    pub fn neg(&self) -> Self {
        LweCiphertext {
            mask: self.mask.iter().map(|a| a.wrapping_neg()).collect(),
            body: self.body.wrapping_neg(),
        }
    }

    /// Multiply by a signed plaintext literal (noise grows by |c|).
    pub fn scalar_mul(&self, c: i64) -> Self {
        let cu = c as u64;
        LweCiphertext {
            mask: self.mask.iter().map(|a| a.wrapping_mul(cu)).collect(),
            body: self.body.wrapping_mul(cu),
        }
    }

    /// Add a plaintext torus offset (no noise growth).
    pub fn add_plain(&self, m: Torus) -> Self {
        LweCiphertext { mask: self.mask.clone(), body: self.body.wrapping_add(m) }
    }

    pub fn sub_plain(&self, m: Torus) -> Self {
        self.add_plain(m.wrapping_neg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::torus::{torus_distance, torus_from_f64};
    use crate::util::prop::{prop_assert, prop_check};

    const STD: f64 = 1.0 / (1u64 << 30) as f64;

    #[test]
    fn encrypt_decrypt_close() {
        let mut rng = Xoshiro256::new(1);
        let key = LweSecretKey::generate(500, &mut rng);
        for frac in [0.0, 0.125, -0.25, 0.4999] {
            let m = torus_from_f64(frac);
            let ct = LweCiphertext::encrypt(m, &key, STD, &mut rng);
            let dec = ct.decrypt(&key);
            assert!(torus_distance(dec, m) < 1e-6, "{frac}");
        }
    }

    #[test]
    fn homomorphic_linear_ops() {
        prop_check("LWE linear homomorphism", 24, |rng| {
            let key = LweSecretKey::generate(400, rng);
            let m1 = torus_from_f64(rng.next_f64() * 0.2 - 0.1);
            let m2 = torus_from_f64(rng.next_f64() * 0.2 - 0.1);
            let c = rng.next_range_i64(-4, 4);
            let ct1 = LweCiphertext::encrypt(m1, &key, STD, rng);
            let ct2 = LweCiphertext::encrypt(m2, &key, STD, rng);
            let got_add = ct1.add(&ct2).decrypt(&key);
            let got_sub = ct1.sub(&ct2).decrypt(&key);
            let got_mul = ct1.scalar_mul(c).decrypt(&key);
            prop_assert(
                torus_distance(got_add, m1.wrapping_add(m2)) < 1e-6,
                "addition phase drifted",
            )?;
            prop_assert(
                torus_distance(got_sub, m1.wrapping_sub(m2)) < 1e-6,
                "subtraction phase drifted",
            )?;
            prop_assert(
                torus_distance(got_mul, m1.wrapping_mul(c as u64)) < 1e-5,
                "scalar mul phase drifted",
            )
        });
    }

    #[test]
    fn trivial_decrypts_under_any_key() {
        let mut rng = Xoshiro256::new(5);
        let k1 = LweSecretKey::generate(300, &mut rng);
        let k2 = LweSecretKey::generate(300, &mut rng);
        let m = torus_from_f64(0.25);
        let ct = LweCiphertext::trivial(m, 300);
        assert_eq!(ct.decrypt(&k1), m);
        assert_eq!(ct.decrypt(&k2), m);
    }

    #[test]
    fn plaintext_offset() {
        let mut rng = Xoshiro256::new(9);
        let key = LweSecretKey::generate(300, &mut rng);
        let m = torus_from_f64(0.1);
        let off = torus_from_f64(0.05);
        let ct = LweCiphertext::encrypt(m, &key, STD, &mut rng);
        let dec = ct.add_plain(off).decrypt(&key);
        assert!(torus_distance(dec, m.wrapping_add(off)) < 1e-6);
        let dec2 = ct.sub_plain(off).decrypt(&key);
        assert!(torus_distance(dec2, m.wrapping_sub(off)) < 1e-6);
    }

    #[test]
    fn ciphertexts_hide_the_message() {
        // Same message encrypted twice yields different ciphertexts.
        let mut rng = Xoshiro256::new(33);
        let key = LweSecretKey::generate(300, &mut rng);
        let m = torus_from_f64(0.2);
        let c1 = LweCiphertext::encrypt(m, &key, STD, &mut rng);
        let c2 = LweCiphertext::encrypt(m, &key, STD, &mut rng);
        assert_ne!(c1, c2);
    }
}
