//! Programmable bootstrapping (S4): the operation the whole paper's cost
//! analysis revolves around.
//!
//! PBS = mod-switch → blind rotation (a chain of `n` CMux over the
//! bootstrap key) → sample extract → key switch. Filling the accumulator
//! ("test vector") with a LUT of `f` over the message space evaluates the
//! univariate function `f` *and* resets noise — Chillotti et al. 2019.
//!
//! Layout: one padding bit + `p` message bits; message `m ∈ [0, 2^p)` is
//! encoded as `m·Δ`, `Δ = 2^(63−p)`. The padding bit keeps the phase in
//! the first half of the torus so the negacyclic wrap never flips the
//! LUT sign. A half-slot pre-rotation centres the rounding window.
//!
//! ## Batched execution engine
//!
//! Two properties make the PBS layer batchable:
//!
//! * A PBS is deterministic server-side (no fresh randomness), so a batch
//!   of independent (ciphertext, LUT) jobs can run in any order — or on
//!   any thread — and produce bit-identical outputs.
//! * [`ServerKey`] is immutable after key generation: the bootstrap key,
//!   key-switch key and FFT plan (twiddles precomputed in
//!   `NegacyclicFft::new`) are plain owned data with no interior
//!   mutability, so `ServerKey: Send + Sync` holds structurally (asserted
//!   by a compile-checked test below) and one key can serve many workers.
//!
//! [`PreparedLut`] hoists the accumulator construction (slot replication
//! + half-slot pre-rotation, previously rebuilt inside every `pbs` call)
//! out of the hot loop; [`ServerKey::pbs_batch`] fans independent jobs
//! across a `std::thread::scope` worker pool with one reusable
//! [`ExtScratch`] per worker. `PBS_COUNT` stays exact under concurrency
//! (atomic increment per bootstrap). Key generation reuses the same
//! scoped-pool pattern: the per-bit GGSW encryptions of
//! [`ClientKey::server_key`] are independent and run across workers, with
//! per-bit child RNGs derived sequentially so the key is thread-count
//! invariant.

use super::fft::NegacyclicFft;
use super::ggsw::{ExtScratch, GgswCiphertext, GgswFourier};
use super::glwe::{GlweCiphertext, GlweSecretKey};
use super::keyswitch::KeySwitchKey;
use super::lwe::{LweCiphertext, LweSecretKey};
use super::params::TfheParams;
use super::torus::Torus;
use crate::util::prng::{Rng64, Xoshiro256};
use std::sync::atomic::{AtomicU64, Ordering};

/// Global PBS counter — the unit the paper counts circuit cost in.
/// Benches read/reset it to report "number of PBS" per circuit.
pub static PBS_COUNT: AtomicU64 = AtomicU64::new(0);

pub fn pbs_count() -> u64 {
    PBS_COUNT.load(Ordering::Relaxed)
}

pub fn reset_pbs_count() {
    PBS_COUNT.store(0, Ordering::Relaxed);
}

/// Client-side key material.
pub struct ClientKey {
    pub params: TfheParams,
    pub lwe_key: LweSecretKey,
    pub glwe_key: GlweSecretKey,
}

impl ClientKey {
    pub fn generate(params: TfheParams, rng: &mut Xoshiro256) -> Self {
        params.validate().expect("invalid TFHE parameters");
        ClientKey {
            params,
            lwe_key: LweSecretKey::generate(params.lwe_dim, rng),
            glwe_key: GlweSecretKey::generate(params.poly_size, params.glwe_dim, rng),
        }
    }

    /// Generate the public server key (bootstrap + key-switch keys),
    /// parallelizing keygen across the default worker budget
    /// (`FHE_THREADS` env or all cores — same knob as `pbs_batch`).
    pub fn server_key(&self, rng: &mut Xoshiro256) -> ServerKey {
        self.server_key_with_threads(crate::tfhe::ops::default_fhe_threads(), rng)
    }

    /// Server-key generation with an explicit worker count. The `n`
    /// per-bit GGSW encryptions dominate keygen and are independent, so
    /// they fan out over a scoped-thread pool (the `pbs_batch` pattern).
    ///
    /// Determinism: one child RNG seed per key bit is drawn
    /// *sequentially* from the parent stream before any worker starts, so
    /// the generated key material is a pure function of the parent RNG
    /// state — bit-identical at every thread count (pinned by
    /// `parallel_keygen_matches_sequential`). The key-switch key is
    /// generated on the caller thread from the parent stream afterwards.
    pub fn server_key_with_threads(&self, threads: usize, rng: &mut Xoshiro256) -> ServerKey {
        let fft = NegacyclicFft::new(self.params.poly_size);
        let bits = &self.lwe_key.bits;
        let n = bits.len();
        let seeds: Vec<u64> = bits.iter().map(|_| rng.next_u64()).collect();
        let encrypt_bit = |bit: u64, seed: u64| -> GgswFourier {
            let mut crng = Xoshiro256::new(seed);
            GgswCiphertext::encrypt(
                bit,
                &self.glwe_key,
                self.params.pbs_decomp,
                self.params.glwe_noise_std,
                &mut crng,
            )
            .to_fourier(&fft)
        };
        let threads = threads.clamp(1, n.max(1));
        let bsk: Vec<GgswFourier> = if threads == 1 {
            bits.iter().zip(&seeds).map(|(&bit, &seed)| encrypt_bit(bit, seed)).collect()
        } else {
            let chunk = (n + threads - 1) / threads;
            let mut out: Vec<Option<GgswFourier>> = bits.iter().map(|_| None).collect();
            std::thread::scope(|s| {
                for ((bit_chunk, seed_chunk), out_chunk) in
                    bits.chunks(chunk).zip(seeds.chunks(chunk)).zip(out.chunks_mut(chunk))
                {
                    let encrypt_bit = &encrypt_bit;
                    s.spawn(move || {
                        for ((&bit, &seed), slot) in
                            bit_chunk.iter().zip(seed_chunk).zip(out_chunk.iter_mut())
                        {
                            *slot = Some(encrypt_bit(bit, seed));
                        }
                    });
                }
            });
            out.into_iter().map(|g| g.expect("worker filled every slot")).collect()
        };
        let ksk = KeySwitchKey::generate(
            &self.glwe_key.to_extracted_lwe(),
            &self.lwe_key,
            self.params.ks_decomp,
            self.params.lwe_noise_std,
            rng,
        );
        ServerKey { params: self.params, bsk, ksk, fft }
    }
}

/// Server-side evaluation key.
pub struct ServerKey {
    pub params: TfheParams,
    /// One GGSW (Fourier domain) per LWE secret bit.
    bsk: Vec<GgswFourier>,
    ksk: KeySwitchKey,
    fft: NegacyclicFft,
}

/// A lookup table over the message space: `table[m]` is the *torus value*
/// the PBS returns for message `m` (usually `f(m)·Δ`).
#[derive(Clone, Debug)]
pub struct Lut {
    pub table: Vec<Torus>,
}

impl Lut {
    /// Build from a message-space function `f: [0,2^p) → [0,2^p)` (values
    /// taken mod 2^p and encoded at Δ).
    pub fn from_fn(params: &TfheParams, f: impl Fn(u64) -> u64) -> Self {
        let space = params.message_space();
        let delta = params.delta();
        let table = (0..space)
            .map(|m| (f(m) & (space - 1)).wrapping_mul(delta))
            .collect();
        Lut { table }
    }

    /// Build from a function returning raw torus values (full control).
    pub fn from_torus_fn(params: &TfheParams, f: impl Fn(u64) -> Torus) -> Self {
        let table = (0..params.message_space()).map(f).collect();
        Lut { table }
    }
}

/// A LUT whose blind-rotation accumulator is fully precomputed: the
/// slot-replicated test vector with its half-slot pre-rotation already
/// applied. Building this once per LUT (instead of once per `pbs` call)
/// removes a GLWE allocation, an `N`-coefficient replication fill and a
/// monomial rotation from every bootstrap; since monomial rotations
/// compose exactly (`rotate(a)∘rotate(b) = rotate(a+b)` over coefficient
/// shuffles), the prepared path is bit-identical to the on-the-fly one.
#[derive(Clone, Debug)]
pub struct PreparedLut {
    /// Trivial GLWE holding the pre-rotated test vector.
    acc: GlweCiphertext,
}

impl ServerKey {
    /// Accumulator polynomial for `lut`: slot `m` replicated over
    /// `N / 2^p` coefficients, with a half-slot pre-rotation so that the
    /// rounding window is centred on each slot.
    fn test_vector(&self, lut: &Lut) -> GlweCiphertext {
        let n = self.params.poly_size;
        let p_space = self.params.message_space() as usize;
        let slot = n / p_space; // coefficients per message slot
        debug_assert!(slot >= 1);
        let mut tv = vec![0u64; n];
        for (m, &val) in lut.table.iter().enumerate() {
            for j in 0..slot {
                tv[m * slot + j] = val;
            }
        }
        // Half-slot pre-rotation: acc ← tv · X^{−half_slot} (rotate left),
        // centring each slot's rounding window. The double sign flip at the
        // 0-boundary (negative noise on m=0 reads −(−tv[...])) makes the
        // wrap exact — same convention as tfhe-rs' generate_lookup_table.
        let acc = GlweCiphertext::trivial(tv, self.params.glwe_dim);
        acc.rotate_monomial((2 * n - slot / 2) as u64)
    }

    /// Precompute the reusable accumulator for `lut`.
    pub fn prepare_lut(&self, lut: &Lut) -> PreparedLut {
        PreparedLut { acc: self.test_vector(lut) }
    }

    /// A fresh scratch buffer sized for this key's CMux chain; reuse one
    /// per worker thread across many PBS.
    pub fn scratch(&self) -> ExtScratch {
        ExtScratch::new(self.params.poly_size, self.params.glwe_dim, self.params.pbs_decomp)
    }

    /// Blind rotation: returns GLWE whose constant coefficient encrypts
    /// `lut[decode(ct)]`.
    fn blind_rotate(
        &self,
        ct: &LweCiphertext,
        lut: &PreparedLut,
        scratch: &mut ExtScratch,
    ) -> GlweCiphertext {
        let n2 = (2 * self.params.poly_size) as u64;
        // Mod-switch mask and body to Z_{2N}.
        let switch = |t: Torus| -> u64 { super::torus::round_to_modulus(t, n2) };
        let b_t = switch(ct.body);
        let mut acc = lut.acc.rotate_monomial(n2 - b_t);
        for (a, ggsw) in ct.mask.iter().zip(self.bsk.iter()) {
            let a_t = switch(*a);
            if a_t == 0 {
                continue;
            }
            ggsw.cmux_rotate_assign(&self.fft, &mut acc, a_t, scratch);
        }
        acc
    }

    /// Full programmable bootstrap: evaluate `lut` on the encrypted
    /// message and return a fresh-noise ciphertext under the small key.
    /// Convenience path — builds the accumulator per call; hot paths use
    /// [`Self::prepare_lut`] + [`Self::pbs_prepared`] / [`Self::pbs_batch`].
    pub fn pbs(&self, ct: &LweCiphertext, lut: &Lut) -> LweCiphertext {
        self.pbs_prepared(ct, &self.prepare_lut(lut))
    }

    /// PBS against a precomputed accumulator (allocates its own scratch).
    pub fn pbs_prepared(&self, ct: &LweCiphertext, lut: &PreparedLut) -> LweCiphertext {
        let mut scratch = self.scratch();
        self.pbs_prepared_with_scratch(ct, lut, &mut scratch)
    }

    /// PBS against a precomputed accumulator with a caller-owned scratch
    /// buffer — the zero-per-call-allocation hot path of the batch engine.
    pub fn pbs_prepared_with_scratch(
        &self,
        ct: &LweCiphertext,
        lut: &PreparedLut,
        scratch: &mut ExtScratch,
    ) -> LweCiphertext {
        PBS_COUNT.fetch_add(1, Ordering::Relaxed);
        let acc = self.blind_rotate(ct, lut, scratch);
        let extracted = acc.sample_extract(0);
        self.ksk.keyswitch(&extracted)
    }

    /// Execute a batch of independent PBS jobs across `threads` workers.
    ///
    /// Jobs are split into contiguous chunks, one `std::thread::scope`
    /// worker per chunk, each with its own reusable [`ExtScratch`].
    /// Output order matches input order, and every output ciphertext is
    /// bit-identical to what sequential execution produces (PBS is
    /// deterministic); `PBS_COUNT` advances by exactly `jobs.len()`.
    pub fn pbs_batch(
        &self,
        jobs: &[(&LweCiphertext, &PreparedLut)],
        threads: usize,
    ) -> Vec<LweCiphertext> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let threads = threads.max(1).min(jobs.len());
        if threads == 1 {
            let mut scratch = self.scratch();
            return jobs
                .iter()
                .map(|&(ct, lut)| self.pbs_prepared_with_scratch(ct, lut, &mut scratch))
                .collect();
        }
        let chunk = (jobs.len() + threads - 1) / threads;
        let mut out: Vec<Option<LweCiphertext>> = jobs.iter().map(|_| None).collect();
        std::thread::scope(|s| {
            for (job_chunk, out_chunk) in jobs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                s.spawn(move || {
                    let mut scratch = self.scratch();
                    for (&(ct, lut), slot) in job_chunk.iter().zip(out_chunk.iter_mut()) {
                        *slot = Some(self.pbs_prepared_with_scratch(ct, lut, &mut scratch));
                    }
                });
            }
        });
        out.into_iter().map(|c| c.expect("worker filled every slot")).collect()
    }

    /// Number of CMux levels (= LWE dim); used by cost reporting.
    pub fn lwe_dim(&self) -> usize {
        self.bsk.len()
    }

    /// Structural equality of the key material (bootstrap-key spectra and
    /// key-switch rows). Used to pin the parallel keygen against the
    /// single-threaded derivation — not a constant-time comparison.
    pub fn key_material_eq(&self, other: &ServerKey) -> bool {
        self.params == other.params && self.bsk == other.bsk && self.ksk == other.ksk
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::encoding::Encoder;

    fn setup() -> (ClientKey, ServerKey, Xoshiro256) {
        let mut rng = Xoshiro256::new(2024);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let sk = ck.server_key(&mut rng);
        (ck, sk, rng)
    }

    #[test]
    fn pbs_identity_over_full_message_space() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        for m in 0..ck.params.message_space() {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let out = sk.pbs(&ct, &lut);
            let got = enc.decrypt_raw(&out, &ck);
            assert_eq!(got, m, "identity LUT at m={m}");
        }
    }

    #[test]
    fn pbs_evaluates_nontrivial_function() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (m * m + 1) % space);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let got = enc.decrypt_raw(&sk.pbs(&ct, &lut), &ck);
            assert_eq!(got, (m * m + 1) % space, "square LUT at m={m}");
        }
    }

    #[test]
    fn pbs_resets_noise() {
        // Chain several PBS; if noise were accumulating the decodes would
        // eventually fail. 8 sequential identity bootstraps must stay exact.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        let m = 5u64;
        let mut ct = enc.encrypt_raw(m, &ck, &mut rng);
        for step in 0..8 {
            ct = sk.pbs(&ct, &lut);
            assert_eq!(enc.decrypt_raw(&ct, &ck), m, "chain step {step}");
        }
    }

    #[test]
    fn pbs_counter_increments() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        let before = pbs_count();
        let ct = enc.encrypt_raw(1, &ck, &mut rng);
        let _ = sk.pbs(&ct, &lut);
        let _ = sk.pbs(&ct, &lut);
        assert_eq!(pbs_count() - before, 2);
    }

    #[test]
    fn server_key_is_send_and_sync() {
        // The Sync audit the batch engine rests on: the bootstrap key
        // (GgswFourier spectra), key-switch key and FFT plan are plain
        // owned data — shared-read safe across scoped worker threads.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ServerKey>();
        assert_send_sync::<PreparedLut>();
        assert_send_sync::<Lut>();
        assert_send_sync::<crate::tfhe::ops::FheContext>();
    }

    #[test]
    fn prepared_lut_is_bit_identical_to_on_the_fly_path() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (3 * m + 2) % space);
        let prepared = sk.prepare_lut(&lut);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let on_the_fly = sk.pbs(&ct, &lut);
            let cached = sk.pbs_prepared(&ct, &prepared);
            assert_eq!(on_the_fly, cached, "ciphertexts must match exactly at m={m}");
        }
    }

    #[test]
    fn parallel_keygen_matches_sequential() {
        // The per-bit child-RNG derivation makes the server key a pure
        // function of the parent RNG state: every thread count must
        // produce byte-identical key material — and a working key.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let params = TfheParams::test_small();
        let mut baseline: Option<ServerKey> = None;
        for threads in [1usize, 2, 5, 16] {
            let mut rng = Xoshiro256::new(0x5EED);
            let ck = ClientKey::generate(params, &mut rng);
            let sk = ck.server_key_with_threads(threads, &mut rng);
            match &baseline {
                None => {
                    // Functional check once: the generated key bootstraps.
                    let enc = Encoder::new(params);
                    let lut = Lut::from_fn(&params, |m| m);
                    let ct = enc.encrypt_raw(3, &ck, &mut rng);
                    assert_eq!(enc.decrypt_raw(&sk.pbs(&ct, &lut), &ck), 3);
                    baseline = Some(sk);
                }
                Some(reference) => {
                    assert!(
                        sk.key_material_eq(reference),
                        "keygen must be thread-count invariant (threads={threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn pbs_batch_matches_sequential_at_any_thread_count() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (m + 1) % space);
        let prepared = sk.prepare_lut(&lut);
        let cts: Vec<LweCiphertext> =
            (0..9u64).map(|i| enc.encrypt_raw(i % space, &ck, &mut rng)).collect();
        let jobs: Vec<(&LweCiphertext, &PreparedLut)> =
            cts.iter().map(|ct| (ct, &prepared)).collect();
        let sequential: Vec<LweCiphertext> =
            cts.iter().map(|ct| sk.pbs_prepared(ct, &prepared)).collect();
        for threads in [1usize, 2, 4, 16] {
            let batched = sk.pbs_batch(&jobs, threads);
            assert_eq!(batched, sequential, "threads={threads}");
        }
        assert!(sk.pbs_batch(&[], 4).is_empty(), "empty batch");
    }
}
