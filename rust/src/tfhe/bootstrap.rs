//! Programmable bootstrapping (S4): the operation the whole paper's cost
//! analysis revolves around.
//!
//! PBS = mod-switch → blind rotation (a chain of `n` CMux over the
//! bootstrap key) → sample extract → key switch. Filling the accumulator
//! ("test vector") with a LUT of `f` over the message space evaluates the
//! univariate function `f` *and* resets noise — Chillotti et al. 2019.
//!
//! Layout: one padding bit + `p` message bits; message `m ∈ [0, 2^p)` is
//! encoded as `m·Δ`, `Δ = 2^(63−p)`. The padding bit keeps the phase in
//! the first half of the torus so the negacyclic wrap never flips the
//! LUT sign. A half-slot pre-rotation centres the rounding window.
//!
//! ## Batched execution engine
//!
//! Two properties make the PBS layer batchable:
//!
//! * A PBS is deterministic server-side (no fresh randomness), so a batch
//!   of independent (ciphertext, LUT) jobs can run in any order — or on
//!   any thread — and produce bit-identical outputs.
//! * [`ServerKey`] is immutable after key generation: the bootstrap key,
//!   key-switch key and FFT plan (twiddles precomputed in
//!   `NegacyclicFft::new`) are plain owned data with no interior
//!   mutability, so `ServerKey: Send + Sync` holds structurally (asserted
//!   by a compile-checked test below) and one key can serve many workers.
//!
//! [`PreparedLut`] hoists the accumulator construction (slot replication
//! + half-slot pre-rotation, previously rebuilt inside every `pbs` call)
//! out of the hot loop; [`ServerKey::pbs_batch`] fans independent jobs
//! across a `std::thread::scope` worker pool with one reusable
//! [`ExtScratch`] per worker. [`ServerKey::pbs_multi`] is the
//! multi-value bootstrap: several LUTs of the *same* input packed into
//! one accumulator ([`PreparedMultiLut`]) and evaluated with a single
//! blind rotation + one sample-extract/key-switch per LUT — the
//! execution target of the plan rewriter's packing pass
//! (`tfhe::plan::PlanRewriter`); [`ServerKey::pbs_batch_mixed`] runs
//! single and multi jobs through one worker pool. `PBS_COUNT` stays
//! exact under concurrency (atomic increment per LUT evaluation;
//! `BLIND_ROTATION_COUNT` per rotation). Key generation reuses the same
//! scoped-pool pattern: the per-bit GGSW encryptions of
//! [`ClientKey::server_key`] are independent and run across workers, with
//! per-bit child RNGs derived sequentially so the key is thread-count
//! invariant.
//!
//! ## Work-stealing, cross-key pool
//!
//! The pool no longer carves the batch into static contiguous chunks
//! (which strangled on skewed batches: a run of expensive multi-value
//! jobs landing on one chunk serialized behind a single worker while the
//! rest idled). Jobs are claimed through a [`StealQueue`]: each worker
//! owns a contiguous range and takes from it with one atomic `fetch_add`
//! per claim; a worker whose range runs dry *steals* from the other
//! ranges' cursors, so the pass ends only when every job is done —
//! regardless of how cost is distributed over the batch. Because a PBS
//! is deterministic, which worker executes a job can never change a
//! ciphertext bit; the counters are atomic, so accounting stays exact.
//!
//! Jobs additionally carry **their own server key** ([`KeyedJob`]):
//! [`pbs_batch_keyed`] / [`pbs_batch_keyed_isolated`] sweep jobs from
//! any number of users' keys in one pool pass (per-worker scratch is
//! cached per key, since keys may differ in geometry). This is the seam
//! the coordinator's cross-session fusion stands on. The per-key
//! entry points ([`ServerKey::pbs_batch_mixed`] and friends) are thin
//! wrappers that tag every job with `self`. [`PoolStats`] reports what a
//! pass did — stolen jobs, distinct keys, and busy/capacity worker time
//! — feeding the `worker_utilization` serving metric.

use super::faults::FaultPlan;
use super::fft::NegacyclicFft;
use super::ggsw::{ExtScratch, GgswCiphertext, GgswFourier};
use super::glwe::{GlweCiphertext, GlweSecretKey};
use super::keyswitch::KeySwitchKey;
use super::lwe::{LweCiphertext, LweSecretKey};
use super::params::TfheParams;
use super::torus::Torus;
use crate::error::{panic_message, FheError};
use crate::util::prng::{Rng64, Xoshiro256};
use std::sync::atomic::{AtomicU64, Ordering};

/// Global PBS counter — the unit the paper counts circuit cost in: one
/// increment per LUT evaluation. Benches read/reset it to report
/// "number of PBS" per circuit.
pub static PBS_COUNT: AtomicU64 = AtomicU64::new(0);

/// Global blind-rotation counter. A standard PBS performs exactly one
/// blind rotation per LUT; a multi-value bootstrap
/// ([`ServerKey::pbs_multi`]) shares one rotation across several LUTs,
/// so this counter is the honest measure of the dominant cost after the
/// plan rewriter packs same-input LUT evaluations.
pub static BLIND_ROTATION_COUNT: AtomicU64 = AtomicU64::new(0);

pub fn pbs_count() -> u64 {
    PBS_COUNT.load(Ordering::Relaxed)
}

pub fn reset_pbs_count() {
    PBS_COUNT.store(0, Ordering::Relaxed);
}

pub fn blind_rotation_count() -> u64 {
    BLIND_ROTATION_COUNT.load(Ordering::Relaxed)
}

pub fn reset_blind_rotation_count() {
    BLIND_ROTATION_COUNT.store(0, Ordering::Relaxed);
}

/// Client-side key material.
pub struct ClientKey {
    pub params: TfheParams,
    pub lwe_key: LweSecretKey,
    pub glwe_key: GlweSecretKey,
}

impl ClientKey {
    pub fn generate(params: TfheParams, rng: &mut Xoshiro256) -> Self {
        params.validate().expect("invalid TFHE parameters");
        ClientKey {
            params,
            lwe_key: LweSecretKey::generate(params.lwe_dim, rng),
            glwe_key: GlweSecretKey::generate(params.poly_size, params.glwe_dim, rng),
        }
    }

    /// Generate the public server key (bootstrap + key-switch keys),
    /// parallelizing keygen across the default worker budget
    /// (`FHE_THREADS` env or all cores — same knob as `pbs_batch`).
    pub fn server_key(&self, rng: &mut Xoshiro256) -> ServerKey {
        self.server_key_with_threads(crate::tfhe::ops::default_fhe_threads(), rng)
    }

    /// Server-key generation with an explicit worker count. The `n`
    /// per-bit GGSW encryptions dominate keygen and are independent, so
    /// they fan out over a scoped-thread pool (the `pbs_batch` pattern).
    ///
    /// Determinism: one child RNG seed per key bit is drawn
    /// *sequentially* from the parent stream before any worker starts, so
    /// the generated key material is a pure function of the parent RNG
    /// state — bit-identical at every thread count (pinned by
    /// `parallel_keygen_matches_sequential`). The key-switch key is
    /// generated on the caller thread from the parent stream afterwards.
    pub fn server_key_with_threads(&self, threads: usize, rng: &mut Xoshiro256) -> ServerKey {
        let fft = NegacyclicFft::new(self.params.poly_size);
        let bits = &self.lwe_key.bits;
        let n = bits.len();
        let seeds: Vec<u64> = bits.iter().map(|_| rng.next_u64()).collect();
        let encrypt_bit = |bit: u64, seed: u64| -> GgswFourier {
            let mut crng = Xoshiro256::new(seed);
            GgswCiphertext::encrypt(
                bit,
                &self.glwe_key,
                self.params.pbs_decomp,
                self.params.glwe_noise_std,
                &mut crng,
            )
            .to_fourier(&fft)
        };
        let threads = threads.clamp(1, n.max(1));
        let bsk: Vec<GgswFourier> = if threads == 1 {
            bits.iter().zip(&seeds).map(|(&bit, &seed)| encrypt_bit(bit, seed)).collect()
        } else {
            let chunk = (n + threads - 1) / threads;
            let mut out: Vec<Option<GgswFourier>> = bits.iter().map(|_| None).collect();
            std::thread::scope(|s| {
                for ((bit_chunk, seed_chunk), out_chunk) in
                    bits.chunks(chunk).zip(seeds.chunks(chunk)).zip(out.chunks_mut(chunk))
                {
                    let encrypt_bit = &encrypt_bit;
                    s.spawn(move || {
                        for ((&bit, &seed), slot) in
                            bit_chunk.iter().zip(seed_chunk).zip(out_chunk.iter_mut())
                        {
                            *slot = Some(encrypt_bit(bit, seed));
                        }
                    });
                }
            });
            out.into_iter().map(|g| g.expect("worker filled every slot")).collect()
        };
        let ksk = KeySwitchKey::generate(
            &self.glwe_key.to_extracted_lwe(),
            &self.lwe_key,
            self.params.ks_decomp,
            self.params.lwe_noise_std,
            rng,
        );
        ServerKey { params: self.params, bsk, ksk, fft }
    }
}

/// Server-side evaluation key.
pub struct ServerKey {
    pub params: TfheParams,
    /// One GGSW (Fourier domain) per LWE secret bit.
    bsk: Vec<GgswFourier>,
    ksk: KeySwitchKey,
    fft: NegacyclicFft,
}

/// A lookup table over the message space: `table[m]` is the *torus value*
/// the PBS returns for message `m` (usually `f(m)·Δ`).
#[derive(Clone, Debug)]
pub struct Lut {
    pub table: Vec<Torus>,
}

impl Lut {
    /// Build from a message-space function `f: [0,2^p) → [0,2^p)` (values
    /// taken mod 2^p and encoded at Δ).
    pub fn from_fn(params: &TfheParams, f: impl Fn(u64) -> u64) -> Self {
        let space = params.message_space();
        let delta = params.delta();
        let table = (0..space)
            .map(|m| (f(m) & (space - 1)).wrapping_mul(delta))
            .collect();
        Lut { table }
    }

    /// Build from a function returning raw torus values (full control).
    pub fn from_torus_fn(params: &TfheParams, f: impl Fn(u64) -> Torus) -> Self {
        let table = (0..params.message_space()).map(f).collect();
        Lut { table }
    }
}

/// A LUT whose blind-rotation accumulator is fully precomputed: the
/// slot-replicated test vector with its half-slot pre-rotation already
/// applied. Building this once per LUT (instead of once per `pbs` call)
/// removes a GLWE allocation, an `N`-coefficient replication fill and a
/// monomial rotation from every bootstrap; since monomial rotations
/// compose exactly (`rotate(a)∘rotate(b) = rotate(a+b)` over coefficient
/// shuffles), the prepared path is bit-identical to the on-the-fly one.
#[derive(Clone, Debug)]
pub struct PreparedLut {
    /// Trivial GLWE holding the pre-rotated test vector.
    acc: GlweCiphertext,
}

/// A packed accumulator for the multi-value bootstrap (PBS-many-LUT in
/// the sense of Chillotti et al. 2021): `n_luts` tables of the same
/// message space interleaved at stride `2^gran_log` inside every message
/// slot, so **one** blind rotation evaluates all of them — coefficient
/// `j` of the rotated accumulator holds `lut_j[m]`, pulled out by one
/// sample extract + key switch per LUT.
///
/// The trade: the mod-switch must round the rotation to a multiple of
/// the stride (otherwise phase noise would smear reads across sub-slots),
/// which costs `gran_log` bits of noise margin. Parameter sets advertise
/// how much of that margin they carry via [`TfheParams::many_lut_log`].
#[derive(Clone, Debug)]
pub struct PreparedMultiLut {
    /// Trivial GLWE holding the packed, pre-rotated test vector.
    acc: GlweCiphertext,
    /// Number of packed LUTs (= outputs per bootstrap).
    n_luts: usize,
    /// log2 of the sub-slot stride = mod-switch rounding granularity.
    gran_log: u32,
}

impl PreparedMultiLut {
    pub fn n_luts(&self) -> usize {
        self.n_luts
    }
}

/// One job of a mixed PBS batch ([`ServerKey::pbs_batch_mixed`]).
#[derive(Clone, Copy)]
pub enum BatchJob<'a> {
    /// Standard bootstrap: one LUT, one output ciphertext.
    Single(&'a LweCiphertext, &'a PreparedLut),
    /// Multi-value bootstrap: one blind rotation, `n_luts` outputs.
    Multi(&'a LweCiphertext, &'a PreparedMultiLut),
}

impl BatchJob<'_> {
    /// Ciphertexts this job contributes to the flattened output vector.
    pub fn n_outputs(&self) -> usize {
        match self {
            BatchJob::Single(..) => 1,
            BatchJob::Multi(_, mlut) => mlut.n_luts,
        }
    }
}

impl ServerKey {
    /// Accumulator polynomial for `lut`: slot `m` replicated over
    /// `N / 2^p` coefficients, with a half-slot pre-rotation so that the
    /// rounding window is centred on each slot.
    fn test_vector(&self, lut: &Lut) -> GlweCiphertext {
        let n = self.params.poly_size;
        let p_space = self.params.message_space() as usize;
        let slot = n / p_space; // coefficients per message slot
        debug_assert!(slot >= 1);
        let mut tv = vec![0u64; n];
        for (m, &val) in lut.table.iter().enumerate() {
            for j in 0..slot {
                tv[m * slot + j] = val;
            }
        }
        // Half-slot pre-rotation: acc ← tv · X^{−half_slot} (rotate left),
        // centring each slot's rounding window. The double sign flip at the
        // 0-boundary (negative noise on m=0 reads −(−tv[...])) makes the
        // wrap exact — same convention as tfhe-rs' generate_lookup_table.
        let acc = GlweCiphertext::trivial(tv, self.params.glwe_dim);
        acc.rotate_monomial((2 * n - slot / 2) as u64)
    }

    /// Precompute the reusable accumulator for `lut`.
    pub fn prepare_lut(&self, lut: &Lut) -> PreparedLut {
        PreparedLut { acc: self.test_vector(lut) }
    }

    /// A fresh scratch buffer sized for this key's CMux chain; reuse one
    /// per worker thread across many PBS.
    pub fn scratch(&self) -> ExtScratch {
        ExtScratch::new(self.params.poly_size, self.params.glwe_dim, self.params.pbs_decomp)
    }

    /// Blind rotation: returns GLWE whose constant coefficient encrypts
    /// `lut[decode(ct)]` (for `gran_log = 0`). With `gran_log = ϑ > 0`
    /// the mod-switch rounds every coefficient to a multiple of `2^ϑ`,
    /// so the total rotation is too — the alignment the packed
    /// multi-value accumulator needs. At ϑ = 0 the arithmetic reduces
    /// exactly to the standard mod-switch, so the single-LUT path is
    /// bit-identical to what it was before the refactor.
    fn blind_rotate(
        &self,
        ct: &LweCiphertext,
        acc_init: &GlweCiphertext,
        gran_log: u32,
        scratch: &mut ExtScratch,
    ) -> GlweCiphertext {
        BLIND_ROTATION_COUNT.fetch_add(1, Ordering::Relaxed);
        let n2 = (2 * self.params.poly_size) as u64;
        // Mod-switch mask and body to Z_{2N} (coarsened to multiples of
        // 2^gran_log: round at the reduced modulus, scale back up).
        let switch =
            |t: Torus| -> u64 { super::torus::round_to_modulus(t, n2 >> gran_log) << gran_log };
        let b_t = switch(ct.body);
        let mut acc = acc_init.rotate_monomial(n2 - b_t);
        for (a, ggsw) in ct.mask.iter().zip(self.bsk.iter()) {
            let a_t = switch(*a);
            if a_t == 0 {
                continue;
            }
            ggsw.cmux_rotate_assign(&self.fft, &mut acc, a_t, scratch);
        }
        acc
    }

    /// Full programmable bootstrap: evaluate `lut` on the encrypted
    /// message and return a fresh-noise ciphertext under the small key.
    /// Convenience path — builds the accumulator per call; hot paths use
    /// [`Self::prepare_lut`] + [`Self::pbs_prepared`] / [`Self::pbs_batch`].
    pub fn pbs(&self, ct: &LweCiphertext, lut: &Lut) -> LweCiphertext {
        self.pbs_prepared(ct, &self.prepare_lut(lut))
    }

    /// PBS against a precomputed accumulator (allocates its own scratch).
    pub fn pbs_prepared(&self, ct: &LweCiphertext, lut: &PreparedLut) -> LweCiphertext {
        let mut scratch = self.scratch();
        self.pbs_prepared_with_scratch(ct, lut, &mut scratch)
    }

    /// PBS against a precomputed accumulator with a caller-owned scratch
    /// buffer — the zero-per-call-allocation hot path of the batch engine.
    pub fn pbs_prepared_with_scratch(
        &self,
        ct: &LweCiphertext,
        lut: &PreparedLut,
        scratch: &mut ExtScratch,
    ) -> LweCiphertext {
        PBS_COUNT.fetch_add(1, Ordering::Relaxed);
        let acc = self.blind_rotate(ct, &lut.acc, 0, scratch);
        let extracted = acc.sample_extract(0);
        self.ksk.keyswitch(&extracted)
    }

    /// Pack several LUTs over this key's message space into one
    /// multi-value accumulator. Within each message slot the tables are
    /// interleaved at a power-of-two stride `B ≥ n_luts`: sub-position
    /// `r·B + j` holds `lut_j[m]`, replicated over every block `r`, so
    /// any stride-aligned rotation inside the slot reads all tables
    /// consistently. Requires `2·B ≤ N/2^p` (checked), i.e. the
    /// polynomial must carry the headroom [`TfheParams::many_lut_log`]
    /// advertises.
    pub fn prepare_multi_lut(&self, luts: &[&Lut]) -> PreparedMultiLut {
        assert!(!luts.is_empty(), "multi-LUT accumulator needs at least one table");
        // The noise budget, not just the geometry: a coarser mod-switch
        // than `many_lut_log` provisions would decode wrongly without
        // ever panicking, so reject it here on the public API.
        assert!(
            luts.len() <= self.params.max_multi_lut(),
            "packing {} LUTs exceeds this parameter set's multi-value budget {} \
             (TfheParams::many_lut_log = {})",
            luts.len(),
            self.params.max_multi_lut(),
            self.params.many_lut_log
        );
        let n = self.params.poly_size;
        let p_space = self.params.message_space() as usize;
        let slot = n / p_space;
        let stride = luts.len().next_power_of_two();
        assert!(
            2 * stride <= slot,
            "cannot pack {} LUTs: stride {stride} needs slot ≥ {} but N/2^p = {slot}",
            luts.len(),
            2 * stride
        );
        for lut in luts {
            assert_eq!(lut.table.len(), p_space, "LUT table must cover the message space");
        }
        let mut tv = vec![0u64; n];
        for m in 0..p_space {
            for r in 0..slot / stride {
                for j in 0..stride {
                    // Unused pad positions repeat the last table.
                    let val = luts[j.min(luts.len() - 1)].table[m];
                    tv[m * slot + r * stride + j] = val;
                }
            }
        }
        // Same half-slot pre-rotation as the single-LUT accumulator; the
        // stride divides slot/2, so block alignment survives it (and the
        // negacyclic wrap at the 0-boundary, which shifts by whole slots).
        let acc = GlweCiphertext::trivial(tv, self.params.glwe_dim);
        PreparedMultiLut {
            acc: acc.rotate_monomial((2 * n - slot / 2) as u64),
            n_luts: luts.len(),
            gran_log: stride.trailing_zeros(),
        }
    }

    /// Multi-value bootstrap: evaluate every LUT packed into `mlut` on
    /// the encrypted message with **one** blind rotation, returning one
    /// fresh ciphertext per LUT (in packing order). Costs `n_luts` on
    /// `PBS_COUNT` (LUT evaluations) but only 1 on
    /// `BLIND_ROTATION_COUNT`; each output decodes to the same message
    /// the corresponding single-LUT PBS would produce, provided the
    /// parameter set carries the advertised mod-switch margin.
    pub fn pbs_multi(&self, ct: &LweCiphertext, mlut: &PreparedMultiLut) -> Vec<LweCiphertext> {
        let mut scratch = self.scratch();
        self.pbs_multi_with_scratch(ct, mlut, &mut scratch)
    }

    /// [`Self::pbs_multi`] with a caller-owned scratch buffer (the batch
    /// engine's zero-per-call-allocation hot path).
    pub fn pbs_multi_with_scratch(
        &self,
        ct: &LweCiphertext,
        mlut: &PreparedMultiLut,
        scratch: &mut ExtScratch,
    ) -> Vec<LweCiphertext> {
        PBS_COUNT.fetch_add(mlut.n_luts as u64, Ordering::Relaxed);
        let acc = self.blind_rotate(ct, &mlut.acc, mlut.gran_log, scratch);
        (0..mlut.n_luts)
            .map(|j| self.ksk.keyswitch(&acc.sample_extract(j)))
            .collect()
    }

    /// Execute a batch of independent single-LUT PBS jobs across
    /// `threads` workers (the common case; a thin wrapper over
    /// [`Self::pbs_batch_mixed`] with one output per job).
    pub fn pbs_batch(
        &self,
        jobs: &[(&LweCiphertext, &PreparedLut)],
        threads: usize,
    ) -> Vec<LweCiphertext> {
        let mixed: Vec<BatchJob> =
            jobs.iter().map(|&(ct, lut)| BatchJob::Single(ct, lut)).collect();
        self.pbs_batch_mixed(&mixed, threads)
    }

    /// Execute a batch of independent PBS jobs — single-LUT bootstraps
    /// and multi-value bootstraps mixed freely — across `threads`
    /// workers.
    ///
    /// A thin single-key wrapper over the work-stealing pool
    /// ([`pbs_batch_keyed`]): every job is tagged with `self` and jobs
    /// are claimed dynamically, so batches mixing cheap single-LUT and
    /// expensive multi-value jobs no longer straggle on whichever static
    /// chunk the expensive run landed in. Outputs are flattened in job
    /// order (a multi job contributes [`BatchJob::n_outputs`]
    /// consecutive ciphertexts in packing order), and every output is
    /// bit-identical to what sequential execution produces at any thread
    /// count (both bootstrap flavors are deterministic). `PBS_COUNT`
    /// advances by the total LUT evaluations, `BLIND_ROTATION_COUNT` by
    /// exactly `jobs.len()`.
    pub fn pbs_batch_mixed(&self, jobs: &[BatchJob], threads: usize) -> Vec<LweCiphertext> {
        let keyed: Vec<KeyedJob> = jobs.iter().map(|&job| KeyedJob { key: self, job }).collect();
        pbs_batch_keyed(&keyed, threads).0
    }

    /// [`Self::pbs_batch_mixed`] with **per-job panic isolation**: each
    /// job runs inside `catch_unwind`, so a poisoned job (a bug, or an
    /// injected `panic@pbs:N` fault) yields `Err(WorkerPanic)` for that
    /// job alone while every other job completes normally, bit-identical
    /// to a fault-free run. Returns one `Result` per job, each `Ok`
    /// carrying the job's [`BatchJob::n_outputs`] ciphertexts in packing
    /// order.
    ///
    /// `faults` arms deterministic injection: a span of global 1-based
    /// job indices is reserved in one `fetch_add` per call and each job's
    /// fault index is `span base + submission index + 1`, so which job
    /// panics depends only on submission order — never on thread count,
    /// work stealing, or worker interleaving.
    pub fn pbs_batch_mixed_isolated(
        &self,
        jobs: &[BatchJob],
        threads: usize,
        faults: Option<&FaultPlan>,
    ) -> Vec<Result<Vec<LweCiphertext>, FheError>> {
        let keyed: Vec<KeyedJob> = jobs.iter().map(|&job| KeyedJob { key: self, job }).collect();
        pbs_batch_keyed_isolated(&keyed, threads, faults).0
    }

    /// Execute one mixed-batch job into its output span (len =
    /// `job.n_outputs()`).
    fn run_batch_job(
        &self,
        job: &BatchJob,
        scratch: &mut ExtScratch,
        out: &mut [Option<LweCiphertext>],
    ) {
        match *job {
            BatchJob::Single(ct, lut) => {
                out[0] = Some(self.pbs_prepared_with_scratch(ct, lut, scratch));
            }
            BatchJob::Multi(ct, mlut) => {
                for (slot, res) in
                    out.iter_mut().zip(self.pbs_multi_with_scratch(ct, mlut, scratch))
                {
                    *slot = Some(res);
                }
            }
        }
    }

    /// Number of CMux levels (= LWE dim); used by cost reporting.
    pub fn lwe_dim(&self) -> usize {
        self.bsk.len()
    }

    /// Structural equality of the key material (bootstrap-key spectra and
    /// key-switch rows). Used to pin the parallel keygen against the
    /// single-threaded derivation — not a constant-time comparison.
    pub fn key_material_eq(&self, other: &ServerKey) -> bool {
        self.params == other.params && self.bsk == other.bsk && self.ksk == other.ksk
    }

    /// Bootstrap-key material — read access for the storage codec
    /// (`tfhe::codec`), which serializes keys for the cold-session tier.
    pub(crate) fn bsk(&self) -> &[GgswFourier] {
        &self.bsk
    }

    /// Key-switch key — read access for the storage codec.
    pub(crate) fn ksk(&self) -> &KeySwitchKey {
        &self.ksk
    }

    /// Rebuild a server key from decoded material. The FFT plan carries
    /// no secrets and its twiddles are a pure function of the polynomial
    /// size, so it is reconstructed here instead of being serialized.
    pub(crate) fn from_material(
        params: TfheParams,
        bsk: Vec<GgswFourier>,
        ksk: KeySwitchKey,
    ) -> Self {
        let fft = NegacyclicFft::new(params.poly_size);
        ServerKey { params, bsk, ksk, fft }
    }
}

/// One job of a cross-key pool pass: a [`BatchJob`] plus the server key
/// it must execute under. Carrying the key per job is what lets a single
/// worker-pool sweep serve several users at once — the fused executor
/// tags each member's jobs with that member's own key and submits them
/// all to one [`pbs_batch_keyed_isolated`] call.
#[derive(Clone, Copy)]
pub struct KeyedJob<'a> {
    pub key: &'a ServerKey,
    pub job: BatchJob<'a>,
}

/// What one work-stealing pool pass did — the saturation observability
/// behind the coordinator's `worker_utilization` / `stolen_jobs` /
/// `fused_keys` serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Jobs executed by a worker other than the one whose range they
    /// were assigned to (idle workers pulling from busy workers' ranges).
    pub stolen_jobs: u64,
    /// Distinct server keys the pass swept jobs from.
    pub keys: usize,
    /// Worker-nanoseconds actually spent inside worker loops (summed
    /// over workers).
    pub busy_ns: u64,
    /// Worker-nanoseconds available: `threads × wall time` of the pass.
    pub capacity_ns: u64,
}

impl PoolStats {
    /// Fraction of the pool's worker-time spent executing jobs; 0 when
    /// nothing ran. Bounded by 1 (each worker's loop time is at most the
    /// pass's wall time).
    pub fn utilization(&self) -> f64 {
        if self.capacity_ns == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / self.capacity_ns as f64
    }

    /// Accumulate another pass into this one (`keys` keeps the maximum
    /// seen in any single pass — "how many keys did one sweep fuse").
    pub fn absorb(&mut self, other: &PoolStats) {
        self.stolen_jobs += other.stolen_jobs;
        self.keys = self.keys.max(other.keys);
        self.busy_ns += other.busy_ns;
        self.capacity_ns += other.capacity_ns;
    }
}

/// Claim coordinator of the work-stealing pool. Jobs `0..n` are split
/// into per-worker contiguous ranges; a worker claims from its own
/// range's cursor with one `fetch_add` per claim and, once its range
/// runs dry, *steals* from the other ranges' cursors. `fetch_add` hands
/// out strictly increasing positions and a claim only counts while it
/// lands inside the range, so every index is claimed exactly once no
/// matter how workers interleave.
struct StealQueue {
    /// Per worker: (next position to claim, exclusive range end).
    ranges: Vec<(std::sync::atomic::AtomicUsize, usize)>,
}

impl StealQueue {
    fn new(n_jobs: usize, workers: usize) -> StealQueue {
        let chunk = (n_jobs + workers - 1) / workers.max(1);
        let ranges = (0..workers.max(1))
            .map(|w| {
                let start = (w * chunk).min(n_jobs);
                let end = ((w + 1) * chunk).min(n_jobs);
                (std::sync::atomic::AtomicUsize::new(start), end)
            })
            .collect();
        StealQueue { ranges }
    }

    /// Claim the next job for `worker`: its own range first, then a
    /// sweep over the other workers' ranges. Returns the job index and
    /// whether it was stolen; `None` once every range is drained.
    fn claim(&self, worker: usize) -> Option<(usize, bool)> {
        let n = self.ranges.len();
        for k in 0..n {
            let w = (worker + k) % n;
            let (cursor, end) = &self.ranges[w];
            if cursor.load(Ordering::Relaxed) >= *end {
                continue;
            }
            let idx = cursor.fetch_add(1, Ordering::Relaxed);
            if idx < *end {
                return Some((idx, k != 0));
            }
        }
        None
    }
}

/// Per-worker scratch buffers keyed by server-key identity. A cross-key
/// pass may hop a worker between keys with different geometry, so each
/// worker keeps one [`ExtScratch`] per key it has executed for (the key
/// count per pass is tiny — one per co-scheduled session). Keys are
/// identified by address, which is stable for the duration of the pass
/// because every key is borrowed by the job list.
#[derive(Default)]
struct ScratchCache {
    entries: Vec<(usize, ExtScratch)>,
}

impl ScratchCache {
    fn for_key(&mut self, key: &ServerKey) -> &mut ExtScratch {
        let id = key as *const ServerKey as usize;
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == id) {
            return &mut self.entries[pos].1;
        }
        self.entries.push((id, key.scratch()));
        &mut self.entries.last_mut().expect("entry just pushed").1
    }

    /// Drop every buffer — called after a caught panic, which may have
    /// left a buffer mid-update.
    fn clear(&mut self) {
        self.entries.clear();
    }
}

/// Work-stealing pool skeleton shared by the plain and panic-isolated
/// entry points: claim jobs through a [`StealQueue`], run `run_one` on
/// each, collect `(job index, result)` pairs per worker and scatter them
/// after the scope joins (no locks, no shared output slices). A panic
/// escaping `run_one` propagates out of the pool (the isolated entry
/// point catches per job before it gets here).
fn run_keyed_pool<R, F>(jobs: &[KeyedJob], threads: usize, run_one: F) -> (Vec<Option<R>>, PoolStats)
where
    R: Send,
    F: Fn(usize, &KeyedJob, &mut ScratchCache) -> R + Sync,
{
    let n = jobs.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut stats = PoolStats::default();
    let mut key_ids: Vec<usize> =
        jobs.iter().map(|j| j.key as *const ServerKey as usize).collect();
    key_ids.sort_unstable();
    key_ids.dedup();
    stats.keys = key_ids.len();
    if n == 0 {
        return (slots, stats);
    }
    let threads = threads.max(1).min(n);
    let wall = std::time::Instant::now();
    if threads == 1 {
        let mut cache = ScratchCache::default();
        for (i, job) in jobs.iter().enumerate() {
            slots[i] = Some(run_one(i, job, &mut cache));
        }
        stats.busy_ns = wall.elapsed().as_nanos() as u64;
        stats.capacity_ns = stats.busy_ns;
        return (slots, stats);
    }
    let queue = StealQueue::new(n, threads);
    let stolen = AtomicU64::new(0);
    let busy = AtomicU64::new(0);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let (queue, run_one, stolen, busy) = (&queue, &run_one, &stolen, &busy);
                s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    let mut cache = ScratchCache::default();
                    let mut local: Vec<(usize, R)> = Vec::new();
                    while let Some((idx, was_stolen)) = queue.claim(w) {
                        if was_stolen {
                            stolen.fetch_add(1, Ordering::Relaxed);
                        }
                        local.push((idx, run_one(idx, &jobs[idx], &mut cache)));
                    }
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    local
                })
            })
            .collect();
        for h in handles {
            for (idx, r) in h.join().expect("pool worker panicked") {
                slots[idx] = Some(r);
            }
        }
    });
    stats.capacity_ns = wall.elapsed().as_nanos() as u64 * threads as u64;
    stats.stolen_jobs = stolen.load(Ordering::Relaxed);
    stats.busy_ns = busy.load(Ordering::Relaxed);
    (slots, stats)
}

/// Execute independent PBS jobs spanning **any number of server keys**
/// through the work-stealing pool. Outputs are flattened in job order (a
/// multi job contributes its `n_outputs` ciphertexts consecutively) and
/// are bit-identical to per-key sequential execution at any thread count
/// — both bootstrap flavors are deterministic, so claim order cannot
/// change a ciphertext bit.
pub fn pbs_batch_keyed(jobs: &[KeyedJob], threads: usize) -> (Vec<LweCiphertext>, PoolStats) {
    let (slots, stats) = run_keyed_pool(jobs, threads, |_, kj, cache| {
        let n = kj.job.n_outputs();
        let mut out: Vec<Option<LweCiphertext>> = (0..n).map(|_| None).collect();
        kj.key.run_batch_job(&kj.job, cache.for_key(kj.key), &mut out);
        out.into_iter().map(|c| c.expect("job filled every slot")).collect::<Vec<LweCiphertext>>()
    });
    let flat = slots.into_iter().flat_map(|r| r.expect("worker visited every job")).collect();
    (flat, stats)
}

/// [`pbs_batch_keyed`] with **per-job panic isolation**: each job runs
/// inside `catch_unwind`, so a poisoned job (a bug, or an injected
/// `panic@pbs:N` fault) yields `Err(WorkerPanic)` for that job alone
/// while every other job — including jobs under *other* keys sharing the
/// pass — completes bit-identical to a fault-free run. A caught panic
/// discards the worker's scratch buffers (they may have been left
/// mid-update); fresh ones are built on the next claim.
///
/// `faults` arms deterministic injection: a span of global 1-based job
/// indices is reserved in one `fetch_add` per call and each job's fault
/// index is `span base + submission index + 1`, independent of which
/// worker executes (or steals) the job.
pub fn pbs_batch_keyed_isolated(
    jobs: &[KeyedJob],
    threads: usize,
    faults: Option<&FaultPlan>,
) -> (Vec<Result<Vec<LweCiphertext>, FheError>>, PoolStats) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    if jobs.is_empty() {
        return (Vec::new(), PoolStats::default());
    }
    let base = faults.map_or(0, |f| f.next_pbs_base(jobs.len() as u64));
    let (slots, stats) = run_keyed_pool(jobs, threads, |i, kj, cache| {
        let idx = base + i as u64 + 1;
        let res = catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = faults {
                f.maybe_panic_pbs(idx);
            }
            let n = kj.job.n_outputs();
            let mut out: Vec<Option<LweCiphertext>> = (0..n).map(|_| None).collect();
            kj.key.run_batch_job(&kj.job, cache.for_key(kj.key), &mut out);
            out.into_iter()
                .map(|c| c.expect("job filled every slot"))
                .collect::<Vec<LweCiphertext>>()
        }));
        match res {
            Ok(cts) => Ok(cts),
            Err(p) => {
                cache.clear();
                Err(FheError::WorkerPanic(panic_message(p)))
            }
        }
    });
    (slots.into_iter().map(|r| r.expect("worker visited every job")).collect(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::encoding::Encoder;

    fn setup() -> (ClientKey, ServerKey, Xoshiro256) {
        let mut rng = Xoshiro256::new(2024);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let sk = ck.server_key(&mut rng);
        (ck, sk, rng)
    }

    #[test]
    fn pbs_identity_over_full_message_space() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        for m in 0..ck.params.message_space() {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let out = sk.pbs(&ct, &lut);
            let got = enc.decrypt_raw(&out, &ck);
            assert_eq!(got, m, "identity LUT at m={m}");
        }
    }

    #[test]
    fn pbs_evaluates_nontrivial_function() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (m * m + 1) % space);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let got = enc.decrypt_raw(&sk.pbs(&ct, &lut), &ck);
            assert_eq!(got, (m * m + 1) % space, "square LUT at m={m}");
        }
    }

    #[test]
    fn pbs_resets_noise() {
        // Chain several PBS; if noise were accumulating the decodes would
        // eventually fail. 8 sequential identity bootstraps must stay exact.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        let m = 5u64;
        let mut ct = enc.encrypt_raw(m, &ck, &mut rng);
        for step in 0..8 {
            ct = sk.pbs(&ct, &lut);
            assert_eq!(enc.decrypt_raw(&ct, &ck), m, "chain step {step}");
        }
    }

    #[test]
    fn pbs_counter_increments() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let lut = Lut::from_fn(&ck.params, |m| m);
        let before = pbs_count();
        let ct = enc.encrypt_raw(1, &ck, &mut rng);
        let _ = sk.pbs(&ct, &lut);
        let _ = sk.pbs(&ct, &lut);
        assert_eq!(pbs_count() - before, 2);
    }

    #[test]
    fn server_key_is_send_and_sync() {
        // The Sync audit the batch engine rests on: the bootstrap key
        // (GgswFourier spectra), key-switch key and FFT plan are plain
        // owned data — shared-read safe across scoped worker threads.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ServerKey>();
        assert_send_sync::<PreparedLut>();
        assert_send_sync::<PreparedMultiLut>();
        assert_send_sync::<Lut>();
        assert_send_sync::<crate::tfhe::ops::FheContext>();
    }

    #[test]
    fn isolated_batch_contains_injected_panic_to_one_job() {
        // The panic-isolation seam: job 3 of 6 is scheduled to panic;
        // every other job's output must be bit-identical to the plain
        // batch path, at one thread and at several.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (m + 1) % space);
        let prepared = sk.prepare_lut(&lut);
        let cts: Vec<_> = (0..6).map(|m| enc.encrypt_raw(m % space, &ck, &mut rng)).collect();
        let jobs: Vec<BatchJob> = cts.iter().map(|ct| BatchJob::Single(ct, &prepared)).collect();
        let clean = sk.pbs_batch_mixed(&jobs, 2);
        for threads in [1usize, 3] {
            let faults = FaultPlan::parse("panic@pbs:3").unwrap();
            let got = sk.pbs_batch_mixed_isolated(&jobs, threads, Some(&faults));
            assert_eq!(got.len(), 6);
            for (i, res) in got.iter().enumerate() {
                if i == 2 {
                    match res {
                        Err(FheError::WorkerPanic(m)) => {
                            assert!(m.contains("panic@pbs:3"), "{m}")
                        }
                        other => panic!("job 3 must fail with WorkerPanic, got {other:?}"),
                    }
                } else {
                    let cts = res.as_ref().expect("survivor job");
                    assert_eq!(cts.as_slice(), &clean[i..i + 1], "job {i} at T={threads}");
                }
            }
        }
    }

    #[test]
    fn isolated_batch_without_faults_matches_plain_batch() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (2 * m) % space);
        let prepared = sk.prepare_lut(&lut);
        let cts: Vec<_> = (0..5).map(|m| enc.encrypt_raw(m % space, &ck, &mut rng)).collect();
        let jobs: Vec<BatchJob> = cts.iter().map(|ct| BatchJob::Single(ct, &prepared)).collect();
        let plain = sk.pbs_batch_mixed(&jobs, 2);
        let isolated = sk.pbs_batch_mixed_isolated(&jobs, 2, None);
        let flat: Vec<_> =
            isolated.into_iter().flat_map(|r| r.expect("no faults armed")).collect();
        assert_eq!(flat, plain);
    }

    #[test]
    fn prepared_lut_is_bit_identical_to_on_the_fly_path() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (3 * m + 2) % space);
        let prepared = sk.prepare_lut(&lut);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let on_the_fly = sk.pbs(&ct, &lut);
            let cached = sk.pbs_prepared(&ct, &prepared);
            assert_eq!(on_the_fly, cached, "ciphertexts must match exactly at m={m}");
        }
    }

    #[test]
    fn parallel_keygen_matches_sequential() {
        // The per-bit child-RNG derivation makes the server key a pure
        // function of the parent RNG state: every thread count must
        // produce byte-identical key material — and a working key.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let params = TfheParams::test_small();
        let mut baseline: Option<ServerKey> = None;
        for threads in [1usize, 2, 5, 16] {
            let mut rng = Xoshiro256::new(0x5EED);
            let ck = ClientKey::generate(params, &mut rng);
            let sk = ck.server_key_with_threads(threads, &mut rng);
            match &baseline {
                None => {
                    // Functional check once: the generated key bootstraps.
                    let enc = Encoder::new(params);
                    let lut = Lut::from_fn(&params, |m| m);
                    let ct = enc.encrypt_raw(3, &ck, &mut rng);
                    assert_eq!(enc.decrypt_raw(&sk.pbs(&ct, &lut), &ck), 3);
                    baseline = Some(sk);
                }
                Some(reference) => {
                    assert!(
                        sk.key_material_eq(reference),
                        "keygen must be thread-count invariant (threads={threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn pbs_multi_decodes_every_packed_lut() {
        // Params with one bit of packing headroom: the coarse mod-switch
        // at stride 2 keeps the same σ-margin the base set has at full
        // resolution, so the packed reads decode exactly.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0x317A);
        let params = TfheParams::test_multi_lut(3);
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let space = params.message_space();
        let lut_a = Lut::from_fn(&params, |m| (m + 1) % space);
        let lut_b = Lut::from_fn(&params, |m| (m * m) % space);
        let mlut = sk.prepare_multi_lut(&[&lut_a, &lut_b]);
        assert_eq!(mlut.n_luts(), 2);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let before_pbs = pbs_count();
            let before_rot = blind_rotation_count();
            let outs = sk.pbs_multi(&ct, &mlut);
            assert_eq!(pbs_count() - before_pbs, 2, "two LUT evaluations at m={m}");
            assert_eq!(blind_rotation_count() - before_rot, 1, "one rotation at m={m}");
            assert_eq!(outs.len(), 2);
            // Each output decodes to what the corresponding single-LUT
            // PBS decodes to.
            assert_eq!(enc.decrypt_raw(&outs[0], &ck), (m + 1) % space, "lut_a at m={m}");
            assert_eq!(enc.decrypt_raw(&outs[1], &ck), (m * m) % space, "lut_b at m={m}");
            assert_eq!(
                enc.decrypt_raw(&sk.pbs(&ct, &lut_a), &ck),
                enc.decrypt_raw(&outs[0], &ck),
                "multi output 0 agrees with the single path at m={m}"
            );
        }
    }

    #[test]
    fn pbs_multi_decodes_three_lut_packs_at_theta2() {
        // ϑ = 2 set: stride-4 packing (three tables rounded up to four
        // sub-slots). The polynomial is scaled by 2^ϑ, so the coarser
        // mod-switch keeps the ϑ = 1 σ-margin — packed reads of a
        // requant + relu + min0-shaped trio must decode exactly.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0x317D);
        let params = TfheParams::test_multi_lut_theta(3, 2);
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let space = params.message_space();
        let lut_a = Lut::from_fn(&params, |m| (m + 1) % space);
        let lut_b = Lut::from_fn(&params, |m| (m * m) % space);
        let lut_c = Lut::from_fn(&params, |m| (space - 1) - m);
        let mlut = sk.prepare_multi_lut(&[&lut_a, &lut_b, &lut_c]);
        assert_eq!(mlut.n_luts(), 3);
        for m in 0..space {
            let ct = enc.encrypt_raw(m, &ck, &mut rng);
            let before_pbs = pbs_count();
            let before_rot = blind_rotation_count();
            let outs = sk.pbs_multi(&ct, &mlut);
            assert_eq!(pbs_count() - before_pbs, 3, "three LUT evaluations at m={m}");
            assert_eq!(blind_rotation_count() - before_rot, 1, "one rotation at m={m}");
            assert_eq!(enc.decrypt_raw(&outs[0], &ck), (m + 1) % space, "lut_a at m={m}");
            assert_eq!(enc.decrypt_raw(&outs[1], &ck), (m * m) % space, "lut_b at m={m}");
            assert_eq!(enc.decrypt_raw(&outs[2], &ck), (space - 1) - m, "lut_c at m={m}");
        }
    }

    #[test]
    fn prepare_multi_lut_rejects_packs_beyond_the_budget() {
        let mut rng = Xoshiro256::new(0x317B);
        // test_multi_lut(3) advertises ϑ = 1: pairs pack, triples must be
        // rejected outright — a coarser mod-switch than provisioned would
        // decode wrongly without ever panicking.
        let params = TfheParams::test_multi_lut(3);
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let lut = Lut::from_fn(&params, |m| m);
        let ok = sk.prepare_multi_lut(&[&lut, &lut]);
        assert_eq!(ok.n_luts(), 2, "a pair fits the ϑ = 1 budget");
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sk.prepare_multi_lut(&[&lut, &lut, &lut])
        }));
        assert!(res.is_err(), "packing beyond 2^many_lut_log must be rejected");
    }

    #[test]
    fn mixed_batch_matches_sequential_at_any_thread_count() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0x317C);
        let params = TfheParams::test_multi_lut(3);
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let space = params.message_space();
        let single = sk.prepare_lut(&Lut::from_fn(&params, |m| (m + 3) % space));
        let lut_a = Lut::from_fn(&params, |m| (m + 1) % space);
        let lut_b = Lut::from_fn(&params, |m| (2 * m) % space);
        let mlut = sk.prepare_multi_lut(&[&lut_a, &lut_b]);
        let cts: Vec<LweCiphertext> =
            (0..7u64).map(|i| enc.encrypt_raw(i % space, &ck, &mut rng)).collect();
        // Alternate single and multi jobs so chunk boundaries land on both.
        let jobs: Vec<BatchJob> = cts
            .iter()
            .enumerate()
            .map(|(i, ct)| {
                if i % 2 == 0 {
                    BatchJob::Single(ct, &single)
                } else {
                    BatchJob::Multi(ct, &mlut)
                }
            })
            .collect();
        let expect_outputs: usize = jobs.iter().map(|j| j.n_outputs()).sum();
        let before = pbs_count();
        let reference = sk.pbs_batch_mixed(&jobs, 1);
        assert_eq!(reference.len(), expect_outputs);
        assert_eq!(pbs_count() - before, expect_outputs as u64);
        for threads in [2usize, 3, 16] {
            let batched = sk.pbs_batch_mixed(&jobs, threads);
            assert_eq!(batched, reference, "threads={threads}");
        }
        assert!(sk.pbs_batch_mixed(&[], 4).is_empty(), "empty mixed batch");
    }

    #[test]
    fn pbs_batch_matches_sequential_at_any_thread_count() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, sk, mut rng) = setup();
        let enc = Encoder::new(ck.params);
        let space = ck.params.message_space();
        let lut = Lut::from_fn(&ck.params, |m| (m + 1) % space);
        let prepared = sk.prepare_lut(&lut);
        let cts: Vec<LweCiphertext> =
            (0..9u64).map(|i| enc.encrypt_raw(i % space, &ck, &mut rng)).collect();
        let jobs: Vec<(&LweCiphertext, &PreparedLut)> =
            cts.iter().map(|ct| (ct, &prepared)).collect();
        let sequential: Vec<LweCiphertext> =
            cts.iter().map(|ct| sk.pbs_prepared(ct, &prepared)).collect();
        for threads in [1usize, 2, 4, 16] {
            let batched = sk.pbs_batch(&jobs, threads);
            assert_eq!(batched, sequential, "threads={threads}");
        }
        assert!(sk.pbs_batch(&[], 4).is_empty(), "empty batch");
    }

    #[test]
    fn steal_queue_hands_out_each_index_once_and_marks_steals() {
        // Deterministic single-threaded walk of the claim mechanics:
        // worker 1 drains its own range [4, 8), then steals from the
        // front of worker 0's range; worker 0 resumes behind the thefts.
        let q = StealQueue::new(8, 2);
        for want in 4..8 {
            assert_eq!(q.claim(1), Some((want, false)));
        }
        assert_eq!(q.claim(1), Some((0, true)), "own range dry: steal from worker 0");
        assert_eq!(q.claim(1), Some((1, true)));
        assert_eq!(q.claim(0), Some((2, false)), "owner resumes behind the thefts");
        assert_eq!(q.claim(0), Some((3, false)));
        assert_eq!(q.claim(0), None, "all ranges drained");
        assert_eq!(q.claim(1), None);
        // Uneven split: 3 jobs over 2 workers → ranges [0, 2) and [2, 3).
        let q = StealQueue::new(3, 2);
        assert_eq!(q.claim(1), Some((2, false)));
        assert_eq!(q.claim(1), Some((0, true)));
        assert_eq!(q.claim(1), Some((1, true)));
        assert_eq!(q.claim(1), None);
        // More workers than jobs leaves the surplus ranges empty.
        let q = StealQueue::new(0, 3);
        assert_eq!(q.claim(0), None);
        assert_eq!(q.claim(2), None);
    }

    #[test]
    fn steal_queue_claims_exactly_once_under_contention() {
        // 8 workers hammering 1000 indices: the union of claims must be
        // exactly 0..1000, each index once, however the threads race.
        let n = 1000usize;
        let workers = 8usize;
        let q = StealQueue::new(n, workers);
        let mut all: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let q = &q;
                    s.spawn(move || {
                        let mut mine = Vec::new();
                        while let Some((idx, _)) = q.claim(w) {
                            mine.push(idx);
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("claimer")).collect()
        });
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "every index claimed exactly once");
    }

    #[test]
    fn skewed_multi_front_loaded_batch_is_thread_count_invariant() {
        // Regression for the static-chunk straggler: every expensive
        // multi-value job packed at the front of the batch — the layout
        // that used to land all of them on one worker's contiguous chunk
        // while the cheap tail idled the rest. The work-stealing pool
        // must return bit-identical flattened outputs at every thread
        // count, and its pass accounting must stay coherent.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0x57EA);
        let params = TfheParams::test_multi_lut(3);
        let ck = ClientKey::generate(params, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(params);
        let space = params.message_space();
        let single = sk.prepare_lut(&Lut::from_fn(&params, |m| (m + 3) % space));
        let lut_a = Lut::from_fn(&params, |m| (m + 1) % space);
        let lut_b = Lut::from_fn(&params, |m| (2 * m) % space);
        let mlut = sk.prepare_multi_lut(&[&lut_a, &lut_b]);
        let cts: Vec<LweCiphertext> =
            (0..8u64).map(|i| enc.encrypt_raw(i % space, &ck, &mut rng)).collect();
        let jobs: Vec<BatchJob> = cts
            .iter()
            .enumerate()
            .map(|(i, ct)| {
                if i < 4 {
                    BatchJob::Multi(ct, &mlut)
                } else {
                    BatchJob::Single(ct, &single)
                }
            })
            .collect();
        let before_rot = blind_rotation_count();
        let reference = sk.pbs_batch_mixed(&jobs, 1);
        assert_eq!(blind_rotation_count() - before_rot, jobs.len() as u64);
        for threads in [2usize, 3, 4, 8] {
            let batched = sk.pbs_batch_mixed(&jobs, threads);
            assert_eq!(batched, reference, "threads={threads}");
            let keyed: Vec<KeyedJob> =
                jobs.iter().map(|&job| KeyedJob { key: &sk, job }).collect();
            let (flat, stats) = pbs_batch_keyed(&keyed, threads);
            assert_eq!(flat, reference, "keyed pool, threads={threads}");
            assert_eq!(stats.keys, 1);
            assert!(stats.busy_ns > 0, "workers must report busy time");
            assert!(stats.busy_ns <= stats.capacity_ns, "busy cannot exceed capacity");
            let u = stats.utilization();
            assert!(u > 0.0 && u <= 1.0, "utilization {u} out of range at T={threads}");
        }
    }

    #[test]
    fn keyed_batch_sweeps_jobs_from_distinct_server_keys_in_one_pass() {
        // Cross-key fusion at the pool layer: jobs under two different
        // users' keys interleaved into one pass. Each output must equal
        // what that job's own key produces sequentially, and the pass
        // must report both keys.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let params = TfheParams::test_small();
        let mut rng_a = Xoshiro256::new(0xA11CE);
        let mut rng_b = Xoshiro256::new(0xB0B);
        let ck_a = ClientKey::generate(params, &mut rng_a);
        let ck_b = ClientKey::generate(params, &mut rng_b);
        let sk_a = ck_a.server_key(&mut rng_a);
        let sk_b = ck_b.server_key(&mut rng_b);
        let enc = Encoder::new(params);
        let space = params.message_space();
        let lut = Lut::from_fn(&params, |m| (m + 1) % space);
        let (pl_a, pl_b) = (sk_a.prepare_lut(&lut), sk_b.prepare_lut(&lut));
        let cts_a: Vec<_> = (0..3).map(|m| enc.encrypt_raw(m % space, &ck_a, &mut rng_a)).collect();
        let cts_b: Vec<_> = (0..3).map(|m| enc.encrypt_raw(m % space, &ck_b, &mut rng_b)).collect();
        // Interleave A and B jobs so neither key owns a contiguous span.
        let mut jobs: Vec<KeyedJob> = Vec::new();
        for i in 0..3 {
            jobs.push(KeyedJob { key: &sk_a, job: BatchJob::Single(&cts_a[i], &pl_a) });
            jobs.push(KeyedJob { key: &sk_b, job: BatchJob::Single(&cts_b[i], &pl_b) });
        }
        let solo: Vec<LweCiphertext> = (0..3)
            .flat_map(|i| {
                [sk_a.pbs_prepared(&cts_a[i], &pl_a), sk_b.pbs_prepared(&cts_b[i], &pl_b)]
            })
            .collect();
        for threads in [1usize, 2, 3] {
            let (flat, stats) = pbs_batch_keyed(&jobs, threads);
            assert_eq!(flat, solo, "threads={threads}");
            assert_eq!(stats.keys, 2, "one pass must sweep both keys");
        }
        // Isolated flavor: a panic at submission index 1 (B's first job)
        // quarantines that job alone; survivors under both keys stay
        // bit-identical to the clean pass.
        let faults = FaultPlan::parse("panic@pbs:2").unwrap();
        let (got, stats) = pbs_batch_keyed_isolated(&jobs, 3, Some(&faults));
        assert_eq!(stats.keys, 2);
        for (i, res) in got.iter().enumerate() {
            if i == 1 {
                assert!(
                    matches!(res, Err(FheError::WorkerPanic(m)) if m.contains("panic@pbs:2")),
                    "job 2 must be the quarantined victim"
                );
            } else {
                assert_eq!(
                    res.as_ref().expect("survivor").as_slice(),
                    &solo[i..i + 1],
                    "survivor {i} bit-identical across keys"
                );
            }
        }
    }
}
