//! Deterministic fault injection + cooperative cancellation.
//!
//! [`FaultPlan`] is the single seam through which tests (and the
//! `FHE_FAULTS` env knob) inject failures into the serving stack. Every
//! trigger is keyed on a **deterministic counter** — a global PBS job
//! index reserved in one `fetch_add` per submission, a level-boundary
//! tick, an engine-batch tick — never on wall-clock time or thread
//! interleaving, so a fault plan reproduces the same blast radius at any
//! `FHE_THREADS` setting.
//!
//! Grammar (comma-separated, whitespace-tolerant):
//!
//! ```text
//! FHE_FAULTS=panic@pbs:17,deadline@level:2,panic@engine:1
//! ```
//!
//! - `panic@pbs:N` — the N-th PBS job (1-based, across the process
//!   lifetime of the plan) panics inside the worker pool.
//! - `deadline@level:N` — the N-th fused level boundary reports the
//!   request deadline as expired, forcing cooperative abandonment.
//! - `panic@engine:N` — the N-th engine batch panics before any work,
//!   exercising scheduler supervision/respawn.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Cooperative cancellation token carried by a request. Cloning shares
/// the underlying flag; the executor polls it at every level boundary.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Work already in flight finishes its current
    /// PBS level; remaining levels are abandoned.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// A deterministic schedule of injected faults. Shared (`Arc`) between
/// the context, pool workers, the fused executor, and engine bodies;
/// the interior counters are atomic so triggers stay exact-once.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// 1-based global PBS job indices that panic in the worker pool.
    pbs_panic_at: Vec<u64>,
    /// 1-based level-boundary ticks at which the deadline check fires.
    deadline_at_level: Vec<u64>,
    /// 1-based engine-batch ticks that panic before doing any work.
    engine_panic_at: Vec<u64>,
    /// Global PBS job counter; submissions reserve spans via one
    /// `fetch_add`, making per-job indices independent of thread order.
    pbs_jobs: AtomicU64,
    /// Global fused level-boundary counter.
    levels: AtomicU64,
    /// Global engine-batch counter.
    engine_batches: AtomicU64,
}

impl FaultPlan {
    /// Parse the `FHE_FAULTS` grammar. Empty spec → empty plan (armed
    /// but never fires), useful for measuring the cost of the checks.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, rest) = part
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': expected kind@site:index"))?;
            let (site, idx) = rest
                .split_once(':')
                .ok_or_else(|| format!("fault '{part}': expected kind@site:index"))?;
            let idx: u64 = idx
                .parse()
                .map_err(|_| format!("fault '{part}': index '{idx}' is not a number"))?;
            if idx == 0 {
                return Err(format!("fault '{part}': indices are 1-based"));
            }
            match (kind, site) {
                ("panic", "pbs") => plan.pbs_panic_at.push(idx),
                ("deadline", "level") => plan.deadline_at_level.push(idx),
                ("panic", "engine") => plan.engine_panic_at.push(idx),
                _ => {
                    return Err(format!(
                        "fault '{part}': unknown trigger '{kind}@{site}' \
                         (known: panic@pbs, deadline@level, panic@engine)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Read `FHE_FAULTS`. Unset/empty → `None`. A malformed spec panics
    /// loudly: this is a developer knob and a typo must not silently
    /// disarm a fault-injection CI leg.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let spec = std::env::var("FHE_FAULTS").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(plan) => Some(Arc::new(plan)),
            Err(e) => panic!("invalid FHE_FAULTS: {e}"),
        }
    }

    /// True if the plan can never fire (all trigger lists empty).
    pub fn is_empty(&self) -> bool {
        self.pbs_panic_at.is_empty()
            && self.deadline_at_level.is_empty()
            && self.engine_panic_at.is_empty()
    }

    /// Reserve a span of `n` global PBS job indices for one submission.
    /// Returns the 0-based base; the jobs are `base+1 ..= base+n`
    /// (1-based) in submission order, independent of worker scheduling.
    pub fn next_pbs_base(&self, n: u64) -> u64 {
        self.pbs_jobs.fetch_add(n, Ordering::Relaxed)
    }

    /// Panic if the 1-based global PBS job index is scheduled to fail.
    /// Called by pool workers *inside* their `catch_unwind` guard.
    pub fn maybe_panic_pbs(&self, idx_1based: u64) {
        if self.pbs_panic_at.contains(&idx_1based) {
            panic!("injected fault: panic@pbs:{idx_1based}");
        }
    }

    /// Tick the level-boundary counter; true if this boundary is
    /// scheduled to report the deadline as expired.
    pub fn deadline_fires(&self) -> bool {
        let tick = self.levels.fetch_add(1, Ordering::Relaxed) + 1;
        self.deadline_at_level.contains(&tick)
    }

    /// Tick the engine-batch counter; panic if this batch is scheduled
    /// to crash. Called by engine bodies before any real work, inside
    /// the scheduler's supervision guard.
    pub fn maybe_panic_engine(&self) {
        let tick = self.engine_batches.fetch_add(1, Ordering::Relaxed) + 1;
        if self.engine_panic_at.contains(&tick) {
            panic!("injected fault: panic@engine:{tick}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_parses_all_trigger_kinds() {
        let p = FaultPlan::parse("panic@pbs:17, deadline@level:2 ,panic@engine:1").unwrap();
        assert_eq!(p.pbs_panic_at, vec![17]);
        assert_eq!(p.deadline_at_level, vec![2]);
        assert_eq!(p.engine_panic_at, vec![1]);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn grammar_rejects_malformed_specs() {
        assert!(FaultPlan::parse("panic@pbs").is_err());
        assert!(FaultPlan::parse("panic:17").is_err());
        assert!(FaultPlan::parse("panic@pbs:zero").is_err());
        assert!(FaultPlan::parse("panic@pbs:0").is_err());
        assert!(FaultPlan::parse("explode@pbs:1").is_err());
        assert!(FaultPlan::parse("panic@gpu:1").is_err());
    }

    #[test]
    fn pbs_base_reservation_is_contiguous_and_exact() {
        let p = FaultPlan::parse("panic@pbs:5").unwrap();
        let a = p.next_pbs_base(3); // jobs 1..=3
        let b = p.next_pbs_base(4); // jobs 4..=7
        assert_eq!(a, 0);
        assert_eq!(b, 3);
        for idx in [1u64, 2, 3, 4, 6, 7] {
            p.maybe_panic_pbs(idx); // must not panic
        }
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.maybe_panic_pbs(5);
        }));
        assert!(hit.is_err(), "job 5 must panic");
    }

    #[test]
    fn deadline_fires_exactly_at_scheduled_tick() {
        let p = FaultPlan::parse("deadline@level:3").unwrap();
        assert!(!p.deadline_fires()); // tick 1
        assert!(!p.deadline_fires()); // tick 2
        assert!(p.deadline_fires()); // tick 3
        assert!(!p.deadline_fires()); // tick 4
    }

    #[test]
    fn engine_panic_fires_exactly_at_scheduled_batch() {
        let p = FaultPlan::parse("panic@engine:2").unwrap();
        p.maybe_panic_engine(); // batch 1: fine
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.maybe_panic_engine(); // batch 2: boom
        }));
        assert!(hit.is_err());
        p.maybe_panic_engine(); // batch 3: fine again
    }

    #[test]
    fn cancel_token_shares_state_across_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!u.is_cancelled());
        t.cancel();
        assert!(u.is_cancelled());
    }
}
