//! Declarative circuit-plan IR (S5b): the plan-then-execute seam of the
//! FHE layer.
//!
//! A [`CircuitPlan`] is a DAG over two node classes, mirroring the
//! paper's cost model exactly: *free* linear ops (add/sub/neg/plain
//! scalar/sum — 0 PBS) and [`Node::Pbs`] nodes (1 PBS each, referencing a
//! [`LutRef`] into the plan's LUT registry). Plans are built by
//! [`CircuitBuilder`] as pure data — no keys, no ciphertexts — so the
//! same object serves three consumers:
//!
//! * **Cost**: [`CircuitPlan::pbs_count`] / [`CircuitPlan::levels`] /
//!   [`CircuitPlan::level_sizes`] are the single source of truth for the
//!   PBS accounting the optimizer and the bench tables previously
//!   hand-derived per circuit.
//! * **Execution**: [`CircuitPlan::execute`] runs the leveling pass —
//!   every PBS node's *level* is its bootstrap depth, so all nodes of one
//!   level are independent — and issues **one batched PBS call per
//!   level** through the [`ServerKey::pbs_batch`] worker pool. Because a
//!   PBS is deterministic and the linear ops are evaluated in the same
//!   dataflow, plan execution is bit-identical to the hand-staged
//!   formulation it replaced (pinned by tests in `fhe_circuits`).
//! * **Fusion**: [`PlanRun`] exposes the level loop one step at a time
//!   (jobs out, results in), which is the seam the serving coordinator's
//!   `FusedLevelExecutor` uses to merge the current level of *every
//!   co-scheduled request* into a single `pbs_batch` submission.
//!
//! [`ServerKey::pbs_batch`]: super::bootstrap::ServerKey::pbs_batch

use super::bootstrap::PreparedLut;
use super::lwe::LweCiphertext;
use super::ops::{CtInt, FheContext};
use std::sync::Arc;

/// Index of a node inside its plan (topological: a node only references
/// smaller ids).
pub type NodeId = usize;

/// Reference into a plan's LUT registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LutRef(pub usize);

/// One DAG node. Linear nodes cost 0 PBS; `Pbs` costs exactly 1.
#[derive(Clone, Debug)]
pub enum Node {
    /// The i-th circuit input ciphertext.
    Input(usize),
    /// A public (trivially encrypted) constant.
    Const(i64),
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Neg(NodeId),
    AddConst(NodeId, i64),
    ScalarMul(NodeId, i64),
    /// Sum of many operands (len − 1 homomorphic additions).
    Sum(Vec<NodeId>),
    /// Programmable bootstrap: apply `lut` to `input`.
    Pbs { input: NodeId, lut: LutRef },
}

/// A univariate signed function registered with the plan; resolved to a
/// [`PreparedLut`] (through the context's table-keyed cache) at run time.
type LutFn = Arc<dyn Fn(i64) -> i64 + Send + Sync>;

/// Builder for [`CircuitPlan`]s. Append-only, so node ids come out in
/// topological order by construction.
pub struct CircuitBuilder {
    nodes: Vec<Node>,
    luts: Vec<LutFn>,
    n_inputs: usize,
    outputs: Vec<NodeId>,
    /// Cached refs for the standard tables (relu/abs/x²⁄4/identity) so
    /// each plan registers them at most once (mirrors `FheContext`'s
    /// prepared standard LUTs).
    std_luts: [Option<LutRef>; 4],
}

/// Indices into `CircuitBuilder::std_luts`.
const STD_RELU: usize = 0;
const STD_ABS: usize = 1;
const STD_SQ4: usize = 2;
const STD_ID: usize = 3;

impl CircuitBuilder {
    pub fn new() -> Self {
        CircuitBuilder {
            nodes: Vec::new(),
            luts: Vec::new(),
            n_inputs: 0,
            outputs: Vec::new(),
            std_luts: [None; 4],
        }
    }

    fn push(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    fn check(&self, id: NodeId) {
        assert!(id < self.nodes.len(), "node {id} not yet defined");
    }

    /// Declare `n` fresh circuit inputs; returns their node ids in order.
    pub fn inputs(&mut self, n: usize) -> Vec<NodeId> {
        (0..n)
            .map(|_| {
                let idx = self.n_inputs;
                self.n_inputs += 1;
                self.push(Node::Input(idx))
            })
            .collect()
    }

    /// A public constant (trivial ciphertext at run time).
    pub fn constant(&mut self, v: i64) -> NodeId {
        self.push(Node::Const(v))
    }

    // ----- free linear ops (0 PBS) -----

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.check(a);
        self.check(b);
        self.push(Node::Add(a, b))
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.check(a);
        self.check(b);
        self.push(Node::Sub(a, b))
    }

    pub fn neg(&mut self, a: NodeId) -> NodeId {
        self.check(a);
        self.push(Node::Neg(a))
    }

    pub fn add_const(&mut self, a: NodeId, c: i64) -> NodeId {
        self.check(a);
        self.push(Node::AddConst(a, c))
    }

    /// Multiplication by a plaintext literal (0 PBS, per the paper).
    pub fn scalar_mul(&mut self, a: NodeId, c: i64) -> NodeId {
        self.check(a);
        self.push(Node::ScalarMul(a, c))
    }

    /// Sum of many nodes (0 PBS; evaluated exactly like `FheContext::sum`).
    pub fn sum(&mut self, xs: &[NodeId]) -> NodeId {
        assert!(!xs.is_empty(), "sum of zero nodes");
        for &x in xs {
            self.check(x);
        }
        self.push(Node::Sum(xs.to_vec()))
    }

    // ----- PBS nodes (1 PBS each) -----

    /// Register a univariate signed function; the returned [`LutRef`] can
    /// feed any number of [`CircuitBuilder::pbs`] nodes.
    pub fn lut<F: Fn(i64) -> i64 + Send + Sync + 'static>(&mut self, f: F) -> LutRef {
        self.luts.push(Arc::new(f));
        LutRef(self.luts.len() - 1)
    }

    /// Apply a registered LUT (1 PBS).
    pub fn pbs(&mut self, x: NodeId, lut: LutRef) -> NodeId {
        self.check(x);
        assert!(lut.0 < self.luts.len(), "LUT {} not registered", lut.0);
        self.push(Node::Pbs { input: x, lut })
    }

    /// Register-once lookup of a standard table.
    fn std_lut(&mut self, idx: usize, f: fn(i64) -> i64) -> LutRef {
        match self.std_luts[idx] {
            Some(l) => l,
            None => {
                let l = self.lut(f);
                self.std_luts[idx] = Some(l);
                l
            }
        }
    }

    /// ReLU x⁺ (1 PBS).
    pub fn relu(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_RELU, |v| v.max(0));
        self.pbs(x, lut)
    }

    /// |x| (1 PBS).
    pub fn abs(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_ABS, |v: i64| v.abs());
        self.pbs(x, lut)
    }

    /// floor(x²/4) (1 PBS) — the paper's eq. 2 table.
    pub fn square_quarter(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_SQ4, |v| (v * v).div_euclid(4));
        self.pbs(x, lut)
    }

    /// Identity noise refresh (1 PBS).
    pub fn refresh(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_ID, |v| v);
        self.pbs(x, lut)
    }

    /// Ciphertext × ciphertext via the paper's eq. 1 (2 PBS):
    /// `ab = PBS(x²/4; a+b) − PBS(x²/4; a−b)`.
    pub fn ct_mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let s = self.add(a, b);
        let d = self.sub(a, b);
        let p1 = self.square_quarter(s);
        let p2 = self.square_quarter(d);
        self.sub(p1, p2)
    }

    /// Mark a node as a circuit output (in call order).
    pub fn output(&mut self, id: NodeId) {
        self.check(id);
        self.outputs.push(id);
    }

    /// Finalize: runs the leveling pass and freezes the DAG.
    pub fn build(self) -> CircuitPlan {
        // Leveling: a node's level is its bootstrap depth — 0 for inputs
        // and constants, max over operands for linear nodes, operand
        // level + 1 for PBS nodes. Nodes are topological, so one forward
        // scan suffices. The same scan records each node's consumer count
        // (+1 per output listing) so the executor can free intermediate
        // ciphertexts after their last read instead of holding the whole
        // DAG live.
        let mut levels = vec![0usize; self.nodes.len()];
        let mut uses = vec![0u32; self.nodes.len()];
        let mut max_level = 0usize;
        for (id, node) in self.nodes.iter().enumerate() {
            let lvl = match node {
                Node::Input(_) | Node::Const(_) => 0,
                Node::Add(a, b) | Node::Sub(a, b) => {
                    uses[*a] += 1;
                    uses[*b] += 1;
                    levels[*a].max(levels[*b])
                }
                Node::Neg(a) | Node::AddConst(a, _) | Node::ScalarMul(a, _) => {
                    uses[*a] += 1;
                    levels[*a]
                }
                Node::Sum(xs) => {
                    let mut lvl = 0;
                    for &x in xs {
                        uses[x] += 1;
                        lvl = lvl.max(levels[x]);
                    }
                    lvl
                }
                Node::Pbs { input, .. } => {
                    uses[*input] += 1;
                    levels[*input] + 1
                }
            };
            levels[id] = lvl;
            max_level = max_level.max(lvl);
        }
        for &out in &self.outputs {
            uses[out] += 1;
        }
        CircuitPlan {
            nodes: self.nodes,
            luts: self.luts,
            n_inputs: self.n_inputs,
            outputs: self.outputs,
            levels,
            uses,
            max_level,
        }
    }
}

impl Default for CircuitBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A finalized circuit plan: the DAG, its LUT registry, and the result of
/// the leveling pass.
pub struct CircuitPlan {
    nodes: Vec<Node>,
    luts: Vec<LutFn>,
    n_inputs: usize,
    outputs: Vec<NodeId>,
    /// Per-node bootstrap depth (see [`CircuitBuilder::build`]).
    levels: Vec<usize>,
    /// Per-node consumer count (operand reads + output listings) — the
    /// executor's liveness information.
    uses: Vec<u32>,
    max_level: usize,
}

impl CircuitPlan {
    /// Number of circuit input ciphertexts.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Number of circuit outputs.
    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Total programmable bootstraps of one execution — the paper's cost
    /// unit, now derived from the same DAG the executor runs.
    pub fn pbs_count(&self) -> u64 {
        self.nodes.iter().filter(|n| matches!(n, Node::Pbs { .. })).count() as u64
    }

    /// Number of PBS execution levels (batched rounds).
    pub fn levels(&self) -> usize {
        self.max_level
    }

    /// PBS jobs per level, index 0 = level 1. Sums to `pbs_count()`.
    pub fn level_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.max_level];
        for (id, node) in self.nodes.iter().enumerate() {
            if matches!(node, Node::Pbs { .. }) {
                sizes[self.levels[id] - 1] += 1;
            }
        }
        sizes
    }

    /// PBS-free homomorphic ops of one execution (`Sum` of k operands
    /// counts its k − 1 additions), for the optimizer's linear-cost term.
    pub fn linear_op_count(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Input(_) | Node::Const(_) | Node::Pbs { .. } => 0,
                Node::Sum(xs) => xs.len() as u64 - 1,
                _ => 1,
            })
            .sum()
    }

    /// Execute the plan: one batched PBS submission per level through the
    /// context's worker pool, linear ops evaluated between levels.
    pub fn execute(&self, ctx: &FheContext, inputs: &[CtInt]) -> Vec<CtInt> {
        let mut run = PlanRun::new(self, ctx, inputs);
        while let Some(jobs) = run.next_level_jobs(ctx) {
            let refs: Vec<(&LweCiphertext, &PreparedLut)> =
                jobs.iter().map(|(ct, lut)| (&ct.ct, lut.as_ref())).collect();
            let outs: Vec<CtInt> =
                ctx.pbs_jobs(&refs).into_iter().map(|ct| CtInt { ct }).collect();
            run.supply(outs);
        }
        run.finish(ctx)
    }
}

/// One in-flight execution of a plan, advanced level by level: call
/// [`PlanRun::next_level_jobs`] to obtain the current level's PBS jobs,
/// run them (any way you like — this is the coordinator's fusion seam),
/// hand the results back via [`PlanRun::supply`], repeat until `None`,
/// then [`PlanRun::finish`].
pub struct PlanRun<'p> {
    plan: &'p CircuitPlan,
    values: Vec<Option<CtInt>>,
    /// Whether a node has been computed (its value may since have been
    /// freed once every consumer read it).
    evaluated: Vec<bool>,
    /// Consumer reads left per node; at 0 the value is dropped, so peak
    /// residency tracks the live frontier, not the whole DAG.
    remaining: Vec<u32>,
    /// LUT registry resolved against the executing context (cache-backed).
    resolved: Vec<Arc<PreparedLut>>,
    /// Next PBS level to execute (1-based).
    current: usize,
    /// Pbs node ids whose jobs were handed out and await `supply`.
    pending: Vec<NodeId>,
}

impl<'p> PlanRun<'p> {
    pub fn new(plan: &'p CircuitPlan, ctx: &FheContext, inputs: &[CtInt]) -> Self {
        assert_eq!(inputs.len(), plan.n_inputs, "plan expects {} inputs", plan.n_inputs);
        let resolved = plan.luts.iter().map(|f| ctx.prepared_dyn(f.as_ref())).collect();
        let mut values: Vec<Option<CtInt>> = plan.nodes.iter().map(|_| None).collect();
        let mut evaluated = vec![false; plan.nodes.len()];
        for (id, node) in plan.nodes.iter().enumerate() {
            match node {
                Node::Input(i) => values[id] = Some(inputs[*i].clone()),
                Node::Const(v) => values[id] = Some(ctx.constant(*v)),
                _ => continue,
            }
            evaluated[id] = true;
        }
        PlanRun {
            plan,
            values,
            evaluated,
            remaining: plan.uses.clone(),
            resolved,
            current: 1,
            pending: Vec::new(),
        }
    }

    fn value(&self, i: NodeId) -> &CtInt {
        self.values[i].as_ref().expect("operand live (topological order + use counts)")
    }

    /// Record one consumer read of `i`; free the value after the last.
    fn release(&mut self, i: NodeId) {
        self.remaining[i] -= 1;
        if self.remaining[i] == 0 {
            self.values[i] = None;
        }
    }

    /// Evaluate every not-yet-evaluated linear node of level < `bound`.
    /// Ids are topological, so a single in-order pass sees all operands
    /// (earlier linear nodes this pass, PBS results from prior levels).
    fn eval_linear(&mut self, ctx: &FheContext, bound: usize) {
        for id in 0..self.plan.nodes.len() {
            if self.evaluated[id] || self.plan.levels[id] >= bound {
                continue;
            }
            // Operand refs live in the plan (`&'p`), so computing the
            // value and releasing the operands can interleave freely
            // with `&mut self` bookkeeping.
            let v = match &self.plan.nodes[id] {
                Node::Input(_) | Node::Const(_) => continue, // prefilled
                Node::Pbs { .. } => continue,                // supplied per level
                Node::Add(a, b) => {
                    let v = ctx.add(self.value(*a), self.value(*b));
                    self.release(*a);
                    self.release(*b);
                    v
                }
                Node::Sub(a, b) => {
                    let v = ctx.sub(self.value(*a), self.value(*b));
                    self.release(*a);
                    self.release(*b);
                    v
                }
                Node::Neg(a) => {
                    let v = ctx.neg(self.value(*a));
                    self.release(*a);
                    v
                }
                Node::AddConst(a, c) => {
                    let v = ctx.add_const(self.value(*a), *c);
                    self.release(*a);
                    v
                }
                Node::ScalarMul(a, c) => {
                    let v = ctx.scalar_mul(self.value(*a), *c);
                    self.release(*a);
                    v
                }
                Node::Sum(xs) => {
                    let refs: Vec<&CtInt> = xs.iter().map(|&x| self.value(x)).collect();
                    let v = ctx.sum_refs(&refs);
                    drop(refs);
                    for &x in xs {
                        self.release(x);
                    }
                    v
                }
            };
            self.values[id] = Some(v);
            self.evaluated[id] = true;
        }
    }

    /// The next level's PBS jobs as (input ciphertext, prepared LUT)
    /// pairs, or `None` once every PBS level has been supplied. Jobs are
    /// in node-id order; results must come back in the same order.
    pub fn next_level_jobs(&mut self, ctx: &FheContext) -> Option<Vec<(CtInt, Arc<PreparedLut>)>> {
        assert!(self.pending.is_empty(), "previous level awaits supply()");
        if self.current > self.plan.max_level {
            return None;
        }
        self.eval_linear(ctx, self.current);
        let mut jobs = Vec::new();
        for (id, node) in self.plan.nodes.iter().enumerate() {
            if let Node::Pbs { input, lut } = node {
                if self.plan.levels[id] == self.current {
                    let ct = self.values[*input]
                        .clone()
                        .expect("PBS input live (level < current)");
                    jobs.push((ct, Arc::clone(&self.resolved[lut.0])));
                    self.pending.push(id);
                    self.release(*input);
                }
            }
        }
        Some(jobs)
    }

    /// Hand back the results of the jobs returned by the last
    /// [`PlanRun::next_level_jobs`] call (same order) and advance.
    pub fn supply(&mut self, outs: Vec<CtInt>) {
        assert_eq!(outs.len(), self.pending.len(), "level result count mismatch");
        for (id, ct) in self.pending.drain(..).zip(outs) {
            self.values[id] = Some(ct);
            self.evaluated[id] = true;
        }
        self.current += 1;
    }

    /// Evaluate the trailing linear nodes and return the outputs.
    pub fn finish(mut self, ctx: &FheContext) -> Vec<CtInt> {
        assert!(
            self.current > self.plan.max_level && self.pending.is_empty(),
            "finish() before all PBS levels were executed"
        );
        self.eval_linear(ctx, self.plan.max_level + 1);
        self.plan
            .outputs
            .iter()
            .map(|&id| self.values[id].clone().expect("output live"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Xoshiro256;

    fn setup() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(0x9147);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    /// relu(a − b) + |b| · 2 — one plan, two levels of depth 1.
    fn small_plan() -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let d = b.sub(ins[0], ins[1]);
        let r = b.relu(d);
        let ab = b.abs(ins[1]);
        let ab2 = b.scalar_mul(ab, 2);
        let out = b.add(r, ab2);
        b.output(out);
        b.build()
    }

    #[test]
    fn analysis_counts_levels_and_ops() {
        let p = small_plan();
        assert_eq!(p.n_inputs(), 2);
        assert_eq!(p.n_outputs(), 1);
        assert_eq!(p.pbs_count(), 2);
        assert_eq!(p.levels(), 1);
        assert_eq!(p.level_sizes(), vec![2]);
        assert_eq!(p.linear_op_count(), 3); // sub, scalar_mul, add
    }

    #[test]
    fn ct_mul_and_chained_levels() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let prod = b.ct_mul(ins[0], ins[1]); // level 1 (2 PBS)
        let r = b.relu(prod); // level 2
        b.output(r);
        let p = b.build();
        assert_eq!(p.pbs_count(), 3);
        assert_eq!(p.levels(), 2);
        assert_eq!(p.level_sizes(), vec![2, 1]);
    }

    #[test]
    fn sum_counts_len_minus_one_linear_ops() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(4);
        let s = b.sum(&ins);
        b.output(s);
        let p = b.build();
        assert_eq!(p.pbs_count(), 0);
        assert_eq!(p.levels(), 0);
        assert_eq!(p.linear_op_count(), 3);
    }

    #[test]
    fn execute_matches_direct_ops_bit_identically() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        // Values keep every intermediate and the output inside the 4-bit
        // signed range [−8, 7] (linear ops do not saturate).
        for (a, b) in [(1i64, -2), (-4, 1), (0, 0), (2, 3)] {
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(b, &ck, &mut rng);
            let before = pbs_count();
            let outs = p.execute(&ctx, &[ca.clone(), cb.clone()]);
            assert_eq!(pbs_count() - before, p.pbs_count(), "plan PBS count a={a} b={b}");
            // Direct formulation of the same dataflow.
            let want =
                ctx.add(&ctx.relu(&ctx.sub(&ca, &cb)), &ctx.scalar_mul(&ctx.abs(&cb), 2));
            assert_eq!(outs.len(), 1);
            assert_eq!(outs[0].ct, want.ct, "bit-identical a={a} b={b}");
            assert_eq!(ctx.decrypt(&outs[0], &ck), (a - b).max(0) + 2 * b.abs());
        }
    }

    #[test]
    fn execute_is_thread_invariant() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(1, &ck, &mut rng);
        let cb = ctx.encrypt(-2, &ck, &mut rng);
        let inputs = [ca, cb];
        ctx.set_threads(1);
        let reference = p.execute(&ctx, &inputs);
        for threads in [2usize, 4] {
            ctx.set_threads(threads);
            let got = p.execute(&ctx, &inputs);
            assert_eq!(got[0].ct, reference[0].ct, "threads={threads}");
        }
    }

    #[test]
    fn constants_and_pure_linear_plans() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let c = b.constant(3);
        let s = b.add(ins[0], c);
        let t = b.add_const(s, -1);
        let n = b.neg(t);
        b.output(n);
        let p = b.build();
        assert_eq!(p.pbs_count(), 0);
        let x = ctx.encrypt(2, &ck, &mut rng);
        let before = pbs_count();
        let outs = p.execute(&ctx, &[x]);
        assert_eq!(pbs_count(), before, "linear plan must not bootstrap");
        assert_eq!(ctx.decrypt(&outs[0], &ck), -(2 + 3 - 1));
    }

    #[test]
    fn stepper_drives_levels_manually() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(-1, &ck, &mut rng);
        let cb = ctx.encrypt(2, &ck, &mut rng);
        let mut run = PlanRun::new(&p, &ctx, &[ca, cb]);
        let mut rounds = 0;
        while let Some(jobs) = run.next_level_jobs(&ctx) {
            rounds += 1;
            // Execute the level's jobs one by one (any schedule is valid).
            let outs: Vec<CtInt> = jobs
                .iter()
                .map(|(ct, lut)| CtInt { ct: ctx.sk.pbs_prepared(&ct.ct, lut) })
                .collect();
            run.supply(outs);
        }
        assert_eq!(rounds, p.levels());
        let outs = run.finish(&ctx);
        assert_eq!(ctx.decrypt(&outs[0], &ck), (-1i64 - 2).max(0) + 2 * 2);
    }
}
