//! Declarative circuit-plan IR (S5b): the plan-then-execute seam of the
//! FHE layer.
//!
//! A [`CircuitPlan`] is a DAG over two node classes, mirroring the
//! paper's cost model exactly: *free* linear ops (add/sub/neg/plain
//! scalar/sum — 0 PBS) and [`Node::Pbs`] nodes (1 PBS each, referencing a
//! [`LutRef`] into the plan's LUT registry). Plans are built by
//! [`CircuitBuilder`] as pure data — no keys, no ciphertexts — so the
//! same object serves three consumers:
//!
//! * **Cost**: [`CircuitPlan::pbs_count`] / [`CircuitPlan::levels`] /
//!   [`CircuitPlan::level_sizes`] are the single source of truth for the
//!   PBS accounting the optimizer and the bench tables previously
//!   hand-derived per circuit.
//! * **Execution**: [`CircuitPlan::execute`] runs the leveling pass —
//!   every PBS node's *level* is its bootstrap depth, so all nodes of one
//!   level are independent — and issues **one batched PBS call per
//!   level** through the [`ServerKey::pbs_batch`] worker pool. Because a
//!   PBS is deterministic and the linear ops are evaluated in the same
//!   dataflow, plan execution is bit-identical to the hand-staged
//!   formulation it replaced (pinned by tests in `fhe_circuits`).
//! * **Fusion**: [`PlanRun`] exposes the level loop one step at a time
//!   (jobs out, results in), which is the seam the serving coordinator's
//!   `FusedLevelExecutor` uses to merge the current level of *every
//!   co-scheduled request* into a single `pbs_batch` submission.
//!
//! ## Rewrite passes
//!
//! Because the plan is pure data, PBS-count reductions are IR rewrites
//! rather than per-circuit hand optimizations. [`PlanRewriter`] runs an
//! ordered pipeline over a finished plan (before execution re-levels
//! it):
//!
//! 1. **Common-subexpression elimination** — merges linear nodes with
//!    identical canonicalized operands (`Add`/`Sum` are commutative on
//!    the torus, so operand order is normalized away) and `Pbs` nodes
//!    with the same input *and the same registered LUT*. Every merge is
//!    ciphertext-exact: the surviving node computes the bit-identical
//!    ciphertext both duplicates would have.
//! 2. **Multi-value bootstrap packing** — groups `Pbs` nodes sharing
//!    one input ciphertext into a [`Node::MultiPbs`] evaluated by
//!    [`ServerKey::pbs_multi`]: one blind rotation for the whole group,
//!    one sample-extract/key-switch per LUT, with each member's result
//!    surfaced through a free [`Node::MultiOut`] projection. Group size
//!    is capped by the parameter set's `many_lut_log` headroom (the
//!    coarse mod-switch spends that margin), so a budget of 0 makes the
//!    pass a no-op. Packing never changes `pbs_count()` (LUT
//!    evaluations) but strictly reduces `blind_rotation_count()`
//!    wherever a group forms; members of a group always sit at the same
//!    level (a PBS level is its input's level + 1).
//!
//! Both passes are idempotent, and rewriting is observable:
//! [`RewriteStats`] reports merged and packed node counts, and the
//! pre/post plans expose `pbs_count()` / `blind_rotation_count()` so
//! tests pin the saving exactly (`tests/rewrite_it.rs`).
//!
//! ## Wavefront dispatch
//!
//! Beside the leveling pass (kept verbatim — it is the counting oracle
//! `levels()` / `level_sizes()` report from), [`PlanRun`] offers a
//! *readiness-driven* stepper: [`PlanRun::next_wave_jobs`] hands out
//! every bootstrap whose operand ciphertext is already materialized,
//! instead of every bootstrap whose level number equals the open level.
//! For this IR the two coincide wave-for-wave — a node's level *is* its
//! exact bootstrap dependency depth, so the ready set at each wave
//! boundary equals the level set — which is precisely why wavefront
//! dispatch is bit-identical with unchanged counter deltas (pinned by
//! tests here and in the differential harnesses). The payoff is at the
//! pool layer: wavefront ticks submit through the work-stealing,
//! cross-key pool (`tfhe::bootstrap::pbs_batch_keyed`), where idle
//! workers steal ready jobs instead of parking at a level barrier. The
//! mode is selected by [`wavefront_enabled`] (`FHE_WAVEFRONT=0` forces
//! the legacy barrier; [`set_wavefront_dispatch`] overrides
//! programmatically for in-process A/B tests).
//!
//! [`ServerKey::pbs_batch`]: super::bootstrap::ServerKey::pbs_batch
//! [`ServerKey::pbs_multi`]: super::bootstrap::ServerKey::pbs_multi

use super::bootstrap::{BatchJob, PreparedLut, PreparedMultiLut};
use super::ops::{CtInt, FheContext};
use crate::quant::FixedMult;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// Index of a node inside its plan (topological: a node only references
/// smaller ids).
pub type NodeId = usize;

/// Reference into a plan's LUT registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LutRef(pub usize);

/// One DAG node. Linear nodes cost 0 PBS; `Pbs` costs exactly 1.
#[derive(Clone, Debug)]
pub enum Node {
    /// The i-th circuit input ciphertext.
    Input(usize),
    /// A public (trivially encrypted) constant.
    Const(i64),
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Neg(NodeId),
    AddConst(NodeId, i64),
    ScalarMul(NodeId, i64),
    /// Sum of many operands (len − 1 homomorphic additions).
    Sum(Vec<NodeId>),
    /// Programmable bootstrap: apply `lut` to `input`.
    Pbs { input: NodeId, lut: LutRef },
    /// Multi-value bootstrap: evaluate every `luts` entry on `input`
    /// with one shared blind rotation (`luts.len()` LUT evaluations, 1
    /// rotation). Produces no value of its own — results surface through
    /// `MultiOut` projections. Only the rewriter's packing pass creates
    /// these.
    MultiPbs { input: NodeId, luts: Vec<LutRef> },
    /// The `index`-th output of a `MultiPbs` node (free: the extraction
    /// happens inside the bootstrap).
    MultiOut { multi: NodeId, index: usize },
}

/// A univariate signed function registered with the plan; resolved to a
/// [`PreparedLut`] (through the context's table-keyed cache) at run time.
type LutFn = Arc<dyn Fn(i64) -> i64 + Send + Sync>;

/// Builder for [`CircuitPlan`]s. Append-only, so node ids come out in
/// topological order by construction.
pub struct CircuitBuilder {
    nodes: Vec<Node>,
    luts: Vec<LutFn>,
    n_inputs: usize,
    outputs: Vec<NodeId>,
    /// Cached refs for the standard tables (relu/abs/x²⁄4/identity/min0)
    /// so each plan registers them at most once (mirrors `FheContext`'s
    /// prepared standard LUTs). Shared registration matters beyond
    /// economy: `Pbs` nodes CSE only on identical `(input, LutRef)`, so
    /// subgraphs emitted into one builder (e.g. the heads of a fused
    /// multi-head plan) deduplicate across each other exactly when they
    /// reference the same registered table.
    std_luts: [Option<LutRef>; 5],
    /// Requantization tables, keyed by the exact fixed-point factor (and
    /// its fused post-function) — the same register-once mechanism the
    /// std tables use, extended to a keyed family: every layer of a
    /// stacked block plan that requants by the same factor references
    /// the *same* `LutRef`, so CSE/packing see cross-layer requants as
    /// one table rather than per-layer clones.
    requant_luts: HashMap<(i64, u32, RequantKind), LutRef>,
    /// Declared bit-widths ([`CircuitBuilder::declare_width`]): nodes
    /// whose accumulator must hold more bits than the native message
    /// space. The radix legalization pass inside [`PlanRewriter`]
    /// rewrites them into limb vectors; undeclared plans are untouched.
    widths: HashMap<NodeId, u32>,
}

/// Post-function fused into a requant table (see
/// [`CircuitBuilder::requant_relu`] / [`CircuitBuilder::requant_min0`]).
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq)]
enum RequantKind {
    Plain,
    Relu,
    Min0,
}

/// Indices into `CircuitBuilder::std_luts`.
const STD_RELU: usize = 0;
const STD_ABS: usize = 1;
const STD_SQ4: usize = 2;
const STD_ID: usize = 3;
const STD_MIN0: usize = 4;

impl CircuitBuilder {
    pub fn new() -> Self {
        CircuitBuilder {
            nodes: Vec::new(),
            luts: Vec::new(),
            n_inputs: 0,
            outputs: Vec::new(),
            std_luts: [None; 5],
            requant_luts: HashMap::new(),
            widths: HashMap::new(),
        }
    }

    fn push(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    fn check(&self, id: NodeId) {
        assert!(id < self.nodes.len(), "node {id} not yet defined");
    }

    /// Declare `n` fresh circuit inputs; returns their node ids in order.
    pub fn inputs(&mut self, n: usize) -> Vec<NodeId> {
        (0..n)
            .map(|_| {
                let idx = self.n_inputs;
                self.n_inputs += 1;
                self.push(Node::Input(idx))
            })
            .collect()
    }

    /// A public constant (trivial ciphertext at run time).
    pub fn constant(&mut self, v: i64) -> NodeId {
        self.push(Node::Const(v))
    }

    // ----- free linear ops (0 PBS) -----

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.check(a);
        self.check(b);
        self.push(Node::Add(a, b))
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.check(a);
        self.check(b);
        self.push(Node::Sub(a, b))
    }

    pub fn neg(&mut self, a: NodeId) -> NodeId {
        self.check(a);
        self.push(Node::Neg(a))
    }

    pub fn add_const(&mut self, a: NodeId, c: i64) -> NodeId {
        self.check(a);
        self.push(Node::AddConst(a, c))
    }

    /// Multiplication by a plaintext literal (0 PBS, per the paper).
    pub fn scalar_mul(&mut self, a: NodeId, c: i64) -> NodeId {
        self.check(a);
        self.push(Node::ScalarMul(a, c))
    }

    /// Sum of many nodes (0 PBS; evaluated exactly like `FheContext::sum`).
    pub fn sum(&mut self, xs: &[NodeId]) -> NodeId {
        assert!(!xs.is_empty(), "sum of zero nodes");
        for &x in xs {
            self.check(x);
        }
        self.push(Node::Sum(xs.to_vec()))
    }

    // ----- PBS nodes (1 PBS each) -----

    /// Register a univariate signed function; the returned [`LutRef`] can
    /// feed any number of [`CircuitBuilder::pbs`] nodes.
    pub fn lut<F: Fn(i64) -> i64 + Send + Sync + 'static>(&mut self, f: F) -> LutRef {
        self.luts.push(Arc::new(f));
        LutRef(self.luts.len() - 1)
    }

    /// Apply a registered LUT (1 PBS).
    pub fn pbs(&mut self, x: NodeId, lut: LutRef) -> NodeId {
        self.check(x);
        assert!(lut.0 < self.luts.len(), "LUT {} not registered", lut.0);
        self.push(Node::Pbs { input: x, lut })
    }

    /// Register-once lookup of a standard table.
    fn std_lut(&mut self, idx: usize, f: fn(i64) -> i64) -> LutRef {
        match self.std_luts[idx] {
            Some(l) => l,
            None => {
                let l = self.lut(f);
                self.std_luts[idx] = Some(l);
                l
            }
        }
    }

    /// ReLU x⁺ (1 PBS).
    pub fn relu(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_RELU, |v| v.max(0));
        self.pbs(x, lut)
    }

    /// |x| (1 PBS).
    pub fn abs(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_ABS, |v: i64| v.abs());
        self.pbs(x, lut)
    }

    /// Negative ReLU x⁻ = min(x, 0) (1 PBS) — the signed inhibitor's
    /// value-split table (paper eq. 11).
    pub fn min0(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_MIN0, |v: i64| v.min(0));
        self.pbs(x, lut)
    }

    /// floor(x²/4) (1 PBS) — the paper's eq. 2 table.
    pub fn square_quarter(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_SQ4, |v| (v * v).div_euclid(4));
        self.pbs(x, lut)
    }

    /// Identity noise refresh (1 PBS).
    pub fn refresh(&mut self, x: NodeId) -> NodeId {
        let lut = self.std_lut(STD_ID, |v| v);
        self.pbs(x, lut)
    }

    /// Register-once lookup of a requant table for `(m, kind)`.
    fn requant_lut(&mut self, m: FixedMult, kind: RequantKind) -> LutRef {
        let key = (m.mult, m.shift, kind);
        if let Some(&hit) = self.requant_luts.get(&key) {
            return hit;
        }
        let lut = match kind {
            RequantKind::Plain => self.lut(move |x| m.apply(x)),
            RequantKind::Relu => self.lut(move |x| m.apply(x).max(0)),
            RequantKind::Min0 => self.lut(move |x| m.apply(x).min(0)),
        };
        self.requant_luts.insert(key, lut);
        lut
    }

    /// Fixed-point requantization `x ↦ round(x·m)` (1 PBS) — the
    /// accumulator→activation rescale of quantized linear layers
    /// ([`crate::quant::FixedMult::apply`], bit-identical to the
    /// plaintext model's requant). Tables are registered once per
    /// distinct factor, so identical requants across the layers of one
    /// plan share a `LutRef`.
    pub fn requant(&mut self, x: NodeId, m: FixedMult) -> NodeId {
        let lut = self.requant_lut(m, RequantKind::Plain);
        self.pbs(x, lut)
    }

    /// Fused `relu(round(x·m))` (1 PBS): the requant + ReLU of an FFN
    /// hidden layer in one table, and the positive half of a
    /// requant-folded signed value split. Evaluating the composition in
    /// one bootstrap instead of two both halves the depth and puts the
    /// split on the *accumulator* node — the same input the plain
    /// requant reads — which is what lets the packing pass fuse
    /// requant + ReLU + negative-split groups of three distinct tables
    /// into one blind rotation at a ϑ ≥ 2 budget.
    pub fn requant_relu(&mut self, x: NodeId, m: FixedMult) -> NodeId {
        let lut = self.requant_lut(m, RequantKind::Relu);
        self.pbs(x, lut)
    }

    /// Fused `min(round(x·m), 0)` (1 PBS): the negative half of a
    /// requant-folded signed value split (see
    /// [`CircuitBuilder::requant_relu`]).
    pub fn requant_min0(&mut self, x: NodeId, m: FixedMult) -> NodeId {
        let lut = self.requant_lut(m, RequantKind::Min0);
        self.pbs(x, lut)
    }

    /// Ciphertext × ciphertext via the paper's eq. 1 (2 PBS):
    /// `ab = PBS(x²/4; a+b) − PBS(x²/4; a−b)`.
    pub fn ct_mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let s = self.add(a, b);
        let d = self.sub(a, b);
        let p1 = self.square_quarter(s);
        let p2 = self.square_quarter(d);
        self.sub(p1, p2)
    }

    /// Mark a node as a circuit output (in call order).
    pub fn output(&mut self, id: NodeId) {
        self.check(id);
        self.outputs.push(id);
    }

    /// Declare that `id`'s value needs `bits` bits of accumulator width.
    /// Widths at or below the executing set's native message space are
    /// free annotations (legalization is a no-op); wider declarations
    /// make the radix pass split the node — and everything it feeds —
    /// into message-space limbs. Re-declaring keeps the widest request.
    pub fn declare_width(&mut self, id: NodeId, bits: u32) {
        self.check(id);
        assert!((1..=32).contains(&bits), "declared width must be 1..=32 bits, got {bits}");
        let w = self.widths.entry(id).or_insert(bits);
        *w = (*w).max(bits);
    }

    /// Finalize: runs the leveling pass and freezes the DAG.
    pub fn build(self) -> CircuitPlan {
        let mut plan =
            CircuitPlan::from_parts(self.nodes, self.luts, self.n_inputs, self.outputs);
        plan.widths = self.widths;
        plan
    }
}

impl Default for CircuitBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A finalized circuit plan: the DAG, its LUT registry, and the result of
/// the leveling pass.
pub struct CircuitPlan {
    nodes: Vec<Node>,
    luts: Vec<LutFn>,
    n_inputs: usize,
    outputs: Vec<NodeId>,
    /// Per-node bootstrap depth (see [`CircuitBuilder::build`]).
    levels: Vec<usize>,
    /// Per-node consumer count (operand reads + output listings) — the
    /// executor's liveness information.
    uses: Vec<u32>,
    max_level: usize,
    /// Declared accumulator widths awaiting legalization (cleared once
    /// the radix pass has rewritten the plan; remapped in place by
    /// CSE/packing so a declared plan survives any pass order).
    widths: HashMap<NodeId, u32>,
    /// Set by the radix legalization pass: how wide values were split
    /// into limbs and which outputs now span `spec.limbs` slots.
    radix: Option<super::radix::RadixInfo>,
}

impl CircuitPlan {
    /// Freeze a node list into an analyzed plan: the leveling pass
    /// assigns every node its bootstrap depth — 0 for inputs and
    /// constants, max over operands for linear nodes, operand level + 1
    /// for (multi-)PBS nodes, and the owning bootstrap's level for
    /// `MultiOut` projections. Nodes are topological, so one forward
    /// scan suffices. The same scan records each node's consumer count
    /// (+1 per output listing) so the executor can free intermediate
    /// ciphertexts after their last read instead of holding the whole
    /// DAG live. Both `CircuitBuilder::build` and the rewriter feed
    /// through here, so rewritten plans carry fresh analysis.
    pub(crate) fn from_parts(
        nodes: Vec<Node>,
        luts: Vec<LutFn>,
        n_inputs: usize,
        outputs: Vec<NodeId>,
    ) -> CircuitPlan {
        let mut levels = vec![0usize; nodes.len()];
        let mut uses = vec![0u32; nodes.len()];
        let mut max_level = 0usize;
        for (id, node) in nodes.iter().enumerate() {
            let lvl = match node {
                Node::Input(_) | Node::Const(_) => 0,
                Node::Add(a, b) | Node::Sub(a, b) => {
                    uses[*a] += 1;
                    uses[*b] += 1;
                    levels[*a].max(levels[*b])
                }
                Node::Neg(a) | Node::AddConst(a, _) | Node::ScalarMul(a, _) => {
                    uses[*a] += 1;
                    levels[*a]
                }
                Node::Sum(xs) => {
                    let mut lvl = 0;
                    for &x in xs {
                        uses[x] += 1;
                        lvl = lvl.max(levels[x]);
                    }
                    lvl
                }
                Node::Pbs { input, .. } | Node::MultiPbs { input, .. } => {
                    uses[*input] += 1;
                    levels[*input] + 1
                }
                Node::MultiOut { multi, .. } => {
                    uses[*multi] += 1;
                    levels[*multi]
                }
            };
            levels[id] = lvl;
            max_level = max_level.max(lvl);
        }
        for &out in &outputs {
            uses[out] += 1;
        }
        CircuitPlan {
            nodes,
            luts,
            n_inputs,
            outputs,
            levels,
            uses,
            max_level,
            widths: HashMap::new(),
            radix: None,
        }
    }

    /// Decompose into the rewriter's working set (nodes, LUT registry,
    /// input count, outputs); analysis is recomputed on reassembly.
    pub(crate) fn into_parts(self) -> (Vec<Node>, Vec<LutFn>, usize, Vec<NodeId>) {
        (self.nodes, self.luts, self.n_inputs, self.outputs)
    }

    /// Number of circuit input ciphertexts.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Radix legalization record, when the rewriter widened this plan:
    /// the limb spec plus which outputs now occupy `spec.limbs` slots.
    pub fn radix(&self) -> Option<&super::radix::RadixInfo> {
        self.radix.as_ref()
    }

    /// Declared accumulator widths not yet legalized (empty after the
    /// radix pass runs, and on plans that never declared any).
    pub fn declared_widths(&self) -> &HashMap<NodeId, u32> {
        &self.widths
    }

    /// Order-sensitive structural fingerprint of the DAG (nodes with
    /// commutative operand order normalized, LUTs by registry index),
    /// ignoring the analysis tables. Tests pin "legalization is a no-op
    /// when the declared width fits the native space" by hash equality.
    pub fn structural_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.n_inputs.hash(&mut h);
        self.outputs.hash(&mut h);
        for node in &self.nodes {
            node_key(node).hash(&mut h);
        }
        h.finish()
    }

    /// Number of circuit outputs.
    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Total LUT evaluations of one execution — the paper's cost unit,
    /// derived from the same DAG the executor runs. A `MultiPbs` node
    /// counts one per packed LUT, so packing never changes this number
    /// (it changes [`CircuitPlan::blind_rotation_count`]).
    pub fn pbs_count(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Pbs { .. } => 1,
                Node::MultiPbs { luts, .. } => luts.len() as u64,
                _ => 0,
            })
            .sum()
    }

    /// Total blind rotations of one execution: 1 per `Pbs` node and 1
    /// per `MultiPbs` node regardless of its group size. Equal to
    /// `pbs_count()` on unpacked plans; strictly smaller wherever the
    /// packing rewrite formed a group.
    pub fn blind_rotation_count(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Pbs { .. } | Node::MultiPbs { .. }))
            .count() as u64
    }

    /// Number of PBS execution levels (batched rounds).
    pub fn levels(&self) -> usize {
        self.max_level
    }

    /// Sizes of the packed multi-value groups in this plan (one entry
    /// per `MultiPbs` node, in node order); empty on unpacked plans.
    /// Tests use this to assert that a ϑ ≥ 2 budget actually formed a
    /// group of ≥ 3 distinct tables on one input.
    pub fn multi_group_sizes(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::MultiPbs { luts, .. } => Some(luts.len()),
                _ => None,
            })
            .collect()
    }

    /// Bootstrap jobs per level (one per `Pbs` or `MultiPbs` node),
    /// index 0 = level 1. Sums to `blind_rotation_count()` — which is
    /// `pbs_count()` on unpacked plans.
    pub fn level_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.max_level];
        for (id, node) in self.nodes.iter().enumerate() {
            if matches!(node, Node::Pbs { .. } | Node::MultiPbs { .. }) {
                sizes[self.levels[id] - 1] += 1;
            }
        }
        sizes
    }

    /// PBS-free homomorphic ops of one execution (`Sum` of k operands
    /// counts its k − 1 additions), for the optimizer's linear-cost term.
    /// `MultiOut` projections are free (the extraction happens inside
    /// the shared bootstrap).
    pub fn linear_op_count(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Input(_)
                | Node::Const(_)
                | Node::Pbs { .. }
                | Node::MultiPbs { .. }
                | Node::MultiOut { .. } => 0,
                Node::Sum(xs) => xs.len() as u64 - 1,
                _ => 1,
            })
            .sum()
    }

    /// Execute the plan: one batched PBS submission per level through the
    /// context's worker pool, linear ops evaluated between levels.
    pub fn execute(&self, ctx: &FheContext, inputs: &[CtInt]) -> Vec<CtInt> {
        let refs: Vec<&CtInt> = inputs.iter().collect();
        self.execute_ref(ctx, &refs)
    }

    /// [`Self::execute`] over borrowed inputs — the zero-copy hot path:
    /// input ciphertexts are never cloned into the run's value table (a
    /// PBS node reading an input *directly* still clones that one
    /// operand into its job, since jobs own their ciphertext). Callers
    /// holding inputs scattered across structures (e.g. the Q/K/V
    /// matrices of an attention head) pass references instead of first
    /// assembling an owned 3·T·d vector.
    pub fn execute_ref(&self, ctx: &FheContext, inputs: &[&CtInt]) -> Vec<CtInt> {
        let mut run = PlanRun::new_ref(self, ctx, inputs);
        while let Some(jobs) = run.next_jobs(ctx) {
            let outs = ctx.pbs_level(&jobs);
            run.supply(outs);
        }
        run.finish(ctx)
    }
}

/// One bootstrap job of a plan level, as handed out by
/// [`PlanRun::next_level_jobs`]: the input ciphertext plus the prepared
/// accumulator, single-LUT or packed. Results go back through
/// [`PlanRun::supply`] flattened in job order (a multi job contributes
/// [`LevelJob::n_outputs`] consecutive ciphertexts in packing order).
pub enum LevelJob {
    Single(CtInt, Arc<PreparedLut>),
    Multi(CtInt, Arc<PreparedMultiLut>),
}

impl LevelJob {
    /// Ciphertexts this job produces.
    pub fn n_outputs(&self) -> usize {
        self.as_batch_job().n_outputs()
    }

    /// Borrow as a worker-pool job for `ServerKey::pbs_batch_mixed`.
    pub fn as_batch_job(&self) -> BatchJob<'_> {
        match self {
            LevelJob::Single(ct, lut) => BatchJob::Single(&ct.ct, lut),
            LevelJob::Multi(ct, mlut) => BatchJob::Multi(&ct.ct, mlut),
        }
    }
}

/// One in-flight execution of a plan, advanced level by level: call
/// [`PlanRun::next_level_jobs`] to obtain the current level's PBS jobs,
/// run them (any way you like — this is the coordinator's fusion seam),
/// hand the results back via [`PlanRun::supply`], repeat until `None`,
/// then [`PlanRun::finish`].
pub struct PlanRun<'p> {
    plan: &'p CircuitPlan,
    /// The circuit inputs, borrowed for the run's lifetime. Input nodes
    /// resolve through this table instead of being cloned into `values`
    /// up front — the by-ref hot path (`CircuitPlan::execute_ref`).
    inputs: Vec<&'p CtInt>,
    values: Vec<Option<CtInt>>,
    /// Whether a node has been computed (its value may since have been
    /// freed once every consumer read it).
    evaluated: Vec<bool>,
    /// Consumer reads left per node; at 0 the value is dropped, so peak
    /// residency tracks the live frontier, not the whole DAG.
    remaining: Vec<u32>,
    /// LUT registry resolved against the executing context
    /// (cache-backed). `None` for tables no `Pbs` node references —
    /// after packing, a table may live only inside a `MultiPbs`
    /// accumulator, and building its unused single-LUT accumulator
    /// would waste memory and first-run latency.
    resolved: Vec<Option<Arc<PreparedLut>>>,
    /// Packed accumulators per `MultiPbs` node (cache-backed likewise).
    multi_accs: HashMap<NodeId, Arc<PreparedMultiLut>>,
    /// `MultiOut` node ids per `MultiPbs` node, indexed by output slot —
    /// where `supply` scatters a multi job's results.
    multi_members: HashMap<NodeId, Vec<NodeId>>,
    /// Next PBS level to execute (1-based).
    current: usize,
    /// `Pbs`/`MultiPbs` node ids whose jobs were handed out and await
    /// `supply`.
    pending: Vec<NodeId>,
}

impl<'p> PlanRun<'p> {
    pub fn new(plan: &'p CircuitPlan, ctx: &FheContext, inputs: &'p [CtInt]) -> Self {
        let refs: Vec<&'p CtInt> = inputs.iter().collect();
        Self::new_ref(plan, ctx, &refs)
    }

    /// [`Self::new`] over borrowed inputs (see
    /// [`CircuitPlan::execute_ref`]): only the *references* are copied
    /// into the run, never the ciphertexts.
    pub fn new_ref(plan: &'p CircuitPlan, ctx: &FheContext, inputs: &[&'p CtInt]) -> Self {
        assert_eq!(inputs.len(), plan.n_inputs, "plan expects {} inputs", plan.n_inputs);
        let mut single_use = vec![false; plan.luts.len()];
        for node in &plan.nodes {
            if let Node::Pbs { lut, .. } = node {
                single_use[lut.0] = true;
            }
        }
        let resolved = plan
            .luts
            .iter()
            .zip(&single_use)
            .map(|(f, &used)| used.then(|| ctx.prepared_dyn(f.as_ref())))
            .collect();
        let mut multi_accs = HashMap::new();
        let mut multi_members: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
        let mut values: Vec<Option<CtInt>> = plan.nodes.iter().map(|_| None).collect();
        let mut evaluated = vec![false; plan.nodes.len()];
        for (id, node) in plan.nodes.iter().enumerate() {
            match node {
                // Inputs resolve from the borrowed table; nothing stored.
                Node::Input(_) => {}
                Node::Const(v) => values[id] = Some(ctx.constant(*v)),
                Node::MultiPbs { luts, .. } => {
                    let fns: Vec<&dyn Fn(i64) -> i64> = luts
                        .iter()
                        .map(|l| {
                            let f: &(dyn Fn(i64) -> i64) = plan.luts[l.0].as_ref();
                            f
                        })
                        .collect();
                    multi_accs.insert(id, ctx.prepared_multi_dyn(&fns));
                    multi_members.insert(id, vec![usize::MAX; luts.len()]);
                    continue;
                }
                Node::MultiOut { multi, index } => {
                    let slots =
                        multi_members.get_mut(multi).expect("MultiOut before its MultiPbs");
                    slots[*index] = id;
                    continue;
                }
                _ => continue,
            }
            evaluated[id] = true;
        }
        // Hard assert (runs once per PlanRun): a rewrite pass that drops
        // a projection would otherwise surface as an opaque out-of-bounds
        // on the sentinel at supply() time.
        assert!(
            multi_members.values().all(|m| m.iter().all(|&id| id != usize::MAX)),
            "every MultiPbs output slot must have a MultiOut projection"
        );
        PlanRun {
            plan,
            inputs: inputs.to_vec(),
            values,
            evaluated,
            remaining: plan.uses.clone(),
            resolved,
            multi_accs,
            multi_members,
            current: 1,
            pending: Vec::new(),
        }
    }

    fn value(&self, i: NodeId) -> &CtInt {
        if let Node::Input(ix) = &self.plan.nodes[i] {
            return self.inputs[*ix];
        }
        self.values[i].as_ref().expect("operand live (topological order + use counts)")
    }

    /// Record one consumer read of `i`; free the value after the last.
    fn release(&mut self, i: NodeId) {
        self.remaining[i] -= 1;
        if self.remaining[i] == 0 {
            self.values[i] = None;
        }
    }

    /// One consumer read of `i` that needs an *owned* ciphertext (a
    /// bootstrap job input or a plan output). The last read moves the
    /// stored value out instead of cloning it; earlier reads clone.
    /// Borrowed circuit inputs are cloned only here — once per bootstrap
    /// job that reads an input directly — never en masse.
    fn consume(&mut self, i: NodeId) -> CtInt {
        self.remaining[i] -= 1;
        if self.remaining[i] == 0 {
            if let Some(v) = self.values[i].take() {
                return v;
            }
        }
        if let Node::Input(ix) = &self.plan.nodes[i] {
            return self.inputs[*ix].clone();
        }
        self.values[i].clone().expect("operand live (topological order + use counts)")
    }

    /// Whether node `i` can serve as an operand right now: computed (its
    /// value may live in `values`) or a circuit input (resolved from the
    /// borrowed input table).
    fn operand_ready(&self, i: NodeId) -> bool {
        self.evaluated[i] || matches!(self.plan.nodes[i], Node::Input(_))
    }

    /// Readiness of a *linear* node: every operand materialized. Always
    /// false for inputs/constants/bootstrap nodes (they are filled by
    /// `new_ref` or `supply`, never computed here).
    fn operands_ready(&self, id: NodeId) -> bool {
        match &self.plan.nodes[id] {
            Node::Add(a, b) | Node::Sub(a, b) => {
                self.operand_ready(*a) && self.operand_ready(*b)
            }
            Node::Neg(a) | Node::AddConst(a, _) | Node::ScalarMul(a, _) => {
                self.operand_ready(*a)
            }
            Node::Sum(xs) => xs.iter().all(|&x| self.operand_ready(x)),
            _ => false,
        }
    }

    /// Evaluate every not-yet-evaluated linear node that is eligible:
    /// with `bound = Some(b)`, every node of level < `b` (the leveling
    /// pass — eligibility known from the level map alone); with `bound =
    /// None`, every node whose operands are materialized (the wavefront
    /// pass — eligibility read off the dataflow). Ids are topological,
    /// so a single in-order pass sees all operands (earlier linear nodes
    /// this pass, PBS results from prior waves) and reaches the fixpoint
    /// either way.
    fn eval_linear(&mut self, ctx: &FheContext, bound: Option<usize>) {
        for id in 0..self.plan.nodes.len() {
            let skip = match bound {
                Some(b) => self.plan.levels[id] >= b,
                None => !self.operands_ready(id),
            };
            if self.evaluated[id] || skip {
                continue;
            }
            // Operand refs live in the plan (`&'p`), so computing the
            // value and releasing the operands can interleave freely
            // with `&mut self` bookkeeping.
            let v = match &self.plan.nodes[id] {
                Node::Input(_) | Node::Const(_) => continue, // prefilled
                // Bootstrap results (including multi projections) are
                // supplied per level, not computed here.
                Node::Pbs { .. } | Node::MultiPbs { .. } | Node::MultiOut { .. } => continue,
                Node::Add(a, b) => {
                    let v = ctx.add(self.value(*a), self.value(*b));
                    self.release(*a);
                    self.release(*b);
                    v
                }
                Node::Sub(a, b) => {
                    let v = ctx.sub(self.value(*a), self.value(*b));
                    self.release(*a);
                    self.release(*b);
                    v
                }
                Node::Neg(a) => {
                    let v = ctx.neg(self.value(*a));
                    self.release(*a);
                    v
                }
                Node::AddConst(a, c) => {
                    let v = ctx.add_const(self.value(*a), *c);
                    self.release(*a);
                    v
                }
                Node::ScalarMul(a, c) => {
                    let v = ctx.scalar_mul(self.value(*a), *c);
                    self.release(*a);
                    v
                }
                Node::Sum(xs) => {
                    let refs: Vec<&CtInt> = xs.iter().map(|&x| self.value(x)).collect();
                    let v = ctx.sum_refs(&refs);
                    drop(refs);
                    for &x in xs {
                        self.release(x);
                    }
                    v
                }
            };
            self.values[id] = Some(v);
            self.evaluated[id] = true;
        }
    }

    /// The next level's bootstrap jobs, or `None` once every PBS level
    /// has been supplied. Jobs are in node-id order; results must come
    /// back in the same order, flattened (a [`LevelJob::Multi`]
    /// contributes its LUT count of consecutive outputs).
    /// Number of PBS levels fully executed (supplied) so far. After a
    /// cooperative abandonment — deadline or cancellation at a level
    /// boundary — this is strictly less than [`CircuitPlan::levels`],
    /// which is how tests pin that work was actually skipped.
    pub fn levels_done(&self) -> usize {
        self.current - 1
    }

    pub fn next_level_jobs(&mut self, ctx: &FheContext) -> Option<Vec<LevelJob>> {
        assert!(self.pending.is_empty(), "previous level awaits supply()");
        if self.current > self.plan.max_level {
            return None;
        }
        self.eval_linear(ctx, Some(self.current));
        let mut jobs = Vec::new();
        for (id, node) in self.plan.nodes.iter().enumerate() {
            if self.plan.levels[id] != self.current {
                continue;
            }
            match node {
                Node::Pbs { input, lut } => {
                    let ct = self.consume(*input);
                    let acc = self.resolved[lut.0]
                        .as_ref()
                        .expect("LUT resolved (referenced by a Pbs node)");
                    jobs.push(LevelJob::Single(ct, Arc::clone(acc)));
                    self.pending.push(id);
                }
                Node::MultiPbs { input, .. } => {
                    let ct = self.consume(*input);
                    jobs.push(LevelJob::Multi(ct, Arc::clone(&self.multi_accs[&id])));
                    self.pending.push(id);
                }
                _ => {}
            }
        }
        Some(jobs)
    }

    /// Readiness-driven counterpart of [`Self::next_level_jobs`]: hand
    /// out every bootstrap whose operand ciphertext is materialized,
    /// without consulting the level map. Linear nodes are folded forward
    /// first, so a bootstrap becomes ready the moment the linear chain
    /// feeding it resolves. Because a node's level is its exact
    /// bootstrap dependency depth, the ready set at each wave boundary
    /// *equals* the level set — waves and levels advance in lockstep and
    /// the two steppers are bit-identical with identical counter deltas
    /// (`levels_done`, `supply`, and `finish` keep their semantics
    /// unchanged). What wavefront mode buys is at the pool layer: its
    /// ticks are the submission points for the work-stealing cross-key
    /// pool, where idle workers steal instead of parking at barriers.
    pub fn next_wave_jobs(&mut self, ctx: &FheContext) -> Option<Vec<LevelJob>> {
        assert!(self.pending.is_empty(), "previous wave awaits supply()");
        if self.current > self.plan.max_level {
            return None;
        }
        self.eval_linear(ctx, None);
        let mut jobs = Vec::new();
        for (id, node) in self.plan.nodes.iter().enumerate() {
            if self.evaluated[id] {
                continue;
            }
            match node {
                Node::Pbs { input, lut } if self.operand_ready(*input) => {
                    let ct = self.consume(*input);
                    let acc = self.resolved[lut.0]
                        .as_ref()
                        .expect("LUT resolved (referenced by a Pbs node)");
                    jobs.push(LevelJob::Single(ct, Arc::clone(acc)));
                    self.pending.push(id);
                }
                Node::MultiPbs { input, .. } if self.operand_ready(*input) => {
                    let ct = self.consume(*input);
                    jobs.push(LevelJob::Multi(ct, Arc::clone(&self.multi_accs[&id])));
                    self.pending.push(id);
                }
                _ => {}
            }
        }
        Some(jobs)
    }

    /// Mode-aware stepping: wavefront readiness when
    /// [`wavefront_enabled`] (the default), legacy level barriers under
    /// `FHE_WAVEFRONT=0`. Executors drive this so one knob A/Bs the two
    /// dispatch modes end to end.
    pub fn next_jobs(&mut self, ctx: &FheContext) -> Option<Vec<LevelJob>> {
        if wavefront_enabled() {
            self.next_wave_jobs(ctx)
        } else {
            self.next_level_jobs(ctx)
        }
    }

    /// Hand back the results of the jobs returned by the last
    /// [`PlanRun::next_level_jobs`] call (same order, flattened) and
    /// advance. A multi job's outputs scatter to its `MultiOut`
    /// projections in packing order.
    pub fn supply(&mut self, outs: Vec<CtInt>) {
        let expect: usize = self
            .pending
            .iter()
            .map(|&id| match &self.plan.nodes[id] {
                Node::Pbs { .. } => 1,
                Node::MultiPbs { luts, .. } => luts.len(),
                _ => unreachable!("pending holds only bootstrap nodes"),
            })
            .sum();
        assert_eq!(outs.len(), expect, "level result count mismatch");
        let pending = std::mem::take(&mut self.pending);
        let mut outs = outs.into_iter();
        for id in pending {
            match &self.plan.nodes[id] {
                Node::Pbs { .. } => {
                    self.values[id] = Some(outs.next().expect("counted above"));
                    self.evaluated[id] = true;
                }
                Node::MultiPbs { luts, .. } => {
                    for slot in 0..luts.len() {
                        let member = self.multi_members[&id][slot];
                        self.values[member] = Some(outs.next().expect("counted above"));
                        self.evaluated[member] = true;
                        // The projection's "read" of the tuple node
                        // happens right here — account for it so the
                        // liveness invariant (consumed ⇒ freed) holds
                        // for MultiPbs nodes too.
                        self.release(id);
                    }
                    self.evaluated[id] = true;
                }
                _ => unreachable!("pending holds only bootstrap nodes"),
            }
        }
        self.current += 1;
    }

    /// Evaluate the trailing linear nodes and return the outputs.
    pub fn finish(mut self, ctx: &FheContext) -> Vec<CtInt> {
        self.finish_in_place(ctx)
    }

    /// [`Self::finish`] without consuming the run (tests use this to
    /// inspect liveness bookkeeping after completion).
    fn finish_in_place(&mut self, ctx: &FheContext) -> Vec<CtInt> {
        assert!(
            self.current > self.plan.max_level && self.pending.is_empty(),
            "finish() before all PBS levels were executed"
        );
        self.eval_linear(ctx, Some(self.plan.max_level + 1));
        // Each output listing holds one accounted use; consuming it moves
        // the last copy out (no terminal clone unless a node is listed as
        // an output more than once or still has other readers).
        let plan = self.plan;
        plan.outputs.iter().map(|&id| self.consume(id)).collect()
    }
}

// ---------------------------------------------------------------------
// Rewrite passes
// ---------------------------------------------------------------------

/// The `FHE_NO_REWRITE` escape hatch: when the variable is set to
/// anything but `0` or the empty string, the cached `plan_for`-style
/// entry points (every head's `forward()` and the serving engines) skip
/// the rewrite pipeline and execute raw builder plans. This is the CI
/// matrix leg that proves the unrewritten pipeline still serves every
/// circuit bit-identically. Explicit [`PlanRewriter`] invocations ignore
/// the knob — tests drive both configurations side by side regardless of
/// the environment.
pub fn rewrites_disabled() -> bool {
    match std::env::var("FHE_NO_REWRITE") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0"
        }
        Err(_) => false,
    }
}

/// Programmatic override for [`wavefront_enabled`]: `0` = defer to the
/// environment, `1` = force legacy barriers, `2` = force wavefront.
/// A process-global atomic rather than `std::env::set_var` because the
/// latter is racy in multithreaded test binaries — in-process A/B tests
/// flip this instead.
static WAVEFRONT_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force (`Some(true)` / `Some(false)`) or clear (`None`) the dispatch
/// mode, overriding `FHE_WAVEFRONT`. Tests that A/B the two steppers in
/// one process use this; whole-process selection (the CI legs) uses the
/// environment variable.
pub fn set_wavefront_dispatch(mode: Option<bool>) {
    let v = match mode {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    WAVEFRONT_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The `FHE_WAVEFRONT` dispatch knob. Wavefront (readiness-driven)
/// dispatch is the **default**; setting the variable to `0` (or empty)
/// selects the legacy level-barrier stepper — the CI matrix leg that
/// keeps both modes green. [`set_wavefront_dispatch`] takes precedence
/// over the environment when armed.
pub fn wavefront_enabled() -> bool {
    match WAVEFRONT_OVERRIDE.load(Ordering::Relaxed) {
        1 => return false,
        2 => return true,
        _ => {}
    }
    match std::env::var("FHE_WAVEFRONT") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0"
        }
        Err(_) => true,
    }
}

/// Configuration of the [`PlanRewriter`] pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RewriteConfig {
    /// Run common-subexpression elimination.
    pub cse: bool,
    /// Largest same-input LUT group the packing pass may fuse into one
    /// `MultiPbs` (1 disables packing). Must not exceed the executing
    /// parameter set's [`TfheParams::max_multi_lut`] budget — the
    /// executor asserts this when resolving the packed accumulator.
    ///
    /// [`TfheParams::max_multi_lut`]: super::params::TfheParams::max_multi_lut
    pub max_multi_lut: usize,
}

impl RewriteConfig {
    /// Everything off — `rewrite` returns the plan unchanged.
    pub fn none() -> Self {
        RewriteConfig { cse: false, max_multi_lut: 1 }
    }

    /// CSE only (parameter-independent: merges are ciphertext-exact on
    /// every set, so this is always safe).
    pub fn cse_only() -> Self {
        RewriteConfig { cse: true, max_multi_lut: 1 }
    }

    /// The full pipeline at the budget a parameter set advertises.
    pub fn for_params(params: &super::params::TfheParams) -> Self {
        RewriteConfig { cse: true, max_multi_lut: params.max_multi_lut() }
    }
}

/// What one rewrite did — pinned by the rewrite test harness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RewriteStats {
    /// Duplicate nodes removed by CSE.
    pub cse_merged: usize,
    /// `MultiPbs` groups formed by the packing pass.
    pub multi_groups: usize,
    /// `Pbs` nodes folded into those groups (≥ 2 per group).
    pub packed_luts: usize,
    /// Narrow sources the radix pass decomposed into limb vectors.
    pub radix_widened: usize,
    /// Limb count of the radix spec the pass legalized against (0 when
    /// the pass did not fire).
    pub radix_limbs: usize,
    /// Carry-propagation LUT evaluations the radix pass emitted
    /// (message/carry/top-wrap tables; decomposition digit LUTs are
    /// ordinary `Pbs` nodes counted by the plan oracles).
    pub carry_luts: u64,
    /// Blind rotations those carry LUTs cost after packing: the message
    /// and carry table of one limb read the same input, so they share a
    /// rotation whenever the budget allows ϑ ≥ 1 groups.
    pub carry_rotations: u64,
}

/// Ordered rewrite pipeline over [`CircuitPlan`]s: radix legalization
/// first (declared-wide nodes become limb vectors, so the passes behind
/// it see only native-width nodes), then CSE (so duplicate `Pbs` nodes
/// collapse instead of wasting packing slots), then multi-value packing —
/// which is what turns the legalizer's same-input digit and carry tables
/// into shared blind rotations. Rewritten plans go through the same
/// leveling pass as freshly built ones, so every consumer of the IR —
/// `execute`, the fused executor, the optimizer profile, the benches —
/// picks the rewrites up transparently. Running the pipeline twice is a
/// no-op (pinned by tests).
pub struct PlanRewriter {
    cfg: RewriteConfig,
    /// Radix legalization config; `None` skips the pass entirely (plans
    /// with declared widths keep them, un-legalized).
    radix: Option<super::radix::RadixConfig>,
}

impl PlanRewriter {
    pub fn new(cfg: RewriteConfig) -> Self {
        PlanRewriter { cfg, radix: None }
    }

    /// Enable radix legalization against `rcfg`'s native message space.
    pub fn with_radix(mut self, rcfg: super::radix::RadixConfig) -> Self {
        self.radix = Some(rcfg);
        self
    }

    /// Pipeline at the executing context's parameter budget, radix
    /// legalization armed at the set's native message width (so plans
    /// without declared widths are untouched, and declared-wide plans
    /// legalize against the space they will actually execute in).
    pub fn for_ctx(ctx: &FheContext) -> Self {
        Self::new(RewriteConfig::for_params(&ctx.sk.params))
            .with_radix(super::radix::RadixConfig::for_params(&ctx.sk.params))
    }

    pub fn config(&self) -> RewriteConfig {
        self.cfg
    }

    /// Run the configured passes, returning the rewritten plan and what
    /// changed.
    pub fn rewrite(&self, mut plan: CircuitPlan) -> (CircuitPlan, RewriteStats) {
        let mut stats = RewriteStats::default();
        let prev_radix = plan.radix.take();
        let mut widths = std::mem::take(&mut plan.widths);
        let (mut nodes, mut luts, n_inputs, mut outputs) = plan.into_parts();
        let radix_info = match &self.radix {
            Some(rcfg) if !widths.is_empty() => radix_pass(
                &mut nodes,
                &mut luts,
                &mut outputs,
                &widths,
                rcfg,
                self.cfg.max_multi_lut.max(1),
                &mut stats,
            ),
            _ => None,
        };
        if radix_info.is_some() {
            // The declared widths are satisfied; a second rewrite must
            // not re-legalize the limb nodes (idempotence).
            widths.clear();
        }
        if self.cfg.cse {
            cse_pass(&mut nodes, &mut outputs, &mut widths, &mut stats);
        }
        if self.cfg.max_multi_lut > 1 {
            pack_pass(&mut nodes, &mut outputs, &mut widths, self.cfg.max_multi_lut, &mut stats);
        }
        let mut out = CircuitPlan::from_parts(nodes, luts, n_inputs, outputs);
        out.widths = widths;
        out.radix = radix_info.or(prev_radix);
        (out, stats)
    }
}

/// Structural identity key of a node, with commutative operand order
/// normalized away (`Add`/`Sum` are wrapping torus additions, so operand
/// order cannot change a single ciphertext bit). `Pbs` keys carry the
/// LUT registry index: two nodes merge only when they reference the
/// *same registered table* — never across distinct tables.
#[derive(Clone, Hash, PartialEq, Eq)]
enum NodeKey {
    Input(usize),
    Const(i64),
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Neg(NodeId),
    AddConst(NodeId, i64),
    ScalarMul(NodeId, i64),
    Sum(Vec<NodeId>),
    Pbs(NodeId, usize),
    MultiPbs(NodeId, Vec<usize>),
    MultiOut(NodeId, usize),
}

fn node_key(node: &Node) -> NodeKey {
    match node {
        Node::Input(i) => NodeKey::Input(*i),
        Node::Const(v) => NodeKey::Const(*v),
        Node::Add(a, b) => NodeKey::Add(*a.min(b), *a.max(b)),
        Node::Sub(a, b) => NodeKey::Sub(*a, *b),
        Node::Neg(a) => NodeKey::Neg(*a),
        Node::AddConst(a, c) => NodeKey::AddConst(*a, *c),
        Node::ScalarMul(a, c) => NodeKey::ScalarMul(*a, *c),
        Node::Sum(xs) => {
            let mut sorted = xs.clone();
            sorted.sort_unstable();
            NodeKey::Sum(sorted)
        }
        Node::Pbs { input, lut } => NodeKey::Pbs(*input, lut.0),
        Node::MultiPbs { input, luts } => {
            NodeKey::MultiPbs(*input, luts.iter().map(|l| l.0).collect())
        }
        Node::MultiOut { multi, index } => NodeKey::MultiOut(*multi, *index),
    }
}

/// Clone `node` with every operand sent through `remap`.
fn remap_node(node: &Node, remap: &[NodeId]) -> Node {
    match node {
        Node::Input(i) => Node::Input(*i),
        Node::Const(v) => Node::Const(*v),
        Node::Add(a, b) => Node::Add(remap[*a], remap[*b]),
        Node::Sub(a, b) => Node::Sub(remap[*a], remap[*b]),
        Node::Neg(a) => Node::Neg(remap[*a]),
        Node::AddConst(a, c) => Node::AddConst(remap[*a], *c),
        Node::ScalarMul(a, c) => Node::ScalarMul(remap[*a], *c),
        Node::Sum(xs) => Node::Sum(xs.iter().map(|&x| remap[x]).collect()),
        Node::Pbs { input, lut } => Node::Pbs { input: remap[*input], lut: *lut },
        Node::MultiPbs { input, luts } => {
            Node::MultiPbs { input: remap[*input], luts: luts.clone() }
        }
        Node::MultiOut { multi, index } => {
            Node::MultiOut { multi: remap[*multi], index: *index }
        }
    }
}

/// Common-subexpression elimination: one forward scan (ids are
/// topological) that remaps operands and drops any node whose
/// canonicalized key was already seen. Because a duplicate's operands
/// were remapped to the survivor's first, chains of duplicates collapse
/// in a single pass, and the pass is idempotent.
fn cse_pass(
    nodes: &mut Vec<Node>,
    outputs: &mut [NodeId],
    widths: &mut HashMap<NodeId, u32>,
    stats: &mut RewriteStats,
) {
    let mut remap: Vec<NodeId> = Vec::with_capacity(nodes.len());
    let mut seen: HashMap<NodeKey, NodeId> = HashMap::with_capacity(nodes.len());
    let mut kept: Vec<Node> = Vec::with_capacity(nodes.len());
    for node in nodes.iter() {
        let node = remap_node(node, &remap);
        match seen.entry(node_key(&node)) {
            std::collections::hash_map::Entry::Occupied(hit) => {
                remap.push(*hit.get());
                stats.cse_merged += 1;
            }
            std::collections::hash_map::Entry::Vacant(slot) => {
                let id = kept.len();
                slot.insert(id);
                remap.push(id);
                kept.push(node);
            }
        }
    }
    for out in outputs.iter_mut() {
        *out = remap[*out];
    }
    remap_widths(widths, &remap);
    *nodes = kept;
}

/// Send pending width declarations through a pass's id remap (merged
/// declarations keep the widest request, matching `declare_width`).
fn remap_widths(widths: &mut HashMap<NodeId, u32>, remap: &[NodeId]) {
    if widths.is_empty() {
        return;
    }
    let old = std::mem::take(widths);
    for (id, w) in old {
        let e = widths.entry(remap[id]).or_insert(w);
        *e = (*e).max(w);
    }
}

/// Multi-value packing: group `Pbs` nodes by input ciphertext, split
/// each group into chunks of at most `max_multi`, and replace every
/// chunk of ≥ 2 with one `MultiPbs` (at the first member's position)
/// plus per-member `MultiOut` projections. Same input ⇒ same level
/// (a PBS level is its input's level + 1), so packing can never merge
/// across levels. Leftover singletons stay plain `Pbs`, which also
/// makes the pass idempotent: a second run finds only groups of one.
fn pack_pass(
    nodes: &mut Vec<Node>,
    outputs: &mut [NodeId],
    widths: &mut HashMap<NodeId, u32>,
    max_multi: usize,
    stats: &mut RewriteStats,
) {
    // Group members in node-id order.
    let mut groups: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
    for (id, node) in nodes.iter().enumerate() {
        if let Node::Pbs { input, .. } = node {
            groups.entry(*input).or_default().push(id);
        }
    }
    // member id -> (leader id, output slot); leader -> packed LUT list.
    let mut member_slot: HashMap<NodeId, (NodeId, usize)> = HashMap::new();
    let mut leader_luts: HashMap<NodeId, Vec<LutRef>> = HashMap::new();
    for members in groups.values() {
        for chunk in members.chunks(max_multi) {
            if chunk.len() < 2 {
                continue;
            }
            let luts: Vec<LutRef> = chunk
                .iter()
                .map(|&m| match &nodes[m] {
                    Node::Pbs { lut, .. } => *lut,
                    _ => unreachable!("group members are Pbs nodes"),
                })
                .collect();
            leader_luts.insert(chunk[0], luts);
            for (slot, &m) in chunk.iter().enumerate() {
                member_slot.insert(m, (chunk[0], slot));
            }
            stats.multi_groups += 1;
            stats.packed_luts += chunk.len();
        }
    }
    if leader_luts.is_empty() {
        return;
    }
    // Rebuild the node list: the leader position grows a MultiPbs right
    // before its own MultiOut, so every projection still follows the
    // bootstrap it reads (ids stay topological).
    let mut remap: Vec<NodeId> = Vec::with_capacity(nodes.len());
    let mut kept: Vec<Node> = Vec::with_capacity(nodes.len() + leader_luts.len());
    let mut multi_of_leader: HashMap<NodeId, NodeId> = HashMap::new();
    for (id, node) in nodes.iter().enumerate() {
        if let Some(&(leader, slot)) = member_slot.get(&id) {
            if leader == id {
                let input = match node {
                    Node::Pbs { input, .. } => remap[*input],
                    _ => unreachable!("leader is a Pbs node"),
                };
                multi_of_leader.insert(leader, kept.len());
                kept.push(Node::MultiPbs { input, luts: leader_luts[&leader].clone() });
            }
            remap.push(kept.len());
            kept.push(Node::MultiOut { multi: multi_of_leader[&leader], index: slot });
        } else {
            remap.push(kept.len());
            kept.push(remap_node(node, &remap));
        }
    }
    for out in outputs.iter_mut() {
        *out = remap[*out];
    }
    remap_widths(widths, &remap);
    *nodes = kept;
}

// ---------------------------------------------------------------------------
// Radix legalization (see rust/DESIGN.md §10)
// ---------------------------------------------------------------------------

/// A wide value mid-legalization: little-endian limb node ids plus the
/// bookkeeping the capacity discipline runs on. `bound` is an upper
/// bound on any limb's magnitude and must never exceed the spec's
/// `add_cap` — the ripple injects up to `carry_cap` into a limb before
/// its split LUTs fire, and the sum has to stay inside the native
/// message space the LUTs resolve.
#[derive(Clone)]
struct WideVal {
    limbs: Vec<NodeId>,
    bound: i64,
    /// Limbs are canonical digits (unsigned below a signed top limb).
    canonical: bool,
}

/// Working state of the radix pass: the new node list being built, the
/// shared LUT registry, register-once digit/carry tables, and the
/// per-old-node caches that make decomposition and carry propagation
/// happen at most once per value.
struct Legalizer<'a> {
    spec: super::radix::RadixSpec,
    /// Packing budget the enclosing pipeline will run with (≥ 1); only
    /// used to account `carry_rotations` — message + carry of one limb
    /// share a blind rotation whenever the budget allows pairs.
    budget: usize,
    nodes: Vec<Node>,
    luts: &'a mut Vec<LutFn>,
    /// Old id → new id for nodes that keep a narrow incarnation
    /// (`usize::MAX` placeholder for purely-wide linear nodes).
    remap: Vec<NodeId>,
    /// Old id → its wide form, once decomposed or computed. Doubles as
    /// the canonicalization cache: `canon_old` stores the rippled form
    /// back, so later consumers reuse it instead of re-propagating.
    wides: Vec<Option<WideVal>>,
    /// Digit-extraction tables, keyed by (divisions, is-quotient-digit).
    digit_luts: HashMap<(usize, bool), LutRef>,
    msg_lut: Option<LutRef>,
    carry_lut: Option<LutRef>,
    top_lut: Option<LutRef>,
    widened: usize,
    carry_luts_count: u64,
    carry_rotations: u64,
}

impl<'a> Legalizer<'a> {
    fn push(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    fn add_lut(&mut self, f: impl Fn(i64) -> i64 + Send + Sync + 'static) -> LutRef {
        self.luts.push(Arc::new(f));
        LutRef(self.luts.len() - 1)
    }

    /// Message half of a carry split: `s mod B` (register-once).
    fn msg_lut(&mut self) -> LutRef {
        if let Some(l) = self.msg_lut {
            return l;
        }
        let base = self.spec.base();
        let l = self.add_lut(move |s| super::radix::carry_split(s, base).0);
        self.msg_lut = Some(l);
        l
    }

    /// Carry half of a carry split: `s div B` (register-once).
    fn carry_lut(&mut self) -> LutRef {
        if let Some(l) = self.carry_lut {
            return l;
        }
        let base = self.spec.base();
        let l = self.add_lut(move |s| super::radix::carry_split(s, base).1);
        self.carry_lut = Some(l);
        l
    }

    /// Signed wrap of the top limb into `[-B/2, B/2)` (register-once).
    fn top_lut(&mut self) -> LutRef {
        if let Some(l) = self.top_lut {
            return l;
        }
        let base = self.spec.base();
        let l = self.add_lut(move |s| super::radix::wrap_digit(s, base));
        self.top_lut = Some(l);
        l
    }

    /// Digit `j` of a narrow source (remainder digit, or the exact
    /// signed quotient for the last digit of the decomposition span).
    fn digit_lut(&mut self, j: usize, top: bool) -> LutRef {
        if let Some(&l) = self.digit_luts.get(&(j, top)) {
            return l;
        }
        let base = self.spec.base();
        let l = self.add_lut(move |x| super::radix::decomp_digit(x, base, j, top));
        self.digit_luts.insert((j, top), l);
        l
    }

    /// Wide form of old node `old`, decomposing on first request.
    /// Constants split into constant digit limbs (0 PBS); everything
    /// else gets `span` digit LUTs on its narrow incarnation — the
    /// same-input group the packing pass fuses into shared rotations.
    fn get_wide(&mut self, old_nodes: &[Node], old: NodeId) -> WideVal {
        if let Some(w) = &self.wides[old] {
            return w.clone();
        }
        let spec = self.spec;
        let w = if let Node::Const(c) = &old_nodes[old] {
            let digits = spec.encode(*c);
            let bound = digits.iter().map(|d| d.abs()).max().unwrap_or(0);
            let limbs = digits.into_iter().map(|d| self.push(Node::Const(d))).collect();
            WideVal { limbs, bound, canonical: true }
        } else {
            let src = self.remap[old];
            debug_assert_ne!(src, usize::MAX, "wide source must have a narrow incarnation");
            self.widened += 1;
            let span = spec.span();
            let mut limbs = Vec::with_capacity(spec.limbs);
            for j in 0..span {
                let lut = self.digit_lut(j, j + 1 == span);
                limbs.push(self.push(Node::Pbs { input: src, lut }));
            }
            for _ in span..spec.limbs {
                limbs.push(self.push(Node::Const(0)));
            }
            // The quotient digit sits below the top position whenever
            // span < limbs, so the vector is only canonical when the
            // decomposition fills every limb.
            WideVal { limbs, bound: spec.digit_max(), canonical: span == spec.limbs }
        };
        self.wides[old] = Some(w.clone());
        w
    }

    /// Emit a carry-propagation ripple: per non-top limb one packed
    /// message + carry LUT pair on `limb + carry_in`, a signed wrap on
    /// the top — `2k − 1` LUT evaluations, `k − 1` shared rotations plus
    /// the top one at a ϑ ≥ 1 budget.
    fn canon(&mut self, w: &WideVal) -> WideVal {
        if w.canonical {
            return w.clone();
        }
        let k = self.spec.limbs;
        let mut limbs = Vec::with_capacity(k);
        let mut carry: Option<NodeId> = None;
        for j in 0..k {
            let s = match carry {
                None => w.limbs[j],
                Some(c) => self.push(Node::Add(w.limbs[j], c)),
            };
            if j + 1 < k {
                let m = self.msg_lut();
                let c = self.carry_lut();
                limbs.push(self.push(Node::Pbs { input: s, lut: m }));
                carry = Some(self.push(Node::Pbs { input: s, lut: c }));
            } else {
                let t = self.top_lut();
                limbs.push(self.push(Node::Pbs { input: s, lut: t }));
            }
        }
        self.carry_luts_count += 2 * k as u64 - 1;
        self.carry_rotations += (k as u64 - 1) * if self.budget >= 2 { 1 } else { 2 } + 1;
        WideVal { limbs, bound: self.spec.digit_max(), canonical: true }
    }

    /// Canonicalize `old`'s wide form, caching the result so every later
    /// consumer reuses the same rippled limbs.
    fn canon_old(&mut self, old_nodes: &[Node], old: NodeId) -> WideVal {
        let w = self.get_wide(old_nodes, old);
        if w.canonical {
            return w;
        }
        let c = self.canon(&w);
        self.wides[old] = Some(c.clone());
        c
    }

    /// Limb-wise combination of two wides, carry propagation inserted
    /// only when the bound bookkeeping says the result could overflow
    /// the native space. The *left* side ripples first (it is the running
    /// accumulator in a `Sum` fold; `profile_radix` mirrors this order),
    /// and two canonical values always fit (`2·digit_max ≤ add_cap` is a
    /// spec invariant).
    fn combine(
        &mut self,
        old_nodes: &[Node],
        mut wa: WideVal,
        a_old: Option<NodeId>,
        mut wb: WideVal,
        b_old: Option<NodeId>,
        sub: bool,
    ) -> WideVal {
        if wa.bound + wb.bound > self.spec.add_cap() {
            wa = match a_old {
                Some(id) => self.canon_old(old_nodes, id),
                None => self.canon(&wa),
            };
            if wa.bound + wb.bound > self.spec.add_cap() {
                wb = match b_old {
                    Some(id) => self.canon_old(old_nodes, id),
                    None => self.canon(&wb),
                };
            }
        }
        let mut limbs = Vec::with_capacity(self.spec.limbs);
        for (&la, &lb) in wa.limbs.iter().zip(&wb.limbs) {
            limbs.push(self.push(if sub { Node::Sub(la, lb) } else { Node::Add(la, lb) }));
        }
        WideVal { limbs, bound: wa.bound + wb.bound, canonical: false }
    }
}

/// Does this node keep a narrow incarnation even when declared wide?
/// Sources (inputs, constants, bootstrap results) are narrow values
/// that *enter* the wide domain by decomposition; linear nodes over
/// wide operands exist only as limb vectors.
fn is_narrow_source(node: &Node) -> bool {
    matches!(node, Node::Input(_) | Node::Const(_) | Node::Pbs { .. } | Node::MultiOut { .. })
}

/// Radix legalization: rewrite every node whose declared width exceeds
/// the native message space — and every linear node a wide value flows
/// into — onto limb vectors (`spec.limbs` little-endian message-space
/// digits, signed top). Narrow sources entering the wide domain are
/// decomposed by `span` same-input digit LUTs; deferred carries are
/// propagated by packed message/carry LUT pairs only when the bound
/// bookkeeping requires it; wide outputs are rippled to canonical form
/// and spliced as `spec.limbs` consecutive output slots (recorded in
/// the returned [`RadixInfo`]). Runs before CSE/packing, which then
/// treat the limb nodes like any others — packing is what turns the
/// same-input digit and carry tables into ϑ ≥ 2 shared rotations.
///
/// Returns `None` (plan untouched) when no declared width exceeds the
/// native space.
fn radix_pass(
    nodes: &mut Vec<Node>,
    luts: &mut Vec<LutFn>,
    outputs: &mut Vec<NodeId>,
    widths: &HashMap<NodeId, u32>,
    rcfg: &super::radix::RadixConfig,
    budget: usize,
    stats: &mut RewriteStats,
) -> Option<super::radix::RadixInfo> {
    // Which nodes carry wide values: declared wider than native, plus
    // everything downstream through linear ops.
    let mut wide = vec![false; nodes.len()];
    let mut max_declared = 0u32;
    for (&id, &w) in widths {
        if rcfg.spec_for(w).is_some() {
            wide[id] = true;
            max_declared = max_declared.max(w);
        }
    }
    if max_declared == 0 {
        return None;
    }
    let spec = rcfg.spec_for(max_declared).expect("checked wide above");
    for id in 0..nodes.len() {
        let prop = match &nodes[id] {
            Node::Add(a, b) | Node::Sub(a, b) => wide[*a] || wide[*b],
            Node::Neg(a) | Node::AddConst(a, _) | Node::ScalarMul(a, _) => wide[*a],
            Node::Sum(xs) => xs.iter().any(|&x| wide[x]),
            Node::Pbs { input, .. } | Node::MultiPbs { input, .. } => {
                // A bootstrap can read a *declared* source (it still has
                // a narrow incarnation) but never a genuinely wide
                // linear value — a LUT cannot resolve more bits than
                // the native space holds.
                assert!(
                    !wide[*input] || is_narrow_source(&nodes[*input]),
                    "radix legalization: PBS of a wide value is unsupported — declare the \
                     width after the last bootstrap of the chain"
                );
                false
            }
            Node::Input(_) | Node::Const(_) | Node::MultiOut { .. } => false,
        };
        if prop {
            assert!(
                !matches!(nodes[id], Node::MultiPbs { .. }),
                "radix legalization: cannot widen a multi-output bootstrap node"
            );
            wide[id] = true;
        }
    }

    let old_nodes = std::mem::take(nodes);
    let mut leg = Legalizer {
        spec,
        budget: budget.max(1),
        nodes: Vec::with_capacity(old_nodes.len() * 2),
        luts,
        remap: Vec::with_capacity(old_nodes.len()),
        wides: vec![None; old_nodes.len()],
        digit_luts: HashMap::new(),
        msg_lut: None,
        carry_lut: None,
        top_lut: None,
        widened: 0,
        carry_luts_count: 0,
        carry_rotations: 0,
    };

    for (id, node) in old_nodes.iter().enumerate() {
        if !wide[id] || is_narrow_source(node) {
            let n = remap_node(node, &leg.remap);
            let new_id = leg.push(n);
            leg.remap.push(new_id);
            continue;
        }
        let wv = match node {
            Node::Add(a, b) | Node::Sub(a, b) => {
                let wa = leg.get_wide(&old_nodes, *a);
                let wb = leg.get_wide(&old_nodes, *b);
                let sub = matches!(node, Node::Sub(..));
                leg.combine(&old_nodes, wa, Some(*a), wb, Some(*b), sub)
            }
            Node::Neg(a) => {
                let wa = leg.get_wide(&old_nodes, *a);
                let limbs = wa.limbs.iter().map(|&l| leg.push(Node::Neg(l))).collect();
                WideVal { limbs, bound: wa.bound, canonical: false }
            }
            Node::AddConst(a, c) => {
                let digits = spec.encode(*c);
                let need = digits.iter().map(|d| d.abs()).max().unwrap_or(0);
                let mut wa = leg.get_wide(&old_nodes, *a);
                if need == 0 {
                    wa
                } else {
                    if wa.bound + need > spec.add_cap() {
                        wa = leg.canon_old(&old_nodes, *a);
                    }
                    let mut limbs = Vec::with_capacity(spec.limbs);
                    for (&la, &d) in wa.limbs.iter().zip(&digits) {
                        limbs.push(if d == 0 { la } else { leg.push(Node::AddConst(la, d)) });
                    }
                    WideVal { limbs, bound: wa.bound + need, canonical: false }
                }
            }
            Node::ScalarMul(a, s) => {
                if *s == 1 {
                    leg.get_wide(&old_nodes, *a)
                } else {
                    let m = s.unsigned_abs() as i64;
                    assert!(
                        m.saturating_mul(spec.digit_max()) <= spec.add_cap(),
                        "radix legalization: scalar multiplier {s} exceeds the limb \
                         headroom of {spec:?} — fold it into a LUT before the declaration"
                    );
                    let mut wa = leg.get_wide(&old_nodes, *a);
                    if wa.bound.saturating_mul(m) > spec.add_cap() {
                        wa = leg.canon_old(&old_nodes, *a);
                    }
                    let limbs =
                        wa.limbs.iter().map(|&l| leg.push(Node::ScalarMul(l, *s))).collect();
                    WideVal { limbs, bound: wa.bound * m, canonical: *s == 0 }
                }
            }
            Node::Sum(xs) => {
                let mut acc = leg.get_wide(&old_nodes, xs[0]);
                let mut acc_old = Some(xs[0]);
                for &x in &xs[1..] {
                    let wx = leg.get_wide(&old_nodes, x);
                    acc = leg.combine(&old_nodes, acc, acc_old, wx, Some(x), false);
                    acc_old = None;
                }
                acc
            }
            Node::MultiPbs { .. } => {
                panic!("radix legalization: cannot declare a width on a multi-output bootstrap")
            }
            Node::Input(_) | Node::Const(_) | Node::Pbs { .. } | Node::MultiOut { .. } => {
                unreachable!("narrow sources handled above")
            }
        };
        leg.remap.push(usize::MAX);
        leg.wides[id] = Some(wv);
    }

    // Wide outputs leave the plan in canonical form, spliced as
    // `spec.limbs` consecutive slots.
    let mut wide_outputs = Vec::with_capacity(outputs.len());
    let mut new_outputs = Vec::with_capacity(outputs.len());
    for &out in outputs.iter() {
        if wide[out] {
            let w = leg.canon_old(&old_nodes, out);
            new_outputs.extend(w.limbs.iter().copied());
            wide_outputs.push(true);
        } else {
            new_outputs.push(leg.remap[out]);
            wide_outputs.push(false);
        }
    }

    stats.radix_widened = leg.widened;
    stats.radix_limbs = spec.limbs;
    stats.carry_luts = leg.carry_luts_count;
    stats.carry_rotations = leg.carry_rotations;
    let info = super::radix::RadixInfo {
        spec,
        widened: leg.widened,
        carry_luts: leg.carry_luts_count,
        carry_rotations: leg.carry_rotations,
        wide_outputs,
    };
    *nodes = leg.nodes;
    *outputs = new_outputs;
    Some(info)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Xoshiro256;

    fn setup() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(0x9147);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    /// relu(a − b) + |b| · 2 — one plan, two levels of depth 1.
    fn small_plan() -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let d = b.sub(ins[0], ins[1]);
        let r = b.relu(d);
        let ab = b.abs(ins[1]);
        let ab2 = b.scalar_mul(ab, 2);
        let out = b.add(r, ab2);
        b.output(out);
        b.build()
    }

    #[test]
    fn analysis_counts_levels_and_ops() {
        let p = small_plan();
        assert_eq!(p.n_inputs(), 2);
        assert_eq!(p.n_outputs(), 1);
        assert_eq!(p.pbs_count(), 2);
        assert_eq!(p.levels(), 1);
        assert_eq!(p.level_sizes(), vec![2]);
        assert_eq!(p.linear_op_count(), 3); // sub, scalar_mul, add
    }

    #[test]
    fn ct_mul_and_chained_levels() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let prod = b.ct_mul(ins[0], ins[1]); // level 1 (2 PBS)
        let r = b.relu(prod); // level 2
        b.output(r);
        let p = b.build();
        assert_eq!(p.pbs_count(), 3);
        assert_eq!(p.levels(), 2);
        assert_eq!(p.level_sizes(), vec![2, 1]);
    }

    #[test]
    fn sum_counts_len_minus_one_linear_ops() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(4);
        let s = b.sum(&ins);
        b.output(s);
        let p = b.build();
        assert_eq!(p.pbs_count(), 0);
        assert_eq!(p.levels(), 0);
        assert_eq!(p.linear_op_count(), 3);
    }

    #[test]
    fn execute_matches_direct_ops_bit_identically() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        // Values keep every intermediate and the output inside the 4-bit
        // signed range [−8, 7] (linear ops do not saturate).
        for (a, b) in [(1i64, -2), (-4, 1), (0, 0), (2, 3)] {
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(b, &ck, &mut rng);
            let before = pbs_count();
            let outs = p.execute(&ctx, &[ca.clone(), cb.clone()]);
            assert_eq!(pbs_count() - before, p.pbs_count(), "plan PBS count a={a} b={b}");
            // Direct formulation of the same dataflow.
            let want =
                ctx.add(&ctx.relu(&ctx.sub(&ca, &cb)), &ctx.scalar_mul(&ctx.abs(&cb), 2));
            assert_eq!(outs.len(), 1);
            assert_eq!(outs[0].ct, want.ct, "bit-identical a={a} b={b}");
            assert_eq!(ctx.decrypt(&outs[0], &ck), (a - b).max(0) + 2 * b.abs());
        }
    }

    #[test]
    fn execute_ref_matches_execute_bit_identically() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(2, &ck, &mut rng);
        let cb = ctx.encrypt(-1, &ck, &mut rng);
        let owned = p.execute(&ctx, &[ca.clone(), cb.clone()]);
        let got = p.execute_ref(&ctx, &[&ca, &cb]);
        assert_eq!(got[0].ct, owned[0].ct, "by-ref execution is the same dataflow");
        assert_eq!(ctx.decrypt(&got[0], &ck), (2i64 + 1).max(0) + 2);
    }

    #[test]
    fn execute_is_thread_invariant() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(1, &ck, &mut rng);
        let cb = ctx.encrypt(-2, &ck, &mut rng);
        let inputs = [ca, cb];
        ctx.set_threads(1);
        let reference = p.execute(&ctx, &inputs);
        for threads in [2usize, 4] {
            ctx.set_threads(threads);
            let got = p.execute(&ctx, &inputs);
            assert_eq!(got[0].ct, reference[0].ct, "threads={threads}");
        }
    }

    #[test]
    fn constants_and_pure_linear_plans() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let c = b.constant(3);
        let s = b.add(ins[0], c);
        let t = b.add_const(s, -1);
        let n = b.neg(t);
        b.output(n);
        let p = b.build();
        assert_eq!(p.pbs_count(), 0);
        let x = ctx.encrypt(2, &ck, &mut rng);
        let before = pbs_count();
        let outs = p.execute(&ctx, &[x]);
        assert_eq!(pbs_count(), before, "linear plan must not bootstrap");
        assert_eq!(ctx.decrypt(&outs[0], &ck), -(2 + 3 - 1));
    }

    #[test]
    fn stepper_drives_levels_manually() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(-1, &ck, &mut rng);
        let cb = ctx.encrypt(2, &ck, &mut rng);
        let inputs = [ca, cb];
        let mut run = PlanRun::new(&p, &ctx, &inputs);
        let mut rounds = 0;
        while let Some(jobs) = run.next_level_jobs(&ctx) {
            rounds += 1;
            // Execute the level's jobs one by one (any schedule is valid).
            let outs: Vec<CtInt> = jobs
                .iter()
                .flat_map(|job| match job {
                    LevelJob::Single(ct, lut) => {
                        vec![CtInt { ct: ctx.sk.pbs_prepared(&ct.ct, lut) }]
                    }
                    LevelJob::Multi(ct, mlut) => ctx
                        .sk
                        .pbs_multi(&ct.ct, mlut)
                        .into_iter()
                        .map(|ct| CtInt { ct })
                        .collect(),
                })
                .collect();
            run.supply(outs);
        }
        assert_eq!(rounds, p.levels());
        let outs = run.finish(&ctx);
        assert_eq!(ctx.decrypt(&outs[0], &ck), (-1i64 - 2).max(0) + 2 * 2);
    }

    #[test]
    fn abandoning_mid_plan_skips_remaining_levels() {
        // Deadline/cancellation contract: a run dropped at a level
        // boundary executes strictly fewer PBS than the full plan, and
        // `levels_done()` records how far it got.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let r1 = b.relu(ins[0]);
        let r2 = b.abs(r1);
        let r3 = b.relu(r2);
        b.output(r3);
        let p = b.build();
        assert_eq!(p.levels(), 3);
        let x = ctx.encrypt(-2, &ck, &mut rng);
        let inputs = [x];
        let mut run = PlanRun::new(&p, &ctx, &inputs);
        assert_eq!(run.levels_done(), 0);
        let before = pbs_count();
        let jobs = run.next_level_jobs(&ctx).expect("level 1 exists");
        run.supply(ctx.pbs_level(&jobs));
        // The deadline "expires" here: abandon by dropping the run.
        assert_eq!(run.levels_done(), 1);
        assert!(run.levels_done() < p.levels());
        drop(run);
        let executed = pbs_count() - before;
        assert_eq!(executed, p.level_sizes()[0] as u64, "only level 1 ran");
        assert!(executed < p.pbs_count(), "levels 2..3 were skipped");
    }

    // ----- rewrite passes -----

    /// A multi-LUT-capable context (ϑ = 1 ⇒ groups of ≤ 2).
    fn multi_setup() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(0x9148);
        let ck = ClientKey::generate(TfheParams::test_multi_lut(3), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    /// relu(x) and |x| of the same input, plus a duplicated difference
    /// and a duplicated relu of it: CSE fodder on top of a packable pair.
    fn redundant_plan() -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let d1 = b.sub(ins[0], ins[1]);
        let d2 = b.sub(ins[0], ins[1]); // duplicate of d1
        let r1 = b.relu(d1);
        let r2 = b.relu(d2); // collapses once d2 → d1
        let ab = b.abs(d1); // same input as r1, different LUT → packable
        let s = b.add(r1, r2);
        let out = b.add(s, ab);
        b.output(out);
        b.build()
    }

    #[test]
    fn cse_merges_duplicates_and_execution_stays_bit_identical() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let p = redundant_plan();
        assert_eq!(p.pbs_count(), 3);
        let (q, stats) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(redundant_plan());
        // d2 and r2 merge; r1/ab survive (different tables).
        assert_eq!(stats.cse_merged, 2);
        assert_eq!(q.pbs_count(), 2);
        assert_eq!(q.blind_rotation_count(), 2);
        // a − b = 2 keeps 2·relu + abs = 6 inside the 4-bit signed range.
        let a = ctx.encrypt(1, &ck, &mut rng);
        let b = ctx.encrypt(-1, &ck, &mut rng);
        let inputs = [a, b];
        let want = p.execute(&ctx, &inputs);
        let before = pbs_count();
        let got = q.execute(&ctx, &inputs);
        assert_eq!(pbs_count() - before, 2, "merged plan executes 2 PBS");
        // CSE merges are ciphertext-exact, so even the *ciphertexts*
        // agree with the unrewritten run.
        assert_eq!(got[0].ct, want[0].ct);
        assert_eq!(ctx.decrypt(&got[0], &ck), 6);
    }

    #[test]
    fn cse_never_merges_nodes_with_different_lut_tables() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let l1 = b.lut(|x| x.max(0));
        let l2 = b.lut(|x| x.min(0)); // different table, same input
        let p1 = b.pbs(ins[0], l1);
        let p2 = b.pbs(ins[0], l2);
        let s = b.add(p1, p2);
        b.output(s);
        let (q, stats) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(b.build());
        assert_eq!(stats.cse_merged, 0, "distinct tables must never merge");
        assert_eq!(q.pbs_count(), 2);
    }

    #[test]
    fn cse_canonicalizes_commutative_operands() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let s1 = b.add(ins[0], ins[1]);
        let s2 = b.add(ins[1], ins[0]); // torus addition commutes
        let t1 = b.sub(ins[0], ins[1]);
        let t2 = b.sub(ins[1], ins[0]); // subtraction does NOT
        let u = b.add(s1, s2);
        let v = b.add(t1, t2);
        let w = b.add(u, v);
        b.output(w);
        let (q, stats) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(b.build());
        assert_eq!(stats.cse_merged, 1, "only the commuted Add merges");
        assert_eq!(q.linear_op_count(), 6);
    }

    #[test]
    fn packing_groups_share_one_rotation_and_decode_identically() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = multi_setup();
        assert_eq!(ctx.max_multi_lut(), 2);
        let p = redundant_plan();
        let (q, stats) = PlanRewriter::for_ctx(&ctx).rewrite(redundant_plan());
        assert_eq!(stats.cse_merged, 2);
        assert_eq!(stats.multi_groups, 1);
        assert_eq!(stats.packed_luts, 2);
        // LUT evaluations unchanged by packing, rotations reduced.
        assert_eq!(q.pbs_count(), 2);
        assert_eq!(q.blind_rotation_count(), 1);
        assert_eq!(q.levels(), p.levels());
        assert_eq!(q.level_sizes(), vec![1], "one fused job on the only level");
        // 3-bit signed range is [−4, 3]: keep every intermediate —
        // including 2·relu(a−b) + |a−b| — inside it.
        for (a, b) in [(1i64, 0), (0, 1), (-1, 1), (-2, -2)] {
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(b, &ck, &mut rng);
            let inputs = [ca, cb];
            let want = p.execute(&ctx, &inputs);
            let before_pbs = pbs_count();
            let before_rot = crate::tfhe::bootstrap::blind_rotation_count();
            let got = q.execute(&ctx, &inputs);
            assert_eq!(pbs_count() - before_pbs, q.pbs_count(), "a={a} b={b}");
            assert_eq!(
                crate::tfhe::bootstrap::blind_rotation_count() - before_rot,
                q.blind_rotation_count(),
                "a={a} b={b}"
            );
            assert_eq!(
                ctx.decrypt(&got[0], &ck),
                ctx.decrypt(&want[0], &ck),
                "decode equality a={a} b={b}"
            );
        }
    }

    #[test]
    fn packing_respects_group_budget_and_level_boundaries() {
        // Four LUTs of one input at budget 2 → two groups of 2; a LUT of
        // a *different* (deeper) node must not join any group.
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let luts: Vec<LutRef> = (0..4i64).map(|k| b.lut(move |x| x + k)).collect();
        let outs: Vec<NodeId> = luts.iter().map(|&l| b.pbs(ins[0], l)).collect();
        let deeper = b.pbs(outs[0], luts[1]); // level 2: same table, different input
        let s = b.sum(&outs);
        let t = b.add(s, deeper);
        b.output(t);
        let p = b.build();
        let (q, stats) =
            PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 }).rewrite(p);
        assert_eq!(stats.multi_groups, 2);
        assert_eq!(stats.packed_luts, 4);
        assert_eq!(q.pbs_count(), 5);
        assert_eq!(q.blind_rotation_count(), 3, "2 groups + the deeper singleton");
        // Grouped members sit at one level; the deeper PBS kept its own.
        assert_eq!(q.levels(), 2);
        assert_eq!(q.level_sizes(), vec![2, 1]);
    }

    #[test]
    fn rewrites_are_idempotent() {
        let rewriter = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 });
        let (once, stats1) = rewriter.rewrite(redundant_plan());
        assert!(stats1.cse_merged > 0 && stats1.multi_groups > 0);
        let (pbs1, rot1, lin1) =
            (once.pbs_count(), once.blind_rotation_count(), once.linear_op_count());
        let (twice, stats2) = rewriter.rewrite(once);
        assert_eq!(stats2, RewriteStats::default(), "second run must be a no-op");
        assert_eq!(twice.pbs_count(), pbs1);
        assert_eq!(twice.blind_rotation_count(), rot1);
        assert_eq!(twice.linear_op_count(), lin1);
    }

    #[test]
    fn requant_tables_are_registered_once_per_factor_and_kind() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let m = FixedMult::from_f64(0.5);
        let r1 = b.requant(ins[0], m);
        let r2 = b.requant(ins[1], m); // same factor → same table
        let rr = b.requant_relu(ins[0], m); // same factor, fused relu → distinct table
        let m2 = FixedMult::from_f64(0.25);
        let r3 = b.requant(ins[0], m2); // different factor → distinct table
        let s = b.sum(&[r1, r2, rr, r3]);
        b.output(s);
        let p = b.build();
        assert_eq!(p.pbs_count(), 4);
        assert!(p.multi_group_sizes().is_empty(), "no packed nodes before rewriting");
        // ins[0] feeds three *distinct* registered tables → one packable
        // group of 3 at a ϑ ≥ 2 budget; the same-table requants on
        // different inputs must NOT merge.
        let (packed, stats) =
            PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 4 }).rewrite(p);
        assert_eq!(stats.cse_merged, 0, "distinct inputs/tables: nothing to merge");
        assert_eq!(stats.multi_groups, 1);
        assert_eq!(stats.packed_luts, 3);
        assert_eq!(packed.multi_group_sizes(), vec![3]);
        assert_eq!(packed.pbs_count(), 4, "packing keeps LUT evaluations");
        assert_eq!(packed.blind_rotation_count(), 2, "group of 3 + the ins[1] singleton");
    }

    #[test]
    fn requant_pbs_matches_fixed_mult_apply_bit_for_bit() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup(); // 4-bit signed range [−8, 7]
        let m = FixedMult::from_f64(0.5);
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let r = b.requant(ins[0], m);
        let rr = b.requant_relu(ins[0], m);
        let rn = b.requant_min0(ins[0], m);
        b.output(r);
        b.output(rr);
        b.output(rn);
        let p = b.build();
        for v in [-8i64, -3, -1, 0, 1, 2, 7] {
            let x = ctx.encrypt(v, &ck, &mut rng);
            let outs = p.execute(&ctx, &[x]);
            assert_eq!(ctx.decrypt(&outs[0], &ck), m.apply(v), "requant({v})");
            assert_eq!(ctx.decrypt(&outs[1], &ck), m.apply(v).max(0), "requant_relu({v})");
            assert_eq!(ctx.decrypt(&outs[2], &ck), m.apply(v).min(0), "requant_min0({v})");
        }
    }

    #[test]
    fn rewrite_none_returns_plan_unchanged() {
        let p = redundant_plan();
        let (q, stats) = PlanRewriter::new(RewriteConfig::none()).rewrite(redundant_plan());
        assert_eq!(stats, RewriteStats::default());
        assert_eq!(q.pbs_count(), p.pbs_count());
        assert_eq!(q.blind_rotation_count(), p.blind_rotation_count());
        assert_eq!(q.level_sizes(), p.level_sizes());
    }

    #[test]
    fn liveness_frees_every_intermediate_in_rewritten_plans() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = multi_setup();
        let (q, _) = PlanRewriter::for_ctx(&ctx).rewrite(redundant_plan());
        let ca = ctx.encrypt(1, &ck, &mut rng);
        let cb = ctx.encrypt(0, &ck, &mut rng);
        let inputs = [ca, cb];
        let mut run = PlanRun::new(&q, &ctx, &inputs);
        while let Some(jobs) = run.next_level_jobs(&ctx) {
            let outs = ctx.pbs_level(&jobs);
            run.supply(outs);
        }
        let outs = run.finish_in_place(&ctx);
        assert_eq!(outs.len(), 1);
        // Every consumed node was freed after its last read — including
        // the listed outputs, whose +1 use `finish` consumes by *moving*
        // the value out (no terminal clone, no leak).
        for id in 0..q.nodes.len() {
            assert_eq!(run.remaining[id], 0, "node {id} has unconsumed reads");
            assert!(run.values[id].is_none(), "node {id} leaked its ciphertext");
        }
    }

    /// Clears the dispatch override on drop so a panicking assertion
    /// can't leak a forced mode into concurrently running tests.
    struct WavefrontGuard;
    impl Drop for WavefrontGuard {
        fn drop(&mut self) {
            set_wavefront_dispatch(None);
        }
    }

    #[test]
    fn wavefront_stepper_matches_level_stepper_bit_identically() {
        // Drive the two steppers side by side over a multi-level plan
        // (rewritten, so MultiPbs/MultiOut nodes are in play): every
        // wave's job count must equal the corresponding level size, and
        // outputs plus PBS counter deltas must be bit-identical.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = multi_setup();
        let (q, _) = PlanRewriter::for_ctx(&ctx).rewrite(redundant_plan());
        let ca = ctx.encrypt(1, &ck, &mut rng);
        let cb = ctx.encrypt(-2, &ck, &mut rng);
        let inputs = [ca, cb];
        let sizes = q.level_sizes();
        let mut by_level = PlanRun::new(&q, &ctx, &inputs);
        let mut by_wave = PlanRun::new(&q, &ctx, &inputs);
        let mut waves = 0usize;
        loop {
            let lj = by_level.next_level_jobs(&ctx);
            let wj = by_wave.next_wave_jobs(&ctx);
            match (lj, wj) {
                (None, None) => break,
                (Some(lj), Some(wj)) => {
                    assert_eq!(lj.len(), wj.len(), "wave {waves} ready set = level set");
                    assert_eq!(lj.len(), sizes[waves], "wave {waves} matches the oracle");
                    by_level.supply(ctx.pbs_level(&lj));
                    by_wave.supply(ctx.pbs_level(&wj));
                    assert_eq!(by_level.levels_done(), by_wave.levels_done());
                    waves += 1;
                }
                (l, w) => panic!(
                    "steppers must exhaust together: level={:?} wave={:?}",
                    l.map(|j| j.len()),
                    w.map(|j| j.len())
                ),
            }
        }
        assert_eq!(waves, q.levels(), "waves and levels advance in lockstep");
        let a = by_level.finish(&ctx);
        let b = by_wave.finish(&ctx);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ct, y.ct, "wavefront output bit-identical");
        }
    }

    #[test]
    fn wavefront_execute_matches_barrier_execute_with_equal_counters() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let _mode_guard = WavefrontGuard;
        let (ck, ctx, mut rng) = setup();
        let p = small_plan();
        let ca = ctx.encrypt(2, &ck, &mut rng);
        let cb = ctx.encrypt(-1, &ck, &mut rng);
        set_wavefront_dispatch(Some(false));
        let before = pbs_count();
        let barrier = p.execute_ref(&ctx, &[&ca, &cb]);
        let barrier_pbs = pbs_count() - before;
        set_wavefront_dispatch(Some(true));
        let before = pbs_count();
        let wave = p.execute_ref(&ctx, &[&ca, &cb]);
        let wave_pbs = pbs_count() - before;
        assert_eq!(barrier[0].ct, wave[0].ct, "modes are bit-identical");
        assert_eq!(barrier_pbs, wave_pbs, "modes cost the same PBS");
        assert_eq!(wave_pbs, p.pbs_count(), "both match the plan oracle");
    }

    #[test]
    fn wavefront_knob_override_beats_environment() {
        let _mode_guard = WavefrontGuard;
        set_wavefront_dispatch(Some(false));
        assert!(!wavefront_enabled(), "forced off");
        set_wavefront_dispatch(Some(true));
        assert!(wavefront_enabled(), "forced on");
        set_wavefront_dispatch(None);
        // Cleared: the mode falls back to FHE_WAVEFRONT (default on).
        let env_default = match std::env::var("FHE_WAVEFRONT") {
            Ok(v) => {
                let v = v.trim();
                !v.is_empty() && v != "0"
            }
            Err(_) => true,
        };
        assert_eq!(wavefront_enabled(), env_default);
    }

    // ----- radix legalization -----

    use crate::tfhe::radix::RadixConfig;

    #[test]
    fn radix_is_a_noop_when_declared_width_fits_native() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let s = b.add(ins[0], ins[1]);
        b.declare_width(s, 4); // fits a 6-bit native space
        b.output(s);
        let plan = b.build();
        let before = plan.structural_hash();
        let (out, stats) = PlanRewriter::new(RewriteConfig::none())
            .with_radix(RadixConfig::new(6))
            .rewrite(plan);
        assert_eq!(out.structural_hash(), before, "no-op legalization keeps the DAG");
        assert!(out.radix().is_none());
        assert_eq!(stats, RewriteStats::default());
        // The declaration survives for a later rewrite at a narrower set.
        assert_eq!(out.declared_widths().get(&s), Some(&4));
    }

    #[test]
    fn radix_wide_add_executes_bit_identically_to_the_mirror() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup(); // 4-bit native space
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let s = b.add(ins[0], ins[1]);
        b.declare_width(s, 6);
        b.output(s);
        let (plan, stats) = PlanRewriter::new(RewriteConfig::cse_only())
            .with_radix(RadixConfig::new(4))
            .rewrite(b.build());
        let info = plan.radix().expect("legalization fired").clone();
        let spec = info.spec;
        assert_eq!((spec.limb_bits, spec.limbs), (1, 6), "4-bit native forces 1-bit limbs");
        assert_eq!(stats.radix_widened, 2, "both operands decomposed");
        assert_eq!(stats.radix_limbs, 6);
        assert_eq!(stats.carry_luts, 2 * 6 - 1, "one output ripple");
        assert_eq!(info.wide_outputs, vec![true]);
        // 2·span digit extractions + one 2k−1 carry ripple.
        assert_eq!(plan.pbs_count(), 2 * spec.span() as u64 + stats.carry_luts);
        for (a, bv) in [(7i64, 7), (-7, -7), (-7, 6), (5, -3), (0, 0)] {
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(bv, &ck, &mut rng);
            let before = pbs_count();
            let outs = plan.execute(&ctx, &[ca, cb]);
            assert_eq!(pbs_count() - before, plan.pbs_count(), "oracle a={a} b={bv}");
            let limbs: Vec<i64> = outs.iter().map(|o| ctx.decrypt(o, &ck)).collect();
            assert_eq!(limbs, spec.encode(a + bv), "canonical limbs a={a} b={bv}");
            assert_eq!(info.decode_outputs(&limbs), vec![a + bv]);
        }
    }

    #[test]
    fn radix_rewrite_is_idempotent_and_keeps_the_record() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(3);
        let s = b.sum(&ins);
        b.declare_width(s, 6);
        b.output(s);
        let rw = PlanRewriter::new(RewriteConfig::cse_only()).with_radix(RadixConfig::new(4));
        let (once, stats1) = rw.rewrite(b.build());
        assert!(stats1.radix_limbs > 0, "first rewrite legalizes");
        let hash = once.structural_hash();
        let (twice, stats2) = rw.rewrite(once);
        assert_eq!(stats2, RewriteStats::default(), "second rewrite is a no-op");
        assert_eq!(twice.structural_hash(), hash);
        assert!(twice.radix().is_some(), "legalization record survives re-rewriting");
    }

    #[test]
    fn radix_digit_groups_pack_to_four_luts_at_theta2() {
        // 2-bit limbs over an 8-bit native space: span-4 digit
        // extraction from each narrow source — exactly a 2^ϑ = 4 group.
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let p1 = b.relu(ins[0]);
        let p2 = b.abs(ins[1]);
        let s = b.add(p1, p2);
        b.declare_width(s, 10);
        b.output(s);
        let (plan, stats) =
            PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 4 })
                .with_radix(RadixConfig::new(8).with_limb_bits(2))
                .rewrite(b.build());
        let spec = plan.radix().unwrap().spec;
        assert_eq!((spec.limb_bits, spec.limbs, spec.span()), (2, 5, 4));
        let sizes = plan.multi_group_sizes();
        assert_eq!(
            sizes.iter().filter(|&&g| g >= 4).count(),
            2,
            "each decomposed source packs its span-4 digit group, got {sizes:?}"
        );
        // relu + abs + 2 span-4 decompositions + one k=5 ripple.
        assert_eq!(plan.pbs_count(), 2 + 8 + 9);
        // Rotations: 2 singletons + 1 per digit group + (k−1) message +
        // carry pairs + the top wrap.
        assert_eq!(plan.blind_rotation_count(), 2 + 2 + 4 + 1);
        assert_eq!(stats.carry_rotations, 5);
    }

    #[test]
    #[should_panic(expected = "PBS of a wide value")]
    fn radix_rejects_bootstrap_of_a_wide_value() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(2);
        let s = b.add(ins[0], ins[1]);
        b.declare_width(s, 9);
        let r = b.relu(s);
        b.output(r);
        let _ = PlanRewriter::new(RewriteConfig::none())
            .with_radix(RadixConfig::new(6))
            .rewrite(b.build());
    }

    #[test]
    #[should_panic(expected = "scalar multiplier")]
    fn radix_rejects_oversized_scalar_multipliers() {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let m = b.scalar_mul(ins[0], 100);
        b.declare_width(m, 9);
        b.output(m);
        let _ = PlanRewriter::new(RewriteConfig::none())
            .with_radix(RadixConfig::new(6))
            .rewrite(b.build());
    }
}
