//! Radix wide-integer arithmetic: one logical value as a little-endian
//! vector of message-space limbs.
//!
//! One torus message space caps every accumulator in the repo at
//! `message_bits` of precision. This module defines the *representation*
//! a wide value takes when that is not enough — `limbs` digits of
//! `limb_bits` each, unsigned except for a two's-complement signed top
//! limb — plus the plaintext mirror arithmetic the differential tests
//! compare against. The *circuit* side (rewriting a declared-wide plan
//! node into limb-wise linear ops and packed carry-propagation PBS)
//! lives in `tfhe::plan` as a legalization pass inside `PlanRewriter`;
//! see rust/DESIGN.md §10.
//!
//! Limb layout (base B = 2^limb_bits, k = limbs):
//!
//! - limbs 0..k-2 hold digits in `[0, B-1]` (canonical form),
//! - the top limb holds a signed digit in `[-B/2, B/2)`,
//! - the represented value is `Σ dᵢ·Bⁱ`, ranging over exactly
//!   `[-Bᵏ/2, Bᵏ/2)` — ordinary two's complement in base B.
//!
//! Between carry propagations, limbs drift outside the canonical digit
//! range (linear ops are applied limb-wise with no carries); the value
//! `Σ dᵢ·Bⁱ` stays exact as long as every limb stays within the native
//! message space. [`RadixSpec::add_cap`]/[`RadixSpec::carry_cap`] budget
//! that headroom: a carry-propagation PBS may only be *skipped* while
//! `|limb| ≤ add_cap`, because the ripple itself adds a carry of up to
//! `carry_cap` to the next limb before its split LUTs fire.

use std::sync::atomic::{AtomicU32, Ordering};

use super::params::TfheParams;

/// Shape of a radix representation: `limbs` digits of `limb_bits` each,
/// legalized against a native message space of `native_bits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RadixSpec {
    /// Bits per limb (digit base is `2^limb_bits`).
    pub limb_bits: u32,
    /// Number of limbs (little-endian; the last is the signed top digit).
    pub limbs: usize,
    /// Native message-space width the limbs must fit inside, carries and
    /// all (the `message_bits` of the parameter set, or a forced override).
    pub native_bits: u32,
}

impl RadixSpec {
    /// Build and validate a spec. Panics on shapes that cannot host a
    /// carry discipline (see the capacity invariant below).
    pub fn new(limb_bits: u32, limbs: usize, native_bits: u32) -> Self {
        assert!(limb_bits >= 1, "radix: limb_bits must be >= 1");
        assert!(limbs >= 2, "radix: a wide value needs >= 2 limbs");
        assert!(
            limb_bits < native_bits,
            "radix: limb_bits {limb_bits} must leave carry headroom below native {native_bits}"
        );
        let spec = RadixSpec { limb_bits, limbs, native_bits };
        assert!(
            spec.width_bits() <= 32,
            "radix: total width {} exceeds the 32-bit mirror range",
            spec.width_bits()
        );
        // Capacity invariant: after a carry propagation every limb is a
        // digit (≤ B-1), and one limb-wise add of two canonical values
        // must fit back under add_cap — otherwise no sequence of ops can
        // ever make progress without overflowing the native space.
        assert!(
            2 * spec.digit_max() <= spec.add_cap(),
            "radix: limb_bits {limb_bits} leaves no add headroom at native {native_bits} \
             (2·digit_max {} > add_cap {})",
            2 * spec.digit_max(),
            spec.add_cap()
        );
        spec
    }

    /// Spec covering `width_bits` of precision with `limb_bits`-wide
    /// digits at the given native space (limb count rounded up, min 2).
    pub fn for_width(width_bits: u32, limb_bits: u32, native_bits: u32) -> Self {
        let limbs = (width_bits.div_ceil(limb_bits) as usize).max(2);
        Self::new(limb_bits, limbs, native_bits)
    }

    /// Digit base B = 2^limb_bits.
    pub fn base(&self) -> i64 {
        1i64 << self.limb_bits
    }

    /// Total represented width in bits (`limb_bits · limbs`).
    pub fn width_bits(&self) -> u32 {
        self.limb_bits * self.limbs as u32
    }

    /// Largest canonical digit, B-1.
    pub fn digit_max(&self) -> i64 {
        self.base() - 1
    }

    /// Largest magnitude the native message space holds: 2^(native-1)-1.
    pub fn native_cap(&self) -> i64 {
        (1i64 << (self.native_bits - 1)) - 1
    }

    /// Worst-case carry magnitude the ripple can inject into a limb that
    /// is itself at `add_cap`: `⌊native_cap/B⌋ + 1`.
    pub fn carry_cap(&self) -> i64 {
        self.native_cap() / self.base() + 1
    }

    /// Largest limb magnitude at which carry propagation may still be
    /// deferred: the ripple adds up to `carry_cap` before the split LUTs
    /// see the limb, and the sum must stay inside the native space.
    pub fn add_cap(&self) -> i64 {
        self.native_cap() - self.carry_cap()
    }

    /// Digits needed to cover one native-space value: ⌈native/limb_bits⌉.
    /// Decomposing a narrow value emits exactly this many digit LUTs from
    /// the *same* input — the natural packed multi-value group.
    pub fn span(&self) -> usize {
        self.native_bits.div_ceil(self.limb_bits) as usize
    }

    /// Wrap-around modulus of the representation, B^limbs.
    pub fn modulus(&self) -> i64 {
        1i64 << self.width_bits()
    }

    // ---- plaintext mirror arithmetic -----------------------------------

    /// Reduce `v` into the represented range `[-B^k/2, B^k/2)`.
    pub fn wrap(&self, v: i64) -> i64 {
        let m = self.modulus();
        let r = v.rem_euclid(m);
        if r >= m / 2 { r - m } else { r }
    }

    /// Canonical little-endian digits of `wrap(v)`: unsigned digits with
    /// a signed top limb.
    pub fn encode(&self, v: i64) -> Vec<i64> {
        let b = self.base();
        let mut x = self.wrap(v);
        let mut digits = Vec::with_capacity(self.limbs);
        for _ in 0..self.limbs - 1 {
            digits.push(x.rem_euclid(b));
            x = x.div_euclid(b);
        }
        digits.push(x); // top quotient is already in [-B/2, B/2)
        digits
    }

    /// Value of a (not necessarily canonical) limb vector, Σ dᵢ·Bⁱ.
    /// Exact as long as the true value fits i64 — limbs here are small
    /// (≤ native_cap) and width ≤ 32 bits, so it always does.
    pub fn decode(&self, limbs: &[i64]) -> i64 {
        assert_eq!(limbs.len(), self.limbs, "radix decode: wrong limb count");
        let mut acc = 0i64;
        for (i, &d) in limbs.iter().enumerate() {
            acc += d << (self.limb_bits * i as u32);
        }
        acc
    }

    /// Plaintext carry ripple: bring arbitrary in-range limbs back to
    /// canonical form. Mirrors the PBS ripple the legalizer emits
    /// (`carry_split` per non-top limb, `wrap_digit` on the top).
    pub fn canonicalize(&self, limbs: &[i64]) -> Vec<i64> {
        assert_eq!(limbs.len(), self.limbs, "radix canonicalize: wrong limb count");
        let b = self.base();
        let mut out = Vec::with_capacity(self.limbs);
        let mut carry = 0i64;
        for (i, &d) in limbs.iter().enumerate() {
            let s = d + carry;
            if i + 1 < self.limbs {
                let (m, c) = carry_split(s, b);
                out.push(m);
                carry = c;
            } else {
                out.push(wrap_digit(s, b));
            }
        }
        out
    }
}

/// Split `s` into a canonical message digit and its carry:
/// `s = m + c·base` with `m ∈ [0, base)`.
pub fn carry_split(s: i64, base: i64) -> (i64, i64) {
    (s.rem_euclid(base), s.div_euclid(base))
}

/// Wrap `s` into the signed top-digit range `[-base/2, base/2)`.
pub fn wrap_digit(s: i64, base: i64) -> i64 {
    let r = s.rem_euclid(base);
    if r >= base / 2 { r - base } else { r }
}

/// Digit `j` of a narrow value: `j` euclidean divisions by `base`, then
/// either the remainder (`top = false`) or the remaining signed quotient
/// (`top = true`). The quotient digit makes a partial decomposition
/// exact: `Σ_{i<j} rem_i·Bⁱ + quot_j·Bʲ = x` for any signed `x`.
pub fn decomp_digit(mut x: i64, base: i64, j: usize, top: bool) -> i64 {
    for _ in 0..j {
        x = x.div_euclid(base);
    }
    if top { x } else { x.rem_euclid(base) }
}

/// Largest `limb_bits` whose [`RadixSpec`] capacity invariant holds at
/// `native_bits` (i.e. `2·(B-1) ≤ add_cap`). Panics below 4 native bits,
/// where no base leaves carry headroom.
pub fn max_limb_bits_for(native_bits: u32) -> u32 {
    for w in (1..native_bits).rev() {
        let base = 1i64 << w;
        let cap = (1i64 << (native_bits - 1)) - 1;
        let carry_cap = cap / base + 1;
        let add_cap = cap - carry_cap;
        if 2 * (base - 1) <= add_cap {
            return w;
        }
    }
    panic!("radix: no limb width fits a native message space of {native_bits} bits (need >= 4)");
}

// ---- configuration -----------------------------------------------------

/// Forced native width for the legalizer, overriding parameter sets:
/// 0 = unset (defer to the `FHE_RADIX_NATIVE_BITS` environment knob).
static RADIX_NATIVE_OVERRIDE: AtomicU32 = AtomicU32::new(0);

/// Programmatic override for the native message-space width the radix
/// legalizer assumes (`None` restores the environment default). Used by
/// tests and the forced-radix CI leg to make legalization fire on plans
/// whose parameter sets would otherwise hold the declared width natively.
pub fn set_radix_native_bits(bits: Option<u32>) {
    RADIX_NATIVE_OVERRIDE.store(bits.unwrap_or(0), Ordering::SeqCst);
}

/// Forced native width, if any: the programmatic override beats the
/// `FHE_RADIX_NATIVE_BITS` environment variable.
pub fn radix_native_override() -> Option<u32> {
    match RADIX_NATIVE_OVERRIDE.load(Ordering::SeqCst) {
        0 => std::env::var("FHE_RADIX_NATIVE_BITS").ok().and_then(|v| v.parse().ok()),
        n => Some(n),
    }
}

/// Legalizer configuration: how wide the native message space is and how
/// to slice declared-wide values into limbs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RadixConfig {
    /// Native message-space width. `None` disables legalization.
    pub native_bits: Option<u32>,
    /// Bits per limb; `None` picks [`max_limb_bits_for`] the native width.
    pub limb_bits: Option<u32>,
}

impl RadixConfig {
    /// Explicit config (env/override-immune); see [`Self::for_params`]
    /// for the production path.
    pub fn new(native_bits: u32) -> Self {
        RadixConfig { native_bits: Some(native_bits), limb_bits: None }
    }

    /// Production config for a parameter set: native width is the set's
    /// `message_bits`, lowered by [`set_radix_native_bits`] /
    /// `FHE_RADIX_NATIVE_BITS` when forced (the forced-radix CI leg).
    pub fn for_params(p: &TfheParams) -> Self {
        let mut native = p.message_bits;
        if let Some(forced) = radix_native_override() {
            native = native.min(forced.max(4));
        }
        RadixConfig { native_bits: Some(native), limb_bits: None }
    }

    /// Fix the per-limb width instead of deriving it from the native
    /// space (e.g. `limb_bits = 2` at 8 native bits yields span-4 digit
    /// groups, the ϑ = 2 packing showcase).
    pub fn with_limb_bits(mut self, w: u32) -> Self {
        self.limb_bits = Some(w);
        self
    }

    /// Native width this config legalizes against, if enabled.
    pub fn effective_native(&self) -> Option<u32> {
        self.native_bits
    }

    /// Spec for a node declared `declared` bits wide, or `None` when the
    /// native space already holds it (legalization is a no-op).
    pub fn spec_for(&self, declared: u32) -> Option<RadixSpec> {
        let native = self.native_bits?;
        if declared <= native {
            return None;
        }
        let w = self.limb_bits.unwrap_or_else(|| max_limb_bits_for(native));
        Some(RadixSpec::for_width(declared, w, native))
    }
}

// ---- per-plan legalization record --------------------------------------

/// What the legalization pass did to one plan: attached to the rewritten
/// [`CircuitPlan`](super::plan::CircuitPlan) so executors, metrics, and
/// tests can interpret the widened output layout without re-deriving it.
#[derive(Clone, Debug)]
pub struct RadixInfo {
    /// Limb shape every wide value in the plan uses.
    pub spec: RadixSpec,
    /// Number of distinct narrow sources decomposed into limbs.
    pub widened: usize,
    /// Carry-propagation LUT evaluations emitted (message/carry/top-wrap
    /// tables), excluding the decomposition digit LUTs.
    pub carry_luts: u64,
    /// Blind rotations those carry LUTs cost after ϑ-packing (message +
    /// carry of one limb share a rotation at budget ≥ 2).
    pub carry_rotations: u64,
    /// Per *original* output: `true` if that output was widened into
    /// `spec.limbs` consecutive slots of the rewritten plan's outputs.
    pub wide_outputs: Vec<bool>,
}

impl RadixInfo {
    /// Total output slots of the legalized plan (wide outputs occupy
    /// `spec.limbs` consecutive slots each).
    pub fn n_slots(&self) -> usize {
        self.wide_outputs
            .iter()
            .map(|&w| if w { self.spec.limbs } else { 1 })
            .sum()
    }

    /// Recombine a legalized plan's decrypted outputs back into the
    /// original circuit's output list (wide slots decoded via Σ dᵢ·Bⁱ).
    pub fn decode_outputs(&self, slots: &[i64]) -> Vec<i64> {
        assert_eq!(slots.len(), self.n_slots(), "radix: wrong output slot count");
        let mut out = Vec::with_capacity(self.wide_outputs.len());
        let mut i = 0;
        for &wide in &self.wide_outputs {
            if wide {
                out.push(self.spec.decode(&slots[i..i + self.spec.limbs]));
                i += self.spec.limbs;
            } else {
                out.push(slots[i]);
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng64;
    use crate::util::prop::{prop_assert, prop_assert_eq, prop_check};

    fn specs_under_test() -> Vec<RadixSpec> {
        vec![
            RadixSpec::new(5, 2, 8),  // k=2 grid point
            RadixSpec::new(3, 3, 6),  // k=3 grid point
            RadixSpec::new(2, 4, 6),  // k=4 grid point
            RadixSpec::new(2, 5, 8),  // span-4 packing showcase shape
            RadixSpec::new(1, 6, 4),  // smallest viable native space
        ]
    }

    #[test]
    fn capacity_invariants_hold() {
        for spec in specs_under_test() {
            assert!(spec.add_cap() + spec.carry_cap() == spec.native_cap());
            assert!(2 * spec.digit_max() <= spec.add_cap(), "{spec:?}");
            assert!(spec.span() <= spec.limbs, "{spec:?}: span must not exceed limbs");
        }
    }

    #[test]
    fn max_limb_bits_matches_hand_checks() {
        assert_eq!(max_limb_bits_for(8), 5);
        assert_eq!(max_limb_bits_for(6), 3);
        assert_eq!(max_limb_bits_for(5), 2);
        assert_eq!(max_limb_bits_for(4), 1);
    }

    #[test]
    #[should_panic(expected = "no limb width fits")]
    fn native_three_bits_has_no_limb_width() {
        max_limb_bits_for(3);
    }

    #[test]
    fn encode_decode_round_trips_whole_range() {
        // Exhaustive over the full represented range for every spec.
        for spec in specs_under_test() {
            let m = spec.modulus();
            for v in -m / 2..m / 2 {
                let digits = spec.encode(v);
                assert_eq!(digits.len(), spec.limbs);
                for (i, &d) in digits.iter().enumerate() {
                    if i + 1 < spec.limbs {
                        assert!((0..spec.base()).contains(&d), "{spec:?} v={v}: digit {d}");
                    } else {
                        assert!(
                            (-spec.base() / 2..spec.base() / 2).contains(&d),
                            "{spec:?} v={v}: top digit {d}"
                        );
                    }
                }
                assert_eq!(spec.decode(&digits), v, "{spec:?}");
            }
        }
    }

    #[test]
    fn wrap_is_twos_complement() {
        let spec = RadixSpec::new(3, 2, 6); // 6-bit representation
        assert_eq!(spec.wrap(31), 31);
        assert_eq!(spec.wrap(32), -32); // overflow wraps to max-negative
        assert_eq!(spec.wrap(-33), 31);
        assert_eq!(spec.wrap(64), 0);
    }

    #[test]
    fn max_negative_edge_cases() {
        for spec in specs_under_test() {
            let min = -spec.modulus() / 2;
            let digits = spec.encode(min);
            // -B^k/2 is all-zero digits below a top limb of -B/2.
            for &d in &digits[..spec.limbs - 1] {
                assert_eq!(d, 0, "{spec:?}");
            }
            assert_eq!(digits[spec.limbs - 1], -spec.base() / 2, "{spec:?}");
            assert_eq!(spec.decode(&digits), min);
            // Negating max-negative wraps back to itself (two's complement).
            assert_eq!(spec.wrap(-min), min, "{spec:?}");
        }
    }

    #[test]
    fn all_carries_ripple_end_to_end() {
        // Limbs all at digit_max with a +1 in the lowest: the carry must
        // ripple through every position (… B-1, B-1, B ⇒ 0, 0, …, +1 top).
        for spec in specs_under_test() {
            let mut limbs = vec![spec.digit_max(); spec.limbs];
            limbs[0] += 1;
            let canon = spec.canonicalize(&limbs);
            assert_eq!(spec.decode(&canon), spec.wrap(spec.decode(&limbs)), "{spec:?}");
            for &d in &canon[..spec.limbs - 1] {
                assert_eq!(d, 0, "{spec:?}: ripple must clear every message digit");
            }
        }
    }

    #[test]
    fn canonicalize_matches_encode_of_decode() {
        // Property: for limbs drifting anywhere inside add_cap (the
        // legalizer's invariant), the PBS-shaped ripple equals the
        // canonical digits of the represented value — including signed
        // digits below the top position (partial decompositions).
        for spec in specs_under_test() {
            prop_check(&format!("canonicalize {spec:?}"), 256, |rng| {
                let cap = spec.add_cap();
                let limbs: Vec<i64> =
                    (0..spec.limbs).map(|_| rng.next_range_i64(-cap, cap)).collect();
                let canon = spec.canonicalize(&limbs);
                let want = spec.encode(spec.decode(&limbs));
                prop_assert_eq(canon, want, "ripple vs encode∘decode")
            });
        }
    }

    #[test]
    fn decomp_digit_partial_sums_are_exact() {
        // Signed/unsigned boundary: a quotient digit at position j makes
        // the j+1-digit partial decomposition exact for negative values.
        for spec in specs_under_test() {
            prop_check(&format!("decomp {spec:?}"), 256, |rng| {
                let cap = spec.native_cap();
                let x = rng.next_range_i64(-cap, cap);
                let b = spec.base();
                for j in 0..spec.span() {
                    let mut acc = 0i64;
                    for i in 0..j {
                        acc += decomp_digit(x, b, i, false) << (spec.limb_bits * i as u32);
                    }
                    acc += decomp_digit(x, b, j, true) << (spec.limb_bits * j as u32);
                    prop_assert_eq(acc, x, &format!("partial at j={j}"))?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn carry_split_and_wrap_digit_cover_signed_boundary() {
        prop_check("carry_split", 512, |rng| {
            let base = 1i64 << rng.next_range_i64(1, 6);
            let s = rng.next_range_i64(-1000, 1000);
            let (m, c) = carry_split(s, base);
            prop_assert((0..base).contains(&m), "message digit in range")?;
            prop_assert_eq(m + c * base, s, "split reassembles")?;
            let w = wrap_digit(s, base);
            prop_assert((-base / 2..base / 2).contains(&w), "top digit in range")?;
            prop_assert_eq((w - s).rem_euclid(base), 0, "wrap preserves residue")
        });
    }

    #[test]
    fn config_spec_for_gates_on_native_width() {
        let cfg = RadixConfig::new(6);
        assert!(cfg.spec_for(6).is_none(), "fits native: no-op");
        assert!(cfg.spec_for(4).is_none());
        let spec = cfg.spec_for(9).unwrap();
        assert_eq!((spec.limb_bits, spec.limbs, spec.native_bits), (3, 3, 6));
        let spec = cfg.with_limb_bits(2).spec_for(8).unwrap();
        assert_eq!((spec.limb_bits, spec.limbs), (2, 4));
        assert_eq!(RadixConfig::default().spec_for(64), None, "disabled config");
    }

    #[test]
    fn forced_native_override_lowers_for_params() {
        let p = TfheParams::test_for_bits(6);
        assert_eq!(RadixConfig::for_params(&p).native_bits, Some(6));
        set_radix_native_bits(Some(4));
        let forced = RadixConfig::for_params(&p);
        set_radix_native_bits(None);
        assert_eq!(forced.native_bits, Some(4));
        // The override only ever lowers: an 8-bit force on 6-bit params
        // stays at the params' own width.
        set_radix_native_bits(Some(8));
        let kept = RadixConfig::for_params(&p);
        set_radix_native_bits(None);
        assert_eq!(kept.native_bits, Some(6));
    }

    #[test]
    fn info_decodes_mixed_output_layouts() {
        let spec = RadixSpec::new(3, 3, 6);
        let info = RadixInfo {
            spec,
            widened: 1,
            carry_luts: 0,
            carry_rotations: 0,
            wide_outputs: vec![false, true, false],
        };
        assert_eq!(info.n_slots(), 5);
        let mut slots = vec![7];
        slots.extend(spec.encode(-200));
        slots.push(-3);
        assert_eq!(info.decode_outputs(&slots), vec![7, -200, -3]);
    }
}
