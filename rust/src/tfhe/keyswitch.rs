//! LWE key switching (S4): move a ciphertext from the sample-extracted
//! key (dimension k·N) back to the small LWE key (dimension n) so the
//! output of a PBS is again a "normal" ciphertext.

use super::lwe::{LweCiphertext, LweSecretKey};
use super::params::DecompParams;
use super::torus::Torus;
use crate::util::prng::Xoshiro256;

/// Signed decomposition of a single torus scalar (most-significant first).
pub fn decompose_scalar(t: Torus, d: DecompParams) -> Vec<i64> {
    let b_log = d.base_log as u32;
    let half_b = 1i64 << (b_log - 1);
    let total = (d.level as u32) * b_log;
    let rounding = 1u64 << (64 - total - 1);
    let mut v = t.wrapping_add(rounding) >> (64 - total);
    let mut digits = vec![0i64; d.level];
    let mut carry = 0i64;
    for l in (0..d.level).rev() {
        let mut digit = ((v & ((1u64 << b_log) - 1)) as i64) + carry;
        v >>= b_log;
        carry = 0;
        if digit >= half_b {
            digit -= 1i64 << b_log;
            carry = 1;
        }
        digits[l] = digit;
    }
    digits
}

/// Key-switching key from `from_key` (dim N_in) to `to_key` (dim n_out).
#[derive(Clone, Debug, PartialEq)]
pub struct KeySwitchKey {
    /// `ksk[j][l]` encrypts `s_in[j] · q / B^(l+1)` under `to_key`.
    rows: Vec<Vec<LweCiphertext>>,
    pub decomp: DecompParams,
    pub out_dim: usize,
}

impl KeySwitchKey {
    pub fn generate(
        from_key: &LweSecretKey,
        to_key: &LweSecretKey,
        decomp: DecompParams,
        noise_std: f64,
        rng: &mut Xoshiro256,
    ) -> Self {
        let rows = from_key
            .bits
            .iter()
            .map(|&s| {
                (1..=decomp.level)
                    .map(|l| {
                        let shift = 64 - (decomp.base_log * l) as u32;
                        let msg = s.wrapping_shl(shift);
                        LweCiphertext::encrypt(msg, to_key, noise_std, rng)
                    })
                    .collect()
            })
            .collect();
        KeySwitchKey { rows, decomp, out_dim: to_key.dim() }
    }

    /// Decomposition rows — read access for the storage codec
    /// (`tfhe::codec`).
    pub(crate) fn rows(&self) -> &[Vec<LweCiphertext>] {
        &self.rows
    }

    /// Rebuild from decoded rows (`tfhe::codec`).
    pub(crate) fn from_material(
        rows: Vec<Vec<LweCiphertext>>,
        decomp: DecompParams,
        out_dim: usize,
    ) -> Self {
        KeySwitchKey { rows, decomp, out_dim }
    }

    /// Switch `ct` (under `from_key`) to the target key:
    /// `out = (0, b) − Σ_j Σ_l digit_{j,l} · KSK[j][l]`.
    pub fn keyswitch(&self, ct: &LweCiphertext) -> LweCiphertext {
        assert_eq!(ct.dim(), self.rows.len(), "ciphertext dim does not match KSK input dim");
        let mut out = LweCiphertext::trivial(ct.body, self.out_dim);
        for (j, &a) in ct.mask.iter().enumerate() {
            let digits = decompose_scalar(a, self.decomp);
            for (l, &dig) in digits.iter().enumerate() {
                if dig == 0 {
                    continue;
                }
                let row = &self.rows[j][l];
                let du = dig as u64;
                for (o, r) in out.mask.iter_mut().zip(row.mask.iter()) {
                    *o = o.wrapping_sub(r.wrapping_mul(du));
                }
                out.body = out.body.wrapping_sub(row.body.wrapping_mul(du));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::torus::{torus_distance, torus_from_f64};

    #[test]
    fn scalar_decomposition_recomposes() {
        let d = DecompParams::new(4, 5);
        for t in [0u64, 1 << 60, u64::MAX, 0x123456789ABCDEF0] {
            let digits = decompose_scalar(t, d);
            let mut rec = 0u64;
            for (l, &dig) in digits.iter().enumerate() {
                let shift = 64 - (d.base_log * (l + 1)) as u32;
                rec = rec.wrapping_add((dig as u64).wrapping_shl(shift));
            }
            let err = (rec.wrapping_sub(t)) as i64;
            let bound = 1i64 << (64 - 20 - 1);
            assert!(err.abs() <= bound, "t={t:#x} err={err}");
        }
    }

    #[test]
    fn keyswitch_preserves_message() {
        let mut rng = Xoshiro256::new(44);
        let big = LweSecretKey::generate(1024, &mut rng);
        let small = LweSecretKey::generate(400, &mut rng);
        let ksk = KeySwitchKey::generate(
            &big,
            &small,
            DecompParams::new(4, 5),
            1.0 / (1u64 << 30) as f64,
            &mut rng,
        );
        for frac in [0.25, -0.125, 0.4] {
            let m = torus_from_f64(frac);
            let ct = LweCiphertext::encrypt(m, &big, 1.0 / (1u64 << 35) as f64, &mut rng);
            let switched = ksk.keyswitch(&ct);
            assert_eq!(switched.dim(), 400);
            let dec = switched.decrypt(&small);
            assert!(torus_distance(dec, m) < 1e-3, "frac={frac}: {}", torus_distance(dec, m));
        }
    }
}
