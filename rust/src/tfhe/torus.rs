//! Discretized torus arithmetic (S4).
//!
//! TFHE works over the real torus T = R/Z; implementations discretize it
//! to `q = 2^64` levels, represented as `u64` with wrapping arithmetic:
//! the torus element is `t / 2^64`. All scheme noise is Gaussian on the
//! torus with standard deviation given as a *fraction of the torus*.

use crate::util::prng::Xoshiro256;

/// One torus element, q = 2^64 discretization.
pub type Torus = u64;

/// Convert a real in (−0.5, 0.5] (fraction of the torus) to a torus element.
pub fn torus_from_f64(x: f64) -> Torus {
    // Wrap into [0, 1), scale. f64 has 53 mantissa bits; the low 11 bits
    // are below fresh-noise level for every parameter set we use.
    let frac = x - x.floor();
    (frac * 2f64.powi(64)) as u64
}

/// Interpret a torus element as a real in [−0.5, 0.5) (centered).
pub fn torus_to_f64(t: Torus) -> f64 {
    (t as i64) as f64 / 2f64.powi(64)
}

/// Gaussian torus noise with standard deviation `std` (torus fraction).
pub fn gaussian_torus(std: f64, rng: &mut Xoshiro256) -> Torus {
    let z = rng.next_gaussian_std(std);
    // Round to the nearest torus level (wrapping).
    (z * 2f64.powi(64)).round() as i64 as u64
}

/// Round a torus value to the nearest multiple of `2^64 / modulus`
/// and return the multiple index in `[0, modulus)`. This is the
/// "mod switch" used before blind rotation (modulus = 2N) and the final
/// decode rounding (modulus = message space size).
pub fn round_to_modulus(t: Torus, modulus: u64) -> u64 {
    debug_assert!(modulus.is_power_of_two(), "modulus must be a power of two");
    let shift = 64 - modulus.trailing_zeros();
    // Add half a step before truncating = round to nearest.
    let half = 1u64 << (shift - 1);
    (t.wrapping_add(half)) >> shift
        & (modulus - 1)
}

/// Centered signed distance |a − b| on the torus, as a fraction.
pub fn torus_distance(a: Torus, b: Torus) -> f64 {
    torus_to_f64(a.wrapping_sub(b)).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_roundtrip_small_values() {
        for x in [0.0, 0.25, -0.25, 0.123456, -0.4999] {
            let t = torus_from_f64(x);
            let back = torus_to_f64(t);
            assert!((back - x).abs() < 1e-9, "{x} -> {back}");
        }
    }

    #[test]
    fn wrapping_addition_is_torus_addition() {
        let a = torus_from_f64(0.4);
        let b = torus_from_f64(0.3);
        // 0.7 wraps to −0.3 in centered representation.
        let s = torus_to_f64(a.wrapping_add(b));
        assert!((s - (-0.3)).abs() < 1e-9, "{s}");
    }

    #[test]
    fn round_to_modulus_nearest() {
        // modulus 8: slots at multiples of 2^61.
        let slot = 1u64 << 61;
        assert_eq!(round_to_modulus(3 * slot, 8), 3);
        assert_eq!(round_to_modulus(3 * slot + (slot >> 1) - 1, 8), 3);
        assert_eq!(round_to_modulus(3 * slot + (slot >> 1), 8), 4);
        // Wraps: just below the top rounds to 0.
        assert_eq!(round_to_modulus(u64::MAX, 8), 0);
    }

    #[test]
    fn gaussian_torus_scale() {
        let mut rng = Xoshiro256::new(123);
        let std = 2f64.powi(-20);
        let n = 20_000;
        let mut sumsq = 0f64;
        for _ in 0..n {
            let e = torus_to_f64(gaussian_torus(std, &mut rng));
            sumsq += e * e;
        }
        let measured = (sumsq / n as f64).sqrt();
        assert!((measured / std - 1.0).abs() < 0.05, "std {measured} vs {std}");
    }

    #[test]
    fn distance_is_symmetric_and_wraps() {
        let a = torus_from_f64(0.49);
        let b = torus_from_f64(-0.49);
        assert!(torus_distance(a, b) < 0.03); // short way around
        assert!((torus_distance(a, b) - torus_distance(b, a)).abs() < 1e-12);
    }
}
