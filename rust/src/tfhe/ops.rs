//! Encrypted integer operator layer (S5): the Concrete-style ops the
//! attention circuits are written against.
//!
//! `CtInt` is an encrypted signed integer (bias convention, see
//! `encoding`). The op costs mirror the paper's accounting exactly:
//!
//! | op                    | PBS | notes                                  |
//! |-----------------------|-----|----------------------------------------|
//! | add / sub / neg       | 0   | additions are cheap under FHE          |
//! | scalar (literal) mul  | 0   | "multiplication by literals is native" |
//! | relu / abs / square…  | 1   | univariate → one PBS table             |
//! | ct × ct (`ct_mul`)    | 2   | paper eq. 1: PBS(x²/4; a+b) − PBS(x²/4; a−b) |
//!
//! Every univariate op resolves to a [`PreparedLut`] (accumulator built
//! once, not per call): the four standard tables are prepared at context
//! construction, and arbitrary `pbs_fn` closures go through a table-keyed
//! cache, so e.g. the Inhibitor's fused scale-shift-ReLU table is built
//! once per head rather than `T²` times. The `*_many` entry points fan
//! independent jobs across the [`ServerKey::pbs_batch`] worker pool; the
//! worker count comes from `FHE_THREADS` (default: all cores) and can be
//! overridden per context via [`FheContext::set_threads`].

use super::bootstrap::{BatchJob, Lut, PreparedLut, PreparedMultiLut, ServerKey};
use super::encoding::Encoder;
use super::faults::FaultPlan;
use super::lwe::LweCiphertext;
use super::plan::LevelJob;
use crate::error::FheError;
use crate::util::prng::Xoshiro256;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Default PBS worker-thread count: the `FHE_THREADS` environment
/// variable when set (≥ 1), otherwise the machine's available
/// parallelism. This is the knob the coordinator and the benches plumb.
pub fn default_fhe_threads() -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("FHE_THREADS") {
        // Unparseable or zero values fall back to all cores, per the
        // documented default — never silently to a single thread.
        Ok(v) => v.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or(cores),
        Err(_) => cores,
    }
}

/// The softmax-normalizer reciprocal `x ↦ round(num/x)` for `x > 0` (and
/// `num` for `x ≤ 0`, matching the softmax mirror's degenerate row) —
/// the single definition of the table, shared by
/// [`FheContext::prepared_recip`] and the dot-product plan builder.
pub fn recip_fn(num: i64) -> impl Fn(i64) -> i64 {
    move |v| if v > 0 { (num + v / 2) / v } else { num }
}

/// Process-global count of [`CtInt`] clones — the observability hook
/// behind the "input ciphertexts are not copied on the hot path"
/// regression tests. One relaxed atomic add per clone; a ciphertext is
/// n+1 words, so the accounting cost is noise.
static CT_CLONE_COUNT: AtomicU64 = AtomicU64::new(0);

/// Total [`CtInt`] clones performed by this process so far (tests take
/// deltas around the operation under scrutiny).
pub fn ct_clone_count() -> u64 {
    CT_CLONE_COUNT.load(Ordering::Relaxed)
}

/// An encrypted signed integer.
#[derive(Debug)]
pub struct CtInt {
    pub ct: LweCiphertext,
}

impl Clone for CtInt {
    fn clone(&self) -> Self {
        CT_CLONE_COUNT.fetch_add(1, Ordering::Relaxed);
        CtInt { ct: self.ct.clone() }
    }
}

/// Evaluation context: server key + encoder (message layout) + the
/// prepared-LUT cache and worker-thread knob of the batched PBS engine.
pub struct FheContext {
    pub sk: ServerKey,
    pub enc: Encoder,
    /// PBS worker threads used by the `*_many` batch entry points.
    threads: AtomicUsize,
    // Prepared accumulators for the common univariate ops.
    lut_relu: PreparedLut,
    lut_abs: PreparedLut,
    lut_sq4: PreparedLut,
    lut_id: PreparedLut,
    /// Keyed cache for arbitrary `pbs_fn` tables: the (tiny) message-space
    /// table is the key, the (large) prepared accumulator is the value —
    /// collision-proof without requiring callers to name their closures.
    lut_cache: RwLock<HashMap<Vec<u64>, Arc<PreparedLut>>>,
    /// Same idea for packed multi-value accumulators: keyed by the
    /// concatenated member tables (each `message_space` long, so the
    /// length encodes the LUT count and keys cannot collide across group
    /// sizes).
    multi_lut_cache: RwLock<HashMap<Vec<u64>, Arc<PreparedMultiLut>>>,
    /// Armed fault-injection schedule (from `FHE_FAULTS` or
    /// [`Self::set_fault_plan`]); `None` in production. Only the checked
    /// execution paths ([`Self::pbs_level_checked`]) consult it — the
    /// solo/reference paths stay fault-free so differential harnesses
    /// can compare against them.
    faults: RwLock<Option<Arc<FaultPlan>>>,
}

impl FheContext {
    pub fn new(sk: ServerKey) -> Self {
        Self::with_threads(sk, default_fhe_threads())
    }

    /// Build a context with an explicit PBS worker count.
    pub fn with_threads(sk: ServerKey, threads: usize) -> Self {
        let enc = Encoder::new(sk.params);
        let bias = enc.bias() as i64;
        let space = sk.params.message_space() as i64;
        let clamp = |v: i64| -> u64 { v.clamp(0, space - 1) as u64 };
        // LUT index is the *biased* message; value is biased back.
        let lut_relu =
            sk.prepare_lut(&Lut::from_fn(&sk.params, |m| clamp((m as i64 - bias).max(0) + bias)));
        let lut_abs =
            sk.prepare_lut(&Lut::from_fn(&sk.params, |m| clamp((m as i64 - bias).abs() + bias)));
        // floor(v²/4), saturating at the top of the signed range: the
        // ct_mul caller guarantees |a±b| small enough that no saturation
        // occurs on the values that matter.
        let lut_sq4 = sk.prepare_lut(&Lut::from_fn(&sk.params, |m| {
            let v = m as i64 - bias;
            clamp((v * v).div_euclid(4) + bias)
        }));
        // Identity (noise-refresh) table.
        let lut_id = sk.prepare_lut(&Lut::from_fn(&sk.params, |m| m));
        FheContext {
            sk,
            enc,
            threads: AtomicUsize::new(threads.max(1)),
            lut_relu,
            lut_abs,
            lut_sq4,
            lut_id,
            lut_cache: RwLock::new(HashMap::new()),
            multi_lut_cache: RwLock::new(HashMap::new()),
            faults: RwLock::new(FaultPlan::from_env()),
        }
    }

    /// Arm (or disarm, with `None`) a fault-injection schedule for this
    /// context. Tests use this to inject deterministic faults without
    /// touching the process environment.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        *self.faults.write().unwrap_or_else(|e| e.into_inner()) = plan;
    }

    /// The armed fault schedule, if any.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.faults.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Largest LUT group the plan rewriter may pack into one blind
    /// rotation under this context's parameter set (1 = packing off).
    pub fn max_multi_lut(&self) -> usize {
        self.sk.params.max_multi_lut()
    }

    /// Current PBS worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads.load(Ordering::Relaxed).max(1)
    }

    /// Override the PBS worker-thread count (shared contexts included:
    /// the coordinator applies its serving-side knob through this).
    pub fn set_threads(&self, n: usize) {
        self.threads.store(n.max(1), Ordering::Relaxed);
    }

    /// Encrypt a signed value (client-side helper for tests/benches —
    /// production clients encrypt with `Encoder` directly).
    pub fn encrypt(
        &self,
        v: i64,
        ck: &super::bootstrap::ClientKey,
        rng: &mut Xoshiro256,
    ) -> CtInt {
        CtInt { ct: self.enc.encrypt_signed(v, ck, rng) }
    }

    pub fn decrypt(&self, x: &CtInt, ck: &super::bootstrap::ClientKey) -> i64 {
        self.enc.decrypt_signed(&x.ct, ck)
    }

    /// A trivial (public constant) ciphertext.
    pub fn constant(&self, v: i64) -> CtInt {
        let m = (v + self.enc.bias() as i64) as u64;
        CtInt { ct: LweCiphertext::trivial(self.enc.encode(m), self.sk.params.lwe_dim) }
    }

    // ----- linear ops (0 PBS) -----

    /// a + b (bias corrected).
    pub fn add(&self, a: &CtInt, b: &CtInt) -> CtInt {
        CtInt { ct: a.ct.add(&b.ct).sub_plain(self.enc.encode(self.enc.bias())) }
    }

    /// a − b (bias corrected).
    pub fn sub(&self, a: &CtInt, b: &CtInt) -> CtInt {
        CtInt { ct: a.ct.sub(&b.ct).add_plain(self.enc.encode(self.enc.bias())) }
    }

    /// −a.
    pub fn neg(&self, a: &CtInt) -> CtInt {
        let two_bias = self.enc.encode(self.enc.bias()).wrapping_mul(2);
        CtInt { ct: a.ct.neg().add_plain(two_bias) }
    }

    /// a + constant.
    pub fn add_const(&self, a: &CtInt, c: i64) -> CtInt {
        let off = (c as u64).wrapping_mul(self.sk.params.delta());
        CtInt { ct: a.ct.add_plain(off) }
    }

    /// a · c for a plaintext literal c ("constant-to-variable" multiply —
    /// no PBS, matching the paper's cost model).
    pub fn scalar_mul(&self, a: &CtInt, c: i64) -> CtInt {
        // (m)·c carries bias·c; correct back to a single bias.
        let ct = a.ct.scalar_mul(c);
        let corr = ((c - 1) as u64)
            .wrapping_mul(self.enc.bias())
            .wrapping_mul(self.sk.params.delta());
        CtInt { ct: ct.sub_plain(corr) }
    }

    /// Sum of many ciphertexts (0 PBS; noise grows linearly).
    pub fn sum(&self, xs: &[CtInt]) -> CtInt {
        let refs: Vec<&CtInt> = xs.iter().collect();
        self.sum_refs(&refs)
    }

    /// [`Self::sum`] over borrowed operands (the plan executor's form —
    /// identical math, so plan and direct paths stay bit-identical).
    pub fn sum_refs(&self, xs: &[&CtInt]) -> CtInt {
        assert!(!xs.is_empty());
        let mut acc = xs[0].ct.clone();
        for x in &xs[1..] {
            acc.add_assign(&x.ct);
        }
        let corr = ((xs.len() - 1) as u64)
            .wrapping_mul(self.enc.bias())
            .wrapping_mul(self.sk.params.delta());
        CtInt { ct: acc.sub_plain(corr) }
    }

    // ----- univariate ops (1 PBS each) -----

    /// Build (or fetch from the cache) the prepared LUT for an arbitrary
    /// univariate signed function. The closure is evaluated over the
    /// (tiny) message space to form the table; the expensive accumulator
    /// construction happens only on a cache miss.
    pub fn prepared_fn(&self, f: impl Fn(i64) -> i64) -> Arc<PreparedLut> {
        self.prepared_dyn(&f)
    }

    /// The message-space table of a signed univariate function — the one
    /// definition both the single-LUT and the packed multi-LUT paths
    /// build from, so a packed member's table is always identical to its
    /// standalone table (the packing rewrite can then never change a
    /// decoded value).
    fn signed_table(&self, f: &dyn Fn(i64) -> i64) -> Lut {
        let bias = self.enc.bias() as i64;
        let space = self.sk.params.message_space() as i64;
        Lut::from_fn(&self.sk.params, |m| {
            (f(m as i64 - bias) + bias).clamp(0, space - 1) as u64
        })
    }

    /// Dynamic-dispatch form of [`Self::prepared_fn`] — the circuit-plan
    /// executor resolves its LUT registry (`Arc<dyn Fn>`) through this.
    pub fn prepared_dyn(&self, f: &dyn Fn(i64) -> i64) -> Arc<PreparedLut> {
        let lut = self.signed_table(f);
        if let Some(hit) = self.lut_cache.read().unwrap().get(&lut.table) {
            return Arc::clone(hit);
        }
        let prepared = Arc::new(self.sk.prepare_lut(&lut));
        let mut cache = self.lut_cache.write().unwrap();
        Arc::clone(cache.entry(lut.table).or_insert(prepared))
    }

    /// Build (or fetch from the cache) the packed accumulator evaluating
    /// several signed univariate functions of one input in a single
    /// blind rotation ([`ServerKey::pbs_multi`]). The group size must
    /// respect [`Self::max_multi_lut`].
    pub fn prepared_multi_dyn(&self, fns: &[&dyn Fn(i64) -> i64]) -> Arc<PreparedMultiLut> {
        assert!(
            fns.len() <= self.max_multi_lut(),
            "group of {} LUTs exceeds this parameter set's multi-value budget {}",
            fns.len(),
            self.max_multi_lut()
        );
        let luts: Vec<Lut> = fns.iter().map(|f| self.signed_table(*f)).collect();
        let key: Vec<u64> = luts.iter().flat_map(|l| l.table.iter().copied()).collect();
        if let Some(hit) = self.multi_lut_cache.read().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        let refs: Vec<&Lut> = luts.iter().collect();
        let prepared = Arc::new(self.sk.prepare_multi_lut(&refs));
        let mut cache = self.multi_lut_cache.write().unwrap();
        Arc::clone(cache.entry(key).or_insert(prepared))
    }

    /// The prepared reciprocal table of [`recip_fn`] — the encrypted
    /// softmax normalizer.
    pub fn prepared_recip(&self, num: i64) -> Arc<PreparedLut> {
        self.prepared_fn(recip_fn(num))
    }

    /// Apply an arbitrary univariate signed function (1 PBS). The LUT is
    /// resolved through the prepared-table cache.
    pub fn pbs_fn(&self, a: &CtInt, f: impl Fn(i64) -> i64) -> CtInt {
        let lut = self.prepared_fn(f);
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &lut) }
    }

    /// ReLU x⁺ (1 PBS).
    pub fn relu(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &self.lut_relu) }
    }

    /// |x| (1 PBS).
    pub fn abs(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &self.lut_abs) }
    }

    /// floor(x²/4) (1 PBS) — the paper's eq. 2 table.
    pub fn square_quarter(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &self.lut_sq4) }
    }

    /// Identity refresh: resets noise without changing the value (1 PBS).
    pub fn refresh(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &self.lut_id) }
    }

    /// Rounded reciprocal scaled by `num`: x ↦ round(num/x) for x>0, used
    /// by the encrypted softmax normalization (1 PBS).
    pub fn recip_scaled(&self, a: &CtInt, num: i64) -> CtInt {
        let lut = self.prepared_recip(num);
        CtInt { ct: self.sk.pbs_prepared(&a.ct, &lut) }
    }

    // ----- batched univariate ops (1 PBS per element, parallel) -----

    /// Evaluate one prepared LUT over many independent ciphertexts via
    /// the multi-threaded batch engine. Outputs are bit-identical to the
    /// sequential path and ordered like the inputs.
    pub fn pbs_many(&self, xs: &[CtInt], lut: &PreparedLut) -> Vec<CtInt> {
        let jobs: Vec<(&LweCiphertext, &PreparedLut)> =
            xs.iter().map(|x| (&x.ct, lut)).collect();
        self.pbs_jobs(&jobs).into_iter().map(|ct| CtInt { ct }).collect()
    }

    /// Run heterogeneous (ciphertext, LUT) jobs through the batch engine
    /// under this context's worker budget — one circuit level (possibly
    /// spanning several fused requests) per call.
    pub fn pbs_jobs(&self, jobs: &[(&LweCiphertext, &PreparedLut)]) -> Vec<LweCiphertext> {
        self.sk.pbs_batch(jobs, self.threads())
    }

    /// Run one plan level's jobs — single bootstraps and multi-value
    /// bootstraps mixed — through the batch engine. Outputs are
    /// flattened in job order (a multi job contributes its LUT count of
    /// consecutive ciphertexts), exactly the order
    /// [`super::plan::PlanRun::supply`] expects.
    pub fn pbs_level(&self, jobs: &[LevelJob]) -> Vec<CtInt> {
        let refs: Vec<BatchJob> = jobs.iter().map(LevelJob::as_batch_job).collect();
        self.sk
            .pbs_batch_mixed(&refs, self.threads())
            .into_iter()
            .map(|ct| CtInt { ct })
            .collect()
    }

    /// [`Self::pbs_level`] with per-job panic isolation: one `Result`
    /// per job, each `Ok` carrying the job's outputs (a multi job
    /// contributes its LUT count of ciphertexts) in packing order. A
    /// poisoned job — injected via the armed [`FaultPlan`] or a genuine
    /// bug — fails only itself; survivors stay bit-identical to
    /// [`Self::pbs_level`]. This is the serving path's entry point; the
    /// unchecked one remains the solo/reference path.
    pub fn pbs_level_checked(&self, jobs: &[LevelJob]) -> Vec<Result<Vec<CtInt>, FheError>> {
        let refs: Vec<BatchJob> = jobs.iter().map(LevelJob::as_batch_job).collect();
        let faults = self.fault_plan();
        self.sk
            .pbs_batch_mixed_isolated(&refs, self.threads(), faults.as_deref())
            .into_iter()
            .map(|r| r.map(|cts| cts.into_iter().map(|ct| CtInt { ct }).collect()))
            .collect()
    }

    /// Batched ReLU.
    pub fn relu_many(&self, xs: &[CtInt]) -> Vec<CtInt> {
        self.pbs_many(xs, &self.lut_relu)
    }

    /// Batched |x|.
    pub fn abs_many(&self, xs: &[CtInt]) -> Vec<CtInt> {
        self.pbs_many(xs, &self.lut_abs)
    }

    /// Batched floor(x²/4).
    pub fn square_quarter_many(&self, xs: &[CtInt]) -> Vec<CtInt> {
        self.pbs_many(xs, &self.lut_sq4)
    }

    /// Batched identity noise refresh.
    pub fn refresh_many(&self, xs: &[CtInt]) -> Vec<CtInt> {
        self.pbs_many(xs, &self.lut_id)
    }

    // ----- the paper's headline op -----

    /// Ciphertext × ciphertext multiplication via two PBS (paper eq. 1):
    /// `ab = PBS(x²/4; a+b) − PBS(x²/4; a−b)`.
    ///
    /// Exact for integers because a+b and a−b share parity, so the two
    /// floor errors cancel. Requires |a±b| within the signed range — this
    /// is exactly the "up to two bits higher precision" cost the paper's
    /// Table 2 attributes to the dot-product variant.
    pub fn ct_mul(&self, a: &CtInt, b: &CtInt) -> CtInt {
        let s = self.add(a, b);
        let d = self.sub(a, b);
        let p1 = self.square_quarter(&s);
        let p2 = self.square_quarter(&d);
        self.sub(&p1, &p2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Rng64;

    fn setup() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(31337);
        // 4 bits so ct_mul has headroom for a±b and ab.
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    #[test]
    fn linear_ops_cost_zero_pbs() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let a = ctx.encrypt(3, &ck, &mut rng);
        let b = ctx.encrypt(-2, &ck, &mut rng);
        let before = pbs_count();
        let add = ctx.add(&a, &b);
        let sub = ctx.sub(&a, &b);
        let neg = ctx.neg(&a);
        let smul = ctx.scalar_mul(&a, 2);
        let addc = ctx.add_const(&a, 4);
        assert_eq!(pbs_count(), before, "linear ops must not bootstrap");
        assert_eq!(ctx.decrypt(&add, &ck), 1);
        assert_eq!(ctx.decrypt(&sub, &ck), 5);
        assert_eq!(ctx.decrypt(&neg, &ck), -3);
        assert_eq!(ctx.decrypt(&smul, &ck), 6);
        assert_eq!(ctx.decrypt(&addc, &ck), 7);
    }

    #[test]
    fn relu_and_abs_over_range() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        for v in [-8i64, -5, -1, 0, 1, 4, 7] {
            let x = ctx.encrypt(v, &ck, &mut rng);
            assert_eq!(ctx.decrypt(&ctx.relu(&x), &ck), v.max(0), "relu({v})");
            assert_eq!(ctx.decrypt(&ctx.abs(&x), &ck), v.abs().min(7), "abs({v})");
        }
    }

    #[test]
    fn ct_mul_is_exact_and_costs_two_pbs() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        // |a|,|b| ≤ 2 keeps a±b and ab within 4-bit signed range.
        for a in -2i64..=2 {
            for b in -2i64..=2 {
                let ca = ctx.encrypt(a, &ck, &mut rng);
                let cb = ctx.encrypt(b, &ck, &mut rng);
                let before = pbs_count();
                let prod = ctx.ct_mul(&ca, &cb);
                assert_eq!(pbs_count() - before, 2, "ct_mul PBS count");
                assert_eq!(ctx.decrypt(&prod, &ck), a * b, "{a}·{b}");
            }
        }
    }

    #[test]
    fn sum_many() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let vals = [1i64, -2, 3, 1, -1];
        let cts: Vec<CtInt> = vals.iter().map(|&v| ctx.encrypt(v, &ck, &mut rng)).collect();
        let s = ctx.sum(&cts);
        assert_eq!(ctx.decrypt(&s, &ck), vals.iter().sum::<i64>());
    }

    #[test]
    fn constants_work_in_ops() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let a = ctx.encrypt(-2, &ck, &mut rng);
        let c = ctx.constant(5);
        assert_eq!(ctx.decrypt(&ctx.add(&a, &c), &ck), 3);
        // 5 − (−2) = 7 = max of the 4-bit signed range (linear ops do NOT
        // saturate — exceeding the range would wrap into the padding bit).
        assert_eq!(ctx.decrypt(&ctx.sub(&c, &a), &ck), 7);
    }

    #[test]
    fn custom_pbs_fn() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let x = ctx.encrypt(3, &ck, &mut rng);
        let y = ctx.pbs_fn(&x, |v| v - 1);
        assert_eq!(ctx.decrypt(&y, &ck), 2);
    }

    #[test]
    fn prepared_fn_cache_hits_on_identical_tables() {
        let (_ck, ctx, _rng) = setup();
        let a = ctx.prepared_fn(|v| v.max(0));
        let b = ctx.prepared_fn(|v| v.max(0));
        assert!(Arc::ptr_eq(&a, &b), "same table must share one prepared accumulator");
        let c = ctx.prepared_fn(|v| v.min(0));
        assert!(!Arc::ptr_eq(&a, &c), "different tables must not collide");
    }

    #[test]
    fn batched_ops_match_scalar_ops() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        let vals = [-5i64, -2, 0, 1, 3, 7];
        let cts: Vec<CtInt> = vals.iter().map(|&v| ctx.encrypt(v, &ck, &mut rng)).collect();
        for threads in [1usize, 3] {
            ctx.set_threads(threads);
            assert_eq!(ctx.threads(), threads);
            let relu_b = ctx.relu_many(&cts);
            let abs_b = ctx.abs_many(&cts);
            let refresh_b = ctx.refresh_many(&cts);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(ctx.relu(&cts[i]).ct, relu_b[i].ct, "relu threads={threads} i={i}");
                assert_eq!(ctx.abs(&cts[i]).ct, abs_b[i].ct, "abs threads={threads} i={i}");
                assert_eq!(ctx.decrypt(&refresh_b[i], &ck), v, "refresh threads={threads}");
            }
        }
    }

    #[test]
    fn recip_scaled_matches_rounded_division() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        for v in [1i64, 2, 3, 5, 7] {
            let x = ctx.encrypt(v, &ck, &mut rng);
            let r = ctx.recip_scaled(&x, 7);
            assert_eq!(ctx.decrypt(&r, &ck), (7 + v / 2) / v, "v={v}");
        }
        // Degenerate (non-positive) input maps to the numerator.
        let z = ctx.encrypt(0, &ck, &mut rng);
        assert_eq!(ctx.decrypt(&ctx.recip_scaled(&z, 7), &ck), 7);
    }

    #[test]
    fn random_linear_circuits_match_plaintext() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = setup();
        for _ in 0..10 {
            let a = rng.next_range_i64(-3, 3);
            let b = rng.next_range_i64(-3, 3);
            let c = rng.next_range_i64(1, 2);
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(b, &ck, &mut rng);
            // (a − b)·c + b
            let r = ctx.add(&ctx.scalar_mul(&ctx.sub(&ca, &cb), c), &cb);
            assert_eq!(ctx.decrypt(&r, &ck), (a - b) * c + b);
        }
    }
}
