//! Encrypted integer operator layer (S5): the Concrete-style ops the
//! attention circuits are written against.
//!
//! `CtInt` is an encrypted signed integer (bias convention, see
//! `encoding`). The op costs mirror the paper's accounting exactly:
//!
//! | op                    | PBS | notes                                  |
//! |-----------------------|-----|----------------------------------------|
//! | add / sub / neg       | 0   | additions are cheap under FHE          |
//! | scalar (literal) mul  | 0   | "multiplication by literals is native" |
//! | relu / abs / square…  | 1   | univariate → one PBS table             |
//! | ct × ct (`ct_mul`)    | 2   | paper eq. 1: PBS(x²/4; a+b) − PBS(x²/4; a−b) |

use super::bootstrap::{Lut, ServerKey};
use super::encoding::Encoder;
use super::lwe::LweCiphertext;
use crate::util::prng::Xoshiro256;

/// An encrypted signed integer.
#[derive(Clone, Debug)]
pub struct CtInt {
    pub ct: LweCiphertext,
}

/// Evaluation context: server key + encoder (message layout).
pub struct FheContext {
    pub sk: ServerKey,
    pub enc: Encoder,
    // Cached LUTs for the common univariate ops.
    lut_relu: Lut,
    lut_abs: Lut,
    lut_sq4: Lut,
}

impl FheContext {
    pub fn new(sk: ServerKey) -> Self {
        let enc = Encoder::new(sk.params);
        let bias = enc.bias() as i64;
        let space = sk.params.message_space() as i64;
        let clamp = |v: i64| -> u64 { v.clamp(0, space - 1) as u64 };
        // LUT index is the *biased* message; value is biased back.
        let lut_relu = Lut::from_fn(&sk.params, |m| clamp((m as i64 - bias).max(0) + bias));
        let lut_abs = Lut::from_fn(&sk.params, |m| clamp((m as i64 - bias).abs() + bias));
        // floor(v²/4), saturating at the top of the signed range: the
        // ct_mul caller guarantees |a±b| small enough that no saturation
        // occurs on the values that matter.
        let lut_sq4 = Lut::from_fn(&sk.params, |m| {
            let v = m as i64 - bias;
            clamp((v * v).div_euclid(4) + bias)
        });
        FheContext { sk, enc, lut_relu, lut_abs, lut_sq4 }
    }

    /// Encrypt a signed value (client-side helper for tests/benches —
    /// production clients encrypt with `Encoder` directly).
    pub fn encrypt(
        &self,
        v: i64,
        ck: &super::bootstrap::ClientKey,
        rng: &mut Xoshiro256,
    ) -> CtInt {
        CtInt { ct: self.enc.encrypt_signed(v, ck, rng) }
    }

    pub fn decrypt(&self, x: &CtInt, ck: &super::bootstrap::ClientKey) -> i64 {
        self.enc.decrypt_signed(&x.ct, ck)
    }

    /// A trivial (public constant) ciphertext.
    pub fn constant(&self, v: i64) -> CtInt {
        let m = (v + self.enc.bias() as i64) as u64;
        CtInt { ct: LweCiphertext::trivial(self.enc.encode(m), self.sk.params.lwe_dim) }
    }

    // ----- linear ops (0 PBS) -----

    /// a + b (bias corrected).
    pub fn add(&self, a: &CtInt, b: &CtInt) -> CtInt {
        CtInt { ct: a.ct.add(&b.ct).sub_plain(self.enc.encode(self.enc.bias())) }
    }

    /// a − b (bias corrected).
    pub fn sub(&self, a: &CtInt, b: &CtInt) -> CtInt {
        CtInt { ct: a.ct.sub(&b.ct).add_plain(self.enc.encode(self.enc.bias())) }
    }

    /// −a.
    pub fn neg(&self, a: &CtInt) -> CtInt {
        let two_bias = self.enc.encode(self.enc.bias()).wrapping_mul(2);
        CtInt { ct: a.ct.neg().add_plain(two_bias) }
    }

    /// a + constant.
    pub fn add_const(&self, a: &CtInt, c: i64) -> CtInt {
        let off = (c as u64).wrapping_mul(self.sk.params.delta());
        CtInt { ct: a.ct.add_plain(off) }
    }

    /// a · c for a plaintext literal c ("constant-to-variable" multiply —
    /// no PBS, matching the paper's cost model).
    pub fn scalar_mul(&self, a: &CtInt, c: i64) -> CtInt {
        // (m)·c carries bias·c; correct back to a single bias.
        let ct = a.ct.scalar_mul(c);
        let corr = ((c - 1) as u64)
            .wrapping_mul(self.enc.bias())
            .wrapping_mul(self.sk.params.delta());
        CtInt { ct: ct.sub_plain(corr) }
    }

    /// Sum of many ciphertexts (0 PBS; noise grows linearly).
    pub fn sum(&self, xs: &[CtInt]) -> CtInt {
        assert!(!xs.is_empty());
        let mut acc = xs[0].ct.clone();
        for x in &xs[1..] {
            acc.add_assign(&x.ct);
        }
        let corr = ((xs.len() - 1) as u64)
            .wrapping_mul(self.enc.bias())
            .wrapping_mul(self.sk.params.delta());
        CtInt { ct: acc.sub_plain(corr) }
    }

    // ----- univariate ops (1 PBS each) -----

    /// Apply an arbitrary univariate signed function (1 PBS).
    pub fn pbs_fn(&self, a: &CtInt, f: impl Fn(i64) -> i64) -> CtInt {
        let bias = self.enc.bias() as i64;
        let space = self.sk.params.message_space() as i64;
        let lut = Lut::from_fn(&self.sk.params, |m| {
            (f(m as i64 - bias) + bias).clamp(0, space - 1) as u64
        });
        CtInt { ct: self.sk.pbs(&a.ct, &lut) }
    }

    /// ReLU x⁺ (1 PBS).
    pub fn relu(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs(&a.ct, &self.lut_relu) }
    }

    /// |x| (1 PBS).
    pub fn abs(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs(&a.ct, &self.lut_abs) }
    }

    /// floor(x²/4) (1 PBS) — the paper's eq. 2 table.
    pub fn square_quarter(&self, a: &CtInt) -> CtInt {
        CtInt { ct: self.sk.pbs(&a.ct, &self.lut_sq4) }
    }

    /// Reciprocal table scaled by `num`: x ↦ round(num/x) for x>0, used by
    /// the encrypted softmax normalization (1 PBS).
    pub fn recip_scaled(&self, a: &CtInt, num: i64) -> CtInt {
        self.pbs_fn(a, move |v| if v > 0 { num / v } else { self.enc.max_signed() })
    }

    // ----- the paper's headline op -----

    /// Ciphertext × ciphertext multiplication via two PBS (paper eq. 1):
    /// `ab = PBS(x²/4; a+b) − PBS(x²/4; a−b)`.
    ///
    /// Exact for integers because a+b and a−b share parity, so the two
    /// floor errors cancel. Requires |a±b| within the signed range — this
    /// is exactly the "up to two bits higher precision" cost the paper's
    /// Table 2 attributes to the dot-product variant.
    pub fn ct_mul(&self, a: &CtInt, b: &CtInt) -> CtInt {
        let s = self.add(a, b);
        let d = self.sub(a, b);
        let p1 = self.square_quarter(&s);
        let p2 = self.square_quarter(&d);
        self.sub(&p1, &p2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Rng64;

    fn setup() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(31337);
        // 4 bits so ct_mul has headroom for a±b and ab.
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    #[test]
    fn linear_ops_cost_zero_pbs() {
        let (ck, ctx, mut rng) = setup();
        let a = ctx.encrypt(3, &ck, &mut rng);
        let b = ctx.encrypt(-2, &ck, &mut rng);
        let before = pbs_count();
        let add = ctx.add(&a, &b);
        let sub = ctx.sub(&a, &b);
        let neg = ctx.neg(&a);
        let smul = ctx.scalar_mul(&a, 2);
        let addc = ctx.add_const(&a, 4);
        assert_eq!(pbs_count(), before, "linear ops must not bootstrap");
        assert_eq!(ctx.decrypt(&add, &ck), 1);
        assert_eq!(ctx.decrypt(&sub, &ck), 5);
        assert_eq!(ctx.decrypt(&neg, &ck), -3);
        assert_eq!(ctx.decrypt(&smul, &ck), 6);
        assert_eq!(ctx.decrypt(&addc, &ck), 7);
    }

    #[test]
    fn relu_and_abs_over_range() {
        let (ck, ctx, mut rng) = setup();
        for v in [-8i64, -5, -1, 0, 1, 4, 7] {
            let x = ctx.encrypt(v, &ck, &mut rng);
            assert_eq!(ctx.decrypt(&ctx.relu(&x), &ck), v.max(0), "relu({v})");
            assert_eq!(ctx.decrypt(&ctx.abs(&x), &ck), v.abs().min(7), "abs({v})");
        }
    }

    #[test]
    fn ct_mul_is_exact_and_costs_two_pbs() {
        let (ck, ctx, mut rng) = setup();
        // |a|,|b| ≤ 2 keeps a±b and ab within 4-bit signed range.
        for a in -2i64..=2 {
            for b in -2i64..=2 {
                let ca = ctx.encrypt(a, &ck, &mut rng);
                let cb = ctx.encrypt(b, &ck, &mut rng);
                let before = pbs_count();
                let prod = ctx.ct_mul(&ca, &cb);
                assert_eq!(pbs_count() - before, 2, "ct_mul PBS count");
                assert_eq!(ctx.decrypt(&prod, &ck), a * b, "{a}·{b}");
            }
        }
    }

    #[test]
    fn sum_many() {
        let (ck, ctx, mut rng) = setup();
        let vals = [1i64, -2, 3, 1, -1];
        let cts: Vec<CtInt> = vals.iter().map(|&v| ctx.encrypt(v, &ck, &mut rng)).collect();
        let s = ctx.sum(&cts);
        assert_eq!(ctx.decrypt(&s, &ck), vals.iter().sum::<i64>());
    }

    #[test]
    fn constants_work_in_ops() {
        let (ck, ctx, mut rng) = setup();
        let a = ctx.encrypt(-2, &ck, &mut rng);
        let c = ctx.constant(5);
        assert_eq!(ctx.decrypt(&ctx.add(&a, &c), &ck), 3);
        // 5 − (−2) = 7 = max of the 4-bit signed range (linear ops do NOT
        // saturate — exceeding the range would wrap into the padding bit).
        assert_eq!(ctx.decrypt(&ctx.sub(&c, &a), &ck), 7);
    }

    #[test]
    fn custom_pbs_fn() {
        let (ck, ctx, mut rng) = setup();
        let x = ctx.encrypt(3, &ck, &mut rng);
        let y = ctx.pbs_fn(&x, |v| v - 1);
        assert_eq!(ctx.decrypt(&y, &ck), 2);
    }

    #[test]
    fn random_linear_circuits_match_plaintext() {
        let (ck, ctx, mut rng) = setup();
        for _ in 0..10 {
            let a = rng.next_range_i64(-3, 3);
            let b = rng.next_range_i64(-3, 3);
            let c = rng.next_range_i64(1, 2);
            let ca = ctx.encrypt(a, &ck, &mut rng);
            let cb = ctx.encrypt(b, &ck, &mut rng);
            // (a − b)·c + b
            let r = ctx.add(&ctx.scalar_mul(&ctx.sub(&ca, &cb), c), &cb);
            assert_eq!(ctx.decrypt(&r, &ck), (a - b) * c + b);
        }
    }
}
