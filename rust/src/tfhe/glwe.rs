//! GLWE ciphertexts over Z_q[X]/(X^N+1) (S4).
//!
//! A GLWE ciphertext is `(A_1..A_k, B)` with `B = Σ A_i·S_i + M + E`,
//! polynomials of size N. GLWE is the accumulator type of the blind
//! rotation; `sample_extract` pulls one coefficient out as an LWE
//! ciphertext under the "extracted" key (the GLWE key read as k·N LWE
//! bits).

use super::lwe::{LweCiphertext, LweSecretKey};
use super::torus::{gaussian_torus, Torus};
use crate::util::prng::{Rng64, Xoshiro256};

/// GLWE secret key: k polynomials with binary coefficients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GlweSecretKey {
    pub poly_size: usize,
    /// k polynomials, each `poly_size` bits (0/1 as u64).
    pub polys: Vec<Vec<u64>>,
}

impl GlweSecretKey {
    pub fn generate(poly_size: usize, glwe_dim: usize, rng: &mut Xoshiro256) -> Self {
        let polys = (0..glwe_dim)
            .map(|_| (0..poly_size).map(|_| rng.next_u64() & 1).collect())
            .collect();
        GlweSecretKey { poly_size, polys }
    }

    pub fn dim(&self) -> usize {
        self.polys.len()
    }

    /// Reinterpret as an LWE key of dimension k·N (sample-extract key).
    /// Coefficient order matches `sample_extract` below.
    pub fn to_extracted_lwe(&self) -> LweSecretKey {
        let mut bits = Vec::with_capacity(self.dim() * self.poly_size);
        for p in &self.polys {
            bits.extend_from_slice(p);
        }
        LweSecretKey { bits }
    }
}

/// GLWE ciphertext: k mask polynomials + body polynomial.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GlweCiphertext {
    pub poly_size: usize,
    pub mask: Vec<Vec<Torus>>,
    pub body: Vec<Torus>,
}

/// Negacyclic product of a torus polynomial by a *binary* polynomial
/// (secret key), exact u64 arithmetic (no FFT needed: digits are 0/1 and
/// this path only runs at encrypt/decrypt time, not in circuits).
fn negacyclic_mul_binary(t: &[Torus], bits: &[u64]) -> Vec<Torus> {
    let n = t.len();
    let mut out = vec![0u64; n];
    for (i, &b) in bits.iter().enumerate() {
        if b == 0 {
            continue;
        }
        for (j, &v) in t.iter().enumerate() {
            let idx = i + j;
            if idx < n {
                out[idx] = out[idx].wrapping_add(v);
            } else {
                out[idx - n] = out[idx - n].wrapping_sub(v);
            }
        }
    }
    out
}

impl GlweCiphertext {
    pub fn zero(poly_size: usize, glwe_dim: usize) -> Self {
        GlweCiphertext {
            poly_size,
            mask: vec![vec![0; poly_size]; glwe_dim],
            body: vec![0; poly_size],
        }
    }

    /// Trivial (noiseless, maskless) encryption of a message polynomial.
    pub fn trivial(msg: Vec<Torus>, glwe_dim: usize) -> Self {
        let poly_size = msg.len();
        GlweCiphertext { poly_size, mask: vec![vec![0; poly_size]; glwe_dim], body: msg }
    }

    /// Encrypt a torus message polynomial.
    pub fn encrypt(
        msg: &[Torus],
        key: &GlweSecretKey,
        noise_std: f64,
        rng: &mut Xoshiro256,
    ) -> Self {
        let n = key.poly_size;
        assert_eq!(msg.len(), n);
        let mask: Vec<Vec<Torus>> =
            (0..key.dim()).map(|_| (0..n).map(|_| rng.next_u64()).collect()).collect();
        let mut body: Vec<Torus> =
            msg.iter().map(|&m| m.wrapping_add(gaussian_torus(noise_std, rng))).collect();
        for (a, s) in mask.iter().zip(key.polys.iter()) {
            let prod = negacyclic_mul_binary(a, s);
            for (b, p) in body.iter_mut().zip(prod.iter()) {
                *b = b.wrapping_add(*p);
            }
        }
        GlweCiphertext { poly_size: n, mask, body }
    }

    /// Decrypt to the noisy phase polynomial.
    pub fn decrypt(&self, key: &GlweSecretKey) -> Vec<Torus> {
        let mut phase = self.body.clone();
        for (a, s) in self.mask.iter().zip(key.polys.iter()) {
            let prod = negacyclic_mul_binary(a, s);
            for (p, q) in phase.iter_mut().zip(prod.iter()) {
                *p = p.wrapping_sub(*q);
            }
        }
        phase
    }

    pub fn add_assign(&mut self, o: &Self) {
        for (a, b) in self.mask.iter_mut().zip(o.mask.iter()) {
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x = x.wrapping_add(*y);
            }
        }
        for (x, y) in self.body.iter_mut().zip(o.body.iter()) {
            *x = x.wrapping_add(*y);
        }
    }

    pub fn sub(&self, o: &Self) -> Self {
        let mask = self
            .mask
            .iter()
            .zip(o.mask.iter())
            .map(|(a, b)| a.iter().zip(b.iter()).map(|(x, y)| x.wrapping_sub(*y)).collect())
            .collect();
        let body =
            self.body.iter().zip(o.body.iter()).map(|(x, y)| x.wrapping_sub(*y)).collect();
        GlweCiphertext { poly_size: self.poly_size, mask, body }
    }

    /// Multiply every polynomial by the monomial X^e (e may exceed N;
    /// negacyclic wrap flips signs). This is the rotation primitive of the
    /// blind rotation; exponent arithmetic is mod 2N.
    pub fn rotate_monomial(&self, e: u64) -> Self {
        let rotate = |p: &[Torus]| rotate_poly_monomial(p, e);
        GlweCiphertext {
            poly_size: self.poly_size,
            mask: self.mask.iter().map(|m| rotate(m)).collect(),
            body: rotate(&self.body),
        }
    }

    /// Allocation-free monomial rotation into `out` (hot path).
    pub fn rotate_monomial_into(&self, e: u64, out: &mut GlweCiphertext) {
        out.poly_size = self.poly_size;
        out.mask.resize(self.mask.len(), Vec::new());
        for (src, dst) in self.mask.iter().zip(out.mask.iter_mut()) {
            dst.resize(self.poly_size, 0);
            rotate_poly_monomial_into(src, e, dst);
        }
        out.body.resize(self.poly_size, 0);
        rotate_poly_monomial_into(&self.body, e, &mut out.body);
    }

    /// Allocation-free subtraction `out = self − o` (hot path).
    pub fn sub_into(&self, o: &Self, out: &mut GlweCiphertext) {
        out.poly_size = self.poly_size;
        out.mask.resize(self.mask.len(), Vec::new());
        for ((a, b), dst) in self.mask.iter().zip(o.mask.iter()).zip(out.mask.iter_mut()) {
            dst.resize(self.poly_size, 0);
            for ((x, y), d) in a.iter().zip(b.iter()).zip(dst.iter_mut()) {
                *d = x.wrapping_sub(*y);
            }
        }
        out.body.resize(self.poly_size, 0);
        for ((x, y), d) in self.body.iter().zip(o.body.iter()).zip(out.body.iter_mut()) {
            *d = x.wrapping_sub(*y);
        }
    }

    /// Extract coefficient `idx` of the message as an LWE ciphertext under
    /// `key.to_extracted_lwe()`.
    pub fn sample_extract(&self, idx: usize) -> LweCiphertext {
        let n = self.poly_size;
        assert!(idx < n);
        let k = self.mask.len();
        let mut mask = Vec::with_capacity(k * n);
        for a in &self.mask {
            // LWE mask entry for key bit s_i[j] is the coefficient of the
            // product contributing to msg coeff idx: a[idx−j] for j ≤ idx,
            // −a[N+idx−j] for j > idx.
            for j in 0..n {
                if j <= idx {
                    mask.push(a[idx - j]);
                } else {
                    mask.push(a[n + idx - j].wrapping_neg());
                }
            }
        }
        LweCiphertext { mask, body: self.body[idx] }
    }
}

/// Rotate a polynomial by the monomial X^e (exponent mod 2N, negacyclic).
pub fn rotate_poly_monomial(p: &[Torus], e: u64) -> Vec<Torus> {
    let mut out = vec![0u64; p.len()];
    rotate_poly_monomial_into(p, e, &mut out);
    out
}

/// Allocation-free monomial rotation. Branchless per-segment copies:
/// exponent e ∈ [0, 2N) splits the output into at most two contiguous
/// runs with fixed sign each.
pub fn rotate_poly_monomial_into(p: &[Torus], e: u64, out: &mut [Torus]) {
    let n = p.len();
    let mut e = (e % (2 * n as u64)) as usize;
    // X^(N+r) = −X^r: reduce to r < N with a sign flip.
    let mut negate = false;
    if e >= n {
        e -= n;
        negate = true;
    }
    // out[j+e] = p[j] for j < n−e  (sign s), out[j+e−n] = −p[j] otherwise.
    let split = n - e;
    if negate {
        for j in 0..split {
            out[j + e] = p[j].wrapping_neg();
        }
        for j in split..n {
            out[j + e - n] = p[j];
        }
    } else {
        out[e..n].copy_from_slice(&p[..split]);
        for j in split..n {
            out[j + e - n] = p[j].wrapping_neg();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::torus::{torus_distance, torus_from_f64};

    const STD: f64 = 1.0 / (1u64 << 40) as f64;

    #[test]
    fn encrypt_decrypt_polynomial() {
        let mut rng = Xoshiro256::new(2);
        let key = GlweSecretKey::generate(256, 2, &mut rng);
        let msg: Vec<Torus> =
            (0..256).map(|i| torus_from_f64((i as f64 / 256.0 - 0.5) * 0.5)).collect();
        let ct = GlweCiphertext::encrypt(&msg, &key, STD, &mut rng);
        let dec = ct.decrypt(&key);
        for (d, m) in dec.iter().zip(msg.iter()) {
            assert!(torus_distance(*d, *m) < 1e-8);
        }
    }

    #[test]
    fn monomial_rotation_wraps_negacyclically() {
        let n = 8;
        let mut p = vec![0u64; n];
        p[0] = 100;
        // X^0 · X^(n) = X^n = −1.
        let r = rotate_poly_monomial(&p, n as u64);
        assert_eq!(r[0], 100u64.wrapping_neg());
        // Rotation by 2N is identity.
        let r2 = rotate_poly_monomial(&p, 2 * n as u64);
        assert_eq!(r2, p);
        // Rotation by 3 moves coeff 0 to 3.
        let r3 = rotate_poly_monomial(&p, 3);
        assert_eq!(r3[3], 100);
    }

    #[test]
    fn rotation_commutes_with_decryption() {
        let mut rng = Xoshiro256::new(4);
        let key = GlweSecretKey::generate(128, 1, &mut rng);
        let mut msg = vec![0u64; 128];
        msg[5] = torus_from_f64(0.25);
        let ct = GlweCiphertext::encrypt(&msg, &key, STD, &mut rng);
        let rot = ct.rotate_monomial(200); // 5+200 = 205 = 128+77 → −coeff at 77
        let dec = rot.decrypt(&key);
        let want = torus_from_f64(0.25).wrapping_neg();
        assert!(torus_distance(dec[77], want) < 1e-8);
    }

    #[test]
    fn sample_extract_matches_coefficient() {
        let mut rng = Xoshiro256::new(6);
        let key = GlweSecretKey::generate(64, 2, &mut rng);
        let lwe_key = key.to_extracted_lwe();
        let msg: Vec<Torus> = (0..64)
            .map(|i| torus_from_f64(((i * 7 % 64) as f64 / 64.0 - 0.5) * 0.4))
            .collect();
        let ct = GlweCiphertext::encrypt(&msg, &key, STD, &mut rng);
        for idx in [0usize, 1, 17, 63] {
            let lwe = ct.sample_extract(idx);
            assert_eq!(lwe.dim(), 128);
            let dec = lwe.decrypt(&lwe_key);
            assert!(
                torus_distance(dec, msg[idx]) < 1e-8,
                "idx {idx}: {} vs {}",
                dec,
                msg[idx]
            );
        }
    }

    #[test]
    fn trivial_glwe_decrypts_exactly() {
        let mut rng = Xoshiro256::new(8);
        let key = GlweSecretKey::generate(32, 1, &mut rng);
        let msg: Vec<Torus> = (0..32).map(|i| (i as u64) << 58).collect();
        let ct = GlweCiphertext::trivial(msg.clone(), 1);
        assert_eq!(ct.decrypt(&key), msg);
    }
}
