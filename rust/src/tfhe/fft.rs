//! Negacyclic polynomial multiplication over Z_q[X]/(X^N + 1) (S4).
//!
//! The hot loop of every external product (and hence every CMux, blind
//! rotation and PBS). Two implementations:
//!
//! * [`negacyclic_mul_schoolbook`] — exact i128 O(N²) product, the oracle.
//! * [`NegacyclicFft`] — the standard folded/twisted f64 FFT of size N/2:
//!   a real negacyclic product of length N becomes one complex FFT, a
//!   pointwise multiply and an inverse FFT. This is how concrete-fft /
//!   tfhe-rs do it; the f64 rounding error behaves as additional Gaussian
//!   noise well below the scheme noise for all parameter sets we use
//!   (verified by `fft_error_small_vs_schoolbook`).
//!
//! Math: with w = e^{iπ/N}, fold q_j = (p_j + i·p_{j+N/2})·w^j; then
//! FFT_{N/2}(q)_k = p(e^{iπ(4k+1)/N}) — evaluations at N/2 of the odd
//! 2N-th roots of unity (the other half are conjugates since p is real).
//! All such points are roots of X^N + 1, so pointwise multiplication
//! there is exactly the negacyclic product.

use std::f64::consts::PI;

/// Minimal complex type (num-complex is not vendored).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    #[inline]
    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }

    #[inline]
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
}

/// FFT plan for negacyclic products of fixed size N (power of two ≥ 2).
pub struct NegacyclicFft {
    /// Polynomial size N.
    pub n: usize,
    /// FFT size N/2.
    half: usize,
    /// Twiddle factors for each FFT stage (size N/2, bit-reversal order
    /// addressed on the fly).
    twiddles: Vec<C64>,
    /// Inverse twiddles.
    inv_twiddles: Vec<C64>,
    /// Folding twist w^j = e^{iπ j/N}, j < N/2.
    twist: Vec<C64>,
    /// Untwist (conjugate of twist) scaled by 2/N for the inverse path.
    untwist: Vec<C64>,
    /// Scratch-free bit-reversal permutation for size N/2.
    bitrev: Vec<u32>,
}

impl NegacyclicFft {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 4, "poly size must be a power of two ≥ 4");
        let half = n / 2;
        // Stage twiddles, laid out per stage: for len = 2,4,..,half we need
        // len/2 roots e^{-2πi k/len}. Store flattened (total = half - 1).
        let mut twiddles = Vec::with_capacity(half);
        let mut inv_twiddles = Vec::with_capacity(half);
        let mut len = 2;
        while len <= half {
            for k in 0..len / 2 {
                let ang = -2.0 * PI * k as f64 / len as f64;
                twiddles.push(C64::new(ang.cos(), ang.sin()));
                inv_twiddles.push(C64::new(ang.cos(), -ang.sin()));
            }
            len <<= 1;
        }
        let twist = (0..half)
            .map(|j| {
                let ang = PI * j as f64 / n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let untwist = (0..half)
            .map(|j| {
                let ang = -PI * j as f64 / n as f64;
                C64::new(ang.cos(), ang.sin()).scale(2.0 / n as f64)
            })
            .collect();
        let bits = half.trailing_zeros();
        let bitrev = (0..half as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect();
        NegacyclicFft { n, half, twiddles, inv_twiddles, twist, untwist, bitrev }
    }

    #[inline]
    fn fft_in_place(&self, buf: &mut [C64], inverse: bool) {
        let half = self.half;
        debug_assert_eq!(buf.len(), half);
        // Bit-reversal permutation.
        for i in 0..half {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let tw = if inverse { &self.inv_twiddles } else { &self.twiddles };
        let mut len = 2;
        let mut tbase = 0;
        while len <= half {
            let hl = len / 2;
            for start in (0..half).step_by(len) {
                for k in 0..hl {
                    let w = tw[tbase + k];
                    let a = buf[start + k];
                    let b = buf[start + k + hl].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + hl] = a.sub(b);
                }
            }
            tbase += hl;
            len <<= 1;
        }
    }

    /// Forward transform of a torus polynomial (u64 coeffs interpreted as
    /// centered signed i64 to keep f64 magnitudes bounded).
    pub fn forward_torus(&self, poly: &[u64]) -> Vec<C64> {
        let mut buf = vec![C64::default(); self.half];
        self.forward_torus_into(poly, &mut buf);
        buf
    }

    /// Allocation-free forward transform into a caller-provided buffer
    /// (hot path: external products reuse one scratch per thread).
    pub fn forward_torus_into(&self, poly: &[u64], buf: &mut [C64]) {
        debug_assert_eq!(poly.len(), self.n);
        debug_assert_eq!(buf.len(), self.half);
        for j in 0..self.half {
            let re = poly[j] as i64 as f64;
            let im = poly[j + self.half] as i64 as f64;
            buf[j] = C64::new(re, im).mul(self.twist[j]);
        }
        self.fft_in_place(buf, false);
    }

    /// Forward transform of a small signed polynomial (decomposition
    /// digits) — same folding, i64 inputs.
    pub fn forward_signed(&self, poly: &[i64]) -> Vec<C64> {
        let mut buf = vec![C64::default(); self.half];
        self.forward_signed_into(poly, &mut buf);
        buf
    }

    /// Allocation-free signed forward transform (hot path).
    pub fn forward_signed_into(&self, poly: &[i64], buf: &mut [C64]) {
        debug_assert_eq!(poly.len(), self.n);
        debug_assert_eq!(buf.len(), self.half);
        for j in 0..self.half {
            buf[j] = C64::new(poly[j] as f64, poly[j + self.half] as f64).mul(self.twist[j]);
        }
        self.fft_in_place(buf, false);
    }

    /// Pointwise multiply-accumulate in the transformed domain:
    /// `acc[k] += a[k]·b[k]`.
    #[inline]
    pub fn mul_acc(acc: &mut [C64], a: &[C64], b: &[C64]) {
        for ((acc, &x), &y) in acc.iter_mut().zip(a.iter()).zip(b.iter()) {
            *acc = acc.add(x.mul(y));
        }
    }

    /// Inverse transform; rounds to the nearest torus element (wrapping).
    pub fn backward_torus(&self, spec: &[C64]) -> Vec<u64> {
        let mut out = vec![0u64; self.n];
        let mut buf = spec.to_vec();
        self.backward_torus_into(&mut buf, &mut out);
        out
    }

    /// Allocation-free inverse transform (hot path). `spec` is consumed as
    /// scratch (transformed in place).
    pub fn backward_torus_into(&self, spec: &mut [C64], out: &mut [u64]) {
        debug_assert_eq!(spec.len(), self.half);
        debug_assert_eq!(out.len(), self.n);
        self.fft_in_place(spec, true);
        for j in 0..self.half {
            let v = spec[j].mul(self.untwist[j]);
            // f64 → u64 wrapping: reduce via i128 of the rounded value.
            out[j] = f64_to_torus(v.re);
            out[j + self.half] = f64_to_torus(v.im);
        }
    }

    /// Add the inverse transform into an existing torus polynomial.
    pub fn backward_torus_add(&self, spec: &[C64], acc: &mut [u64]) {
        let p = self.backward_torus(spec);
        for (a, &v) in acc.iter_mut().zip(p.iter()) {
            *a = a.wrapping_add(v);
        }
    }
}

/// Round an f64 to u64 with wrapping mod 2^64 semantics.
#[inline]
pub fn f64_to_torus(x: f64) -> u64 {
    // Values can exceed ±2^63 before reduction; go through i128 mod 2^64.
    let r = x.round();
    let m = r % 2f64.powi(64);
    (m as i128) as u64
}

/// Exact negacyclic product of a torus polynomial by a small signed
/// polynomial (digits), i128 accumulation. O(N²); used as the test oracle
/// and for tiny parameter sets.
pub fn negacyclic_mul_schoolbook(torus_poly: &[u64], signed_poly: &[i64]) -> Vec<u64> {
    let n = torus_poly.len();
    assert_eq!(n, signed_poly.len());
    let mut out = vec![0u64; n];
    for (i, &a) in signed_poly.iter().enumerate() {
        if a == 0 {
            continue;
        }
        for (j, &b) in torus_poly.iter().enumerate() {
            let prod = (a as i128).wrapping_mul(b as i64 as i128) as u64;
            let idx = i + j;
            if idx < n {
                out[idx] = out[idx].wrapping_add(prod);
            } else {
                out[idx - n] = out[idx - n].wrapping_sub(prod);
            }
        }
    }
    out
}

/// FFT-based negacyclic product of torus × signed (convenience wrapper
/// around a plan; external products keep operands in the spectral domain
/// and use the plan API directly).
pub fn negacyclic_mul_fft(plan: &NegacyclicFft, torus_poly: &[u64], signed_poly: &[i64]) -> Vec<u64> {
    let a = plan.forward_torus(torus_poly);
    let b = plan.forward_signed(signed_poly);
    let mut acc = vec![C64::default(); plan.half];
    NegacyclicFft::mul_acc(&mut acc, &a, &b);
    plan.backward_torus(&acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{Rng64, Xoshiro256};

    #[test]
    fn schoolbook_negacyclic_wraps_sign() {
        // (X^{N-1}) · (X) = X^N = −1 mod X^N+1.
        let n = 8;
        let mut a = vec![0u64; n];
        a[n - 1] = 5;
        let mut b = vec![0i64; n];
        b[1] = 1;
        let c = negacyclic_mul_schoolbook(&a, &b);
        assert_eq!(c[0], (-5i64) as u64);
        assert!(c[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn fft_matches_schoolbook_small_values() {
        let mut rng = Xoshiro256::new(7);
        for n in [8usize, 32, 256] {
            let plan = NegacyclicFft::new(n);
            let a: Vec<u64> = (0..n).map(|_| rng.next_range_i64(-1000, 1000) as u64).collect();
            let b: Vec<i64> = (0..n).map(|_| rng.next_range_i64(-50, 50)).collect();
            let want = negacyclic_mul_schoolbook(&a, &b);
            let got = negacyclic_mul_fft(&plan, &a, &b);
            for i in 0..n {
                let diff = (got[i].wrapping_sub(want[i])) as i64;
                assert!(diff.abs() <= 1, "n={n} i={i}: got {} want {}", got[i], want[i]);
            }
        }
    }

    #[test]
    fn fft_error_small_vs_schoolbook() {
        // Torus-magnitude coefficients × decomposition-digit magnitudes:
        // the worst realistic case for f64 precision. Error must stay far
        // below the scheme noise floor (≪ 2^40 absolute here, i.e. 2^-24
        // of the torus) for N = 1024, digits ≤ 2^22.
        let mut rng = Xoshiro256::new(99);
        let n = 1024;
        let plan = NegacyclicFft::new(n);
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let b: Vec<i64> = (0..n).map(|_| rng.next_range_i64(-(1 << 22), 1 << 22)).collect();
        let want = negacyclic_mul_schoolbook(&a, &b);
        let got = negacyclic_mul_fft(&plan, &a, &b);
        let mut max_err = 0f64;
        for i in 0..n {
            let diff = (got[i].wrapping_sub(want[i])) as i64 as f64;
            max_err = max_err.max(diff.abs());
        }
        assert!(max_err < 2f64.powi(40), "fft error {max_err:e} too large");
    }

    #[test]
    fn linearity_in_spectral_domain() {
        let n = 64;
        let plan = NegacyclicFft::new(n);
        let mut rng = Xoshiro256::new(21);
        let a: Vec<u64> = (0..n).map(|_| rng.next_range_i64(-500, 500) as u64).collect();
        let b1: Vec<i64> = (0..n).map(|_| rng.next_range_i64(-20, 20)).collect();
        let b2: Vec<i64> = (0..n).map(|_| rng.next_range_i64(-20, 20)).collect();
        // FFT(a)·(B1+B2) == FFT(a)·B1 + FFT(a)·B2 (up to rounding ±2).
        let sum: Vec<i64> = b1.iter().zip(&b2).map(|(&x, &y)| x + y).collect();
        let lhs = negacyclic_mul_fft(&plan, &a, &sum);
        let r1 = negacyclic_mul_fft(&plan, &a, &b1);
        let r2 = negacyclic_mul_fft(&plan, &a, &b2);
        for i in 0..n {
            let rhs = r1[i].wrapping_add(r2[i]);
            let diff = (lhs[i].wrapping_sub(rhs)) as i64;
            assert!(diff.abs() <= 2, "i={i}");
        }
    }

    #[test]
    fn f64_to_torus_wraps() {
        assert_eq!(f64_to_torus(0.0), 0);
        assert_eq!(f64_to_torus(-1.0), u64::MAX);
        assert_eq!(f64_to_torus(2f64.powi(64)), 0);
        // Note: near 2^64 the f64 ulp is 4096, so exact small offsets are
        // only representable after wrapping; check a representable case.
        assert_eq!(f64_to_torus(2f64.powi(64) + 8192.0), 8192);
    }
}
