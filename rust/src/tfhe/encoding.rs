//! Integer encoding on the torus (S4): how the quantized model's signed
//! codes map into the TFHE message space.
//!
//! Message layout: 1 padding bit + `p` message bits, slot width
//! Δ = 2^(63−p). Unsigned messages live in `[0, 2^p)`. Signed values use
//! the *bias convention*: `v ∈ [−2^(p−1), 2^(p−1))` is carried as
//! `m = v + 2^(p−1)`. Linear ops then need bias bookkeeping (handled by
//! `ops::CtInt`), but the padding bit invariant — phase in the first half
//! of the torus — always holds, which is what makes every PBS LUT fully
//! programmable.

use super::bootstrap::ClientKey;
use super::lwe::LweCiphertext;
use super::params::TfheParams;
use super::torus::round_to_modulus;
use crate::util::prng::Xoshiro256;

/// Encoder/decoder for one parameter set.
#[derive(Clone, Copy, Debug)]
pub struct Encoder {
    pub params: TfheParams,
}

impl Encoder {
    pub fn new(params: TfheParams) -> Self {
        Encoder { params }
    }

    /// Signed range: `[min_signed, max_signed]` inclusive.
    pub fn min_signed(&self) -> i64 {
        -(1i64 << (self.params.message_bits - 1))
    }

    pub fn max_signed(&self) -> i64 {
        (1i64 << (self.params.message_bits - 1)) - 1
    }

    /// The bias added to signed values (2^(p−1)).
    pub fn bias(&self) -> u64 {
        1u64 << (self.params.message_bits - 1)
    }

    /// Encode an unsigned message to its torus position.
    pub fn encode(&self, m: u64) -> u64 {
        debug_assert!(m < self.params.message_space(), "message {m} out of space");
        m.wrapping_mul(self.params.delta())
    }

    /// Decode a noisy torus phase to the nearest message.
    pub fn decode(&self, phase: u64) -> u64 {
        round_to_modulus(phase, self.params.message_space() * 2) & (self.params.message_space() - 1)
    }

    /// Encrypt an unsigned message.
    pub fn encrypt_raw(&self, m: u64, ck: &ClientKey, rng: &mut Xoshiro256) -> LweCiphertext {
        LweCiphertext::encrypt(self.encode(m), &ck.lwe_key, self.params.lwe_noise_std, rng)
    }

    /// Decrypt to an unsigned message.
    pub fn decrypt_raw(&self, ct: &LweCiphertext, ck: &ClientKey) -> u64 {
        self.decode(ct.decrypt(&ck.lwe_key))
    }

    /// Encrypt a signed value with the bias convention.
    pub fn encrypt_signed(&self, v: i64, ck: &ClientKey, rng: &mut Xoshiro256) -> LweCiphertext {
        assert!(
            (self.min_signed()..=self.max_signed()).contains(&v),
            "value {v} outside signed range [{}, {}]",
            self.min_signed(),
            self.max_signed()
        );
        self.encrypt_raw((v + self.bias() as i64) as u64, ck, rng)
    }

    /// Decrypt a signed value.
    pub fn decrypt_signed(&self, ct: &LweCiphertext, ck: &ClientKey) -> i64 {
        self.decrypt_raw(ct, ck) as i64 - self.bias() as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng64;

    #[test]
    fn encode_decode_roundtrip_all_messages() {
        let enc = Encoder::new(TfheParams::test_small());
        for m in 0..enc.params.message_space() {
            assert_eq!(enc.decode(enc.encode(m)), m);
        }
    }

    #[test]
    fn decode_tolerates_noise_below_half_slot() {
        let enc = Encoder::new(TfheParams::test_small());
        let delta = enc.params.delta();
        for m in 0..enc.params.message_space() {
            let noisy_up = enc.encode(m).wrapping_add(delta / 2 - 1);
            let noisy_dn = enc.encode(m).wrapping_sub(delta / 2 - 1);
            assert_eq!(enc.decode(noisy_up), m, "up m={m}");
            assert_eq!(enc.decode(noisy_dn), m, "down m={m}");
        }
    }

    #[test]
    fn signed_roundtrip_under_encryption() {
        let mut rng = Xoshiro256::new(77);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let enc = Encoder::new(ck.params);
        for v in enc.min_signed()..=enc.max_signed() {
            let ct = enc.encrypt_signed(v, &ck, &mut rng);
            assert_eq!(enc.decrypt_signed(&ct, &ck), v);
        }
    }

    #[test]
    fn signed_addition_with_bias_correction() {
        // (a + bias) + (b + bias) − bias = (a+b) + bias.
        let mut rng = Xoshiro256::new(78);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let enc = Encoder::new(ck.params);
        for _ in 0..20 {
            let a = rng.next_range_i64(-2, 1);
            let b = rng.next_range_i64(-2, 1);
            let ca = enc.encrypt_signed(a, &ck, &mut rng);
            let cb = enc.encrypt_signed(b, &ck, &mut rng);
            let sum = ca.add(&cb).sub_plain(enc.encode(enc.bias()));
            assert_eq!(enc.decrypt_signed(&sum, &ck), a + b, "a={a} b={b}");
        }
    }

    #[test]
    #[should_panic(expected = "outside signed range")]
    fn rejects_out_of_range_signed() {
        let mut rng = Xoshiro256::new(79);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let enc = Encoder::new(ck.params);
        let _ = enc.encrypt_signed(100, &ck, &mut rng);
    }
}
