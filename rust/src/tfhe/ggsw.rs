//! GGSW ciphertexts, gadget decomposition, external product and CMux (S4).
//!
//! GGSW(m) (m a small integer, here a secret key bit) is the matrix of
//! (k+1)·ℓ GLWE ciphertexts `Enc(0) + m·(q/B^l)·e_i` — the gadget rows.
//! The external product `GLWE ⊠ GGSW(m)` decomposes the GLWE into signed
//! base-B digits and recombines against the rows, yielding an encryption
//! of `m · msg` with additive noise. CMux(GGSW(b), c0, c1) = c0 + (c1−c0)
//! ⊠ GGSW(b) selects between two ciphertexts under encryption — the
//! building block of the blind rotation.

use super::fft::{C64, NegacyclicFft};
use super::glwe::{GlweCiphertext, GlweSecretKey};
use super::params::DecompParams;
use super::torus::Torus;
use crate::util::prng::Xoshiro256;

/// Signed (balanced) base-2^base_log decomposition of a torus polynomial:
/// returns `level` digit polynomials, most-significant first, with digits
/// in `[−B/2, B/2)`, such that `Σ_l digits[l]·q/B^(l+1) ≈ poly` (error
/// ≤ q/(2B^level)).
pub fn decompose_poly(poly: &[Torus], d: DecompParams) -> Vec<Vec<i64>> {
    let mut digits = vec![vec![0i64; poly.len()]; d.level];
    decompose_poly_into(poly, d, &mut digits);
    digits
}

/// Allocation-free decomposition into caller-provided digit buffers.
pub fn decompose_poly_into(poly: &[Torus], d: DecompParams, digits: &mut [Vec<i64>]) {
    let b_log = d.base_log as u32;
    let half_b = 1i64 << (b_log - 1);
    let total = (d.level as u32) * b_log;
    debug_assert_eq!(digits.len(), d.level);
    for (j, &t) in poly.iter().enumerate() {
        // Round to the closest multiple of q/B^level (keep top `total` bits).
        let rounding = 1u64 << (64 - total - 1);
        let mut v = t.wrapping_add(rounding) >> (64 - total);
        // Balanced digit extraction, least-significant first.
        let mut carry = 0i64;
        for l in (0..d.level).rev() {
            let mut digit = ((v & ((1u64 << b_log) - 1)) as i64) + carry;
            v >>= b_log;
            carry = 0;
            if digit >= half_b {
                digit -= 1i64 << b_log;
                carry = 1;
            }
            digits[l][j] = digit;
        }
        // Any final carry wraps modulo the torus — dropped by design.
    }
}

/// GGSW ciphertext in the standard (coefficient) domain.
#[derive(Clone, Debug)]
pub struct GgswCiphertext {
    /// (k+1)·level rows; row (i, l) at index `i*level + l`.
    pub rows: Vec<GlweCiphertext>,
    pub decomp: DecompParams,
    pub glwe_dim: usize,
}

impl GgswCiphertext {
    /// Encrypt a small integer (typically a key bit 0/1).
    pub fn encrypt(
        m: u64,
        key: &GlweSecretKey,
        decomp: DecompParams,
        noise_std: f64,
        rng: &mut Xoshiro256,
    ) -> Self {
        let n = key.poly_size;
        let k = key.dim();
        let mut rows = Vec::with_capacity((k + 1) * decomp.level);
        for i in 0..=k {
            for l in 1..=decomp.level {
                let zero = vec![0u64; n];
                let mut ct = GlweCiphertext::encrypt(&zero, key, noise_std, rng);
                // Add m·q/B^l to component i (mask polys 0..k−1, body = k).
                let shift = 64 - (decomp.base_log * l) as u32;
                let g = m.wrapping_shl(shift);
                if i < k {
                    ct.mask[i][0] = ct.mask[i][0].wrapping_add(g);
                } else {
                    ct.body[0] = ct.body[0].wrapping_add(g);
                }
                rows.push(ct);
            }
        }
        GgswCiphertext { rows, decomp, glwe_dim: k }
    }

    /// Move to the spectral (Fourier) domain for fast external products.
    pub fn to_fourier(&self, fft: &NegacyclicFft) -> GgswFourier {
        let rows = self
            .rows
            .iter()
            .map(|row| {
                let mut comps: Vec<Vec<C64>> =
                    row.mask.iter().map(|p| fft.forward_torus(p)).collect();
                comps.push(fft.forward_torus(&row.body));
                comps
            })
            .collect();
        GgswFourier {
            rows,
            decomp: self.decomp,
            glwe_dim: self.glwe_dim,
            poly_size: self.rows[0].poly_size,
        }
    }
}

/// GGSW in the spectral domain: per row, k+1 component spectra.
#[derive(Clone, Debug, PartialEq)]
pub struct GgswFourier {
    pub rows: Vec<Vec<Vec<C64>>>,
    pub decomp: DecompParams,
    pub glwe_dim: usize,
    pub poly_size: usize,
}

/// Reusable scratch buffers for external products / CMux chains (one per
/// PBS call; shared across all `n` CMux of a blind rotation). Eliminates
/// every per-CMux heap allocation on the hot path — see rust/DESIGN.md
/// §6.
pub struct ExtScratch {
    /// Spectrum of one decomposed digit polynomial.
    spec: Vec<C64>,
    /// k+1 spectral accumulators.
    acc: Vec<Vec<C64>>,
    /// `level` digit polynomials.
    digits: Vec<Vec<i64>>,
    /// CMux difference ciphertext.
    pub diff: GlweCiphertext,
    /// Blind-rotation rotated accumulator.
    pub rotated: GlweCiphertext,
}

impl ExtScratch {
    pub fn new(poly_size: usize, glwe_dim: usize, decomp: DecompParams) -> Self {
        let half = poly_size / 2;
        ExtScratch {
            spec: vec![C64::default(); half],
            acc: vec![vec![C64::default(); half]; glwe_dim + 1],
            digits: vec![vec![0i64; poly_size]; decomp.level],
            diff: GlweCiphertext::zero(poly_size, glwe_dim),
            rotated: GlweCiphertext::zero(poly_size, glwe_dim),
        }
    }
}

impl GgswFourier {
    /// External product `glwe ⊠ self` → GLWE of `m · msg(glwe)`.
    pub fn external_product(&self, fft: &NegacyclicFft, glwe: &GlweCiphertext) -> GlweCiphertext {
        let mut out = GlweCiphertext::zero(self.poly_size, self.glwe_dim);
        let mut scratch = ExtScratch::new(self.poly_size, self.glwe_dim, self.decomp);
        self.external_product_into(fft, glwe, &mut out, &mut scratch);
        out
    }

    /// Allocation-free external product into `out` (hot path).
    pub fn external_product_into(
        &self,
        fft: &NegacyclicFft,
        glwe: &GlweCiphertext,
        out: &mut GlweCiphertext,
        s: &mut ExtScratch,
    ) {
        let k = self.glwe_dim;
        for a in s.acc.iter_mut() {
            a.fill(C64::default());
        }
        // Decompose all k+1 components of the input GLWE and accumulate
        // spectral products against the GGSW rows.
        let mut row_idx = 0;
        for i in 0..=k {
            let comp: &[Torus] = if i < k { &glwe.mask[i] } else { &glwe.body };
            decompose_poly_into(comp, self.decomp, &mut s.digits);
            for digit_poly in s.digits.iter() {
                fft.forward_signed_into(digit_poly, &mut s.spec);
                let row = &self.rows[row_idx];
                for (c, rc) in s.acc.iter_mut().zip(row.iter()) {
                    NegacyclicFft::mul_acc(c, &s.spec, rc);
                }
                row_idx += 1;
            }
        }
        for (i, spec) in s.acc.iter_mut().enumerate() {
            let poly = if i < k { &mut out.mask[i] } else { &mut out.body };
            fft.backward_torus_into(spec, poly);
        }
    }

    /// CMux: homomorphic select, `b=0 → c0`, `b=1 → c1`.
    pub fn cmux(
        &self,
        fft: &NegacyclicFft,
        c0: &GlweCiphertext,
        c1: &GlweCiphertext,
    ) -> GlweCiphertext {
        let diff = c1.sub(c0);
        let mut sel = self.external_product(fft, &diff);
        sel.add_assign(c0);
        sel
    }

    /// Blind-rotation step, allocation-free:
    /// `acc ← CMux(self, acc, acc·X^rot)` using the scratch buffers.
    pub fn cmux_rotate_assign(
        &self,
        fft: &NegacyclicFft,
        acc: &mut GlweCiphertext,
        rot: u64,
        s: &mut ExtScratch,
    ) {
        // rotated = acc · X^rot  (written into scratch)
        let mut rotated = std::mem::replace(
            &mut s.rotated,
            GlweCiphertext::zero(0, 0), // placeholder, swapped back below
        );
        acc.rotate_monomial_into(rot, &mut rotated);
        // diff = rotated − acc
        let mut diff = std::mem::replace(&mut s.diff, GlweCiphertext::zero(0, 0));
        rotated.sub_into(acc, &mut diff);
        // prod = diff ⊠ self  (reuse `rotated` as the output buffer)
        self.external_product_into(fft, &diff, &mut rotated, s);
        // acc += prod
        acc.add_assign(&rotated);
        s.rotated = rotated;
        s.diff = diff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::torus::{torus_distance, torus_from_f64};
    use crate::util::prng::Rng64;

    const STD: f64 = 1.0 / (1u64 << 45) as f64;

    fn recompose(digits: &[Vec<i64>], d: DecompParams, j: usize) -> u64 {
        let mut acc = 0u64;
        for (l, dp) in digits.iter().enumerate() {
            let shift = 64 - (d.base_log * (l + 1)) as u32;
            acc = acc.wrapping_add((dp[j] as u64).wrapping_shl(shift));
        }
        acc
    }

    #[test]
    fn decomposition_recomposes_within_bound() {
        let mut rng = Xoshiro256::new(3);
        let d = DecompParams::new(8, 3);
        let poly: Vec<u64> = (0..64).map(|_| rng.next_u64()).collect();
        let digits = decompose_poly(&poly, d);
        let err_bound = 1u64 << (64 - 24 - 1); // q / (2·B^level)
        for j in 0..64 {
            let rec = recompose(&digits, d, j);
            let err = (rec.wrapping_sub(poly[j])) as i64;
            assert!(
                (err.unsigned_abs()) <= err_bound,
                "j={j}: err {err} bound {err_bound}"
            );
        }
    }

    #[test]
    fn decomposition_digits_are_balanced() {
        let mut rng = Xoshiro256::new(13);
        let d = DecompParams::new(6, 4);
        let poly: Vec<u64> = (0..128).map(|_| rng.next_u64()).collect();
        for dp in decompose_poly(&poly, d) {
            for &v in &dp {
                assert!((-32..32).contains(&v), "digit {v} out of balanced range");
            }
        }
    }

    #[test]
    fn external_product_by_bit() {
        let mut rng = Xoshiro256::new(7);
        let n = 256;
        let key = GlweSecretKey::generate(n, 1, &mut rng);
        let fft = NegacyclicFft::new(n);
        let d = DecompParams::new(10, 3);
        let mut msg = vec![0u64; n];
        msg[0] = torus_from_f64(0.25);
        msg[3] = torus_from_f64(-0.125);
        let glwe = GlweCiphertext::encrypt(&msg, &key, STD, &mut rng);
        for bit in [0u64, 1] {
            let ggsw = GgswCiphertext::encrypt(bit, &key, d, STD, &mut rng).to_fourier(&fft);
            let out = ggsw.external_product(&fft, &glwe);
            let dec = out.decrypt(&key);
            for j in 0..n {
                let want = if bit == 1 { msg[j] } else { 0 };
                assert!(
                    torus_distance(dec[j], want) < 1e-4,
                    "bit={bit} j={j}: {} vs {want}",
                    dec[j]
                );
            }
        }
    }

    #[test]
    fn cmux_selects() {
        let mut rng = Xoshiro256::new(11);
        let n = 256;
        let key = GlweSecretKey::generate(n, 1, &mut rng);
        let fft = NegacyclicFft::new(n);
        let d = DecompParams::new(10, 3);
        let m0 = torus_from_f64(0.1);
        let m1 = torus_from_f64(-0.2);
        let c0 = GlweCiphertext::encrypt(&vec![m0; n], &key, STD, &mut rng);
        let c1 = GlweCiphertext::encrypt(&vec![m1; n], &key, STD, &mut rng);
        for (bit, want) in [(0u64, m0), (1, m1)] {
            let ggsw = GgswCiphertext::encrypt(bit, &key, d, STD, &mut rng).to_fourier(&fft);
            let sel = ggsw.cmux(&fft, &c0, &c1);
            let dec = sel.decrypt(&key);
            assert!(torus_distance(dec[0], want) < 1e-4, "bit={bit}");
        }
    }
}
