//! A working TFHE implementation (S4–S5): torus arithmetic, LWE/GLWE/GGSW
//! ciphertexts, FFT-based external products, programmable bootstrapping,
//! key switching, integer encoding, and the encrypted operator layer the
//! attention circuits are built on.
//!
//! This substitutes for the Concrete compiler the paper used (see
//! rust/DESIGN.md §3): the scheme is real — ciphertexts, noise, blind
//! rotations — so measured *relative* costs (PBS-dominated; ct×ct = 2 PBS;
//! precision → polynomial size → time) are physical, not modeled.
//!
//! Security note: parameters follow a λ=128 curve approximating the
//! lattice estimator (see `optimizer::noise`), but the RNG is not a
//! CSPRNG and no constant-time discipline is attempted — this is a
//! research artifact for cost reproduction, not a deployment library.

pub mod bootstrap;
pub mod codec;
pub mod encoding;
pub mod faults;
pub mod fft;
pub mod ggsw;
pub mod glwe;
pub mod keyswitch;
pub mod lwe;
pub mod ops;
pub mod params;
pub mod plan;
pub mod radix;
pub mod torus;

/// Serializes unit tests that bootstrap (and hence touch the
/// process-global `PBS_COUNT`): the parallel test harness would otherwise
/// interleave counter deltas and flake the exact-count assertions.
#[cfg(test)]
pub(crate) fn pbs_test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

pub use bootstrap::{
    blind_rotation_count, pbs_batch_keyed, pbs_batch_keyed_isolated, pbs_count,
    reset_blind_rotation_count, reset_pbs_count, BatchJob, ClientKey, KeyedJob, Lut, PoolStats,
    PreparedLut, PreparedMultiLut, ServerKey,
};
pub use codec::{decode_bundle, decode_server_key, CtCodec};
pub use encoding::Encoder;
pub use faults::{CancelToken, FaultPlan};
pub use ops::{ct_clone_count, default_fhe_threads, CtInt, FheContext};
pub use params::{DecompParams, TfheParams};
pub use plan::{
    rewrites_disabled, set_wavefront_dispatch, wavefront_enabled, CircuitBuilder, CircuitPlan,
    LevelJob, LutRef, NodeId, PlanRewriter, PlanRun, RewriteConfig, RewriteStats,
};
pub use radix::{set_radix_native_bits, RadixConfig, RadixInfo, RadixSpec};
