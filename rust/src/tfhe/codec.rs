//! Alloc-free fixed-layout binary codec for ciphertext bundles and
//! server-key material (S9) — the serialization seam under the
//! `coordinator::storage` spill tier.
//!
//! Everything is **little-endian u64 words** appended to a reusable
//! buffer ([`CtCodec`] keeps its `Vec<u8>` across calls, so a warmed
//! encoder performs zero heap allocation per bundle). No serde: the
//! offline build vendors nothing, and the layouts below are small enough
//! to keep honest by hand. `f64` fields travel as IEEE-754 bit patterns
//! (`to_bits`/`from_bits`) so round-trips are bit-exact, which is the
//! contract the spill tier's differential tests pin (a rehydrated decode
//! stream must be *bit-identical* to one served all-in-memory).
//!
//! Layouts (one u64 word each unless noted):
//!
//! **Bundle** (`encode_bundle`): `BUNDLE_MAGIC`, `meta` (caller-owned,
//! e.g. the decode cache's `cached_len`), `count`, `dim`, then per
//! ciphertext `dim` mask words followed by the body word. The dimension
//! is uniform across the bundle — every ciphertext in one session lives
//! under one parameter set.
//!
//! **Server key** (`encode_server_key`): `KEY_MAGIC`, 11 parameter words
//! (`lwe_dim`, `poly_size`, `glwe_dim`, the two noise stds as f64 bits,
//! `pbs_decomp` base/level, `ks_decomp` base/level, `message_bits`,
//! `many_lut_log`), then the bootstrap key (count, then per GGSW the
//! nested `rows`/`row`/`poly` lengths and two words per spectral
//! coefficient) and the key-switch rows (nested lengths, mask words,
//! body). The FFT plan is *not* serialized: its twiddles are a pure
//! function of `poly_size`, so the decoder rebuilds it.
//!
//! Decoding is defensive — truncated input, a wrong magic, or a length
//! prefix larger than the remaining payload all return `Err(String)`
//! before any oversized allocation happens.

use super::bootstrap::ServerKey;
use super::fft::C64;
use super::ggsw::GgswFourier;
use super::keyswitch::KeySwitchKey;
use super::lwe::LweCiphertext;
use super::ops::CtInt;
use super::params::{DecompParams, TfheParams};

/// Format tag for ciphertext bundles (ASCII "CTBNDL" + version 1).
pub const BUNDLE_MAGIC: u64 = 0x0100_4C44_4E42_5443;
/// Format tag for server-key material (ASCII "SRVKEY" + version 1).
pub const KEY_MAGIC: u64 = 0x0100_5945_4B56_5253;

/// Reusable encoder: owns one append buffer that survives across calls,
/// so steady-state encoding allocates nothing.
#[derive(Default)]
pub struct CtCodec {
    buf: Vec<u8>,
}

impl CtCodec {
    pub fn new() -> Self {
        CtCodec::default()
    }

    #[inline]
    fn word(&mut self, w: u64) {
        self.buf.extend_from_slice(&w.to_le_bytes());
    }

    /// Encode a ciphertext bundle plus one caller-owned `meta` word into
    /// the internal buffer and return the encoded bytes. The returned
    /// slice is valid until the next `encode_*` call. Panics if the
    /// bundle mixes LWE dimensions (one session = one parameter set; a
    /// mixed bundle is a coordinator logic error, not bad input).
    pub fn encode_bundle(&mut self, cts: &[CtInt], meta: u64) -> &[u8] {
        self.buf.clear();
        let dim = cts.first().map(|c| c.ct.mask.len()).unwrap_or(0);
        self.word(BUNDLE_MAGIC);
        self.word(meta);
        self.word(cts.len() as u64);
        self.word(dim as u64);
        for ct in cts {
            assert_eq!(ct.ct.mask.len(), dim, "bundle mixes LWE dimensions");
            for &m in &ct.ct.mask {
                self.word(m);
            }
            self.word(ct.ct.body);
        }
        &self.buf
    }

    /// Encode a server key's material (params + bootstrap key +
    /// key-switch key) into the internal buffer. The FFT plan is
    /// deliberately omitted — see the module docs.
    pub fn encode_server_key(&mut self, sk: &ServerKey) -> &[u8] {
        self.buf.clear();
        self.word(KEY_MAGIC);
        let p = &sk.params;
        self.word(p.lwe_dim as u64);
        self.word(p.poly_size as u64);
        self.word(p.glwe_dim as u64);
        self.word(p.lwe_noise_std.to_bits());
        self.word(p.glwe_noise_std.to_bits());
        self.word(p.pbs_decomp.base_log as u64);
        self.word(p.pbs_decomp.level as u64);
        self.word(p.ks_decomp.base_log as u64);
        self.word(p.ks_decomp.level as u64);
        self.word(u64::from(p.message_bits));
        self.word(u64::from(p.many_lut_log));
        let bsk = sk.bsk();
        self.word(bsk.len() as u64);
        for ggsw in bsk {
            self.word(ggsw.rows.len() as u64);
            for row in &ggsw.rows {
                self.word(row.len() as u64);
                for poly in row {
                    self.word(poly.len() as u64);
                    for c in poly {
                        self.word(c.re.to_bits());
                        self.word(c.im.to_bits());
                    }
                }
            }
        }
        let ksk_rows = sk.ksk().rows();
        self.word(ksk_rows.len() as u64);
        for row in ksk_rows {
            self.word(row.len() as u64);
            for ct in row {
                self.word(ct.mask.len() as u64);
                for &m in &ct.mask {
                    self.word(m);
                }
                self.word(ct.body);
            }
        }
        &self.buf
    }
}

/// Cursor over the encoded words; every read is bounds-checked so a
/// truncated or corrupt blob fails fast instead of panicking or
/// allocating absurdly.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    fn remaining_words(&self) -> usize {
        (self.bytes.len() - self.pos) / 8
    }

    fn word(&mut self) -> Result<u64, String> {
        let end = self.pos + 8;
        if end > self.bytes.len() {
            return Err(format!("truncated blob: wanted 8 bytes at offset {}", self.pos));
        }
        let mut raw = [0u8; 8];
        raw.copy_from_slice(&self.bytes[self.pos..end]);
        self.pos = end;
        Ok(u64::from_le_bytes(raw))
    }

    /// Read a length prefix whose elements each occupy at least one
    /// word, rejecting any count that cannot fit in the remaining
    /// payload (the guard against corrupt-length allocation bombs).
    fn len(&mut self, what: &str) -> Result<usize, String> {
        let n = self.word()?;
        if n as usize > self.remaining_words() {
            return Err(format!("{what} length {n} exceeds remaining payload"));
        }
        Ok(n as usize)
    }

    fn done(&self) -> Result<(), String> {
        if self.pos == self.bytes.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after decode", self.bytes.len() - self.pos))
        }
    }
}

/// Decode a ciphertext bundle; inverse of [`CtCodec::encode_bundle`].
/// Returns the ciphertexts and the caller's `meta` word.
pub fn decode_bundle(bytes: &[u8]) -> Result<(Vec<CtInt>, u64), String> {
    let mut r = Reader::new(bytes);
    let magic = r.word()?;
    if magic != BUNDLE_MAGIC {
        return Err(format!("bad bundle magic {magic:#018x}"));
    }
    let meta = r.word()?;
    let count = r.len("bundle ciphertext")?;
    let dim = r.word()? as usize;
    let fits = count
        .checked_mul(dim + 1)
        .map(|w| w <= r.remaining_words())
        .unwrap_or(false);
    if !fits {
        return Err(format!("bundle of {count} x dim {dim} exceeds remaining payload"));
    }
    let mut cts = Vec::with_capacity(count);
    for _ in 0..count {
        let mut mask = Vec::with_capacity(dim);
        for _ in 0..dim {
            mask.push(r.word()?);
        }
        let body = r.word()?;
        cts.push(CtInt { ct: LweCiphertext { mask, body } });
    }
    r.done()?;
    Ok((cts, meta))
}

/// Decode server-key material; inverse of
/// [`CtCodec::encode_server_key`]. Rebuilds the FFT plan from
/// `poly_size` — the decoded key is `key_material_eq` to the original
/// and PBS under it is bit-identical.
pub fn decode_server_key(bytes: &[u8]) -> Result<ServerKey, String> {
    let mut r = Reader::new(bytes);
    let magic = r.word()?;
    if magic != KEY_MAGIC {
        return Err(format!("bad server-key magic {magic:#018x}"));
    }
    let params = TfheParams {
        lwe_dim: r.word()? as usize,
        poly_size: r.word()? as usize,
        glwe_dim: r.word()? as usize,
        lwe_noise_std: f64::from_bits(r.word()?),
        glwe_noise_std: f64::from_bits(r.word()?),
        pbs_decomp: DecompParams::new(r.word()? as usize, r.word()? as usize),
        ks_decomp: DecompParams::new(r.word()? as usize, r.word()? as usize),
        message_bits: r.word()? as u32,
        many_lut_log: r.word()? as u32,
    };
    let n_ggsw = r.len("bootstrap-key")?;
    let mut bsk = Vec::with_capacity(n_ggsw);
    for _ in 0..n_ggsw {
        let n_rows = r.len("ggsw row")?;
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            let n_polys = r.len("ggsw component")?;
            let mut row = Vec::with_capacity(n_polys);
            for _ in 0..n_polys {
                let n_coeffs = r.len("spectrum coefficient")?;
                let mut poly = Vec::with_capacity(n_coeffs);
                for _ in 0..n_coeffs {
                    let re = f64::from_bits(r.word()?);
                    let im = f64::from_bits(r.word()?);
                    poly.push(C64 { re, im });
                }
                row.push(poly);
            }
            rows.push(row);
        }
        bsk.push(GgswFourier {
            rows,
            decomp: params.pbs_decomp,
            glwe_dim: params.glwe_dim,
            poly_size: params.poly_size,
        });
    }
    let n_ksk = r.len("key-switch row")?;
    let mut ksk_rows = Vec::with_capacity(n_ksk);
    for _ in 0..n_ksk {
        let n_cts = r.len("key-switch level")?;
        let mut row = Vec::with_capacity(n_cts);
        for _ in 0..n_cts {
            let dim = r.len("key-switch mask")?;
            let mut mask = Vec::with_capacity(dim);
            for _ in 0..dim {
                mask.push(r.word()?);
            }
            let body = r.word()?;
            row.push(LweCiphertext { mask, body });
        }
        ksk_rows.push(row);
    }
    r.done()?;
    let ksk = KeySwitchKey::from_material(ksk_rows, params.ks_decomp, params.lwe_dim);
    Ok(ServerKey::from_material(params, bsk, ksk))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::tfhe::ops::FheContext;
    use crate::util::prng::Xoshiro256;

    fn context() -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(901);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    #[test]
    fn bundle_roundtrip_is_bit_exact_and_buffer_is_reused() {
        let (ck, ctx, mut rng) = context();
        let cts: Vec<CtInt> = (0..5).map(|i| ctx.encrypt(i - 2, &ck, &mut rng)).collect();
        let mut codec = CtCodec::new();
        let bytes = codec.encode_bundle(&cts, 42).to_vec();
        let (back, meta) = decode_bundle(&bytes).expect("decodes");
        assert_eq!(meta, 42);
        assert_eq!(back.len(), cts.len());
        for (a, b) in back.iter().zip(&cts) {
            assert_eq!(a.ct, b.ct, "bit-exact round trip");
        }
        // Warmed encoder: re-encoding an equally-sized bundle must not
        // grow the buffer (alloc-free steady state).
        let cap = {
            codec.encode_bundle(&cts, 7);
            codec.buf.capacity()
        };
        codec.encode_bundle(&cts, 9);
        assert_eq!(codec.buf.capacity(), cap, "no realloc on re-encode");
        // Empty bundles are legal (reserved slots travel as zero cts).
        let empty = codec.encode_bundle(&[], 3).to_vec();
        let (none, meta) = decode_bundle(&empty).expect("decodes");
        assert!(none.is_empty());
        assert_eq!(meta, 3);
    }

    #[test]
    fn corrupt_bundles_are_rejected_not_panicked() {
        let (ck, ctx, mut rng) = context();
        let cts = vec![ctx.encrypt(1, &ck, &mut rng)];
        let mut codec = CtCodec::new();
        let bytes = codec.encode_bundle(&cts, 0).to_vec();
        // Wrong magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(decode_bundle(&bad).is_err());
        // Truncation at every word boundary.
        for cut in (8..bytes.len()).step_by(8) {
            assert!(decode_bundle(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 8]);
        assert!(decode_bundle(&long).is_err());
        // Absurd count must fail before allocating (length guard).
        let mut bomb = bytes.clone();
        bomb[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_bundle(&bomb).is_err());
    }

    #[test]
    fn server_key_roundtrip_preserves_key_material() {
        let (_ck, ctx, _rng) = context();
        let mut codec = CtCodec::new();
        let bytes = codec.encode_server_key(&ctx.sk).to_vec();
        let back = decode_server_key(&bytes).expect("decodes");
        assert!(back.key_material_eq(&ctx.sk), "params + bsk + ksk survive");
        // Corrupt magic and truncation fail typed.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(decode_server_key(&bad).is_err());
        assert!(decode_server_key(&bytes[..bytes.len() - 8]).is_err());
    }

    #[test]
    fn decoded_server_key_evaluates_bit_identically() {
        // PBS is deterministic server-side, so a rebuilt key (fresh FFT
        // plan, decoded material) must produce the *same ciphertext* as
        // the original — the property the spill tier's cold-attach path
        // rests on.
        let _guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = context();
        let mut codec = CtCodec::new();
        let bytes = codec.encode_server_key(&ctx.sk).to_vec();
        let rebuilt = FheContext::new(decode_server_key(&bytes).expect("decodes"));
        for v in [-2i64, 0, 3] {
            let x = ctx.encrypt(v, &ck, &mut rng);
            let a = ctx.relu(&x);
            let b = rebuilt.relu(&x);
            assert_eq!(a.ct, b.ct, "relu({v}) bit-identical under decoded key");
            assert_eq!(rebuilt.decrypt(&b, &ck), v.max(0));
        }
    }
}
