//! Encrypted attention circuits (S6): the paper's two mechanisms as
//! declarative `tfhe::plan` builders (executed level-by-level through the
//! batched PBS engine), plus plaintext mirrors used for exact correctness
//! checks and the PR 1 hand-staged forwards kept as bit-identity
//! references.

pub mod attention_fhe;

pub use attention_fhe::{CtMatrix, DotProductFhe, InhibitorFhe};
