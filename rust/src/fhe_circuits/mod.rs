//! Encrypted attention circuits (S6): the paper's mechanisms as
//! declarative `tfhe::plan` builders (executed level-by-level through the
//! batched PBS engine after the rewrite pipeline), plus plaintext
//! mirrors used for exact correctness checks and the PR 1 hand-staged
//! forwards kept as bit-identity references. The signed Inhibitor
//! (paper eq. 7) is transcribed verbatim — its redundancy is the
//! rewriter's to remove. `multihead` fuses H heads of any mechanism
//! into one combined plan, where the rewrite passes finally work
//! *across* head boundaries (S6b). `block_fhe` completes the picture:
//! the full transformer block (attention + W_O + residuals + requants +
//! ReLU FFN) as one plan, stacked over L layers into a single DAG so
//! the passes also work across *layer* boundaries (S6c). `decode` turns
//! the stacked model autoregressive (S7): per-token step plans over an
//! encrypted KV-cache, the causal prefill built from the same per-token
//! recurrence, and the streaming plaintext mirror.

pub mod attention_fhe;
pub mod block_fhe;
pub mod decode;
pub mod multihead;

pub use attention_fhe::{CtMatrix, DotProductFhe, InhibitorFhe, InhibitorSignedFhe};
pub use block_fhe::{block_engine_mechanism, BlockFhe, BlockWeights, ModelFhe};
pub use decode::{decode_engine_mechanism, DecodeFhe, DecodeMirror};
pub use multihead::{multihead_engine_mechanism, MultiHeadFhe};
