//! Encrypted attention circuits (S6): the paper's two mechanisms composed
//! from the `tfhe::ops` operator layer, plus plaintext mirrors used for
//! exact correctness checks and PBS accounting.

pub mod attention_fhe;

pub use attention_fhe::{CtMatrix, DotProductFhe, InhibitorFhe};
