//! Incremental **decode** subsystem (S7): token-by-token encrypted
//! inference with an encrypted KV-cache, instead of recomputing the full
//! T×T attention every forward.
//!
//! ## The recurrence
//!
//! Real serving of the paper's inhibitor attention is autoregressive:
//! one new token enters, attends **causally** over everything before it,
//! and the model emits one output row. [`DecodeFhe`] compiles exactly
//! that recurrence:
//!
//! * a **step plan** ([`DecodeFhe::step_plan`]) takes the new token's
//!   `[D]` input row plus the *cache bundle* at prefix length `t` as
//!   plan inputs, and emits only the new token's work — the new row's
//!   scores against every cached position, the inhibition sums over
//!   cached values, the W_O/FFN/residual row — returning the output row
//!   plus the cache *extension* (each layer's new residual-stream row
//!   and, for the signed mechanism, the new (v⁺, v⁻) split pair). Fresh
//!   PBS per token is **O(T·d)**, not O(T²·d).
//! * a **prefill plan** ([`DecodeFhe::prefill_plan`]) bootstraps a
//!   stream: the *same* per-token emitter ([`DecodeFhe`]'s internal
//!   `emit_token`) looped over the `[T, D]` input grid, so the causal
//!   prefill is **by construction** the identical dataflow as T
//!   consecutive steps — the step ≡ one-shot bit-identity the
//!   differential harness pins is structural, not coincidental. Its
//!   output tail *is* the cache bundle at `t = T`.
//!
//! The degenerate `T = 1` stream is the companion paper's gated-RNN
//! workload: prefill one token, then pure recurrence — same plans, same
//! cache, no special case.
//!
//! ## Cache bundle layout
//!
//! One flat `Vec<CtInt>`, per layer ℓ in order:
//!
//! ```text
//! x^ℓ rows      t·D          layer ℓ's INPUT rows, position-major
//!                            (x⁰ = model input; x^ℓ = layer ℓ−1 out)
//! split pairs   2·t·vcols    signed mechanism only: the (v⁺, v⁻)
//!                            pairs, position-major, interleaved p,n
//! ```
//!
//! with `vcols = d_head` under `shared_kv` else `D`. Cached positions
//! cost **zero** fresh PBS at every later step: K rows are the cached
//! x rows verbatim (q = k = v residual-stream attention), and the
//! signed value splits — the one per-position PBS product the full
//! circuit re-derives T times — are cached post-PBS. The residual
//! *accumulator* seam (the ϑ ≥ 2 trio fold of the block circuit) never
//! enters the cache: layer ℓ's new-token splits read layer ℓ−1's
//! accumulator row **in-step**, threading through the step plan exactly
//! as the full stacked plan threads it across layers.
//!
//! Closed forms for the per-step counts live in
//! [`crate::optimizer::precision::profile_step`] and are pinned against
//! the plan oracles; because every per-call LUT (`ssr`, `exp`, `recip`,
//! `rescale`) registers fresh per token and causal ordering admits no
//! transposed product pairs, the prefill counts are *exactly* the sum of
//! the step counts over prefixes — also pinned.
//!
//! The plaintext reference is [`DecodeMirror`]: the same streaming
//! recurrence over integer state with every LUT clamp applied, matching
//! the encrypted decode bit for bit.

use super::attention_fhe::{
    exp_lut_at, scaled_shift_relu, CtMatrix, DotProductFhe, InhibitorFhe, InhibitorSignedFhe,
    PlanCache,
};
use super::block_fhe::{mirror_linear, BlockFhe, ModelFhe};
use crate::attention::Mechanism;
use crate::quant::FixedMult;
use crate::tensor::ITensor;
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitBuilder, CircuitPlan, NodeId};
use std::sync::Arc;

/// Per-layer node state threaded through `emit_token`: this layer's
/// input rows so far, plus (signed mechanism) the cached split pairs.
struct LayerState {
    x_rows: Vec<NodeId>,
    splits: Vec<(NodeId, NodeId)>,
}

/// The incremental-decode compiler over a [`ModelFhe`] block stack: step
/// plans per prefix length, the causal prefill plan, and the cache
/// bundle plumbing (see the module docs for the layout).
#[derive(Clone, Debug)]
pub struct DecodeFhe {
    pub model: ModelFhe,
    /// Step plans keyed `(t_cached, D, budget)`.
    step_cache: Arc<PlanCache>,
    /// Prefill plans keyed `(T, D, budget)` — a separate cache so a
    /// step plan at prefix t and a prefill of length t cannot collide.
    prefill_cache: Arc<PlanCache>,
}

impl DecodeFhe {
    pub fn new(model: ModelFhe) -> Self {
        DecodeFhe {
            model,
            step_cache: Arc::new(PlanCache::default()),
            prefill_cache: Arc::new(PlanCache::default()),
        }
    }

    /// Declare the wrapped model's output accumulators `bits` wide (see
    /// [`ModelFhe::with_accumulator_bits`]): step and prefill output
    /// *rows* become radix limb vectors, while the cache bundle stays
    /// narrow — cached rows are layer inputs, which wide outputs never
    /// feed. Resets both plan caches.
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        self.model = self.model.with_accumulator_bits(bits);
        self.step_cache = Arc::new(PlanCache::default());
        self.prefill_cache = Arc::new(PlanCache::default());
        self
    }

    pub fn d_model(&self) -> usize {
        self.model.split.d_model
    }

    pub fn n_layers(&self) -> usize {
        self.model.n_layers()
    }

    fn signed(&self) -> bool {
        self.model.mechanism == Mechanism::InhibitorSigned
    }

    /// Width of the cached split rows: the shared K/V slice under
    /// multi-query, the full stream otherwise.
    fn vcols(&self) -> usize {
        if self.model.shared_kv { self.model.split.d_head() } else { self.d_model() }
    }

    /// Cache ciphertexts per position per layer (see the module docs).
    fn per_position_len(&self) -> usize {
        self.d_model() + if self.signed() { 2 * self.vcols() } else { 0 }
    }

    /// One layer's cache slice length at prefix `t`.
    pub fn cache_layer_len(&self, t: usize) -> usize {
        t * self.per_position_len()
    }

    /// Total cache bundle length at prefix `t`.
    pub fn cache_len(&self, t: usize) -> usize {
        self.n_layers() * self.cache_layer_len(t)
    }

    /// Prefix length a well-formed cache bundle of `len` ciphertexts
    /// encodes; `None` if `len` is not a whole number of positions.
    pub fn cached_len_of(&self, len: usize) -> Option<usize> {
        let per_t = self.n_layers() * self.per_position_len();
        if per_t == 0 || len % per_t != 0 {
            None
        } else {
            Some(len / per_t)
        }
    }

    /// Step-plan inputs at prefix `t`: the new `[D]` row, then the cache
    /// bundle in its canonical layout.
    pub fn n_step_inputs(&self, t: usize) -> usize {
        self.d_model() + self.cache_len(t)
    }

    /// Step-plan outputs: the final output row, then per layer the cache
    /// extension (new x row; signed: new split pair per value column).
    pub fn n_step_outputs(&self) -> usize {
        self.d_model() + self.n_layers() * self.per_position_len()
    }

    /// Mechanism string the serving registry keys decode engines by:
    /// `decode/<mechanism>@h<H>xL<L>[s]` (router key
    /// `fhe/decode/<mech>@h<H>xL<L>[s]/<session>`).
    pub fn engine_mechanism(&self) -> String {
        decode_engine_mechanism(
            self.model.mechanism,
            self.model.split.n_heads,
            self.n_layers(),
            self.model.shared_kv,
        )
    }

    /// Emit one token's pass through the whole block stack: the new
    /// row's work at every layer, against (and extending) the per-layer
    /// `states`. The accumulator seam threads across layers exactly as
    /// in [`ModelFhe::plan`]; each layer's consumed input row and new
    /// split pair are appended to its state, so after the call the state
    /// tails are this token's cache extension. Both the step and the
    /// prefill plan builders feed through here — the single definition
    /// of the decode recurrence.
    fn emit_token(
        &self,
        b: &mut CircuitBuilder,
        states: &mut [LayerState],
        x_row: &[NodeId],
    ) -> Vec<NodeId> {
        let dm = self.d_model();
        let mut row = x_row.to_vec();
        let mut acc: Option<(Vec<NodeId>, FixedMult)> = None;
        for (blk, st) in self.model.blocks.iter().zip(states.iter_mut()) {
            let t_cached = st.x_rows.len() / dm;
            let (out, naccs, new_pairs) = blk.emit_step(
                b,
                &row,
                acc.as_ref().map(|(a, m)| (a.as_slice(), *m)),
                &st.x_rows,
                &st.splits,
                t_cached,
            );
            st.x_rows.extend_from_slice(&row);
            st.splits.extend(new_pairs);
            acc = Some((naccs, blk.weights.resid_requant));
            row = out;
        }
        row
    }

    /// Build the step plan at prefix `t_cached`, **raw** (the rewrite
    /// pipeline is `step_plan_for`'s). Inputs: new row ‖ cache bundle;
    /// outputs: output row ‖ cache extension (layer 0's "new x row" is
    /// the plan's own input row, re-exported so every layer's extension
    /// has one shape).
    pub fn step_plan(&self, t_cached: usize) -> CircuitPlan {
        let dm = self.d_model();
        let vcols = self.vcols();
        let mut b = CircuitBuilder::new();
        let x_row = b.inputs(dm);
        let mut states = Vec::with_capacity(self.n_layers());
        for _ in 0..self.n_layers() {
            let x_rows = b.inputs(t_cached * dm);
            let splits = if self.signed() {
                let raw = b.inputs(2 * t_cached * vcols);
                raw.chunks(2).map(|p| (p[0], p[1])).collect()
            } else {
                Vec::new()
            };
            states.push(LayerState { x_rows, splits });
        }
        let out = self.emit_token(&mut b, &mut states, &x_row);
        for id in out {
            b.output(id);
        }
        for st in &states {
            for &id in &st.x_rows[t_cached * dm..] {
                b.output(id);
            }
            if self.signed() {
                for &(p, n) in &st.splits[t_cached * vcols..] {
                    b.output(p);
                    b.output(n);
                }
            }
        }
        b.build()
    }

    /// Build the causal prefill plan for `t` tokens, **raw**: the step
    /// recurrence looped over the `[T, D]` input grid. Outputs: the
    /// `[T, D]` causal output grid, then the cache bundle at prefix `t`
    /// (the per-layer states in canonical layout).
    pub fn prefill_plan(&self, t: usize) -> CircuitPlan {
        assert!(t >= 1, "prefill needs at least one token");
        let dm = self.d_model();
        let mut b = CircuitBuilder::new();
        let grid = b.inputs(t * dm);
        let mut states: Vec<LayerState> = (0..self.n_layers())
            .map(|_| LayerState { x_rows: Vec::new(), splits: Vec::new() })
            .collect();
        let mut outs = Vec::with_capacity(t * dm);
        for i in 0..t {
            let row = self.emit_token(&mut b, &mut states, &grid[i * dm..(i + 1) * dm]);
            outs.extend(row);
        }
        for id in outs {
            b.output(id);
        }
        for st in &states {
            for &id in &st.x_rows {
                b.output(id);
            }
            for &(p, n) in &st.splits {
                b.output(p);
                b.output(n);
            }
        }
        b.build()
    }

    /// The rewritten, cached step plan for prefix `t_cached` under `ctx`
    /// (honors `FHE_NO_REWRITE`, like every `plan_for`).
    pub fn step_plan_for(&self, ctx: &FheContext, t_cached: usize) -> Arc<CircuitPlan> {
        self.step_cache.rewritten_for(ctx, t_cached, self.d_model(), || self.step_plan(t_cached))
    }

    /// The rewritten, cached prefill plan for `t` tokens under `ctx`.
    pub fn prefill_plan_for(&self, ctx: &FheContext, t: usize) -> Arc<CircuitPlan> {
        self.prefill_cache.rewritten_for(ctx, t, self.d_model(), || self.prefill_plan(t))
    }

    /// Step-plan cache regression counter (see `InhibitorFhe::plan_builds`).
    pub fn step_plan_builds(&self) -> usize {
        self.step_cache.builds()
    }

    /// Prefill-plan cache regression counter.
    pub fn prefill_plan_builds(&self) -> usize {
        self.prefill_cache.builds()
    }

    /// Split a prefill plan's output vector into (causal `[T, D]` output
    /// rows, cache bundle at prefix `t`). The cache bundle has a fixed
    /// (always-narrow) length, so the split point is measured from the
    /// back — under a declared accumulator width the output rows expand
    /// to `D·limbs` slots each and this still lands correctly.
    pub fn cache_from_prefill(&self, t: usize, mut outputs: Vec<CtInt>) -> (Vec<CtInt>, Vec<CtInt>) {
        let dm = self.d_model();
        let cache_len = self.cache_len(t);
        assert!(outputs.len() >= t * dm + cache_len, "prefill output length");
        assert_eq!((outputs.len() - cache_len) % t, 0, "ragged prefill output rows");
        let cache = outputs.split_off(outputs.len() - cache_len);
        (outputs, cache)
    }

    /// Merge a step plan's outputs into the successor cache bundle:
    /// per layer, old x rows ‖ new x row ‖ old splits ‖ new splits.
    /// Consumes the pre-step bundle and returns `(output row, cache at
    /// t_cached + 1)`.
    pub fn cache_after_step(
        &self,
        t_cached: usize,
        old_cache: Vec<CtInt>,
        mut step_out: Vec<CtInt>,
    ) -> (Vec<CtInt>, Vec<CtInt>) {
        let dm = self.d_model();
        let vcols = self.vcols();
        assert_eq!(old_cache.len(), self.cache_len(t_cached), "pre-step cache length");
        // The cache extension is always narrow, so split from the back:
        // a wide-declared model returns `D·limbs` output-row slots.
        let ext_len = self.n_layers() * self.per_position_len();
        assert!(step_out.len() >= dm + ext_len, "step output length");
        let tail = step_out.split_off(step_out.len() - ext_len);
        let out_row = step_out;
        let mut cache = Vec::with_capacity(self.cache_len(t_cached + 1));
        let mut old = old_cache.into_iter();
        let mut new = tail.into_iter();
        for _ in 0..self.n_layers() {
            cache.extend(old.by_ref().take(t_cached * dm));
            cache.extend(new.by_ref().take(dm));
            if self.signed() {
                cache.extend(old.by_ref().take(2 * t_cached * vcols));
                cache.extend(new.by_ref().take(2 * vcols));
            }
        }
        (out_row, cache)
    }

    /// Encrypted prefill: execute the causal prefill plan over the
    /// `[T, D]` input grid and return (causal output rows, cache bundle).
    pub fn prefill(&self, ctx: &FheContext, x: &CtMatrix) -> (CtMatrix, Vec<CtInt>) {
        let dm = self.d_model();
        assert_eq!(x.cols, dm, "input must be [T, d_model]");
        let t = x.rows;
        let refs: Vec<&CtInt> = x.data.iter().collect();
        let outputs = self.prefill_plan_for(ctx, t).execute_ref(ctx, &refs);
        let (out, cache) = self.cache_from_prefill(t, outputs);
        let cols = out.len() / t;
        (CtMatrix { rows: t, cols, data: out }, cache)
    }

    /// Encrypted decode step: one new input row against (and consuming)
    /// the cache bundle; returns `(output row, successor cache)`.
    pub fn step(&self, ctx: &FheContext, x_row: &[CtInt], cache: Vec<CtInt>) -> (Vec<CtInt>, Vec<CtInt>) {
        let dm = self.d_model();
        assert_eq!(x_row.len(), dm, "step input must be one [d_model] row");
        let t_cached = self
            .cached_len_of(cache.len())
            .unwrap_or_else(|| panic!("malformed cache bundle of {} ciphertexts", cache.len()));
        let plan = self.step_plan_for(ctx, t_cached);
        let mut refs: Vec<&CtInt> = Vec::with_capacity(dm + cache.len());
        refs.extend(x_row.iter());
        refs.extend(cache.iter());
        let outputs = plan.execute_ref(ctx, &refs);
        self.cache_after_step(t_cached, cache, outputs)
    }
}

/// See [`DecodeFhe::engine_mechanism`]: `decode/<mech>@h<H>xL<L>[s]`.
pub fn decode_engine_mechanism(
    mech: Mechanism,
    n_heads: usize,
    n_layers: usize,
    shared_kv: bool,
) -> String {
    format!(
        "decode/{}@h{}xL{}{}",
        mech.name(),
        n_heads,
        n_layers,
        if shared_kv { "s" } else { "" }
    )
}

// ---------------------------------------------------------------------
// Plaintext streaming mirror
// ---------------------------------------------------------------------

/// Per-layer integer state of the streaming mirror.
struct MirrorLayer {
    /// This layer's input rows so far, `[t, D]` row-major.
    x_rows: Vec<i64>,
    /// Signed mechanism: cached v⁺ rows, `[t, vcols]`.
    vp: Vec<i64>,
    /// Signed mechanism: cached v⁻ rows, `[t, vcols]`.
    vn: Vec<i64>,
}

/// Plaintext mirror of the decode recurrence: the exact integer function
/// the step plans compute (every LUT clamp included), carried as mutable
/// per-layer state so a stream of `step` calls mirrors a stream of
/// encrypted steps position for position. Because the encrypted prefill
/// is the same recurrence looped, [`Self::prefill`] simply steps over
/// the grid rows.
pub struct DecodeMirror {
    model: ModelFhe,
    min_s: i64,
    max_s: i64,
    layers: Vec<MirrorLayer>,
}

impl DecodeMirror {
    /// `min_s`/`max_s` are the executing encoder's signed bounds (the
    /// LUT clamp range, e.g. −16..15 at 5 bits).
    pub fn new(model: &ModelFhe, min_s: i64, max_s: i64) -> Self {
        let layers = (0..model.n_layers())
            .map(|_| MirrorLayer { x_rows: Vec::new(), vp: Vec::new(), vn: Vec::new() })
            .collect();
        DecodeMirror { model: model.clone(), min_s, max_s, layers }
    }

    /// Positions decoded so far.
    pub fn cached_len(&self) -> usize {
        self.layers[0].x_rows.len() / self.model.split.d_model
    }

    /// One decode step: the new input row in, the output row back, state
    /// extended by one position.
    pub fn step(&mut self, x_row: &[i64]) -> Vec<i64> {
        let dm = self.model.split.d_model;
        assert_eq!(x_row.len(), dm, "step input must be one [d_model] row");
        let mut row = x_row.to_vec();
        let mut acc: Option<(Vec<i64>, FixedMult)> = None;
        // Split borrows: the block list is read-only while layer states
        // mutate, so iterate indices.
        for ell in 0..self.model.blocks.len() {
            let blk = &self.model.blocks[ell];
            let st = &self.layers[ell];
            let t_cached = st.x_rows.len() / dm;
            let (out, naccs, vp_new, vn_new) = mirror_block_step(
                blk,
                &row,
                acc.as_ref().map(|(a, m)| (a.as_slice(), *m)),
                &st.x_rows,
                &st.vp,
                &st.vn,
                t_cached,
                self.min_s,
                self.max_s,
            );
            let st = &mut self.layers[ell];
            st.x_rows.extend_from_slice(&row);
            st.vp.extend(vp_new);
            st.vn.extend(vn_new);
            acc = Some((naccs, blk.weights.resid_requant));
            row = out;
        }
        row
    }

    /// Causal prefill: step over the `[T, D]` grid rows, returning the
    /// `[T, D]` causal output grid.
    pub fn prefill(&mut self, x: &ITensor) -> ITensor {
        let dm = self.model.split.d_model;
        assert_eq!(x.dims()[1], dm, "input must be [T, d_model]");
        let t = x.dims()[0];
        let mut out = ITensor::zeros(&[t, dm]);
        for i in 0..t {
            let row = self.step(&x.data[i * dm..(i + 1) * dm]);
            out.data[i * dm..(i + 1) * dm].copy_from_slice(&row);
        }
        out
    }
}

/// Plaintext mirror of [`BlockFhe::emit_step`] (see `block_fhe`'s
/// `mirror_step` for the full-grid analogue): one new row through one
/// block, against cached state. Returns `(out_row, acc_row, vp_new,
/// vn_new)` — the split extensions empty for unsigned mechanisms.
#[allow(clippy::too_many_arguments)]
fn mirror_block_step(
    blk: &BlockFhe,
    x_row: &[i64],
    x_acc_row: Option<(&[i64], FixedMult)>,
    cached_x: &[i64],
    cached_vp: &[i64],
    cached_vn: &[i64],
    t_cached: usize,
    min_s: i64,
    max_s: i64,
) -> (Vec<i64>, Vec<i64>, Vec<i64>, Vec<i64>) {
    let dm = blk.split.d_model;
    let d = blk.split.d_head();
    let heads = blk.split.n_heads;
    let n = t_cached + 1;
    let clamp = |v: i64| v.clamp(min_s, max_s);
    let w = &blk.weights;
    // Row-major [n, d] column slice of cached rows + the new row.
    let seg = |rows: &[i64], new_row: &[i64], width: usize, col0: usize| -> Vec<i64> {
        let mut s = Vec::with_capacity(n * d);
        for j in 0..t_cached {
            for kk in 0..d {
                s.push(rows[j * width + col0 + kk]);
            }
        }
        for kk in 0..d {
            s.push(new_row[col0 + kk]);
        }
        s
    };
    let mut h_row = vec![0i64; dm];
    let (vp_new, vn_new) = match blk.mechanism {
        Mechanism::InhibitorSigned => {
            let vcols = if blk.shared_kv { d } else { dm };
            let mut vp_new = Vec::with_capacity(vcols);
            let mut vn_new = Vec::with_capacity(vcols);
            for c in 0..vcols {
                let (p, nn) = match x_acc_row {
                    Some((acc, m)) => {
                        let raw = m.apply(acc[c]);
                        (clamp(raw.max(0)), clamp(raw.min(0)))
                    }
                    None => (clamp(x_row[c].max(0)), clamp(x_row[c].min(0))),
                };
                vp_new.push(p);
                vn_new.push(nn);
            }
            // The same per-head defaults `MultiHeadFhe::new` documents
            // (α_q = 1) — the mirror's single source of the score table.
            let head = InhibitorSignedFhe::new(d, 1);
            for h in 0..heads {
                let c0 = blk.split.col0(h);
                let kc0 = if blk.shared_kv { 0 } else { c0 };
                let q = &x_row[c0..c0 + d];
                let k = seg(cached_x, x_row, dm, kc0);
                let vp = seg(cached_vp, &vp_new, vcols, kc0);
                let vn = seg(cached_vn, &vn_new, vcols, kc0);
                let out = step_mirror_signed_presplit(&head, q, &k, &vp, &vn, n, d, min_s, max_s);
                h_row[c0..c0 + d].copy_from_slice(&out);
            }
            (vp_new, vn_new)
        }
        Mechanism::Inhibitor => {
            let head = InhibitorFhe::new(d, 1);
            for h in 0..heads {
                let c0 = blk.split.col0(h);
                let kc0 = if blk.shared_kv { 0 } else { c0 };
                let q = &x_row[c0..c0 + d];
                let k = seg(cached_x, x_row, dm, kc0);
                let out = step_mirror_inhibitor(&head, q, &k, &k, n, d, max_s);
                h_row[c0..c0 + d].copy_from_slice(&out);
            }
            (Vec::new(), Vec::new())
        }
        Mechanism::DotProduct => {
            let head = DotProductFhe::new(d, 2);
            for h in 0..heads {
                let c0 = blk.split.col0(h);
                let kc0 = if blk.shared_kv { 0 } else { c0 };
                let q = &x_row[c0..c0 + d];
                let k = seg(cached_x, x_row, dm, kc0);
                let out = step_mirror_dotprod(&head, q, &k, &k, n, d, min_s, max_s);
                h_row[c0..c0 + d].copy_from_slice(&out);
            }
            (Vec::new(), Vec::new())
        }
    };
    // --- W_O + first residual, FFN, second residual: the block mirror
    // at t = 1, row-wise ---
    let h_t = ITensor::from_vec(&[1, dm], h_row);
    let wo_out = mirror_linear(&h_t, &w.wo, &w.wo_b, w.wo_requant, false, min_s, max_s);
    let x1: Vec<i64> =
        (0..dm).map(|c| clamp(w.resid_requant.apply(x_row[c] + wo_out.data[c]))).collect();
    let x1_t = ITensor::from_vec(&[1, dm], x1.clone());
    let h1 = mirror_linear(&x1_t, &w.fc1, &w.fc1_b, w.fc1_requant, true, min_s, max_s);
    let f = mirror_linear(&h1, &w.fc2, &w.fc2_b, w.fc2_requant, false, min_s, max_s);
    let mut out = Vec::with_capacity(dm);
    let mut accs = Vec::with_capacity(dm);
    for c in 0..dm {
        let acc = x1[c] + f.data[c];
        // Wide-declared output tail: the raw accumulator, as in
        // `BlockFhe::mirror_step`.
        out.push(if blk.out_acc_bits.is_some() {
            acc
        } else {
            clamp(w.resid_requant.apply(acc))
        });
        accs.push(acc);
    }
    (out, accs, vp_new, vn_new)
}

/// Row mirror of `InhibitorFhe::emit_step` — the single-row case of
/// `InhibitorFhe::mirror` (which, like its circuit, only clamps at the
/// table maximum).
#[allow(clippy::too_many_arguments)]
fn step_mirror_inhibitor(
    head: &InhibitorFhe,
    q: &[i64],
    k: &[i64],
    v: &[i64],
    n: usize,
    d: usize,
    max_s: i64,
) -> Vec<i64> {
    let mut z = vec![0i64; n];
    for j in 0..n {
        let dist: i64 = (0..d).map(|kk| (q[kk] - k[j * d + kk]).abs()).sum();
        z[j] = scaled_shift_relu(dist, head.gamma, head.alpha_q).min(max_s);
    }
    (0..d)
        .map(|kk| (0..n).map(|j| (v[j * d + kk] - z[j]).max(0).min(max_s)).sum())
        .collect()
}

/// Row mirror of `InhibitorSignedFhe::emit_step_presplit` — the
/// single-row case of `InhibitorSignedFhe::mirror_presplit`.
#[allow(clippy::too_many_arguments)]
fn step_mirror_signed_presplit(
    head: &InhibitorSignedFhe,
    q: &[i64],
    k: &[i64],
    vp: &[i64],
    vn: &[i64],
    n: usize,
    d: usize,
    min_s: i64,
    max_s: i64,
) -> Vec<i64> {
    let clamp = |x: i64| x.clamp(min_s, max_s);
    let mut z = vec![0i64; n];
    for j in 0..n {
        let dist: i64 = (0..d).map(|kk| clamp((q[kk] - k[j * d + kk]).abs())).sum();
        z[j] = clamp(scaled_shift_relu(dist, head.gamma, head.alpha_q));
    }
    (0..d)
        .map(|kk| {
            let h: i64 = (0..n)
                .map(|j| {
                    clamp((vp[j * d + kk] - z[j]).max(0)) + clamp((vn[j * d + kk] + z[j]).min(0))
                })
                .sum();
            clamp(h)
        })
        .collect()
}

/// Row mirror of `DotProductFhe::emit_step` — the single-row case of
/// `DotProductFhe::mirror`.
#[allow(clippy::too_many_arguments)]
fn step_mirror_dotprod(
    head: &DotProductFhe,
    q: &[i64],
    k: &[i64],
    v: &[i64],
    n: usize,
    d: usize,
    min_s: i64,
    max_s: i64,
) -> Vec<i64> {
    let max_out = (1i64 << head.prob_bits) - 1;
    let clamp = |x: i64| x.clamp(min_s, max_s);
    let mut e = vec![0i64; n];
    for j in 0..n {
        let s: i64 = (0..d).map(|kk| q[kk] * k[j * d + kk]).sum();
        e[j] = clamp(exp_lut_at(head.exp_scale, clamp(s), max_out));
    }
    let srow: i64 = e.iter().sum();
    let r = clamp(if srow > 0 { (max_out + srow / 2) / srow } else { max_out });
    (0..d)
        .map(|kk| {
            let acc: i64 = (0..n).map(|j| clamp(clamp(e[j] * r) * v[j * d + kk])).sum();
            clamp((acc as f64 / max_out as f64).round() as i64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn demo(mech: Mechanism, heads: usize, layers: usize, shared: bool) -> DecodeFhe {
        let dm = 2 * heads;
        DecodeFhe::new(ModelFhe::demo(mech, dm, heads, layers, shared, dm, 0xDEC0))
    }

    #[test]
    fn step_plan_shapes_levels_and_io() {
        // Analysis only — no crypto. The step plan keeps the full
        // stack's level depth (the new row threads every layer) with
        // O(n·d) width.
        for &(mech, per_layer) in &[
            (Mechanism::Inhibitor, 9usize),
            (Mechanism::InhibitorSigned, 9),
            (Mechanism::DotProduct, 11),
        ] {
            for &(heads, layers, t) in &[(1usize, 1usize, 0usize), (2, 2, 1), (2, 1, 3)] {
                let dec = demo(mech, heads, layers, false);
                let p = dec.step_plan(t);
                let tag = format!("{mech:?} H={heads} L={layers} t={t}");
                assert_eq!(p.n_inputs(), dec.n_step_inputs(t), "{tag}: inputs");
                assert_eq!(p.n_outputs(), dec.n_step_outputs(), "{tag}: outputs");
                assert_eq!(p.levels(), layers * per_layer, "{tag}: levels");
            }
        }
    }

    #[test]
    fn prefill_plan_shapes_and_levels() {
        for &(mech, per_layer) in &[
            (Mechanism::Inhibitor, 9usize),
            (Mechanism::InhibitorSigned, 9),
            (Mechanism::DotProduct, 11),
        ] {
            for &(heads, layers, t) in &[(1usize, 1usize, 1usize), (2, 2, 2), (1, 2, 3)] {
                let dec = demo(mech, heads, layers, false);
                let dm = dec.d_model();
                let p = dec.prefill_plan(t);
                let tag = format!("{mech:?} H={heads} L={layers} T={t}");
                assert_eq!(p.n_inputs(), t * dm, "{tag}: inputs");
                assert_eq!(p.n_outputs(), t * dm + dec.cache_len(t), "{tag}: outputs");
                // Causal: layer ℓ's keys are layer ℓ−1 outputs, never a
                // *later* token's — so depth stays L·per_layer, exactly
                // the step plans'.
                assert_eq!(p.levels(), layers * per_layer, "{tag}: levels");
            }
        }
    }

    #[test]
    fn mirror_prefill_equals_streamed_steps() {
        // The structural identity at the mirror level: prefilling T
        // tokens and streaming T steps are the same recurrence.
        let mut rng = Xoshiro256::new(0xDEC1);
        for mech in [Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for shared in [false, true] {
                let dec = demo(mech, 2, 2, shared);
                let dm = dec.d_model();
                let x = ITensor::random(&[3, dm], -1, 1, &mut rng);
                let mut one_shot = DecodeMirror::new(&dec.model, -16, 15);
                let grid = one_shot.prefill(&x);
                let mut streamed = DecodeMirror::new(&dec.model, -16, 15);
                for i in 0..3 {
                    let row = streamed.step(&x.data[i * dm..(i + 1) * dm]);
                    assert_eq!(
                        row,
                        grid.data[i * dm..(i + 1) * dm].to_vec(),
                        "{mech:?} shared={shared} token {i}"
                    );
                }
                assert_eq!(streamed.cached_len(), 3);
            }
        }
    }

    #[test]
    fn single_token_decode_matches_the_full_model_mirror() {
        // T = 1 is the one prefix where causal and full attention
        // coincide, so the decode mirror must agree with the model
        // mirror exactly — the RNN-mode anchor.
        let mut rng = Xoshiro256::new(0xDEC2);
        for mech in [Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            let dec = demo(mech, 2, 2, false);
            let dm = dec.d_model();
            let x = ITensor::random(&[1, dm], -1, 1, &mut rng);
            let mut mirror = DecodeMirror::new(&dec.model, -16, 15);
            let got = mirror.prefill(&x);
            let want = dec.model.mirror(&x, -16, 15);
            assert_eq!(got, want, "{mech:?}");
        }
    }

    #[test]
    fn cache_layout_lengths_are_consistent() {
        let dec = demo(Mechanism::InhibitorSigned, 2, 2, true);
        // shared_kv signed: per position per layer D + 2·d_head.
        assert_eq!(dec.cache_layer_len(3), 3 * (4 + 2 * 2));
        assert_eq!(dec.cache_len(3), 2 * dec.cache_layer_len(3));
        assert_eq!(dec.cached_len_of(dec.cache_len(3)), Some(3));
        assert_eq!(dec.cached_len_of(dec.cache_len(3) + 1), None);
        assert_eq!(dec.n_step_inputs(3), 4 + dec.cache_len(3));
        assert_eq!(dec.n_step_outputs(), 4 + 2 * (4 + 2 * 2));
        let plain = demo(Mechanism::Inhibitor, 2, 1, false);
        assert_eq!(plain.cache_len(2), 2 * 4);
        assert_eq!(plain.n_step_outputs(), 4 + 4);
    }

    #[test]
    fn engine_mechanism_strings_are_distinct_per_configuration() {
        assert_eq!(
            decode_engine_mechanism(Mechanism::Inhibitor, 2, 3, false),
            "decode/inhibitor@h2xL3"
        );
        assert_eq!(
            decode_engine_mechanism(Mechanism::InhibitorSigned, 4, 1, true),
            "decode/inhibitor-signed@h4xL1s"
        );
        let dec = demo(Mechanism::DotProduct, 2, 2, true);
        assert_eq!(dec.engine_mechanism(), "decode/dotprod@h2xL2s");
        // Decode and block engines of the same shape never collide.
        assert_ne!(dec.engine_mechanism(), dec.model.engine_mechanism());
    }
}
