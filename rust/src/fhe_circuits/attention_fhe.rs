//! The attention mechanisms as TFHE circuits (S6).
//!
//! Faithful to how the paper's Concrete circuits must be built:
//!
//! * **Inhibitor** (eqs. 5–6): per score, `d` subtractions (free) + `d`
//!   abs PBS, a fused scale-shift-ReLU PBS (the 1/γ literal is not an
//!   integer, so it folds into the LUT), then per output `T` subtract-ReLU
//!   PBS and free additions. PBS per head: `2·T²·d + T² + T·d`.
//! * **Dot-product** (eq. 3): every q·k product is a ct×ct mult = 2 PBS
//!   (paper eq. 1); Softmax = exp LUT per score + row sum + reciprocal
//!   LUT + ct×ct by the reciprocal; attending V is another ct×ct per
//!   term. PBS per head: `4·T²·d + 3·T² + T + T·d` (incl. rescale PBS).
//!
//! Each circuit has a plaintext *mirror* computing the identical integer
//! function; tests assert ciphertext == mirror on every coordinate, which
//! pins both the circuit logic and the noise budget.
//!
//! Since PR 2 both circuits are **declarative plan builders**: `plan()`
//! emits a [`CircuitPlan`] DAG of free linear ops and PBS nodes, and
//! `forward()` executes it — the leveling pass batches each level's
//! independent PBS into one `pbs_many`-style submission exactly like the
//! hand-staged loops did (score abs → fused scale-shift-ReLU → inhibition
//! ReLU → refresh; square/exp/recip/probs/attend/rescale for the
//! baseline). The PR 1 hand-staged forwards survive as
//! `forward_staged()`, the reference the bit-identity tests and the
//! plan-vs-staged bench compare against. The same plan object is the
//! optimizer's and the bench tables' PBS-count oracle
//! ([`CircuitPlan::pbs_count`]).
//!
//! Since PR 3 `forward()` executes the plan **after** the
//! [`PlanRewriter`] pipeline (CSE + multi-value bootstrap packing at the
//! context's parameter budget) and caches the rewritten plan per
//! `(T, d, budget)` on the head, so repeated forwards neither rebuild
//! nor re-rewrite the DAG. `plan()` still returns the raw builder
//! output — the verbatim-dataflow oracle the rewrite tests compare
//! against. The third circuit, [`InhibitorSignedFhe`] (paper eq. 7),
//! transcribes the signed inhibition verbatim: the V⁺/V⁻ splits are
//! emitted per score row, which is exactly the redundancy CSE collapses
//! (T-fold duplicate `Pbs` nodes) and the packing pass then fuses
//! (`relu(v)` and `min(v, 0)` of the *same* input share one blind
//! rotation), so its PBS and blind-rotation counts drop strictly under
//! rewriting.
//!
//! Since PR 4 each circuit's plan body is an `emit` function over a
//! shared [`CircuitBuilder`] — `plan()` wraps it for the single-head
//! case, and [`super::MultiHeadFhe`] emits H heads into **one** combined
//! plan so the rewrite passes work across head boundaries. `forward()`
//! executes plans **by reference** (`execute_ref`): the 3·T·d input
//! ciphertexts are borrowed, never copied into the run.
//!
//! [`PlanRewriter`]: crate::tfhe::plan::PlanRewriter

use crate::tfhe::bootstrap::ClientKey;
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitBuilder, CircuitPlan, NodeId, PlanRewriter, RewriteConfig};
use crate::tfhe::radix::RadixConfig;
use crate::util::prng::Xoshiro256;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A matrix of encrypted integers, row-major.
pub struct CtMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<CtInt>,
}

impl CtMatrix {
    pub fn encrypt(
        vals: &crate::tensor::ITensor,
        ctx: &FheContext,
        ck: &ClientKey,
        rng: &mut Xoshiro256,
    ) -> Self {
        assert_eq!(vals.rank(), 2);
        let (rows, cols) = (vals.dims()[0], vals.dims()[1]);
        let data = vals.data.iter().map(|&v| ctx.encrypt(v, ck, rng)).collect();
        CtMatrix { rows, cols, data }
    }

    pub fn decrypt(&self, ctx: &FheContext, ck: &ClientKey) -> crate::tensor::ITensor {
        crate::tensor::ITensor::from_vec(
            &[self.rows, self.cols],
            self.data.iter().map(|c| ctx.decrypt(c, ck)).collect(),
        )
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &CtInt {
        &self.data[i * self.cols + j]
    }
}

/// Q, K, V as one *borrowed* plan-input vector (the layout `plan()`
/// declares: q row-major, then k, then v). References only — paired
/// with [`CircuitPlan::execute_ref`], `forward()` never copies the
/// 3·T·d input ciphertexts into the run.
fn qkv_input_refs<'m>(q: &'m CtMatrix, k: &'m CtMatrix, v: &'m CtMatrix) -> Vec<&'m CtInt> {
    let mut inputs = Vec::with_capacity(q.data.len() + k.data.len() + v.data.len());
    inputs.extend(q.data.iter());
    inputs.extend(k.data.iter());
    inputs.extend(v.data.iter());
    inputs
}

/// Scale-shift LUT shared by circuit and mirror: `relu(round(x/γ) − α)`.
/// `pub(super)` so the incremental-decode mirror (`super::decode`)
/// evaluates the identical table.
pub(super) fn scaled_shift_relu(x: i64, gamma: f64, alpha_q: i64) -> i64 {
    ((x as f64 / gamma).round() as i64 - alpha_q).max(0)
}

/// exp LUT shared by the dot-product circuit and its mirror, normalized
/// to (0, max_out]: exp of the max score maps to max_out. `pub(super)`
/// for the same reason as [`scaled_shift_relu`].
pub(super) fn exp_lut_at(exp_scale: f64, x: i64, max_out: i64) -> i64 {
    let e = (x as f64 * exp_scale).exp();
    (e * max_out as f64).round().clamp(1.0, max_out as f64) as i64
}

/// Per-head cache of rewritten circuit plans, keyed by
/// `(T, d, multi-LUT budget)` so one head can serve contexts with
/// different packing headroom. Shared across clones (`Arc`) and safe
/// from concurrent engine workers (`Mutex`); `builds` counts cache
/// misses so tests can pin "one build across repeated forwards".
/// `pub(super)` so the multi-head wrapper caches through the same
/// machinery.
#[derive(Default)]
pub(super) struct PlanCache {
    plans: Mutex<HashMap<(usize, usize, usize), Arc<CircuitPlan>>>,
    builds: AtomicUsize,
}

impl PlanCache {
    /// Fetch the rewritten plan for `(t, d)` under `ctx`'s parameter
    /// budget, building (and rewriting) it on first use. Honors the
    /// `FHE_NO_REWRITE` knob ([`crate::tfhe::plan::rewrites_disabled`]):
    /// when set, CSE and packing are suppressed and the plan is cached
    /// under a sentinel budget so toggling the knob between calls can
    /// never leak a rewritten plan into a no-rewrite run or vice versa.
    /// Radix legalization still runs under the knob — declared widths
    /// are a correctness obligation, not an optimization, so a plan
    /// that declares accumulators wider than the native message space
    /// must be legalized on every path that executes it.
    pub(super) fn rewritten_for(
        &self,
        ctx: &FheContext,
        t: usize,
        d: usize,
        build: impl FnOnce() -> CircuitPlan,
    ) -> Arc<CircuitPlan> {
        let no_rewrite = crate::tfhe::plan::rewrites_disabled();
        let budget = if no_rewrite { usize::MAX } else { ctx.max_multi_lut() };
        let key = (t, d, budget);
        if let Some(hit) = self.plans.lock().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        // Build outside the lock (plan construction is pure); a racing
        // worker may build too — `or_insert` keeps the first insert and
        // drops the loser's copy, which is fine: both plans are
        // identical.
        self.builds.fetch_add(1, Ordering::Relaxed);
        let plan = if no_rewrite {
            PlanRewriter::new(RewriteConfig::none())
                .with_radix(RadixConfig::for_params(&ctx.sk.params))
                .rewrite(build())
                .0
        } else {
            PlanRewriter::for_ctx(ctx).rewrite(build()).0
        };
        let plan = Arc::new(plan);
        let mut cache = self.plans.lock().unwrap();
        Arc::clone(cache.entry(key).or_insert(plan))
    }

    pub(super) fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache").field("builds", &self.builds()).finish()
    }
}

/// Per-head value source for emitting a head's subgraph into a shared
/// builder (`MultiHeadFhe::emit`): either plain value nodes (every head
/// circuit takes these — the signed head emits its own V⁺/V⁻ split PBS
/// from them), or pre-split `(v⁺, v⁻)` node pairs the caller already
/// emitted. The pre-split form is how the block circuit
/// (`super::block_fhe::BlockFhe`) folds the previous layer's residual
/// requant into the splits: the pair then reads the *accumulator* node,
/// landing on the same input as the plain requant so the packing pass
/// can fuse all three tables into one blind rotation at ϑ ≥ 2.
pub(super) enum HeadValues<'a> {
    Plain(&'a [NodeId]),
    /// `(v⁺, v⁻)` per value element, row-major `[T, d]`. Only the signed
    /// inhibitor consumes splits; passing this to any other mechanism
    /// panics.
    PreSplit(&'a [(NodeId, NodeId)]),
}

/// Square-LUT inputs for a batch of eq.-1 products `a·b`: `a+b` for every
/// pair (first half), then `a−b` (second half). After the square batch,
/// product `idx` is `sq[idx] − sq[pairs.len() + idx]`.
fn mul_halves(ctx: &FheContext, pairs: &[(&CtInt, &CtInt)]) -> Vec<CtInt> {
    let mut out = Vec::with_capacity(2 * pairs.len());
    for &(a, b) in pairs {
        out.push(ctx.add(a, b));
    }
    for &(a, b) in pairs {
        out.push(ctx.sub(a, b));
    }
    out
}

/// Encrypted Inhibitor attention head.
#[derive(Clone, Debug)]
pub struct InhibitorFhe {
    /// γ literal (paper: √d).
    pub gamma: f64,
    /// Shift α quantized to the score scale.
    pub alpha_q: i64,
    /// Declared output-accumulator width in bits; `None` keeps the
    /// native-width tail (refresh PBS). See
    /// [`InhibitorFhe::with_accumulator_bits`].
    pub(super) acc_bits: Option<u32>,
    cache: Arc<PlanCache>,
}

impl InhibitorFhe {
    pub fn new(dim: usize, alpha_q: i64) -> Self {
        InhibitorFhe {
            gamma: (dim as f64).sqrt(),
            alpha_q,
            acc_bits: None,
            cache: Arc::new(PlanCache::default()),
        }
    }

    /// Declare the head's output accumulators `bits` wide. The emitted
    /// tail then skips the output refresh and marks the raw inhibition
    /// sum with [`CircuitBuilder::declare_width`], so the radix
    /// legalization pass splits it into message-space limbs and
    /// `forward()` returns limb vectors (`cols = d · limbs`,
    /// little-endian per element — decode with
    /// [`crate::tfhe::radix::RadixSpec::decode`] via the plan's
    /// [`CircuitPlan::radix`] info). The mirror correspondingly keeps
    /// the unclamped accumulator. Resets the plan cache: cached plans
    /// embed the old tail.
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        self.acc_bits = Some(bits);
        self.cache = Arc::new(PlanCache::default());
        self
    }

    /// The rewritten, `(T, d)`-cached plan `forward()` executes under
    /// `ctx`. Repeated calls rebuild nothing (see
    /// [`InhibitorFhe::plan_builds`]).
    pub fn plan_for(&self, ctx: &FheContext, t: usize, d: usize) -> Arc<CircuitPlan> {
        self.cache.rewritten_for(ctx, t, d, || self.plan(t, d))
    }

    /// How many times this head (and its clones) actually built a plan —
    /// the per-head cache regression counter.
    pub fn plan_builds(&self) -> usize {
        self.cache.builds()
    }

    /// Emit this head's subgraph into a shared builder: `q`/`k`/`v` are
    /// the head's `T·d` input-segment node ids; the returned `T·d`
    /// output nodes (refreshed, row-major) are *not* marked as plan
    /// outputs — the caller owns the combined plan's output order. Both
    /// [`InhibitorFhe::plan`] and the multi-head builder
    /// ([`super::MultiHeadFhe`]) feed through here, so the per-head
    /// dataflow is defined exactly once.
    pub(super) fn emit(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        t: usize,
        d: usize,
    ) -> Vec<NodeId> {
        let gamma = self.gamma;
        let alpha_q = self.alpha_q;
        // Level 1 — |q_ik − k_jk| for every (i, j, k): subtractions free.
        let mut abs = Vec::with_capacity(t * t * d);
        for i in 0..t {
            for j in 0..t {
                for kk in 0..d {
                    let diff = b.sub(q[i * d + kk], k[j * d + kk]);
                    abs.push(b.abs(diff));
                }
            }
        }
        // Level 2 — scores Z'_ij = relu(round(Σ_k |·| / γ) − α): free adds
        // per score, then the fused scale-shift-ReLU LUT (one table per
        // head — the γ literal folds into it).
        let ssr = b.lut(move |x| scaled_shift_relu(x, gamma, alpha_q));
        let mut z = Vec::with_capacity(t * t);
        for ij in 0..t * t {
            let dist = b.sum(&abs[ij * d..(ij + 1) * d]);
            z.push(b.pbs(dist, ssr));
        }
        // Level 3 — inhibition H_ik = Σ_j (v_jk − z_ij)⁺, then level 4 —
        // output refresh (identity PBS) before the ciphertext leaves the
        // head.
        let mut outs = Vec::with_capacity(t * d);
        for i in 0..t {
            for kk in 0..d {
                let mut terms = Vec::with_capacity(t);
                for j in 0..t {
                    let diff = b.sub(v[j * d + kk], z[i * t + j]);
                    terms.push(b.relu(diff));
                }
                let h = b.sum(&terms);
                match self.acc_bits {
                    Some(w) => {
                        b.declare_width(h, w);
                        outs.push(h);
                    }
                    None => outs.push(b.refresh(h)),
                }
            }
        }
        outs
    }

    /// Incremental-decode form of [`Self::emit`]: one query row `q`
    /// (`d` nodes) attending `n` key/value positions (`n·d` nodes each,
    /// position-major — the cached prefix plus the new token's own
    /// row). Emits exactly the dataflow [`Self::emit`] produces for a
    /// single query row, so a causal prefill built by looping this
    /// recurrence is bit-identical to streaming the same tokens one
    /// step at a time. The scale-shift table is registered fresh per
    /// call — as in `emit` — so steps never CSE-merge across tokens and
    /// the per-step closed form `2·n·d + n + d` is rewrite-stable.
    pub(super) fn emit_step(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        n: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(q.len(), d, "one query row");
        assert_eq!(k.len(), n * d, "n cached+new key rows");
        assert_eq!(v.len(), n * d, "n cached+new value rows");
        let gamma = self.gamma;
        let alpha_q = self.alpha_q;
        let mut abs = Vec::with_capacity(n * d);
        for j in 0..n {
            for kk in 0..d {
                let diff = b.sub(q[kk], k[j * d + kk]);
                abs.push(b.abs(diff));
            }
        }
        let ssr = b.lut(move |x| scaled_shift_relu(x, gamma, alpha_q));
        let mut z = Vec::with_capacity(n);
        for j in 0..n {
            let dist = b.sum(&abs[j * d..(j + 1) * d]);
            z.push(b.pbs(dist, ssr));
        }
        let mut outs = Vec::with_capacity(d);
        for kk in 0..d {
            let mut terms = Vec::with_capacity(n);
            for j in 0..n {
                let diff = b.sub(v[j * d + kk], z[j]);
                terms.push(b.relu(diff));
            }
            let h = b.sum(&terms);
            match self.acc_bits {
                Some(w) => {
                    b.declare_width(h, w);
                    outs.push(h);
                }
                None => outs.push(b.refresh(h)),
            }
        }
        outs
    }

    /// Build the head's circuit plan for a `[T, d]` head. Inputs are
    /// `q ‖ k ‖ v` row-major; outputs are `H` row-major. Four PBS levels:
    /// score abs (T²·d) → fused scale-shift-ReLU (T²) → inhibition ReLU
    /// (T²·d) → output refresh (T·d); `2·T²·d + T² + T·d` PBS total.
    pub fn plan(&self, t: usize, d: usize) -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let q = b.inputs(t * d);
        let k = b.inputs(t * d);
        let v = b.inputs(t * d);
        for out in self.emit(&mut b, &q, &k, &v, t, d) {
            b.output(out);
        }
        b.build()
    }

    /// Encrypted forward: Q, K, V are `[T, d]` ciphertext matrices.
    /// Executes the cached rewritten plan *by reference* — one batched
    /// PBS submission per level through the context's worker pool, and
    /// no copy of the 3·T·d input ciphertexts. (The rewrite pipeline
    /// finds nothing to change in this circuit — its verbatim dataflow
    /// is already duplicate-free with all-distinct PBS inputs — so
    /// counts and ciphertexts are those of the raw plan.) Under a
    /// declared accumulator width the output matrix is `[T, d·limbs]`:
    /// each element's limbs are contiguous, little-endian.
    pub fn forward(&self, ctx: &FheContext, q: &CtMatrix, k: &CtMatrix, v: &CtMatrix) -> CtMatrix {
        let (t, d) = (q.rows, q.cols);
        assert_eq!((k.rows, k.cols), (t, d));
        assert_eq!((v.rows, v.cols), (t, d));
        let data = self.plan_for(ctx, t, d).execute_ref(ctx, &qkv_input_refs(q, k, v));
        let cols = data.len() / t;
        CtMatrix { rows: t, cols, data }
    }

    /// The PR 1 hand-staged forward (level-synchronous loops over
    /// `pbs_many`), kept as the reference implementation: tests pin the
    /// plan path bit-identical to it, and `plan_bench` compares latency.
    pub fn forward_staged(
        &self,
        ctx: &FheContext,
        q: &CtMatrix,
        k: &CtMatrix,
        v: &CtMatrix,
    ) -> CtMatrix {
        let (t, d) = (q.rows, q.cols);
        assert_eq!((k.rows, k.cols), (t, d));
        assert_eq!((v.rows, v.cols), (t, d));
        let gamma = self.gamma;
        let alpha_q = self.alpha_q;
        // Stage 1 — |q_ik − k_jk| for every (i, j, k): the subtractions
        // are free; the T²·d abs PBS are independent → one batch.
        let mut deltas = Vec::with_capacity(t * t * d);
        for i in 0..t {
            for j in 0..t {
                for kk in 0..d {
                    deltas.push(ctx.sub(q.at(i, kk), k.at(j, kk)));
                }
            }
        }
        let abs = ctx.abs_many(&deltas);
        drop(deltas);
        // Stage 2 — scores Z'_ij = relu(round(Σ_k |·| / γ) − α): free adds
        // per score, then one fused scale-shift-ReLU PBS batch. The LUT is
        // prepared once per head (not per score).
        let dists: Vec<CtInt> =
            (0..t * t).map(|ij| ctx.sum(&abs[ij * d..(ij + 1) * d])).collect();
        drop(abs);
        let ssr = ctx.prepared_fn(|x| scaled_shift_relu(x, gamma, alpha_q));
        let z = ctx.pbs_many(&dists, &ssr);
        // Stage 3 — inhibition H_ik = Σ_j (v_jk − z_ij)⁺: T²·d ReLU batch,
        // then free adds per output.
        let mut inh = Vec::with_capacity(t * d * t);
        for i in 0..t {
            for kk in 0..d {
                for j in 0..t {
                    inh.push(ctx.sub(v.at(j, kk), &z[i * t + j]));
                }
            }
        }
        let relus = ctx.relu_many(&inh);
        drop(inh);
        let sums: Vec<CtInt> =
            (0..t * d).map(|ik| ctx.sum(&relus[ik * t..(ik + 1) * t])).collect();
        drop(relus);
        // Stage 4 — output refresh (identity PBS batch): resets noise
        // before the ciphertext leaves the head (mirrors the
        // requantization PBS in the profile).
        let out = ctx.refresh_many(&sums);
        CtMatrix { rows: t, cols: d, data: out }
    }

    /// Plaintext mirror of the exact integer function `forward` computes.
    pub fn mirror(&self, q: &crate::tensor::ITensor, k: &crate::tensor::ITensor, v: &crate::tensor::ITensor, clamp: i64) -> crate::tensor::ITensor {
        let (t, d) = (q.dims()[0], q.dims()[1]);
        let mut z = vec![0i64; t * t];
        for i in 0..t {
            for j in 0..t {
                let dist: i64 = (0..d).map(|kk| (q.at2(i, kk) - k.at2(j, kk)).abs()).sum();
                z[i * t + j] = scaled_shift_relu(dist, self.gamma, self.alpha_q).min(clamp);
            }
        }
        let mut out = crate::tensor::ITensor::zeros(&[t, d]);
        for i in 0..t {
            for kk in 0..d {
                out.data[i * d + kk] =
                    (0..t).map(|j| (v.at2(j, kk) - z[i * t + j]).max(0).min(clamp)).sum();
            }
        }
        out
    }
}

/// Encrypted **signed** Inhibitor attention head (paper eq. 7): values
/// split into positive and negative parts, inhibited symmetrically:
/// `H_ik = Σ_j [(V⁺_jk − Z_ij)⁺ + (V⁻_jk + Z_ij)⁻]`.
///
/// The plan builder transcribes the equation verbatim: the V⁺/V⁻ split
/// PBS are re-emitted inside the per-query-row loop (duplicated across
/// the `T` rows, exactly as eq. 7 reads), and the two splits are two
/// *different* LUTs — `relu` and `min(·,0)` — of the *same* value
/// ciphertext. That makes this the circuit where both rewrite passes
/// bite: CSE collapses the T-fold duplicate splits
/// (`5T²d + T² + Td` → `3T²d + T² + 3Td` LUT evaluations) and
/// multi-value packing fuses each surviving V⁺/V⁻ pair into one blind
/// rotation (`3T²d + T² + 2Td` rotations at a packing budget ≥ 2) —
/// closed forms pinned by `tests/rewrite_it.rs`.
#[derive(Clone, Debug)]
pub struct InhibitorSignedFhe {
    /// γ literal (paper: √d).
    pub gamma: f64,
    /// Shift α quantized to the score scale.
    pub alpha_q: i64,
    /// Declared output-accumulator width in bits; `None` keeps the
    /// native-width tail. See [`InhibitorFhe::with_accumulator_bits`].
    pub(super) acc_bits: Option<u32>,
    cache: Arc<PlanCache>,
}

impl InhibitorSignedFhe {
    pub fn new(dim: usize, alpha_q: i64) -> Self {
        InhibitorSignedFhe {
            gamma: (dim as f64).sqrt(),
            alpha_q,
            acc_bits: None,
            cache: Arc::new(PlanCache::default()),
        }
    }

    /// Declare the head's output accumulators `bits` wide; see
    /// [`InhibitorFhe::with_accumulator_bits`] for the full contract
    /// (limb layout, mirror behavior, cache reset).
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        self.acc_bits = Some(bits);
        self.cache = Arc::new(PlanCache::default());
        self
    }

    /// Shared score path of [`Self::emit`] and [`Self::emit_presplit`]:
    /// |q − k| abs PBS, per-score free sums, and the fused
    /// scale-shift-ReLU table. Returns the `T²` score nodes.
    fn emit_scores(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        t: usize,
        d: usize,
    ) -> Vec<NodeId> {
        let gamma = self.gamma;
        let alpha_q = self.alpha_q;
        // Level 1 — |q_ik − k_jk| for every (i, j, k), as the unsigned head.
        let mut abs = Vec::with_capacity(t * t * d);
        for i in 0..t {
            for j in 0..t {
                for kk in 0..d {
                    let diff = b.sub(q[i * d + kk], k[j * d + kk]);
                    abs.push(b.abs(diff));
                }
            }
        }
        // Level 2 — scores Z'_ij = relu(round(Σ_k |·| / γ) − α).
        let ssr = b.lut(move |x| scaled_shift_relu(x, gamma, alpha_q));
        let mut z = Vec::with_capacity(t * t);
        for ij in 0..t * t {
            let dist = b.sum(&abs[ij * d..(ij + 1) * d]);
            z.push(b.pbs(dist, ssr));
        }
        z
    }

    /// Emit this head's subgraph, **verbatim** (no manual deduplication
    /// — that is the rewriter's job), into a shared builder; see
    /// [`InhibitorFhe::emit`] for the contract. The value-split tables
    /// are the builder's *standard* relu/min0 LUTs, so in a fused
    /// multi-head plan every head references the same registered tables
    /// — which is exactly what lets CSE collapse split PBS across head
    /// boundaries when heads share a V segment (multi-query layouts).
    pub(super) fn emit(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        t: usize,
        d: usize,
    ) -> Vec<NodeId> {
        let z = self.emit_scores(b, q, k, t, d);
        // Level 3 — eq. 7's signed inhibition, with the V⁺/V⁻ splits
        // written where the equation uses them (per query row — the
        // duplicates CSE removes and the same-input pairs packing fuses).
        // Positive and negative terms interleave per j so every partial
        // sum stays within the magnitude of the final result.
        let mut outs = Vec::with_capacity(t * d);
        for i in 0..t {
            for kk in 0..d {
                let mut terms = Vec::with_capacity(2 * t);
                for j in 0..t {
                    let vp = b.relu(v[j * d + kk]);
                    let vn = b.min0(v[j * d + kk]);
                    let pos_in = b.sub(vp, z[i * t + j]);
                    terms.push(b.relu(pos_in));
                    let neg_in = b.add(vn, z[i * t + j]);
                    terms.push(b.min0(neg_in));
                }
                let h = b.sum(&terms);
                match self.acc_bits {
                    Some(w) => {
                        b.declare_width(h, w);
                        outs.push(h);
                    }
                    None => outs.push(b.refresh(h)),
                }
            }
        }
        outs
    }

    /// [`Self::emit`] over **pre-split** values: the caller already
    /// emitted one `(v⁺, v⁻)` node pair per value element (row-major
    /// `[T, d]`) and the inhibition consumes those pairs directly — no
    /// split PBS are emitted here. This is the block circuit's seam: it
    /// lets the splits read the previous layer's residual *accumulator*
    /// (with the requant folded into the split tables) instead of the
    /// requanted activation, and under a shared-KV layout lets one pair
    /// per value serve every head by construction.
    pub(super) fn emit_presplit(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        vsplits: &[(NodeId, NodeId)],
        t: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(vsplits.len(), t * d, "one (v⁺, v⁻) pair per value element");
        let z = self.emit_scores(b, q, k, t, d);
        let mut outs = Vec::with_capacity(t * d);
        for i in 0..t {
            for kk in 0..d {
                let mut terms = Vec::with_capacity(2 * t);
                for j in 0..t {
                    let (vp, vn) = vsplits[j * d + kk];
                    let pos_in = b.sub(vp, z[i * t + j]);
                    terms.push(b.relu(pos_in));
                    let neg_in = b.add(vn, z[i * t + j]);
                    terms.push(b.min0(neg_in));
                }
                let h = b.sum(&terms);
                match self.acc_bits {
                    Some(w) => {
                        b.declare_width(h, w);
                        outs.push(h);
                    }
                    None => outs.push(b.refresh(h)),
                }
            }
        }
        outs
    }

    /// Incremental-decode score path: one query row against `n`
    /// cached+new key rows. Fresh scale-shift table per call, exactly
    /// like [`Self::emit_scores`] — one table per (token, head).
    fn emit_step_scores(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        n: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(q.len(), d, "one query row");
        assert_eq!(k.len(), n * d, "n cached+new key rows");
        let gamma = self.gamma;
        let alpha_q = self.alpha_q;
        let mut abs = Vec::with_capacity(n * d);
        for j in 0..n {
            for kk in 0..d {
                let diff = b.sub(q[kk], k[j * d + kk]);
                abs.push(b.abs(diff));
            }
        }
        let ssr = b.lut(move |x| scaled_shift_relu(x, gamma, alpha_q));
        let mut z = Vec::with_capacity(n);
        for j in 0..n {
            let dist = b.sum(&abs[j * d..(j + 1) * d]);
            z.push(b.pbs(dist, ssr));
        }
        z
    }

    /// Incremental-decode form of [`Self::emit_presplit`]: one query
    /// row, `n` pre-split `(v⁺, v⁻)` pairs (position-major). The block
    /// circuit's decode seam — cached splits arrive as plan inputs, the
    /// new token's pair is emitted by the caller from its residual
    /// accumulator. Positive and negative terms interleave per j
    /// exactly as in the full emitter, so partial-sum magnitudes match.
    /// Per-step closed form: `3·n·d + n + d` LUT evaluations.
    pub(super) fn emit_step_presplit(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        vsplits: &[(NodeId, NodeId)],
        n: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(vsplits.len(), n * d, "one (v⁺, v⁻) pair per value element");
        let z = self.emit_step_scores(b, q, k, n, d);
        let mut outs = Vec::with_capacity(d);
        for kk in 0..d {
            let mut terms = Vec::with_capacity(2 * n);
            for j in 0..n {
                let (vp, vn) = vsplits[j * d + kk];
                let pos_in = b.sub(vp, z[j]);
                terms.push(b.relu(pos_in));
                let neg_in = b.add(vn, z[j]);
                terms.push(b.min0(neg_in));
            }
            let h = b.sum(&terms);
            match self.acc_bits {
                Some(w) => {
                    b.declare_width(h, w);
                    outs.push(h);
                }
                None => outs.push(b.refresh(h)),
            }
        }
        outs
    }

    /// Incremental-decode form of [`Self::emit`] over plain values:
    /// splits each of the `n` value elements once (std relu/min0
    /// tables) and feeds [`Self::emit_step_presplit`]. Standalone
    /// multi-head decode uses this arm; the block circuit passes
    /// pre-split pairs instead.
    pub(super) fn emit_step(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        n: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(v.len(), n * d, "n cached+new value rows");
        let splits: Vec<(NodeId, NodeId)> =
            v.iter().map(|&x| (b.relu(x), b.min0(x))).collect();
        self.emit_step_presplit(b, q, k, &splits, n, d)
    }

    /// Build the head's circuit plan. Inputs `q ‖ k ‖ v` row-major;
    /// outputs `H` row-major. Four PBS levels: score abs + value splits
    /// (3·T²·d) → fused scale-shift-ReLU (T²) → signed inhibition
    /// (2·T²·d) → output refresh (T·d).
    pub fn plan(&self, t: usize, d: usize) -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let q = b.inputs(t * d);
        let k = b.inputs(t * d);
        let v = b.inputs(t * d);
        for out in self.emit(&mut b, &q, &k, &v, t, d) {
            b.output(out);
        }
        b.build()
    }

    /// The rewritten, `(T, d)`-cached plan `forward()` executes under
    /// `ctx`.
    pub fn plan_for(&self, ctx: &FheContext, t: usize, d: usize) -> Arc<CircuitPlan> {
        self.cache.rewritten_for(ctx, t, d, || self.plan(t, d))
    }

    /// Per-head cache regression counter (see [`InhibitorFhe::plan_builds`]).
    pub fn plan_builds(&self) -> usize {
        self.cache.builds()
    }

    /// Encrypted forward: executes the cached rewritten plan by
    /// reference (no input copies). On packing-capable parameter sets
    /// this is where the multi-value saving lands in serving: fewer
    /// blind rotations, identical decrypted outputs. Under a declared
    /// accumulator width the output matrix is `[T, d·limbs]`.
    pub fn forward(&self, ctx: &FheContext, q: &CtMatrix, k: &CtMatrix, v: &CtMatrix) -> CtMatrix {
        let (t, d) = (q.rows, q.cols);
        assert_eq!((k.rows, k.cols), (t, d));
        assert_eq!((v.rows, v.cols), (t, d));
        let data = self.plan_for(ctx, t, d).execute_ref(ctx, &qkv_input_refs(q, k, v));
        let cols = data.len() / t;
        CtMatrix { rows: t, cols, data }
    }

    /// Shared score path of the signed mirrors: clamped |q − k| sums
    /// through the fused scale-shift-ReLU table, exactly as
    /// [`Self::emit_scores`] computes them.
    fn mirror_scores(
        &self,
        q: &crate::tensor::ITensor,
        k: &crate::tensor::ITensor,
        min_s: i64,
        max_s: i64,
    ) -> Vec<i64> {
        let (t, d) = (q.dims()[0], q.dims()[1]);
        let clamp = |x: i64| x.clamp(min_s, max_s);
        let mut z = vec![0i64; t * t];
        for i in 0..t {
            for j in 0..t {
                let dist: i64 =
                    (0..d).map(|kk| clamp((q.at2(i, kk) - k.at2(j, kk)).abs())).sum();
                z[i * t + j] = clamp(scaled_shift_relu(dist, self.gamma, self.alpha_q));
            }
        }
        z
    }

    /// Plaintext mirror of the exact integer function the circuit
    /// computes, including every LUT clamp, for exact equality testing.
    pub fn mirror(
        &self,
        q: &crate::tensor::ITensor,
        k: &crate::tensor::ITensor,
        v: &crate::tensor::ITensor,
        min_s: i64,
        max_s: i64,
    ) -> crate::tensor::ITensor {
        let (t, d) = (q.dims()[0], q.dims()[1]);
        let clamp = |x: i64| x.clamp(min_s, max_s);
        // The verbatim circuit splits through the std relu/min0 tables:
        // v⁺ = clamp(v⁺), v⁻ = clamp(v⁻) of the (in-range) value codes.
        let mut vp = crate::tensor::ITensor::zeros(&[t, d]);
        let mut vn = crate::tensor::ITensor::zeros(&[t, d]);
        for e in 0..t * d {
            vp.data[e] = clamp(v.data[e].max(0));
            vn.data[e] = clamp(v.data[e].min(0));
        }
        self.mirror_presplit(q, k, &vp, &vn, min_s, max_s)
    }

    /// Plaintext mirror of [`Self::emit_presplit`]: identical score
    /// path, inhibition from caller-provided (already clamped) value
    /// splits — the block circuit's reference path, where the splits may
    /// carry a folded requant of the previous layer's accumulator.
    pub(super) fn mirror_presplit(
        &self,
        q: &crate::tensor::ITensor,
        k: &crate::tensor::ITensor,
        vp: &crate::tensor::ITensor,
        vn: &crate::tensor::ITensor,
        min_s: i64,
        max_s: i64,
    ) -> crate::tensor::ITensor {
        let (t, d) = (q.dims()[0], q.dims()[1]);
        assert_eq!((vp.dims()[0], vp.dims()[1]), (t, d), "v⁺ must be [T, d]");
        assert_eq!((vn.dims()[0], vn.dims()[1]), (t, d), "v⁻ must be [T, d]");
        let clamp = |x: i64| x.clamp(min_s, max_s);
        let z = self.mirror_scores(q, k, min_s, max_s);
        let mut out = crate::tensor::ITensor::zeros(&[t, d]);
        for i in 0..t {
            for kk in 0..d {
                let h: i64 = (0..t)
                    .map(|j| {
                        let zij = z[i * t + j];
                        clamp((vp.at2(j, kk) - zij).max(0)) + clamp((vn.at2(j, kk) + zij).min(0))
                    })
                    .sum();
                // A declared-wide tail has no output refresh: the radix
                // limbs carry the exact accumulator, so the mirror keeps
                // it unclamped too.
                out.data[i * d + kk] = if self.acc_bits.is_some() { h } else { clamp(h) };
            }
        }
        out
    }
}

/// Encrypted dot-product + Softmax attention head (the baseline).
#[derive(Clone, Debug)]
pub struct DotProductFhe {
    /// Fixed-point bits of the probability representation.
    pub prob_bits: u32,
    /// exp LUT scale: e(x) = round(exp(x·exp_scale)·(2^prob_bits − 1)).
    pub exp_scale: f64,
    /// Declared output-accumulator width in bits; `None` keeps the
    /// native-width tail (rescale PBS). See
    /// [`DotProductFhe::with_accumulator_bits`].
    pub(super) acc_bits: Option<u32>,
    cache: Arc<PlanCache>,
}

impl DotProductFhe {
    pub fn new(dim: usize, input_mag: i64) -> Self {
        // Scores reach d·input_mag²; pick exp_scale so the LUT spans ~e^-3
        // over that range (behaves like 1/√d temperature at these widths).
        let max_score = (dim as i64) * input_mag * input_mag;
        DotProductFhe {
            prob_bits: 3,
            exp_scale: 3.0 / max_score as f64,
            acc_bits: None,
            cache: Arc::new(PlanCache::default()),
        }
    }

    /// Declare the head's output accumulators `bits` wide. The tail
    /// then keeps the raw fixed-point attend accumulator `Σ_j p_ij·v_jk`
    /// (probabilities still scaled by `2^prob_bits − 1` — the rescale
    /// PBS is not emitted) as radix limbs; the mirror matches by
    /// skipping the rescale and final clamp. See
    /// [`InhibitorFhe::with_accumulator_bits`] for the limb layout and
    /// cache-reset contract.
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        self.acc_bits = Some(bits);
        self.cache = Arc::new(PlanCache::default());
        self
    }

    /// The rewritten, `(T, d)`-cached plan `forward()` executes under
    /// `ctx`.
    pub fn plan_for(&self, ctx: &FheContext, t: usize, d: usize) -> Arc<CircuitPlan> {
        self.cache.rewritten_for(ctx, t, d, || self.plan(t, d))
    }

    /// Per-head cache regression counter (see [`InhibitorFhe::plan_builds`]).
    pub fn plan_builds(&self) -> usize {
        self.cache.builds()
    }

    fn exp_lut(&self, x: i64, max_out: i64) -> i64 {
        exp_lut_at(self.exp_scale, x, max_out)
    }

    /// Emit the baseline's subgraph into a shared builder; see
    /// [`InhibitorFhe::emit`] for the contract.
    pub(super) fn emit(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        t: usize,
        d: usize,
    ) -> Vec<NodeId> {
        let exp_scale = self.exp_scale;
        let max_out = (1i64 << self.prob_bits) - 1; // LUT output magnitude
        // Level 1 — scores S_ij = Σ_k q_ik·k_jk, each product via eq. 1.
        let mut scores = Vec::with_capacity(t * t);
        for i in 0..t {
            for j in 0..t {
                let prods: Vec<_> =
                    (0..d).map(|kk| b.ct_mul(q[i * d + kk], k[j * d + kk])).collect();
                scores.push(b.sum(&prods));
            }
        }
        // Level 2 — exp LUT (one table per head).
        let exp = b.lut(move |x| exp_lut_at(exp_scale, x, max_out));
        let e: Vec<_> = scores.iter().map(|&s| b.pbs(s, exp)).collect();
        // Level 3 — row normalizers r_i = round(max_out / Σ_j e_ij): free
        // row sums, then the shared reciprocal table (see
        // `tfhe::ops::recip_fn` — the softmax normalizer's single
        // definition).
        let recip = b.lut(crate::tfhe::ops::recip_fn(max_out));
        let r: Vec<_> = (0..t)
            .map(|i| {
                let row = b.sum(&e[i * t..(i + 1) * t]);
                b.pbs(row, recip)
            })
            .collect();
        // Level 4 — probabilities p_ij = e_ij · r_i (fixed point with
        // max_out ≈ 1.0).
        let mut probs = Vec::with_capacity(t * t);
        for i in 0..t {
            for j in 0..t {
                probs.push(b.ct_mul(e[i * t + j], r[i]));
            }
        }
        // Level 5 — attend V: H_ik = Σ_j p_ij · v_jk, then level 6 —
        // rescale by 1/max_out.
        let rescale = b.lut(move |x| (x as f64 / max_out as f64).round() as i64);
        let mut outs = Vec::with_capacity(t * d);
        for i in 0..t {
            for kk in 0..d {
                let terms: Vec<_> =
                    (0..t).map(|j| b.ct_mul(probs[i * t + j], v[j * d + kk])).collect();
                let acc = b.sum(&terms);
                match self.acc_bits {
                    Some(w) => {
                        b.declare_width(acc, w);
                        outs.push(acc);
                    }
                    None => outs.push(b.pbs(acc, rescale)),
                }
            }
        }
        outs
    }

    /// Incremental-decode form of [`Self::emit`]: one query row against
    /// `n` cached+new key/value rows (the causal softmax row — only
    /// positions ≤ the new token exist, so no transposed product pair
    /// ever forms and the per-step count `4·n·d + 3·n + 1 + d` is
    /// rewrite-stable). exp/recip/rescale tables are registered fresh
    /// per call, as in `emit`.
    pub(super) fn emit_step(
        &self,
        b: &mut CircuitBuilder,
        q: &[NodeId],
        k: &[NodeId],
        v: &[NodeId],
        n: usize,
        d: usize,
    ) -> Vec<NodeId> {
        assert_eq!(q.len(), d, "one query row");
        assert_eq!(k.len(), n * d, "n cached+new key rows");
        assert_eq!(v.len(), n * d, "n cached+new value rows");
        let exp_scale = self.exp_scale;
        let max_out = (1i64 << self.prob_bits) - 1;
        let mut scores = Vec::with_capacity(n);
        for j in 0..n {
            let prods: Vec<_> = (0..d).map(|kk| b.ct_mul(q[kk], k[j * d + kk])).collect();
            scores.push(b.sum(&prods));
        }
        let exp = b.lut(move |x| exp_lut_at(exp_scale, x, max_out));
        let e: Vec<_> = scores.iter().map(|&s| b.pbs(s, exp)).collect();
        let recip = b.lut(crate::tfhe::ops::recip_fn(max_out));
        let row = b.sum(&e);
        let r = b.pbs(row, recip);
        let probs: Vec<_> = e.iter().map(|&ej| b.ct_mul(ej, r)).collect();
        let rescale = b.lut(move |x| (x as f64 / max_out as f64).round() as i64);
        let mut outs = Vec::with_capacity(d);
        for kk in 0..d {
            let terms: Vec<_> =
                (0..n).map(|j| b.ct_mul(probs[j], v[j * d + kk])).collect();
            let acc = b.sum(&terms);
            match self.acc_bits {
                Some(w) => {
                    b.declare_width(acc, w);
                    outs.push(acc);
                }
                None => outs.push(b.pbs(acc, rescale)),
            }
        }
        outs
    }

    /// Build the baseline's circuit plan for a `[T, d]` head. Inputs are
    /// `q ‖ k ‖ v` row-major. Six PBS levels: score squares (2·T²·d, the
    /// two halves of every eq.-1 product) → exp (T²) → reciprocal (T) →
    /// probability squares (2·T²) → attend squares (2·T²·d) → rescale
    /// (T·d); `4·T²·d + 3·T² + T + T·d` PBS total.
    pub fn plan(&self, t: usize, d: usize) -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let q = b.inputs(t * d);
        let k = b.inputs(t * d);
        let v = b.inputs(t * d);
        for out in self.emit(&mut b, &q, &k, &v, t, d) {
            b.output(out);
        }
        b.build()
    }

    /// Encrypted forward: executes the cached rewritten plan by
    /// reference — one batched PBS submission per level, no input
    /// copies. (As with the unsigned inhibitor, the rewrite pipeline is
    /// a no-op on this circuit's all-distinct dataflow.) Under a
    /// declared accumulator width the output matrix is `[T, d·limbs]`.
    pub fn forward(&self, ctx: &FheContext, q: &CtMatrix, k: &CtMatrix, v: &CtMatrix) -> CtMatrix {
        let (t, d) = (q.rows, q.cols);
        assert_eq!((k.rows, k.cols), (t, d));
        assert_eq!((v.rows, v.cols), (t, d));
        let data = self.plan_for(ctx, t, d).execute_ref(ctx, &qkv_input_refs(q, k, v));
        let cols = data.len() / t;
        CtMatrix { rows: t, cols, data }
    }

    /// The PR 1 hand-staged forward, kept as the reference implementation
    /// (see [`InhibitorFhe::forward_staged`]).
    pub fn forward_staged(
        &self,
        ctx: &FheContext,
        q: &CtMatrix,
        k: &CtMatrix,
        v: &CtMatrix,
    ) -> CtMatrix {
        let (t, d) = (q.rows, q.cols);
        let max_out = (1i64 << self.prob_bits) - 1; // LUT output magnitude
        // Stage 1 — scores S_ij = Σ_k q_ik·k_jk. Each product is
        // PBS(x²/4; a+b) − PBS(x²/4; a−b); all 2·T²·d square jobs are
        // independent → one batch (sums first, then differences). Stage
        // inputs are built as statement temporaries so each stage's
        // scratch is freed before the next one peaks.
        let n_prod = t * t * d;
        let mut pairs = Vec::with_capacity(n_prod);
        for i in 0..t {
            for j in 0..t {
                for kk in 0..d {
                    pairs.push((q.at(i, kk), k.at(j, kk)));
                }
            }
        }
        let sq = ctx.square_quarter_many(&mul_halves(ctx, &pairs));
        drop(pairs);
        let scores: Vec<CtInt> = (0..t * t)
            .map(|ij| {
                let prods: Vec<CtInt> = (0..d)
                    .map(|kk| ctx.sub(&sq[ij * d + kk], &sq[n_prod + ij * d + kk]))
                    .collect();
                ctx.sum(&prods)
            })
            .collect();
        drop(sq);
        // Stage 2 — exp LUT batch (T² PBS, one table per head).
        let exp = ctx.prepared_fn(|x| self.exp_lut(x, max_out));
        let e = ctx.pbs_many(&scores, &exp);
        // Stage 3 — row normalizers r_i = round(max_out / Σ_j e_ij): free
        // row sums, then the shared reciprocal table (see
        // `FheContext::prepared_recip` — the softmax normalizer's single
        // definition), one PBS per row.
        let row_sums: Vec<CtInt> = (0..t).map(|i| ctx.sum(&e[i * t..(i + 1) * t])).collect();
        let recip = ctx.prepared_recip(max_out);
        let r = ctx.pbs_many(&row_sums, &recip);
        // Stage 4 — probabilities p_ij = e_ij · r_i: 2·T² square jobs
        // (fixed point with max_out ≈ 1.0).
        let mut pairs = Vec::with_capacity(t * t);
        for i in 0..t {
            for j in 0..t {
                pairs.push((&e[i * t + j], &r[i]));
            }
        }
        let p_sq = ctx.square_quarter_many(&mul_halves(ctx, &pairs));
        drop(pairs);
        let probs: Vec<CtInt> =
            (0..t * t).map(|ij| ctx.sub(&p_sq[ij], &p_sq[t * t + ij])).collect();
        drop(p_sq);
        // Stage 5 — attend V: H_ik = Σ_j p_ij · v_jk, 2·T²·d square jobs.
        let n_att = t * d * t;
        let mut pairs = Vec::with_capacity(n_att);
        for i in 0..t {
            for kk in 0..d {
                for j in 0..t {
                    pairs.push((&probs[i * t + j], v.at(j, kk)));
                }
            }
        }
        let a_sq = ctx.square_quarter_many(&mul_halves(ctx, &pairs));
        drop(pairs);
        let accs: Vec<CtInt> = (0..t * d)
            .map(|ik| {
                let terms: Vec<CtInt> = (0..t)
                    .map(|j| ctx.sub(&a_sq[ik * t + j], &a_sq[n_att + ik * t + j]))
                    .collect();
                ctx.sum(&terms)
            })
            .collect();
        drop(a_sq);
        // Stage 6 — rescale by 1/max_out (T·d PBS batch).
        let rescale = ctx.prepared_fn(|x| (x as f64 / max_out as f64).round() as i64);
        let out = ctx.pbs_many(&accs, &rescale);
        CtMatrix { rows: t, cols: d, data: out }
    }

    /// Plaintext mirror of the integer circuit (including every clamp the
    /// LUTs apply), for exact equality testing.
    pub fn mirror(
        &self,
        q: &crate::tensor::ITensor,
        k: &crate::tensor::ITensor,
        v: &crate::tensor::ITensor,
        min_s: i64,
        max_s: i64,
    ) -> crate::tensor::ITensor {
        let (t, d) = (q.dims()[0], q.dims()[1]);
        let max_out = (1i64 << self.prob_bits) - 1;
        let clamp = |x: i64| x.clamp(min_s, max_s);
        let mut e = vec![0i64; t * t];
        for i in 0..t {
            for j in 0..t {
                let s: i64 = (0..d).map(|kk| q.at2(i, kk) * k.at2(j, kk)).sum();
                e[i * t + j] = clamp(self.exp_lut(clamp(s), max_out));
            }
        }
        let mut out = crate::tensor::ITensor::zeros(&[t, d]);
        for i in 0..t {
            let srow: i64 = (0..t).map(|j| e[i * t + j]).sum();
            let r = clamp(if srow > 0 { (max_out + srow / 2) / srow } else { max_out });
            for kk in 0..d {
                let acc: i64 = (0..t)
                    .map(|j| clamp(clamp(e[i * t + j] * r) * v.at2(j, kk)))
                    .sum();
                // A declared-wide tail keeps the raw fixed-point
                // accumulator (no rescale PBS is emitted).
                out.data[i * d + kk] = if self.acc_bits.is_some() {
                    acc
                } else {
                    clamp((acc as f64 / max_out as f64).round() as i64)
                };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ITensor;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::tfhe::FheContext;

    fn fhe_setup(bits: u32) -> (ClientKey, FheContext, Xoshiro256) {
        let mut rng = Xoshiro256::new(0xFEED);
        let ck = ClientKey::generate(TfheParams::test_for_bits(bits), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx, rng)
    }

    #[test]
    fn encrypted_inhibitor_matches_plaintext_mirror() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = fhe_setup(5);
        let t = 2;
        let d = 2;
        // Small inputs: |q|,|k| ≤ 2, v ∈ [0, 3].
        let q = ITensor::from_vec(&[t, d], vec![1, -2, 0, 2]);
        let k = ITensor::from_vec(&[t, d], vec![1, -1, -2, 0]);
        let v = ITensor::from_vec(&[t, d], vec![3, 1, 2, 0]);
        let head = InhibitorFhe::new(d, 1);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let before = pbs_count();
        let h = head.forward(&ctx, &cq, &ckk, &cv);
        let used = pbs_count() - before;
        let expect_pbs = (2 * t * t * d + t * t + t * d) as u64;
        assert_eq!(used, expect_pbs, "inhibitor PBS count");
        assert_eq!(head.plan(t, d).pbs_count(), expect_pbs, "plan count oracle");
        let got = h.decrypt(&ctx, &ck);
        let want = head.mirror(&q, &k, &v, ctx.enc.max_signed());
        assert_eq!(got, want);
    }

    #[test]
    fn encrypted_dotprod_matches_plaintext_mirror() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = fhe_setup(6);
        let t = 2;
        let d = 2;
        // Tiny inputs so every ct_mul intermediate fits 6 bits signed.
        let q = ITensor::from_vec(&[t, d], vec![1, -1, 2, 0]);
        let k = ITensor::from_vec(&[t, d], vec![1, 1, -1, 2]);
        let v = ITensor::from_vec(&[t, d], vec![2, 1, -1, 3]);
        let head = DotProductFhe::new(d, 2);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let before = pbs_count();
        let h = head.forward(&ctx, &cq, &ckk, &cv);
        let used = pbs_count() - before;
        // 2·T²·d (scores) + T² (exp) + T (recip) + 2·T² (probs)
        // + 2·T²·d (attend) + T·d (rescale)
        let expect = (4 * t * t * d + t * t + t + 2 * t * t + t * d) as u64;
        assert_eq!(used, expect, "dotprod PBS count");
        assert_eq!(head.plan(t, d).pbs_count(), expect, "plan count oracle");
        let got = h.decrypt(&ctx, &ck);
        let want = head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
        assert_eq!(got, want);
    }

    #[test]
    fn plan_forward_is_bit_identical_to_staged_forward() {
        // The PR 2 acceptance bar: the declarative plan path must produce
        // exactly the ciphertexts of the PR 1 hand-staged path, for both
        // mechanisms, at every thread count.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = fhe_setup(6);
        let t = 2;
        let d = 2;
        let q = ITensor::from_vec(&[t, d], vec![1, -1, 2, 0]);
        let k = ITensor::from_vec(&[t, d], vec![1, 1, -1, 2]);
        let v = ITensor::from_vec(&[t, d], vec![2, 1, -1, 3]);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let inh = InhibitorFhe::new(d, 1);
        let dot = DotProductFhe::new(d, 2);
        for threads in [1usize, 3] {
            ctx.set_threads(threads);
            let staged = inh.forward_staged(&ctx, &cq, &ckk, &cv);
            let planned = inh.forward(&ctx, &cq, &ckk, &cv);
            for (i, (s, p)) in staged.data.iter().zip(planned.data.iter()).enumerate() {
                assert_eq!(s.ct, p.ct, "inhibitor threads={threads} i={i}");
            }
            let staged = dot.forward_staged(&ctx, &cq, &ckk, &cv);
            let planned = dot.forward(&ctx, &cq, &ckk, &cv);
            for (i, (s, p)) in staged.data.iter().zip(planned.data.iter()).enumerate() {
                assert_eq!(s.ct, p.ct, "dotprod threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn plan_counts_reproduce_paper_closed_forms_across_t_d() {
        // Pure DAG analysis — no crypto — so the sweep can be wide. The
        // level structure is part of the contract: it is what the fused
        // executor synchronizes on.
        for &t in &[2usize, 3, 4, 8, 16] {
            for &d in &[1usize, 2, 4] {
                let inh = InhibitorFhe::new(d, 1).plan(t, d);
                assert_eq!(
                    inh.pbs_count(),
                    (2 * t * t * d + t * t + t * d) as u64,
                    "inhibitor T={t} d={d}"
                );
                assert_eq!(inh.levels(), 4, "inhibitor levels T={t} d={d}");
                assert_eq!(
                    inh.level_sizes(),
                    vec![t * t * d, t * t, t * t * d, t * d],
                    "inhibitor level sizes T={t} d={d}"
                );
                assert_eq!(inh.n_inputs(), 3 * t * d);
                assert_eq!(inh.n_outputs(), t * d);
                let dot = DotProductFhe::new(d, 2).plan(t, d);
                assert_eq!(
                    dot.pbs_count(),
                    (4 * t * t * d + 3 * t * t + t + t * d) as u64,
                    "dotprod T={t} d={d}"
                );
                assert_eq!(dot.levels(), 6, "dotprod levels T={t} d={d}");
                assert_eq!(
                    dot.level_sizes(),
                    vec![2 * t * t * d, t * t, t, 2 * t * t, 2 * t * t * d, t * d],
                    "dotprod level sizes T={t} d={d}"
                );
            }
        }
    }

    #[test]
    fn signed_inhibitor_counts_follow_the_rewrite_closed_forms() {
        // Analysis only (no crypto): the verbatim eq.-7 transcription,
        // its CSE'd form, and the packed form at budget 2.
        use crate::tfhe::plan::{PlanRewriter, RewriteConfig};
        for &(t, d) in &[(2usize, 2usize), (3, 2), (2, 3), (4, 4)] {
            let head = InhibitorSignedFhe::new(d, 1);
            let p = head.plan(t, d);
            let verbatim = (5 * t * t * d + t * t + t * d) as u64;
            assert_eq!(p.pbs_count(), verbatim, "verbatim T={t} d={d}");
            assert_eq!(p.blind_rotation_count(), verbatim, "unpacked plans: 1 rot/PBS");
            assert_eq!(p.levels(), 4);
            assert_eq!(p.level_sizes(), vec![3 * t * t * d, t * t, 2 * t * t * d, t * d]);
            let (cse, stats) =
                PlanRewriter::new(RewriteConfig::cse_only()).rewrite(head.plan(t, d));
            let deduped = (3 * t * t * d + t * t + 3 * t * d) as u64;
            assert_eq!(stats.cse_merged, 2 * t * d * (t - 1), "T-fold splits merge");
            assert_eq!(cse.pbs_count(), deduped, "CSE'd T={t} d={d}");
            assert_eq!(cse.blind_rotation_count(), deduped);
            let (packed, pstats) = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 })
                .rewrite(head.plan(t, d));
            assert_eq!(pstats.multi_groups, t * d, "one V⁺/V⁻ pair per value");
            assert_eq!(pstats.packed_luts, 2 * t * d);
            assert_eq!(packed.pbs_count(), deduped, "packing keeps LUT evaluations");
            assert_eq!(
                packed.blind_rotation_count(),
                (3 * t * t * d + t * t + 2 * t * d) as u64,
                "packed T={t} d={d}"
            );
            assert_eq!(packed.levels(), 4, "packing never crosses levels");
            assert_eq!(
                packed.level_sizes(),
                vec![t * t * d + t * d, t * t, 2 * t * t * d, t * d]
            );
        }
    }

    #[test]
    fn encrypted_signed_inhibitor_matches_mirror_with_packed_execution() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xFEED5);
        let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        assert_eq!(ctx.max_multi_lut(), 2);
        let t = 2;
        let d = 2;
        // |q|,|k| ≤ 2 and v ∈ [−3, 3] keep every intermediate of the
        // signed circuit inside the 4-bit signed range [−8, 7].
        let q = ITensor::from_vec(&[t, d], vec![1, -2, 0, 1]);
        let k = ITensor::from_vec(&[t, d], vec![1, -1, -2, 0]);
        let v = ITensor::from_vec(&[t, d], vec![3, -1, -2, 2]);
        let head = InhibitorSignedFhe::new(d, 1);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let before_pbs = pbs_count();
        let before_rot = crate::tfhe::bootstrap::blind_rotation_count();
        let h = head.forward(&ctx, &cq, &ckk, &cv);
        // forward() runs the rewritten plan: CSE'd LUT evaluations,
        // packed rotations.
        assert_eq!(
            pbs_count() - before_pbs,
            (3 * t * t * d + t * t + 3 * t * d) as u64,
            "signed PBS count (rewritten)"
        );
        assert_eq!(
            crate::tfhe::bootstrap::blind_rotation_count() - before_rot,
            (3 * t * t * d + t * t + 2 * t * d) as u64,
            "signed blind rotations (packed)"
        );
        let got = h.decrypt(&ctx, &ck);
        let want = head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
        assert_eq!(got, want);
    }

    #[test]
    fn per_head_plan_cache_builds_once_across_forwards() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx, mut rng) = fhe_setup(5);
        let t = 2;
        let d = 2;
        let q = ITensor::from_vec(&[t, d], vec![1, -2, 0, 2]);
        let k = ITensor::from_vec(&[t, d], vec![1, -1, -2, 0]);
        let v = ITensor::from_vec(&[t, d], vec![3, 1, 2, 0]);
        let head = InhibitorFhe::new(d, 1);
        assert_eq!(head.plan_builds(), 0);
        let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
        let first = head.forward(&ctx, &cq, &ckk, &cv);
        let second = head.forward(&ctx, &cq, &ckk, &cv);
        assert_eq!(head.plan_builds(), 1, "repeated forwards must reuse the cached plan");
        // Clones share the cache (the serving engine clones heads freely).
        let clone = head.clone();
        let third = clone.forward(&ctx, &cq, &ckk, &cv);
        assert_eq!(clone.plan_builds(), 1, "clones share the cache");
        for (a, b) in first.data.iter().zip(second.data.iter()) {
            assert_eq!(a.ct, b.ct, "cached plan must not change results");
        }
        for (a, b) in first.data.iter().zip(third.data.iter()) {
            assert_eq!(a.ct, b.ct);
        }
        // A different shape is a separate cache entry.
        let _ = head.plan_for(&ctx, t + 1, d);
        assert_eq!(head.plan_builds(), 2);
    }

    #[test]
    fn dotprod_uses_about_twice_the_pbs_of_inhibitor() {
        // PBS accounting only (no crypto execution): the paper's "about
        // twice as many PBS" claim, per head, at d=2 — read off the plans.
        for t in [2usize, 4, 8, 16] {
            let inh = InhibitorFhe::new(2, 1).plan(t, 2).pbs_count() as f64;
            let dot = DotProductFhe::new(2, 2).plan(t, 2).pbs_count() as f64;
            let ratio = dot / inh;
            assert!((1.5..=2.6).contains(&ratio), "T={t}: {ratio}");
        }
    }
}
