//! Encrypted transformer **block** subsystem (S6c): the full quantized
//! block — multi-head attention, W_O projection, residual adds, requant
//! PBS, and the two-layer ReLU FFN — emitted into ONE [`CircuitPlan`],
//! and [`ModelFhe`] stacking L such blocks into a single DAG (block ℓ+1
//! reading block ℓ's outputs), so the PR 3 rewrite passes finally work
//! *across layer boundaries*.
//!
//! ## Dataflow
//!
//! The block operates on the residual stream `x : [T, D]` (D = H·d):
//! attention runs per head directly on x's column slices (q = k = v =
//! slice — the projections ahead of the paper's benchmarked circuits
//! stay client-side; under `shared_kv` every head attends the first
//! slice, the multi-query layout), the head outputs concatenate and go
//! through W_O, and the rest is the standard pre-activation arithmetic
//! of `model::Block` with layer norm elided (LN-under-FHE needs a
//! data-dependent rsqrt and is off the benchmarked path — see
//! `model::layers::QLayerNorm`):
//!
//! ```text
//! h   = W_O · attn(x) + b        → requant PBS        (QLinear::forward)
//! x₁  = requant(x + h)                                 (resid_requant)
//! h₁  = relu(requant(W₁·x₁ + b₁))  — ONE fused table   (QFfn's fc1 + relu)
//! f   = requant(W₂·h₁ + b₂)                            (QFfn's fc2)
//! out = requant(x₁ + f)                                (resid_requant)
//! ```
//!
//! Plaintext-weight matmuls lower to free `scalar_mul`/`sum` linear
//! nodes (no ciphertext×ciphertext cost — "multiplication by literals is
//! native"); every requant is a [`CircuitBuilder::requant`]-family LUT,
//! registered once per distinct fixed-point factor so all layers of a
//! stacked plan share tables.
//!
//! ## Cross-layer rewrite wins (the ϑ ≥ 2 story)
//!
//! For the **signed** mechanism the value splits of layer ℓ+1 do not
//! read layer ℓ's requanted output: they fold the residual requant into
//! the split tables and read layer ℓ's final *accumulator* directly
//! (`requant_relu` / `requant_min0`). That puts **three distinct
//! tables on one input** — the plain output requant (still needed by
//! the score path and the residual) plus the two folded splits — so the
//! multi-value packing pass forms groups of 3 and a ϑ ≥ 2 budget
//! (`TfheParams::test_multi_lut_theta(bits, 2)`) executes each trio in
//! ONE blind rotation: a stacked L-layer plan needs `(L−1)·T·d_kv`
//! fewer rotations than L separately-rewritten block plans (pinned by
//! `tests/block_it.rs`). At ϑ = 1 the trio still packs pairwise and at
//! layer 0 the splits read the plan inputs as a packable pair, exactly
//! like the standalone signed head.
//!
//! Every count is deterministic because the emitted DAG carries no
//! accidental duplicates: closed forms live in
//! [`crate::optimizer::precision::profile_block`] and are checked
//! against the plan oracles (the only data dependence is CSE merging
//! identical weight rows — [`BlockWeights::demo`] generates
//! pairwise-distinct rows so the forms are exact).
//!
//! The plaintext reference is [`ModelFhe::mirror`]: the same integer
//! function (including every LUT clamp), built from the head mirrors
//! and the shared [`HeadSplit`] slicing — and `tests/block_it.rs` pins
//! it (and the encrypted decode) bit-identical to a stack of
//! `model::Block` layer objects (`QLinear`/`QFfn` forwards) built from
//! the same weights.

use super::attention_fhe::{CtMatrix, HeadValues, PlanCache};
use super::multihead::MultiHeadFhe;
use crate::attention::{AttentionHead, AttnConfig, HeadSplit, Mechanism};
use crate::model::layers::{QFfn, QLayerNorm, QLinear};
use crate::model::transformer::Block;
use crate::quant::FixedMult;
use crate::tensor::ITensor;
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitBuilder, CircuitPlan, NodeId};
use crate::util::prng::{Rng64, Xoshiro256};
use std::sync::Arc;

/// The plaintext-weight parameters of one encrypted block, extracted
/// from (or interchangeable with) a `model::Block`'s quantized layers.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    /// W_O codes `[D, D]`.
    pub wo: ITensor,
    /// W_O bias at accumulator scale `[D]`.
    pub wo_b: Vec<i64>,
    pub wo_requant: FixedMult,
    /// Requant applied to both residual additions.
    pub resid_requant: FixedMult,
    /// FFN first layer codes `[F, D]`.
    pub fc1: ITensor,
    pub fc1_b: Vec<i64>,
    pub fc1_requant: FixedMult,
    /// FFN second layer codes `[D, F]`.
    pub fc2: ITensor,
    pub fc2_b: Vec<i64>,
    pub fc2_requant: FixedMult,
}

impl BlockWeights {
    /// Extract the block-circuit weights from a plaintext model block
    /// (the `QLinear`/`QFfn` integer codes, biases and requant factors —
    /// shared verbatim, so circuit and model cannot drift).
    pub fn from_block(blk: &Block) -> BlockWeights {
        BlockWeights {
            wo: blk.wo.w.clone(),
            wo_b: blk.wo.b.clone(),
            wo_requant: blk.wo.requant,
            resid_requant: blk.resid_requant,
            fc1: blk.ffn.fc1.w.clone(),
            fc1_b: blk.ffn.fc1.b.clone(),
            fc1_requant: blk.ffn.fc1.requant,
            fc2: blk.ffn.fc2.w.clone(),
            fc2_b: blk.ffn.fc2.b.clone(),
            fc2_requant: blk.ffn.fc2.requant,
        }
    }

    /// Demo/test weights with provable range bounds on `x ∈ [−1, 1]`
    /// inputs: every matrix row holds exactly `min(2, cols)` nonzero
    /// ±1 entries (rows pairwise distinct, so CSE can never merge two
    /// accumulators and the closed-form counts of `profile_block` are
    /// exact), biases in {−1, 0, 1} on W_O/fc1 and zero on fc2, 0.5
    /// requants on the linears and 0.25 on the residuals. With T ≤ 3,
    /// d_head ≤ 2 and L ≤ 2 every linear intermediate of the inhibitor
    /// blocks stays within the 5-bit signed range [−16, 15] and of
    /// dot-product blocks within the 6-bit range [−32, 31] (the
    /// fixed-point requant floors negatives, so the residual stream
    /// drifts to a few negative codes but stays bounded — worked
    /// through in `tests/block_it.rs`).
    pub fn demo(d_model: usize, ffn_dim: usize, rng: &mut Xoshiro256) -> BlockWeights {
        BlockWeights {
            wo: sparse_signed_rows(d_model, d_model, rng),
            wo_b: (0..d_model).map(|_| rng.next_range_i64(-1, 1)).collect(),
            wo_requant: FixedMult::from_f64(0.5),
            resid_requant: FixedMult::from_f64(0.25),
            fc1: sparse_signed_rows(ffn_dim, d_model, rng),
            fc1_b: (0..ffn_dim).map(|_| rng.next_range_i64(-1, 1)).collect(),
            fc1_requant: FixedMult::from_f64(0.5),
            fc2: sparse_signed_rows(d_model, ffn_dim, rng),
            fc2_b: vec![0; d_model],
            fc2_requant: FixedMult::from_f64(0.5),
        }
    }

    /// FFN hidden width F.
    pub fn ffn_dim(&self) -> usize {
        self.fc1.dims()[0]
    }

    /// Inverse of [`Self::from_block`]: a plaintext `model::Block`
    /// carrying exactly these weights, with identity Q/K/V projections
    /// and defaulted (unused on the LN-free reference path) layer-norm
    /// fields — the single definition of the circuit ↔ `model::Block`
    /// bridge the differential tests pin against, so the two sides
    /// cannot drift.
    pub fn to_model_block(&self, mechanism: Mechanism, n_heads: usize) -> Block {
        let d = self.wo.dims()[0];
        let d_head = HeadSplit::new(d, n_heads).d_head();
        let mut eye = ITensor::zeros(&[d, d]);
        for i in 0..d {
            eye.set(&[i, i], 1);
        }
        let identity = QLinear::new(eye, vec![0; d], FixedMult::from_f64(1.0));
        Block {
            ln1: QLayerNorm::from_float(&vec![1.0; d], &vec![0.0; d], 0.05),
            wq: identity.clone(),
            wk: identity.clone(),
            wv: identity,
            wo: QLinear::new(self.wo.clone(), self.wo_b.clone(), self.wo_requant),
            attn: AttentionHead::build(AttnConfig::new(mechanism, 4, d_head), 0.05),
            n_heads,
            ln2: QLayerNorm::from_float(&vec![1.0; d], &vec![0.0; d], 0.05),
            ffn: QFfn {
                fc1: QLinear::new(self.fc1.clone(), self.fc1_b.clone(), self.fc1_requant),
                fc2: QLinear::new(self.fc2.clone(), self.fc2_b.clone(), self.fc2_requant),
            },
            resid_requant: self.resid_requant,
        }
    }

    /// Shape checks against the block width; panics on mismatch (the
    /// same contract the layer constructors use).
    fn validate(&self, d_model: usize) {
        assert_eq!(self.wo.dims(), &[d_model, d_model], "W_O must be [D, D]");
        assert_eq!(self.wo_b.len(), d_model, "W_O bias must be [D]");
        let f = self.ffn_dim();
        assert!(f >= 1, "FFN width must be at least 1");
        assert_eq!(self.fc1.dims(), &[f, d_model], "fc1 must be [F, D]");
        assert_eq!(self.fc1_b.len(), f, "fc1 bias must be [F]");
        assert_eq!(self.fc2.dims(), &[d_model, f], "fc2 must be [D, F]");
        assert_eq!(self.fc2_b.len(), d_model, "fc2 bias must be [D]");
    }
}

/// `[rows, cols]` codes with `min(2, cols)` nonzero ±1 entries per row,
/// rows pairwise distinct (see [`BlockWeights::demo`]).
fn sparse_signed_rows(rows: usize, cols: usize, rng: &mut Xoshiro256) -> ITensor {
    // Distinct-row capacity: 2 single-column rows at cols = 1, otherwise
    // C(cols, 2) sign-pattern-distinct pairs × 4 sign combinations.
    let capacity = if cols == 1 { 2 } else { 2 * cols * (cols - 1) };
    assert!(
        rows <= capacity,
        "cannot generate {rows} pairwise-distinct demo rows over {cols} columns"
    );
    let mut w = ITensor::zeros(&[rows, cols]);
    let mut seen: std::collections::HashSet<Vec<i64>> = std::collections::HashSet::new();
    for r in 0..rows {
        loop {
            let mut row = vec![0i64; cols];
            let c0 = rng.next_bounded(cols as u64) as usize;
            row[c0] = if rng.next_bounded(2) == 0 { 1 } else { -1 };
            if cols > 1 {
                let step = 1 + rng.next_bounded(cols as u64 - 1) as usize;
                let c1 = (c0 + step) % cols;
                row[c1] = if rng.next_bounded(2) == 0 { 1 } else { -1 };
            }
            if seen.insert(row.clone()) {
                w.data[r * cols..(r + 1) * cols].copy_from_slice(&row);
                break;
            }
        }
    }
    w
}

/// One complete quantized transformer block as a plan-builder (see the
/// module docs for the dataflow). Usually owned by a [`ModelFhe`].
#[derive(Clone, Debug)]
pub struct BlockFhe {
    pub mechanism: Mechanism,
    pub split: HeadSplit,
    /// Multi-query layout: every head attends the first `d_head` columns
    /// of the residual stream as K/V.
    pub shared_kv: bool,
    pub weights: BlockWeights,
    /// The fused H-head attention emitter this block reuses
    /// (`MultiHeadFhe::emit` — per-head defaults identical to the
    /// standalone multi-head engines).
    attn: MultiHeadFhe,
    /// Declared width for the block's *output* residual accumulators.
    /// When set, the final residual requant is not emitted: the block's
    /// outputs are the raw second-residual accumulators, declared
    /// `out_acc_bits` wide so the radix legalization pass splits them
    /// into limbs. Only meaningful on the last block of a stack — a
    /// following block expects a narrow residual stream.
    pub(super) out_acc_bits: Option<u32>,
}

impl BlockFhe {
    pub fn new(
        mechanism: Mechanism,
        d_model: usize,
        n_heads: usize,
        shared_kv: bool,
        weights: BlockWeights,
    ) -> Self {
        let split = HeadSplit::new(d_model, n_heads);
        weights.validate(d_model);
        let attn = MultiHeadFhe::new(mechanism, split.d_head(), n_heads, shared_kv);
        BlockFhe { mechanism, split, shared_kv, weights, attn, out_acc_bits: None }
    }

    /// Declare this block's output accumulators `bits` wide (see the
    /// `out_acc_bits` field docs). Exposed so single-block plans can be
    /// built wide; stacks should use [`ModelFhe::with_accumulator_bits`],
    /// which applies it to the last layer only.
    pub fn with_output_accumulator_bits(mut self, bits: u32) -> Self {
        self.out_acc_bits = Some(bits);
        self
    }

    /// Build a block circuit from a plaintext `model::Block` (mechanism,
    /// head count and every quantized weight taken from the model).
    pub fn from_block(blk: &Block, shared_kv: bool) -> Self {
        let d_model = blk.wo.w.dims()[1];
        Self::new(
            blk.attn.mechanism(),
            d_model,
            blk.n_heads,
            shared_kv,
            BlockWeights::from_block(blk),
        )
    }

    /// Single-block plan (the L = 1 case of [`ModelFhe::plan`]).
    pub fn plan(&self, t: usize) -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let x = b.inputs(t * self.split.d_model);
        let (out, _accs) = self.emit(&mut b, &x, None, t);
        for id in out {
            b.output(id);
        }
        b.build()
    }

    /// Emit this block's subgraph into a shared builder. `x` is the
    /// `[T, D]` residual-stream grid (row-major node ids); `x_acc`, when
    /// present, is the previous layer's final accumulator grid with its
    /// requant factor — the seam the signed value splits fold onto.
    /// Returns the requanted `[T, D]` output grid plus this block's own
    /// final accumulators (the next layer's `x_acc`).
    pub(super) fn emit(
        &self,
        b: &mut CircuitBuilder,
        x: &[NodeId],
        x_acc: Option<(&[NodeId], FixedMult)>,
        t: usize,
    ) -> (Vec<NodeId>, Vec<NodeId>) {
        let dm = self.split.d_model;
        let d = self.split.d_head();
        let heads = self.split.n_heads;
        assert_eq!(x.len(), t * dm, "block input must be [T, d_model] row-major");
        if let Some((acc, _)) = x_acc {
            assert_eq!(acc.len(), t * dm, "accumulator grid must match the input grid");
        }
        let w = &self.weights;
        // --- attention sub-layer on the residual stream (q = k = v) ---
        let slice = |col0: usize| -> Vec<NodeId> {
            let mut s = Vec::with_capacity(t * d);
            for i in 0..t {
                for kk in 0..d {
                    s.push(x[i * dm + col0 + kk]);
                }
            }
            s
        };
        let qs: Vec<Vec<NodeId>> = (0..heads).map(|h| slice(self.split.col0(h))).collect();
        let ks: Vec<Vec<NodeId>> =
            if self.shared_kv { vec![slice(0); heads] } else { qs.clone() };
        let outs = if self.mechanism == Mechanism::InhibitorSigned {
            // One (v⁺, v⁻) split pair per distinct value element, emitted
            // ONCE and shared by every head that attends it. Stacked
            // layers fold the previous residual requant into the split
            // tables and read the accumulator — the ϑ ≥ 2 trio with the
            // plain output requant (module docs).
            let vcols = if self.shared_kv { d } else { dm };
            let mut pairs = Vec::with_capacity(t * vcols);
            for i in 0..t {
                for c in 0..vcols {
                    let idx = i * dm + c;
                    let pair = match x_acc {
                        Some((acc, m)) => {
                            (b.requant_relu(acc[idx], m), b.requant_min0(acc[idx], m))
                        }
                        None => (b.relu(x[idx]), b.min0(x[idx])),
                    };
                    pairs.push(pair);
                }
            }
            let pair_slice = |col0: usize| -> Vec<(NodeId, NodeId)> {
                let mut s = Vec::with_capacity(t * d);
                for i in 0..t {
                    for kk in 0..d {
                        s.push(pairs[i * vcols + col0 + kk]);
                    }
                }
                s
            };
            let per_head: Vec<Vec<(NodeId, NodeId)>> = (0..heads)
                .map(|h| pair_slice(if self.shared_kv { 0 } else { self.split.col0(h) }))
                .collect();
            let values: Vec<HeadValues> =
                per_head.iter().map(|p| HeadValues::PreSplit(p)).collect();
            self.attn.emit(b, &qs, &ks, &values, t, d)
        } else {
            let values: Vec<HeadValues> = ks.iter().map(|k| HeadValues::Plain(k)).collect();
            self.attn.emit(b, &qs, &ks, &values, t, d)
        };
        // Concatenate the head outputs back into a [T, D] grid.
        let mut hgrid = vec![0usize; t * dm];
        for (h, head_out) in outs.iter().enumerate() {
            let c0 = self.split.col0(h);
            for i in 0..t {
                for kk in 0..d {
                    hgrid[i * dm + c0 + kk] = head_out[i * d + kk];
                }
            }
        }
        // --- W_O projection + first residual requant ---
        let wo_out = self.emit_linear(b, &hgrid, t, &w.wo, &w.wo_b, w.wo_requant, false);
        let mut x1 = Vec::with_capacity(t * dm);
        for idx in 0..t * dm {
            let acc = b.add(x[idx], wo_out[idx]);
            x1.push(b.requant(acc, w.resid_requant));
        }
        // --- two-layer ReLU FFN (fc1's requant + ReLU as ONE table) ---
        let h1 = self.emit_linear(b, &x1, t, &w.fc1, &w.fc1_b, w.fc1_requant, true);
        let f = self.emit_linear(b, &h1, t, &w.fc2, &w.fc2_b, w.fc2_requant, false);
        // --- second residual: the requant is the block's output; the
        // raw accumulators are returned so a stacked next layer can fold
        // its value splits onto them ---
        let mut out = Vec::with_capacity(t * dm);
        let mut accs = Vec::with_capacity(t * dm);
        for idx in 0..t * dm {
            let acc = b.add(x1[idx], f[idx]);
            match self.out_acc_bits {
                Some(wbits) => {
                    b.declare_width(acc, wbits);
                    out.push(acc);
                }
                None => out.push(b.requant(acc, w.resid_requant)),
            }
            accs.push(acc);
        }
        (out, accs)
    }

    /// Incremental-decode form of [`Self::emit`]: one new residual-stream
    /// row against `t_cached` cached positions. `x_row` is the new
    /// token's `[D]` input row; `cached_x` is the `[t_cached, D]` grid of
    /// *this layer's* previous input rows (the decode cache); for the
    /// signed mechanism `cached_splits` carries the `t_cached · vcols`
    /// already-computed (v⁺, v⁻) pairs (position-major) so cached value
    /// splits cost zero fresh PBS, and `x_acc_row` is the previous
    /// layer's accumulator for the new row only — the same fold seam as
    /// the full emitter, now per token. Returns the requanted output
    /// row, the raw accumulator row (next layer's `x_acc_row`) and the
    /// new position's split pairs (empty for unsigned mechanisms), which
    /// the caller appends to the cache.
    pub(super) fn emit_step(
        &self,
        b: &mut CircuitBuilder,
        x_row: &[NodeId],
        x_acc_row: Option<(&[NodeId], FixedMult)>,
        cached_x: &[NodeId],
        cached_splits: &[(NodeId, NodeId)],
        t_cached: usize,
    ) -> (Vec<NodeId>, Vec<NodeId>, Vec<(NodeId, NodeId)>) {
        let dm = self.split.d_model;
        let d = self.split.d_head();
        let heads = self.split.n_heads;
        let n = t_cached + 1;
        assert_eq!(x_row.len(), dm, "step input must be one [d_model] row");
        assert_eq!(cached_x.len(), t_cached * dm, "cache must be [t_cached, d_model]");
        if let Some((acc, _)) = x_acc_row {
            assert_eq!(acc.len(), dm, "accumulator row must match the input row");
        }
        let w = &self.weights;
        // --- attention: the new row's query against cached + new K/V ---
        let q_slice = |col0: usize| -> Vec<NodeId> {
            (0..d).map(|kk| x_row[col0 + kk]).collect()
        };
        let k_slice = |col0: usize| -> Vec<NodeId> {
            let mut s = Vec::with_capacity(n * d);
            for j in 0..t_cached {
                for kk in 0..d {
                    s.push(cached_x[j * dm + col0 + kk]);
                }
            }
            for kk in 0..d {
                s.push(x_row[col0 + kk]);
            }
            s
        };
        let qs: Vec<Vec<NodeId>> = (0..heads).map(|h| q_slice(self.split.col0(h))).collect();
        let ks: Vec<Vec<NodeId>> =
            (0..heads).map(|h| k_slice(if self.shared_kv { 0 } else { self.split.col0(h) })).collect();
        let (outs, new_pairs) = if self.mechanism == Mechanism::InhibitorSigned {
            let vcols = if self.shared_kv { d } else { dm };
            assert_eq!(
                cached_splits.len(),
                t_cached * vcols,
                "cached splits must be [t_cached, vcols]"
            );
            // Only the NEW position's splits are emitted; every cached
            // pair arrives as a plan input — the O(T·d) saving.
            let mut new_pairs = Vec::with_capacity(vcols);
            for c in 0..vcols {
                let pair = match x_acc_row {
                    Some((acc, m)) => {
                        (b.requant_relu(acc[c], m), b.requant_min0(acc[c], m))
                    }
                    None => (b.relu(x_row[c]), b.min0(x_row[c])),
                };
                new_pairs.push(pair);
            }
            let pair_slice = |col0: usize| -> Vec<(NodeId, NodeId)> {
                let mut s = Vec::with_capacity(n * d);
                for j in 0..t_cached {
                    for kk in 0..d {
                        s.push(cached_splits[j * vcols + col0 + kk]);
                    }
                }
                for kk in 0..d {
                    s.push(new_pairs[col0 + kk]);
                }
                s
            };
            let per_head: Vec<Vec<(NodeId, NodeId)>> = (0..heads)
                .map(|h| pair_slice(if self.shared_kv { 0 } else { self.split.col0(h) }))
                .collect();
            let values: Vec<HeadValues> =
                per_head.iter().map(|p| HeadValues::PreSplit(p)).collect();
            (self.attn.emit_step(b, &qs, &ks, &values, n, d), new_pairs)
        } else {
            let values: Vec<HeadValues> = ks.iter().map(|k| HeadValues::Plain(k)).collect();
            (self.attn.emit_step(b, &qs, &ks, &values, n, d), Vec::new())
        };
        // Concatenate the head output rows into one [D] row.
        let mut hrow = vec![0usize; dm];
        for (h, head_out) in outs.iter().enumerate() {
            let c0 = self.split.col0(h);
            hrow[c0..c0 + d].copy_from_slice(head_out);
        }
        // --- W_O projection + first residual requant (t = 1 rows) ---
        let wo_out = self.emit_linear(b, &hrow, 1, &w.wo, &w.wo_b, w.wo_requant, false);
        let mut x1 = Vec::with_capacity(dm);
        for c in 0..dm {
            let acc = b.add(x_row[c], wo_out[c]);
            x1.push(b.requant(acc, w.resid_requant));
        }
        // --- FFN + second residual, exactly like the full emitter ---
        let h1 = self.emit_linear(b, &x1, 1, &w.fc1, &w.fc1_b, w.fc1_requant, true);
        let f = self.emit_linear(b, &h1, 1, &w.fc2, &w.fc2_b, w.fc2_requant, false);
        let mut out = Vec::with_capacity(dm);
        let mut accs = Vec::with_capacity(dm);
        for c in 0..dm {
            let acc = b.add(x1[c], f[c]);
            match self.out_acc_bits {
                Some(wbits) => {
                    b.declare_width(acc, wbits);
                    out.push(acc);
                }
                None => out.push(b.requant(acc, w.resid_requant)),
            }
            accs.push(acc);
        }
        (out, accs, new_pairs)
    }

    /// Lower `y = requant(x·Wᵀ + b)` (optionally with the ReLU fused
    /// into the requant table) to free scalar_mul/sum/add_const linear
    /// nodes plus one requant PBS per output element — the plaintext
    /// weights never touch a ciphertext×ciphertext multiply.
    #[allow(clippy::too_many_arguments)]
    fn emit_linear(
        &self,
        b: &mut CircuitBuilder,
        xin: &[NodeId],
        t: usize,
        w: &ITensor,
        bias: &[i64],
        m: FixedMult,
        fuse_relu: bool,
    ) -> Vec<NodeId> {
        let (rows, cols) = (w.dims()[0], w.dims()[1]);
        assert_eq!(xin.len(), t * cols, "linear input grid must be [T, {cols}]");
        assert_eq!(bias.len(), rows, "bias length must match out features");
        let mut out = Vec::with_capacity(t * rows);
        for i in 0..t {
            for r in 0..rows {
                let mut terms: Vec<NodeId> = Vec::with_capacity(cols);
                for c in 0..cols {
                    match w.at2(r, c) {
                        0 => {}
                        1 => terms.push(xin[i * cols + c]),
                        wv => terms.push(b.scalar_mul(xin[i * cols + c], wv)),
                    }
                }
                let mut acc = if terms.is_empty() {
                    b.constant(0)
                } else if terms.len() == 1 {
                    terms[0]
                } else {
                    b.sum(&terms)
                };
                if bias[r] != 0 {
                    acc = b.add_const(acc, bias[r]);
                }
                out.push(if fuse_relu { b.requant_relu(acc, m) } else { b.requant(acc, m) });
            }
        }
        out
    }

    /// Plaintext mirror of one block step: the exact integer function
    /// [`Self::emit`] computes, including every LUT clamp and the
    /// cross-layer requant folding. Returns `(out, final_acc)` exactly
    /// like the emitter.
    pub(super) fn mirror_step(
        &self,
        x: &ITensor,
        x_acc: Option<(&ITensor, FixedMult)>,
        min_s: i64,
        max_s: i64,
    ) -> (ITensor, ITensor) {
        let dm = self.split.d_model;
        let d = self.split.d_head();
        let t = x.dims()[0];
        assert_eq!(x.dims()[1], dm, "block input must be [T, d_model]");
        let clamp = |v: i64| v.clamp(min_s, max_s);
        let w = &self.weights;
        // --- attention ---
        let h_attn = if self.mechanism == Mechanism::InhibitorSigned {
            let vcols = if self.shared_kv { d } else { dm };
            let mut vp = ITensor::zeros(&[t, vcols]);
            let mut vn = ITensor::zeros(&[t, vcols]);
            for i in 0..t {
                for c in 0..vcols {
                    let (p, n) = match x_acc {
                        Some((acc, m)) => {
                            // The folded split tables read the raw
                            // accumulator: relu/min0 of the requant,
                            // clamped once (no intermediate clamp).
                            let raw = m.apply(acc.at2(i, c));
                            (clamp(raw.max(0)), clamp(raw.min(0)))
                        }
                        None => (clamp(x.at2(i, c).max(0)), clamp(x.at2(i, c).min(0))),
                    };
                    vp.data[i * vcols + c] = p;
                    vn.data[i * vcols + c] = n;
                }
            }
            let mut parts = Vec::with_capacity(self.split.n_heads);
            for h in 0..self.split.n_heads {
                let qs = x.slice_cols(self.split.col0(h), d);
                let (ks, vps, vns) = if self.shared_kv {
                    (x.slice_cols(0, d), vp.clone(), vn.clone())
                } else {
                    let c0 = self.split.col0(h);
                    (x.slice_cols(c0, d), vp.slice_cols(c0, d), vn.slice_cols(c0, d))
                };
                parts.push(self.attn.head_mirror_presplit(&qs, &ks, &vps, &vns, min_s, max_s));
            }
            let refs: Vec<&ITensor> = parts.iter().collect();
            ITensor::concat_cols(&refs)
        } else {
            let (k, v) = if self.shared_kv {
                (x.slice_cols(0, d), x.slice_cols(0, d))
            } else {
                (x.clone(), x.clone())
            };
            self.attn.mirror(x, &k, &v, min_s, max_s)
        };
        // --- W_O + first residual ---
        let wo_out = mirror_linear(&h_attn, &w.wo, &w.wo_b, w.wo_requant, false, min_s, max_s);
        let mut x1 = ITensor::zeros(&[t, dm]);
        for e in 0..t * dm {
            x1.data[e] = clamp(w.resid_requant.apply(x.data[e] + wo_out.data[e]));
        }
        // --- FFN ---
        let h1 = mirror_linear(&x1, &w.fc1, &w.fc1_b, w.fc1_requant, true, min_s, max_s);
        let f = mirror_linear(&h1, &w.fc2, &w.fc2_b, w.fc2_requant, false, min_s, max_s);
        // --- second residual ---
        let mut out = ITensor::zeros(&[t, dm]);
        let mut accs = ITensor::zeros(&[t, dm]);
        for e in 0..t * dm {
            let acc = x1.data[e] + f.data[e];
            accs.data[e] = acc;
            // A wide-declared output tail keeps the raw accumulator (no
            // requant PBS is emitted).
            out.data[e] = if self.out_acc_bits.is_some() {
                acc
            } else {
                clamp(w.resid_requant.apply(acc))
            };
        }
        (out, accs)
    }
}

/// Plaintext mirror of [`BlockFhe::emit_linear`]: i64-exact matmul +
/// bias, then the (optionally ReLU-fused) requant table with its clamp.
/// `pub(super)` because the incremental-decode mirror (`super::decode`)
/// reuses it row by row.
pub(super) fn mirror_linear(
    x: &ITensor,
    w: &ITensor,
    bias: &[i64],
    m: FixedMult,
    fuse_relu: bool,
    min_s: i64,
    max_s: i64,
) -> ITensor {
    let (t, cols) = (x.dims()[0], x.dims()[1]);
    let rows = w.dims()[0];
    assert_eq!(w.dims()[1], cols, "weight width must match input width");
    let mut y = ITensor::zeros(&[t, rows]);
    for i in 0..t {
        for r in 0..rows {
            let mut acc = bias[r];
            for c in 0..cols {
                acc += x.at2(i, c) * w.at2(r, c);
            }
            let v = m.apply(acc);
            y.data[i * rows + r] = (if fuse_relu { v.max(0) } else { v }).clamp(min_s, max_s);
        }
    }
    y
}

/// L stacked [`BlockFhe`]s compiled into a single [`CircuitPlan`] DAG —
/// the "encrypted transformer server" unit: one plan, one input grid,
/// cross-layer CSE/packing, one fused level loop end to end.
#[derive(Clone, Debug)]
pub struct ModelFhe {
    pub mechanism: Mechanism,
    pub split: HeadSplit,
    pub shared_kv: bool,
    pub blocks: Vec<BlockFhe>,
    cache: Arc<PlanCache>,
}

impl ModelFhe {
    /// Stack pre-built blocks; all must agree on mechanism, width, head
    /// count and KV layout (they share one residual stream).
    pub fn new(blocks: Vec<BlockFhe>) -> Self {
        assert!(!blocks.is_empty(), "a model needs at least one block");
        let (mechanism, split, shared_kv) =
            (blocks[0].mechanism, blocks[0].split, blocks[0].shared_kv);
        for blk in &blocks {
            assert_eq!(blk.mechanism, mechanism, "blocks must share one mechanism");
            assert_eq!(blk.split, split, "blocks must share one head split");
            assert_eq!(blk.shared_kv, shared_kv, "blocks must share one KV layout");
        }
        ModelFhe { mechanism, split, shared_kv, blocks, cache: Arc::new(PlanCache::default()) }
    }

    /// Build the encrypted model from a plaintext block stack (e.g. a
    /// `QTransformer`'s `blocks`), taking every quantized weight from
    /// the model layers.
    pub fn from_blocks(blocks: &[Block], shared_kv: bool) -> Self {
        Self::new(blocks.iter().map(|blk| BlockFhe::from_block(blk, shared_kv)).collect())
    }

    /// Deterministic demo model over [`BlockWeights::demo`] layers — the
    /// CLI's and the harness's weight source (range-closed on x ∈
    /// [−1, 1] inputs; see the demo docs).
    pub fn demo(
        mechanism: Mechanism,
        d_model: usize,
        n_heads: usize,
        n_layers: usize,
        shared_kv: bool,
        ffn_dim: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self::new(
            (0..n_layers)
                .map(|_| {
                    let w = BlockWeights::demo(d_model, ffn_dim, &mut rng);
                    BlockFhe::new(mechanism, d_model, n_heads, shared_kv, w)
                })
                .collect(),
        )
    }

    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// Declare the stack's output accumulators `bits` wide: the last
    /// block's final residual requant is replaced by a declared-width
    /// accumulator (see [`BlockFhe::with_output_accumulator_bits`]), so
    /// `forward()` returns `[T, D·limbs]` radix limb vectors and
    /// [`Self::mirror`] keeps the last layer's raw accumulators.
    /// Interior layers are untouched — they feed the next layer's narrow
    /// residual stream. Resets the plan cache.
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        let last = self.blocks.last_mut().expect("a model has at least one block");
        last.out_acc_bits = Some(bits);
        self.cache = Arc::new(PlanCache::default());
        self
    }

    /// Ciphertexts the stacked plan takes: the `[T, D]` input grid.
    pub fn n_plan_inputs(&self, t: usize) -> usize {
        t * self.split.d_model
    }

    /// Mechanism string the serving registry keys block engines by:
    /// `block/<mechanism>@h<H>xL<L>[s]` (router key
    /// `fhe/block/<mech>@h<H>xL<L>[s]/<session>`).
    pub fn engine_mechanism(&self) -> String {
        block_engine_mechanism(self.mechanism, self.split.n_heads, self.n_layers(), self.shared_kv)
    }

    /// Build the fused L-layer plan, **raw** (the rewrite pipeline is
    /// the caller's — `plan_for` applies it). Inputs and outputs are the
    /// `[T, D]` residual stream, row-major.
    pub fn plan(&self, t: usize) -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let mut x = b.inputs(t * self.split.d_model);
        let mut acc: Option<(Vec<NodeId>, FixedMult)> = None;
        for blk in &self.blocks {
            let (nx, naccs) = blk.emit(
                &mut b,
                &x,
                acc.as_ref().map(|(a, m)| (a.as_slice(), *m)),
                t,
            );
            acc = Some((naccs, blk.weights.resid_requant));
            x = nx;
        }
        for id in x {
            b.output(id);
        }
        b.build()
    }

    /// The rewritten, `(T, D, budget)`-cached plan `forward()` executes
    /// under `ctx` (honors `FHE_NO_REWRITE`, like every head's
    /// `plan_for`).
    pub fn plan_for(&self, ctx: &FheContext, t: usize) -> Arc<CircuitPlan> {
        self.cache.rewritten_for(ctx, t, self.split.d_model, || self.plan(t))
    }

    /// Per-model cache regression counter (see
    /// `InhibitorFhe::plan_builds`).
    pub fn plan_builds(&self) -> usize {
        self.cache.builds()
    }

    /// Borrowed plan-input vector: the `[T, D]` grid row-major — the
    /// single definition of the wire layout (trivially x's own order).
    pub fn input_refs<'m>(&self, x: &'m CtMatrix) -> Vec<&'m CtInt> {
        assert_eq!(x.cols, self.split.d_model, "input must be [T, d_model]");
        x.data.iter().collect()
    }

    /// Encrypted forward through the whole block stack: executes the
    /// cached rewritten plan by reference and returns the `[T, D]`
    /// output stream.
    pub fn forward(&self, ctx: &FheContext, x: &CtMatrix) -> CtMatrix {
        let t = x.rows;
        let refs = self.input_refs(x);
        let data = self.plan_for(ctx, t).execute_ref(ctx, &refs);
        let cols = data.len() / t;
        CtMatrix { rows: t, cols, data }
    }

    /// Plaintext mirror of the exact integer function the stacked plan
    /// computes (every LUT clamp, every cross-layer fold included).
    /// `min_s`/`max_s` are the executing encoder's signed bounds.
    pub fn mirror(&self, x: &ITensor, min_s: i64, max_s: i64) -> ITensor {
        let mut x = x.clone();
        let mut acc: Option<(ITensor, FixedMult)> = None;
        for blk in &self.blocks {
            let (nx, naccs) =
                blk.mirror_step(&x, acc.as_ref().map(|(a, m)| (a, *m)), min_s, max_s);
            acc = Some((naccs, blk.weights.resid_requant));
            x = nx;
        }
        x
    }

    /// The QTransformer-side reference of the same function, computed
    /// through the given `model::Block` layer objects' own
    /// `QLinear`/`QFfn` forwards (unclamped i64 model arithmetic) with
    /// only the attention sub-layer going through the head mirrors.
    /// Exact equality with [`Self::mirror`] (and the encrypted decode)
    /// holds whenever no LUT clamp bites — which the demo-weight ranges
    /// guarantee; the differential harness pins all three against each
    /// other. One definition, shared by the unit and integration tests,
    /// so the bridge cannot drift.
    pub fn reference_stack(
        &self,
        blocks: &[Block],
        x0: &ITensor,
        min_s: i64,
        max_s: i64,
    ) -> ITensor {
        assert_eq!(blocks.len(), self.blocks.len(), "one model::Block per layer");
        let d = self.split.d_head();
        let mut x = x0.clone();
        for (blk, fhe) in blocks.iter().zip(&self.blocks) {
            let (k, v) = if self.shared_kv {
                (x.slice_cols(0, d), x.slice_cols(0, d))
            } else {
                (x.clone(), x.clone())
            };
            let h = fhe.attn.mirror(&x, &k, &v, min_s, max_s);
            let h = blk.wo.forward(&h);
            let x1 = x.add(&h).map(|t| blk.resid_requant.apply(t));
            let f = blk.ffn.forward(&x1);
            x = x1.add(&f).map(|t| blk.resid_requant.apply(t));
        }
        x
    }
}

/// See [`ModelFhe::engine_mechanism`]: `block/<mech>@h<H>xL<L>[s]`.
pub fn block_engine_mechanism(
    mech: Mechanism,
    n_heads: usize,
    n_layers: usize,
    shared_kv: bool,
) -> String {
    format!(
        "block/{}@h{}xL{}{}",
        mech.name(),
        n_heads,
        n_layers,
        if shared_kv { "s" } else { "" }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shapes_levels_and_io() {
        // Analysis only — no crypto. Depth: 9 PBS levels per layer for
        // the inhibitors (splits/abs → ssr → inhibition → refresh → W_O
        // → resid → fc1 → fc2 → out), 11 for dot-product (its attention
        // alone is 6 deep).
        for &(mech, per_layer_levels) in &[
            (Mechanism::Inhibitor, 9usize),
            (Mechanism::InhibitorSigned, 9),
            (Mechanism::DotProduct, 11),
        ] {
            for &(heads, layers, t, d) in
                &[(1usize, 1usize, 2usize, 2usize), (2, 2, 2, 1), (2, 1, 3, 2)]
            {
                let dm = heads * d;
                let model = ModelFhe::demo(mech, dm, heads, layers, false, dm, 0xB10C);
                let p = model.plan(t);
                let tag = format!("{mech:?} H={heads} L={layers} T={t} d={d}");
                assert_eq!(p.n_inputs(), t * dm, "{tag}: inputs");
                assert_eq!(p.n_inputs(), model.n_plan_inputs(t), "{tag}");
                assert_eq!(p.n_outputs(), t * dm, "{tag}: outputs");
                assert_eq!(p.levels(), layers * per_layer_levels, "{tag}: levels");
            }
        }
    }

    #[test]
    fn engine_mechanism_strings_are_distinct_per_configuration() {
        assert_eq!(
            block_engine_mechanism(Mechanism::Inhibitor, 2, 3, false),
            "block/inhibitor@h2xL3"
        );
        assert_eq!(
            block_engine_mechanism(Mechanism::InhibitorSigned, 4, 1, true),
            "block/inhibitor-signed@h4xL1s"
        );
        let model = ModelFhe::demo(Mechanism::DotProduct, 2, 2, 2, true, 2, 7);
        assert_eq!(model.engine_mechanism(), "block/dotprod@h2xL2s");
    }

    #[test]
    fn from_blocks_extracts_the_model_weights_verbatim() {
        let mut rng = Xoshiro256::new(41);
        let (heads, d) = (2usize, 2usize);
        let dm = heads * d;
        let weights = BlockWeights::demo(dm, dm, &mut rng);
        let blk = weights.to_model_block(Mechanism::Inhibitor, heads);
        let fhe = BlockFhe::from_block(&blk, false);
        assert_eq!(fhe.mechanism, Mechanism::Inhibitor);
        assert_eq!(fhe.split, HeadSplit::new(dm, heads));
        assert_eq!(fhe.weights.wo, weights.wo);
        assert_eq!(fhe.weights.fc1, weights.fc1);
        assert_eq!(fhe.weights.fc2, weights.fc2);
        assert_eq!(fhe.weights.wo_b, weights.wo_b);
        assert_eq!(fhe.weights.resid_requant, weights.resid_requant);
        // Stacks too.
        let model = ModelFhe::from_blocks(&[blk], false);
        assert_eq!(model.n_layers(), 1);
        assert_eq!(model.engine_mechanism(), "block/inhibitor@h2xL1");
    }

    #[test]
    fn mirror_matches_model_layer_stack_when_nothing_clamps() {
        // With clamp bounds far beyond every intermediate, the block
        // mirror must equal the plaintext dataflow computed with the
        // model's own QLinear/QFfn layers and the attention head
        // mirrors — for every mechanism and both KV layouts.
        let mut rng = Xoshiro256::new(0xB10C2);
        let (bound_lo, bound_hi) = (-1_000_000i64, 1_000_000i64);
        for mech in [Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for shared in [false, true] {
                let (heads, d, t, layers) = (2usize, 2usize, 2usize, 2usize);
                let dm = heads * d;
                let model = ModelFhe::demo(mech, dm, heads, layers, shared, dm, 0xB10C3);
                let blocks: Vec<Block> = model
                    .blocks
                    .iter()
                    .map(|b| b.weights.to_model_block(mech, heads))
                    .collect();
                let x0 = ITensor::random(&[t, dm], -1, 1, &mut rng);
                let got = model.mirror(&x0, bound_lo, bound_hi);
                let want = model.reference_stack(&blocks, &x0, bound_lo, bound_hi);
                assert_eq!(got, want, "{mech:?} shared={shared}");
            }
        }
    }

    #[test]
    fn demo_weights_stay_in_documented_ranges_on_unit_inputs() {
        // The documented bounds: x ∈ [−1, 1] in, every mirror value
        // within the 5-bit (inhibitors) / 6-bit (dot-product) signed
        // range — checked by the mirror at those clamp bounds agreeing
        // with the mirror at effectively-unbounded clamps (no LUT clamp
        // ever bites on demo weights), across seeds and layouts.
        let mut rng = Xoshiro256::new(0xB10C4);
        for mech in [Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            let (heads, d, layers) = (2usize, 2usize, 2usize);
            let dm = heads * d;
            let (lo, hi) = if mech == Mechanism::DotProduct { (-32, 31) } else { (-16, 15) };
            for shared in [false, true] {
                let model = ModelFhe::demo(mech, dm, heads, layers, shared, dm, 0xB10C5);
                for trial in 0..4 {
                    let x = ITensor::random(&[2, dm], -1, 1, &mut rng);
                    let clamped = model.mirror(&x, lo, hi);
                    let unclamped = model.mirror(&x, -1_000_000, 1_000_000);
                    assert_eq!(
                        clamped, unclamped,
                        "{mech:?} shared={shared} trial={trial}: a clamp bit"
                    );
                    assert!(
                        clamped.data.iter().all(|&v| (-4..=4).contains(&v)),
                        "{mech:?} shared={shared} trial={trial}: output outside [−4, 4]"
                    );
                }
            }
        }
    }
}
