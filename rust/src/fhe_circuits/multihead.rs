//! Multi-head encrypted attention as **one** fused `CircuitPlan` (S6b).
//!
//! A transformer block splits its model width into H head slices, runs
//! attention per slice, and concatenates. Serving each head as its own
//! circuit would hand the rewrite pipeline H isolated DAGs — and PR 3's
//! passes find nothing *across* circuits. [`MultiHeadFhe`] instead emits
//! every head's subgraph into a single [`CircuitBuilder`] (per-head
//! Q/K/V input segments, head outputs interleaved into `[T, H·d]`
//! row-major order), so:
//!
//! * **CSE works across head boundaries.** In the multi-query layout
//!   (`shared_kv`: one K/V segment attended by every head — the standard
//!   bandwidth optimization), the signed inhibitor's V⁺/V⁻ split PBS are
//!   re-emitted by *every* head on the *same* value ciphertexts, and the
//!   splits reference the builder's standard relu/min0 tables — so CSE
//!   collapses them to one split pair per value for the whole block
//!   (`2·(H−1)·T·d` fewer LUT evaluations than H separate circuits).
//! * **Packing amortizes across heads.** The surviving split pairs fuse
//!   into `T·d` shared blind rotations whose results feed all H heads'
//!   subgraphs: at any `many_lut_log ≥ 1` budget the fused plan needs
//!   **strictly fewer** rotations than H separately-rewritten
//!   single-head plans (`(H−1)·T·d` fewer — pinned by
//!   `tests/multihead_it.rs`).
//! * **Fusion sees one deeper batch.** The combined plan has the same
//!   level count as one head but H× the jobs per level, so
//!   `FusedLevelExecutor` fills the PBS worker pool even for a single
//!   request, and co-scheduled multi-head requests fuse level-wise
//!   exactly like single-head ones.
//!
//! With per-head K/V (`shared_kv = false`) the H subgraphs are disjoint
//! and every count is exactly H× the single-head closed form — also
//! pinned, so the fused builder provably adds no hidden cost.
//!
//! The plaintext reference ([`MultiHeadFhe::mirror`]) is the per-head
//! single-head mirror applied to each column slice and concatenated —
//! the same function `model::Block` computes with `n_heads > 1` — which
//! is what the differential harness tests encrypted outputs against,
//! bit for bit.

use super::attention_fhe::{
    CtMatrix, DotProductFhe, HeadValues, InhibitorFhe, InhibitorSignedFhe, PlanCache,
};
use crate::attention::{HeadSplit, Mechanism};
use crate::tensor::ITensor;
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitBuilder, CircuitPlan, NodeId};
use std::sync::Arc;

/// The per-head circuit a [`MultiHeadFhe`] instantiates H times.
#[derive(Clone, Debug)]
enum HeadProto {
    Inhibitor(InhibitorFhe),
    InhibitorSigned(InhibitorSignedFhe),
    DotProduct(DotProductFhe),
}

/// Generic H-head wrapper over the three head mechanisms, compiled into
/// a single combined [`CircuitPlan`] (see the module docs).
#[derive(Clone, Debug)]
pub struct MultiHeadFhe {
    pub mechanism: Mechanism,
    pub n_heads: usize,
    /// Multi-query layout: one K/V segment shared by every head (per-head
    /// Q segments). `false` gives each head its own K/V slice.
    pub shared_kv: bool,
    proto: HeadProto,
    cache: Arc<PlanCache>,
}

impl MultiHeadFhe {
    /// `d_head` is the per-head width (γ = √d_head for the inhibitors);
    /// the per-head constructors use the same defaults as the serving
    /// registry's single-head engines (α_q = 1, input magnitude 2).
    pub fn new(mechanism: Mechanism, d_head: usize, n_heads: usize, shared_kv: bool) -> Self {
        assert!(n_heads >= 1, "a multi-head block needs at least one head");
        let proto = match mechanism {
            Mechanism::Inhibitor => HeadProto::Inhibitor(InhibitorFhe::new(d_head, 1)),
            Mechanism::InhibitorSigned => {
                HeadProto::InhibitorSigned(InhibitorSignedFhe::new(d_head, 1))
            }
            Mechanism::DotProduct => HeadProto::DotProduct(DotProductFhe::new(d_head, 2)),
        };
        MultiHeadFhe { mechanism, n_heads, shared_kv, proto, cache: Arc::new(PlanCache::default()) }
    }

    /// Declare every head's output accumulators `bits` wide (see
    /// [`InhibitorFhe::with_accumulator_bits`] for the per-head
    /// contract): the combined plan's outputs become radix limb
    /// vectors and `forward()` returns `[T, H·d·limbs]`, limbs
    /// innermost. Resets the plan cache.
    pub fn with_accumulator_bits(mut self, bits: u32) -> Self {
        self.proto = match self.proto {
            HeadProto::Inhibitor(h) => HeadProto::Inhibitor(h.with_accumulator_bits(bits)),
            HeadProto::InhibitorSigned(h) => {
                HeadProto::InhibitorSigned(h.with_accumulator_bits(bits))
            }
            HeadProto::DotProduct(h) => HeadProto::DotProduct(h.with_accumulator_bits(bits)),
        };
        self.cache = Arc::new(PlanCache::default());
        self
    }

    /// Ciphertexts the combined plan takes: H Q segments of `T·d` each,
    /// plus H (or, under `shared_kv`, one) K and V segment pairs.
    pub fn n_plan_inputs(&self, t: usize, d: usize) -> usize {
        if self.shared_kv {
            (self.n_heads + 2) * t * d
        } else {
            3 * self.n_heads * t * d
        }
    }

    /// Mechanism string the serving registry keys multi-head engines by
    /// — distinct from the single-head engine of the same mechanism and
    /// session (e.g. `inhibitor-signed@h4s` = 4 heads, shared KV).
    pub fn engine_mechanism(&self) -> String {
        multihead_engine_mechanism(self.mechanism, self.n_heads, self.shared_kv)
    }

    /// Build the combined H-head plan, **raw** (the rewrite pipeline is
    /// the caller's — `plan_for` applies it). Input layout: per head
    /// `q_h ‖ k_h ‖ v_h` row-major segments, or `q_0 ‖ … ‖ q_{H−1} ‖ k ‖
    /// v` under `shared_kv`. Outputs are `[T, H·d]` row-major — the
    /// decrypted plan output *is* the concatenated multi-head matrix.
    pub fn plan(&self, t: usize, d: usize) -> CircuitPlan {
        let h = self.n_heads;
        let mut b = CircuitBuilder::new();
        let (qs, ks, vs) = if self.shared_kv {
            let qs: Vec<Vec<NodeId>> = (0..h).map(|_| b.inputs(t * d)).collect();
            let k = b.inputs(t * d);
            let v = b.inputs(t * d);
            (qs, vec![k; h], vec![v; h])
        } else {
            let mut qs = Vec::with_capacity(h);
            let mut ks = Vec::with_capacity(h);
            let mut vs = Vec::with_capacity(h);
            for _ in 0..h {
                qs.push(b.inputs(t * d));
                ks.push(b.inputs(t * d));
                vs.push(b.inputs(t * d));
            }
            (qs, ks, vs)
        };
        let values: Vec<HeadValues> = vs.iter().map(|v| HeadValues::Plain(v)).collect();
        let outs = self.emit(&mut b, &qs, &ks, &values, t, d);
        for i in 0..t {
            for head_out in &outs {
                for kk in 0..d {
                    b.output(head_out[i * d + kk]);
                }
            }
        }
        b.build()
    }

    /// Emit all H heads' subgraphs into a shared builder: `qs`/`ks` are
    /// per-head `T·d` node segments (the same segment may repeat under a
    /// shared-KV layout) and `vs` gives each head's value source —
    /// plain nodes, or pre-split `(v⁺, v⁻)` pairs for the signed
    /// mechanism (see [`HeadValues`]). Returns the per-head output node
    /// grids; the caller owns output ordering. Both [`Self::plan`] and
    /// the block circuit (`super::block_fhe::BlockFhe`) feed through
    /// here, so the fused multi-head dataflow is defined exactly once.
    pub(super) fn emit(
        &self,
        b: &mut CircuitBuilder,
        qs: &[Vec<NodeId>],
        ks: &[Vec<NodeId>],
        vs: &[HeadValues<'_>],
        t: usize,
        d: usize,
    ) -> Vec<Vec<NodeId>> {
        assert_eq!(qs.len(), self.n_heads, "one Q segment per head");
        assert_eq!(ks.len(), self.n_heads, "one K segment per head");
        assert_eq!(vs.len(), self.n_heads, "one value source per head");
        (0..self.n_heads)
            .map(|hh| match (&self.proto, &vs[hh]) {
                (HeadProto::Inhibitor(head), HeadValues::Plain(v)) => {
                    head.emit(b, &qs[hh], &ks[hh], v, t, d)
                }
                (HeadProto::InhibitorSigned(head), HeadValues::Plain(v)) => {
                    head.emit(b, &qs[hh], &ks[hh], v, t, d)
                }
                (HeadProto::InhibitorSigned(head), HeadValues::PreSplit(pairs)) => {
                    head.emit_presplit(b, &qs[hh], &ks[hh], pairs, t, d)
                }
                (HeadProto::DotProduct(head), HeadValues::Plain(v)) => {
                    head.emit(b, &qs[hh], &ks[hh], v, t, d)
                }
                _ => panic!("pre-split values are only defined for the signed inhibitor"),
            })
            .collect()
    }

    /// Incremental-decode form of [`Self::emit`]: each head attends one
    /// query row (`qs[h]` is `d` nodes) against `n` cached+new
    /// positions (`ks[h]`/`vs[h]` cover `n·d` elements, position-major).
    /// Dispatch mirrors `emit` exactly, so a causal prefill looped
    /// through this recurrence is the same dataflow streaming emits
    /// step by step.
    pub(super) fn emit_step(
        &self,
        b: &mut CircuitBuilder,
        qs: &[Vec<NodeId>],
        ks: &[Vec<NodeId>],
        vs: &[HeadValues<'_>],
        n: usize,
        d: usize,
    ) -> Vec<Vec<NodeId>> {
        assert_eq!(qs.len(), self.n_heads, "one Q row per head");
        assert_eq!(ks.len(), self.n_heads, "one K segment per head");
        assert_eq!(vs.len(), self.n_heads, "one value source per head");
        (0..self.n_heads)
            .map(|hh| match (&self.proto, &vs[hh]) {
                (HeadProto::Inhibitor(head), HeadValues::Plain(v)) => {
                    head.emit_step(b, &qs[hh], &ks[hh], v, n, d)
                }
                (HeadProto::InhibitorSigned(head), HeadValues::Plain(v)) => {
                    head.emit_step(b, &qs[hh], &ks[hh], v, n, d)
                }
                (HeadProto::InhibitorSigned(head), HeadValues::PreSplit(pairs)) => {
                    head.emit_step_presplit(b, &qs[hh], &ks[hh], pairs, n, d)
                }
                (HeadProto::DotProduct(head), HeadValues::Plain(v)) => {
                    head.emit_step(b, &qs[hh], &ks[hh], v, n, d)
                }
                _ => panic!("pre-split values are only defined for the signed inhibitor"),
            })
            .collect()
    }

    /// The rewritten, `(T, d, budget)`-cached combined plan `forward()`
    /// executes under `ctx` (honors `FHE_NO_REWRITE`, like every
    /// single-head `plan_for`).
    pub fn plan_for(&self, ctx: &FheContext, t: usize, d: usize) -> Arc<CircuitPlan> {
        self.cache.rewritten_for(ctx, t, d, || self.plan(t, d))
    }

    /// Per-wrapper cache regression counter (see
    /// [`InhibitorFhe::plan_builds`]).
    pub fn plan_builds(&self) -> usize {
        self.cache.builds()
    }

    /// Borrowed plan-input vector in exactly the layout [`Self::plan`]
    /// declares. `forward()`, the serving engine's clients, and the
    /// differential tests all pack through here, so the wire layout has
    /// a single definition. `q` is `[T, H·d]`; `k`/`v` are the same
    /// shape, or `[T, d]` under `shared_kv`.
    pub fn input_refs<'m>(
        &self,
        q: &'m CtMatrix,
        k: &'m CtMatrix,
        v: &'m CtMatrix,
    ) -> Vec<&'m CtInt> {
        let h = self.n_heads;
        let t = q.rows;
        let split = HeadSplit::new(q.cols, h);
        let d = split.d_head();
        let kv_cols = if self.shared_kv { d } else { h * d };
        assert_eq!((k.rows, k.cols), (t, kv_cols), "k must be [T, {kv_cols}]");
        assert_eq!((v.rows, v.cols), (t, kv_cols), "v must be [T, {kv_cols}]");
        let mut refs = Vec::with_capacity(self.n_plan_inputs(t, d));
        if self.shared_kv {
            for hh in 0..h {
                push_cols(&mut refs, q, split.col0(hh), d);
            }
            push_cols(&mut refs, k, 0, d);
            push_cols(&mut refs, v, 0, d);
        } else {
            for hh in 0..h {
                push_cols(&mut refs, q, split.col0(hh), d);
                push_cols(&mut refs, k, split.col0(hh), d);
                push_cols(&mut refs, v, split.col0(hh), d);
            }
        }
        refs
    }

    /// Encrypted multi-head forward: splits `q` (and `k`/`v` unless
    /// shared) into H column slices, executes the cached combined plan
    /// by reference, and returns the concatenated `[T, H·d]` result.
    pub fn forward(&self, ctx: &FheContext, q: &CtMatrix, k: &CtMatrix, v: &CtMatrix) -> CtMatrix {
        let t = q.rows;
        let d = q.cols / self.n_heads;
        let refs = self.input_refs(q, k, v);
        let data = self.plan_for(ctx, t, d).execute_ref(ctx, &refs);
        let cols = data.len() / t;
        CtMatrix { rows: t, cols, data }
    }

    /// One head's mirror, dispatched per mechanism (the unsigned
    /// inhibitor only clamps at `max_s`, like its own mirror).
    fn head_mirror(&self, q: &ITensor, k: &ITensor, v: &ITensor, min_s: i64, max_s: i64) -> ITensor {
        match &self.proto {
            HeadProto::Inhibitor(head) => head.mirror(q, k, v, max_s),
            HeadProto::InhibitorSigned(head) => head.mirror(q, k, v, min_s, max_s),
            HeadProto::DotProduct(head) => head.mirror(q, k, v, min_s, max_s),
        }
    }

    /// Plaintext mirror of the exact integer function the combined
    /// circuit computes (including every LUT clamp): the single-head
    /// mirror on each column slice, concatenated into `[T, H·d]` through
    /// the shared [`HeadSplit`] slicing helper (the same arithmetic
    /// `model::Block` uses). `min_s`/`max_s` are the executing encoder's
    /// signed bounds.
    pub fn mirror(&self, q: &ITensor, k: &ITensor, v: &ITensor, min_s: i64, max_s: i64) -> ITensor {
        let split = HeadSplit::new(q.dims()[1], self.n_heads);
        split.apply(q, k, v, self.shared_kv, |qs, ks, vs| {
            self.head_mirror(qs, ks, vs, min_s, max_s)
        })
    }

    /// One head's mirror over pre-split values — the block circuit's
    /// per-head reference path (signed mechanism only; see
    /// [`InhibitorSignedFhe::mirror_presplit`]).
    pub(super) fn head_mirror_presplit(
        &self,
        q: &ITensor,
        k: &ITensor,
        vp: &ITensor,
        vn: &ITensor,
        min_s: i64,
        max_s: i64,
    ) -> ITensor {
        match &self.proto {
            HeadProto::InhibitorSigned(head) => head.mirror_presplit(q, k, vp, vn, min_s, max_s),
            _ => panic!("pre-split mirrors are only defined for the signed inhibitor"),
        }
    }
}

/// Push the `[T, width]` column slice of `m` starting at `col0`,
/// row-major, as references.
fn push_cols<'m>(refs: &mut Vec<&'m CtInt>, m: &'m CtMatrix, col0: usize, width: usize) {
    for i in 0..m.rows {
        for kk in 0..width {
            refs.push(m.at(i, col0 + kk));
        }
    }
}

/// See [`MultiHeadFhe::engine_mechanism`]: `<mechanism>@h<H>[s]`.
pub fn multihead_engine_mechanism(mech: Mechanism, n_heads: usize, shared_kv: bool) -> String {
    format!("{}@h{}{}", mech.name(), n_heads, if shared_kv { "s" } else { "" })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_head_plan_is_the_single_head_plan() {
        // H = 1 (either layout — they coincide) must reproduce the
        // single-head plan exactly: same counts, levels, IO. Analysis
        // only, so the sweep is cheap.
        for &(t, d) in &[(2usize, 2usize), (3, 2), (4, 1)] {
            for shared in [false, true] {
                let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, 1, shared);
                let p = mh.plan(t, d);
                let s = InhibitorFhe::new(d, 1).plan(t, d);
                assert_eq!(p.pbs_count(), s.pbs_count(), "T={t} d={d}");
                assert_eq!(p.levels(), s.levels());
                assert_eq!(p.level_sizes(), s.level_sizes());
                assert_eq!(p.n_inputs(), s.n_inputs());
                assert_eq!(p.n_outputs(), s.n_outputs());
                assert_eq!(p.linear_op_count(), s.linear_op_count());
            }
        }
        let mh = MultiHeadFhe::new(Mechanism::DotProduct, 2, 1, false);
        let s = DotProductFhe::new(2, 2).plan(2, 2);
        assert_eq!(mh.plan(2, 2).pbs_count(), s.pbs_count());
        let mh = MultiHeadFhe::new(Mechanism::InhibitorSigned, 2, 1, true);
        let s = InhibitorSignedFhe::new(2, 1).plan(2, 2);
        assert_eq!(mh.plan(2, 2).pbs_count(), s.pbs_count());
    }

    #[test]
    fn plan_input_and_output_layout() {
        let (t, d, h) = (3usize, 2usize, 4usize);
        let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, h, false);
        let p = mh.plan(t, d);
        assert_eq!(p.n_inputs(), 3 * h * t * d);
        assert_eq!(p.n_inputs(), mh.n_plan_inputs(t, d));
        assert_eq!(p.n_outputs(), h * t * d, "outputs cover [T, H·d]");
        let shared = MultiHeadFhe::new(Mechanism::Inhibitor, d, h, true);
        assert_eq!(shared.plan(t, d).n_inputs(), (h + 2) * t * d);
        assert_eq!(shared.plan(t, d).n_outputs(), h * t * d);
    }

    #[test]
    fn engine_mechanism_strings_are_distinct_per_configuration() {
        let a = multihead_engine_mechanism(Mechanism::Inhibitor, 4, false);
        let b = multihead_engine_mechanism(Mechanism::Inhibitor, 4, true);
        let c = multihead_engine_mechanism(Mechanism::Inhibitor, 2, false);
        assert_eq!(a, "inhibitor@h4");
        assert_eq!(b, "inhibitor@h4s");
        assert!(a != b && a != c && b != c);
        assert_eq!(
            MultiHeadFhe::new(Mechanism::DotProduct, 2, 3, true).engine_mechanism(),
            "dotprod@h3s"
        );
    }

    #[test]
    fn mirror_concatenates_per_head_single_head_mirrors() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(77);
        let (t, d, h) = (3usize, 2usize, 2usize);
        let q = ITensor::random(&[t, h * d], -2, 2, &mut rng);
        let k = ITensor::random(&[t, h * d], -2, 2, &mut rng);
        let v = ITensor::random(&[t, h * d], 0, 3, &mut rng);
        let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, h, false);
        let got = mh.mirror(&q, &k, &v, -16, 15);
        let single = InhibitorFhe::new(d, 1);
        for hh in 0..h {
            let want = single.mirror(
                &q.slice_cols(hh * d, d),
                &k.slice_cols(hh * d, d),
                &v.slice_cols(hh * d, d),
                15,
            );
            assert_eq!(got.slice_cols(hh * d, d), want, "head {hh} slice");
        }
    }
}
