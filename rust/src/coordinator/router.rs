//! Router + coordinator facade (S9): the entry point the server and the
//! examples use. Owns the scheduler, the key manager, and the routing
//! policy that picks an engine for each logical request.
//!
//! Engines:
//!   * `quant/<mechanism>` — the plaintext quantized integer transformer.
//!   * `pjrt/<model>`      — the AOT float model (engine is constructed
//!     lazily *inside* its worker thread: PJRT handles never cross
//!     threads).
//!   * `fhe/<mech>/<sid>`  — per-session encrypted attention.

use super::batcher::BatchPolicy;
use super::fused::FusedLevelExecutor;
use super::keymgr::{KeyManager, Session};
use super::request::{EngineOutput, EnginePath, InferRequest, InferResponse, Payload};
use super::scheduler::Scheduler;
use crate::fhe_circuits::{DotProductFhe, InhibitorFhe, InhibitorSignedFhe, ModelFhe, MultiHeadFhe};
use crate::model::{ModelInput, QTransformer};
use crate::tensor::ITensor;
use crate::tfhe::plan::CircuitPlan;
#[cfg(feature = "xla")]
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// Routing preference for float requests that both clear engines can run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always the quantized integer engine.
    PreferQuant,
    /// Always the PJRT float engine.
    PreferPjrt,
    /// Pick the engine with the shorter queue.
    LeastLoaded,
}

/// The coordinator facade.
pub struct Coordinator {
    scheduler: Scheduler,
    pub keymgr: Arc<KeyManager>,
    pub policy: RoutePolicy,
}

impl Coordinator {
    pub fn new(policy: RoutePolicy) -> Self {
        Coordinator { scheduler: Scheduler::new(), keymgr: Arc::new(KeyManager::new()), policy }
    }

    pub fn metrics(&self) -> &super::metrics::Metrics {
        &self.scheduler.metrics
    }

    /// PBS worker threads granted to encrypted engines registered from
    /// here on (default: `FHE_THREADS` env or all cores).
    pub fn set_fhe_threads(&mut self, n: usize) {
        self.scheduler.set_fhe_threads(n);
    }

    /// Register a quantized integer model under `quant/<mechanism>`.
    pub fn add_quant_engine(&mut self, mechanism: &str, model: QTransformer, policy: BatchPolicy) {
        let key = EnginePath::QuantInt(mechanism.into()).batch_key();
        self.scheduler.add_engine(
            &key,
            policy,
            Box::new(move || {
                Box::new(move |batch: &[InferRequest]| {
                batch
                    .iter()
                    .map(|req| match &req.payload {
                        Payload::Features(data, (r, c)) => {
                            let codes: Vec<i64> = data
                                .iter()
                                .map(|&x| (x / model.act_scale).round() as i64)
                                .collect();
                            let t = ITensor::from_vec(&[*r, *c], codes);
                            let out = model.forward(&ModelInput::Features(t));
                            Ok(EngineOutput::Values(
                                out.data.iter().map(|&c| c as f32 * model.act_scale).collect(),
                            ))
                        }
                        Payload::Tokens(toks) => {
                            let out = model.forward(&ModelInput::Tokens(toks.clone()));
                            Ok(EngineOutput::Values(
                                out.data.iter().map(|&c| c as f32 * model.act_scale).collect(),
                            ))
                        }
                        Payload::CiphertextRef(_) => {
                            Err("ciphertext sent to a clear engine".to_string())
                        }
                    })
                    .collect::<Result<Vec<_>, _>>()
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Register a PJRT model engine under `pjrt/<name>`. The artifact is
    /// compiled on first use inside the worker thread. Only available
    /// with the `xla` feature (the PJRT runtime needs the vendored `xla`
    /// crate).
    #[cfg(feature = "xla")]
    pub fn add_pjrt_model(&mut self, artifacts_dir: PathBuf, model_name: &str, policy: BatchPolicy) {
        let key = EnginePath::Pjrt(model_name.into()).batch_key();
        let name = model_name.to_string();
        self.scheduler.add_engine(
            &key,
            policy,
            Box::new(move || {
                // PJRT state is created here, on the worker thread, and
                // never crosses a thread boundary (xla handles are !Send).
                let mut registry: Option<crate::runtime::Registry> = None;
                Box::new(move |batch: &[InferRequest]| {
                if registry.is_none() {
                    registry = Some(
                        crate::runtime::Registry::open(artifacts_dir.clone())
                            .map_err(|e| format!("opening artifacts: {e:#}"))?,
                    );
                }
                let engine = registry
                    .as_mut()
                    .unwrap()
                    .model_engine(&name)
                    .map_err(|e| format!("loading model '{name}': {e:#}"))?;
                batch
                    .iter()
                    .map(|req| match &req.payload {
                        Payload::Features(data, _shape) => engine
                            .run_f32(&[data.clone()])
                            .map(EngineOutput::Values)
                            .map_err(|e| format!("pjrt execute: {e:#}")),
                        _ => Err("pjrt engine takes float features".to_string()),
                    })
                    .collect::<Result<Vec<_>, _>>()
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Register the encrypted attention engine for a session. Requests
    /// carry `Payload::CiphertextRef` pointing at a registered Q/K/V
    /// bundle (3·T·d ciphertexts); the result bundle id comes back as
    /// the response's typed `result_blob` reference.
    ///
    /// The worker builds the head's `CircuitPlan` once (the engine's
    /// mechanism and shape are fixed) and executes every batch through
    /// [`super::fused::FusedLevelExecutor`]: the current PBS level of all
    /// co-scheduled requests goes to the worker pool as one fused
    /// `pbs_batch`, so small-`T` requests fill the pool together. Fusion
    /// never changes results or PBS counts — outputs are bit-identical to
    /// single-request execution (pinned by `tests/fusion_it.rs`).
    pub fn add_fhe_engine(
        &mut self,
        session_id: u64,
        mechanism: &str,
        seq_len: usize,
        dim: usize,
        policy: BatchPolicy,
    ) -> Result<(), String> {
        // Same name resolution as every other entry point (CLI included):
        // aliases like "softmax" select the dot-product circuit.
        let mech = crate::attention::Mechanism::parse(mechanism)
            .ok_or_else(|| format!("unknown mechanism '{mechanism}'"))?;
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| format!("unknown session {session_id}"))?;
        // Key the engine by the *canonical* mechanism name so routing
        // agrees with registration no matter which alias was used.
        let key = EnginePath::Encrypted { session: session_id, mechanism: mech.name().into() }
            .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| match mech {
            crate::attention::Mechanism::DotProduct => {
                DotProductFhe::new(dim, 2).plan_for(ctx, seq_len, dim)
            }
            crate::attention::Mechanism::Inhibitor => {
                InhibitorFhe::new(dim, 1).plan_for(ctx, seq_len, dim)
            }
            crate::attention::Mechanism::InhibitorSigned => {
                InhibitorSignedFhe::new(dim, 1).plan_for(ctx, seq_len, dim)
            }
        });
        Ok(())
    }

    /// Register an encrypted **multi-head** engine for a session: H
    /// heads of the mechanism fused into one combined `CircuitPlan`
    /// (`fhe_circuits::MultiHeadFhe`), so the rewrite passes optimize
    /// across heads and the fused level executor sees H× the jobs per
    /// level. The engine key carries the head configuration
    /// (`<mechanism>@h<H>[s]`, see `MultiHeadFhe::engine_mechanism`),
    /// keeping it distinct from the session's single-head engines.
    /// Request bundles hold the plan's inputs in `MultiHeadFhe::plan`
    /// layout: per-head `q_h ‖ k_h ‖ v_h` segments, or all Q segments
    /// then one shared K/V pair when `shared_kv` (multi-query) is on.
    #[allow(clippy::too_many_arguments)]
    pub fn add_fhe_multihead_engine(
        &mut self,
        session_id: u64,
        mechanism: &str,
        seq_len: usize,
        d_head: usize,
        n_heads: usize,
        shared_kv: bool,
        policy: BatchPolicy,
    ) -> Result<(), String> {
        let mech = crate::attention::Mechanism::parse(mechanism)
            .ok_or_else(|| format!("unknown mechanism '{mechanism}'"))?;
        if n_heads == 0 {
            return Err("n_heads must be at least 1".into());
        }
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| format!("unknown session {session_id}"))?;
        let head = MultiHeadFhe::new(mech, d_head, n_heads, shared_kv);
        let key = EnginePath::Encrypted { session: session_id, mechanism: head.engine_mechanism() }
            .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| {
            head.plan_for(ctx, seq_len, d_head)
        });
        Ok(())
    }

    /// Register an encrypted **transformer-block** engine for a session:
    /// the full L-layer block stack (`fhe_circuits::ModelFhe` — fused
    /// multi-head attention, W_O projection, residual adds, requant PBS
    /// and the two-layer ReLU FFN per layer) served as ONE circuit plan,
    /// so the rewrite passes optimize across heads *and* layers and the
    /// fused level executor drives the whole model level-by-level.
    /// The engine key carries the full configuration
    /// (`block/<mechanism>@h<H>xL<L>[s]`, see
    /// `ModelFhe::engine_mechanism`). Request bundles hold the `[T, D]`
    /// residual-stream grid row-major (`ModelFhe::input_refs`); the
    /// result bundle is the output stream in the same layout, returned
    /// as a typed `result_blob` reference.
    pub fn add_fhe_block_engine(
        &mut self,
        session_id: u64,
        model: ModelFhe,
        seq_len: usize,
        policy: BatchPolicy,
    ) -> Result<(), String> {
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| format!("unknown session {session_id}"))?;
        let key = EnginePath::Encrypted {
            session: session_id,
            mechanism: model.engine_mechanism(),
        }
        .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| {
            model.plan_for(ctx, seq_len)
        });
        Ok(())
    }

    /// Shared registration body of every encrypted engine: grants the
    /// session the scheduler's PBS worker budget, resolves the
    /// (rewritten, cached) plan once on the engine's worker thread, and
    /// executes each batch through [`FusedLevelExecutor`] — the current
    /// PBS level of all co-scheduled requests goes to the worker pool as
    /// one fused `pbs_batch`. Fusion never changes results or counts —
    /// outputs are bit-identical to single-request execution (pinned by
    /// `tests/fusion_it.rs` and `tests/multihead_it.rs`).
    fn add_encrypted_engine(
        &mut self,
        key: &str,
        session: Arc<Session>,
        policy: BatchPolicy,
        make_plan: impl FnOnce(&crate::tfhe::FheContext) -> Arc<CircuitPlan> + Send + 'static,
    ) {
        // Grant this session's context the scheduler's PBS worker budget:
        // the fused level batches fan out across it.
        session.ctx.set_threads(self.scheduler.fhe_threads());
        let metrics = Arc::clone(&self.scheduler.metrics);
        self.scheduler.add_engine(
            key,
            policy,
            Box::new(move || {
                // The worker holds the engine's *rewritten* plan (CSE +
                // multi-value packing at the session's parameter budget),
                // cached on the head: the serving path executes the same
                // reduced-rotation IR the benches and the profile report.
                let plan = make_plan(&session.ctx);
                let n_inputs = plan.n_inputs();
                Box::new(move |batch: &[InferRequest]| {
                    // Phase 1 — resolve every request's ciphertext bundle.
                    // Any bad request fails the whole batch (matching the
                    // scheduler's per-batch error propagation), but the
                    // bundles already taken are restored so the innocent
                    // co-batched requests can be resubmitted.
                    let mut bundles: Vec<(u64, Vec<_>)> = Vec::with_capacity(batch.len());
                    let mut bad: Option<String> = None;
                    for req in batch {
                        let blob = match req.payload {
                            Payload::CiphertextRef(b) => b,
                            _ => {
                                bad = Some("fhe engine takes ciphertext refs".into());
                                break;
                            }
                        };
                        let cts = match session.take(blob) {
                            Some(cts) => cts,
                            None => {
                                bad = Some(format!("unknown ciphertext bundle {blob}"));
                                break;
                            }
                        };
                        if cts.len() != n_inputs {
                            bad = Some(format!(
                                "bundle must hold {} ciphertexts, got {}",
                                n_inputs,
                                cts.len()
                            ));
                            session.restore(blob, cts);
                            break;
                        }
                        bundles.push((blob, cts));
                    }
                    if let Some(msg) = bad {
                        for (blob, cts) in bundles {
                            session.restore(blob, cts);
                        }
                        return Err(msg);
                    }
                    // Phase 2 — fused level-synchronous execution across
                    // the whole batch.
                    let requests: Vec<(&CircuitPlan, &[_])> =
                        bundles.iter().map(|(_, b)| (plan.as_ref(), b.as_slice())).collect();
                    let (outs, stats) = FusedLevelExecutor::new(&session.ctx).run(&requests);
                    let levels = stats.level_batch_sizes.len() as u64;
                    metrics.fused_levels.fetch_add(levels, Ordering::Relaxed);
                    metrics.fused_pbs.fetch_add(stats.pbs_total, Ordering::Relaxed);
                    metrics
                        .fused_blind_rotations
                        .fetch_add(stats.blind_rotations, Ordering::Relaxed);
                    // Phase 3 — register each request's result bundle
                    // and return a *typed* reference. The id travels in
                    // the response's dedicated `result_blob` field, so —
                    // unlike the retired ride-along-as-f32 encoding — it
                    // is exact at any magnitude and needs no 2²⁴ guard.
                    let results: Vec<EngineOutput> = outs
                        .into_iter()
                        .map(|data| EngineOutput::ResultRef(session.put_result(data)))
                        .collect();
                    Ok(results)
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Route a logical float request per the policy.
    pub fn route_float(&self, model: &str, mechanism: &str) -> EnginePath {
        let quant = EnginePath::QuantInt(mechanism.into());
        let pjrt = EnginePath::Pjrt(model.into());
        let names = self.scheduler.engine_names();
        let have = |p: &EnginePath| names.iter().any(|n| n == &p.batch_key());
        match self.policy {
            RoutePolicy::PreferQuant if have(&quant) => quant,
            RoutePolicy::PreferPjrt if have(&pjrt) => pjrt,
            RoutePolicy::LeastLoaded if have(&quant) && have(&pjrt) => quant, // queue introspection below
            _ if have(&quant) => quant,
            _ => pjrt,
        }
    }

    /// Submit a request and get the response receiver.
    pub fn submit(&self, path: EnginePath, payload: Payload) -> Result<Receiver<InferResponse>, String> {
        self.scheduler.submit(InferRequest::new(0, path, payload))
    }

    /// Submit and block for the response.
    pub fn infer_blocking(
        &self,
        path: EnginePath,
        payload: Payload,
        timeout: std::time::Duration,
    ) -> Result<InferResponse, String> {
        let rx = self.submit(path, payload)?;
        rx.recv_timeout(timeout).map_err(|e| format!("response timeout: {e}"))
    }

    pub fn shutdown(&mut self) {
        self.scheduler.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mechanism;
    use crate::model::ModelConfig;
    use std::time::Duration;

    #[test]
    fn quant_engine_roundtrip() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
        let model = QTransformer::random(cfg, 3);
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        c.add_quant_engine("inhibitor", model, BatchPolicy::default());
        let path = c.route_float("model_inhibitor", "inhibitor");
        assert_eq!(path, EnginePath::QuantInt("inhibitor".into()));
        let resp = c
            .infer_blocking(
                path,
                Payload::Features(vec![0.1; 8 * 16], (8, 16)),
                Duration::from_secs(10),
            )
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.output.len(), 1); // regression head
    }

    #[test]
    fn routing_falls_back_to_available_engine() {
        let cfg = ModelConfig::small(Mechanism::DotProduct, 4, 8);
        let model = QTransformer::random(cfg, 1);
        let mut c = Coordinator::new(RoutePolicy::PreferPjrt);
        c.add_quant_engine("dotprod", model, BatchPolicy::default());
        // PJRT engine absent → falls back to quant.
        let path = c.route_float("model_dotprod", "dotprod");
        assert_eq!(path, EnginePath::QuantInt("dotprod".into()));
    }

    #[test]
    fn fhe_engine_requires_session() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        let err = c.add_fhe_engine(99, "inhibitor", 2, 2, BatchPolicy::default()).unwrap_err();
        assert!(err.contains("unknown session"), "{err}");
    }

    #[test]
    fn fhe_engine_rejects_unknown_mechanism_and_accepts_all_circuits() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        // Mechanism checks run before session resolution.
        let err = c.add_fhe_engine(1, "nonsense", 2, 2, BatchPolicy::default()).unwrap_err();
        assert!(err.contains("unknown mechanism"), "{err}");
        // Every named mechanism now has an encrypted circuit (the signed
        // inhibitor landed with the rewrite passes): each must get past
        // the mechanism check and fail only on the missing session.
        for mech in ["inhibitor-signed", "softmax", "inhibitor"] {
            let err = c.add_fhe_engine(1, mech, 2, 2, BatchPolicy::default()).unwrap_err();
            assert!(err.contains("unknown session"), "{mech}: {err}");
        }
    }

    #[test]
    fn multihead_engine_registration_checks() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        // Mechanism and head-count checks run before session resolution.
        let err = c
            .add_fhe_multihead_engine(1, "nonsense", 2, 2, 2, false, BatchPolicy::default())
            .unwrap_err();
        assert!(err.contains("unknown mechanism"), "{err}");
        let err = c
            .add_fhe_multihead_engine(1, "inhibitor", 2, 2, 0, false, BatchPolicy::default())
            .unwrap_err();
        assert!(err.contains("n_heads"), "{err}");
        for mech in ["inhibitor", "inhibitor-signed", "softmax"] {
            let err = c
                .add_fhe_multihead_engine(1, mech, 2, 2, 4, true, BatchPolicy::default())
                .unwrap_err();
            assert!(err.contains("unknown session"), "{mech}: {err}");
        }
    }

    #[test]
    fn block_engine_registration_requires_a_session() {
        use crate::fhe_circuits::ModelFhe;
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        let model = ModelFhe::demo(Mechanism::Inhibitor, 4, 2, 2, false, 4, 3);
        let err = c.add_fhe_block_engine(99, model, 2, BatchPolicy::default()).unwrap_err();
        assert!(err.contains("unknown session"), "{err}");
    }

    #[test]
    fn fhe_engine_applies_scheduler_thread_budget() {
        use crate::tfhe::{ClientKey, FheContext, TfheParams};
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(12);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        c.set_fhe_threads(3);
        let sid = c.keymgr.create_session(ctx);
        c.add_fhe_engine(sid, "inhibitor", 2, 2, BatchPolicy::default()).unwrap();
        assert_eq!(
            c.keymgr.session(sid).unwrap().ctx.threads(),
            3,
            "registering the engine must grant the session the scheduler's PBS budget"
        );
    }
}
