//! Router + coordinator facade (S9): the entry point the server and the
//! examples use. Owns the scheduler, the key manager, and the routing
//! policy that picks an engine for each logical request.
//!
//! Engines:
//!   * `quant/<mechanism>` — the plaintext quantized integer transformer.
//!   * `pjrt/<model>`      — the AOT float model (engine is constructed
//!     lazily *inside* its worker thread: PJRT handles never cross
//!     threads).
//!   * `fhe/<mech>/<sid>`  — per-session encrypted attention.
//!   * `fhe/decode/<mech>@h<H>xL<L>/<sid>` — per-session incremental
//!     decode over session-persistent encrypted KV-cache bundles
//!     (PR 7: per-token step plans, prefill, restore-on-abandon).
//!
//! Every fallible edge speaks [`FheError`] (PR 6): registration,
//! submission, and each engine body's per-request results. Engine
//! factories are re-invokable — the scheduler respawns a crashed body
//! from its factory — so registration closures capture only state that
//! can be reused (`Arc`s, configs) and rebuild the rest per spawn.

use super::batcher::BatchPolicy;
use super::fused::{FusedLevelExecutor, FusedRequest};
use super::keymgr::{KeyManager, Session};
use super::request::{EngineOutput, EnginePath, InferRequest, InferResponse, Payload};
use super::scheduler::Scheduler;
use super::session_store::{CacheEntry, SessionStore, DEFAULT_CACHE_CAP};
use super::storage::{BlobSink, CtStore, DiskSink, MemorySink, DEFAULT_STORAGE_BUDGET};
use crate::error::FheError;
use crate::fhe_circuits::{
    DecodeFhe, DotProductFhe, InhibitorFhe, InhibitorSignedFhe, ModelFhe, MultiHeadFhe,
};
use crate::model::{ModelInput, QTransformer};
use crate::tensor::ITensor;
use crate::tfhe::ops::CtInt;
use crate::tfhe::plan::CircuitPlan;
#[cfg(feature = "xla")]
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// Routing preference for float requests that both clear engines can run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always the quantized integer engine.
    PreferQuant,
    /// Always the PJRT float engine.
    PreferPjrt,
    /// Pick the engine with the shorter queue.
    LeastLoaded,
}

/// The coordinator facade.
pub struct Coordinator {
    scheduler: Scheduler,
    pub keymgr: Arc<KeyManager>,
    pub policy: RoutePolicy,
    /// Session-persistent decode cache bundles (`(session, stream)` →
    /// encrypted KV-cache), shared by every decode engine.
    session_store: Arc<SessionStore>,
}

impl Coordinator {
    /// Build with storage wiring from the environment: `FHE_STORAGE_DIR`
    /// selects a [`DiskSink`] root for cold bundles (default: in-memory
    /// sink), `FHE_STORAGE_BUDGET` the hot-tier byte budget (`0` spills
    /// every bundle — the CI tiny-budget leg).
    pub fn new(policy: RoutePolicy) -> Self {
        let sink: Arc<dyn BlobSink> = match std::env::var("FHE_STORAGE_DIR") {
            Ok(dir) if !dir.is_empty() => match DiskSink::new(&dir) {
                Ok(s) => Arc::new(s),
                Err(e) => {
                    eprintln!("FHE_STORAGE_DIR={dir} unusable ({e}); using in-memory sink");
                    Arc::new(MemorySink::new())
                }
            },
            _ => Arc::new(MemorySink::new()),
        };
        let budget = std::env::var("FHE_STORAGE_BUDGET")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(DEFAULT_STORAGE_BUDGET);
        Self::with_storage(policy, sink, budget)
    }

    /// Build over an explicit blob sink and hot-tier byte budget. Both
    /// stores — the key manager's result blobs (`"blob"` namespace) and
    /// the decode cache (`"cache"`) — share the sink and the scheduler's
    /// storage metrics, so eviction/rehydration counters and teardown go
    /// through one accounting path. Tests use this to force spill
    /// through a `DiskSink` without racing on process-global env vars.
    pub fn with_storage(policy: RoutePolicy, sink: Arc<dyn BlobSink>, budget: u64) -> Self {
        let scheduler = Scheduler::new();
        let sm = Arc::clone(&scheduler.metrics.storage);
        let blob_tier = Arc::new(CtStore::new("blob", Arc::clone(&sink), Arc::clone(&sm), budget));
        let cache_tier = Arc::new(CtStore::new("cache", sink, sm, budget));
        Coordinator {
            keymgr: Arc::new(KeyManager::with_storage(blob_tier)),
            policy,
            session_store: Arc::new(SessionStore::with_store(DEFAULT_CACHE_CAP, cache_tier)),
            scheduler,
        }
    }

    pub fn metrics(&self) -> &super::metrics::Metrics {
        &self.scheduler.metrics
    }

    /// The decode cache-bundle store (cap knob, gauges).
    pub fn session_store(&self) -> &SessionStore {
        &self.session_store
    }

    /// Drop a decode stream's cache bundle (the `release_cache` wire
    /// op); `true` if one was live. Updates the cache gauges.
    pub fn release_cache(&self, session: u64, stream: u64) -> bool {
        let hit = self.session_store.release(session, stream);
        self.scheduler.metrics.refresh_cache_gauges(&self.session_store);
        hit
    }

    /// Tear a session down completely (the `drop_session` wire op): its
    /// key material (live or parked), every registered ciphertext
    /// bundle, and every decode cache bundle — hot, spilled, and sink
    /// bytes — with the cache gauges refreshed afterwards. `true` if the
    /// session existed. This is the satellite bugfix for the pre-S9
    /// leak where `KeyManager::drop_session` left the dropped session's
    /// cache bundles live forever.
    pub fn drop_session(&self, session: u64) -> bool {
        let existed = self.keymgr.drop_session(session);
        self.session_store.release_session(session);
        self.scheduler.metrics.refresh_cache_gauges(&self.session_store);
        existed
    }

    /// PBS worker threads granted to encrypted engines registered from
    /// here on (default: `FHE_THREADS` env or all cores).
    pub fn set_fhe_threads(&mut self, n: usize) {
        self.scheduler.set_fhe_threads(n);
    }

    /// Register a quantized integer model under `quant/<mechanism>`.
    pub fn add_quant_engine(&mut self, mechanism: &str, model: QTransformer, policy: BatchPolicy) {
        let key = EnginePath::QuantInt(mechanism.into()).batch_key();
        let model = Arc::new(model);
        self.scheduler.add_engine(
            &key,
            policy,
            Box::new(move || {
                let model = Arc::clone(&model);
                Box::new(move |batch: &[InferRequest]| {
                    Ok(batch
                        .iter()
                        .map(|req| match &req.payload {
                            Payload::Features(data, (r, c)) => {
                                let codes: Vec<i64> = data
                                    .iter()
                                    .map(|&x| (x / model.act_scale).round() as i64)
                                    .collect();
                                let t = ITensor::from_vec(&[*r, *c], codes);
                                let out = model.forward(&ModelInput::Features(t));
                                Ok(EngineOutput::Values(
                                    out.data.iter().map(|&c| c as f32 * model.act_scale).collect(),
                                ))
                            }
                            Payload::Tokens(toks) => {
                                let out = model.forward(&ModelInput::Tokens(toks.clone()));
                                Ok(EngineOutput::Values(
                                    out.data.iter().map(|&c| c as f32 * model.act_scale).collect(),
                                ))
                            }
                            Payload::CiphertextRef(_) => Err(FheError::BadRequest(
                                "ciphertext sent to a clear engine".to_string(),
                            )),
                        })
                        .collect())
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Register a PJRT model engine under `pjrt/<name>`. The artifact is
    /// compiled on first use inside the worker thread. Only available
    /// with the `xla` feature (the PJRT runtime needs the vendored `xla`
    /// crate).
    #[cfg(feature = "xla")]
    pub fn add_pjrt_model(&mut self, artifacts_dir: PathBuf, model_name: &str, policy: BatchPolicy) {
        let key = EnginePath::Pjrt(model_name.into()).batch_key();
        let name = model_name.to_string();
        self.scheduler.add_engine(
            &key,
            policy,
            Box::new(move || {
                // PJRT state is created here, on the worker thread, and
                // never crosses a thread boundary (xla handles are !Send).
                // A respawned body simply re-opens the registry.
                let artifacts_dir = artifacts_dir.clone();
                let name = name.clone();
                let mut registry: Option<crate::runtime::Registry> = None;
                Box::new(move |batch: &[InferRequest]| {
                    if registry.is_none() {
                        registry = Some(
                            crate::runtime::Registry::open(artifacts_dir.clone()).map_err(|e| {
                                FheError::Internal(format!("opening artifacts: {e:#}"))
                            })?,
                        );
                    }
                    let engine = registry
                        .as_mut()
                        .expect("registry populated above")
                        .model_engine(&name)
                        .map_err(|e| FheError::Internal(format!("loading model '{name}': {e:#}")))?;
                    Ok(batch
                        .iter()
                        .map(|req| match &req.payload {
                            Payload::Features(data, _shape) => engine
                                .run_f32(&[data.clone()])
                                .map(EngineOutput::Values)
                                .map_err(|e| FheError::Internal(format!("pjrt execute: {e:#}"))),
                            _ => Err(FheError::BadRequest(
                                "pjrt engine takes float features".to_string(),
                            )),
                        })
                        .collect())
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Register the encrypted attention engine for a session. Requests
    /// carry `Payload::CiphertextRef` pointing at a registered Q/K/V
    /// bundle (3·T·d ciphertexts); the result bundle id comes back as
    /// the response's typed `result_blob` reference.
    ///
    /// The worker builds the head's `CircuitPlan` once (the engine's
    /// mechanism and shape are fixed) and executes every batch through
    /// [`super::fused::FusedLevelExecutor`]: the current PBS level of all
    /// co-scheduled requests goes to the worker pool as one fused
    /// `pbs_batch`, so small-`T` requests fill the pool together. Fusion
    /// never changes results or PBS counts — outputs are bit-identical to
    /// single-request execution (pinned by `tests/fusion_it.rs`).
    pub fn add_fhe_engine(
        &mut self,
        session_id: u64,
        mechanism: &str,
        seq_len: usize,
        dim: usize,
        policy: BatchPolicy,
    ) -> Result<(), FheError> {
        // Same name resolution as every other entry point (CLI included):
        // aliases like "softmax" select the dot-product circuit.
        let mech = crate::attention::Mechanism::parse(mechanism)
            .ok_or_else(|| FheError::PlanInvalid(format!("unknown mechanism '{mechanism}'")))?;
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| FheError::KeyMissing(format!("unknown session {session_id}")))?;
        // Key the engine by the *canonical* mechanism name so routing
        // agrees with registration no matter which alias was used.
        let key = EnginePath::Encrypted { session: session_id, mechanism: mech.name().into() }
            .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| match mech {
            crate::attention::Mechanism::DotProduct => {
                DotProductFhe::new(dim, 2).plan_for(ctx, seq_len, dim)
            }
            crate::attention::Mechanism::Inhibitor => {
                InhibitorFhe::new(dim, 1).plan_for(ctx, seq_len, dim)
            }
            crate::attention::Mechanism::InhibitorSigned => {
                InhibitorSignedFhe::new(dim, 1).plan_for(ctx, seq_len, dim)
            }
        });
        Ok(())
    }

    /// Register an encrypted **multi-head** engine for a session: H
    /// heads of the mechanism fused into one combined `CircuitPlan`
    /// (`fhe_circuits::MultiHeadFhe`), so the rewrite passes optimize
    /// across heads and the fused level executor sees H× the jobs per
    /// level. The engine key carries the head configuration
    /// (`<mechanism>@h<H>[s]`, see `MultiHeadFhe::engine_mechanism`),
    /// keeping it distinct from the session's single-head engines.
    /// Request bundles hold the plan's inputs in `MultiHeadFhe::plan`
    /// layout: per-head `q_h ‖ k_h ‖ v_h` segments, or all Q segments
    /// then one shared K/V pair when `shared_kv` (multi-query) is on.
    #[allow(clippy::too_many_arguments)]
    pub fn add_fhe_multihead_engine(
        &mut self,
        session_id: u64,
        mechanism: &str,
        seq_len: usize,
        d_head: usize,
        n_heads: usize,
        shared_kv: bool,
        policy: BatchPolicy,
    ) -> Result<(), FheError> {
        let mech = crate::attention::Mechanism::parse(mechanism)
            .ok_or_else(|| FheError::PlanInvalid(format!("unknown mechanism '{mechanism}'")))?;
        if n_heads == 0 {
            return Err(FheError::PlanInvalid("n_heads must be at least 1".to_string()));
        }
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| FheError::KeyMissing(format!("unknown session {session_id}")))?;
        let head = MultiHeadFhe::new(mech, d_head, n_heads, shared_kv);
        let key = EnginePath::Encrypted { session: session_id, mechanism: head.engine_mechanism() }
            .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| {
            head.plan_for(ctx, seq_len, d_head)
        });
        Ok(())
    }

    /// Register an encrypted **transformer-block** engine for a session:
    /// the full L-layer block stack (`fhe_circuits::ModelFhe` — fused
    /// multi-head attention, W_O projection, residual adds, requant PBS
    /// and the two-layer ReLU FFN per layer) served as ONE circuit plan,
    /// so the rewrite passes optimize across heads *and* layers and the
    /// fused level executor drives the whole model level-by-level.
    /// The engine key carries the full configuration
    /// (`block/<mechanism>@h<H>xL<L>[s]`, see
    /// `ModelFhe::engine_mechanism`). Request bundles hold the `[T, D]`
    /// residual-stream grid row-major (`ModelFhe::input_refs`); the
    /// result bundle is the output stream in the same layout, returned
    /// as a typed `result_blob` reference.
    pub fn add_fhe_block_engine(
        &mut self,
        session_id: u64,
        model: ModelFhe,
        seq_len: usize,
        policy: BatchPolicy,
    ) -> Result<(), FheError> {
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| FheError::KeyMissing(format!("unknown session {session_id}")))?;
        let key = EnginePath::Encrypted {
            session: session_id,
            mechanism: model.engine_mechanism(),
        }
        .batch_key();
        self.add_encrypted_engine(&key, session, policy, move |ctx| {
            model.plan_for(ctx, seq_len)
        });
        Ok(())
    }

    /// Register the encrypted **incremental decode** engine for a
    /// session: the same L-layer model as [`Self::add_fhe_block_engine`]
    /// served autoregressively (`fhe_circuits::DecodeFhe`). A stream
    /// starts with one *prefill* request (`cache_ref: None`, bundle = the
    /// `[T, D]` input grid) which runs the causal prefill plan and
    /// deposits the stream's encrypted KV-cache bundle in the
    /// coordinator's [`SessionStore`] under `cache_out`. Every following
    /// *step* request (`cache_ref: Some(stream)`, bundle = one `[D]` row)
    /// consumes that bundle **by move**, runs the per-token step plan —
    /// O(t·d) work, the prefix is never recomputed — and deposits the
    /// successor bundle (under `cache_out`, defaulting to the same
    /// stream). The engine key carries the full configuration
    /// (`decode/<mechanism>@h<H>xL<L>[s]`, see
    /// `DecodeFhe::engine_mechanism`); result rows come back as typed
    /// `result_blob` references like every encrypted engine.
    ///
    /// Abandonment contract: on any member failure (bad request,
    /// deadline, quarantined PBS job, cache-cap overflow) the member's
    /// input bundle AND the stream's *pre-step* cache bundle are
    /// restored, so a resubmit replays the exact same step
    /// (`tests/decode_it.rs`, `tests/faults_it.rs`).
    pub fn add_fhe_decode_engine(
        &mut self,
        session_id: u64,
        model: ModelFhe,
        policy: BatchPolicy,
    ) -> Result<(), FheError> {
        let session = self
            .keymgr
            .session(session_id)
            .ok_or_else(|| FheError::KeyMissing(format!("unknown session {session_id}")))?;
        let decode = DecodeFhe::new(model);
        let key = EnginePath::Encrypted {
            session: session_id,
            mechanism: decode.engine_mechanism(),
        }
        .batch_key();
        session.ctx.set_threads(self.scheduler.fhe_threads());
        let metrics = Arc::clone(&self.scheduler.metrics);
        let store = Arc::clone(&self.session_store);
        self.scheduler.add_engine(
            &key,
            policy,
            Box::new(move || {
                let session = Arc::clone(&session);
                let metrics = Arc::clone(&metrics);
                let store = Arc::clone(&store);
                let decode = decode.clone();
                let dm = decode.d_model();
                Box::new(move |batch: &[InferRequest]| {
                    // What phase 1 resolved for one member, plus how to
                    // undo its takes if the step is abandoned.
                    enum Kind {
                        Prefill { t: usize, out_stream: u64 },
                        Step { cached_len: usize, stream: u64, out_stream: u64 },
                    }
                    struct Member {
                        blob: u64,
                        /// Step: row ‖ pre-step cache; prefill: the grid.
                        inputs: Vec<CtInt>,
                        plan: Arc<CircuitPlan>,
                        kind: Kind,
                    }
                    // Deterministic fault seam (`panic@engine:N`), fired
                    // before any bundle is taken.
                    if let Some(f) = session.ctx.fault_plan() {
                        f.maybe_panic_engine();
                    }
                    // Phase 1 — resolve each member's input bundle and,
                    // for steps, take the stream's cache bundle by move
                    // and pick the step plan for its prefix length.
                    let members: Vec<Result<Member, FheError>> = batch
                        .iter()
                        .map(|req| {
                            let blob = match req.payload {
                                Payload::CiphertextRef(b) => b,
                                _ => {
                                    return Err(FheError::BadRequest(
                                        "decode engine takes ciphertext refs".to_string(),
                                    ))
                                }
                            };
                            let cts = session.try_take(blob)?.ok_or_else(|| {
                                FheError::KeyMissing(format!("unknown ciphertext bundle {blob}"))
                            })?;
                            match req.cache_ref {
                                None => {
                                    let Some(out_stream) = req.cache_out else {
                                        session.restore(blob, cts);
                                        return Err(FheError::BadRequest(
                                            "prefill must name cache_out (the stream id)"
                                                .to_string(),
                                        ));
                                    };
                                    if cts.is_empty() || cts.len() % dm != 0 {
                                        let msg = format!(
                                            "prefill bundle must be a non-empty [T, {dm}] grid, \
                                             got {} ciphertexts",
                                            cts.len()
                                        );
                                        session.restore(blob, cts);
                                        return Err(FheError::BadRequest(msg));
                                    }
                                    let t = cts.len() / dm;
                                    let plan = decode.prefill_plan_for(&session.ctx, t);
                                    Ok(Member {
                                        blob,
                                        inputs: cts,
                                        plan,
                                        kind: Kind::Prefill { t, out_stream },
                                    })
                                }
                                Some(stream) => {
                                    if cts.len() != dm {
                                        let msg = format!(
                                            "step bundle must be one [{dm}] row, got {} \
                                             ciphertexts",
                                            cts.len()
                                        );
                                        session.restore(blob, cts);
                                        return Err(FheError::BadRequest(msg));
                                    }
                                    let entry = match store.try_take(session_id, stream) {
                                        Ok(Some(entry)) => entry,
                                        Ok(None) => {
                                            session.restore(blob, cts);
                                            return Err(FheError::KeyMissing(format!(
                                                "no live cache bundle for stream {stream}"
                                            )));
                                        }
                                        Err(e) => {
                                            // Storage-tier failure (lost or
                                            // corrupt spilled bytes): typed,
                                            // and the row stays resubmittable.
                                            session.restore(blob, cts);
                                            return Err(e);
                                        }
                                    };
                                    if entry.cts.len() != decode.cache_len(entry.cached_len) {
                                        let msg = format!(
                                            "stream {stream} cache holds {} ciphertexts, want {}",
                                            entry.cts.len(),
                                            decode.cache_len(entry.cached_len)
                                        );
                                        session.restore(blob, cts);
                                        store.restore(session_id, stream, entry);
                                        return Err(FheError::Internal(msg));
                                    }
                                    let cached_len = entry.cached_len;
                                    let plan = decode.step_plan_for(&session.ctx, cached_len);
                                    // Thread the cache into the plan by
                                    // move: row ‖ cache, executed by ref —
                                    // no ciphertext is ever cloned.
                                    let mut inputs = cts;
                                    inputs.extend(entry.cts);
                                    let out_stream = req.cache_out.unwrap_or(stream);
                                    Ok(Member {
                                        blob,
                                        inputs,
                                        plan,
                                        kind: Kind::Step { cached_len, stream, out_stream },
                                    })
                                }
                            }
                        })
                        .collect();
                    // Phase 2 — fused level-synchronous execution. Steps
                    // at different prefix lengths and prefills co-batch:
                    // the executor handles heterogeneous plans/depths.
                    let fused: Vec<FusedRequest> = members
                        .iter()
                        .zip(batch)
                        .filter_map(|(m, req)| {
                            m.as_ref().ok().map(|m| FusedRequest {
                                plan: m.plan.as_ref(),
                                inputs: m.inputs.as_slice(),
                                deadline: req.deadline,
                                cancel: Some(req.cancel.clone()),
                                ctx: None,
                            })
                        })
                        .collect();
                    let (outs, stats) = FusedLevelExecutor::new(&session.ctx).run_checked(&fused);
                    drop(fused);
                    metrics.record_fused(&stats);
                    for m in members.iter().flatten() {
                        if let Some(r) = m.plan.radix() {
                            metrics.record_radix(r);
                        }
                    }
                    // Phase 3 — deposit successor cache bundles and typed
                    // result refs, or restore the pre-step world exactly.
                    let mut outs = outs.into_iter();
                    let results: Vec<Result<EngineOutput, FheError>> = members
                        .into_iter()
                        .map(|m| {
                            let Member { blob, mut inputs, plan: _, kind } = m?;
                            match outs.next().expect("one executor result per fused member") {
                                Err(e) => {
                                    match kind {
                                        Kind::Prefill { .. } => session.restore(blob, inputs),
                                        Kind::Step { cached_len, stream, .. } => {
                                            let cache_old = inputs.split_off(dm);
                                            session.restore(blob, inputs);
                                            store.restore(
                                                session_id,
                                                stream,
                                                CacheEntry { cts: cache_old, cached_len },
                                            );
                                        }
                                    }
                                    Err(e)
                                }
                                Ok(data) => match kind {
                                    Kind::Prefill { t, out_stream } => {
                                        let (out, cache) = decode.cache_from_prefill(t, data);
                                        match store.put(session_id, out_stream, cache, t) {
                                            Ok(()) => match session.put_result(out) {
                                                Ok(rid) => Ok(EngineOutput::ResultRef(rid)),
                                                Err(e) => {
                                                    // Blob cap: roll the fresh
                                                    // cache deposit back too so
                                                    // the prefill replays clean.
                                                    store.release(session_id, out_stream);
                                                    session.restore(blob, inputs);
                                                    Err(e)
                                                }
                                            },
                                            Err(e) => {
                                                session.restore(blob, inputs);
                                                Err(e)
                                            }
                                        }
                                    }
                                    Kind::Step { cached_len, stream, out_stream } => {
                                        let cache_old = inputs.split_off(dm);
                                        // Reserve the output cache slot and
                                        // the result blob id first (atomic
                                        // cap checks): on either overflow
                                        // the pre-step cache is still in
                                        // one piece to restore.
                                        if let Err(e) =
                                            store.put(session_id, out_stream, Vec::new(), 0)
                                        {
                                            session.restore(blob, inputs);
                                            store.restore(
                                                session_id,
                                                stream,
                                                CacheEntry { cts: cache_old, cached_len },
                                            );
                                            return Err(e);
                                        }
                                        let rid = match session.put_result(Vec::new()) {
                                            Ok(rid) => rid,
                                            Err(e) => {
                                                store.release(session_id, out_stream);
                                                session.restore(blob, inputs);
                                                store.restore(
                                                    session_id,
                                                    stream,
                                                    CacheEntry { cts: cache_old, cached_len },
                                                );
                                                return Err(e);
                                            }
                                        };
                                        let (out_row, cache_new) =
                                            decode.cache_after_step(cached_len, cache_old, data);
                                        store.restore(
                                            session_id,
                                            out_stream,
                                            CacheEntry {
                                                cts: cache_new,
                                                cached_len: cached_len + 1,
                                            },
                                        );
                                        // Fill the reserved result id with
                                        // the actual row (restore = replace
                                        // under an existing id, never
                                        // cap-checked).
                                        session.restore(rid, out_row);
                                        metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
                                        Ok(EngineOutput::ResultRef(rid))
                                    }
                                },
                            }
                        })
                        .collect();
                    metrics.refresh_cache_gauges(&store);
                    Ok(results)
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
        Ok(())
    }

    /// Shared registration body of every encrypted engine: grants the
    /// session the scheduler's PBS worker budget, resolves the
    /// (rewritten, cached) plan on the engine's worker thread, and
    /// executes each batch through [`FusedLevelExecutor::run_checked`] —
    /// the current PBS level of all co-scheduled requests goes to the
    /// panic-isolated worker pool as one fused `pbs_batch`. Fusion never
    /// changes results or counts — outputs are bit-identical to
    /// single-request execution (pinned by `tests/fusion_it.rs` and
    /// `tests/multihead_it.rs`).
    ///
    /// Failure model per member: a bad bundle fails only its own request
    /// (typed error); a poisoned PBS job quarantines only the member
    /// that owns it; a deadline or cancellation abandons the member at
    /// the next level boundary. On any member failure its input bundle
    /// is restored, so the client can resubmit without re-uploading.
    /// `make_plan` is a `Fn`: the scheduler respawns a crashed engine
    /// body from the factory, which re-resolves the (cached) plan.
    fn add_encrypted_engine(
        &mut self,
        key: &str,
        session: Arc<Session>,
        policy: BatchPolicy,
        make_plan: impl Fn(&crate::tfhe::FheContext) -> Arc<CircuitPlan> + Send + 'static,
    ) {
        // Grant this session's context the scheduler's PBS worker budget:
        // the fused level batches fan out across it.
        session.ctx.set_threads(self.scheduler.fhe_threads());
        let metrics = Arc::clone(&self.scheduler.metrics);
        self.scheduler.add_engine(
            key,
            policy,
            Box::new(move || {
                // The worker holds the engine's *rewritten* plan (CSE +
                // multi-value packing at the session's parameter budget),
                // cached on the head: the serving path executes the same
                // reduced-rotation IR the benches and the profile report.
                let session = Arc::clone(&session);
                let metrics = Arc::clone(&metrics);
                let plan = make_plan(&session.ctx);
                if let Some(r) = plan.radix() {
                    metrics.record_radix(r);
                }
                let n_inputs = plan.n_inputs();
                Box::new(move |batch: &[InferRequest]| {
                    // Deterministic fault seam (`panic@engine:N`): fires
                    // before any bundle is taken, so the scheduler's
                    // respawn + solo replay sees intact session state.
                    if let Some(f) = session.ctx.fault_plan() {
                        f.maybe_panic_engine();
                    }
                    // Phase 1 — resolve each request's ciphertext bundle.
                    // A bad request fails only itself; its co-batched
                    // neighbors proceed.
                    let bundles: Vec<Result<(u64, Vec<CtInt>), FheError>> = batch
                        .iter()
                        .map(|req| {
                            let blob = match req.payload {
                                Payload::CiphertextRef(b) => b,
                                _ => {
                                    return Err(FheError::BadRequest(
                                        "fhe engine takes ciphertext refs".to_string(),
                                    ))
                                }
                            };
                            let cts = session.try_take(blob)?.ok_or_else(|| {
                                FheError::KeyMissing(format!("unknown ciphertext bundle {blob}"))
                            })?;
                            if cts.len() != n_inputs {
                                let msg = format!(
                                    "bundle must hold {} ciphertexts, got {}",
                                    n_inputs,
                                    cts.len()
                                );
                                session.restore(blob, cts);
                                return Err(FheError::BadRequest(msg));
                            }
                            Ok((blob, cts))
                        })
                        .collect();
                    // Phase 2 — fused level-synchronous execution of the
                    // members that resolved, carrying each request's
                    // deadline and cancellation token into the
                    // executor's level-boundary checks.
                    let fused: Vec<FusedRequest> = bundles
                        .iter()
                        .zip(batch)
                        .filter_map(|(b, req)| {
                            b.as_ref().ok().map(|(_, cts)| FusedRequest {
                                plan: plan.as_ref(),
                                inputs: cts.as_slice(),
                                deadline: req.deadline,
                                cancel: Some(req.cancel.clone()),
                                ctx: None,
                            })
                        })
                        .collect();
                    let (outs, stats) = FusedLevelExecutor::new(&session.ctx).run_checked(&fused);
                    // `fused` borrows the bundles consumed below.
                    drop(fused);
                    metrics.record_fused(&stats);
                    // Phase 3 — marry executor results back to the batch
                    // order. Success registers the result bundle and
                    // returns a *typed* reference (exact at any
                    // magnitude — no 2²⁴ f32 guard). Failure restores
                    // the member's input bundle for a clean resubmit.
                    let mut outs = outs.into_iter();
                    Ok(bundles
                        .into_iter()
                        .map(|b| {
                            let (blob, cts) = b?;
                            match outs.next().expect("one executor result per fused member") {
                                Ok(data) => match session.put_result(data) {
                                    Ok(rid) => Ok(EngineOutput::ResultRef(rid)),
                                    Err(e) => {
                                        session.restore(blob, cts);
                                        Err(e)
                                    }
                                },
                                Err(e) => {
                                    session.restore(blob, cts);
                                    Err(e)
                                }
                            }
                        })
                        .collect())
                }) as crate::coordinator::scheduler::EngineBody
            }),
        );
    }

    /// Route a logical float request per the policy.
    pub fn route_float(&self, model: &str, mechanism: &str) -> EnginePath {
        let quant = EnginePath::QuantInt(mechanism.into());
        let pjrt = EnginePath::Pjrt(model.into());
        let names = self.scheduler.engine_names();
        let have = |p: &EnginePath| names.iter().any(|n| n == &p.batch_key());
        match self.policy {
            RoutePolicy::PreferQuant if have(&quant) => quant,
            RoutePolicy::PreferPjrt if have(&pjrt) => pjrt,
            RoutePolicy::LeastLoaded if have(&quant) && have(&pjrt) => quant, // queue introspection below
            _ if have(&quant) => quant,
            _ => pjrt,
        }
    }

    /// Submit a request and get the response receiver.
    pub fn submit(
        &self,
        path: EnginePath,
        payload: Payload,
    ) -> Result<Receiver<InferResponse>, FheError> {
        self.scheduler.submit(InferRequest::new(0, path, payload))
    }

    /// Submit a fully-formed request (deadline/cancel token attached).
    pub fn submit_request(&self, req: InferRequest) -> Result<Receiver<InferResponse>, FheError> {
        self.scheduler.submit(req)
    }

    /// Submit and block for the response.
    pub fn infer_blocking(
        &self,
        path: EnginePath,
        payload: Payload,
        timeout: std::time::Duration,
    ) -> Result<InferResponse, FheError> {
        self.infer_request_blocking(InferRequest::new(0, path, payload), timeout)
    }

    /// [`Self::infer_blocking`] for a fully-formed request.
    pub fn infer_request_blocking(
        &self,
        req: InferRequest,
        timeout: std::time::Duration,
    ) -> Result<InferResponse, FheError> {
        let rx = self.submit_request(req)?;
        rx.recv_timeout(timeout)
            .map_err(|e| FheError::DeadlineExceeded(format!("response timeout: {e}")))
    }

    /// Graceful shutdown: queued work drains, receivers never hang.
    pub fn shutdown(&mut self) {
        self.scheduler.shutdown();
    }

    /// Immediate shutdown: queued (not yet running) requests fail with
    /// a typed `Shutdown` error instead of executing.
    pub fn shutdown_now(&mut self) {
        self.scheduler.shutdown_now();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::attention::Mechanism;
    use crate::model::ModelConfig;
    use std::time::Duration;

    #[test]
    fn quant_engine_roundtrip() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
        let model = QTransformer::random(cfg, 3);
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        c.add_quant_engine("inhibitor", model, BatchPolicy::default());
        let path = c.route_float("model_inhibitor", "inhibitor");
        assert_eq!(path, EnginePath::QuantInt("inhibitor".into()));
        let resp = c
            .infer_blocking(
                path,
                Payload::Features(vec![0.1; 8 * 16], (8, 16)),
                Duration::from_secs(10),
            )
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.output.len(), 1); // regression head
    }

    #[test]
    fn routing_falls_back_to_available_engine() {
        let cfg = ModelConfig::small(Mechanism::DotProduct, 4, 8);
        let model = QTransformer::random(cfg, 1);
        let mut c = Coordinator::new(RoutePolicy::PreferPjrt);
        c.add_quant_engine("dotprod", model, BatchPolicy::default());
        // PJRT engine absent → falls back to quant.
        let path = c.route_float("model_dotprod", "dotprod");
        assert_eq!(path, EnginePath::QuantInt("dotprod".into()));
    }

    #[test]
    fn clear_engine_rejects_ciphertext_payload_per_request() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 4, 8);
        let model = QTransformer::random(cfg, 1);
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        c.add_quant_engine("inhibitor", model, BatchPolicy::default());
        let resp = c
            .infer_blocking(
                EnginePath::QuantInt("inhibitor".into()),
                Payload::CiphertextRef(7),
                Duration::from_secs(10),
            )
            .unwrap();
        match resp.error {
            Some(FheError::BadRequest(ref m)) => assert!(m.contains("clear engine"), "{m}"),
            ref other => panic!("want BadRequest, got {other:?}"),
        }
    }

    #[test]
    fn fhe_engine_requires_session() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        let err = c.add_fhe_engine(99, "inhibitor", 2, 2, BatchPolicy::default()).unwrap_err();
        assert!(matches!(err, FheError::KeyMissing(_)), "{err:?}");
        assert!(err.to_string().contains("unknown session"), "{err}");
    }

    #[test]
    fn fhe_engine_rejects_unknown_mechanism_and_accepts_all_circuits() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        // Mechanism checks run before session resolution.
        let err = c.add_fhe_engine(1, "nonsense", 2, 2, BatchPolicy::default()).unwrap_err();
        assert!(matches!(err, FheError::PlanInvalid(_)), "{err:?}");
        assert!(err.to_string().contains("unknown mechanism"), "{err}");
        // Every named mechanism now has an encrypted circuit (the signed
        // inhibitor landed with the rewrite passes): each must get past
        // the mechanism check and fail only on the missing session.
        for mech in ["inhibitor-signed", "softmax", "inhibitor"] {
            let err = c.add_fhe_engine(1, mech, 2, 2, BatchPolicy::default()).unwrap_err();
            assert!(err.to_string().contains("unknown session"), "{mech}: {err}");
        }
    }

    #[test]
    fn multihead_engine_registration_checks() {
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        // Mechanism and head-count checks run before session resolution.
        let err = c
            .add_fhe_multihead_engine(1, "nonsense", 2, 2, 2, false, BatchPolicy::default())
            .unwrap_err();
        assert!(err.to_string().contains("unknown mechanism"), "{err}");
        let err = c
            .add_fhe_multihead_engine(1, "inhibitor", 2, 2, 0, false, BatchPolicy::default())
            .unwrap_err();
        assert!(err.to_string().contains("n_heads"), "{err}");
        for mech in ["inhibitor", "inhibitor-signed", "softmax"] {
            let err = c
                .add_fhe_multihead_engine(1, mech, 2, 2, 4, true, BatchPolicy::default())
                .unwrap_err();
            assert!(err.to_string().contains("unknown session"), "{mech}: {err}");
        }
    }

    #[test]
    fn block_engine_registration_requires_a_session() {
        use crate::fhe_circuits::ModelFhe;
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        let model = ModelFhe::demo(Mechanism::Inhibitor, 4, 2, 2, false, 4, 3);
        let err = c.add_fhe_block_engine(99, model, 2, BatchPolicy::default()).unwrap_err();
        assert_eq!(err.code(), "key_missing");
        assert!(err.to_string().contains("unknown session"), "{err}");
    }

    #[test]
    fn decode_engine_registration_requires_a_session() {
        use crate::fhe_circuits::ModelFhe;
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        let model = ModelFhe::demo(Mechanism::Inhibitor, 4, 2, 2, false, 4, 3);
        let err = c.add_fhe_decode_engine(99, model, BatchPolicy::default()).unwrap_err();
        assert_eq!(err.code(), "key_missing");
        assert!(err.to_string().contains("unknown session"), "{err}");
    }

    #[test]
    fn release_cache_reports_liveness_and_updates_gauges() {
        let c = Coordinator::new(RoutePolicy::PreferQuant);
        assert!(!c.release_cache(1, 1), "nothing live yet");
        c.session_store().put(1, 1, Vec::new(), 0).unwrap();
        assert!(c.release_cache(1, 1));
        assert_eq!(c.metrics().cache_blobs_live.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics().cache_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn drop_session_clears_cache_state_and_gauges() {
        use crate::tfhe::{ClientKey, FheContext, TfheParams};
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(41);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let c = Coordinator::new(RoutePolicy::PreferQuant);
        let sid = c.keymgr.create_session(ctx);
        let sess = c.keymgr.session(sid).expect("live session");
        let cts: Vec<_> = (0..3i64).map(|i| sess.ctx.encrypt(i - 1, &ck, &mut rng)).collect();
        sess.register(cts.clone());
        c.session_store().put(sid, 1, cts.clone(), 1).unwrap();
        c.session_store().put(sid, 2, cts, 2).unwrap();
        c.metrics().refresh_cache_gauges(c.session_store());
        assert_eq!(c.metrics().cache_blobs_live.load(Ordering::Relaxed), 2);
        assert!(c.metrics().cache_bytes.load(Ordering::Relaxed) > 0);
        drop(sess);
        assert!(c.drop_session(sid), "session was live");
        assert_eq!(c.session_store().live_blobs(), 0, "decode cache bundles released");
        assert_eq!(c.session_store().live_bytes(), 0);
        assert_eq!(c.keymgr.storage().live_blobs(), 0, "result blobs released");
        assert_eq!(
            c.metrics().cache_blobs_live.load(Ordering::Relaxed),
            0,
            "teardown refreshes the gauges"
        );
        assert_eq!(c.metrics().cache_bytes.load(Ordering::Relaxed), 0);
        assert!(!c.drop_session(sid), "second teardown is a no-op");
    }

    #[test]
    fn fhe_engine_applies_scheduler_thread_budget() {
        use crate::tfhe::{ClientKey, FheContext, TfheParams};
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(12);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let mut c = Coordinator::new(RoutePolicy::PreferQuant);
        c.set_fhe_threads(3);
        let sid = c.keymgr.create_session(ctx);
        c.add_fhe_engine(sid, "inhibitor", 2, 2, BatchPolicy::default()).unwrap();
        assert_eq!(
            c.keymgr.session(sid).unwrap().ctx.threads(),
            3,
            "registering the engine must grant the session the scheduler's PBS budget"
        );
    }
}
