//! Dynamic batcher (S9): groups compatible requests (same batch key)
//! into batches bounded by size and wait time — the standard
//! continuous-batching front of a serving system (vLLM-router-style),
//! implemented over std::sync primitives (tokio is unavailable offline).

use super::request::InferRequest;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time the oldest request may wait before the batch flushes.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure: submit fails when full).
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), queue_cap: 1024 }
    }
}

struct Inner {
    queue: VecDeque<InferRequest>,
    closed: bool,
}

/// A single-key dynamic batcher. The router keeps one per batch key.
pub struct Batcher {
    policy: BatchPolicy,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. Err(req) when the queue is full (backpressure)
    /// or the batcher is closed.
    pub fn submit(&self, req: InferRequest) -> Result<(), InferRequest> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.closed || g.queue.len() >= self.policy.queue_cap {
            return Err(req);
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking: wait for the next batch. Returns None when closed and
    /// drained. Flushes when `max_batch` is reached or the oldest request
    /// has waited `max_wait`.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if g.queue.len() >= self.policy.max_batch {
                return Some(drain(&mut g.queue, self.policy.max_batch));
            }
            if let Some(oldest) = g.queue.front() {
                let age = oldest.enqueued.elapsed();
                if age >= self.policy.max_wait {
                    let n = g.queue.len().min(self.policy.max_batch);
                    return Some(drain(&mut g.queue, n));
                }
                // Wait for more requests or the deadline of the oldest.
                let timeout = self.policy.max_wait - age;
                let (ng, _) =
                    self.cv.wait_timeout(g, timeout).unwrap_or_else(|e| e.into_inner());
                g = ng;
            } else {
                if g.closed {
                    return None;
                }
                // Idle: sleep until a submit (or close) signals.
                g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    /// Close the batcher: pending requests still drain via next_batch.
    pub fn close(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).closed = true;
        self.cv.notify_all();
    }

    /// Close **and** evict whatever is still queued, returning the
    /// evicted requests so the caller can fail them (the scheduler
    /// responds `Shutdown` — receivers must never be left hanging).
    /// Unlike [`Self::close`], nothing queued will reach an engine.
    pub fn abort(&self) -> Vec<InferRequest> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        let leftover = g.queue.drain(..).collect();
        self.cv.notify_all();
        leftover
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn drain(q: &mut VecDeque<InferRequest>, n: usize) -> Vec<InferRequest> {
    q.drain(..n).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::coordinator::request::{EnginePath, Payload};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    fn req(id: u64) -> InferRequest {
        InferRequest::new(id, EnginePath::QuantInt("inhibitor".into()), Payload::Tokens(vec![]))
    }

    #[test]
    fn flushes_on_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            queue_cap: 100,
        });
        for i in 0..3 {
            b.submit(req(i)).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn flushes_on_timeout_with_partial_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
            queue_cap: 100,
        });
        b.submit(req(7)).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(1),
            queue_cap: 2,
        });
        b.submit(req(0)).unwrap();
        b.submit(req(1)).unwrap();
        assert!(b.submit(req(2)).is_err());
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_millis(1),
            queue_cap: 10,
        });
        b.submit(req(1)).unwrap();
        b.close();
        assert!(b.submit(req(2)).is_err(), "closed batcher rejects");
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn abort_evicts_queued_requests() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_millis(1),
            queue_cap: 10,
        });
        b.submit(req(1)).unwrap();
        b.submit(req(2)).unwrap();
        let evicted = b.abort();
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(b.submit(req(3)).is_err(), "aborted batcher rejects");
        assert!(b.next_batch().is_none(), "nothing left to drain");
    }

    #[test]
    fn concurrent_producers_no_loss_no_duplication() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 7,
            max_wait: Duration::from_millis(2),
            queue_cap: 10_000,
        }));
        let n_threads = 4;
        let per_thread = 250u64;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    b.submit(req(t * 1_000_000 + i)).unwrap();
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    assert!(batch.len() <= 7, "batch size bound");
                    seen.extend(batch.iter().map(|r| r.id));
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        // Allow the consumer to drain, then close.
        while !b.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), (n_threads * per_thread) as usize, "no loss");
        seen.dedup();
        assert_eq!(seen.len(), (n_threads * per_thread) as usize, "no duplication");
    }
}
