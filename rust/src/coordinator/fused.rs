//! Cross-request PBS batch fusion (S9b): the coordinator-level payoff of
//! the circuit-plan IR.
//!
//! The batcher already groups compatible encrypted requests (same
//! session, mechanism and shape) into one engine invocation. Before PR 2
//! each request's circuit still ran its PBS levels alone, so at small `T`
//! a level batch (e.g. `T²·d = 8` jobs at T=2, d=2) could not fill the
//! worker pool. [`FusedLevelExecutor`] advances the [`PlanRun`] of every
//! co-scheduled request in lock-step and submits **one** `pbs_batch` per
//! level containing the union of all requests' jobs — the per-level batch
//! size the engine sees is exactly the *sum* of the per-request level
//! sizes (recorded in [`FusedStats`] and pinned by tests).
//!
//! Fusion changes scheduling only, never results or accounting: each
//! request's PBS jobs and linear ops are the same DAG evaluations as in
//! solo execution, so outputs are bit-identical to per-request
//! `CircuitPlan::execute` and the total PBS count is the sum of the plan
//! counts.

use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitPlan, LevelJob, PlanRun};

/// What one fused execution did — the observability the "worker pool
/// actually fills up" claim rests on.
#[derive(Clone, Debug, Default)]
pub struct FusedStats {
    /// Union batch size (bootstrap jobs, i.e. blind rotations) submitted
    /// to the worker pool at each level.
    pub level_batch_sizes: Vec<usize>,
    /// Total LUT evaluations across all fused requests
    /// (= Σ plan.pbs_count()).
    pub pbs_total: u64,
    /// Total blind rotations (= Σ plan.blind_rotation_count(); smaller
    /// than `pbs_total` when the plans carry packed multi-value nodes).
    pub blind_rotations: u64,
}

/// Lock-step executor over many plan runs sharing one context.
pub struct FusedLevelExecutor<'c> {
    ctx: &'c FheContext,
}

impl<'c> FusedLevelExecutor<'c> {
    pub fn new(ctx: &'c FheContext) -> Self {
        FusedLevelExecutor { ctx }
    }

    /// Execute every (plan, inputs) request, merging the current level of
    /// all still-running requests into a single batched PBS submission.
    /// Requests may have different plans/depths; a request that runs out
    /// of levels simply stops contributing jobs. Returns the per-request
    /// outputs (same order as `requests`) and the fusion stats.
    pub fn run(
        &self,
        requests: &[(&CircuitPlan, &[CtInt])],
    ) -> (Vec<Vec<CtInt>>, FusedStats) {
        let ctx = self.ctx;
        let mut runs: Vec<PlanRun> =
            requests.iter().map(|(plan, inputs)| PlanRun::new(plan, ctx, inputs)).collect();
        let mut stats = FusedStats::default();
        loop {
            // Gather the next level of every still-running request.
            let mut level_jobs: Vec<LevelJob> = Vec::new();
            // Per run: flattened output count to hand back (a packed
            // multi job contributes several outputs for one submission).
            let mut counts: Vec<Option<usize>> = Vec::with_capacity(runs.len());
            for run in runs.iter_mut() {
                match run.next_level_jobs(ctx) {
                    Some(jobs) => {
                        counts.push(Some(jobs.iter().map(LevelJob::n_outputs).sum()));
                        level_jobs.extend(jobs);
                    }
                    None => counts.push(None),
                }
            }
            if counts.iter().all(|c| c.is_none()) {
                break;
            }
            stats.level_batch_sizes.push(level_jobs.len());
            stats.blind_rotations += level_jobs.len() as u64;
            stats.pbs_total += level_jobs.iter().map(|j| j.n_outputs() as u64).sum::<u64>();
            // One fused submission for the whole level.
            let mut outs = ctx.pbs_level(&level_jobs).into_iter();
            // Scatter results back to their runs (same order as gathered).
            for (run, count) in runs.iter_mut().zip(&counts) {
                if let Some(n) = count {
                    run.supply((&mut outs).take(*n).collect());
                }
            }
        }
        let outputs = runs.into_iter().map(|run| run.finish(ctx)).collect();
        (outputs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe_circuits::InhibitorFhe;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::{Rng64, Xoshiro256};

    #[test]
    fn fused_execution_matches_solo_execution_and_sums_level_sizes() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xF05E);
        let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let (t, d) = (2usize, 2usize);
        let head = InhibitorFhe::new(d, 1);
        let plan = head.plan(t, d);
        // Three co-scheduled requests with distinct inputs.
        let make_inputs = |rng: &mut Xoshiro256| -> Vec<CtInt> {
            (0..3 * t * d)
                .map(|i| {
                    let v = if i < 2 * t * d {
                        rng.next_range_i64(-2, 2) // q, k
                    } else {
                        rng.next_range_i64(0, 3) // v
                    };
                    ctx.encrypt(v, &ck, rng)
                })
                .collect()
        };
        let bundles: Vec<Vec<CtInt>> = (0..3).map(|_| make_inputs(&mut rng)).collect();
        // Solo reference executions.
        let solo: Vec<Vec<CtInt>> =
            bundles.iter().map(|inputs| plan.execute(&ctx, inputs)).collect();
        // Fused execution.
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (&plan, b.as_slice())).collect();
        let before = pbs_count();
        let (fused, stats) = FusedLevelExecutor::new(&ctx).run(&requests);
        // Accounting: fusion reschedules, never changes the count.
        assert_eq!(pbs_count() - before, 3 * plan.pbs_count(), "total PBS");
        assert_eq!(stats.pbs_total, 3 * plan.pbs_count());
        assert_eq!(stats.blind_rotations, stats.pbs_total, "unpacked: 1 rotation per LUT");
        let want_sizes: Vec<usize> = plan.level_sizes().iter().map(|s| 3 * s).collect();
        assert_eq!(stats.level_batch_sizes, want_sizes, "summed per-level batch sizes");
        // Results: bit-identical to solo execution, request by request.
        for (r, (f, s)) in fused.iter().zip(&solo).enumerate() {
            assert_eq!(f.len(), s.len());
            for (i, (a, b)) in f.iter().zip(s.iter()).enumerate() {
                assert_eq!(a.ct, b.ct, "request {r} output {i}");
            }
        }
    }

    #[test]
    fn fused_handles_heterogeneous_depths() {
        // A deep plan fused with a shallow one: the shallow request stops
        // contributing after its last level while the deep one continues.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xD2E9);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        use crate::tfhe::plan::CircuitBuilder;
        // Shallow: relu(x). Deep: refresh(relu(x)).
        let shallow = {
            let mut b = CircuitBuilder::new();
            let ins = b.inputs(1);
            let r = b.relu(ins[0]);
            b.output(r);
            b.build()
        };
        let deep = {
            let mut b = CircuitBuilder::new();
            let ins = b.inputs(1);
            let r = b.relu(ins[0]);
            let f = b.refresh(r);
            b.output(f);
            b.build()
        };
        let xs = ctx.encrypt(-3, &ck, &mut rng);
        let xd = ctx.encrypt(5, &ck, &mut rng);
        let in_s = [xs.clone()];
        let in_d = [xd.clone()];
        let (outs, stats) =
            FusedLevelExecutor::new(&ctx).run(&[(&shallow, &in_s), (&deep, &in_d)]);
        assert_eq!(stats.level_batch_sizes, vec![2, 1]);
        assert_eq!(stats.pbs_total, 3);
        assert_eq!(ctx.decrypt(&outs[0][0], &ck), 0);
        assert_eq!(ctx.decrypt(&outs[1][0], &ck), 5);
        // Bit-identity with solo runs.
        assert_eq!(outs[0][0].ct, shallow.execute(&ctx, &[xs])[0].ct);
        assert_eq!(outs[1][0].ct, deep.execute(&ctx, &[xd])[0].ct);
    }

    #[test]
    fn fused_execution_carries_packed_multi_value_plans() {
        // Two co-scheduled signed-inhibitor requests on a packing-capable
        // set: the fused level loop must route the MultiPbs jobs through
        // the mixed worker pool, keep accounting exact, and stay
        // bit-identical to solo execution of the same rewritten plan.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        use crate::fhe_circuits::InhibitorSignedFhe;
        let mut rng = Xoshiro256::new(0xF05F);
        let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let (t, d) = (2usize, 2usize);
        let head = InhibitorSignedFhe::new(d, 1);
        let plan = head.plan_for(&ctx, t, d);
        assert!(
            plan.blind_rotation_count() < plan.pbs_count(),
            "signed plan must actually carry packed nodes"
        );
        let make_inputs = |rng: &mut Xoshiro256| -> Vec<CtInt> {
            (0..3 * t * d)
                .map(|i| {
                    let v = if i < 2 * t * d {
                        rng.next_range_i64(-2, 1) // q, k
                    } else {
                        rng.next_range_i64(-3, 3) // v (signed values)
                    };
                    ctx.encrypt(v, &ck, rng)
                })
                .collect()
        };
        let bundles: Vec<Vec<CtInt>> = (0..2).map(|_| make_inputs(&mut rng)).collect();
        let solo: Vec<Vec<CtInt>> =
            bundles.iter().map(|inputs| plan.execute(&ctx, inputs)).collect();
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (plan.as_ref(), b.as_slice())).collect();
        let before_pbs = pbs_count();
        let before_rot = crate::tfhe::bootstrap::blind_rotation_count();
        let (fused, stats) = FusedLevelExecutor::new(&ctx).run(&requests);
        assert_eq!(pbs_count() - before_pbs, 2 * plan.pbs_count());
        assert_eq!(
            crate::tfhe::bootstrap::blind_rotation_count() - before_rot,
            2 * plan.blind_rotation_count()
        );
        assert_eq!(stats.pbs_total, 2 * plan.pbs_count());
        assert_eq!(stats.blind_rotations, 2 * plan.blind_rotation_count());
        for (r, (f, s)) in fused.iter().zip(&solo).enumerate() {
            for (i, (a, b)) in f.iter().zip(s.iter()).enumerate() {
                assert_eq!(a.ct, b.ct, "request {r} output {i}");
            }
        }
    }
}
