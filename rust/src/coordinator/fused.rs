//! Cross-request PBS batch fusion (S9b): the coordinator-level payoff of
//! the circuit-plan IR.
//!
//! The batcher already groups compatible encrypted requests (same
//! session, mechanism and shape) into one engine invocation. Before PR 2
//! each request's circuit still ran its PBS levels alone, so at small `T`
//! a level batch (e.g. `T²·d = 8` jobs at T=2, d=2) could not fill the
//! worker pool. [`FusedLevelExecutor`] advances the [`PlanRun`] of every
//! co-scheduled request in lock-step and submits **one** `pbs_batch` per
//! level containing the union of all requests' jobs — the per-level batch
//! size the engine sees is exactly the *sum* of the per-request level
//! sizes (recorded in [`FusedStats`] and pinned by tests).
//!
//! Fusion changes scheduling only, never results or accounting: each
//! request's PBS jobs and linear ops are the same DAG evaluations as in
//! solo execution, so outputs are bit-identical to per-request
//! `CircuitPlan::execute` and the total PBS count is the sum of the plan
//! counts.
//!
//! ## Failure model (PR 6)
//!
//! [`FusedLevelExecutor::run_checked`] is the fault-tolerant serving
//! entry point. Levels are submitted through the panic-isolated pool
//! (`FheContext::pbs_level_checked`), so a poisoned job **quarantines**
//! only the member that owns it — the co-scheduled survivors keep their
//! in-flight `PlanRun`s and continue in the same lock-step pass,
//! bit-identical to a fault-free run (no replay needed at this layer;
//! the scheduler's bounded solo-replay handles wholesale engine
//! crashes). Every level boundary is also a **cooperative cancellation
//! point**: a member whose deadline expired or whose [`CancelToken`]
//! fired abandons its remaining levels right there, returning
//! `DeadlineExceeded`/`Cancelled` with [`FusedStats::levels_done`]
//! recording how far it got.
//!
//! ## Wavefront dispatch + cross-key fusion (PR 8)
//!
//! The lock-step loop now advances by **wavefront ticks**: each member's
//! `PlanRun` is stepped through the mode-aware
//! [`PlanRun::next_jobs`] (readiness-driven by default, legacy level
//! barriers under `FHE_WAVEFRONT=0` — bit-identical either way), and the
//! gathered jobs go through the **work-stealing, cross-key pool**
//! (`tfhe::bootstrap::pbs_batch_keyed_isolated`) in a single sweep per
//! tick. Every job carries its member's server key: a member may bring
//! its own [`FheContext`] ([`FusedRequest::with_ctx`]), so requests from
//! *different sessions with different keys* fuse into one pool pass —
//! [`FusedStats::fused_keys`] records how many keys one sweep served,
//! [`FusedStats::stolen_jobs`] and
//! [`FusedStats::worker_utilization`] how well the pool stayed
//! saturated. The failure-model checkpoints (deadline, cancellation,
//! fault ticks) sit at the top of each wavefront tick — the same
//! cadence the level boundaries had, since waves and levels advance in
//! lockstep — and per-job `catch_unwind` quarantine is unchanged.

use crate::error::FheError;
use crate::tfhe::bootstrap::{pbs_batch_keyed_isolated, KeyedJob};
use crate::tfhe::faults::CancelToken;
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::plan::{CircuitPlan, LevelJob, PlanRun};
use std::time::Instant;

/// What one fused execution did — the observability the "worker pool
/// actually fills up" claim rests on.
#[derive(Clone, Debug, Default)]
pub struct FusedStats {
    /// Union batch size (bootstrap jobs, i.e. blind rotations) submitted
    /// to the worker pool at each level.
    pub level_batch_sizes: Vec<usize>,
    /// Total LUT evaluations across all fused requests
    /// (= Σ plan.pbs_count()).
    pub pbs_total: u64,
    /// Total blind rotations (= Σ plan.blind_rotation_count(); smaller
    /// than `pbs_total` when the plans carry packed multi-value nodes).
    pub blind_rotations: u64,
    /// Members removed from the lock-step group because a PBS job they
    /// owned failed (worker panic — genuine or injected).
    pub quarantined: u64,
    /// Members abandoned at a level boundary because their deadline
    /// expired (injected `deadline@level:N` counts here too).
    pub deadline_kills: u64,
    /// Per member (same order as the request slice): PBS levels fully
    /// executed. Equals the plan's level count on success, strictly
    /// fewer after a deadline kill or cancellation.
    pub levels_done: Vec<usize>,
    /// Jobs executed by a pool worker other than the one they were
    /// assigned to (summed over ticks) — nonzero means the
    /// work-stealing pool actually rebalanced a skewed tick.
    pub stolen_jobs: u64,
    /// Most distinct server keys any single pool sweep served — ≥ 2
    /// proves cross-key fusion happened in one pass.
    pub fused_keys: usize,
    /// Worker-nanoseconds spent executing jobs, summed over ticks.
    pub busy_ns: u64,
    /// Worker-nanoseconds available (threads × wall), summed over ticks.
    pub capacity_ns: u64,
}

impl FusedStats {
    /// Fraction of pool worker-time spent executing jobs across the
    /// whole run (0 when nothing ran).
    pub fn worker_utilization(&self) -> f64 {
        if self.capacity_ns == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / self.capacity_ns as f64
    }
}

/// One member of a fused execution: a plan over an input bundle, plus
/// the request's failure-model context (deadline + cancellation token).
pub struct FusedRequest<'a> {
    pub plan: &'a CircuitPlan,
    pub inputs: &'a [CtInt],
    /// Absolute wall-clock deadline; checked at every wavefront tick.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation; checked at every wavefront tick.
    pub cancel: Option<CancelToken>,
    /// The member's own context (its session's server key, LUT caches,
    /// encoder). `None` means "the executor's context" — the single-key
    /// case. Distinct contexts across members is what cross-key fusion
    /// is: each member's jobs are tagged with *its* key and the pool
    /// sweeps them all in one pass.
    pub ctx: Option<&'a FheContext>,
}

impl<'a> FusedRequest<'a> {
    /// A member with no deadline, no cancellation token, and the
    /// executor's own context.
    pub fn new(plan: &'a CircuitPlan, inputs: &'a [CtInt]) -> Self {
        FusedRequest { plan, inputs, deadline: None, cancel: None, ctx: None }
    }

    /// Attach the member's own session context (cross-key fusion).
    pub fn with_ctx(mut self, ctx: &'a FheContext) -> Self {
        self.ctx = Some(ctx);
        self
    }
}

/// Lock-step executor over many plan runs. Members default to the
/// executor's context; members carrying their own ([`FusedRequest::
/// with_ctx`]) fuse across server keys in the same pool sweeps. The
/// executor's context supplies the pool width (`threads()`) and the
/// armed fault plan.
pub struct FusedLevelExecutor<'c> {
    ctx: &'c FheContext,
}

impl<'c> FusedLevelExecutor<'c> {
    pub fn new(ctx: &'c FheContext) -> Self {
        FusedLevelExecutor { ctx }
    }

    /// Execute every (plan, inputs) request, merging the current level of
    /// all still-running requests into a single batched PBS submission.
    /// Requests may have different plans/depths; a request that runs out
    /// of levels simply stops contributing jobs. Returns the per-request
    /// outputs (same order as `requests`) and the fusion stats.
    ///
    /// This is the solo/reference path: any failure (which the checked
    /// path would contain to one member) panics. Serving goes through
    /// [`Self::run_checked`].
    pub fn run(
        &self,
        requests: &[(&CircuitPlan, &[CtInt])],
    ) -> (Vec<Vec<CtInt>>, FusedStats) {
        let members: Vec<FusedRequest> =
            requests.iter().map(|&(plan, inputs)| FusedRequest::new(plan, inputs)).collect();
        let (results, stats) = self.run_checked(&members);
        let outputs = results
            .into_iter()
            .map(|r| r.expect("fault-free fused run"))
            .collect();
        (outputs, stats)
    }

    /// [`Self::run`] with the full failure model: per-member results, a
    /// poisoned PBS job quarantines only its owner, and every level
    /// boundary checks each member's deadline and cancellation token.
    ///
    /// An armed [`crate::tfhe::FaultPlan`] on the context participates
    /// deterministically: `panic@pbs:N` poisons the N-th submitted job,
    /// and `deadline@level:N` makes the N-th boundary report expiry for
    /// every member that carries a deadline (the boundary *before* the
    /// first level is tick 1).
    pub fn run_checked(
        &self,
        requests: &[FusedRequest<'_>],
    ) -> (Vec<Result<Vec<CtInt>, FheError>>, FusedStats) {
        let ctx = self.ctx;
        let faults = ctx.fault_plan();
        let n = requests.len();
        let mut stats = FusedStats { levels_done: vec![0; n], ..FusedStats::default() };
        let mut results: Vec<Option<Result<Vec<CtInt>, FheError>>> =
            (0..n).map(|_| None).collect();
        // Arity is a request-triggerable failure: reject the member here
        // rather than letting `PlanRun::new` assert.
        let mut runs: Vec<Option<PlanRun>> = Vec::with_capacity(n);
        for (i, req) in requests.iter().enumerate() {
            if req.inputs.len() != req.plan.n_inputs() {
                results[i] = Some(Err(FheError::PlanInvalid(format!(
                    "plan expects {} inputs, request carries {}",
                    req.plan.n_inputs(),
                    req.inputs.len()
                ))));
                runs.push(None);
            } else {
                // Resolve LUT accumulators against the member's own
                // context — under cross-key fusion each member's
                // bootstraps must run under that member's server key.
                runs.push(Some(PlanRun::new(req.plan, req.ctx.unwrap_or(ctx), req.inputs)));
            }
        }
        loop {
            // Wavefront tick: cooperative cancellation checkpoint. One
            // fault tick per boundary, shared by every live member —
            // waves and levels advance in lockstep, so `deadline@level:N`
            // keeps its exact meaning under wavefront dispatch.
            let fault_deadline = faults.as_deref().is_some_and(|f| f.deadline_fires());
            for i in 0..n {
                let Some(run) = runs[i].as_ref() else { continue };
                let req = &requests[i];
                let cancelled = req.cancel.as_ref().is_some_and(|c| c.is_cancelled());
                let expired =
                    req.deadline.is_some_and(|d| fault_deadline || Instant::now() >= d);
                if !(cancelled || expired) {
                    continue;
                }
                stats.levels_done[i] = run.levels_done();
                let err = if cancelled {
                    FheError::Cancelled
                } else {
                    stats.deadline_kills += 1;
                    FheError::DeadlineExceeded(format!(
                        "deadline expired: abandoned after {}/{} PBS levels",
                        run.levels_done(),
                        req.plan.levels()
                    ))
                };
                results[i] = Some(Err(err));
                runs[i] = None;
            }
            // Gather the next wave of every still-running member.
            let mut level_jobs: Vec<LevelJob> = Vec::new();
            // Per member: jobs contributed this tick (`None` = finished
            // earlier or not running).
            let mut njobs: Vec<Option<usize>> = (0..n).map(|_| None).collect();
            for i in 0..n {
                let Some(run) = runs[i].as_mut() else { continue };
                let mctx = requests[i].ctx.unwrap_or(ctx);
                match run.next_jobs(mctx) {
                    Some(jobs) => {
                        njobs[i] = Some(jobs.len());
                        level_jobs.extend(jobs);
                    }
                    None => {
                        let run = runs[i].take().expect("checked above");
                        stats.levels_done[i] = run.levels_done();
                        results[i] = Some(Ok(run.finish(mctx)));
                    }
                }
            }
            if level_jobs.is_empty() {
                break;
            }
            stats.level_batch_sizes.push(level_jobs.len());
            stats.blind_rotations += level_jobs.len() as u64;
            stats.pbs_total += level_jobs.iter().map(|j| j.n_outputs() as u64).sum::<u64>();
            // Tag every job with its member's server key and sweep the
            // whole tick — all members, all keys — through the
            // work-stealing pool in one panic-isolated pass.
            let mut keyed: Vec<KeyedJob> = Vec::with_capacity(level_jobs.len());
            {
                let mut off = 0;
                for i in 0..n {
                    let Some(k) = njobs[i] else { continue };
                    let key = &requests[i].ctx.unwrap_or(ctx).sk;
                    for job in &level_jobs[off..off + k] {
                        keyed.push(KeyedJob { key, job: job.as_batch_job() });
                    }
                    off += k;
                }
            }
            let (tick_results, pool) =
                pbs_batch_keyed_isolated(&keyed, ctx.threads(), faults.as_deref());
            stats.stolen_jobs += pool.stolen_jobs;
            stats.fused_keys = stats.fused_keys.max(pool.keys);
            stats.busy_ns += pool.busy_ns;
            stats.capacity_ns += pool.capacity_ns;
            let mut job_results = tick_results.into_iter();
            // Scatter per-job results back to their members (same order
            // as gathered). A failed job quarantines its owner; the
            // survivors' outputs are moved (never cloned) into supply.
            for i in 0..n {
                let Some(k) = njobs[i] else { continue };
                let mut outs: Vec<CtInt> = Vec::new();
                let mut failed: Option<FheError> = None;
                for job in (&mut job_results).take(k) {
                    match job {
                        Ok(cts) => outs.extend(cts.into_iter().map(|ct| CtInt { ct })),
                        Err(e) => {
                            // Keep the first failure as the member's error.
                            failed.get_or_insert(e);
                        }
                    }
                }
                if let Some(e) = failed {
                    let run = runs[i].take().expect("member contributed jobs");
                    stats.levels_done[i] = run.levels_done();
                    stats.quarantined += 1;
                    results[i] = Some(Err(e));
                } else if let Some(run) = runs[i].as_mut() {
                    run.supply(outs);
                }
            }
        }
        let results = results
            .into_iter()
            .map(|r| r.expect("every member resolved"))
            .collect();
        (results, stats)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::fhe_circuits::InhibitorFhe;
    use crate::tfhe::bootstrap::{pbs_count, ClientKey};
    use crate::tfhe::faults::FaultPlan;
    use crate::tfhe::params::TfheParams;
    use crate::tfhe::plan::CircuitBuilder;
    use crate::util::prng::{Rng64, Xoshiro256};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fused_execution_matches_solo_execution_and_sums_level_sizes() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xF05E);
        let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let (t, d) = (2usize, 2usize);
        let head = InhibitorFhe::new(d, 1);
        let plan = head.plan(t, d);
        // Three co-scheduled requests with distinct inputs.
        let make_inputs = |rng: &mut Xoshiro256| -> Vec<CtInt> {
            (0..3 * t * d)
                .map(|i| {
                    let v = if i < 2 * t * d {
                        rng.next_range_i64(-2, 2) // q, k
                    } else {
                        rng.next_range_i64(0, 3) // v
                    };
                    ctx.encrypt(v, &ck, rng)
                })
                .collect()
        };
        let bundles: Vec<Vec<CtInt>> = (0..3).map(|_| make_inputs(&mut rng)).collect();
        // Solo reference executions.
        let solo: Vec<Vec<CtInt>> =
            bundles.iter().map(|inputs| plan.execute(&ctx, inputs)).collect();
        // Fused execution.
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (&plan, b.as_slice())).collect();
        let before = pbs_count();
        let (fused, stats) = FusedLevelExecutor::new(&ctx).run(&requests);
        // Accounting: fusion reschedules, never changes the count.
        assert_eq!(pbs_count() - before, 3 * plan.pbs_count(), "total PBS");
        assert_eq!(stats.pbs_total, 3 * plan.pbs_count());
        assert_eq!(stats.blind_rotations, stats.pbs_total, "unpacked: 1 rotation per LUT");
        let want_sizes: Vec<usize> = plan.level_sizes().iter().map(|s| 3 * s).collect();
        assert_eq!(stats.level_batch_sizes, want_sizes, "summed per-level batch sizes");
        assert_eq!(stats.quarantined, 0);
        assert_eq!(stats.deadline_kills, 0);
        assert_eq!(stats.levels_done, vec![plan.levels(); 3]);
        // Results: bit-identical to solo execution, request by request.
        for (r, (f, s)) in fused.iter().zip(&solo).enumerate() {
            assert_eq!(f.len(), s.len());
            for (i, (a, b)) in f.iter().zip(s.iter()).enumerate() {
                assert_eq!(a.ct, b.ct, "request {r} output {i}");
            }
        }
    }

    #[test]
    fn fused_handles_heterogeneous_depths() {
        // A deep plan fused with a shallow one: the shallow request stops
        // contributing after its last level while the deep one continues.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xD2E9);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        // Shallow: relu(x). Deep: refresh(relu(x)).
        let shallow = {
            let mut b = CircuitBuilder::new();
            let ins = b.inputs(1);
            let r = b.relu(ins[0]);
            b.output(r);
            b.build()
        };
        let deep = {
            let mut b = CircuitBuilder::new();
            let ins = b.inputs(1);
            let r = b.relu(ins[0]);
            let f = b.refresh(r);
            b.output(f);
            b.build()
        };
        let xs = ctx.encrypt(-3, &ck, &mut rng);
        let xd = ctx.encrypt(5, &ck, &mut rng);
        let in_s = [xs.clone()];
        let in_d = [xd.clone()];
        let (outs, stats) =
            FusedLevelExecutor::new(&ctx).run(&[(&shallow, &in_s), (&deep, &in_d)]);
        assert_eq!(stats.level_batch_sizes, vec![2, 1]);
        assert_eq!(stats.pbs_total, 3);
        assert_eq!(stats.levels_done, vec![1, 2]);
        assert_eq!(ctx.decrypt(&outs[0][0], &ck), 0);
        assert_eq!(ctx.decrypt(&outs[1][0], &ck), 5);
        // Bit-identity with solo runs.
        assert_eq!(outs[0][0].ct, shallow.execute(&ctx, &[xs])[0].ct);
        assert_eq!(outs[1][0].ct, deep.execute(&ctx, &[xd])[0].ct);
    }

    #[test]
    fn fused_execution_carries_packed_multi_value_plans() {
        // Two co-scheduled signed-inhibitor requests on a packing-capable
        // set: the fused level loop must route the MultiPbs jobs through
        // the mixed worker pool, keep accounting exact, and stay
        // bit-identical to solo execution of the same rewritten plan.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        use crate::fhe_circuits::InhibitorSignedFhe;
        let mut rng = Xoshiro256::new(0xF05F);
        let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let (t, d) = (2usize, 2usize);
        let head = InhibitorSignedFhe::new(d, 1);
        let plan = head.plan_for(&ctx, t, d);
        assert!(
            plan.blind_rotation_count() < plan.pbs_count(),
            "signed plan must actually carry packed nodes"
        );
        let make_inputs = |rng: &mut Xoshiro256| -> Vec<CtInt> {
            (0..3 * t * d)
                .map(|i| {
                    let v = if i < 2 * t * d {
                        rng.next_range_i64(-2, 1) // q, k
                    } else {
                        rng.next_range_i64(-3, 3) // v (signed values)
                    };
                    ctx.encrypt(v, &ck, rng)
                })
                .collect()
        };
        let bundles: Vec<Vec<CtInt>> = (0..2).map(|_| make_inputs(&mut rng)).collect();
        let solo: Vec<Vec<CtInt>> =
            bundles.iter().map(|inputs| plan.execute(&ctx, inputs)).collect();
        let requests: Vec<(&CircuitPlan, &[CtInt])> =
            bundles.iter().map(|b| (plan.as_ref(), b.as_slice())).collect();
        let before_pbs = pbs_count();
        let before_rot = crate::tfhe::bootstrap::blind_rotation_count();
        let (fused, stats) = FusedLevelExecutor::new(&ctx).run(&requests);
        assert_eq!(pbs_count() - before_pbs, 2 * plan.pbs_count());
        assert_eq!(
            crate::tfhe::bootstrap::blind_rotation_count() - before_rot,
            2 * plan.blind_rotation_count()
        );
        assert_eq!(stats.pbs_total, 2 * plan.pbs_count());
        assert_eq!(stats.blind_rotations, 2 * plan.blind_rotation_count());
        for (r, (f, s)) in fused.iter().zip(&solo).enumerate() {
            for (i, (a, b)) in f.iter().zip(s.iter()).enumerate() {
                assert_eq!(a.ct, b.ct, "request {r} output {i}");
            }
        }
    }

    /// relu → refresh → relu: three levels of one job each.
    fn deep_plan() -> CircuitPlan {
        let mut b = CircuitBuilder::new();
        let ins = b.inputs(1);
        let r = b.relu(ins[0]);
        let f = b.refresh(r);
        let r2 = b.relu(f);
        b.output(r2);
        b.build()
    }

    #[test]
    fn injected_deadline_abandons_remaining_levels() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xDEAD);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let plan = deep_plan();
        assert_eq!(plan.levels(), 3);
        let inputs = [ctx.encrypt(-2, &ck, &mut rng)];
        // Boundary ticks: 1 (before level 1), 2 (after level 1) — so the
        // member executes exactly one of its three levels.
        ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse("deadline@level:2").unwrap())));
        let member = FusedRequest {
            plan: &plan,
            inputs: &inputs,
            deadline: Some(Instant::now() + Duration::from_secs(3600)),
            cancel: None,
            ctx: None,
        };
        let before = pbs_count();
        let (results, stats) = FusedLevelExecutor::new(&ctx).run_checked(&[member]);
        ctx.set_fault_plan(None);
        match &results[0] {
            Err(FheError::DeadlineExceeded(m)) => assert!(m.contains("1/3"), "{m}"),
            other => panic!("want DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(stats.deadline_kills, 1);
        assert_eq!(stats.levels_done, vec![1]);
        let executed = pbs_count() - before;
        assert_eq!(executed, 1, "only level 1 ran");
        assert!(executed < plan.pbs_count(), "levels 2..3 skipped");
    }

    #[test]
    fn cancellation_token_abandons_before_any_work() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xCA9C);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let plan = deep_plan();
        let inputs = [ctx.encrypt(1, &ck, &mut rng)];
        let token = CancelToken::new();
        token.cancel();
        let member = FusedRequest {
            plan: &plan,
            inputs: &inputs,
            deadline: None,
            cancel: Some(token),
            ctx: None,
        };
        let before = pbs_count();
        let (results, stats) = FusedLevelExecutor::new(&ctx).run_checked(&[member]);
        assert_eq!(results[0], Err(FheError::Cancelled));
        assert_eq!(stats.levels_done, vec![0]);
        assert_eq!(pbs_count(), before, "no PBS for a pre-cancelled member");
    }

    #[test]
    fn cross_key_members_fuse_into_one_pool_sweep() {
        // The acceptance shape: two sessions with *distinct server keys*
        // co-scheduled into one fused execution. Every tick must sweep
        // both members' jobs in a single pool pass (level_batch_sizes =
        // summed level sizes, fused_keys = 2), and each member's outputs
        // must be bit-identical to a solo run under its own context.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let params = TfheParams::test_for_bits(4);
        let mut rng_a = Xoshiro256::new(0x5E551);
        let mut rng_b = Xoshiro256::new(0x5E552);
        let ck_a = ClientKey::generate(params, &mut rng_a);
        let ck_b = ClientKey::generate(params, &mut rng_b);
        let ctx_a = FheContext::new(ck_a.server_key(&mut rng_a));
        let ctx_b = FheContext::new(ck_b.server_key(&mut rng_b));
        let plan = deep_plan();
        let in_a = [ctx_a.encrypt(-3, &ck_a, &mut rng_a)];
        let in_b = [ctx_b.encrypt(2, &ck_b, &mut rng_b)];
        let solo_a = plan.execute(&ctx_a, &in_a);
        let solo_b = plan.execute(&ctx_b, &in_b);
        let members = [
            FusedRequest::new(&plan, &in_a), // executor default = session A
            FusedRequest::new(&plan, &in_b).with_ctx(&ctx_b),
        ];
        let before = pbs_count();
        let (results, stats) = FusedLevelExecutor::new(&ctx_a).run_checked(&members);
        assert_eq!(pbs_count() - before, 2 * plan.pbs_count(), "fusion never changes cost");
        assert_eq!(stats.fused_keys, 2, "one sweep must serve both sessions' keys");
        let want_sizes: Vec<usize> = plan.level_sizes().iter().map(|s| 2 * s).collect();
        assert_eq!(stats.level_batch_sizes, want_sizes, "both members in every sweep");
        assert_eq!(stats.levels_done, vec![plan.levels(); 2]);
        assert_eq!(stats.quarantined, 0);
        let out_a = results[0].as_ref().expect("member A succeeds");
        let out_b = results[1].as_ref().expect("member B succeeds");
        assert_eq!(out_a[0].ct, solo_a[0].ct, "A bit-identical to solo under key A");
        assert_eq!(out_b[0].ct, solo_b[0].ct, "B bit-identical to solo under key B");
        assert_eq!(ctx_a.decrypt(&out_a[0], &ck_a), 0, "relu(relu(-3)) refreshed");
        assert_eq!(ctx_b.decrypt(&out_b[0], &ck_b), 2);
        // Pool observability is coherent: busy time was recorded and
        // utilization is a fraction.
        assert!(stats.busy_ns > 0);
        let u = stats.worker_utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u} out of range");
    }

    #[test]
    fn cross_key_quarantine_contains_fault_to_the_victim_member() {
        // An injected PBS panic inside a cross-key sweep must quarantine
        // only the member that owns the poisoned job; the other session's
        // member survives bit-identically.
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let params = TfheParams::test_for_bits(4);
        let mut rng_a = Xoshiro256::new(0x5E553);
        let mut rng_b = Xoshiro256::new(0x5E554);
        let ck_a = ClientKey::generate(params, &mut rng_a);
        let ck_b = ClientKey::generate(params, &mut rng_b);
        let ctx_a = FheContext::new(ck_a.server_key(&mut rng_a));
        let ctx_b = FheContext::new(ck_b.server_key(&mut rng_b));
        let plan = deep_plan();
        let in_a = [ctx_a.encrypt(4, &ck_a, &mut rng_a)];
        let in_b = [ctx_b.encrypt(-1, &ck_b, &mut rng_b)];
        let solo_a = plan.execute(&ctx_a, &in_a);
        // Tick 1 submits [A's job, B's job]; poison the 2nd submitted
        // job — B's — through the executor context's fault plan.
        ctx_a.set_fault_plan(Some(Arc::new(FaultPlan::parse("panic@pbs:2").unwrap())));
        let members = [
            FusedRequest::new(&plan, &in_a),
            FusedRequest::new(&plan, &in_b).with_ctx(&ctx_b),
        ];
        let (results, stats) = FusedLevelExecutor::new(&ctx_a).run_checked(&members);
        ctx_a.set_fault_plan(None);
        assert_eq!(stats.quarantined, 1, "exactly the victim is quarantined");
        assert!(
            matches!(&results[1], Err(FheError::WorkerPanic(m)) if m.contains("panic@pbs:2")),
            "member B is the victim"
        );
        let out_a = results[0].as_ref().expect("member A survives");
        assert_eq!(out_a[0].ct, solo_a[0].ct, "survivor bit-identical across keys");
        assert_eq!(stats.levels_done[1], 0, "B fell at its first level");
        assert_eq!(stats.levels_done[0], plan.levels());
    }

    #[test]
    fn wrong_arity_fails_only_that_member() {
        let _pbs_guard = crate::tfhe::pbs_test_guard();
        let mut rng = Xoshiro256::new(0xA217);
        let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let plan = deep_plan();
        let good_in = [ctx.encrypt(3, &ck, &mut rng)];
        let bad_in =
            [ctx.encrypt(1, &ck, &mut rng), ctx.encrypt(2, &ck, &mut rng)];
        let members = [
            FusedRequest::new(&plan, &good_in),
            FusedRequest::new(&plan, &bad_in),
        ];
        let (results, _) = FusedLevelExecutor::new(&ctx).run_checked(&members);
        let good = results[0].as_ref().expect("well-formed member succeeds");
        assert_eq!(good[0].ct, plan.execute(&ctx, &good_in)[0].ct);
        match &results[1] {
            Err(FheError::PlanInvalid(m)) => assert!(m.contains("expects 1"), "{m}"),
            other => panic!("want PlanInvalid, got {other:?}"),
        }
    }
}
