//! Serving metrics (S9): counters and log-bucket latency histograms,
//! lock-free on the hot path (atomics only).

use std::sync::atomic::{AtomicU64, Ordering};

/// Histogram with exponential buckets: [1µs·2^i, 1µs·2^(i+1)).
const BUCKETS: usize = 32;

#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_s(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile from the bucket boundaries (upper bound).
    pub fn quantile_s(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 / 1e6;
            }
        }
        (1u64 << BUCKETS) as f64 / 1e6
    }
}

/// Storage-tier metrics (S9): shared between the coordinator's
/// [`Metrics`] and the `coordinator::storage` tiers, which hold the same
/// `Arc` and count directly — no polling, no drift.
#[derive(Default)]
pub struct StorageMetrics {
    /// Bundles spilled cold to the blob sink (hot tier over budget).
    pub evictions: AtomicU64,
    /// Spilled bundles decoded back into the hot path on `take`.
    pub rehydrations: AtomicU64,
    /// `take`s served from the hot tier.
    pub hits: AtomicU64,
    /// `take`s that had to touch the sink.
    pub misses: AtomicU64,
    /// Parked sessions whose server key was rebuilt from the sink on
    /// first touch.
    pub cold_key_attaches: AtomicU64,
    /// Latency of those cold-key attaches (decode + FFT-plan rebuild —
    /// the price of parking a session).
    pub key_attach: LatencyHistogram,
}

impl StorageMetrics {
    /// Fraction of tier `take`s served hot (1.0 when nothing ever
    /// spilled, including before any traffic).
    pub fn hit_rate(&self) -> f64 {
        let hits = self.hits.load(Ordering::Relaxed);
        let misses = self.misses.load(Ordering::Relaxed);
        if hits + misses == 0 {
            return 1.0;
        }
        hits as f64 / (hits + misses) as f64
    }
}

/// Top-level serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Fused PBS levels executed by encrypted engines (one per
    /// cross-request `pbs_batch` submission).
    pub fused_levels: AtomicU64,
    /// Total LUT evaluations submitted through fused levels.
    pub fused_pbs: AtomicU64,
    /// Total blind rotations behind those evaluations — smaller than
    /// `fused_pbs` when the rewritten plans pack multi-value bootstraps.
    pub fused_blind_rotations: AtomicU64,
    // --- failure-model counters (PR 6) ---
    /// Requests that failed because a worker panicked on their job or
    /// their whole engine batch crashed.
    pub worker_panics: AtomicU64,
    /// Engine workers rebuilt from their factory after a crash.
    pub respawns: AtomicU64,
    /// Requests replayed solo after a wholesale engine-batch crash
    /// (bounded: each request is replayed at most once).
    pub retries: AtomicU64,
    /// Members removed from a fused batch (poisoned PBS job) or pinned
    /// as the poison by the scheduler's solo replay.
    pub quarantined: AtomicU64,
    /// Requests abandoned for an expired deadline (at dequeue or at a
    /// PBS level boundary).
    pub deadline_kills: AtomicU64,
    /// Queued requests drained with a `Shutdown` error instead of being
    /// left with hanging receivers.
    pub shutdown_drained: AtomicU64,
    // --- incremental decode (PR 7) ---
    /// Successful decode steps served (prefills are not steps).
    pub decode_steps: AtomicU64,
    /// Gauge: live decode cache bundles in the session store.
    pub cache_blobs_live: AtomicU64,
    /// Gauge: ciphertext bytes held live by those bundles.
    pub cache_bytes: AtomicU64,
    // --- wavefront work-stealing pool (PR 8) ---
    /// PBS jobs executed by a pool worker other than their assigned one
    /// — the work-stealing pool rebalancing skewed sweeps.
    pub stolen_jobs: AtomicU64,
    /// High-water mark: most distinct server keys any single pool sweep
    /// served (≥ 2 means cross-session fusion happened in one pass).
    pub fused_keys: AtomicU64,
    /// Worker-nanoseconds spent executing PBS jobs.
    pub pool_busy_ns: AtomicU64,
    /// Worker-nanoseconds available (threads × wall per sweep).
    pub pool_capacity_ns: AtomicU64,
    // --- storage tier (PR 9) ---
    /// Spill-tier counters, shared by `Arc` with the `CtStore` tiers so
    /// evictions/rehydrations are counted at the point they happen.
    pub storage: std::sync::Arc<StorageMetrics>,
    // --- radix wide arithmetic (PR 10) ---
    /// Limb slots materialized by radix-legalized plans the serving
    /// layer built (Σ over plans of widened sources × limbs).
    pub radix_limbs: AtomicU64,
    /// Blind rotations those plans spend on packed carry propagation.
    pub carry_rotations: AtomicU64,
    pub latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean PBS jobs per fused level — the worker-utilization signal of
    /// the cross-request fusion path.
    pub fn mean_fused_level_size(&self) -> f64 {
        let l = self.fused_levels.load(Ordering::Relaxed);
        if l == 0 {
            return 0.0;
        }
        self.fused_pbs.load(Ordering::Relaxed) as f64 / l as f64
    }

    /// Fraction of pool worker-time spent executing PBS jobs across all
    /// fused sweeps recorded so far (0 before the first sweep).
    pub fn worker_utilization(&self) -> f64 {
        let cap = self.pool_capacity_ns.load(Ordering::Relaxed);
        if cap == 0 {
            return 0.0;
        }
        self.pool_busy_ns.load(Ordering::Relaxed) as f64 / cap as f64
    }

    /// Fold one fused execution's stats into the serving counters — the
    /// single recording point the engine bodies share.
    pub fn record_fused(&self, stats: &crate::coordinator::fused::FusedStats) {
        self.fused_levels.fetch_add(stats.level_batch_sizes.len() as u64, Ordering::Relaxed);
        self.fused_pbs.fetch_add(stats.pbs_total, Ordering::Relaxed);
        self.fused_blind_rotations.fetch_add(stats.blind_rotations, Ordering::Relaxed);
        self.quarantined.fetch_add(stats.quarantined, Ordering::Relaxed);
        self.deadline_kills.fetch_add(stats.deadline_kills, Ordering::Relaxed);
        self.stolen_jobs.fetch_add(stats.stolen_jobs, Ordering::Relaxed);
        self.fused_keys.fetch_max(stats.fused_keys as u64, Ordering::Relaxed);
        self.pool_busy_ns.fetch_add(stats.busy_ns, Ordering::Relaxed);
        self.pool_capacity_ns.fetch_add(stats.capacity_ns, Ordering::Relaxed);
    }

    /// Fold a legalized plan's radix accounting into the serving
    /// counters — called wherever the serving layer rewrites a plan
    /// whose legalization produced wide values (no-op plans carry no
    /// [`crate::tfhe::radix::RadixInfo`] and never reach here).
    pub fn record_radix(&self, info: &crate::tfhe::radix::RadixInfo) {
        self.radix_limbs
            .fetch_add(info.widened as u64 * info.spec.limbs as u64, Ordering::Relaxed);
        self.carry_rotations.fetch_add(info.carry_rotations, Ordering::Relaxed);
    }

    /// Refresh the store-footprint gauges from the session store — the
    /// one place `cache_blobs_live`/`cache_bytes` are written, shared by
    /// `release_cache`, the decode engine body, and session teardown so
    /// the storage paths cannot drift out of sync with the store.
    pub fn refresh_cache_gauges(&self, store: &crate::coordinator::session_store::SessionStore) {
        self.cache_blobs_live.store(store.live_blobs(), Ordering::Relaxed);
        self.cache_bytes.store(store.live_bytes(), Ordering::Relaxed);
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} batches={} mean_batch={:.2} \
             fused_levels={} fused_pbs={} fused_blind_rotations={} worker_panics={} \
             respawns={} retries={} quarantined={} deadline_kills={} shutdown_drained={} \
             decode_steps={} cache_blobs_live={} cache_bytes={} \
             stolen_jobs={} fused_keys={} worker_utilization={:.3} \
             storage_evictions={} storage_rehydrations={} storage_hit_rate={:.3} \
             cold_key_attaches={} \
             radix_limbs={} carry_rotations={} \
             mean_latency={} p50={} p99={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.fused_levels.load(Ordering::Relaxed),
            self.fused_pbs.load(Ordering::Relaxed),
            self.fused_blind_rotations.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.respawns.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.quarantined.load(Ordering::Relaxed),
            self.deadline_kills.load(Ordering::Relaxed),
            self.shutdown_drained.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.cache_blobs_live.load(Ordering::Relaxed),
            self.cache_bytes.load(Ordering::Relaxed),
            self.stolen_jobs.load(Ordering::Relaxed),
            self.fused_keys.load(Ordering::Relaxed),
            self.worker_utilization(),
            self.storage.evictions.load(Ordering::Relaxed),
            self.storage.rehydrations.load(Ordering::Relaxed),
            self.storage.hit_rate(),
            self.storage.cold_key_attaches.load(Ordering::Relaxed),
            self.radix_limbs.load(Ordering::Relaxed),
            self.carry_rotations.load(Ordering::Relaxed),
            crate::bench_harness::Measurement::fmt_time(self.latency.mean_s()),
            crate::bench_harness::Measurement::fmt_time(self.latency.quantile_s(0.5)),
            crate::bench_harness::Measurement::fmt_time(self.latency.quantile_s(0.99)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record(0.001); // 1 ms
        }
        for _ in 0..10 {
            h.record(0.1); // 100 ms
        }
        assert_eq!(h.count(), 100);
        assert!(h.mean_s() > 0.009 && h.mean_s() < 0.012, "{}", h.mean_s());
        assert!(h.quantile_s(0.5) < 0.005);
        assert!(h.quantile_s(0.99) > 0.05);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-9);
        assert!(m.summary().contains("mean_batch=2.50"));
    }

    #[test]
    fn storage_hit_rate_and_summary_fields() {
        let m = Metrics::new();
        assert!((m.storage.hit_rate() - 1.0).abs() < 1e-9, "no traffic reads as all-hot");
        m.storage.hits.store(3, Ordering::Relaxed);
        m.storage.misses.store(1, Ordering::Relaxed);
        m.storage.evictions.store(2, Ordering::Relaxed);
        m.storage.rehydrations.store(1, Ordering::Relaxed);
        m.storage.key_attach.record(0.01);
        let s = m.summary();
        assert!(s.contains("storage_evictions=2"), "{s}");
        assert!(s.contains("storage_rehydrations=1"), "{s}");
        assert!(s.contains("storage_hit_rate=0.750"), "{s}");
        assert!(s.contains("cold_key_attaches=0"), "{s}");
    }

    #[test]
    fn record_radix_accumulates_limbs_and_carry_rotations() {
        use crate::tfhe::radix::{RadixInfo, RadixSpec};
        let m = Metrics::new();
        let info = RadixInfo {
            spec: RadixSpec::new(3, 3, 6),
            widened: 4,
            carry_luts: 10,
            carry_rotations: 6,
            wide_outputs: 2,
        };
        m.record_radix(&info);
        m.record_radix(&info);
        assert_eq!(m.radix_limbs.load(Ordering::Relaxed), 24, "2 × widened·limbs");
        assert_eq!(m.carry_rotations.load(Ordering::Relaxed), 12);
        let s = m.summary();
        assert!(s.contains("radix_limbs=24"), "{s}");
        assert!(s.contains("carry_rotations=12"), "{s}");
    }

    #[test]
    fn record_fused_accumulates_counters_and_key_high_water() {
        use crate::coordinator::fused::FusedStats;
        let m = Metrics::new();
        let first = FusedStats {
            level_batch_sizes: vec![4, 2],
            pbs_total: 6,
            blind_rotations: 6,
            stolen_jobs: 3,
            fused_keys: 2,
            busy_ns: 600,
            capacity_ns: 1_000,
            ..FusedStats::default()
        };
        let second = FusedStats {
            level_batch_sizes: vec![5],
            pbs_total: 5,
            blind_rotations: 4,
            stolen_jobs: 1,
            fused_keys: 1,
            busy_ns: 200,
            capacity_ns: 1_000,
            ..FusedStats::default()
        };
        m.record_fused(&first);
        m.record_fused(&second);
        assert_eq!(m.fused_levels.load(Ordering::Relaxed), 3);
        assert_eq!(m.fused_pbs.load(Ordering::Relaxed), 11);
        assert_eq!(m.fused_blind_rotations.load(Ordering::Relaxed), 10);
        assert_eq!(m.stolen_jobs.load(Ordering::Relaxed), 4);
        // High-water, not sum: a later single-key sweep must not erase
        // the evidence that a sweep served two keys.
        assert_eq!(m.fused_keys.load(Ordering::Relaxed), 2);
        assert!((m.worker_utilization() - 0.4).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("stolen_jobs=4"), "{s}");
        assert!(s.contains("fused_keys=2"), "{s}");
        assert!(s.contains("worker_utilization=0.400"), "{s}");
    }
}
