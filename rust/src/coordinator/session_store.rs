//! Session-persistent ciphertext state for incremental decode (S7): the
//! coordinator-side store of decode **cache bundles**, keyed by
//! `(session, stream_id)`, held between requests of one token stream.
//!
//! A decode stream's KV-cache never leaves the server: prefill deposits
//! the bundle here, every step `take`s it (by move — the scheduler
//! threads it into by-ref plan execution without cloning a single
//! ciphertext), and the successor bundle is `put` back under the same
//! stream id. Abandonment (deadline, fault, panic) uses [`restore`] to
//! roll the *pre-step* bundle back so a resubmit is exact — the same
//! contract `keymgr::Session::restore` gives victim request bundles.
//!
//! Hygiene: live bundles are capped **per session**
//! ([`SessionStore::put`] returns [`FheError::CacheOverflow`] past the
//! cap), the `release_cache` wire op drops a stream's bundle
//! explicitly, and the `cache_blobs_live`/`cache_bytes` gauges in
//! `coordinator::metrics` track the store's footprint.
//!
//! [`restore`]: SessionStore::restore

use crate::error::FheError;
use crate::tfhe::ops::CtInt;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default cap on live cache bundles per session.
pub const DEFAULT_CACHE_CAP: usize = 8;

/// One stream's persisted decode state: the cache bundle in the
/// canonical `fhe_circuits::decode` layout plus its prefix length.
pub struct CacheEntry {
    pub cts: Vec<CtInt>,
    /// Positions the bundle encodes (the step plan key).
    pub cached_len: usize,
}

/// Store state behind one lock: the stream map plus the gauges derived
/// from it. Counts and bytes are maintained *incrementally* on every
/// mutation — `put`/`take`/`restore`/`release` each adjust them by the
/// touched entry only — so the per-session cap check and the
/// `live_bytes` gauge are O(1) instead of rescanning every live bundle
/// under the lock.
struct Inner {
    streams: HashMap<(u64, u64), CacheEntry>,
    /// Live-bundle count per session (entries removed at zero, so the
    /// map never outgrows the set of sessions with live state).
    per_session: HashMap<u64, usize>,
    /// Running ciphertext-byte total across all live bundles.
    bytes: u64,
}

impl Inner {
    /// Account one bundle entering the store.
    fn credit(&mut self, session: u64, entry: &CacheEntry) {
        *self.per_session.entry(session).or_insert(0) += 1;
        self.bytes += entry_bytes(entry);
    }

    /// Account one bundle leaving the store.
    fn debit(&mut self, session: u64, entry: &CacheEntry) {
        let n = self.per_session.get_mut(&session).expect("session has live bundles");
        *n -= 1;
        if *n == 0 {
            self.per_session.remove(&session);
        }
        self.bytes -= entry_bytes(entry);
    }
}

/// The `(session, stream)`-keyed cache-bundle store (see module docs).
pub struct SessionStore {
    inner: Mutex<Inner>,
    max_per_session: AtomicUsize,
}

impl SessionStore {
    pub fn new(max_per_session: usize) -> Self {
        SessionStore {
            inner: Mutex::new(Inner {
                streams: HashMap::new(),
                per_session: HashMap::new(),
                bytes: 0,
            }),
            max_per_session: AtomicUsize::new(max_per_session),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Adjust the per-session live-bundle cap (operational knob; tests
    /// use it to drive overflow cheaply).
    pub fn set_cache_cap(&self, cap: usize) {
        self.max_per_session.store(cap, Ordering::Relaxed);
    }

    /// Deposit a stream's bundle. Replacing the same stream's bundle is
    /// always allowed; opening a *new* stream past the per-session cap
    /// fails with [`FheError::CacheOverflow`] (the bundle is dropped —
    /// the caller owns rollback of anything it consumed first).
    pub fn put(
        &self,
        session: u64,
        stream: u64,
        cts: Vec<CtInt>,
        cached_len: usize,
    ) -> Result<(), FheError> {
        let mut inner = self.lock();
        let key = (session, stream);
        if !inner.streams.contains_key(&key) {
            let live = inner.per_session.get(&session).copied().unwrap_or(0);
            let cap = self.max_per_session.load(Ordering::Relaxed);
            if live >= cap {
                return Err(FheError::CacheOverflow(format!(
                    "session {session} already holds {live} live cache bundles (cap {cap}); \
                     release_cache a stream before opening another"
                )));
            }
        }
        let entry = CacheEntry { cts, cached_len };
        inner.credit(session, &entry);
        if let Some(old) = inner.streams.insert(key, entry) {
            inner.debit(session, &old);
        }
        Ok(())
    }

    /// Consume a stream's bundle (by move — the executor reads the
    /// ciphertexts by reference, so nothing is ever cloned).
    pub fn take(&self, session: u64, stream: u64) -> Option<CacheEntry> {
        let mut inner = self.lock();
        let entry = inner.streams.remove(&(session, stream))?;
        inner.debit(session, &entry);
        Some(entry)
    }

    /// Roll a consumed bundle back after an abandoned step (deadline,
    /// fault, panic) so a resubmit is exact. Never cap-checked: the
    /// entry was live moments ago and rollback must not fail.
    pub fn restore(&self, session: u64, stream: u64, entry: CacheEntry) {
        let mut inner = self.lock();
        inner.credit(session, &entry);
        if let Some(old) = inner.streams.insert((session, stream), entry) {
            inner.debit(session, &old);
        }
    }

    /// Drop a stream's bundle explicitly (the `release_cache` wire op);
    /// `true` if one existed.
    pub fn release(&self, session: u64, stream: u64) -> bool {
        self.take(session, stream).is_some()
    }

    /// Live bundles across all sessions (the `cache_blobs_live` gauge).
    pub fn live_blobs(&self) -> u64 {
        self.lock().streams.len() as u64
    }

    /// Approximate ciphertext bytes held live (the `cache_bytes` gauge):
    /// LWE mask + body words per cached ciphertext. O(1) — read off the
    /// running total, not recomputed by walking the store.
    pub fn live_bytes(&self) -> u64 {
        self.lock().bytes
    }
}

impl Default for SessionStore {
    fn default() -> Self {
        Self::new(DEFAULT_CACHE_CAP)
    }
}

/// Heap bytes of one LWE ciphertext (mask words + body word).
fn ct_bytes(ct: &CtInt) -> u64 {
    ((ct.ct.mask.len() + 1) * std::mem::size_of::<u64>()) as u64
}

/// Heap bytes of one cache bundle — the unit the running byte gauge is
/// credited/debited in.
fn entry_bytes(entry: &CacheEntry) -> u64 {
    entry.cts.iter().map(ct_bytes).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::tfhe::ops::FheContext;
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Xoshiro256;

    fn some_cts(n: usize) -> (FheContext, Vec<CtInt>) {
        let mut rng = Xoshiro256::new(5);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let cts = (0..n).map(|i| ctx.encrypt(i as i64 % 3, &ck, &mut rng)).collect();
        (ctx, cts)
    }

    #[test]
    fn put_take_restore_release_lifecycle() {
        let (_ctx, cts) = some_cts(4);
        let store = SessionStore::new(4);
        assert!(store.put(1, 10, cts, 2).is_ok());
        assert_eq!(store.live_blobs(), 1);
        assert!(store.live_bytes() > 0);
        let entry = store.take(1, 10).expect("bundle exists");
        assert_eq!(entry.cached_len, 2);
        assert_eq!(entry.cts.len(), 4);
        assert!(store.take(1, 10).is_none(), "take consumes");
        assert_eq!(store.live_blobs(), 0);
        store.restore(1, 10, entry);
        assert_eq!(store.live_blobs(), 1);
        assert!(store.release(1, 10));
        assert!(!store.release(1, 10), "release is idempotent-false");
        assert_eq!(store.live_bytes(), 0);
    }

    #[test]
    fn per_session_cap_is_enforced_and_typed() {
        let store = SessionStore::new(2);
        let (_ctx, cts) = some_cts(6);
        let mut cts = cts.into_iter();
        let two = |it: &mut dyn Iterator<Item = CtInt>| it.by_ref().take(2).collect::<Vec<_>>();
        assert!(store.put(1, 1, two(&mut cts), 1).is_ok());
        assert!(store.put(1, 2, two(&mut cts), 1).is_ok());
        let err = store.put(1, 3, two(&mut cts), 1).unwrap_err();
        assert_eq!(err.code(), "cache_overflow", "{err}");
        // Replacing a live stream is not an "open".
        assert!(store.put(1, 2, Vec::new(), 0).is_ok());
        // Other sessions have their own budget.
        assert!(store.put(2, 1, Vec::new(), 0).is_ok());
        // Raising the cap unblocks.
        store.set_cache_cap(3);
        assert!(store.put(1, 3, Vec::new(), 0).is_ok());
    }

    /// Pins the incremental gauge accounting: after every randomized
    /// `put`/`take`/`restore`/`release`, the store's O(1) `live_blobs`
    /// and `live_bytes` gauges must equal a full recompute over a shadow
    /// copy of the live entries — including across cap rejections
    /// (which must leave the gauges untouched) and same-stream
    /// replacements (which must debit the evicted bundle).
    #[test]
    fn gauges_match_full_recompute_across_randomized_lifecycle() {
        use crate::util::prng::Rng64;
        let (_ctx, pool) = some_cts(3);
        let bundle = |n: usize| -> Vec<CtInt> { pool.iter().take(n).cloned().collect() };
        let store = SessionStore::new(2);
        // Shadow of the live entries: key -> ciphertext count, recomputed
        // from scratch after every operation.
        let mut shadow: HashMap<(u64, u64), usize> = HashMap::new();
        let per_ct = ct_bytes(&pool[0]);
        let mut rng = Xoshiro256::new(42);
        let mut taken: Vec<(u64, u64, CacheEntry)> = Vec::new();
        let mut saw_live = false;
        for _ in 0..400 {
            let session = rng.next_u64() % 3;
            let stream = rng.next_u64() % 4;
            let n = (rng.next_u64() % 4) as usize;
            match rng.next_u64() % 4 {
                0 => {
                    let live = shadow.keys().filter(|(s, _)| *s == session).count();
                    let opens = !shadow.contains_key(&(session, stream));
                    let res = store.put(session, stream, bundle(n), n);
                    if opens && live >= 2 {
                        assert_eq!(res.unwrap_err().code(), "cache_overflow");
                    } else {
                        res.expect("under cap");
                        shadow.insert((session, stream), n);
                    }
                }
                1 => {
                    let entry = store.take(session, stream);
                    assert_eq!(entry.is_some(), shadow.remove(&(session, stream)).is_some());
                    if let Some(entry) = entry {
                        taken.push((session, stream, entry));
                    }
                }
                2 => {
                    if let Some((s, t, entry)) = taken.pop() {
                        shadow.insert((s, t), entry.cts.len());
                        store.restore(s, t, entry);
                    }
                }
                _ => {
                    assert_eq!(
                        store.release(session, stream),
                        shadow.remove(&(session, stream)).is_some()
                    );
                }
            }
            assert_eq!(store.live_blobs(), shadow.len() as u64);
            let expect_bytes: u64 = shadow.values().map(|&n| n as u64 * per_ct).sum();
            assert_eq!(store.live_bytes(), expect_bytes);
            saw_live = saw_live || !shadow.is_empty();
        }
        assert!(saw_live, "lifecycle exercised live state");
    }
}
