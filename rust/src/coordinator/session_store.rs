//! Session-persistent ciphertext state for incremental decode (S7): the
//! coordinator-side store of decode **cache bundles**, keyed by
//! `(session, stream_id)`, held between requests of one token stream.
//!
//! A decode stream's KV-cache never leaves the server: prefill deposits
//! the bundle here, every step `take`s it (by move — the scheduler
//! threads it into by-ref plan execution without cloning a single
//! ciphertext), and the successor bundle is `put` back under the same
//! stream id. Abandonment (deadline, fault, panic) uses [`restore`] to
//! roll the *pre-step* bundle back so a resubmit is exact — the same
//! contract `keymgr::Session::restore` gives victim request bundles.
//!
//! Since S9 the bundles live in a [`CtStore`] spill tier under the
//! `"cache"` namespace: bundles past the hot byte budget are encoded and
//! spilled to the configured [`BlobSink`], and a `take` of a spilled
//! bundle rehydrates it bit-identically (PBS is deterministic, so a
//! stream served through disk equals one served all-in-memory — pinned
//! by `tests/decode_it.rs`). Gauges (`cache_blobs_live`/`cache_bytes`)
//! count hot + spilled state uniformly.
//!
//! Hygiene: live bundles are capped **per session**
//! ([`SessionStore::put`] returns [`FheError::CacheOverflow`] past the
//! cap), the `release_cache` wire op drops a stream's bundle explicitly,
//! and session teardown calls [`SessionStore::release_session`] so a
//! dropped session leaves zero bundles and zero bytes behind.
//!
//! [`restore`]: SessionStore::restore
//! [`BlobSink`]: crate::coordinator::storage::BlobSink

use crate::coordinator::storage::{ct_bytes, Bundle, CtStore, DEFAULT_STORAGE_BUDGET};
use crate::error::FheError;
use crate::tfhe::ops::CtInt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Default cap on live cache bundles per session.
pub const DEFAULT_CACHE_CAP: usize = 8;

/// One stream's persisted decode state: the cache bundle in the
/// canonical `fhe_circuits::decode` layout plus its prefix length.
pub struct CacheEntry {
    pub cts: Vec<CtInt>,
    /// Positions the bundle encodes (the step plan key).
    pub cached_len: usize,
}

/// The `(session, stream)`-keyed cache-bundle store (see module docs) —
/// a per-session-capped facade over the `"cache"` storage tier.
pub struct SessionStore {
    store: Arc<CtStore>,
    max_per_session: AtomicUsize,
}

impl SessionStore {
    /// A store over a private in-memory tier with the default budget
    /// (never spills in practice — tests and small deployments).
    pub fn new(max_per_session: usize) -> Self {
        Self::with_store(
            max_per_session,
            Arc::new(CtStore::with_memory("cache", DEFAULT_STORAGE_BUDGET)),
        )
    }

    /// A store over an externally wired tier (shared sink, shared
    /// metrics) — how the coordinator builds it.
    pub fn with_store(max_per_session: usize, store: Arc<CtStore>) -> Self {
        SessionStore { store, max_per_session: AtomicUsize::new(max_per_session) }
    }

    /// The underlying spill tier (tests reach through for eviction
    /// counters and budget control).
    pub fn storage(&self) -> &Arc<CtStore> {
        &self.store
    }

    /// Adjust the per-session live-bundle cap (operational knob; tests
    /// use it to drive overflow cheaply).
    pub fn set_cache_cap(&self, cap: usize) {
        self.max_per_session.store(cap, Ordering::Relaxed);
    }

    /// Adjust the hot-tier byte budget (0 = spill every bundle).
    pub fn set_storage_budget(&self, bytes: u64) {
        self.store.set_budget(bytes);
    }

    /// Deposit a stream's bundle. Replacing the same stream's bundle is
    /// always allowed; opening a *new* stream past the per-session cap
    /// fails with [`FheError::CacheOverflow`] (the bundle is dropped —
    /// the caller owns rollback of anything it consumed first).
    pub fn put(
        &self,
        session: u64,
        stream: u64,
        cts: Vec<CtInt>,
        cached_len: usize,
    ) -> Result<(), FheError> {
        let cap = self.max_per_session.load(Ordering::Relaxed);
        self.store.try_insert(
            session,
            stream,
            Bundle { cts, meta: cached_len as u64 },
            cap,
            "cache bundles",
            "release_cache a stream before opening another",
        )
    }

    /// Consume a stream's bundle (by move — the executor reads the
    /// ciphertexts by reference, so nothing is ever cloned). Collapses
    /// storage-tier failures to `None`; the serving path uses
    /// [`Self::try_take`] to keep them typed.
    pub fn take(&self, session: u64, stream: u64) -> Option<CacheEntry> {
        self.try_take(session, stream).ok().flatten()
    }

    /// Consume a stream's bundle, rehydrating from the sink if it was
    /// spilled. `Ok(None)` if the stream holds nothing;
    /// `Err(`[`FheError::Storage`]`)` if it exists but its cold bytes
    /// are missing or corrupt.
    pub fn try_take(&self, session: u64, stream: u64) -> Result<Option<CacheEntry>, FheError> {
        Ok(self
            .store
            .try_take(session, stream)?
            .map(|b| CacheEntry { cached_len: b.meta as usize, cts: b.cts }))
    }

    /// Roll a consumed bundle back after an abandoned step (deadline,
    /// fault, panic) so a resubmit is exact. Never cap-checked: the
    /// entry was live moments ago and rollback must not fail.
    pub fn restore(&self, session: u64, stream: u64, entry: CacheEntry) {
        self.store.insert(
            session,
            stream,
            Bundle { cts: entry.cts, meta: entry.cached_len as u64 },
        );
    }

    /// Drop a stream's bundle explicitly (the `release_cache` wire op);
    /// `true` if one existed.
    pub fn release(&self, session: u64, stream: u64) -> bool {
        self.store.release(session, stream)
    }

    /// Drop *all* of a session's bundles — hot, spilled, and sink bytes
    /// — plus its per-session counter entry. Called from session
    /// teardown (`Coordinator::drop_session`); returns how many streams
    /// were released.
    pub fn release_session(&self, session: u64) -> usize {
        self.store.release_session(session)
    }

    /// Live bundles across all sessions, hot + spilled (the
    /// `cache_blobs_live` gauge).
    pub fn live_blobs(&self) -> u64 {
        self.store.live_blobs()
    }

    /// Approximate ciphertext bytes held live (the `cache_bytes` gauge):
    /// LWE mask + body words per cached ciphertext, counted identically
    /// for hot and spilled bundles. O(1) — read off the tier's running
    /// totals, not recomputed by walking the store.
    pub fn live_bytes(&self) -> u64 {
        self.store.live_bytes()
    }
}

impl Default for SessionStore {
    fn default() -> Self {
        Self::new(DEFAULT_CACHE_CAP)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::tfhe::ops::FheContext;
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Xoshiro256;
    use std::collections::HashMap;

    fn some_cts(n: usize) -> (FheContext, Vec<CtInt>) {
        let mut rng = Xoshiro256::new(5);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let cts = (0..n).map(|i| ctx.encrypt(i as i64 % 3, &ck, &mut rng)).collect();
        (ctx, cts)
    }

    #[test]
    fn put_take_restore_release_lifecycle() {
        let (_ctx, cts) = some_cts(4);
        let store = SessionStore::new(4);
        assert!(store.put(1, 10, cts, 2).is_ok());
        assert_eq!(store.live_blobs(), 1);
        assert!(store.live_bytes() > 0);
        let entry = store.take(1, 10).expect("bundle exists");
        assert_eq!(entry.cached_len, 2);
        assert_eq!(entry.cts.len(), 4);
        assert!(store.take(1, 10).is_none(), "take consumes");
        assert_eq!(store.live_blobs(), 0);
        store.restore(1, 10, entry);
        assert_eq!(store.live_blobs(), 1);
        assert!(store.release(1, 10));
        assert!(!store.release(1, 10), "release is idempotent-false");
        assert_eq!(store.live_bytes(), 0);
    }

    #[test]
    fn per_session_cap_is_enforced_and_typed() {
        let store = SessionStore::new(2);
        let (_ctx, cts) = some_cts(6);
        let mut cts = cts.into_iter();
        let two = |it: &mut dyn Iterator<Item = CtInt>| it.by_ref().take(2).collect::<Vec<_>>();
        assert!(store.put(1, 1, two(&mut cts), 1).is_ok());
        assert!(store.put(1, 2, two(&mut cts), 1).is_ok());
        let err = store.put(1, 3, two(&mut cts), 1).unwrap_err();
        assert_eq!(err.code(), "cache_overflow", "{err}");
        // Replacing a live stream is not an "open".
        assert!(store.put(1, 2, Vec::new(), 0).is_ok());
        // Other sessions have their own budget.
        assert!(store.put(2, 1, Vec::new(), 0).is_ok());
        // Raising the cap unblocks.
        store.set_cache_cap(3);
        assert!(store.put(1, 3, Vec::new(), 0).is_ok());
    }

    #[test]
    fn release_session_drops_every_stream_and_all_bytes() {
        let (_ctx, cts) = some_cts(4);
        let mut it = cts.into_iter();
        let mut two = || -> Vec<CtInt> { it.by_ref().take(2).collect() };
        let store = SessionStore::new(4);
        store.put(1, 1, two(), 1).unwrap();
        store.put(1, 2, two(), 2).unwrap();
        store.put(2, 1, Vec::new(), 0).unwrap();
        assert_eq!(store.release_session(1), 2);
        assert_eq!(store.live_blobs(), 1, "other sessions untouched");
        assert!(store.take(1, 1).is_none());
        assert!(store.take(1, 2).is_none());
        assert_eq!(store.release_session(1), 0, "idempotent");
        // The freed cap is actually reusable.
        store.set_cache_cap(1);
        assert!(store.put(1, 9, Vec::new(), 0).is_ok());
    }

    #[test]
    fn spilled_streams_rehydrate_bit_identically_through_the_facade() {
        let (_ctx, cts) = some_cts(3);
        let originals: Vec<_> = cts.iter().map(|c| c.ct.clone()).collect();
        let store = SessionStore::new(4);
        store.set_storage_budget(0);
        store.put(1, 7, cts, 3).unwrap();
        assert_eq!(store.storage().spilled_blobs(), 1, "zero budget spills the bundle");
        assert_eq!(store.live_blobs(), 1, "spilled is still live");
        let entry = store.try_take(1, 7).unwrap().expect("rehydrates");
        assert_eq!(entry.cached_len, 3);
        for (a, b) in entry.cts.iter().zip(&originals) {
            assert_eq!(&a.ct, b, "bit-identical after spill + rehydrate");
        }
        assert_eq!(store.storage().metrics().rehydrations.load(Ordering::Relaxed), 1);
    }

    /// Pins the incremental gauge accounting: after every randomized
    /// `put`/`take`/`restore`/`release`, the store's O(1) `live_blobs`
    /// and `live_bytes` gauges must equal a full recompute over a shadow
    /// copy of the live entries — including across cap rejections
    /// (which must leave the gauges untouched) and same-stream
    /// replacements (which must debit the evicted bundle).
    #[test]
    fn gauges_match_full_recompute_across_randomized_lifecycle() {
        use crate::util::prng::Rng64;
        let (_ctx, pool) = some_cts(3);
        let bundle = |n: usize| -> Vec<CtInt> { pool.iter().take(n).cloned().collect() };
        let per_ct = ct_bytes(&pool[0]);
        // Exercise the same lifecycle twice: all-hot (default budget)
        // and all-spilled (zero budget). The gauges must not notice.
        for budget in [DEFAULT_STORAGE_BUDGET, 0] {
            let store = SessionStore::new(2);
            store.set_storage_budget(budget);
            // Shadow of the live entries: key -> ciphertext count,
            // recomputed from scratch after every operation.
            let mut shadow: HashMap<(u64, u64), usize> = HashMap::new();
            let mut rng = Xoshiro256::new(42);
            let mut taken: Vec<(u64, u64, CacheEntry)> = Vec::new();
            let mut saw_live = false;
            for _ in 0..400 {
                let session = rng.next_u64() % 3;
                let stream = rng.next_u64() % 4;
                let n = (rng.next_u64() % 4) as usize;
                match rng.next_u64() % 4 {
                    0 => {
                        let live = shadow.keys().filter(|(s, _)| *s == session).count();
                        let opens = !shadow.contains_key(&(session, stream));
                        let res = store.put(session, stream, bundle(n), n);
                        if opens && live >= 2 {
                            assert_eq!(res.unwrap_err().code(), "cache_overflow");
                        } else {
                            res.expect("under cap");
                            shadow.insert((session, stream), n);
                        }
                    }
                    1 => {
                        let entry = store.take(session, stream);
                        assert_eq!(entry.is_some(), shadow.remove(&(session, stream)).is_some());
                        if let Some(entry) = entry {
                            taken.push((session, stream, entry));
                        }
                    }
                    2 => {
                        if let Some((s, t, entry)) = taken.pop() {
                            shadow.insert((s, t), entry.cts.len());
                            store.restore(s, t, entry);
                        }
                    }
                    _ => {
                        assert_eq!(
                            store.release(session, stream),
                            shadow.remove(&(session, stream)).is_some()
                        );
                    }
                }
                assert_eq!(store.live_blobs(), shadow.len() as u64, "budget={budget}");
                let expect_bytes: u64 = shadow.values().map(|&n| n as u64 * per_ct).sum();
                assert_eq!(store.live_bytes(), expect_bytes, "budget={budget}");
                saw_live = saw_live || !shadow.is_empty();
            }
            assert!(saw_live, "lifecycle exercised live state");
            if budget == 0 {
                let m = store.storage().metrics();
                assert!(m.evictions.load(Ordering::Relaxed) > 0, "zero budget forced spills");
                assert!(m.rehydrations.load(Ordering::Relaxed) > 0, "takes rehydrated");
            }
        }
    }
}
