//! The LRU spill tier (S9): a byte-budgeted hot cache of decoded
//! ciphertext bundles in front of a [`BlobSink`]. Bundles over budget
//! are encoded (`tfhe::codec`) and spilled coldest-first; a `take` of a
//! spilled bundle rehydrates it transparently — and bit-identically,
//! which is the property the differential tests pin (PBS is
//! deterministic, so a decode stream served through disk must equal one
//! served all-in-memory).
//!
//! One [`CtStore`] instance backs each of the two coordinator stores
//! (`keymgr::Session` result blobs under the `"blob"` namespace, the
//! decode `SessionStore` under `"cache"`), typically sharing one sink —
//! eviction, rehydration, and session teardown all flow through this
//! single accounting path, so the liveness gauges cannot drift from the
//! store (the pre-S9 leak class).
//!
//! Accounting is *logical*: `live_bytes` counts decoded ciphertext bytes
//! (mask + body words) whether a bundle is hot or spilled, so the
//! `cache_bytes` gauge reads the same for a spilled and an in-memory
//! run. `live_blobs` likewise counts hot + spilled. Sink I/O happens
//! under the tier lock — the spill path trades a wider critical section
//! for crash-consistent accounting (a bundle is never half-moved).

use super::lru::LruIndex;
use super::sink::{BlobSink, MemorySink};
use crate::coordinator::metrics::StorageMetrics;
use crate::error::FheError;
use crate::tfhe::codec::{decode_bundle, CtCodec};
use crate::tfhe::ops::CtInt;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default hot-tier byte budget: 256 MiB of decoded ciphertext. Large
/// enough that unit tests and single-session serving never spill unless
/// a test (or `FHE_STORAGE_BUDGET`) forces it.
pub const DEFAULT_STORAGE_BUDGET: u64 = 256 * 1024 * 1024;

/// A stored unit: a ciphertext bundle plus one caller-owned metadata
/// word (the decode cache keeps its `cached_len` here; result blobs
/// leave it zero).
pub struct Bundle {
    pub cts: Vec<CtInt>,
    pub meta: u64,
}

/// Tier state behind one lock. Gauges are maintained incrementally on
/// every mutation (same discipline the pre-S9 `SessionStore` pinned with
/// its randomized shadow test, which now runs against this path).
struct TierInner {
    hot: HashMap<(u64, u64), Bundle>,
    lru: LruIndex<(u64, u64)>,
    /// Spilled keys → their *logical* (decoded) byte size.
    spilled: HashMap<(u64, u64), u64>,
    hot_bytes: u64,
    spilled_bytes: u64,
    /// Live entries (hot + spilled) per session; removed at zero.
    per_session: HashMap<u64, usize>,
    /// Reusable encoder — spilling allocates nothing once warm.
    codec: CtCodec,
}

impl TierInner {
    fn inc_session(&mut self, session: u64) {
        *self.per_session.entry(session).or_insert(0) += 1;
    }

    fn dec_session(&mut self, session: u64) {
        if let Some(n) = self.per_session.get_mut(&session) {
            *n -= 1;
            if *n == 0 {
                self.per_session.remove(&session);
            }
        }
    }
}

/// Byte-budgeted LRU store of ciphertext bundles over a [`BlobSink`]
/// (see module docs).
pub struct CtStore {
    /// Key-grammar prefix: `"{namespace}/{session}/{id}"`.
    namespace: &'static str,
    sink: Arc<dyn BlobSink>,
    metrics: Arc<StorageMetrics>,
    budget_bytes: AtomicU64,
    inner: Mutex<TierInner>,
}

impl CtStore {
    pub fn new(
        namespace: &'static str,
        sink: Arc<dyn BlobSink>,
        metrics: Arc<StorageMetrics>,
        budget_bytes: u64,
    ) -> Self {
        CtStore {
            namespace,
            sink,
            metrics,
            budget_bytes: AtomicU64::new(budget_bytes),
            inner: Mutex::new(TierInner {
                hot: HashMap::new(),
                lru: LruIndex::new(),
                spilled: HashMap::new(),
                hot_bytes: 0,
                spilled_bytes: 0,
                per_session: HashMap::new(),
                codec: CtCodec::new(),
            }),
        }
    }

    /// Convenience: a memory-sink tier with private metrics (the default
    /// wiring when no disk root is configured).
    pub fn with_memory(namespace: &'static str, budget_bytes: u64) -> Self {
        CtStore::new(
            namespace,
            Arc::new(MemorySink::new()),
            Arc::new(StorageMetrics::default()),
            budget_bytes,
        )
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TierInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn skey(&self, session: u64, id: u64) -> String {
        format!("{}/{session}/{id}", self.namespace)
    }

    /// The backing sink (the key-manager parks serialized server keys in
    /// it directly, outside the bundle namespaces).
    pub fn sink(&self) -> &Arc<dyn BlobSink> {
        &self.sink
    }

    pub fn metrics(&self) -> &Arc<StorageMetrics> {
        &self.metrics
    }

    /// Adjust the hot-tier byte budget; the next insert spills down to
    /// it. `0` forces eviction on every insert (the CI tiny-budget leg).
    pub fn set_budget(&self, bytes: u64) {
        self.budget_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn budget(&self) -> u64 {
        self.budget_bytes.load(Ordering::Relaxed)
    }

    /// Deposit a bundle unconditionally (rollback/`restore` path — the
    /// entry was live moments ago and rollback must not fail).
    pub fn insert(&self, session: u64, id: u64, bundle: Bundle) {
        let mut inner = self.lock();
        self.insert_locked(&mut inner, session, id, bundle);
    }

    /// Deposit a bundle, enforcing a per-session live-entry cap
    /// atomically under the tier lock. Replacing a live key is always
    /// allowed; opening a *new* key past `cap` fails with
    /// [`FheError::CacheOverflow`] (the bundle is dropped — the caller
    /// owns rollback of anything it consumed first). `what`/`hint`
    /// flavor the error for the two namespaces.
    pub fn try_insert(
        &self,
        session: u64,
        id: u64,
        bundle: Bundle,
        cap: usize,
        what: &str,
        hint: &str,
    ) -> Result<(), FheError> {
        let mut inner = self.lock();
        let key = (session, id);
        if !inner.hot.contains_key(&key) && !inner.spilled.contains_key(&key) {
            let live = inner.per_session.get(&session).copied().unwrap_or(0);
            if live >= cap {
                return Err(FheError::CacheOverflow(format!(
                    "session {session} already holds {live} live {what} (cap {cap}); {hint}"
                )));
            }
        }
        self.insert_locked(&mut inner, session, id, bundle);
        Ok(())
    }

    fn insert_locked(&self, inner: &mut TierInner, session: u64, id: u64, bundle: Bundle) {
        let key = (session, id);
        let bytes = bundle_bytes(&bundle);
        // Drop any previous incarnation of this key (replace semantics).
        if let Some(old) = inner.hot.remove(&key) {
            inner.lru.remove(&key);
            inner.hot_bytes -= bundle_bytes(&old);
            inner.dec_session(session);
        } else if let Some(old_bytes) = inner.spilled.remove(&key) {
            inner.spilled_bytes -= old_bytes;
            inner.dec_session(session);
            // Best-effort: a stale sink blob under a replaced key is
            // garbage, not state.
            let _ = self.sink.delete(&self.skey(session, id));
        }
        inner.hot.insert(key, bundle);
        inner.lru.touch(key);
        inner.hot_bytes += bytes;
        inner.inc_session(session);
        self.spill_over_budget(inner);
    }

    /// Spill coldest-first until the hot tier fits the budget. A sink
    /// failure keeps the victim hot (state is never dropped to meet a
    /// budget) and stops the pass.
    fn spill_over_budget(&self, inner: &mut TierInner) {
        let budget = self.budget_bytes.load(Ordering::Relaxed);
        while inner.hot_bytes > budget {
            let Some(key) = inner.lru.pop_oldest() else { break };
            let Some(bundle) = inner.hot.remove(&key) else { break };
            let bytes = bundle_bytes(&bundle);
            let encoded = inner.codec.encode_bundle(&bundle.cts, bundle.meta);
            match self.sink.put(&self.skey(key.0, key.1), encoded) {
                Ok(()) => {
                    inner.hot_bytes -= bytes;
                    inner.spilled.insert(key, bytes);
                    inner.spilled_bytes += bytes;
                    self.metrics.evictions.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {
                    let hot_again = Bundle { cts: bundle.cts, meta: bundle.meta };
                    inner.hot.insert(key, hot_again);
                    inner.lru.touch(key);
                    break;
                }
            }
        }
    }

    /// Consume an entry by move, rehydrating transparently if it was
    /// spilled. `Ok(None)` if the key holds nothing; `Err(Storage)` if
    /// the entry exists but its spilled bytes are missing or corrupt (the
    /// spilled record is kept, so a sink that recovers can still serve a
    /// retry).
    pub fn try_take(&self, session: u64, id: u64) -> Result<Option<Bundle>, FheError> {
        let mut inner = self.lock();
        let key = (session, id);
        if let Some(bundle) = inner.hot.remove(&key) {
            inner.lru.remove(&key);
            inner.hot_bytes -= bundle_bytes(&bundle);
            inner.dec_session(session);
            self.metrics.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Some(bundle));
        }
        let Some(&bytes) = inner.spilled.get(&key) else {
            return Ok(None);
        };
        self.metrics.misses.fetch_add(1, Ordering::Relaxed);
        let skey = self.skey(session, id);
        let raw = self
            .sink
            .get(&skey)?
            .ok_or_else(|| FheError::Storage(format!("spilled blob {skey} missing from sink")))?;
        let (cts, meta) = decode_bundle(&raw)
            .map_err(|e| FheError::Storage(format!("corrupt spilled blob {skey}: {e}")))?;
        inner.spilled.remove(&key);
        inner.spilled_bytes -= bytes;
        inner.dec_session(session);
        let _ = self.sink.delete(&skey);
        self.metrics.rehydrations.fetch_add(1, Ordering::Relaxed);
        Ok(Some(Bundle { cts, meta }))
    }

    /// Whether the key holds a live entry (hot or spilled).
    pub fn contains(&self, session: u64, id: u64) -> bool {
        let inner = self.lock();
        let key = (session, id);
        inner.hot.contains_key(&key) || inner.spilled.contains_key(&key)
    }

    /// Drop one entry; `true` if it existed (either tier).
    pub fn release(&self, session: u64, id: u64) -> bool {
        let mut inner = self.lock();
        let key = (session, id);
        if let Some(bundle) = inner.hot.remove(&key) {
            inner.lru.remove(&key);
            inner.hot_bytes -= bundle_bytes(&bundle);
            inner.dec_session(session);
            true
        } else if let Some(bytes) = inner.spilled.remove(&key) {
            inner.spilled_bytes -= bytes;
            inner.dec_session(session);
            let _ = self.sink.delete(&self.skey(session, id));
            true
        } else {
            false
        }
    }

    /// Drop *every* entry a session holds — hot, spilled, and their sink
    /// bytes — and its per-session counter. The teardown path
    /// (`drop_session`) calls this so a dropped session leaves zero
    /// bundles and zero bytes behind. Returns the number of entries
    /// released.
    pub fn release_session(&self, session: u64) -> usize {
        let mut inner = self.lock();
        let hot_keys: Vec<(u64, u64)> =
            inner.hot.keys().filter(|k| k.0 == session).copied().collect();
        for key in &hot_keys {
            if let Some(bundle) = inner.hot.remove(key) {
                inner.lru.remove(key);
                inner.hot_bytes -= bundle_bytes(&bundle);
            }
        }
        let cold_keys: Vec<(u64, u64)> =
            inner.spilled.keys().filter(|k| k.0 == session).copied().collect();
        for key in &cold_keys {
            if let Some(bytes) = inner.spilled.remove(key) {
                inner.spilled_bytes -= bytes;
                let _ = self.sink.delete(&self.skey(key.0, key.1));
            }
        }
        inner.per_session.remove(&session);
        hot_keys.len() + cold_keys.len()
    }

    /// Live entries a session holds (hot + spilled).
    pub fn session_live(&self, session: u64) -> usize {
        self.lock().per_session.get(&session).copied().unwrap_or(0)
    }

    /// Live entries across all sessions, hot + spilled (a spilled bundle
    /// is still *live* state — it just lives cold).
    pub fn live_blobs(&self) -> u64 {
        let inner = self.lock();
        (inner.hot.len() + inner.spilled.len()) as u64
    }

    /// Logical ciphertext bytes held live (hot + spilled; see module
    /// docs for why spilled entries count their decoded size).
    pub fn live_bytes(&self) -> u64 {
        let inner = self.lock();
        inner.hot_bytes + inner.spilled_bytes
    }

    /// Entries currently spilled cold (observability / tests).
    pub fn spilled_blobs(&self) -> u64 {
        self.lock().spilled.len() as u64
    }
}

/// Heap bytes of one LWE ciphertext (mask words + body word) — the unit
/// both gauges and the spill budget are denominated in.
pub(crate) fn ct_bytes(ct: &CtInt) -> u64 {
    ((ct.ct.mask.len() + 1) * std::mem::size_of::<u64>()) as u64
}

fn bundle_bytes(bundle: &Bundle) -> u64 {
    bundle.cts.iter().map(ct_bytes).sum()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::super::sink::{scratch_dir, DiskSink};
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::tfhe::ops::FheContext;
    use crate::tfhe::params::TfheParams;
    use crate::util::prng::Xoshiro256;

    fn some_cts(n: usize) -> (FheContext, ClientKey, Vec<CtInt>) {
        let mut rng = Xoshiro256::new(17);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let cts = (0..n).map(|i| ctx.encrypt(i as i64 % 3 - 1, &ck, &mut rng)).collect();
        (ctx, ck, cts)
    }

    #[test]
    fn zero_budget_spills_every_insert_and_rehydrates_bit_identically() {
        let (_ctx, _ck, cts) = some_cts(3);
        let originals: Vec<_> = cts.iter().map(|c| c.ct.clone()).collect();
        let store = CtStore::with_memory("cache", 0);
        store.insert(7, 1, Bundle { cts, meta: 5 });
        assert_eq!(store.spilled_blobs(), 1, "zero budget spills immediately");
        assert_eq!(store.live_blobs(), 1, "spilled is still live");
        assert!(store.live_bytes() > 0);
        assert_eq!(store.sink().len(), 1);
        assert_eq!(store.metrics().evictions.load(Ordering::Relaxed), 1);
        let bundle = store.try_take(7, 1).unwrap().expect("rehydrates");
        assert_eq!(bundle.meta, 5);
        assert_eq!(bundle.cts.len(), 3);
        for (a, b) in bundle.cts.iter().zip(&originals) {
            assert_eq!(&a.ct, b, "rehydrated ciphertext is bit-identical");
        }
        assert_eq!(store.metrics().rehydrations.load(Ordering::Relaxed), 1);
        assert_eq!(store.live_blobs(), 0);
        assert_eq!(store.live_bytes(), 0);
        assert_eq!(store.sink().len(), 0, "rehydration reclaims sink bytes");
        assert!(store.try_take(7, 1).unwrap().is_none());
    }

    #[test]
    fn lru_spills_coldest_first_and_gauges_span_both_tiers() {
        let (_ctx, _ck, cts) = some_cts(6);
        let per = ct_bytes(&cts[0]);
        let mut it = cts.into_iter();
        let mut two = || -> Vec<CtInt> { it.by_ref().take(2).collect() };
        // Budget fits exactly two 2-ct bundles.
        let store = CtStore::with_memory("cache", 4 * per);
        store.insert(1, 10, Bundle { cts: two(), meta: 0 });
        store.insert(1, 11, Bundle { cts: two(), meta: 0 });
        assert_eq!(store.spilled_blobs(), 0);
        // Touch 10 (take + restore) so 11 becomes coldest.
        let b = store.try_take(1, 10).unwrap().unwrap();
        store.insert(1, 10, b);
        store.insert(1, 12, Bundle { cts: two(), meta: 0 });
        assert_eq!(store.spilled_blobs(), 1);
        assert!(!store.try_take(1, 11).unwrap().unwrap().cts.is_empty(), "11 was the victim");
        assert_eq!(store.metrics().rehydrations.load(Ordering::Relaxed), 1);
        // Gauges count hot + spilled uniformly.
        assert_eq!(store.live_blobs(), 2);
        assert_eq!(store.live_bytes(), 4 * per);
        assert_eq!(store.session_live(1), 2);
    }

    #[test]
    fn try_insert_cap_is_atomic_and_spill_aware() {
        let (_ctx, _ck, cts) = some_cts(2);
        let store = CtStore::with_memory("cache", 0);
        store.insert(1, 1, Bundle { cts, meta: 0 });
        assert_eq!(store.spilled_blobs(), 1);
        // A spilled entry still counts against the cap...
        let err = store
            .try_insert(1, 2, Bundle { cts: Vec::new(), meta: 0 }, 1, "cache bundles", "release")
            .unwrap_err();
        assert_eq!(err.code(), "cache_overflow", "{err}");
        // ...and replacing a *spilled* key is not an "open".
        store
            .try_insert(1, 1, Bundle { cts: Vec::new(), meta: 9 }, 1, "cache bundles", "release")
            .unwrap();
        assert_eq!(store.sink().len(), 0, "replaced spill reclaims stale sink bytes");
        assert_eq!(store.try_take(1, 1).unwrap().unwrap().meta, 9);
    }

    #[test]
    fn release_session_clears_hot_spilled_and_sink_state() {
        let (_ctx, _ck, cts) = some_cts(4);
        let per = ct_bytes(&cts[0]);
        let mut it = cts.into_iter();
        let mut one = || -> Vec<CtInt> { it.by_ref().take(1).collect() };
        // Budget of one ciphertext: the older of two bundles spills.
        let store = CtStore::with_memory("cache", per);
        store.insert(1, 1, Bundle { cts: one(), meta: 0 });
        store.insert(1, 2, Bundle { cts: one(), meta: 0 });
        store.insert(2, 1, Bundle { cts: one(), meta: 0 });
        assert!(store.spilled_blobs() >= 1);
        assert_eq!(store.release_session(1), 2);
        assert_eq!(store.session_live(1), 0);
        assert!(!store.contains(1, 1));
        assert!(!store.contains(1, 2));
        // Session 2's entry survives; no session-1 bytes linger anywhere.
        assert_eq!(store.live_blobs(), 1);
        assert!(store.contains(2, 1));
        assert_eq!(store.release_session(1), 0, "idempotent");
        store.release_session(2);
        assert_eq!(store.live_bytes(), 0);
        assert_eq!(store.sink().len(), 0);
    }

    #[test]
    fn disk_sink_round_trip_through_the_tier() {
        let dir = scratch_dir("tier");
        let (_ctx, _ck, cts) = some_cts(2);
        let originals: Vec<_> = cts.iter().map(|c| c.ct.clone()).collect();
        let store = CtStore::new(
            "cache",
            Arc::new(DiskSink::new(&dir).unwrap()),
            Arc::new(StorageMetrics::default()),
            0,
        );
        store.insert(3, 8, Bundle { cts, meta: 2 });
        assert_eq!(store.sink().len(), 1, "bundle written to disk");
        let bundle = store.try_take(3, 8).unwrap().expect("rehydrates from disk");
        assert_eq!(bundle.meta, 2);
        for (a, b) in bundle.cts.iter().zip(&originals) {
            assert_eq!(&a.ct, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
