//! Blob sinks (S9): the pluggable persistence seam under the LRU spill
//! tier. A sink is a flat key→bytes store — it knows nothing about
//! ciphertexts, sessions, or the codec; the tier above owns layout and
//! accounting, the sink owns durability.
//!
//! Keys follow the grammar `"{namespace}/{session}/{id}"` (e.g.
//! `"cache/3/7"`, `"blob/3/12"`, `"key/3"`). [`DiskSink`] flattens them
//! to single path components, so the grammar's alphanumeric segments
//! guarantee collision-freedom on disk.

use crate::error::FheError;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

/// A flat blob store the spill tier writes cold bundles into. All
/// methods are infallible-by-absence: `get` on a missing key is
/// `Ok(None)`, `delete` on a missing key is `Ok(false)` — only real I/O
/// or backend failures surface as [`FheError::Storage`].
pub trait BlobSink: Send + Sync {
    /// Store `bytes` under `key`, replacing any previous value.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), FheError>;
    /// Fetch the blob under `key`; `None` if absent.
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, FheError>;
    /// Remove the blob under `key`; `true` if one existed.
    fn delete(&self, key: &str) -> Result<bool, FheError>;
    /// Number of blobs currently held.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// In-process sink: a mutexed map. The default backend — spilling to it
/// still bounds the *hot* tier (decoded ciphertexts cost ~8x their
/// encoded form once mask `Vec`s and `CtInt` overhead are live) and it
/// is the substrate the [`ObjectStoreSink`] stub delegates to.
#[derive(Default)]
pub struct MemorySink {
    blobs: Mutex<HashMap<String, Vec<u8>>>,
}

impl MemorySink {
    pub fn new() -> Self {
        MemorySink::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Vec<u8>>> {
        self.blobs.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl BlobSink for MemorySink {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), FheError> {
        self.lock().insert(key.to_string(), bytes.to_vec());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, FheError> {
        Ok(self.lock().get(key).cloned())
    }

    fn delete(&self, key: &str) -> Result<bool, FheError> {
        Ok(self.lock().remove(key).is_some())
    }

    fn len(&self) -> usize {
        self.lock().len()
    }
}

/// Filesystem sink: one file per blob under a root directory. Keys are
/// sanitized to a single path component (every non-alphanumeric byte
/// becomes `_`), which is collision-free under the tier's key grammar
/// and keeps the sink immune to path traversal in hostile keys.
pub struct DiskSink {
    root: PathBuf,
}

impl DiskSink {
    /// Open (creating if needed) a sink rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> Result<Self, FheError> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .map_err(|e| FheError::Storage(format!("create sink dir {}: {e}", root.display())))?;
        Ok(DiskSink { root })
    }

    fn path_of(&self, key: &str) -> PathBuf {
        let flat: String =
            key.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
        self.root.join(format!("{flat}.blob"))
    }
}

impl BlobSink for DiskSink {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), FheError> {
        let path = self.path_of(key);
        std::fs::write(&path, bytes)
            .map_err(|e| FheError::Storage(format!("write {}: {e}", path.display())))
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, FheError> {
        let path = self.path_of(key);
        match std::fs::read(&path) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(FheError::Storage(format!("read {}: {e}", path.display()))),
        }
    }

    fn delete(&self, key: &str) -> Result<bool, FheError> {
        let path = self.path_of(key);
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(FheError::Storage(format!("delete {}: {e}", path.display()))),
        }
    }

    fn len(&self) -> usize {
        std::fs::read_dir(&self.root)
            .map(|d| {
                d.filter_map(Result::ok)
                    .filter(|e| e.path().extension().is_some_and(|x| x == "blob"))
                    .count()
            })
            .unwrap_or(0)
    }
}

/// Object-store sink **stub**: the S3/GCS-shaped backend slot. The
/// offline build vendors no HTTP stack, so this delegates to an
/// in-process [`MemorySink`] while pinning the trait surface a real
/// implementation must satisfy (same key grammar, same absent-key
/// semantics). `bucket` is carried so wiring code exercises the real
/// configuration shape.
pub struct ObjectStoreSink {
    bucket: String,
    inner: MemorySink,
}

impl ObjectStoreSink {
    pub fn new(bucket: impl Into<String>) -> Self {
        ObjectStoreSink { bucket: bucket.into(), inner: MemorySink::new() }
    }

    /// The configured bucket name (diagnostics only in the stub).
    pub fn bucket(&self) -> &str {
        &self.bucket
    }
}

impl BlobSink for ObjectStoreSink {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), FheError> {
        self.inner.put(key, bytes)
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, FheError> {
        self.inner.get(key)
    }

    fn delete(&self, key: &str) -> Result<bool, FheError> {
        self.inner.delete(key)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }
}

/// A unique scratch directory per test invocation (no tempfile crate in
/// the offline build). Shared by the tier tests.
#[cfg(test)]
pub(crate) fn scratch_dir(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("inhibitor-sink-{tag}-{}-{n}", std::process::id()))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn exercise(sink: &dyn BlobSink) {
        assert!(sink.is_empty());
        assert_eq!(sink.get("cache/1/2").unwrap(), None);
        assert!(!sink.delete("cache/1/2").unwrap());
        sink.put("cache/1/2", b"alpha").unwrap();
        sink.put("blob/1/2", b"beta").unwrap();
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.get("cache/1/2").unwrap().as_deref(), Some(&b"alpha"[..]));
        // Replace is idempotent on count.
        sink.put("cache/1/2", b"gamma").unwrap();
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.get("cache/1/2").unwrap().as_deref(), Some(&b"gamma"[..]));
        assert!(sink.delete("cache/1/2").unwrap());
        assert!(!sink.delete("cache/1/2").unwrap());
        assert_eq!(sink.len(), 1);
        assert!(sink.delete("blob/1/2").unwrap());
        assert!(sink.is_empty());
    }

    #[test]
    fn memory_sink_contract() {
        exercise(&MemorySink::new());
    }

    #[test]
    fn object_store_stub_contract() {
        let sink = ObjectStoreSink::new("inhibitor-sessions");
        assert_eq!(sink.bucket(), "inhibitor-sessions");
        exercise(&sink);
    }

    #[test]
    fn disk_sink_contract_and_key_sanitization() {
        let dir = scratch_dir("contract");
        let sink = DiskSink::new(&dir).unwrap();
        exercise(&sink);
        // Hostile keys cannot escape the root.
        sink.put("../../etc/passwd", b"nope").unwrap();
        let stored = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(stored, 1, "traversal key flattened into the root");
        assert_eq!(sink.get("../../etc/passwd").unwrap().as_deref(), Some(&b"nope"[..]));
        assert!(sink.delete("../../etc/passwd").unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_sink_persists_across_reopen() {
        let dir = scratch_dir("reopen");
        {
            let sink = DiskSink::new(&dir).unwrap();
            sink.put("key/7", &[1, 2, 3]).unwrap();
        }
        let sink = DiskSink::new(&dir).unwrap();
        assert_eq!(sink.get("key/7").unwrap(), Some(vec![1, 2, 3]));
        assert_eq!(sink.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
