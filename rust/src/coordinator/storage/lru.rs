//! Recency index for the spill tier (S9): a logical-clock LRU over
//! arbitrary keys. Two maps — key→tick and tick→key — give O(log n)
//! touch/evict with no unsafe linked-list plumbing, in the spirit of the
//! `cache/lru.rs` exemplar named in the ROADMAP. Ticks come from a
//! monotonically increasing `u64` (never reused, so a billion touches
//! per second would take half a millennium to wrap).

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

/// LRU recency index. Tracks *order only* — the owner keeps the values
/// and byte accounting; this keeps the index reusable for both the
/// decode-cache tier and the session blob tier.
pub struct LruIndex<K> {
    tick_of: HashMap<K, u64>,
    by_tick: BTreeMap<u64, K>,
    clock: u64,
}

impl<K: Eq + Hash + Clone> Default for LruIndex<K> {
    fn default() -> Self {
        LruIndex { tick_of: HashMap::new(), by_tick: BTreeMap::new(), clock: 0 }
    }
}

impl<K: Eq + Hash + Clone> LruIndex<K> {
    pub fn new() -> Self {
        LruIndex::default()
    }

    /// Insert `key` as most-recent (or refresh it if already present).
    pub fn touch(&mut self, key: K) {
        if let Some(old) = self.tick_of.get(&key) {
            self.by_tick.remove(old);
        }
        self.clock += 1;
        self.tick_of.insert(key.clone(), self.clock);
        self.by_tick.insert(self.clock, key);
    }

    /// Forget `key`; `true` if it was tracked.
    pub fn remove(&mut self, key: &K) -> bool {
        match self.tick_of.remove(key) {
            Some(tick) => {
                self.by_tick.remove(&tick);
                true
            }
            None => false,
        }
    }

    /// Pop the least-recently-touched key (eviction candidate).
    pub fn pop_oldest(&mut self) -> Option<K> {
        let (&tick, _) = self.by_tick.iter().next()?;
        let key = self.by_tick.remove(&tick)?;
        self.tick_of.remove(&key);
        Some(key)
    }

    pub fn len(&self) -> usize {
        self.tick_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tick_of.is_empty()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn eviction_order_follows_recency() {
        let mut lru = LruIndex::new();
        for k in [1u64, 2, 3] {
            lru.touch(k);
        }
        assert_eq!(lru.len(), 3);
        // Re-touching 1 makes 2 the oldest.
        lru.touch(1);
        assert_eq!(lru.pop_oldest(), Some(2));
        assert_eq!(lru.pop_oldest(), Some(3));
        assert_eq!(lru.pop_oldest(), Some(1));
        assert_eq!(lru.pop_oldest(), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn remove_and_retouch_are_consistent() {
        let mut lru = LruIndex::new();
        lru.touch((1u64, 7u64));
        lru.touch((2, 8));
        assert!(lru.remove(&(1, 7)));
        assert!(!lru.remove(&(1, 7)), "second remove is false");
        assert_eq!(lru.len(), 1);
        // Double-touch keeps exactly one entry per key.
        lru.touch((2, 8));
        lru.touch((2, 8));
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.pop_oldest(), Some((2, 8)));
        assert!(lru.is_empty());
    }
}
