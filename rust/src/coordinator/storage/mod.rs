//! Pluggable ciphertext/key storage tier (S9): blob sinks
//! ([`BlobSink`]: memory, disk, object-store stub), an LRU recency
//! index, and the byte-budgeted spill tier ([`CtStore`]) the
//! coordinator's two stores — `keymgr::Session` result blobs and the
//! decode `SessionStore` — are refactored onto. Serialization is the
//! alloc-free word codec in `tfhe::codec`; see rust/DESIGN.md §9b for
//! the layout and the teardown contract.

pub mod lru;
pub mod sink;
pub mod tier;

pub use lru::LruIndex;
pub use sink::{BlobSink, DiskSink, MemorySink, ObjectStoreSink};
pub use tier::{Bundle, CtStore, DEFAULT_STORAGE_BUDGET};

pub(crate) use tier::ct_bytes;
