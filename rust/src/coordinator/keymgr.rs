//! Key/session manager for the encrypted path (S9): holds per-client
//! server keys (bootstrap + key-switch material) and registered
//! ciphertext payloads. Client secret keys never enter this process in a
//! real deployment; tests generate both sides locally.

use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::params::TfheParams;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One client session: evaluation context + ciphertext store.
pub struct Session {
    pub ctx: FheContext,
    store: Mutex<HashMap<u64, Vec<CtInt>>>,
    next_blob: AtomicU64,
}

impl Session {
    pub fn new(ctx: FheContext) -> Self {
        Session { ctx, store: Mutex::new(HashMap::new()), next_blob: AtomicU64::new(1) }
    }

    /// Register a ciphertext bundle; returns its reference id.
    pub fn register(&self, cts: Vec<CtInt>) -> u64 {
        let id = self.next_blob.fetch_add(1, Ordering::Relaxed);
        self.store.lock().unwrap_or_else(|e| e.into_inner()).insert(id, cts);
        id
    }

    pub fn take(&self, id: u64) -> Option<Vec<CtInt>> {
        self.store.lock().unwrap_or_else(|e| e.into_inner()).remove(&id)
    }

    /// Re-insert a bundle under its original id — the error-path rollback
    /// of [`Self::take`], so a failed batch does not consume the bundles
    /// of co-batched requests that could otherwise be retried.
    pub fn restore(&self, id: u64, cts: Vec<CtInt>) {
        self.store.lock().unwrap_or_else(|e| e.into_inner()).insert(id, cts);
    }

    pub fn put_result(&self, cts: Vec<CtInt>) -> u64 {
        self.register(cts)
    }

    /// Advance the blob-id counter to `next`. Operational hook (id-space
    /// partitioning) also used by tests to drive ids past the retired
    /// f32-exact 2²⁴ protocol limit and pin that typed result references
    /// stay exact at any magnitude.
    pub fn set_next_blob_id(&self, next: u64) {
        self.next_blob.store(next, Ordering::Relaxed);
    }
}

/// The key manager: session id → Session.
pub struct KeyManager {
    sessions: Mutex<HashMap<u64, std::sync::Arc<Session>>>,
    next_session: AtomicU64,
}

impl KeyManager {
    pub fn new() -> Self {
        KeyManager { sessions: Mutex::new(HashMap::new()), next_session: AtomicU64::new(1) }
    }

    /// Create a session from a client-provided server key context.
    pub fn create_session(&self, ctx: FheContext) -> u64 {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let sess = std::sync::Arc::new(Session::new(ctx));
        self.sessions.lock().unwrap_or_else(|e| e.into_inner()).insert(id, sess);
        id
    }

    pub fn session(&self, id: u64) -> Option<std::sync::Arc<Session>> {
        self.sessions.lock().unwrap_or_else(|e| e.into_inner()).get(&id).cloned()
    }

    pub fn drop_session(&self, id: u64) -> bool {
        self.sessions.lock().unwrap_or_else(|e| e.into_inner()).remove(&id).is_some()
    }

    pub fn params_of(&self, id: u64) -> Option<TfheParams> {
        self.session(id).map(|s| s.ctx.sk.params)
    }
}

impl Default for KeyManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::util::prng::Xoshiro256;

    fn make_ctx() -> (ClientKey, FheContext) {
        let mut rng = Xoshiro256::new(9);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx)
    }

    #[test]
    fn session_lifecycle() {
        let (ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let sess = km.session(sid).expect("session exists");
        let mut rng = Xoshiro256::new(1);
        let ct = sess.ctx.encrypt(2, &ck, &mut rng);
        let blob = sess.register(vec![ct]);
        let got = sess.take(blob).expect("blob exists");
        assert_eq!(sess.ctx.decrypt(&got[0], &ck), 2);
        assert!(sess.take(blob).is_none(), "take consumes");
        assert!(km.drop_session(sid));
        assert!(km.session(sid).is_none());
    }

    #[test]
    fn unknown_session_is_none() {
        let km = KeyManager::new();
        assert!(km.session(42).is_none());
        assert!(!km.drop_session(42));
    }
}
