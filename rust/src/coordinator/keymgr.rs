//! Key/session manager for the encrypted path (S9): holds per-client
//! server keys (bootstrap + key-switch material) and registered
//! ciphertext payloads. Client secret keys never enter this process in a
//! real deployment; tests generate both sides locally.
//!
//! Since S9 a session's ciphertext bundles live in a shared [`CtStore`]
//! spill tier under the `"blob"` namespace (LRU-evicted past the hot
//! byte budget, capped per session), and *whole sessions* can be parked
//! cold: [`KeyManager::park_session`] serializes the server key through
//! `tfhe::codec` into the tier's sink, and the next
//! [`KeyManager::session`] lookup rebuilds the evaluation context
//! transparently — the cold-key attach path whose latency
//! `coordinator::metrics` tracks. Teardown
//! ([`KeyManager::drop_session`]) releases the session's key material
//! *and* every bundle it holds, hot or spilled, through the same
//! accounting path.

use crate::coordinator::storage::{Bundle, CtStore, DEFAULT_STORAGE_BUDGET};
use crate::error::FheError;
use crate::tfhe::codec::{decode_server_key, CtCodec};
use crate::tfhe::ops::{CtInt, FheContext};
use crate::tfhe::params::TfheParams;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default cap on live ciphertext bundles per session — one misbehaving
/// client that never `take`s its results cannot grow the server
/// unboundedly (bundles past the cap fail typed; bundles under it can
/// still spill cold, so the cap bounds *state*, not RAM).
pub const DEFAULT_BLOB_CAP: usize = 1024;

/// One client session: evaluation context + a handle into the shared
/// blob tier (bundles are keyed by this session's id).
pub struct Session {
    pub ctx: FheContext,
    id: u64,
    blobs: Arc<CtStore>,
    next_blob: AtomicU64,
    max_blobs: AtomicUsize,
}

impl Session {
    fn new(ctx: FheContext, id: u64, blobs: Arc<CtStore>) -> Self {
        Session {
            ctx,
            id,
            blobs,
            next_blob: AtomicU64::new(1),
            max_blobs: AtomicUsize::new(DEFAULT_BLOB_CAP),
        }
    }

    /// This session's id in the key manager.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Register a ciphertext bundle; returns its reference id. Client
    /// upload surface — panics past the per-session blob cap (tests and
    /// examples stay far under it; the serving path uses the fallible
    /// [`Self::try_register`]/[`Self::put_result`]).
    pub fn register(&self, cts: Vec<CtInt>) -> u64 {
        self.try_register(cts).expect("session blob cap exceeded")
    }

    /// Register a ciphertext bundle, failing typed past the per-session
    /// cap ([`FheError::CacheOverflow`]; the bundle is dropped).
    pub fn try_register(&self, cts: Vec<CtInt>) -> Result<u64, FheError> {
        let id = self.next_blob.fetch_add(1, Ordering::Relaxed);
        let cap = self.max_blobs.load(Ordering::Relaxed);
        self.blobs.try_insert(
            self.id,
            id,
            Bundle { cts, meta: 0 },
            cap,
            "ciphertext bundles",
            "take results (or drop the session) before registering more",
        )?;
        Ok(id)
    }

    /// Consume a bundle by id, rehydrating transparently if the tier
    /// spilled it. Collapses storage failures to `None`; the serving
    /// path uses [`Self::try_take`] to keep them typed.
    pub fn take(&self, id: u64) -> Option<Vec<CtInt>> {
        self.try_take(id).ok().flatten()
    }

    /// Consume a bundle by id. `Ok(None)` if the id holds nothing;
    /// `Err(`[`FheError::Storage`]`)` if its spilled bytes are missing
    /// or corrupt.
    pub fn try_take(&self, id: u64) -> Result<Option<Vec<CtInt>>, FheError> {
        Ok(self.blobs.try_take(self.id, id)?.map(|b| b.cts))
    }

    /// Re-insert a bundle under its original id — the error-path rollback
    /// of [`Self::take`], so a failed batch does not consume the bundles
    /// of co-batched requests that could otherwise be retried. Never
    /// cap-checked: rollback must not fail.
    pub fn restore(&self, id: u64, cts: Vec<CtInt>) {
        self.blobs.insert(self.id, id, Bundle { cts, meta: 0 });
    }

    /// Deposit a result bundle for the client to `take`, failing typed
    /// past the per-session cap (the satellite bugfix: results a client
    /// never collects can no longer grow the server unboundedly).
    pub fn put_result(&self, cts: Vec<CtInt>) -> Result<u64, FheError> {
        self.try_register(cts)
    }

    /// Advance the blob-id counter to `next`. Operational hook (id-space
    /// partitioning) also used by tests to drive ids past the retired
    /// f32-exact 2²⁴ protocol limit and pin that typed result references
    /// stay exact at any magnitude.
    pub fn set_next_blob_id(&self, next: u64) {
        self.next_blob.store(next, Ordering::Relaxed);
    }

    /// Adjust the per-session blob cap (operational knob; tests use it
    /// to drive overflow cheaply).
    pub fn set_blob_cap(&self, cap: usize) {
        self.max_blobs.store(cap, Ordering::Relaxed);
    }

    /// Live bundles this session holds (hot + spilled).
    pub fn live_blobs(&self) -> usize {
        self.blobs.session_live(self.id)
    }
}

/// A session whose server key lives cold in the blob sink. Everything
/// needed to answer metadata queries and resume exactly — the blob-id
/// counter, the thread setting, the parameter set — without touching
/// the sink.
struct ParkedSession {
    next_blob: u64,
    threads: usize,
    params: TfheParams,
}

/// The key manager: session id → live [`Session`] or parked key
/// material. Lock order is `sessions` → `parked` everywhere (attach,
/// park, drop, params), which is what makes the cold-attach path
/// race-free without a third lock.
pub struct KeyManager {
    sessions: Mutex<HashMap<u64, Arc<Session>>>,
    parked: Mutex<HashMap<u64, ParkedSession>>,
    blobs: Arc<CtStore>,
    next_session: AtomicU64,
}

impl KeyManager {
    /// A manager over a private in-memory blob tier (tests, examples).
    pub fn new() -> Self {
        Self::with_storage(Arc::new(CtStore::with_memory("blob", DEFAULT_STORAGE_BUDGET)))
    }

    /// A manager over an externally wired blob tier (shared sink and
    /// metrics) — how the coordinator builds it.
    pub fn with_storage(blobs: Arc<CtStore>) -> Self {
        KeyManager {
            sessions: Mutex::new(HashMap::new()),
            parked: Mutex::new(HashMap::new()),
            blobs,
            next_session: AtomicU64::new(1),
        }
    }

    fn lock_sessions(&self) -> std::sync::MutexGuard<'_, HashMap<u64, Arc<Session>>> {
        self.sessions.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_parked(&self) -> std::sync::MutexGuard<'_, HashMap<u64, ParkedSession>> {
        self.parked.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn key_of(id: u64) -> String {
        format!("key/{id}")
    }

    /// The blob tier sessions store their bundles in (and whose sink
    /// parks cold keys).
    pub fn storage(&self) -> &Arc<CtStore> {
        &self.blobs
    }

    /// Create a session from a client-provided server key context.
    pub fn create_session(&self, ctx: FheContext) -> u64 {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let sess = Arc::new(Session::new(ctx, id, Arc::clone(&self.blobs)));
        self.lock_sessions().insert(id, sess);
        id
    }

    /// Look up a session, attaching it from the cold tier if it was
    /// parked: the serialized server key is fetched from the sink,
    /// decoded, and rebuilt into an evaluation context (FFT plan
    /// included) under the session's original blob-id counter and thread
    /// setting. The attach latency lands in the `key_attach` histogram.
    /// A sink or codec failure leaves the session parked (a recovered
    /// sink can still serve it) and reads as `None`.
    pub fn session(&self, id: u64) -> Option<Arc<Session>> {
        let mut sessions = self.lock_sessions();
        if let Some(s) = sessions.get(&id) {
            return Some(Arc::clone(s));
        }
        let mut parked = self.lock_parked();
        let info = parked.remove(&id)?;
        let start = Instant::now();
        let skey = Self::key_of(id);
        let attached = self
            .blobs
            .sink()
            .get(&skey)
            .and_then(|raw| {
                raw.ok_or_else(|| {
                    FheError::Storage(format!("parked key {skey} missing from sink"))
                })
            })
            .and_then(|raw| {
                decode_server_key(&raw)
                    .map_err(|e| FheError::Storage(format!("corrupt parked key {skey}: {e}")))
            });
        match attached {
            Ok(sk) => {
                let ctx = FheContext::with_threads(sk, info.threads);
                let sess = Arc::new(Session::new(ctx, id, Arc::clone(&self.blobs)));
                sess.set_next_blob_id(info.next_blob);
                let _ = self.blobs.sink().delete(&skey);
                let m = self.blobs.metrics();
                m.cold_key_attaches.fetch_add(1, Ordering::Relaxed);
                m.key_attach.record(start.elapsed().as_secs_f64());
                sessions.insert(id, Arc::clone(&sess));
                Some(sess)
            }
            Err(e) => {
                parked.insert(id, info);
                eprintln!("cold attach of session {id} failed: {e}");
                None
            }
        }
    }

    /// Park a live session cold: serialize its server key into the blob
    /// tier's sink and drop the in-memory evaluation context (bootstrap
    /// key, FFT plan and all). `Ok(false)` if the id is unknown or
    /// already parked; `Err(`[`FheError::Storage`]`)` if the session is
    /// pinned by a live holder (e.g. a registered decode engine) or the
    /// sink write fails — in both cases the session stays live and
    /// untouched. The session's bundles stay in the tier (LRU-spillable)
    /// and its blob-id counter resumes exactly on attach.
    pub fn park_session(&self, id: u64) -> Result<bool, FheError> {
        let mut sessions = self.lock_sessions();
        let mut parked = self.lock_parked();
        let Some(sess) = sessions.get(&id) else {
            return Ok(false);
        };
        if Arc::strong_count(sess) > 1 {
            return Err(FheError::Storage(format!(
                "session {id} is pinned by a live engine or handle; cannot park"
            )));
        }
        let mut codec = CtCodec::new();
        self.blobs.sink().put(&Self::key_of(id), codec.encode_server_key(&sess.ctx.sk))?;
        let info = ParkedSession {
            next_blob: sess.next_blob.load(Ordering::Relaxed),
            threads: sess.ctx.threads(),
            params: sess.ctx.sk.params,
        };
        sessions.remove(&id);
        parked.insert(id, info);
        self.blobs.metrics().evictions.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Sessions currently parked cold (observability / tests).
    pub fn parked_sessions(&self) -> usize {
        self.lock_parked().len()
    }

    /// Tear a session down — live or parked — releasing its key
    /// material (including parked sink bytes) *and* every ciphertext
    /// bundle it holds in the blob tier. `true` if it existed. The
    /// decode-cache side of teardown lives in
    /// `Coordinator::drop_session`, which pairs this with
    /// `SessionStore::release_session`.
    pub fn drop_session(&self, id: u64) -> bool {
        let mut sessions = self.lock_sessions();
        let mut parked = self.lock_parked();
        let live = sessions.remove(&id).is_some();
        let was_parked = parked.remove(&id).is_some();
        if was_parked {
            let _ = self.blobs.sink().delete(&Self::key_of(id));
        }
        drop(parked);
        drop(sessions);
        let existed = live || was_parked;
        if existed {
            self.blobs.release_session(id);
        }
        existed
    }

    /// Parameter set of a session — answered for parked sessions from
    /// their metadata, *without* triggering a cold attach.
    pub fn params_of(&self, id: u64) -> Option<TfheParams> {
        let sessions = self.lock_sessions();
        if let Some(s) = sessions.get(&id) {
            return Some(s.ctx.sk.params);
        }
        self.lock_parked().get(&id).map(|p| p.params)
    }
}

impl Default for KeyManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::tfhe::bootstrap::ClientKey;
    use crate::util::prng::Xoshiro256;

    fn make_ctx() -> (ClientKey, FheContext) {
        let mut rng = Xoshiro256::new(9);
        let ck = ClientKey::generate(TfheParams::test_small(), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        (ck, ctx)
    }

    #[test]
    fn session_lifecycle() {
        let (ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let sess = km.session(sid).expect("session exists");
        assert_eq!(sess.id(), sid);
        let mut rng = Xoshiro256::new(1);
        let ct = sess.ctx.encrypt(2, &ck, &mut rng);
        let blob = sess.register(vec![ct]);
        let got = sess.take(blob).expect("blob exists");
        assert_eq!(sess.ctx.decrypt(&got[0], &ck), 2);
        assert!(sess.take(blob).is_none(), "take consumes");
        assert!(km.drop_session(sid));
        assert!(km.session(sid).is_none());
    }

    #[test]
    fn unknown_session_is_none() {
        let km = KeyManager::new();
        assert!(km.session(42).is_none());
        assert!(!km.drop_session(42));
        assert!(!km.park_session(42).unwrap());
    }

    #[test]
    fn park_and_cold_attach_evaluate_bit_identically() {
        let _guard = crate::tfhe::pbs_test_guard();
        let (ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let mut rng = Xoshiro256::new(3);
        let sess = km.session(sid).expect("live");
        let x = sess.ctx.encrypt(-1, &ck, &mut rng);
        let hot = sess.ctx.relu(&x);
        let blob = sess.register(vec![x.clone()]);
        drop(sess);
        assert!(km.park_session(sid).unwrap());
        assert_eq!(km.parked_sessions(), 1);
        assert!(km.storage().sink().len() >= 1, "key bytes parked in the sink");
        assert!(km.params_of(sid).is_some(), "params readable without attaching");
        assert_eq!(km.parked_sessions(), 1, "params_of does not attach");
        assert!(!km.park_session(sid).unwrap(), "already parked reads as false");
        let sess = km.session(sid).expect("cold attach");
        assert_eq!(km.parked_sessions(), 0);
        // PBS under the re-attached (decoded, fresh-FFT) key is
        // bit-identical to the original context.
        let cold = sess.ctx.relu(&x);
        assert_eq!(hot.ct, cold.ct, "deterministic PBS across park/attach");
        // Bundles survive parking; the blob-id counter resumes, so new
        // ids never collide with pre-park ones.
        let got = sess.take(blob).expect("pre-park bundle survives");
        assert_eq!(got[0].ct, x.ct);
        let blob2 = sess.register(vec![x]);
        assert!(blob2 > blob, "blob ids resume past pre-park ids");
        let m = km.storage().metrics();
        assert_eq!(m.cold_key_attaches.load(Ordering::Relaxed), 1);
        assert_eq!(m.key_attach.count(), 1, "attach latency recorded");
    }

    #[test]
    fn park_refuses_pinned_sessions() {
        let (_ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let pin = km.session(sid).expect("live");
        let err = km.park_session(sid).unwrap_err();
        assert_eq!(err.code(), "storage", "{err}");
        assert!(km.session(sid).is_some(), "refused park leaves the session live");
        drop(pin);
        assert!(km.park_session(sid).unwrap());
        // Dropping a parked session reclaims its sink bytes too.
        assert!(km.drop_session(sid));
        assert_eq!(km.storage().sink().len(), 0);
        assert!(km.session(sid).is_none());
        assert!(km.params_of(sid).is_none());
    }

    #[test]
    fn result_blob_cap_is_typed_and_take_frees_it() {
        let (ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let sess = km.session(sid).expect("live");
        sess.set_blob_cap(2);
        let mut rng = Xoshiro256::new(4);
        let ct = sess.ctx.encrypt(1, &ck, &mut rng);
        let a = sess.try_register(vec![ct.clone()]).unwrap();
        let _b = sess.put_result(vec![ct.clone()]).unwrap();
        let err = sess.put_result(vec![ct.clone()]).unwrap_err();
        assert_eq!(err.code(), "cache_overflow", "{err}");
        assert_eq!(sess.live_blobs(), 2);
        // Consuming a bundle frees the cap slot.
        assert!(sess.take(a).is_some());
        sess.put_result(vec![ct]).unwrap();
    }

    #[test]
    fn drop_session_releases_every_bundle_and_byte() {
        let (ck, ctx) = make_ctx();
        let km = KeyManager::new();
        let sid = km.create_session(ctx);
        let sess = km.session(sid).expect("live");
        let mut rng = Xoshiro256::new(6);
        let ct = sess.ctx.encrypt(0, &ck, &mut rng);
        sess.register(vec![ct.clone()]);
        sess.register(vec![ct]);
        assert_eq!(sess.live_blobs(), 2);
        assert!(km.storage().live_bytes() > 0);
        drop(sess);
        assert!(km.drop_session(sid));
        assert_eq!(km.storage().session_live(sid), 0);
        assert_eq!(km.storage().live_blobs(), 0);
        assert_eq!(km.storage().live_bytes(), 0);
        assert_eq!(km.storage().sink().len(), 0);
    }
}
