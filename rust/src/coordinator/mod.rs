//! The serving coordinator (S9): request types, dynamic batcher,
//! scheduler with per-engine workers, key/session manager for the
//! encrypted path, serving metrics, and the router facade.
//!
//! Thread-based (std::sync) rather than async — tokio is unavailable in
//! the offline build, and the workload is CPU-bound FHE/integer compute
//! where one worker thread per engine is the right execution model.

pub mod batcher;
pub mod fused;
pub mod keymgr;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod session_store;
pub mod storage;

pub use batcher::{BatchPolicy, Batcher};
pub use fused::{FusedLevelExecutor, FusedRequest, FusedStats};
pub use keymgr::{KeyManager, Session};
pub use metrics::{Metrics, StorageMetrics};
pub use request::{EngineOutput, EnginePath, InferRequest, InferResponse, Payload};
pub use router::{Coordinator, RoutePolicy};
pub use scheduler::{EngineFn, Scheduler};
pub use session_store::SessionStore;
pub use storage::{BlobSink, Bundle, CtStore, DiskSink, MemorySink, ObjectStoreSink};
