//! Request/response types for the serving coordinator (S9).

use std::time::Instant;

/// Which execution engine a request targets.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum EnginePath {
    /// AOT float model via PJRT (`runtime::Registry` model name).
    Pjrt(String),
    /// Plaintext quantized integer engine ("dotprod" | "inhibitor" | ...).
    QuantInt(String),
    /// Encrypted TFHE engine, keyed by client session.
    Encrypted { session: u64, mechanism: String },
}

impl EnginePath {
    /// Batching key: requests with the same key may share a batch.
    /// Encrypted keys canonicalize mechanism aliases (e.g. "softmax" →
    /// "dotprod") so registration and submission agree no matter which
    /// accepted name either side used; unknown strings pass through
    /// verbatim (registration rejects them anyway). Multi-head engines
    /// suffix the mechanism (`dotprod@h4s` — see
    /// `fhe_circuits::multihead_engine_mechanism`); canonicalization
    /// applies to the base name, so `softmax@h4s` and `dotprod@h4s`
    /// share a key while head-count/layout variants stay distinct.
    pub fn batch_key(&self) -> String {
        match self {
            EnginePath::Pjrt(m) => format!("pjrt/{m}"),
            EnginePath::QuantInt(m) => format!("quant/{m}"),
            EnginePath::Encrypted { session, mechanism } => {
                let (base, suffix) = match mechanism.split_once('@') {
                    Some((b, s)) => (b, Some(s)),
                    None => (mechanism.as_str(), None),
                };
                let canon =
                    crate::attention::Mechanism::parse(base).map(|m| m.name()).unwrap_or(base);
                match suffix {
                    Some(s) => format!("fhe/{canon}@{s}/{session}"),
                    None => format!("fhe/{canon}/{session}"),
                }
            }
        }
    }
}

/// Request payload: float features, token ids, or opaque ciphertext blobs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Row-major floats + (rows, cols).
    Features(Vec<f32>, (usize, usize)),
    Tokens(Vec<usize>),
    /// Indices into the key manager's ciphertext store (the TCP protocol
    /// registers ciphertexts first, then references them).
    CiphertextRef(u64),
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: u64,
    pub path: EnginePath,
    pub payload: Payload,
    pub enqueued: Instant,
}

impl InferRequest {
    pub fn new(id: u64, path: EnginePath, payload: Payload) -> Self {
        InferRequest { id, path, payload, enqueued: Instant::now() }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    /// Flattened output values (floats for clear paths; decrypt-side
    /// handles ciphertext outputs referenced by id).
    pub output: Vec<f32>,
    pub engine: String,
    /// Queue + execution latency in seconds.
    pub latency_s: f64,
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_keys_separate_engines_and_sessions() {
        let a = EnginePath::Pjrt("model_inhibitor".into()).batch_key();
        let b = EnginePath::QuantInt("inhibitor".into()).batch_key();
        let c = EnginePath::Encrypted { session: 1, mechanism: "inhibitor".into() }.batch_key();
        let d = EnginePath::Encrypted { session: 2, mechanism: "inhibitor".into() }.batch_key();
        assert!(a != b && b != c && c != d);
    }

    #[test]
    fn same_variant_shares_key() {
        let a = EnginePath::QuantInt("dotprod".into()).batch_key();
        let b = EnginePath::QuantInt("dotprod".into()).batch_key();
        assert_eq!(a, b);
    }

    #[test]
    fn encrypted_keys_canonicalize_mechanism_aliases() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "softmax".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "dotprod".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        // Unknown names pass through (rejected later at registration).
        let junk = EnginePath::Encrypted { session: 7, mechanism: "nonsense".into() };
        assert_eq!(junk.batch_key(), "fhe/nonsense/7");
    }

    #[test]
    fn multihead_keys_canonicalize_base_and_keep_configuration_distinct() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "softmax@h4s".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "dotprod@h4s".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        assert_eq!(canon.batch_key(), "fhe/dotprod@h4s/7");
        let single = EnginePath::Encrypted { session: 7, mechanism: "dotprod".into() };
        let two = EnginePath::Encrypted { session: 7, mechanism: "dotprod@h2".into() };
        assert!(canon.batch_key() != single.batch_key());
        assert!(canon.batch_key() != two.batch_key());
    }
}
