//! Request/response types for the serving coordinator (S9).

use crate::error::FheError;
use crate::tfhe::faults::CancelToken;
use std::time::Instant;

/// Which execution engine a request targets.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum EnginePath {
    /// AOT float model via PJRT (`runtime::Registry` model name).
    Pjrt(String),
    /// Plaintext quantized integer engine ("dotprod" | "inhibitor" | ...).
    QuantInt(String),
    /// Encrypted TFHE engine, keyed by client session.
    Encrypted { session: u64, mechanism: String },
}

impl EnginePath {
    /// Batching key: requests with the same key may share a batch.
    /// Encrypted keys canonicalize mechanism aliases (e.g. "softmax" →
    /// "dotprod") so registration and submission agree no matter which
    /// accepted name either side used; unknown strings pass through
    /// verbatim (registration rejects them anyway). Multi-head engines
    /// suffix the mechanism (`dotprod@h4s` — see
    /// `fhe_circuits::multihead_engine_mechanism`); canonicalization
    /// applies to the base name, so `softmax@h4s` and `dotprod@h4s`
    /// share a key while head-count/layout variants stay distinct.
    pub fn batch_key(&self) -> String {
        match self {
            EnginePath::Pjrt(m) => format!("pjrt/{m}"),
            EnginePath::QuantInt(m) => format!("quant/{m}"),
            EnginePath::Encrypted { session, mechanism } => {
                let (base, suffix) = match mechanism.split_once('@') {
                    Some((b, s)) => (b, Some(s)),
                    None => (mechanism.as_str(), None),
                };
                // Block and decode engines prefix the mechanism
                // (`block/<mech>`, `decode/<mech>`); canonicalize the
                // inner name so `block/softmax@…` and `block/dotprod@…`
                // share a key too.
                let canon: String = match base.strip_prefix("block/") {
                    Some(inner) => format!(
                        "block/{}",
                        crate::attention::Mechanism::parse(inner)
                            .map(|m| m.name())
                            .unwrap_or(inner)
                    ),
                    None => match base.strip_prefix("decode/") {
                        Some(inner) => format!(
                            "decode/{}",
                            crate::attention::Mechanism::parse(inner)
                                .map(|m| m.name())
                                .unwrap_or(inner)
                        ),
                        None => crate::attention::Mechanism::parse(base)
                            .map(|m| m.name().to_string())
                            .unwrap_or_else(|| base.to_string()),
                    },
                };
                match suffix {
                    Some(s) => format!("fhe/{canon}@{s}/{session}"),
                    None => format!("fhe/{canon}/{session}"),
                }
            }
        }
    }
}

/// Request payload: float features, token ids, or opaque ciphertext blobs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Row-major floats + (rows, cols).
    Features(Vec<f32>, (usize, usize)),
    Tokens(Vec<usize>),
    /// Indices into the key manager's ciphertext store (the TCP protocol
    /// registers ciphertexts first, then references them).
    CiphertextRef(u64),
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: u64,
    pub path: EnginePath,
    pub payload: Payload,
    pub enqueued: Instant,
    /// Absolute wall-clock deadline. An expired request is dropped with
    /// `DeadlineExceeded` at dequeue, and the encrypted executor checks
    /// it cooperatively at every PBS level boundary.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation: callers keep a clone and fire it to
    /// abandon the request at the next checkpoint.
    pub cancel: CancelToken,
    /// Decode engines only: the stream id whose server-side cache bundle
    /// this request extends. `None` means prefill (start a stream).
    pub cache_ref: Option<u64>,
    /// Decode engines only: the stream id the successor cache bundle is
    /// stored under. Steps default to `cache_ref` when `None`; prefill
    /// requires it (there is no stream yet to inherit from).
    pub cache_out: Option<u64>,
}

impl InferRequest {
    pub fn new(id: u64, path: EnginePath, payload: Payload) -> Self {
        InferRequest {
            id,
            path,
            payload,
            enqueued: Instant::now(),
            deadline: None,
            cancel: CancelToken::new(),
            cache_ref: None,
            cache_out: None,
        }
    }

    /// Attach an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach decode-stream cache routing (see the field docs).
    pub fn with_cache(mut self, cache_ref: Option<u64>, cache_out: Option<u64>) -> Self {
        self.cache_ref = cache_ref;
        self.cache_out = cache_out;
        self
    }

    /// Whether the deadline has already passed.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// One request's engine-side result: clear float outputs, or a typed
/// reference into the session's ciphertext store. Encrypted engines
/// return `ResultRef` — the blob id no longer rides the `f32` output
/// vector, so ids are not limited to the f32-exact 2²⁴ range the old
/// encoding imposed (ROADMAP item).
#[derive(Clone, Debug, PartialEq)]
pub enum EngineOutput {
    Values(Vec<f32>),
    ResultRef(u64),
}

impl EngineOutput {
    /// Split into the response fields (`output`, `result_blob`).
    pub fn into_response_fields(self) -> (Vec<f32>, Option<u64>) {
        match self {
            EngineOutput::Values(v) => (v, None),
            EngineOutput::ResultRef(id) => (Vec::new(), Some(id)),
        }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    /// Flattened output values (floats for clear paths; empty for
    /// encrypted results, which arrive as [`InferResponse::result_blob`]).
    pub output: Vec<f32>,
    /// Typed reference to an encrypted result bundle in the session's
    /// ciphertext store (encrypted engines only). Carried as an exact
    /// `u64` — unlike the retired encode-as-f32 scheme and its 2²⁴
    /// limit. (The TCP JSON layer narrows this to the 2⁵³ JSON-number
    /// range, refusing larger ids loudly — see `server::proto`.)
    pub result_blob: Option<u64>,
    pub engine: String,
    /// Queue + execution latency in seconds.
    pub latency_s: f64,
    /// Typed failure (its [`FheError::code`] is the wire `error_code`).
    pub error: Option<FheError>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_keys_separate_engines_and_sessions() {
        let a = EnginePath::Pjrt("model_inhibitor".into()).batch_key();
        let b = EnginePath::QuantInt("inhibitor".into()).batch_key();
        let c = EnginePath::Encrypted { session: 1, mechanism: "inhibitor".into() }.batch_key();
        let d = EnginePath::Encrypted { session: 2, mechanism: "inhibitor".into() }.batch_key();
        assert!(a != b && b != c && c != d);
    }

    #[test]
    fn same_variant_shares_key() {
        let a = EnginePath::QuantInt("dotprod".into()).batch_key();
        let b = EnginePath::QuantInt("dotprod".into()).batch_key();
        assert_eq!(a, b);
    }

    #[test]
    fn encrypted_keys_canonicalize_mechanism_aliases() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "softmax".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "dotprod".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        // Unknown names pass through (rejected later at registration).
        let junk = EnginePath::Encrypted { session: 7, mechanism: "nonsense".into() };
        assert_eq!(junk.batch_key(), "fhe/nonsense/7");
    }

    #[test]
    fn multihead_keys_canonicalize_base_and_keep_configuration_distinct() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "softmax@h4s".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "dotprod@h4s".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        assert_eq!(canon.batch_key(), "fhe/dotprod@h4s/7");
        let single = EnginePath::Encrypted { session: 7, mechanism: "dotprod".into() };
        let two = EnginePath::Encrypted { session: 7, mechanism: "dotprod@h2".into() };
        assert!(canon.batch_key() != single.batch_key());
        assert!(canon.batch_key() != two.batch_key());
    }

    #[test]
    fn block_keys_canonicalize_the_inner_mechanism() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "block/softmax@h2xL3".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "block/dotprod@h2xL3".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        assert_eq!(canon.batch_key(), "fhe/block/dotprod@h2xL3/7");
        // Block keys never collide with the bare multi-head keys of the
        // same mechanism/session.
        let mh = EnginePath::Encrypted { session: 7, mechanism: "dotprod@h2xL3".into() };
        assert!(canon.batch_key() != mh.batch_key());
    }

    #[test]
    fn decode_keys_canonicalize_the_inner_mechanism() {
        let alias = EnginePath::Encrypted { session: 7, mechanism: "decode/softmax@h2xL3".into() };
        let canon = EnginePath::Encrypted { session: 7, mechanism: "decode/dotprod@h2xL3".into() };
        assert_eq!(alias.batch_key(), canon.batch_key());
        assert_eq!(canon.batch_key(), "fhe/decode/dotprod@h2xL3/7");
        // Decode keys never collide with the block keys of the same
        // mechanism/session — their plan inventories are disjoint.
        let blk = EnginePath::Encrypted { session: 7, mechanism: "block/dotprod@h2xL3".into() };
        assert!(canon.batch_key() != blk.batch_key());
    }

    #[test]
    fn cache_routing_defaults_off_and_attaches_via_builder() {
        let base = InferRequest::new(1, EnginePath::QuantInt("dotprod".into()), Payload::Tokens(vec![]));
        assert!(base.cache_ref.is_none() && base.cache_out.is_none());
        let step = base.with_cache(Some(3), Some(4));
        assert_eq!(step.cache_ref, Some(3));
        assert_eq!(step.cache_out, Some(4));
    }

    #[test]
    fn engine_output_splits_into_response_fields() {
        assert_eq!(
            EngineOutput::Values(vec![1.0, 2.0]).into_response_fields(),
            (vec![1.0, 2.0], None)
        );
        // Typed refs carry ids the f32 vector could not represent.
        let big = (1u64 << 24) + 1;
        assert_eq!(EngineOutput::ResultRef(big).into_response_fields(), (Vec::new(), Some(big)));
    }
}
