//! Scheduler (S9): per-engine worker threads consuming batches from their
//! batcher and running the engine body; responses flow back through
//! per-request channels. Thread-based (tokio is unavailable offline); for
//! a CPU-bound FHE/integer workload a thread per engine is the right
//! granularity anyway.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{EngineOutput, InferRequest, InferResponse};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// An engine body: maps a batch of requests to outputs (same order) —
/// clear float vectors or typed encrypted-result references
/// ([`EngineOutput`]). Errors are reported per-batch and propagated to
/// every member. The body itself need not be `Send` — it is *created
/// inside* its worker thread by the factory (PJRT handles, for example,
/// must never cross threads).
pub type EngineBody = Box<dyn FnMut(&[InferRequest]) -> Result<Vec<EngineOutput>, String>>;

/// Factory that builds the engine body on the worker thread.
pub type EngineFn = Box<dyn FnOnce() -> EngineBody + Send>;

/// Handle to one running engine worker.
pub struct EngineWorker {
    pub name: String,
    pub batcher: Arc<Batcher>,
    handle: Option<JoinHandle<()>>,
}

/// Pending-response routing table.
type PendingMap = Arc<Mutex<std::collections::HashMap<u64, Sender<InferResponse>>>>;

/// The scheduler: owns workers, metrics and the pending-response table.
pub struct Scheduler {
    pub metrics: Arc<Metrics>,
    pending: PendingMap,
    workers: Vec<EngineWorker>,
    next_id: std::sync::atomic::AtomicU64,
    /// PBS worker threads granted to each encrypted engine's batch stages
    /// (`FHE_THREADS` env or all cores by default). The router applies
    /// this to a session's `FheContext` when its engine is registered.
    fhe_threads: usize,
}

impl Scheduler {
    pub fn new() -> Self {
        Scheduler {
            metrics: Arc::new(Metrics::new()),
            pending: Arc::new(Mutex::new(std::collections::HashMap::new())),
            workers: Vec::new(),
            next_id: std::sync::atomic::AtomicU64::new(1),
            fhe_threads: crate::tfhe::default_fhe_threads(),
        }
    }

    /// PBS worker threads handed to encrypted engines.
    pub fn fhe_threads(&self) -> usize {
        self.fhe_threads
    }

    /// Override the per-engine PBS worker count (serving-side config;
    /// applies to engines registered after the call).
    pub fn set_fhe_threads(&mut self, n: usize) {
        self.fhe_threads = n.max(1);
    }

    /// Register an engine under `name` with its batching policy; spawns
    /// the worker thread.
    pub fn add_engine(&mut self, name: &str, policy: BatchPolicy, factory: EngineFn) {
        let batcher = Arc::new(Batcher::new(policy));
        let b = Arc::clone(&batcher);
        let pending = Arc::clone(&self.pending);
        let metrics = Arc::clone(&self.metrics);
        let engine_name = name.to_string();
        let handle = std::thread::spawn(move || {
            let mut body = factory();
            while let Some(batch) = b.next_batch() {
                metrics.batches.fetch_add(1, Ordering::Relaxed);
                metrics.batched_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
                // A panicking engine body must not kill the worker: convert
                // panics into per-batch errors and keep serving.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    body(&batch)
                }))
                .unwrap_or_else(|p| {
                    let msg = p
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "engine panicked".to_string());
                    Err(format!("engine panic: {msg}"))
                });
                let mut pend = pending.lock().unwrap();
                match result {
                    Ok(outputs) => {
                        debug_assert_eq!(outputs.len(), batch.len());
                        for (req, out) in batch.iter().zip(outputs) {
                            let latency = req.enqueued.elapsed().as_secs_f64();
                            metrics.latency.record(latency);
                            metrics.completed.fetch_add(1, Ordering::Relaxed);
                            if let Some(tx) = pend.remove(&req.id) {
                                let (output, result_blob) = out.into_response_fields();
                                let _ = tx.send(InferResponse {
                                    id: req.id,
                                    output,
                                    result_blob,
                                    engine: engine_name.clone(),
                                    latency_s: latency,
                                    error: None,
                                });
                            }
                        }
                    }
                    Err(e) => {
                        for req in &batch {
                            if let Some(tx) = pend.remove(&req.id) {
                                let _ = tx.send(InferResponse {
                                    id: req.id,
                                    output: Vec::new(),
                                    result_blob: None,
                                    engine: engine_name.clone(),
                                    latency_s: req.enqueued.elapsed().as_secs_f64(),
                                    error: Some(e.clone()),
                                });
                            }
                        }
                    }
                }
            }
        });
        self.workers.push(EngineWorker { name: name.to_string(), batcher, handle: Some(handle) });
    }

    /// Find the worker serving a batch key.
    fn worker(&self, key: &str) -> Option<&EngineWorker> {
        self.workers.iter().find(|w| w.name == key)
    }

    pub fn engine_names(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.name.clone()).collect()
    }

    /// Submit a request (id is assigned here); returns the response
    /// receiver, or Err when the engine is unknown or backpressure hits.
    pub fn submit(
        &self,
        mut req: InferRequest,
    ) -> Result<Receiver<InferResponse>, String> {
        let key = req.path.batch_key();
        let worker =
            self.worker(&key).ok_or_else(|| format!("no engine registered for '{key}'"))?;
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.enqueued = std::time::Instant::now();
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(req.id, tx);
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match worker.batcher.submit(req) {
            Ok(()) => Ok(rx),
            Err(rejected) => {
                self.pending.lock().unwrap().remove(&rejected.id);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(format!("queue full for '{key}'"))
            }
        }
    }

    /// Graceful shutdown: close all batchers, join workers.
    pub fn shutdown(&mut self) {
        for w in &self.workers {
            w.batcher.close();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{EnginePath, Payload};
    use std::time::Duration;

    fn echo_engine() -> EngineFn {
        Box::new(|| {
            Box::new(|batch: &[InferRequest]| {
                Ok(batch
                    .iter()
                    .map(|r| {
                        EngineOutput::Values(match &r.payload {
                            Payload::Features(f, _) => f.iter().map(|x| x * 2.0).collect(),
                            _ => vec![r.id as f32],
                        })
                    })
                    .collect())
            })
        })
    }

    fn quant_path() -> EnginePath {
        EnginePath::QuantInt("inhibitor".into())
    }

    #[test]
    fn submit_and_receive() {
        let mut s = Scheduler::new();
        s.add_engine(&quant_path().batch_key(), BatchPolicy::default(), echo_engine());
        let rx = s
            .submit(InferRequest::new(
                0,
                quant_path(),
                Payload::Features(vec![1.0, 2.0], (1, 2)),
            ))
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert!(resp.error.is_none());
        assert!(resp.latency_s >= 0.0);
    }

    #[test]
    fn unknown_engine_rejected() {
        let s = Scheduler::new();
        let err = s
            .submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![])))
            .unwrap_err();
        assert!(err.contains("no engine"), "{err}");
    }

    #[test]
    fn errors_propagate_to_all_batch_members() {
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), queue_cap: 64 },
            Box::new(|| Box::new(|_batch: &[InferRequest]| Err("engine exploded".to_string()))),
        );
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                s.submit(InferRequest::new(i, quant_path(), Payload::Tokens(vec![]))).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.error.as_deref(), Some("engine exploded"));
        }
    }

    #[test]
    fn many_requests_all_complete_with_batching() {
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2), queue_cap: 4096 },
            echo_engine(),
        );
        let rxs: Vec<_> = (0..500)
            .map(|i| {
                s.submit(InferRequest::new(
                    i,
                    quant_path(),
                    Payload::Features(vec![i as f32], (1, 1)),
                ))
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(resp.error.is_none());
        }
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 500);
        assert!(s.metrics.mean_batch_size() > 1.0, "batching should kick in");
    }
}
