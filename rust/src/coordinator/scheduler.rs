//! Scheduler (S9): per-engine worker threads consuming batches from their
//! batcher and running the engine body; responses flow back through
//! per-request channels. Thread-based (tokio is unavailable offline); for
//! a CPU-bound FHE/integer workload a thread per engine is the right
//! granularity anyway.
//!
//! ## Supervision (PR 6)
//!
//! The worker loop is a supervisor: a panicking engine body is caught,
//! the body is **respawned from its factory**, and — when the crashed
//! batch had several members — the survivors are **replayed solo**
//! (bounded: each request runs at most twice) so one poison request
//! cannot fail its co-scheduled neighbors. Requests whose deadline
//! expired while queued are dropped at dequeue with `DeadlineExceeded`
//! instead of burning engine time, and shutdown drains every pending
//! receiver with a typed `Shutdown` error — receivers never hang.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{EngineOutput, InferRequest, InferResponse};
use crate::error::{panic_message, FheError};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// An engine body: maps a batch of requests to per-request results (same
/// order) — clear float vectors or typed encrypted-result references
/// ([`EngineOutput`]), each independently fallible so one bad member
/// does not fail its neighbors. The outer `Result` is for failures that
/// genuinely affect the whole batch (and is propagated to every
/// member). The body itself need not be `Send` — it is *created inside*
/// its worker thread by the factory (PJRT handles, for example, must
/// never cross threads).
pub type EngineBody =
    Box<dyn FnMut(&[InferRequest]) -> Result<Vec<Result<EngineOutput, FheError>>, FheError>>;

/// Factory that builds the engine body on the worker thread — callable
/// repeatedly, because the supervisor respawns a crashed body from it.
pub type EngineFn = Box<dyn Fn() -> EngineBody + Send>;

/// Handle to one running engine worker.
pub struct EngineWorker {
    pub name: String,
    pub batcher: Arc<Batcher>,
    handle: Option<JoinHandle<()>>,
}

/// Pending-response routing table.
type PendingMap = Arc<Mutex<HashMap<u64, Sender<InferResponse>>>>;

/// Resolve one request with its result: remove the pending sender and
/// ship the response (success records latency/completion; a
/// `WorkerPanic` bumps its counter).
fn respond(
    pending: &Mutex<HashMap<u64, Sender<InferResponse>>>,
    metrics: &Metrics,
    engine: &str,
    req: &InferRequest,
    result: Result<EngineOutput, FheError>,
) {
    let latency = req.enqueued.elapsed().as_secs_f64();
    let tx = pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&req.id);
    let Some(tx) = tx else { return };
    let resp = match result {
        Ok(out) => {
            metrics.latency.record(latency);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            let (output, result_blob) = out.into_response_fields();
            InferResponse {
                id: req.id,
                output,
                result_blob,
                engine: engine.to_string(),
                latency_s: latency,
                error: None,
            }
        }
        Err(e) => {
            if matches!(e, FheError::WorkerPanic(_)) {
                metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
            }
            InferResponse {
                id: req.id,
                output: Vec::new(),
                result_blob: None,
                engine: engine.to_string(),
                latency_s: latency,
                error: Some(e),
            }
        }
    };
    let _ = tx.send(resp);
}

/// The scheduler: owns workers, metrics and the pending-response table.
pub struct Scheduler {
    pub metrics: Arc<Metrics>,
    pending: PendingMap,
    workers: Vec<EngineWorker>,
    next_id: std::sync::atomic::AtomicU64,
    /// Set once shutdown begins: new submissions fail `Shutdown` instead
    /// of racing the closing batchers.
    closing: Arc<AtomicBool>,
    /// PBS worker threads granted to each encrypted engine's batch stages
    /// (`FHE_THREADS` env or all cores by default). The router applies
    /// this to a session's `FheContext` when its engine is registered.
    fhe_threads: usize,
}

impl Scheduler {
    pub fn new() -> Self {
        Scheduler {
            metrics: Arc::new(Metrics::new()),
            pending: Arc::new(Mutex::new(HashMap::new())),
            workers: Vec::new(),
            next_id: std::sync::atomic::AtomicU64::new(1),
            closing: Arc::new(AtomicBool::new(false)),
            fhe_threads: crate::tfhe::default_fhe_threads(),
        }
    }

    /// PBS worker threads handed to encrypted engines.
    pub fn fhe_threads(&self) -> usize {
        self.fhe_threads
    }

    /// Override the per-engine PBS worker count (serving-side config;
    /// applies to engines registered after the call).
    pub fn set_fhe_threads(&mut self, n: usize) {
        self.fhe_threads = n.max(1);
    }

    /// Register an engine under `name` with its batching policy; spawns
    /// the supervising worker thread.
    pub fn add_engine(&mut self, name: &str, policy: BatchPolicy, factory: EngineFn) {
        let batcher = Arc::new(Batcher::new(policy));
        let b = Arc::clone(&batcher);
        let pending = Arc::clone(&self.pending);
        let metrics = Arc::clone(&self.metrics);
        let engine_name = name.to_string();
        let handle = std::thread::spawn(move || {
            let mut body = factory();
            while let Some(batch) = b.next_batch() {
                // Dequeue-time checkpoint: expired or cancelled requests
                // are dropped here instead of burning engine time.
                let mut live = Vec::with_capacity(batch.len());
                for req in batch {
                    if req.cancel.is_cancelled() {
                        respond(&pending, &metrics, &engine_name, &req, Err(FheError::Cancelled));
                    } else if req.expired() {
                        metrics.deadline_kills.fetch_add(1, Ordering::Relaxed);
                        respond(
                            &pending,
                            &metrics,
                            &engine_name,
                            &req,
                            Err(FheError::DeadlineExceeded(
                                "deadline expired while queued".to_string(),
                            )),
                        );
                    } else {
                        live.push(req);
                    }
                }
                if live.is_empty() {
                    continue;
                }
                metrics.batches.fetch_add(1, Ordering::Relaxed);
                metrics.batched_requests.fetch_add(live.len() as u64, Ordering::Relaxed);
                // A panicking engine body must not kill the worker:
                // catch, respond, respawn from the factory, keep serving.
                match catch_unwind(AssertUnwindSafe(|| body(&live))) {
                    Ok(Ok(outputs)) => {
                        debug_assert_eq!(outputs.len(), live.len());
                        let mut saw_panic = false;
                        for (req, out) in live.iter().zip(outputs) {
                            saw_panic |=
                                matches!(&out, Err(FheError::WorkerPanic(_)));
                            respond(&pending, &metrics, &engine_name, req, out);
                        }
                        if saw_panic {
                            // A pool worker panicked under the body (the
                            // pool contained it to one job, but the body
                            // may hold state the panic left mid-update):
                            // rebuild defensively.
                            metrics.respawns.fetch_add(1, Ordering::Relaxed);
                            body = factory();
                        }
                    }
                    Ok(Err(e)) => {
                        // Typed whole-batch failure: propagate to every
                        // member; the body is intact, no respawn.
                        for req in &live {
                            respond(&pending, &metrics, &engine_name, req, Err(e.clone()));
                        }
                    }
                    Err(p) => {
                        // Wholesale crash. Respawn, then — if several
                        // members were aboard — replay each solo exactly
                        // once to pin the poison and save the survivors.
                        metrics.respawns.fetch_add(1, Ordering::Relaxed);
                        body = factory();
                        let msg = panic_message(p);
                        if live.len() == 1 {
                            metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                            respond(
                                &pending,
                                &metrics,
                                &engine_name,
                                &live[0],
                                Err(FheError::WorkerPanic(msg)),
                            );
                            continue;
                        }
                        for req in &live {
                            metrics.retries.fetch_add(1, Ordering::Relaxed);
                            let solo = std::slice::from_ref(req);
                            match catch_unwind(AssertUnwindSafe(|| body(solo))) {
                                Ok(Ok(mut outs)) => {
                                    debug_assert_eq!(outs.len(), 1);
                                    let out = outs.pop().unwrap_or_else(|| {
                                        Err(FheError::Internal(
                                            "engine returned no output".to_string(),
                                        ))
                                    });
                                    respond(&pending, &metrics, &engine_name, req, out);
                                }
                                Ok(Err(e)) => {
                                    respond(&pending, &metrics, &engine_name, req, Err(e));
                                }
                                Err(p2) => {
                                    // The poison: quarantine it (no second
                                    // replay) and respawn once more.
                                    metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                                    metrics.respawns.fetch_add(1, Ordering::Relaxed);
                                    body = factory();
                                    respond(
                                        &pending,
                                        &metrics,
                                        &engine_name,
                                        req,
                                        Err(FheError::WorkerPanic(panic_message(p2))),
                                    );
                                }
                            }
                        }
                    }
                }
            }
        });
        self.workers.push(EngineWorker { name: name.to_string(), batcher, handle: Some(handle) });
    }

    /// Find the worker serving a batch key.
    fn worker(&self, key: &str) -> Option<&EngineWorker> {
        self.workers.iter().find(|w| w.name == key)
    }

    pub fn engine_names(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.name.clone()).collect()
    }

    /// Submit a request (id is assigned here); returns the response
    /// receiver, or a typed error when the engine is unknown,
    /// backpressure hits, or the scheduler is shutting down.
    pub fn submit(&self, mut req: InferRequest) -> Result<Receiver<InferResponse>, FheError> {
        if self.closing.load(Ordering::Relaxed) {
            return Err(FheError::Shutdown);
        }
        let key = req.path.batch_key();
        let worker = self
            .worker(&key)
            .ok_or_else(|| FheError::UnknownEngine(format!("no engine registered for '{key}'")))?;
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.enqueued = std::time::Instant::now();
        let (tx, rx) = channel();
        self.pending.lock().unwrap_or_else(|e| e.into_inner()).insert(req.id, tx);
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match worker.batcher.submit(req) {
            Ok(()) => Ok(rx),
            Err(rejected) => {
                self.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&rejected.id);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                if self.closing.load(Ordering::Relaxed) {
                    Err(FheError::Shutdown)
                } else {
                    Err(FheError::QueueFull(format!("queue full for '{key}'")))
                }
            }
        }
    }

    /// Graceful shutdown: refuse new work, let queued requests drain
    /// through their engines, join workers, then resolve any receiver
    /// still pending with `Shutdown` (nothing is ever left hanging).
    pub fn shutdown(&mut self) {
        self.closing.store(true, Ordering::Relaxed);
        for w in &self.workers {
            w.batcher.close();
        }
        self.join_and_drain();
    }

    /// Immediate shutdown: evict queued requests *without* running them
    /// and fail them (and anything else pending) with `Shutdown`; only
    /// the batch already inside an engine completes.
    pub fn shutdown_now(&mut self) {
        self.closing.store(true, Ordering::Relaxed);
        for w in &self.workers {
            let _ = w.batcher.abort();
        }
        self.join_and_drain();
    }

    fn join_and_drain(&mut self) {
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
        // Whatever is still routed (evicted by abort, or orphaned any
        // other way) resolves with a typed Shutdown error now.
        let drained: Vec<(u64, Sender<InferResponse>)> =
            self.pending.lock().unwrap_or_else(|e| e.into_inner()).drain().collect();
        for (id, tx) in drained {
            self.metrics.shutdown_drained.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(InferResponse {
                id,
                output: Vec::new(),
                result_blob: None,
                engine: String::new(),
                latency_s: 0.0,
                error: Some(FheError::Shutdown),
            });
        }
    }
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::coordinator::request::{EnginePath, Payload};
    use std::sync::atomic::AtomicU64;
    use std::time::{Duration, Instant};

    fn echo_engine() -> EngineFn {
        Box::new(|| {
            Box::new(|batch: &[InferRequest]| {
                Ok(batch
                    .iter()
                    .map(|r| {
                        Ok(EngineOutput::Values(match &r.payload {
                            Payload::Features(f, _) => f.iter().map(|x| x * 2.0).collect(),
                            _ => vec![r.id as f32],
                        }))
                    })
                    .collect())
            })
        })
    }

    fn quant_path() -> EnginePath {
        EnginePath::QuantInt("inhibitor".into())
    }

    #[test]
    fn submit_and_receive() {
        let mut s = Scheduler::new();
        s.add_engine(&quant_path().batch_key(), BatchPolicy::default(), echo_engine());
        let rx = s
            .submit(InferRequest::new(
                0,
                quant_path(),
                Payload::Features(vec![1.0, 2.0], (1, 2)),
            ))
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert!(resp.error.is_none());
        assert!(resp.latency_s >= 0.0);
    }

    #[test]
    fn unknown_engine_rejected() {
        let s = Scheduler::new();
        let err = s
            .submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![])))
            .unwrap_err();
        assert!(matches!(err, FheError::UnknownEngine(_)), "{err:?}");
        assert_eq!(err.code(), "unknown_engine");
        assert!(err.to_string().contains("no engine"), "{err}");
    }

    #[test]
    fn typed_batch_errors_propagate_to_all_batch_members() {
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), queue_cap: 64 },
            Box::new(|| {
                Box::new(|_batch: &[InferRequest]| {
                    Err(FheError::Internal("engine exploded".to_string()))
                })
            }),
        );
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                s.submit(InferRequest::new(i, quant_path(), Payload::Tokens(vec![]))).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.error, Some(FheError::Internal("engine exploded".to_string())));
        }
        // A typed error is not a crash: the body was never respawned.
        assert_eq!(s.metrics.respawns.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn per_request_errors_fail_only_their_member() {
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20), queue_cap: 64 },
            Box::new(|| {
                Box::new(|batch: &[InferRequest]| {
                    Ok(batch
                        .iter()
                        .map(|r| match &r.payload {
                            Payload::Tokens(t) if t == &vec![13] => {
                                Err(FheError::BadRequest("unlucky".to_string()))
                            }
                            _ => Ok(EngineOutput::Values(vec![r.id as f32])),
                        })
                        .collect())
                })
            }),
        );
        let good = s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![1]))).unwrap();
        let bad = s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![13]))).unwrap();
        assert!(good.recv_timeout(Duration::from_secs(5)).unwrap().error.is_none());
        let resp = bad.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.error, Some(FheError::BadRequest("unlucky".to_string())));
    }

    #[test]
    fn many_requests_all_complete_with_batching() {
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2), queue_cap: 4096 },
            echo_engine(),
        );
        let rxs: Vec<_> = (0..500)
            .map(|i| {
                s.submit(InferRequest::new(
                    i,
                    quant_path(),
                    Payload::Features(vec![i as f32], (1, 1)),
                ))
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(resp.error.is_none());
        }
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 500);
        assert!(s.metrics.mean_batch_size() > 1.0, "batching should kick in");
    }

    #[test]
    fn engine_respawned_after_panic_and_keeps_serving() {
        let mut s = Scheduler::new();
        let bodies = Arc::new(AtomicU64::new(0));
        let bodies_in_factory = Arc::clone(&bodies);
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 64 },
            Box::new(move || {
                // Body #1 always panics; respawned bodies echo.
                let generation = bodies_in_factory.fetch_add(1, Ordering::Relaxed) + 1;
                Box::new(move |batch: &[InferRequest]| {
                    if generation == 1 {
                        panic!("engine bug");
                    }
                    Ok(batch.iter().map(|r| Ok(EngineOutput::Values(vec![r.id as f32]))).collect())
                })
            }),
        );
        let rx1 = s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![]))).unwrap();
        let resp1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        match resp1.error {
            Some(FheError::WorkerPanic(ref m)) => assert!(m.contains("engine bug"), "{m}"),
            ref other => panic!("want WorkerPanic, got {other:?}"),
        }
        // The supervisor rebuilt the body: the same engine keeps serving.
        let rx2 = s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![]))).unwrap();
        assert!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().error.is_none());
        assert_eq!(s.metrics.respawns.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.quarantined.load(Ordering::Relaxed), 1);
        assert_eq!(bodies.load(Ordering::Relaxed), 2, "factory called once per spawn");
    }

    #[test]
    fn poison_batch_quarantined_by_bounded_solo_replay() {
        // Batch of 3 with one poison member: the wholesale crash is
        // replayed solo (each member exactly once); the two survivors
        // succeed, the poison is quarantined with WorkerPanic.
        let mut s = Scheduler::new();
        let poison = |r: &InferRequest| matches!(&r.payload, Payload::Tokens(t) if t == &vec![13]);
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(5), queue_cap: 64 },
            Box::new(move || {
                Box::new(move |batch: &[InferRequest]| {
                    if batch.iter().any(poison) {
                        panic!("poisoned job");
                    }
                    Ok(batch.iter().map(|r| Ok(EngineOutput::Values(vec![r.id as f32]))).collect())
                })
            }),
        );
        let payloads = [vec![1], vec![13], vec![2]];
        let rxs: Vec<_> = payloads
            .iter()
            .map(|t| {
                s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(t.clone()))).unwrap()
            })
            .collect();
        let resps: Vec<_> =
            rxs.iter().map(|rx| rx.recv_timeout(Duration::from_secs(5)).unwrap()).collect();
        assert!(resps[0].error.is_none(), "{:?}", resps[0].error);
        assert!(resps[2].error.is_none(), "{:?}", resps[2].error);
        match resps[1].error {
            Some(FheError::WorkerPanic(ref m)) => assert!(m.contains("poisoned job"), "{m}"),
            ref other => panic!("want WorkerPanic, got {other:?}"),
        }
        let m = &s.metrics;
        assert_eq!(m.retries.load(Ordering::Relaxed), 3, "each member replayed once");
        assert_eq!(m.quarantined.load(Ordering::Relaxed), 1);
        assert_eq!(m.respawns.load(Ordering::Relaxed), 2, "batch crash + poison replay");
        assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn expired_deadline_dropped_at_dequeue() {
        let mut s = Scheduler::new();
        s.add_engine(&quant_path().batch_key(), BatchPolicy::default(), echo_engine());
        let req = InferRequest::new(0, quant_path(), Payload::Tokens(vec![]))
            .with_deadline(Instant::now() - Duration::from_millis(1));
        let rx = s.submit(req).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(
            matches!(resp.error, Some(FheError::DeadlineExceeded(_))),
            "{:?}",
            resp.error
        );
        assert_eq!(s.metrics.deadline_kills.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn cancelled_request_dropped_at_dequeue() {
        let mut s = Scheduler::new();
        s.add_engine(&quant_path().batch_key(), BatchPolicy::default(), echo_engine());
        let req = InferRequest::new(0, quant_path(), Payload::Tokens(vec![]));
        let token = req.cancel.clone();
        token.cancel();
        let rx = s.submit(req).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.error, Some(FheError::Cancelled));
    }

    #[test]
    fn shutdown_drains_queued_requests_with_shutdown_error() {
        // A slow single-request engine with a deep queue: shutdown_now
        // must resolve every receiver — the in-flight batch finishes,
        // everything still queued fails with the typed Shutdown error.
        let mut s = Scheduler::new();
        s.add_engine(
            &quant_path().batch_key(),
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 64 },
            Box::new(|| {
                Box::new(|batch: &[InferRequest]| {
                    std::thread::sleep(Duration::from_millis(150));
                    Ok(batch.iter().map(|r| Ok(EngineOutput::Values(vec![r.id as f32]))).collect())
                })
            }),
        );
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                s.submit(InferRequest::new(i, quant_path(), Payload::Tokens(vec![]))).unwrap()
            })
            .collect();
        // Let the worker pick up the first request, then pull the plug.
        std::thread::sleep(Duration::from_millis(50));
        s.shutdown_now();
        let mut ok = 0;
        let mut shut = 0;
        for rx in rxs {
            // Every receiver resolves — the old hang is the regression.
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match resp.error {
                None => ok += 1,
                Some(FheError::Shutdown) => shut += 1,
                ref other => panic!("unexpected error {other:?}"),
            }
        }
        assert_eq!(ok + shut, 5);
        assert!(shut >= 1, "queued requests must drain with Shutdown");
        assert_eq!(s.metrics.shutdown_drained.load(Ordering::Relaxed), shut);
        // New submissions are refused while shut down.
        let err =
            s.submit(InferRequest::new(0, quant_path(), Payload::Tokens(vec![]))).unwrap_err();
        assert_eq!(err, FheError::Shutdown);
    }
}
