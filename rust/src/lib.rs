//! # inhibitor — ReLU and Addition-Based Attention under TFHE
//!
//! A full-system reproduction of *"The Inhibitor: ReLU and Addition-Based
//! Attention for Efficient Transformers under Fully Homomorphic Encryption
//! on the Torus"* (Brännvall & Stoian, FHE.org 2024).
//!
//! The crate is the Layer-3 (request-path) half of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   fused inhibitor attention (paper eqs. 5–10); build time only.
//! * **L2** — a JAX transformer (`python/compile/model.py`) lowers to HLO
//!   text artifacts; build time only.
//! * **L3** — this crate: a serving coordinator that routes requests to a
//!   PJRT float engine (`runtime`, behind the `xla` feature), a quantized
//!   integer engine (`tensor`/`quant`/`attention`/`model`) and a real
//!   TFHE engine (`tfhe`/`fhe_circuits`), plus the parameter optimizer
//!   (`optimizer`) and the paper-table bench harness (`bench_tables`).
//!
//! ## Declarative circuit plans over a batched parallel PBS engine
//!
//! The paper denominates every circuit cost in PBS, and the runtime's
//! wall-clock is PBS-bound, so the TFHE layer is built plan-then-execute:
//!
//! * **Circuit-plan IR** (`tfhe::plan`): `CircuitBuilder` emits a
//!   `CircuitPlan` — a DAG of free linear ops and `Pbs { lut }` nodes. A
//!   leveling pass groups independent PBS into execution levels; the
//!   executor issues one batched submission per level. The same plan is
//!   the PBS-count oracle the optimizer's cost model and the bench
//!   tables read (`CircuitPlan::pbs_count`/`levels`), so accounting and
//!   implementation cannot drift. Both attention circuits
//!   (`fhe_circuits`) are plan builders; the PR 1 hand-staged forwards
//!   survive as bit-identity references (`forward_staged`).
//! * **Prepared LUTs** (`tfhe::PreparedLut`): the blind-rotation
//!   accumulator (slot replication + half-slot pre-rotation) is built
//!   once per LUT instead of inside every `pbs` call, with arbitrary
//!   tables cached by their message-space table.
//! * **Batch API** (`ServerKey::pbs_batch` / `FheContext::pbs_many`):
//!   independent (ciphertext, LUT) jobs fan out over a
//!   `std::thread::scope` worker pool — no external thread-pool crate —
//!   with one reusable `ExtScratch` per worker and an exact atomic
//!   `PBS_COUNT`. The worker count comes from the `FHE_THREADS` env var
//!   (default: all cores) and is plumbed through the serving coordinator
//!   (`Scheduler::set_fhe_threads`) and the benches. Keygen
//!   (`ClientKey::server_key`) fans its per-bit GGSW encryptions across
//!   the same pattern, thread-count invariantly.
//! * **Cross-request fusion** (`coordinator::FusedLevelExecutor`): the
//!   encrypted engine merges the current plan level of every
//!   co-scheduled request into one `pbs_batch` submission, filling the
//!   worker pool at small `T` without changing results or counts.
//!
//! See `rust/DESIGN.md` for the system inventory (§4 plan IR, §5 block
//! subsystem, §6 PBS engine, §7 coordinator fusion) and
//! `BENCH_pbs.json`/`BENCH_plan.json` for the checked-in perf
//! trajectory records.

// The integer/FHE kernels are written in explicit index notation to
// mirror the paper's equations (i, j, k subscripts over T×d heads);
// iterator rewrites of those loops obscure the math without changing
// the codegen.
#![allow(clippy::needless_range_loop)]

pub mod attention;
pub mod bench_harness;
pub mod bench_tables;
// The serving path must never crash on a request: every request-
// triggerable failure is a typed `error::FheError`, and the lint keeps
// new `unwrap()` calls from sneaking raw panics back in.
#[deny(clippy::unwrap_used)]
pub mod coordinator;
pub mod error;
pub mod fhe_circuits;
pub mod model;
pub mod optimizer;
pub mod quant;
#[cfg(feature = "xla")]
pub mod runtime;
#[deny(clippy::unwrap_used)]
pub mod server;
pub mod tensor;
pub mod tfhe;
pub mod util;
