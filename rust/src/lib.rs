//! # inhibitor — ReLU and Addition-Based Attention under TFHE
//!
//! A full-system reproduction of *"The Inhibitor: ReLU and Addition-Based
//! Attention for Efficient Transformers under Fully Homomorphic Encryption
//! on the Torus"* (Brännvall & Stoian, FHE.org 2024).
//!
//! The crate is the Layer-3 (request-path) half of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   fused inhibitor attention (paper eqs. 5–10); build time only.
//! * **L2** — a JAX transformer (`python/compile/model.py`) lowers to HLO
//!   text artifacts; build time only.
//! * **L3** — this crate: a serving coordinator that routes requests to a
//!   PJRT float engine (`runtime`, behind the `xla` feature), a quantized
//!   integer engine (`tensor`/`quant`/`attention`/`model`) and a real
//!   TFHE engine (`tfhe`/`fhe_circuits`), plus the parameter optimizer
//!   (`optimizer`) and the paper-table bench harness (`bench_tables`).
//!
//! ## Batched parallel PBS engine
//!
//! The paper denominates every circuit cost in PBS, and the runtime's
//! wall-clock is PBS-bound, so the TFHE layer executes bootstraps through
//! a batched, multi-threaded engine:
//!
//! * **Prepared LUTs** (`tfhe::PreparedLut`): the blind-rotation
//!   accumulator (slot replication + half-slot pre-rotation) is built
//!   once per LUT instead of inside every `pbs` call. `FheContext` keeps
//!   the standard tables (ReLU/abs/x²⁄4/identity) prepared and caches
//!   arbitrary `pbs_fn` tables keyed by their message-space table, so
//!   per-head LUTs like the Inhibitor's fused scale-shift-ReLU are built
//!   once per head rather than `T²` times.
//! * **Batch API** (`ServerKey::pbs_batch` / `FheContext::pbs_many`):
//!   independent (ciphertext, LUT) jobs fan out over a
//!   `std::thread::scope` worker pool — no external thread-pool crate —
//!   with one reusable `ExtScratch` per worker and an exact atomic
//!   `PBS_COUNT`. The worker count comes from the `FHE_THREADS` env var
//!   (default: all cores) and is plumbed through the serving coordinator
//!   (`Scheduler::set_fhe_threads`) and the benches.
//! * **Sync audit**: `ServerKey` (bootstrap key spectra, key-switch key,
//!   FFT plan with precomputed twiddles) and `FheContext` are immutable
//!   shared-read state — `Send + Sync` holds structurally and is asserted
//!   by compile-checked tests.
//! * **Level-synchronous circuits** (`fhe_circuits`): both attention
//!   forwards gather each circuit level's independent PBS into a single
//!   batch (score abs → fused scale-shift-ReLU → inhibition ReLU →
//!   refresh; square/exp/recip/probs/attend/rescale for the dot-product
//!   baseline), preserving exact ciphertext==mirror equality and the
//!   paper's per-head PBS counts.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod attention;
pub mod bench_harness;
pub mod bench_tables;
pub mod coordinator;
pub mod fhe_circuits;
pub mod model;
pub mod optimizer;
pub mod quant;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tfhe;
pub mod util;
