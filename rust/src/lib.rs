//! # inhibitor — ReLU and Addition-Based Attention under TFHE
//!
//! A full-system reproduction of *"The Inhibitor: ReLU and Addition-Based
//! Attention for Efficient Transformers under Fully Homomorphic Encryption
//! on the Torus"* (Brännvall & Stoian, FHE.org 2024).
//!
//! The crate is the Layer-3 (request-path) half of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   fused inhibitor attention (paper eqs. 5–10); build time only.
//! * **L2** — a JAX transformer (`python/compile/model.py`) lowers to HLO
//!   text artifacts; build time only.
//! * **L3** — this crate: a serving coordinator that routes requests to a
//!   PJRT float engine (`runtime`), a quantized integer engine
//!   (`tensor`/`quant`/`attention`/`model`) and a real TFHE engine
//!   (`tfhe`/`fhe_circuits`), plus the parameter optimizer (`optimizer`)
//!   and the paper-table bench harness (`bench_tables`).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod attention;
pub mod bench_harness;
pub mod bench_tables;
pub mod coordinator;
pub mod fhe_circuits;
pub mod model;
pub mod optimizer;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tfhe;
pub mod util;
