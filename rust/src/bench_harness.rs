//! Statistical micro-benchmark harness (criterion replacement).
//!
//! Criterion is not available in the offline registry, so the paper-table
//! benches use this harness. It mirrors what the paper reports: timings
//! averaged over repeated runs with a 95% confidence interval
//! ("averaged over 20 repeated experiments and significant at the 95%
//! confidence level").
//!
//! Protocol per benchmark:
//!   1. warm up for `warmup_iters` un-timed iterations,
//!   2. take `samples` timed samples (each sample may batch `inner_iters`
//!      iterations for fast bodies so the clock resolution doesn't bite),
//!   3. report mean, std-dev, and the 95% CI half-width (t≈1.96·σ/√n — we
//!      use the normal quantile; at n=20 the Student-t correction is ~6%,
//!      irrelevant at the factor-level comparisons the paper makes).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Mean wall-clock time per iteration, seconds.
    pub mean_s: f64,
    /// Sample standard deviation, seconds.
    pub std_s: f64,
    /// Half-width of the 95% confidence interval, seconds.
    pub ci95_s: f64,
    pub samples: usize,
    pub inner_iters: usize,
}

impl Measurement {
    /// Pretty time with an auto-selected unit, e.g. "63.1 µs".
    pub fn fmt_time(s: f64) -> String {
        if s >= 1.0 {
            format!("{:.3} s", s)
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else if s >= 1e-6 {
            format!("{:.1} µs", s * 1e6)
        } else {
            format!("{:.1} ns", s * 1e9)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} ± {:>10}  (n={}, inner={})",
            self.name,
            Self::fmt_time(self.mean_s),
            Self::fmt_time(self.ci95_s),
            self.samples,
            self.inner_iters
        )
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Iterations batched inside one timed sample (1 for slow bodies).
    pub inner_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 20, inner_iters: 1 }
    }
}

impl BenchConfig {
    /// Config for fast (sub-ms) bodies: batch iterations per sample.
    pub fn fast() -> Self {
        Self { warmup_iters: 50, samples: 20, inner_iters: 50 }
    }

    /// Config for very slow bodies (seconds each), e.g. PBS-heavy circuits.
    pub fn slow(samples: usize) -> Self {
        Self { warmup_iters: 1, samples, inner_iters: 1 }
    }
}

/// Run one benchmark. `f` is the body; its return value is black-boxed so
/// the optimizer cannot delete the computation.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut times = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..cfg.inner_iters {
            black_box(f());
        }
        let dt = t0.elapsed();
        times.push(dt.as_secs_f64() / cfg.inner_iters as f64);
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = if times.len() > 1 {
        times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / (n - 1.0)
    } else {
        0.0
    };
    let std = var.sqrt();
    let ci95 = 1.96 * std / n.sqrt();
    Measurement {
        name: name.to_string(),
        mean_s: mean,
        std_s: std,
        ci95_s: ci95,
        samples: times.len(),
        inner_iters: cfg.inner_iters,
    }
}

/// Re-implementation of `std::hint::black_box` semantics that works on
/// stable without relying on the (now stable) intrinsic — kept thin.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Auto-tune `inner_iters` so one sample takes ≥ `target` wall time, then
/// run the benchmark. Good default for bodies of unknown speed.
pub fn bench_auto<T>(name: &str, target: Duration, mut f: impl FnMut() -> T) -> Measurement {
    // Estimate the body cost with a few probes.
    let t0 = Instant::now();
    let mut probes = 0usize;
    while t0.elapsed() < Duration::from_millis(20) && probes < 1000 {
        black_box(f());
        probes += 1;
    }
    let per_iter = t0.elapsed().as_secs_f64() / probes.max(1) as f64;
    let inner = ((target.as_secs_f64() / per_iter).ceil() as usize).clamp(1, 100_000);
    let cfg = BenchConfig { warmup_iters: inner.min(10), samples: 20, inner_iters: inner };
    bench(name, cfg, f)
}

/// Render a simple aligned table of measurements (one row per entry),
/// plus a ratio column against a named baseline if provided.
pub fn print_table(title: &str, rows: &[Measurement], baseline_of: impl Fn(&str) -> Option<usize>) {
    println!("\n=== {title} ===");
    for (i, m) in rows.iter().enumerate() {
        let ratio = baseline_of(&m.name)
            .and_then(|b| rows.get(b))
            .map(|b| format!("  x{:.2} vs {}", b.mean_s / m.mean_s, b.name))
            .unwrap_or_default();
        println!("{:>2}. {}{}", i + 1, m.summary(), ratio);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench("spin", BenchConfig { warmup_iters: 2, samples: 10, inner_iters: 10 }, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(m.mean_s > 0.0);
        assert!(m.std_s >= 0.0);
        assert_eq!(m.samples, 10);
    }

    #[test]
    fn fmt_time_units() {
        assert!(Measurement::fmt_time(2.5).ends_with(" s"));
        assert!(Measurement::fmt_time(2.5e-3).ends_with(" ms"));
        assert!(Measurement::fmt_time(2.5e-6).ends_with(" µs"));
        assert!(Measurement::fmt_time(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_auto_picks_inner() {
        let m = bench_auto("fast-body", Duration::from_millis(5), || 1 + 1);
        assert!(m.inner_iters >= 1);
    }
}
