//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! Usage:
//! ```ignore
//! prop_check("add commutes", 256, |rng| {
//!     let a = rng.next_range_i64(-100, 100);
//!     let b = rng.next_range_i64(-100, 100);
//!     prop_assert_eq(a + b, b + a, "commutativity")
//! });
//! ```
//! Each case gets a fresh RNG derived from a base seed and the case index,
//! so a failure report ("case #k, seed s") is exactly reproducible.

use super::prng::Xoshiro256;

/// Result of a single property case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `f`, panicking with a reproducible report on
/// the first failure. The per-case RNG seed is `BASE_SEED ^ case_index`.
pub fn prop_check(name: &str, cases: u64, mut f: impl FnMut(&mut Xoshiro256) -> PropResult) {
    const BASE_SEED: u64 = 0x1AB1B1707_u64;
    for i in 0..cases {
        let seed = BASE_SEED ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case #{i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert equality inside a property, producing a descriptive error.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(got: T, want: T, ctx: &str) -> PropResult {
    if got == want {
        Ok(())
    } else {
        Err(format!("{ctx}: got {got:?}, want {want:?}"))
    }
}

/// Assert a boolean condition inside a property.
pub fn prop_assert(cond: bool, ctx: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(ctx.to_string())
    }
}

/// Assert |got - want| <= tol.
pub fn prop_assert_close(got: f64, want: f64, tol: f64, ctx: &str) -> PropResult {
    if (got - want).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: got {got}, want {want} (tol {tol}, err {})", (got - want).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng64;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("trivial", 32, |rng| {
            count += 1;
            let x = rng.next_range_i64(-5, 5);
            prop_assert(x.abs() <= 5, "bounded")
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_case_info() {
        prop_check("must fail", 8, |_rng| prop_assert(false, "always fails"));
    }
}
