//! Dependency-free utilities: PRNG, JSON, property-testing harness.
//!
//! The offline crate registry only carries the `xla` crate and its build
//! dependencies, so `rand`, `serde_json` and `proptest` are replaced by
//! these small in-tree implementations (see rust/DESIGN.md §3, S14).

pub mod json;
pub mod prng;
pub mod prop;
