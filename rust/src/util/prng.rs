//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement the generators we
//! need ourselves: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256**) as the workhorse generator. Both are well-studied,
//! public-domain algorithms (Blackman & Vigna). The TFHE layer additionally
//! needs Gaussian samples, provided via Box–Muller in
//! [`Xoshiro256::next_gaussian`].
//!
//! NOTE ON SECURITY: these generators are *not* cryptographically secure.
//! They are used for (a) reproducible tests/benchmarks and (b) the noise
//! sampling of the TFHE *simulation substrate*. A production deployment
//! would swap in a CSPRNG behind the same [`Rng64`] trait; the scheme logic
//! in `tfhe/` is agnostic to the source of randomness.

/// Minimal trait over 64-bit generators so TFHE code can be generic.
pub trait Rng64 {
    /// Next raw 64-bit value.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)`.
    fn next_f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)` (bound > 0) via Lemire-style rejection.
    fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Rejection sampling over the top to remove modulo bias.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform `i64` in `[lo, hi]` inclusive.
    fn next_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.next_bounded(span) as i64)
    }
}

/// SplitMix64 — used to expand one seed into xoshiro's four state words.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng64 for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Seed from a single u64 (expanded via SplitMix64 per Vigna's advice).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Standard-normal sample via Box–Muller (mean 0, std 1).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u1 == 0 (log(0)).
        let mut u1 = self.next_f64();
        while u1 <= f64::EPSILON {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gaussian with the given standard deviation.
    pub fn next_gaussian_std(&mut self, std: f64) -> f64 {
        self.next_gaussian() * std
    }
}

impl Rng64 for Xoshiro256 {
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        let v1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        assert_eq!(v1, v2);
        // Different seed should diverge immediately (overwhelming probability).
        let mut r3 = Xoshiro256::new(43);
        assert_ne!(v1[0], r3.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_in_bounds_and_covers() {
        let mut r = Xoshiro256::new(99);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_bounded(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Xoshiro256::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..20_000 {
            let v = r.next_range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(2024);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = r.next_gaussian();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean} too far from 0");
        assert!((var - 1.0).abs() < 0.03, "variance {var} too far from 1");
    }
}
