//! Minimal JSON value type, parser and serializer.
//!
//! `serde`/`serde_json` are not available in the offline build, so the
//! server wire protocol (JSON-lines over TCP) and the results files use
//! this small, dependency-free implementation. It supports the full JSON
//! data model except exotic number forms; numbers are held as `f64` (and
//! as `i64` when exactly integral), which is sufficient for our protocol.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::new(p.i, "trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: &'static str,
}

impl JsonError {
    fn new(offset: usize, msg: &'static str) -> Self {
        Self { offset, msg }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::new(self.i, "unexpected character"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit(b"true", Json::Bool(true)),
            Some(b'f') => self.lit(b"false", Json::Bool(false)),
            Some(b'n') => self.lit(b"null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::new(self.i, "expected value")),
        }
    }

    fn lit(&mut self, pat: &[u8], v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(pat) {
            self.i += pat.len();
            Ok(v)
        } else {
            Err(JsonError::new(self.i, "bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError::new(self.i, "expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(JsonError::new(self.i, "expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::new(self.i, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or(JsonError::new(self.i, "bad unicode escape"))?);
                            continue; // hex4 advanced past the digits already
                        }
                        _ => return Err(JsonError::new(self.i, "bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(JsonError::new(start, "truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| JsonError::new(start, "invalid utf-8"))?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or(JsonError::new(self.i, "eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or(JsonError::new(self.i, "bad hex"))?;
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| JsonError::new(start, "bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| JsonError::new(start, "bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        // Serialize and re-parse: must be identical value.
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-0", 0.0), ("3.25", 3.25), ("1e3", 1000.0), ("-2E-2", -0.02)]
        {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "tru", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(s).is_err(), "{s} should fail");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::obj(vec![("z", Json::num(1.0)), ("a", Json::num(2.0))]);
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
