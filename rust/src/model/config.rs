//! Model configuration (S3): a real config system for the quantized
//! transformer engine, parseable from JSON (the same file the Python
//! build path writes next to the exported weights).

use crate::attention::Mechanism;
use crate::util::json::Json;

/// Task endpoint the model exposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskHead {
    /// Mean-pool over the sequence then classify into `n` classes.
    Classify(usize),
    /// Mean-pool then a single regression output (adding problem).
    Regress,
    /// Per-position logits over `n` symbols (CTC-style decoding).
    PerPosition(usize),
}

/// Full model configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub mechanism: Mechanism,
    pub n_layers: usize,
    pub seq_len: usize,
    /// Embedding / model dimension d.
    pub dim: usize,
    /// Attention heads per block: `dim` splits into `n_heads` slices of
    /// `dim / n_heads`, attended independently and concatenated (1 =
    /// single-head, the paper's benchmark setups).
    pub n_heads: usize,
    /// FFN hidden dimension.
    pub ffn_dim: usize,
    /// Vocabulary size (0 ⇒ continuous inputs projected by a linear layer).
    pub vocab: usize,
    /// Input feature width when `vocab == 0`.
    pub in_features: usize,
    pub head: TaskHead,
    /// Code width for activations (paper plaintext experiments: 16).
    pub act_bits: u32,
    /// Code width for weights.
    pub weight_bits: u32,
    /// Inhibitor shift α (paper: 0.5).
    pub alpha: f32,
    /// Score scale γ; ≤ 0 means √d.
    pub gamma: f32,
}

impl ModelConfig {
    /// Small single-layer defaults matching the paper's benchmark setups.
    pub fn small(mechanism: Mechanism, seq_len: usize, dim: usize) -> Self {
        ModelConfig {
            mechanism,
            n_layers: 1,
            seq_len,
            dim,
            n_heads: 1,
            ffn_dim: dim * 4,
            vocab: 0,
            in_features: dim,
            head: TaskHead::Regress,
            act_bits: 16,
            weight_bits: 8,
            alpha: 0.5,
            gamma: -1.0,
        }
    }

    /// Parse from the JSON object written by `python/compile/aot.py`.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let get_i = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(|v| v.as_i64())
                .map(|v| v as usize)
                .ok_or_else(|| format!("config missing integer field '{k}'"))
        };
        let get_f = |k: &str, dflt: f32| -> f32 {
            j.get(k).and_then(|v| v.as_f64()).map(|v| v as f32).unwrap_or(dflt)
        };
        let mech_s = j
            .get("mechanism")
            .and_then(|v| v.as_str())
            .ok_or("config missing 'mechanism'")?;
        let mechanism =
            Mechanism::parse(mech_s).ok_or_else(|| format!("unknown mechanism '{mech_s}'"))?;
        let head = match j.get("head").and_then(|v| v.as_str()).unwrap_or("regress") {
            "regress" => TaskHead::Regress,
            "classify" => TaskHead::Classify(get_i("n_classes")?),
            "per_position" => TaskHead::PerPosition(get_i("n_classes")?),
            other => return Err(format!("unknown head '{other}'")),
        };
        Ok(ModelConfig {
            mechanism,
            n_layers: get_i("n_layers")?,
            seq_len: get_i("seq_len")?,
            dim: get_i("dim")?,
            n_heads: j.get("n_heads").and_then(|v| v.as_i64()).unwrap_or(1).max(1) as usize,
            ffn_dim: get_i("ffn_dim")?,
            vocab: j.get("vocab").and_then(|v| v.as_i64()).unwrap_or(0) as usize,
            in_features: j.get("in_features").and_then(|v| v.as_i64()).unwrap_or(0) as usize,
            head,
            act_bits: get_i("act_bits").unwrap_or(16) as u32,
            weight_bits: get_i("weight_bits").unwrap_or(8) as u32,
            alpha: get_f("alpha", 0.5),
            gamma: get_f("gamma", -1.0),
        })
    }

    pub fn to_json(&self) -> Json {
        let head = match self.head {
            TaskHead::Regress => ("regress", 0usize),
            TaskHead::Classify(n) => ("classify", n),
            TaskHead::PerPosition(n) => ("per_position", n),
        };
        Json::obj(vec![
            ("mechanism", Json::str(self.mechanism.name())),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("ffn_dim", Json::num(self.ffn_dim as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("in_features", Json::num(self.in_features as f64)),
            ("head", Json::str(head.0)),
            ("n_classes", Json::num(head.1 as f64)),
            ("act_bits", Json::num(self.act_bits as f64)),
            ("weight_bits", Json::num(self.weight_bits as f64)),
            ("alpha", Json::num(self.alpha as f64)),
            ("gamma", Json::num(self.gamma as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = ModelConfig::small(Mechanism::Inhibitor, 16, 8);
        c.head = TaskHead::Classify(10);
        c.vocab = 100;
        c.n_heads = 4;
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c2.mechanism, c.mechanism);
        assert_eq!(c2.head, c.head);
        assert_eq!(c2.seq_len, 16);
        assert_eq!(c2.vocab, 100);
        assert_eq!(c2.n_heads, 4);
        assert_eq!(c2.alpha, 0.5);
    }

    #[test]
    fn n_heads_defaults_to_one_for_legacy_configs() {
        // Configs written before the multi-head change carry no
        // `n_heads` field; they must keep parsing as single-head.
        let j = Json::parse(
            r#"{"mechanism":"inhibitor","n_layers":1,"seq_len":4,"dim":4,"ffn_dim":8}"#,
        )
        .unwrap();
        assert_eq!(ModelConfig::from_json(&j).unwrap().n_heads, 1);
    }

    #[test]
    fn rejects_bad_mechanism() {
        let j = Json::parse(r#"{"mechanism":"telepathy","n_layers":1,"seq_len":4,"dim":4,"ffn_dim":8}"#)
            .unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
