//! Composable quantized transformer (S3): blocks of attention + FFN with
//! residual connections and layer norm, plus task heads. The attention
//! mechanism is injected per the model config — the Inhibitor is a
//! first-class citizen of the model definition, not a bolt-on.

use super::config::{ModelConfig, TaskHead};
use super::layers::{QEmbedding, QFfn, QLayerNorm, QLinear};
use crate::attention::{AttentionHead, AttnConfig, HeadSplit};
use crate::quant::{FixedMult, QParams};
use crate::tensor::{FTensor, ITensor};
use crate::util::prng::Xoshiro256;

/// One transformer block (pre-LN variant, as in the paper's simple setups).
pub struct Block {
    pub ln1: QLayerNorm,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    /// The per-head attention mechanism (built at width `dim / n_heads`).
    pub attn: AttentionHead,
    /// Heads the attention sub-layer splits `dim` into: Q/K/V are column
    /// sliced per head, attended independently, and concatenated — the
    /// plaintext reference of the fused multi-head FHE path
    /// (`fhe_circuits::MultiHeadFhe`). 1 = single-head.
    pub n_heads: usize,
    pub ln2: QLayerNorm,
    pub ffn: QFfn,
    /// Requant applied to residual additions to stay in the act range.
    pub resid_requant: FixedMult,
}

impl Block {
    /// Multi-head attention over already-projected Q/K/V: per-head
    /// column slices through `self.attn`, concatenated. This is the
    /// exact function the fused multi-head circuit mirrors.
    fn attention(&self, q: &ITensor, k: &ITensor, v: &ITensor) -> ITensor {
        if self.n_heads <= 1 {
            return self.attn.forward(q, k, v);
        }
        // Per-head slicing through the shared HeadSplit helper — the same
        // arithmetic the fused encrypted plans and the block profiler use.
        let split = HeadSplit::new(q.dims()[1], self.n_heads);
        split.apply(q, k, v, false, |qs, ks, vs| self.attn.forward(qs, ks, vs))
    }

    pub fn forward(&self, x: &ITensor, act_scale: f32) -> ITensor {
        // --- attention sub-layer ---
        let xn = self.ln1.forward(x, act_scale);
        let q = self.wq.forward(&xn);
        let k = self.wk.forward(&xn);
        let v = self.wv.forward(&xn);
        let h = self.attention(&q, &k, &v);
        let h = self.wo.forward(&h);
        let x1 = x.add(&h).map(|t| self.resid_requant.apply(t));
        // --- FFN sub-layer ---
        let x1n = self.ln2.forward(&x1, act_scale);
        let f = self.ffn.forward(&x1n);
        x1.add(&f).map(|t| self.resid_requant.apply(t))
    }
}

/// The full quantized model: input adapter → blocks → task head.
pub struct QTransformer {
    pub cfg: ModelConfig,
    /// Common activation code scale.
    pub act_scale: f32,
    /// Input: token embedding (vocab > 0) or linear projection.
    pub embedding: Option<QEmbedding>,
    pub in_proj: Option<QLinear>,
    pub blocks: Vec<Block>,
    /// Output head weights `[n_out, dim]`.
    pub head: QLinear,
}

/// Model input: token ids or continuous features `[seq, in_features]`.
pub enum ModelInput {
    Tokens(Vec<usize>),
    Features(ITensor),
}

impl QTransformer {
    /// Randomly-initialized model (tests/benches; a trained model loads
    /// its weights via `model::weights`).
    pub fn random(cfg: ModelConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let act_scale = 4.0 / ((1i64 << (cfg.act_bits - 1)) - 1) as f32;
        let d = cfg.dim;
        let make_lin = |dout: usize, din: usize, rng: &mut Xoshiro256, scale: f32| {
            let w = FTensor::randn(&[dout, din], (1.0 / (din as f32).sqrt()) * scale, rng);
            let b = vec![0.0f32; dout];
            QLinear::from_float(&w, &b, act_scale, cfg.weight_bits, act_scale)
        };
        let embedding = if cfg.vocab > 0 {
            let qp = QParams::fit_symmetric(2.0, cfg.act_bits);
            let table = FTensor::randn(&[cfg.vocab, d], 0.5, &mut rng);
            Some(QEmbedding { table: qp.quantize_tensor(&table) })
        } else {
            None
        };
        let in_proj = if cfg.vocab == 0 {
            Some(make_lin(d, cfg.in_features.max(1), &mut rng, 1.0))
        } else {
            None
        };
        let n_heads = cfg.n_heads.max(1);
        assert_eq!(d % n_heads, 0, "dim {d} must split into {n_heads} heads");
        let blocks = (0..cfg.n_layers)
            .map(|_| {
                // The head mechanism operates on d/n_heads-wide slices
                // (γ = √d_head), matching the fused encrypted plan.
                let mut acfg = AttnConfig::new(cfg.mechanism, cfg.seq_len, d / n_heads);
                acfg.alpha = cfg.alpha;
                acfg.gamma = cfg.gamma;
                Block {
                    ln1: QLayerNorm::from_float(&vec![1.0; d], &vec![0.0; d], act_scale),
                    wq: make_lin(d, d, &mut rng, 1.0),
                    wk: make_lin(d, d, &mut rng, 1.0),
                    wv: make_lin(d, d, &mut rng, 1.0),
                    wo: make_lin(d, d, &mut rng, 1.0),
                    attn: AttentionHead::build(acfg, act_scale),
                    n_heads,
                    ln2: QLayerNorm::from_float(&vec![1.0; d], &vec![0.0; d], act_scale),
                    ffn: QFfn {
                        fc1: make_lin(cfg.ffn_dim, d, &mut rng, 1.0),
                        fc2: make_lin(d, cfg.ffn_dim, &mut rng, 1.0),
                    },
                    resid_requant: FixedMult::from_f64(0.5),
                }
            })
            .collect();
        let n_out = match cfg.head {
            TaskHead::Regress => 1,
            TaskHead::Classify(n) | TaskHead::PerPosition(n) => n,
        };
        let head = make_lin(n_out, d, &mut rng, 1.0);
        QTransformer { cfg, act_scale, embedding, in_proj, blocks, head }
    }

    /// Forward pass. Returns logits: `[n_classes]` for classification,
    /// `[1]` for regression, `[seq, n_symbols]` for per-position heads.
    pub fn forward(&self, input: &ModelInput) -> ITensor {
        let mut x = match (input, &self.embedding, &self.in_proj) {
            (ModelInput::Tokens(t), Some(emb), _) => emb.forward(t),
            (ModelInput::Features(f), _, Some(proj)) => proj.forward(f),
            (ModelInput::Features(f), None, None) => f.clone(),
            _ => panic!("input kind does not match model configuration"),
        };
        assert_eq!(x.dims()[1], self.cfg.dim, "input width mismatch");
        for b in &self.blocks {
            x = b.forward(&x, self.act_scale);
        }
        match self.cfg.head {
            TaskHead::PerPosition(_) => self.head.forward(&x),
            _ => {
                // Mean-pool over the sequence, then the head.
                let (n, d) = (x.dims()[0], x.dims()[1]);
                let mut pooled = ITensor::zeros(&[1, d]);
                for j in 0..d {
                    let s: i64 = (0..n).map(|i| x.at2(i, j)).sum();
                    pooled.data[j] = s / n as i64;
                }
                self.head.forward(&pooled)
            }
        }
    }

    /// Argmax class for classification heads.
    pub fn classify(&self, input: &ModelInput) -> usize {
        let logits = self.forward(input);
        logits
            .data
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .expect("non-empty logits")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mechanism;

    fn feat_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
        let mut rng = Xoshiro256::new(seed);
        ModelInput::Features(ITensor::random(
            &[cfg.seq_len, cfg.in_features],
            -100,
            100,
            &mut rng,
        ))
    }

    #[test]
    fn forward_shapes_all_mechanisms_and_heads() {
        for mech in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
            for (head, want) in [
                (TaskHead::Regress, vec![1, 1]),
                (TaskHead::Classify(10), vec![1, 10]),
                (TaskHead::PerPosition(5), vec![8, 5]),
            ] {
                let mut cfg = ModelConfig::small(mech, 8, 16);
                cfg.head = head;
                let m = QTransformer::random(cfg.clone(), 42);
                let out = m.forward(&feat_input(&cfg, 1));
                assert_eq!(out.dims(), want.as_slice(), "{mech:?} {head:?}");
            }
        }
    }

    #[test]
    fn token_model_forward() {
        let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 12, 16);
        cfg.vocab = 50;
        cfg.head = TaskHead::Classify(2);
        let m = QTransformer::random(cfg, 7);
        let out = m.forward(&ModelInput::Tokens(vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 49]));
        assert_eq!(out.dims(), &[1, 2]);
        let _cls = m.classify(&ModelInput::Tokens(vec![0; 12]));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
        let m1 = QTransformer::random(cfg.clone(), 9);
        let m2 = QTransformer::random(cfg.clone(), 9);
        let inp = feat_input(&cfg, 3);
        assert_eq!(m1.forward(&inp), m2.forward(&inp));
    }

    #[test]
    fn activations_stay_in_declared_bits() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 16, 16);
        let m = QTransformer::random(cfg.clone(), 5);
        let out = m.forward(&feat_input(&cfg, 11));
        // Output after requant should fit comfortably in 24 bits even in the
        // worst case (head accumulates over dim).
        assert!(out.check_bits(24).is_ok());
    }

    #[test]
    fn multilayer_stack_runs() {
        let mut cfg = ModelConfig::small(Mechanism::InhibitorSigned, 8, 8);
        cfg.n_layers = 3;
        let m = QTransformer::random(cfg.clone(), 2);
        let out = m.forward(&feat_input(&cfg, 13));
        assert_eq!(out.dims(), &[1, 1]);
    }

    #[test]
    fn multihead_blocks_run_for_all_mechanisms() {
        for mech in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
            let mut cfg = ModelConfig::small(mech, 8, 16);
            cfg.n_heads = 4;
            let m = QTransformer::random(cfg.clone(), 21);
            let out = m.forward(&feat_input(&cfg, 8));
            assert_eq!(out.dims(), &[1, 1], "{mech:?}");
        }
    }

    #[test]
    fn block_multihead_attention_is_slicewise_single_head_attention() {
        // The multi-head reference is *defined* as per-slice single-head
        // attention + concat; pin that the Block computes exactly it.
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 6, 8);
        let m = QTransformer::random(cfg, 31);
        let block = &m.blocks[0];
        let mut rng = Xoshiro256::new(17);
        let q = ITensor::random(&[6, 8], -40, 40, &mut rng);
        let k = ITensor::random(&[6, 8], -40, 40, &mut rng);
        let v = ITensor::random(&[6, 8], -40, 40, &mut rng);
        // n_heads = 1: the whole width in one head.
        assert_eq!(block.n_heads, 1);
        let single = block.attention(&q, &k, &v);
        assert_eq!(single, block.attn.forward(&q, &k, &v));
        // A 2-head clone of the same mechanism at half width.
        let mut cfg2 = ModelConfig::small(Mechanism::Inhibitor, 6, 8);
        cfg2.n_heads = 2;
        let m2 = QTransformer::random(cfg2, 31);
        let b2 = &m2.blocks[0];
        let got = b2.attention(&q, &k, &v);
        let lo = b2.attn.forward(&q.slice_cols(0, 4), &k.slice_cols(0, 4), &v.slice_cols(0, 4));
        let hi = b2.attn.forward(&q.slice_cols(4, 4), &k.slice_cols(4, 4), &v.slice_cols(4, 4));
        assert_eq!(got, ITensor::concat_cols(&[&lo, &hi]));
    }
}
