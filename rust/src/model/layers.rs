//! Quantized transformer layers (S3): linear, FFN, layer norm, embedding.
//!
//! The paper changes *only* the attention mechanism; "FFN and
//! normalization are left unchanged". These layers implement the standard
//! blocks in integer arithmetic with per-layer requantization so the whole
//! forward pass stays inside the declared activation bit-width.

use crate::quant::{FixedMult, QParams};
use crate::tensor::ITensor;

/// Quantized linear layer: `y = requant(x·Wᵀ + b)`.
///
/// Weights are integer codes at `w_scale`; the bias is pre-quantized to the
/// accumulator scale (`x_scale · w_scale`) so it adds directly onto the
/// matmul accumulator, and `requant` maps the accumulator back to the
/// output activation scale.
#[derive(Clone, Debug)]
pub struct QLinear {
    /// `[out, in]` weight codes.
    pub w: ITensor,
    /// `[out]` bias at accumulator scale.
    pub b: Vec<i64>,
    pub requant: FixedMult,
}

impl QLinear {
    pub fn new(w: ITensor, b: Vec<i64>, requant: FixedMult) -> Self {
        assert_eq!(w.rank(), 2);
        assert_eq!(w.dims()[0], b.len(), "bias length must match out features");
        QLinear { w, b, requant }
    }

    /// Build from float weights: quantize W to `w_bits`, bias to the
    /// accumulator scale, and derive the requant factor to land on
    /// `out_scale`.
    pub fn from_float(
        w: &crate::tensor::FTensor,
        b: &[f32],
        x_scale: f32,
        w_bits: u32,
        out_scale: f32,
    ) -> Self {
        let wq = QParams::fit_symmetric(w.data.iter().fold(0.0f32, |a, &x| a.max(x.abs())), w_bits);
        let wi = wq.quantize_tensor(w);
        let acc_scale = x_scale * wq.scale;
        let bi = b.iter().map(|&x| (x / acc_scale).round() as i64).collect();
        let requant = FixedMult::from_f64(acc_scale as f64 / out_scale as f64);
        QLinear::new(wi, bi, requant)
    }

    /// `x: [n, in] → [n, out]`.
    pub fn forward(&self, x: &ITensor) -> ITensor {
        let acc = x.matmul(&self.w.transpose2());
        let (n, out) = (acc.dims()[0], acc.dims()[1]);
        let mut y = acc;
        for i in 0..n {
            for j in 0..out {
                let v = y.data[i * out + j] + self.b[j];
                y.data[i * out + j] = self.requant.apply(v);
            }
        }
        y
    }
}

/// Feed-forward network, paper eq. 4: `H = (X·W1ᵀ + b1)⁺ · W2 + b2`.
#[derive(Clone, Debug)]
pub struct QFfn {
    pub fc1: QLinear,
    pub fc2: QLinear,
}

impl QFfn {
    pub fn forward(&self, x: &ITensor) -> ITensor {
        let h = self.fc1.forward(x).relu();
        self.fc2.forward(&h)
    }
}

/// Integer layer normalization.
///
/// Mean/variance are computed exactly in i64; the per-row `1/√var` factor
/// is data-dependent, so it cannot be a compile-time literal — we compute
/// it in double precision and apply it as a per-row fixed-point multiply.
/// (Under FHE the paper's benchmarked circuits cover the attention
/// mechanism; LN-under-FHE would use a PBS rsqrt table — see
/// `tfhe::ops::pbs_rsqrt` — but is not on the benchmarked path.)
#[derive(Clone, Debug)]
pub struct QLayerNorm {
    /// Learned gain per feature, code scale folded into `out_requant`.
    pub gamma_q: Vec<i64>,
    /// Learned shift per feature at output scale.
    pub beta_q: Vec<i64>,
    /// Output activation scale relative to the normalized (unit-variance)
    /// intermediate: out_code = normalized · gamma · (1/out_scale).
    pub inv_out_scale: f64,
    /// Scale of the gamma codes.
    pub gamma_scale: f64,
}

impl QLayerNorm {
    pub fn from_float(gamma: &[f32], beta: &[f32], out_scale: f32) -> Self {
        let gmax = gamma.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-6);
        let gq = QParams::fit_symmetric(gmax, 8);
        QLayerNorm {
            gamma_q: gamma.iter().map(|&g| gq.quantize(g)).collect(),
            beta_q: beta.iter().map(|&b| (b / out_scale).round() as i64).collect(),
            inv_out_scale: 1.0 / out_scale as f64,
            gamma_scale: gq.scale as f64,
        }
    }

    /// `x: [n, d]` codes at `x_scale` → codes at the configured out scale.
    /// `x_scale` is needed because normalization divides by the data std,
    /// which is itself at x_scale — the scales cancel except for rounding.
    pub fn forward(&self, x: &ITensor, _x_scale: f32) -> ITensor {
        let (n, d) = (x.dims()[0], x.dims()[1]);
        assert_eq!(d, self.gamma_q.len());
        let mut y = ITensor::zeros(&[n, d]);
        for i in 0..n {
            let row = &x.data[i * d..(i + 1) * d];
            let mean_num: i64 = row.iter().sum();
            // mean in code units (rounded)
            let mean = (mean_num as f64) / d as f64;
            let var = row
                .iter()
                .map(|&v| {
                    let c = v as f64 - mean;
                    c * c
                })
                .sum::<f64>()
                / d as f64;
            let inv_std = 1.0 / (var + 1e-5).sqrt();
            // normalized = (x − mean)·inv_std  (unitless, ~N(0,1))
            // out_code = normalized · gamma_q·gamma_scale · inv_out_scale + beta_q
            let m = FixedMult::from_f64(
                (inv_std * self.gamma_scale * self.inv_out_scale).max(1e-12),
            );
            for j in 0..d {
                let centered = ((row[j] as f64 - mean) * 256.0).round() as i64; // 8 frac bits
                let scaled = m.apply(centered * self.gamma_q[j]) >> 8;
                y.data[i * d + j] = scaled + self.beta_q[j];
            }
        }
        y
    }
}

/// Token embedding: lookup of integer code vectors.
#[derive(Clone, Debug)]
pub struct QEmbedding {
    /// `[vocab, dim]` codes.
    pub table: ITensor,
}

impl QEmbedding {
    pub fn forward(&self, tokens: &[usize]) -> ITensor {
        let (vocab, dim) = (self.table.dims()[0], self.table.dims()[1]);
        let mut out = ITensor::zeros(&[tokens.len(), dim]);
        for (i, &t) in tokens.iter().enumerate() {
            assert!(t < vocab, "token {t} out of vocab {vocab}");
            out.data[i * dim..(i + 1) * dim]
                .copy_from_slice(&self.table.data[t * dim..(t + 1) * dim]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FTensor;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn qlinear_matches_float_within_quant_error() {
        let mut rng = Xoshiro256::new(31);
        let (n, din, dout) = (4, 8, 6);
        let xf = FTensor::randn(&[n, din], 1.0, &mut rng);
        let wf = FTensor::randn(&[dout, din], 0.5, &mut rng);
        let bf: Vec<f32> = (0..dout).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
        let xq = QParams::fit_symmetric(4.0, 12);
        let lin = QLinear::from_float(&wf, &bf, xq.scale, 8, xq.scale);
        let y = xq.dequantize_tensor(&lin.forward(&xq.quantize_tensor(&xf)));
        // float reference
        let want = {
            let mut t = xf.matmul(&wf.transpose2());
            for i in 0..n {
                for j in 0..dout {
                    t.data[i * dout + j] += bf[j];
                }
            }
            t
        };
        let err = y.max_abs_diff(&want);
        assert!(err < 0.15, "err {err}");
    }

    #[test]
    fn ffn_relu_nonlinearity_applied() {
        // W1 = I, b1 very negative → ReLU kills everything → out = b2.
        let dim = 3;
        let mut eye = ITensor::zeros(&[dim, dim]);
        for i in 0..dim {
            eye.set(&[i, i], 1);
        }
        let fc1 = QLinear::new(eye.clone(), vec![-1000; dim], FixedMult::from_f64(1.0));
        let fc2 = QLinear::new(eye, vec![7; dim], FixedMult::from_f64(1.0));
        let ffn = QFfn { fc1, fc2 };
        let x = ITensor::from_vec(&[1, dim], vec![5, 10, 20]);
        let y = ffn.forward(&x);
        assert_eq!(y.data, vec![7, 7, 7]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Xoshiro256::new(77);
        let d = 16;
        let gamma = vec![1.0f32; d];
        let beta = vec![0.0f32; d];
        let out_scale = 0.05f32;
        let ln = QLayerNorm::from_float(&gamma, &beta, out_scale);
        let x = ITensor::random(&[4, d], -200, 200, &mut rng);
        let y = ln.forward(&x, 0.05);
        for i in 0..4 {
            let row: Vec<f64> =
                (0..d).map(|j| y.at2(i, j) as f64 * out_scale as f64).collect();
            let mean = row.iter().sum::<f64>() / d as f64;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d as f64;
            assert!(mean.abs() < 0.1, "mean {mean}");
            assert!((var - 1.0).abs() < 0.2, "var {var}");
        }
    }

    #[test]
    fn embedding_lookup() {
        let table = ITensor::from_vec(&[3, 2], vec![1, 2, 3, 4, 5, 6]);
        let emb = QEmbedding { table };
        let out = emb.forward(&[2, 0]);
        assert_eq!(out.data, vec![5, 6, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn embedding_bounds_checked() {
        let emb = QEmbedding { table: ITensor::zeros(&[3, 2]) };
        let _ = emb.forward(&[3]);
    }
}
