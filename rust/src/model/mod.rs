//! Quantized transformer model definition (S3): config, layers, blocks,
//! weight interchange with the Python build path.

pub mod config;
pub mod layers;
pub mod transformer;
pub mod weights;

pub use config::{ModelConfig, TaskHead};
pub use transformer::{ModelInput, QTransformer};
