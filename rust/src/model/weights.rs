//! Weight interchange (S12): a small self-describing binary format written
//! by `python/compile/aot.py` (`export_weights`) and loaded here. Floats
//! are stored; quantization happens at load time on the Rust side so the
//! integer pipeline has a single source of truth for code scales.
//!
//! Layout (little-endian):
//!   magic   8 bytes  b"INHWGT01"
//!   count   u32
//!   repeat count times:
//!     name_len u16, name utf-8 bytes
//!     rank     u8, dims u32 × rank
//!     data     f32 × prod(dims)

use crate::attention::{AttentionHead, AttnConfig};
use crate::model::config::ModelConfig;
use crate::model::layers::{QEmbedding, QFfn, QLayerNorm, QLinear};
use crate::model::transformer::{Block, QTransformer};
use crate::quant::{FixedMult, QParams};
use crate::tensor::FTensor;
use std::collections::BTreeMap;
use std::io::{Read, Write};

pub const MAGIC: &[u8; 8] = b"INHWGT01";

/// Named float tensors, as exported by the build path.
pub type WeightMap = BTreeMap<String, FTensor>;

/// Serialize a weight map.
pub fn save_weights(w: &WeightMap, mut out: impl Write) -> std::io::Result<()> {
    out.write_all(MAGIC)?;
    out.write_all(&(w.len() as u32).to_le_bytes())?;
    for (name, t) in w {
        let nb = name.as_bytes();
        out.write_all(&(nb.len() as u16).to_le_bytes())?;
        out.write_all(nb)?;
        out.write_all(&[t.rank() as u8])?;
        for &d in t.dims() {
            out.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize a weight map.
pub fn load_weights(mut inp: impl Read) -> std::io::Result<WeightMap> {
    let mut magic = [0u8; 8];
    inp.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad weight file magic {magic:?}"),
        ));
    }
    let mut u32b = [0u8; 4];
    inp.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b);
    let mut map = WeightMap::new();
    for _ in 0..count {
        let mut u16b = [0u8; 2];
        inp.read_exact(&mut u16b)?;
        let nlen = u16::from_le_bytes(u16b) as usize;
        let mut nb = vec![0u8; nlen];
        inp.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let mut rank = [0u8; 1];
        inp.read_exact(&mut rank)?;
        let mut dims = Vec::with_capacity(rank[0] as usize);
        for _ in 0..rank[0] {
            inp.read_exact(&mut u32b)?;
            dims.push(u32::from_le_bytes(u32b) as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = Vec::with_capacity(numel);
        let mut f32b = [0u8; 4];
        for _ in 0..numel {
            inp.read_exact(&mut f32b)?;
            data.push(f32::from_le_bytes(f32b));
        }
        map.insert(name, FTensor::from_vec(&dims, data));
    }
    Ok(map)
}

/// Load weights from a file path.
pub fn load_weights_file(path: &str) -> std::io::Result<WeightMap> {
    load_weights(std::io::BufReader::new(std::fs::File::open(path)?))
}

/// Save weights to a file path.
pub fn save_weights_file(w: &WeightMap, path: &str) -> std::io::Result<()> {
    save_weights(w, std::io::BufWriter::new(std::fs::File::create(path)?))
}

fn get<'a>(w: &'a WeightMap, name: &str) -> Result<&'a FTensor, String> {
    w.get(name).ok_or_else(|| format!("weight '{name}' missing from file"))
}

fn vec1(t: &FTensor) -> Vec<f32> {
    t.data.clone()
}

/// Build a quantized transformer from exported float weights.
///
/// Expected names (layer i): `block{i}.{ln1,ln2}.{gamma,beta}`,
/// `block{i}.{wq,wk,wv,wo}.{w,b}`, `block{i}.ffn.{fc1,fc2}.{w,b}`,
/// plus `embedding.table` or `in_proj.{w,b}`, and `head.{w,b}`.
pub fn build_model(cfg: &ModelConfig, w: &WeightMap) -> Result<QTransformer, String> {
    let act_scale = 4.0 / ((1i64 << (cfg.act_bits - 1)) - 1) as f32;
    let lin = |prefix: &str| -> Result<QLinear, String> {
        let wt = get(w, &format!("{prefix}.w"))?;
        let bt = get(w, &format!("{prefix}.b"))?;
        Ok(QLinear::from_float(wt, &vec1(bt), act_scale, cfg.weight_bits, act_scale))
    };
    let ln = |prefix: &str| -> Result<QLayerNorm, String> {
        let g = get(w, &format!("{prefix}.gamma"))?;
        let b = get(w, &format!("{prefix}.beta"))?;
        Ok(QLayerNorm::from_float(&vec1(g), &vec1(b), act_scale))
    };
    let embedding = if cfg.vocab > 0 {
        let t = get(w, "embedding.table")?;
        let qp = QParams::fit_symmetric(
            t.data.iter().fold(0.0f32, |a, &x| a.max(x.abs())),
            cfg.act_bits,
        );
        Some(QEmbedding { table: qp.quantize_tensor(t) })
    } else {
        None
    };
    let in_proj = if cfg.vocab == 0 { Some(lin("in_proj")?) } else { None };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let n_heads = cfg.n_heads.max(1);
    if cfg.dim % n_heads != 0 {
        return Err(format!("dim {} does not split into {n_heads} heads", cfg.dim));
    }
    for i in 0..cfg.n_layers {
        let p = format!("block{i}");
        // Heads attend d/n_heads-wide slices (γ = √d_head), matching
        // QTransformer::random and the fused encrypted plan.
        let mut acfg = AttnConfig::new(cfg.mechanism, cfg.seq_len, cfg.dim / n_heads);
        acfg.alpha = cfg.alpha;
        acfg.gamma = cfg.gamma;
        blocks.push(Block {
            ln1: ln(&format!("{p}.ln1"))?,
            wq: lin(&format!("{p}.wq"))?,
            wk: lin(&format!("{p}.wk"))?,
            wv: lin(&format!("{p}.wv"))?,
            wo: lin(&format!("{p}.wo"))?,
            attn: AttentionHead::build(acfg, act_scale),
            n_heads,
            ln2: ln(&format!("{p}.ln2"))?,
            ffn: QFfn { fc1: lin(&format!("{p}.ffn.fc1"))?, fc2: lin(&format!("{p}.ffn.fc2"))? },
            resid_requant: FixedMult::from_f64(0.5),
        });
    }
    let head = lin("head")?;
    Ok(QTransformer { cfg: cfg.clone(), act_scale, embedding, in_proj, blocks, head })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mechanism;
    use crate::model::config::TaskHead;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Xoshiro256::new(101);
        let mut w = WeightMap::new();
        w.insert("a.w".into(), FTensor::randn(&[3, 4], 1.0, &mut rng));
        w.insert("b".into(), FTensor::randn(&[7], 1.0, &mut rng));
        let mut buf = Vec::new();
        save_weights(&w, &mut buf).unwrap();
        let w2 = load_weights(&buf[..]).unwrap();
        assert_eq!(w, w2);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTMAGIC\x00\x00\x00\x00".to_vec();
        assert!(load_weights(&buf[..]).is_err());
    }

    #[test]
    fn build_model_from_synthetic_weights() {
        let mut rng = Xoshiro256::new(55);
        let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 8);
        cfg.head = TaskHead::Classify(3);
        let d = cfg.dim;
        let mut w = WeightMap::new();
        let mut lin = |name: &str, dout: usize, din: usize, rng: &mut Xoshiro256, w: &mut WeightMap| {
            w.insert(format!("{name}.w"), FTensor::randn(&[dout, din], 0.3, rng));
            w.insert(format!("{name}.b"), FTensor::zeros(&[dout]));
        };
        lin("in_proj", d, cfg.in_features, &mut rng, &mut w);
        for p in ["block0.wq", "block0.wk", "block0.wv", "block0.wo"] {
            lin(p, d, d, &mut rng, &mut w);
        }
        lin("block0.ffn.fc1", cfg.ffn_dim, d, &mut rng, &mut w);
        lin("block0.ffn.fc2", d, cfg.ffn_dim, &mut rng, &mut w);
        for p in ["block0.ln1", "block0.ln2"] {
            w.insert(format!("{p}.gamma"), FTensor::from_vec(&[d], vec![1.0; d]));
            w.insert(format!("{p}.beta"), FTensor::zeros(&[d]));
        }
        lin("head", 3, d, &mut rng, &mut w);
        let model = build_model(&cfg, &w).unwrap();
        let mut irng = Xoshiro256::new(1);
        let x = crate::tensor::ITensor::random(&[8, d], -50, 50, &mut irng);
        let out = model.forward(&crate::model::transformer::ModelInput::Features(x));
        assert_eq!(out.dims(), &[1, 3]);
    }

    #[test]
    fn missing_weight_is_reported_by_name() {
        let cfg = ModelConfig::small(Mechanism::Inhibitor, 4, 4);
        let err = match build_model(&cfg, &WeightMap::new()) {
            Err(e) => e,
            Ok(_) => panic!("expected an error for empty weights"),
        };
        assert!(err.contains("in_proj.w"), "{err}");
    }
}
